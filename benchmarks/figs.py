"""Paper-figure benchmarks (Sec. V-A), one function per figure.

Each returns a list of CSV rows ``name,value,derived`` and mirrors the
paper's comparison:  Fig.3 total utility vs #jobs; Fig.4 completion
timeliness; Fig.5 performance ratio vs the exact offline optimum;
Fig.6 sensitivity to inaccurate U/L estimates.
"""
from __future__ import annotations

import time
from typing import List, Optional

import numpy as np

from repro.core import OASiS, price_params_from_jobs
from repro.core.offline_opt import offline_optimum
from repro.sim import (make_cluster, make_jobs, scenarios, simulate,
                       simulate_reference)

SCHEDULERS = ["oasis", "fifo", "drf", "rrh", "dorm"]


def _stage_profiling_reset() -> bool:
    """True (and reset the accumulators) iff the fused engine's
    per-stage decision profiling is on (``REPRO_DECIDE_PROFILE=1``).
    The stage breakdown then lands in the tracked record as a
    ``decision.stages`` sub-record — diagnostic only, since profiling
    re-runs each DP launch and roughly doubles decision latency."""
    import os
    if os.environ.get("REPRO_DECIDE_PROFILE", "") in ("", "0"):
        return False
    from repro.core.schedule_jax import decide_profile_reset
    decide_profile_reset()
    return True


def _stage_profile_snapshot() -> dict:
    from repro.core.schedule_jax import decide_profile_snapshot
    return decide_profile_snapshot()


def fig3_total_utility(T: int = 100, H: int = 20, K: int = 20,
                       sizes=(20, 40, 60, 80)) -> List[str]:
    rows = []
    for n in sizes:
        cluster = make_cluster(T=T, H=H, K=K)
        jobs = make_jobs(n, T=T, seed=3, small=False)
        for name in SCHEDULERS:
            kw = dict(quantum=0) if name == "oasis" else {}
            t0 = time.perf_counter()
            r = simulate(cluster, jobs, scheduler=name, check=False, **kw)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(f"fig3_utility[{name};n={n}],{us:.0f},"
                        f"{r.total_utility:.2f}")
    return rows


def fig4_timeliness(T: int = 100, H: int = 20, K: int = 20,
                    n: int = 50) -> List[str]:
    """Mean |completion - target| over time-sensitive+critical jobs."""
    rows = []
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(n, T=T, seed=7, small=False, time_insensitive=0.0,
                     time_sensitive=0.5)
    for name in SCHEDULERS:
        kw = dict(quantum=0) if name == "oasis" else {}
        t0 = time.perf_counter()
        r = simulate(cluster, jobs, scheduler=name, check=False, **kw)
        us = (time.perf_counter() - t0) * 1e6
        gap = float(np.mean(np.abs(r.target_gap))) if r.target_gap else -1.0
        rows.append(f"fig4_timeliness[{name}],{us:.0f},{gap:.2f}")
    return rows


def fig5_perf_ratio(seeds=(0, 1, 2, 3, 4)) -> List[str]:
    """OPT / OASiS on exhaustively-solvable instances.  The paper (Fig. 5,
    T=10, ~80 servers) reports 1.1-1.5; we report two capacity regimes —
    paper-like (ample) and adversarially scarce."""
    rows = []
    for label, H, scale in [("ample", 3, 1.0), ("scarce", 2, 0.6)]:
        ratios = []
        for seed in seeds:
            cluster = make_cluster(T=6, H=H, K=H, scale=scale)
            jobs = make_jobs(5, T=6, seed=seed, small=True)
            # literal U/L values (the Theorem-4 setting)
            params = price_params_from_jobs(jobs, cluster, floor_frac=0.0)
            sched = OASiS(cluster, params)
            t0 = time.perf_counter()
            for j in sorted(jobs, key=lambda x: x.arrival):
                sched.on_arrival(j)
            us = (time.perf_counter() - t0) * 1e6
            opt = offline_optimum(cluster, jobs, time_limit=60.0)
            ratio = opt / sched.total_utility if sched.total_utility > 1e-9 \
                else 1.0
            ratios.append(ratio)
            rows.append(f"fig5_ratio[{label};seed={seed}],{us:.0f},{ratio:.3f}")
        rows.append(f"fig5_ratio[{label};mean],0,{float(np.mean(ratios)):.3f}")
    return rows


def fig6_estimates(T: int = 100, H: int = 20, K: int = 20,
                   n: int = 60, factors=(0.25, 0.5, 1.0, 2.0, 4.0)
                   ) -> List[str]:
    """OASiS with mis-estimated U/L ratios (paper: underestimation beats
    overestimation under scarcity)."""
    rows = []
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(n, T=T, seed=11, small=False)
    exact = price_params_from_jobs(jobs, cluster)
    for f in factors:
        params = exact.scaled(f)
        t0 = time.perf_counter()
        r = simulate(cluster, jobs, scheduler="oasis", params=params,
                     check=False, quantum=0)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"fig6_estimate[x{f}],{us:.0f},{r.total_utility:.2f}")
    return rows


def latency_table(T: int = 300, H: int = 50, K: int = 50, n: int = 20
                  ) -> List[str]:
    """Footnote-4 claim: <1 s per decision at T=100-300, 50+50 servers."""
    rows = []
    for quantum, label in [(0, "auto"), (1, "exact")]:
        cluster = make_cluster(T=T, H=H, K=K)
        jobs = make_jobs(n, T=T, seed=13, small=False)
        r = simulate(cluster, jobs, scheduler="oasis", check=False,
                     quantum=quantum)
        dec = np.array(r.decision_seconds)
        rows.append(f"latency[q={label};mean],{dec.mean()*1e6:.0f},"
                    f"{dec.mean():.4f}")
        rows.append(f"latency[q={label};p95],{np.percentile(dec,95)*1e6:.0f},"
                    f"{np.percentile(dec,95):.4f}")
    return rows


def sim_v2_speedup(T: int = 100, H: int = 20, K: int = 20, n: int = 60,
                   seed: int = 3, stats_out: Optional[dict] = None
                   ) -> List[str]:
    """fig3-shaped workload: v1 per-slot loop (seed placement path) vs the
    sim-v2 event engine, per reactive scheduler plus OASiS sim overhead
    (wall minus decision time; OASiS decisions are scheduler work shared
    by both drivers, so they are excluded from the engine's speedup)."""
    rows = []
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(n, T=T, seed=seed, small=False)
    agg = {"v1": 0.0, "v2": 0.0}
    stats = {} if stats_out is None else stats_out
    for name in ("fifo", "drf", "rrh", "dorm"):
        t0 = time.perf_counter()
        a = simulate_reference(cluster, jobs, scheduler=name, check=False)
        t1 = time.perf_counter()
        b = simulate(cluster, jobs, scheduler=name, check=False)
        t2 = time.perf_counter()
        assert a.completion == b.completion, f"sim v2 diverged on {name}"
        v1, v2 = t1 - t0, t2 - t1
        agg["v1"] += v1
        agg["v2"] += v2
        stats[name] = {"v1_seconds": v1, "v2_seconds": v2,
                       "speedup": v1 / max(v2, 1e-12)}
        rows.append(f"sim_v2[{name}],{v2*1e6:.0f},{v1/max(v2,1e-12):.2f}")
    for impl, fn in [("v1", simulate_reference), ("v2", simulate)]:
        t0 = time.perf_counter()
        r = fn(cluster, jobs, scheduler="oasis", check=False, quantum=0)
        over = time.perf_counter() - t0 - sum(r.decision_seconds)
        stats[f"oasis_overhead_{impl}_seconds"] = over
        rows.append(f"sim_v2[oasis_overhead;{impl}],{over*1e6:.0f},")
    speedup = agg["v1"] / max(agg["v2"], 1e-12)
    stats["reactive_total"] = {"v1_seconds": agg["v1"], "v2_seconds": agg["v2"],
                               "speedup": speedup}
    rows.append(f"sim_v2[reactive_total],{agg['v2']*1e6:.0f},{speedup:.2f}")
    return rows


def fig3_scale(quick: bool = False, include_oasis: bool = False,
               include_learned: bool = False,
               stats_out: Optional[dict] = None,
               dims: Optional[dict] = None,
               tag: str = "fig3_scale") -> List[str]:
    """fig3 at 10x the paper setting (T=500, 100+100 servers, 2000 jobs) on
    the sim-v2 engine; the v1 per-slot loop cannot finish this in
    reasonable time (see sim_v2_speedup for the controlled comparison).

    ``include_oasis=True`` adds the paper's own scheduler on the fused jit
    engine + device-resident price state (``impl="jax"``);
    ``include_learned=True`` adds the rl/ policy scheduler (untrained
    seed-init net — a decision-pipeline wall-clock column, not a quality
    claim; the trained-policy quality row lives in the ``rl`` section).
    ``stats_out`` receives machine-readable per-scheduler wall clocks,
    utilities, and — for plan-ahead schedulers — per-decision latency
    stats (the ``sim_scale`` record tracked in ``BENCH_decision.json`` —
    see ``benchmarks.run --only simscale``).  ``dims`` overrides the
    instance dimensions (e.g. ``scenarios.SCALE_DIMS_100X`` for the 100x
    record, with ``tag`` labelling its CSV rows)."""
    scheds = scenarios.ALL_SCHEDULERS if include_oasis else scenarios.REACTIVE
    if include_learned:
        scheds = tuple(scheds) + ("learned",)
    rows = []
    if dims is None:
        dims = scenarios.SCALE_DIMS_QUICK if quick else scenarios.SCALE_DIMS
    profiling = _stage_profiling_reset()
    results = scenarios.run_scale(seed=0, quick=quick, schedulers=scheds,
                                  T=dims["T"], H=dims["H"], K=dims["K"],
                                  n=dims["n"])
    for r in results:
        rows.append(f"{tag}[{r.scheduler};{r.variant}],"
                    f"{r.wall_seconds*1e6:.0f},{r.utility:.2f}")
        if r.decision_p50 is not None:
            rows.append(f"{tag}[{r.scheduler};decision_p50],"
                        f"{r.decision_p50*1e6:.0f},{r.decision_p50:.6f}")
            rows.append(f"{tag}[{r.scheduler};decision_mean],"
                        f"{r.decision_mean*1e6:.0f},{r.decision_mean:.6f}")
    if stats_out is not None:
        decision = {r.scheduler: {"p50": r.decision_p50,
                                  "mean": r.decision_mean,
                                  "p95": r.decision_p95}
                    for r in results if r.decision_p50 is not None}
        if profiling:
            decision["stages"] = _stage_profile_snapshot()
        stats_out.update({
            "T": dims["T"], "H": dims["H"], "K": dims["K"],
            "n_jobs": dims["n"], "quick": bool(quick),
            "wall_seconds": {r.scheduler: r.wall_seconds for r in results},
            "utility": {r.scheduler: r.utility for r in results},
            "decision": decision,
        })
    return rows


def rl_scoreboard(train_budget_seconds: float = 270.0,
                  iterations: int = 160, eval_seeds=(5, 6, 7),
                  quick: bool = False,
                  stats_out: Optional[dict] = None) -> List[str]:
    """The learned-scheduler acceptance row: train the rl/ policy for at
    most ``train_budget_seconds`` on CPU (REINFORCE + DL2-style warm
    start, paper-scale congested instances, training seeds disjoint from
    ``eval_seeds``) and evaluate greedy vs FIFO on the held-out seeded
    paper-scale instances.  ``--quick`` shrinks everything to a smoke
    (tiny instance, 2 iterations) whose numbers are pipeline checks, not
    quality claims.  ``stats_out`` receives the ``rl`` record for
    BENCH_decision.json."""
    from repro.rl.policy import PolicyConfig
    from repro.rl.train import TrainConfig, evaluate, smoke_config, train

    if quick:
        cfg, pcfg = smoke_config()
    else:
        cfg = TrainConfig(iterations=iterations,
                          budget_seconds=train_budget_seconds)
        pcfg = PolicyConfig()
    t0 = time.perf_counter()
    params, history = train(cfg, pcfg, log=None)
    train_seconds = time.perf_counter() - t0
    ev = evaluate(params, pcfg, eval_seeds, cfg=cfg,
                  schedulers=("learned", "fifo"))
    rows = []
    for name, stats in ev.items():
        rows.append(f"rl_scoreboard[{name};mean],0,"
                    f"{stats['mean_utility']:.2f}")
        for s, v in stats["per_seed"].items():
            rows.append(f"rl_scoreboard[{name};seed={s}],0,{v:.2f}")
    rows.append(f"rl_scoreboard[train],{train_seconds*1e6:.0f},"
                f"{len(history)}")
    if stats_out is not None:
        stats_out.update({
            "quick": bool(quick),
            "train_seconds": train_seconds,
            "train_iterations": len(history),
            "eval_seeds": [int(s) for s in eval_seeds],
            "instance": {"T": cfg.T, "H": cfg.H, "K": cfg.K,
                         "n_jobs": cfg.n_jobs},
            "utility": {name: stats["mean_utility"]
                        for name, stats in ev.items()},
            "per_seed": {name: stats["per_seed"]
                         for name, stats in ev.items()},
        })
    return rows


def serving_table(quick: bool = False,
                  stats_out: Optional[dict] = None) -> List[str]:
    """Continuous serving mode: every scheduler over the same seeded
    open-ended diurnal x bursty stream (``sim.workload.stream_jobs``)
    through the rolling-window engine (``sim.engine.run_stream``).

    The tracked record is throughput-shaped: sustained decisions/sec over
    the whole trace and the price-state's resident ``window_bytes`` (the
    peak-RSS proxy — constant in trace length by construction) next to the
    usual wall clock / utility / decision-latency columns.  ``stats_out``
    receives the ``serving`` (or, under ``quick``, ``serving_quick``)
    record for BENCH_decision.json."""
    profiling = _stage_profiling_reset()
    results = scenarios.run_serving(seed=0, quick=quick)
    rows = []
    for r in results:
        rows.append(f"serving[{r.scheduler};{r.variant}],"
                    f"{r.wall_seconds*1e6:.0f},{r.utility:.2f}")
        rows.append(f"serving[{r.scheduler};decisions_per_sec],0,"
                    f"{r.decisions_per_sec:.1f}")
        if r.decision_p50 is not None:
            rows.append(f"serving[{r.scheduler};decision_p50],"
                        f"{r.decision_p50*1e6:.0f},{r.decision_p50:.6f}")
    if stats_out is not None:
        dims = (scenarios.SERVING_DIMS_QUICK if quick
                else scenarios.SERVING_DIMS)
        decision = {r.scheduler: {"p50": r.decision_p50,
                                  "mean": r.decision_mean,
                                  "p95": r.decision_p95}
                    for r in results if r.decision_p50 is not None}
        if profiling:
            decision["stages"] = _stage_profile_snapshot()
        stats_out.update({
            "H": dims["H"], "K": dims["K"], "window": dims["window"],
            "slots": dims["slots"],
            "n_jobs": int(max(r.n_jobs for r in results)),
            "quick": bool(quick),
            "wall_seconds": {r.scheduler: r.wall_seconds for r in results},
            "utility": {r.scheduler: r.utility for r in results},
            "decisions_per_sec": {r.scheduler: r.decisions_per_sec
                                  for r in results},
            "window_bytes": {r.scheduler: r.window_bytes for r in results},
            "decision": decision,
        })
    return rows


def churn_table(quick: bool = False,
                stats_out: Optional[dict] = None) -> List[str]:
    """Fleet-churn robustness: utility retention (churned / churn-free
    utility, higher is better) per scheduler at each churn level of
    ``sim.scenarios.run_churn``, plus the preemption counters.  The
    churned runs execute with ``check=True`` — a capacity violation on
    the surviving fleet aborts the benchmark.  ``stats_out`` receives
    the ``churn`` (or, under ``quick``, ``churn_quick``) record for
    BENCH_decision.json."""
    results = scenarios.run_churn(seed=0, quick=quick)
    rows = []
    for r in results:
        rows.append(f"churn[{r.scheduler};{r.variant}],"
                    f"{r.wall_seconds*1e6:.0f},{r.utility:.2f}")
        if r.retention is not None:
            rows.append(f"churn[{r.scheduler};{r.variant};retention],0,"
                        f"{r.retention:.4f}")
            rows.append(f"churn[{r.scheduler};{r.variant};preempted],0,"
                        f"{r.preempted}")
    if stats_out is not None:
        dims = scenarios.CHURN_DIMS_QUICK if quick else scenarios.CHURN_DIMS
        wall: dict = {}
        utility: dict = {}
        retention: dict = {}
        preempted: dict = {}
        dropped: dict = {}
        for r in results:
            wall[r.scheduler] = wall.get(r.scheduler, 0.0) + r.wall_seconds
            utility.setdefault(r.scheduler, {})[r.variant] = r.utility
            if r.retention is not None:
                retention.setdefault(r.scheduler, {})[r.variant] = r.retention
                preempted.setdefault(r.scheduler, {})[r.variant] = r.preempted
                dropped.setdefault(r.scheduler, {})[r.variant] = \
                    r.preempt_dropped
        stats_out.update({
            "T": dims["T"], "H": dims["H"], "K": dims["K"],
            "n_jobs": dims["n"], "quick": bool(quick),
            "levels": [float(f) for f in dims["levels"]],
            "wall_seconds": wall,
            "utility": utility,
            "retention": retention,
            "preempted": preempted,
            "preempt_dropped": dropped,
        })
    return rows


def scenario_table(quick: bool = False,
                   names=("hetero", "cancel", "straggler", "misest")
                   ) -> List[str]:
    """One row per (scenario, scheduler, variant) from sim/scenarios.py."""
    rows = []
    for name in names:
        for r in scenarios.run_scenario(name, seed=0, quick=quick):
            rows.append(f"scenario[{r.scenario};{r.scheduler};{r.variant}],"
                        f"{r.wall_seconds*1e6:.0f},{r.utility:.2f}")
    return rows


def decision_latency(T: int = 96, H: int = 16, K: int = 16, n: int = 200,
                     stats_out: Optional[dict] = None) -> List[str]:
    """Per-decision scheduler latency (p50/p95 of ``decision_seconds``):
    seed per-slot-loop baseline vs vectorized numpy vs the fused jit engine.

    Each impl is run twice and the second (warm) run is reported — the jit
    engine compiles one executable per shape bucket on first contact, which
    a long-running scheduler amortises away; the one-off cost is reported
    separately as ``jax;cold_mean``.  The final row is the p50 speedup of
    impl="jax" over the seed per-slot-loop path.
    """
    rows = []
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(n, T=T, seed=17, small=False)
    stats = {} if stats_out is None else stats_out
    for impl in ("loop", "fast", "jax"):
        # every impl gets a discarded first run so the comparison is
        # symmetric (jit compiles; numpy warms allocator/page cache)
        cold = simulate(cluster, jobs, scheduler="oasis", impl=impl,
                        check=False, quantum=0)
        r = simulate(cluster, jobs, scheduler="oasis", impl=impl,
                     check=False, quantum=0)
        dec = np.array(r.decision_seconds)
        stats[impl] = {"p50": float(np.percentile(dec, 50)),
                       "p95": float(np.percentile(dec, 95)),
                       "mean": float(dec.mean())}
        for label, val in stats[impl].items():
            rows.append(f"decision_latency[{impl};{label}],{val*1e6:.0f},"
                        f"{val:.6f}")
        if impl == "jax":
            cm = float(np.mean(cold.decision_seconds))
            stats["jax_cold_mean_seconds"] = cm
            rows.append(f"decision_latency[jax;cold_mean],{cm*1e6:.0f},"
                        f"{cm:.6f}")
    for label in ("p50", "p95", "mean"):
        rows.append(f"decision_latency[speedup_jax_vs_seed;{label}],0,"
                    f"{stats['loop'][label] / stats['jax'][label]:.2f}")
    return rows
