"""Perf-regression gate over the tracked benchmark stats.

Compares a freshly-generated ``BENCH_decision.json`` against the
committed baseline and fails (exit 1) when any recorded p50 or
wall-clock figure regressed by more than ``--ratio`` (default 2x).

Compared leaves:

* ``decision_seconds.<impl>.p50`` — per-backend decision latency
* ``sim_v2.<sched>.v2_seconds`` and the ``oasis_overhead_v2_seconds``
  figures — the event engine's wall clocks (the v1 baseline's wall
  clock is informational, not a gate)
* ``sim_scale.wall_seconds.<sched>`` and
  ``sim_scale.decision.<sched>.p50`` — the 10x-scale run (incl. the
  oasis column's per-decision latency).  The ``sim_scale_quick`` CI
  smoke record is informational only — never gated (see
  ``SCALE_SECTIONS``)
* ``serving.wall_seconds.<sched>``, ``serving.decision.<sched>.p50``
  and — inverted, since higher is better — the sustained
  ``serving.decisions_per_sec.<sched>`` throughput of the continuous
  serving mode (gate fires when baseline/fresh exceeds the ratio, i.e.
  throughput dropped).  ``serving_quick`` is the CI smoke — never gated
  (see ``SERVING_SECTIONS``)
* ``minplus.<case>.p50`` — the structure-aware DP slot kernel
  micro-bench (chain vs monotone dispatch vs plateau); like the
  decision sections, a ``quick`` flag mismatch between baseline and
  fresh refuses the check.  The ``sim_scale``/``serving``
  ``decision.stages`` sub-record (per-stage profiling wall) is
  diagnostic and never gated
* ``churn.retention.<sched>.<variant>`` — utility retention under fleet
  churn, also inverted (higher is better): the gate fires when a
  scheduler keeps a ``ratio``-times smaller share of its churn-free
  utility than the baseline recorded.  ``churn_quick`` is the CI smoke
  — never gated (see ``CHURN_SECTIONS``)
* ``obs.derived.*`` — the flight-recorder probe's deterministic
  efficiency figures (schema v5): ``early_exit_frac`` and
  ``device_uploads`` gate lower-is-better, ``row_cache_hit_rate``
  inverted; a drift here is a semantic efficiency regression (the
  row cache stopped hitting, the early exit stopped firing, full-table
  uploads reappeared) even when wall clocks stay within ratio

A section is only ever compared against a like-configured baseline
(``quick`` flag for the decision sections; T/H/K/n_jobs dims for the
scale sections).  A configuration mismatch is an **error** (exit 2):
silently diffing a quick run against a full-mode baseline — or vice
versa — compares different workloads and means the caller's setup is
wrong.  Pass ``--allow-config-mismatch`` to downgrade mismatched
sections to a reported skip.  Improvements and sections missing from
one side are reported but never fail the gate.

Usage::

    python -m benchmarks.check_regression BASELINE FRESH [--ratio 2.0]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterator, Tuple

# baseline figures below this are treated as noise and skipped: a 2x
# ratio on a sub-millisecond wall clock is scheduler jitter, not a
# regression
MIN_BASELINE_SECONDS = 1e-3


# gated scale sections.  sim_scale_quick is deliberately NOT gated: it is
# the CI smoke (shrunk instance, jit-compile-heavy, ~90x p50/p95 in-run
# spread) regenerated on shared runners against a dev-machine baseline —
# a 2x wall-clock ratio there measures runner weather, not regressions.
# Its record is still written and uploaded for inspection.
SCALE_SECTIONS = ("sim_scale", "sim_scale_100x")

# gated serving sections.  serving_quick is the CI smoke (short streamed
# trace on shared runners) — informational only, same rationale as
# sim_scale_quick.
SERVING_SECTIONS = ("serving",)

# gated churn sections.  churn_quick is the CI smoke — informational
# only, same rationale as sim_scale_quick.  Retention is deterministic
# (seeded trace, seeded workload), so unlike the wall-clock leaves a
# drop here is a semantic robustness regression, not runner weather.
CHURN_SECTIONS = ("churn",)

# gated flight-recorder sections (schema v5): the obs probe's derived
# efficiency figures are deterministic counter ratios, so like churn
# retention a drift is semantic — the row cache stopped hitting, the
# early exit stopped firing, or full-table uploads reappeared on the
# commit path.  ``early_exit_frac`` / ``device_uploads`` are
# lower-is-better leaves; ``row_cache_hit_rate`` is higher-is-better
# (inverted like the throughputs).  ``preempted`` and the raw counter
# snapshot are informational — preemption counts track the churn
# workload, not an efficiency property.
OBS_SECTIONS = ("obs",)

# the gated derived leaves of the obs section, by direction
OBS_LEAVES = ("early_exit_frac", "device_uploads")
OBS_RATE_LEAVES = ("row_cache_hit_rate",)


def _leaves(doc: dict) -> Iterator[Tuple[str, float]]:
    """Yield (path, value) for every gated numeric leaf in ``doc``."""
    dec = doc.get("decision_seconds", {})
    for impl, stats in sorted(dec.items()):
        if isinstance(stats, dict) and "p50" in stats:
            yield f"decision_seconds.{impl}.p50", float(stats["p50"])
    sim = doc.get("sim_v2", {})
    for key, stats in sorted(sim.items()):
        if isinstance(stats, dict) and "v2_seconds" in stats:
            yield f"sim_v2.{key}.v2_seconds", float(stats["v2_seconds"])
        elif key.endswith("_v2_seconds") and isinstance(stats, (int, float)):
            yield f"sim_v2.{key}", float(stats)
    for section in SCALE_SECTIONS + SERVING_SECTIONS:
        scale = doc.get(section, {})
        for sched, wall in sorted(scale.get("wall_seconds", {}).items()):
            yield f"{section}.wall_seconds.{sched}", float(wall)
        for sched, stats in sorted(scale.get("decision", {}).items()):
            if sched == "stages":
                continue        # diagnostic sub-record, never gated
            if isinstance(stats, dict) and stats.get("p50") is not None:
                yield f"{section}.decision.{sched}.p50", float(stats["p50"])
    mp = doc.get("minplus", {})
    for case, stats in sorted(mp.items()):
        if isinstance(stats, dict) and stats.get("p50") is not None:
            yield f"minplus.{case}.p50", float(stats["p50"])
    for section in OBS_SECTIONS:
        derived = doc.get(section, {}).get("derived", {})
        for name in OBS_LEAVES:
            if name in derived:
                yield f"{section}.derived.{name}", float(derived[name])


def _rate_leaves(doc: dict) -> Iterator[Tuple[str, float]]:
    """Yield (path, value) for the gated HIGHER-is-better leaves
    (sustained throughputs); the gate inverts the ratio for these."""
    for section in SERVING_SECTIONS:
        srv = doc.get(section, {})
        for sched, dps in sorted(srv.get("decisions_per_sec", {}).items()):
            yield f"{section}.decisions_per_sec.{sched}", float(dps)
    for section in CHURN_SECTIONS:
        ch = doc.get(section, {})
        for sched, per_variant in sorted(ch.get("retention", {}).items()):
            if not isinstance(per_variant, dict):
                continue
            for variant, ret in sorted(per_variant.items()):
                yield f"{section}.retention.{sched}.{variant}", float(ret)
    for section in OBS_SECTIONS:
        derived = doc.get(section, {}).get("derived", {})
        for name in OBS_RATE_LEAVES:
            if name in derived:
                yield f"{section}.derived.{name}", float(derived[name])


def _section_quick(doc: dict, section: str):
    """Per-section quick flag (v2 schema), falling back to the v1
    top-level flag for old baselines."""
    sec = doc.get(section, {})
    if isinstance(sec, dict) and "quick" in sec:
        return bool(sec["quick"])
    return bool(doc.get("quick"))


def _config_mismatches(base: dict, fresh: dict) -> Dict[str, str]:
    """Section prefixes whose configurations differ.

    Comparing such leaves would diff different workloads (e.g. a
    ``--quick`` fresh run against a full-mode baseline): the caller
    decides whether that refuses the whole check (default) or merely
    skips the section (``--allow-config-mismatch``)."""
    skip: Dict[str, str] = {}
    for section in ("decision_seconds", "sim_v2", "minplus"):
        if not (base.get(section) and fresh.get(section)):
            continue            # missing on one side: MISS leaves, no refusal
        bq, fq = _section_quick(base, section), _section_quick(fresh, section)
        if bq != fq:
            skip[f"{section}."] = (
                f"quick flag differs (baseline={bq}, fresh={fq})")
    dim_sets = {section: ("T", "H", "K", "n_jobs", "quick")
                for section in SCALE_SECTIONS}
    dim_sets.update({section: ("H", "K", "window", "slots", "n_jobs",
                               "quick") for section in SERVING_SECTIONS})
    dim_sets.update({section: ("T", "H", "K", "n_jobs", "levels", "quick")
                     for section in CHURN_SECTIONS})
    dim_sets.update({section: ("T", "H", "K", "n_jobs", "quick")
                     for section in OBS_SECTIONS})
    for section, dims in dim_sets.items():
        bs, fs = base.get(section, {}), fresh.get(section, {})
        if bs and fs and any(bs.get(d) != fs.get(d) for d in dims):
            skip[f"{section}."] = (
                "dims differ (baseline "
                + "/".join(str(bs.get(d)) for d in dims) + " vs fresh "
                + "/".join(str(fs.get(d)) for d in dims) + ")")
    return skip


def check(base: dict, fresh: dict, ratio: float,
          allow_config_mismatch: bool = False) -> int:
    mismatched = _config_mismatches(base, fresh)
    if mismatched and not allow_config_mismatch:
        print("configuration mismatch between baseline and fresh run — "
              "refusing to diff different workloads:")
        for prefix, why in sorted(mismatched.items()):
            print(f"  {prefix}*: {why}")
        print("(re-run both sides with the same mode, or pass "
              "--allow-config-mismatch to skip the mismatched sections)")
        return 2
    fresh_leaves = dict(_leaves(fresh))
    failures = []
    compared = 0
    for path, bval in _leaves(base):
        skipped = next((why for pre, why in mismatched.items()
                        if path.startswith(pre)), None)
        if skipped is not None:
            print(f"SKIP  {path}: {skipped}")
            continue
        if path not in fresh_leaves:
            print(f"MISS  {path}: not in fresh run (not gated)")
            continue
        if bval < MIN_BASELINE_SECONDS:
            print(f"SKIP  {path}: baseline {bval:.2e}s below noise floor")
            continue
        fval = fresh_leaves[path]
        r = fval / bval
        compared += 1
        mark = "FAIL" if r > ratio else "ok  "
        print(f"{mark}  {path}: {bval:.4f}s -> {fval:.4f}s ({r:.2f}x)")
        if r > ratio:
            failures.append((path, r))
    # higher-is-better leaves (throughputs): invert the ratio so the gate
    # still fires on "r > ratio" when the fresh figure DROPPED
    fresh_rates = dict(_rate_leaves(fresh))
    for path, bval in _rate_leaves(base):
        skipped = next((why for pre, why in mismatched.items()
                        if path.startswith(pre)), None)
        if skipped is not None:
            print(f"SKIP  {path}: {skipped}")
            continue
        if path not in fresh_rates:
            print(f"MISS  {path}: not in fresh run (not gated)")
            continue
        if bval <= 0.0 or 1.0 / bval < MIN_BASELINE_SECONDS:
            # a baseline sustaining >1k decisions/sec spends sub-ms per
            # decision — same noise floor as the latency leaves
            print(f"SKIP  {path}: baseline {bval:.1f} below noise floor")
            continue
        fval = fresh_rates[path]
        r = bval / max(fval, 1e-12)
        compared += 1
        mark = "FAIL" if r > ratio else "ok  "
        print(f"{mark}  {path}: {bval:.4g} -> {fval:.4g} "
              f"({r:.2f}x drop)")
        if r > ratio:
            failures.append((path, r))
    if failures:
        print(f"\n{len(failures)} regression(s) beyond {ratio:.1f}x:")
        for path, r in failures:
            print(f"  {path}: {r:.2f}x")
        return 1
    print(f"\nno regressions beyond {ratio:.1f}x ({compared} figures compared)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_decision.json")
    ap.add_argument("fresh", help="freshly generated BENCH_decision.json")
    ap.add_argument("--ratio", type=float, default=2.0,
                    help="fail when fresh/baseline exceeds this (default 2)")
    ap.add_argument("--allow-config-mismatch", action="store_true",
                    help="skip (instead of refuse on) sections whose "
                         "configuration differs between the two files")
    args = ap.parse_args()
    with open(args.baseline) as fh:
        base = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    sys.exit(check(base, fresh, args.ratio, args.allow_config_mismatch))


if __name__ == "__main__":
    main()
