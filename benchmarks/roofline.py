"""Roofline analysis from the dry-run artifacts.

Reads experiments/dryrun/*.json (full-config compile: memory proof,
collective counts) and *.probe.json (scan-corrected FLOPs/bytes/
collective bytes — XLA cost analysis counts while-loop bodies once, so
per-layer costs are extrapolated from 1-/2-layer probe compiles).

Per (arch x shape x mesh) cell:
  compute_term    = FLOPs_total   / (chips * 197e12  bf16 FLOP/s)
  memory_term     = bytes_total   / (chips * 819e9   B/s HBM)
  collective_term = coll_bytes    / (chips * 50e9    B/s ICI per link)
  dominant        = argmax of the three
  model_flops     = 6 * N_active * tokens   (x3 for the backward pass is
                    included in HLO flops; the ratio uses train fwd+bwd)
  efficiency      = model_flops / FLOPs_total

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict

CHIP_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# N_active parameters (backbone, approx) for MODEL_FLOPS = 6*N_active*D
ACTIVE_PARAMS = {
    "whisper-large-v3": 1.54e9,
    "olmoe-1b-7b": 1.3e9,
    "deepseek-v3-671b": 37e9,
    "granite-34b": 33.7e9,
    "gemma2-27b": 27.2e9,
    "starcoder2-3b": 3.0e9,
    "gemma2-9b": 9.2e9,
    "mamba2-370m": 0.37e9,
    "pixtral-12b": 12.2e9,
    "zamba2-7b": 6.7e9,
}


def load_cells(root: Path, mesh: str = "single") -> Dict[str, Dict]:
    cells = {}
    for f in sorted(root.glob(f"*_{mesh}.json")):
        r = json.loads(f.read_text())
        tag = f"{r['arch']}|{r['shape']}"
        probe = root / (f.stem + ".probe.json")
        if probe.exists():
            p = json.loads(probe.read_text())
            r["flops_c"] = p["flops_corrected"]
            r["bytes_c"] = p["bytes_corrected"]
            r["coll_c"] = sum(p["collectives_corrected"].values())
        else:
            r["flops_c"] = r["flops"]
            r["bytes_c"] = r["bytes_accessed"]
            r["coll_c"] = sum(v for k, v in r["collectives"].items()
                              if k != "count")
        cells[tag] = r
    return cells


def model_flops(arch: str, shape_kind: str, seq: int, gbatch: int) -> float:
    n = ACTIVE_PARAMS[arch]
    if shape_kind == "train":
        return 6.0 * n * seq * gbatch
    if shape_kind == "prefill":
        return 2.0 * n * seq * gbatch
    return 2.0 * n * 1 * gbatch          # decode: one token per sequence


def analyse(cell: Dict) -> Dict:
    chips = cell["n_devices"]
    # cost_analysis numbers are per-device; probe-corrected values inherit
    # that convention -> totals = value * chips.
    flops_total = cell["flops_c"] * chips
    bytes_total = cell["bytes_c"] * chips
    coll_total = cell["coll_c"] * chips
    compute_t = flops_total / (chips * CHIP_FLOPS)
    memory_t = bytes_total / (chips * HBM_BW)
    coll_t = coll_total / (chips * ICI_BW)
    dom = max(("compute", compute_t), ("memory", memory_t),
              ("collective", coll_t), key=lambda kv: kv[1])[0]
    mf = model_flops(cell["arch"], cell["kind"], cell["seq"],
                     cell["global_batch"])
    eff = mf / flops_total if flops_total else 0.0
    bound = max(compute_t, memory_t, coll_t)
    ideal = mf / (chips * CHIP_FLOPS)
    frac = ideal / bound if bound > 0 else 0.0
    mem = cell["memory"]
    # donated caches alias their outputs: count them once
    per_dev_bytes = mem["argument_bytes"] + mem["temp_bytes"] + \
        max(0, mem["output_bytes"] - mem["alias_bytes"])
    return {
        "arch": cell["arch"], "shape": cell["shape"], "kind": cell["kind"],
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "dominant": dom, "model_flops": mf, "hlo_flops": flops_total,
        "efficiency": eff, "roofline_frac": frac,
        "mem_gib": per_dev_bytes / 2 ** 30,
        "fits_hbm": per_dev_bytes <= 16 * 2 ** 30,
    }


def table(root: str = "experiments/final", mesh: str = "single") -> str:
    cells = load_cells(Path(root), mesh)
    multi = load_cells(Path(root), "multi")
    lines = ["| arch | shape | compute s | memory s | coll s | dominant | "
             "MODEL/HLO | roofline frac | GiB/dev | fits | multi GiB |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for tag in sorted(cells):
        a = analyse(cells[tag])
        m_gib = ""
        if tag in multi:
            am = analyse(multi[tag])
            m_gib = f"{am['mem_gib']:.1f}{'' if am['fits_hbm'] else '!'}"
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.3e} | "
            f"{a['memory_s']:.3e} | {a['collective_s']:.3e} | "
            f"{a['dominant']} | {a['efficiency']:.2f} | "
            f"{a['roofline_frac']:.2f} | {a['mem_gib']:.1f} | "
            f"{'Y' if a['fits_hbm'] else 'N'} | {m_gib} |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Scheduler DP kernels: analytic arithmetic intensity
# ---------------------------------------------------------------------------

def dp_kernel_cells(T: int = 500, dc1: int = 65, d1: int = 4097,
                    runs: int = 13, dtype_bytes: int = 4) -> Dict[str, Dict]:
    """FLOPs / HBM bytes per horizon sweep for the min-plus slot kernels
    (``kernels/minplus``), at the 10x-scale shape by default.

    All variants stream the same HBM traffic — the (T, DC+1) COST rows
    once, the (D+1,) carry in and out per slot — because the plateau's
    doubling table and the chain's band window live in VMEM scratch.
    What differs is the FLOP count per slot:

    * chain: one fused add+min per band tap — ``2 * DC1 * D1``;
    * plateau (run-compressed): a ``log2(DC1)``-level doubling-table
      build over DC1+D1 lanes plus one add and two window mins per run —
      ``(DC1 + D1) * log2(DC1) + 3 * runs * D1`` (``runs`` defaults to
      the measured p50 run count of real COST rows, 13);
    * monotone D&C: candidate evaluations along the recursion —
      ``~2 * D1 * log2(DC1)``.

    The monotone sweep dispatches per row, so its cost sits between the
    plateau and chain cells depending on the workload's run structure.
    """
    import math
    lg = max(math.ceil(math.log2(max(dc1, 2))), 1)
    sweep_bytes = float(T * (dc1 + 2 * d1) * dtype_bytes)
    flops = {
        "minplus_chain": 2.0 * T * dc1 * d1,
        "minplus_plateau": float(T * ((dc1 + d1) * lg + 3 * runs * d1)),
        "minplus_dnc": 2.0 * T * d1 * lg,
    }
    cells = {}
    for name, fl in flops.items():
        cells[name] = {
            "flops": fl, "bytes": sweep_bytes,
            "intensity": fl / sweep_bytes,
            # v5e ridge point: below CHIP_FLOPS/HBM_BW flop/B a kernel
            # cannot be compute-bound no matter how well it is scheduled
            "bound": ("compute" if fl / sweep_bytes > CHIP_FLOPS / HBM_BW
                      else "memory"),
        }
    return cells


def dp_kernel_table(T: int = 500, dc1: int = 65, d1: int = 4097,
                    runs: int = 13) -> str:
    cells = dp_kernel_cells(T=T, dc1=dc1, d1=d1, runs=runs)
    lines = [f"| DP slot kernel (T={T}, DC={dc1 - 1}, D={d1 - 1}, "
             f"runs={runs}) | GFLOP/sweep | MiB/sweep | flop/B | bound |",
             "|---|---|---|---|---|"]
    for name in sorted(cells):
        c = cells[name]
        lines.append(f"| {name} | {c['flops'] / 1e9:.3f} | "
                     f"{c['bytes'] / 2 ** 20:.2f} | {c['intensity']:.1f} | "
                     f"{c['bound']} |")
    return "\n".join(lines)


def main() -> None:
    root = sys.argv[1] if len(sys.argv) > 1 else "experiments/final"
    print(table(root))
    print()
    print(dp_kernel_table())


if __name__ == "__main__":
    main()
