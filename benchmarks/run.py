"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig3_utility[...]   total job utility per scheduler x #jobs   (Fig. 3)
  fig3_scale[...]     fig3-shaped workload at 10x paper scale (sim v2)
  fig4_timeliness[..] mean |completion - target| per scheduler  (Fig. 4)
  fig5_ratio[...]     OPT / OASiS on exact-solvable instances   (Fig. 5)
  fig6_estimate[...]  utility under mis-estimated U/L           (Fig. 6)
  latency[...]        per-decision scheduler latency            (fn. 4)
  decision_latency[.] loop vs fast vs fused-jax backend p50/p95
  sim_v2[...]         event-engine vs v1 per-slot-loop wall clock
  scenario[...]       sim-v2 scenario library (hetero/cancel/...)
  minplus[...]        scheduler DP kernel micro-benchmarks

Machine-readable perf tracking (``--json``, default
``BENCH_decision.json``, schema ``bench_decision/v4``; v2/v3 baselines
are read compatibly): the ``decision`` section writes p50/p95 per backend
plus the sim-v2 wall-clock comparison, and the ``simscale`` section
times the 10x-scale fig3 run per scheduler *including OASiS itself* on
the fused jit engine + device-resident price state (``sim_scale``: wall
clock, utility, and decision p50/mean; always the full T=500 /
100+100-server / 2000-job instance — it is the tracked configuration,
so ``--quick`` does not shrink it).  ``simscale_quick`` records the
shrunk instance with the oasis column as a separate ``sim_scale_quick``
section — the CI smoke that exercises the streaming decision pipeline
on every PR.  ``serving`` records the continuous-traffic mode (the
>=20k-slot diurnal x bursty stream over the paper-scale fleet through
the rolling-window engine): sustained decisions/sec and the resident
``window_bytes`` memory proxy per scheduler; ``serving_quick`` is its
CI-smoke shrink.  ``churn`` records the fleet-churn robustness table
(per-scheduler utility **retention** — churned / churn-free utility,
higher is better — at each churn level of ``sim.scenarios.run_churn``,
plus preemption counters; churned runs execute with capacity checks
on); ``churn_quick`` is its CI-smoke shrink.  ``minplus`` records the
structure-aware DP slot kernel micro-bench (chain vs monotone dispatch
vs plateau across band widths, convex and adversarial rows); its
per-case p50s are regression-gated.  ``obs`` (schema v5) runs a seeded
OASiS-on-jax episode plus a reactive episode under fleet churn with the
``repro.obs`` flight recorder installed and records the counter
snapshot plus derived health figures (row-cache hit rate, early-exit
tile fraction, device uploads, preemptions) — the derived leaves are
regression-gated so a silent efficiency loss (cache stops hitting,
early exit stops firing, uploads reappear on the commit path) fails CI
even when wall clocks stay within ratio.  Under ``REPRO_DECIDE_PROFILE=1``
the ``simscale``/``serving`` sections additionally record the fused
engine's per-stage wall clock (row build / DP sweep / backtrack /
placement) as a ``decision.stages`` sub-record — diagnostic only
(profiling re-runs each DP launch).  Sections *merge* into an
existing ``--json`` file, so
the committed baseline can accumulate all records; CI regenerates the
file and fails on >2x regressions via
``python -m benchmarks.check_regression``.

``--quick`` shrinks the other sections' instance sizes.  The roofline
table is a separate consumer of the dry-run artifacts:
``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SECTIONS = ("fig3", "fig4", "fig5", "fig6", "latency", "decision",
            "simspeed", "scale", "simscale", "simscale_quick", "serving",
            "serving_quick", "churn", "churn_quick", "scenarios", "rl",
            "kernels", "minplus", "obs")


def _is_num(x) -> bool:
    import math
    return isinstance(x, (int, float)) and not isinstance(x, bool) \
        and math.isfinite(x)


def _num_dict(sec: str, name: str, d, problems) -> None:
    if not isinstance(d, dict) or not all(_is_num(v) for v in d.values()):
        problems.append(f"{sec}.{name}: expected dict of finite numbers")


def validate_tracked(payload: dict) -> list:
    """Structural validation of a bench_decision payload (v2..v5; v3
    added the ``serving``/``serving_quick`` sections, v4 added
    ``churn``/``churn_quick``, v5 adds the flight-recorder ``obs``
    section — readers stay backward-compatible with committed v2..v4
    baselines).

    Returns a list of problems (empty = valid).  ``_merge_json`` refuses
    to write an invalid file: a malformed section used to be caught only
    much later, by ``check_regression`` diffing against it — by which
    time the broken file was already committed as the baseline.

    >>> from benchmarks.run import validate_tracked
    >>> validate_tracked({"schema": "bench_decision/v5"})
    []
    >>> validate_tracked({"schema": "bench_decision/v5",
    ...                   "decision_seconds": {"jax": {"p50": 0.01}}})
    ['decision_seconds.jax: needs finite p50/p95/mean']
    """
    problems = []
    if payload.get("schema") not in ("bench_decision/v2",
                                     "bench_decision/v3",
                                     "bench_decision/v4",
                                     "bench_decision/v5"):
        problems.append(f"schema: expected 'bench_decision/v2'..'v5', "
                        f"got {payload.get('schema')!r}")
    known = {"schema", "platform", "python", "decision_seconds", "sim_v2",
             "sim_scale", "sim_scale_quick", "sim_scale_100x", "serving",
             "serving_quick", "churn", "churn_quick", "rl", "minplus",
             "obs"}
    for sec in sorted(set(payload) - known):
        problems.append(f"{sec}: unknown section (known: {sorted(known)})")

    def _section(name):
        """Present section, or None; a non-dict section is a problem,
        not an AttributeError (the baseline file on disk may be
        arbitrarily corrupted — that is what this validator guards)."""
        sec = payload.get(name)
        if sec is None or isinstance(sec, dict):
            return sec
        problems.append(f"{name}: expected dict section, "
                        f"got {type(sec).__name__}")
        return None

    dec = _section("decision_seconds")
    if dec is not None:
        for impl, stats in dec.items():
            if impl == "quick":
                if not isinstance(stats, bool):
                    problems.append("decision_seconds.quick: expected bool")
            elif isinstance(stats, dict):
                if not {"p50", "p95", "mean"} <= set(stats) or \
                        not all(_is_num(stats[k])
                                for k in ("p50", "p95", "mean")):
                    problems.append(f"decision_seconds.{impl}: needs "
                                    "finite p50/p95/mean")
            elif not _is_num(stats):
                problems.append(f"decision_seconds.{impl}: expected "
                                "stats dict or number")
    sim = _section("sim_v2")
    if sim is not None:
        for key, stats in sim.items():
            if key == "quick":
                continue
            if isinstance(stats, dict):
                _num_dict("sim_v2", key, stats, problems)
            elif not _is_num(stats):
                problems.append(f"sim_v2.{key}: expected number")
    for sec in ("sim_scale", "sim_scale_quick", "sim_scale_100x"):
        scale = _section(sec)
        if scale is None:
            continue
        for dim in ("T", "H", "K", "n_jobs"):
            if not isinstance(scale.get(dim), int):
                problems.append(f"{sec}.{dim}: expected int")
        _num_dict(sec, "wall_seconds", scale.get("wall_seconds"), problems)
        _num_dict(sec, "utility", scale.get("utility"), problems)
        decision = scale.get("decision") or {}
        if not isinstance(decision, dict):
            problems.append(f"{sec}.decision: expected dict")
            decision = {}
        for sched, stats in decision.items():
            if not isinstance(stats, dict) or not all(
                    v is None or _is_num(v) for v in stats.values()):
                problems.append(f"{sec}.decision.{sched}: expected dict of "
                                "numbers/nulls")
    for sec in ("serving", "serving_quick"):
        srv = _section(sec)
        if srv is None:
            continue
        for dim in ("H", "K", "window", "slots", "n_jobs"):
            if not isinstance(srv.get(dim), int):
                problems.append(f"{sec}.{dim}: expected int")
        for name in ("wall_seconds", "utility", "decisions_per_sec",
                     "window_bytes"):
            _num_dict(sec, name, srv.get(name), problems)
        decision = srv.get("decision") or {}
        if not isinstance(decision, dict):
            problems.append(f"{sec}.decision: expected dict")
            decision = {}
        for sched, stats in decision.items():
            if not isinstance(stats, dict) or not all(
                    v is None or _is_num(v) for v in stats.values()):
                problems.append(f"{sec}.decision.{sched}: expected dict of "
                                "numbers/nulls")
    for sec in ("churn", "churn_quick"):
        ch = _section(sec)
        if ch is None:
            continue
        for dim in ("T", "H", "K", "n_jobs"):
            if not isinstance(ch.get(dim), int):
                problems.append(f"{sec}.{dim}: expected int")
        levels = ch.get("levels")
        if not isinstance(levels, list) or not levels or \
                not all(_is_num(f) for f in levels):
            problems.append(f"{sec}.levels: expected non-empty list of "
                            "finite numbers")
        _num_dict(sec, "wall_seconds", ch.get("wall_seconds"), problems)
        for name in ("utility", "retention", "preempted", "preempt_dropped"):
            per_sched = ch.get(name)
            if not isinstance(per_sched, dict):
                problems.append(f"{sec}.{name}: expected dict")
                continue
            for sched, per_variant in per_sched.items():
                _num_dict(sec, f"{name}.{sched}", per_variant, problems)
    ob = _section("obs")
    if ob is not None:
        for dim in ("T", "H", "K", "n_jobs"):
            if not isinstance(ob.get(dim), int):
                problems.append(f"obs.{dim}: expected int")
        if not isinstance(ob.get("quick"), bool):
            problems.append("obs.quick: expected bool")
        _num_dict("obs", "counters", ob.get("counters"), problems)
        _num_dict("obs", "derived", ob.get("derived"), problems)
    mp = _section("minplus")
    if mp is not None:
        for case, stats in mp.items():
            if case == "quick":
                if not isinstance(stats, bool):
                    problems.append("minplus.quick: expected bool")
            elif not isinstance(stats, dict) or not _is_num(
                    stats.get("p50")):
                problems.append(f"minplus.{case}: needs finite p50")
    rl = _section("rl")
    if rl is not None:
        if not _is_num(rl.get("train_seconds")):
            problems.append("rl.train_seconds: expected finite number")
        _num_dict("rl", "utility", rl.get("utility"), problems)
        per_seed = rl.get("per_seed") or {}
        if not isinstance(per_seed, dict):
            problems.append("rl.per_seed: expected dict")
            per_seed = {}
        for name, per in per_seed.items():
            _num_dict("rl", f"per_seed.{name}", per, problems)
    return problems


def _merge_json(path: str, updates: dict) -> None:
    """Merge freshly-measured sections into the tracked stats file.

    Existing sections not re-measured this run are preserved, so e.g.
    ``--only simscale`` does not drop the decision-latency record.  Each
    section carries its own ``quick`` flag (sections can be measured
    under different modes), so there is no top-level one.  The merged
    payload is validated against the bench_decision schema BEFORE
    writing; a malformed section aborts the run instead of poisoning the
    committed baseline."""
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                prev = json.load(fh)
            if str(prev.get("schema", "")).startswith("bench_decision/"):
                payload = prev
        except (OSError, ValueError):
            pass
    payload.pop("quick", None)                  # v1 leftover
    payload.update(updates)
    payload.update({
        # always write the current version; reads accept v2..v4 baselines
        "schema": "bench_decision/v5",
        "platform": platform.platform(),
        "python": platform.python_version(),
    })
    problems = validate_tracked(payload)
    if problems:
        print(f"# NOT writing {path}: payload fails bench_decision "
              "validation:", file=sys.stderr)
        for p in problems:
            print(f"#   {p}", file=sys.stderr)
        raise SystemExit(1)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
    print(f"# wrote {path}", file=sys.stderr)


def _kernel_micro() -> list:
    import jax
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.minplus.ref import minplus_ref
    from repro.core.subroutine import minplus_band

    rows = []
    rng = np.random.default_rng(0)
    prev = jnp.asarray(rng.random(4096).astype(np.float32))
    row = jnp.asarray(rng.random(257).astype(np.float32))
    f = jax.jit(minplus_ref)
    f(row, prev)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f(row, prev)[0].block_until_ready()
    us = (time.perf_counter() - t0) / 20 * 1e6
    rows.append(f"minplus_xla[D=4096;DC=256],{us:.0f},")

    pnp = np.asarray(prev)
    rnp = np.asarray(row)
    t0 = time.perf_counter()
    for _ in range(20):
        minplus_band(pnp, rnp)
    us = (time.perf_counter() - t0) / 20 * 1e6
    rows.append(f"minplus_numpy[D=4096;DC=256],{us:.0f},")
    return rows


def _minplus_micro(quick: bool = False):
    """Chain vs monotone-dispatch vs plateau slot kernels across band
    widths, on certified-convex, staircase (few-run), and adversarial
    (many-run, non-convex) rows — the structure split real COST_t rows
    live on (see ``kernels/minplus/monotone.py``: real rows are
    staircases, so the plateau path is the one that matters and the
    convex D&C fires only on synthetic rows).

    Returns (CSV rows, tracked record): the record's per-case ``p50``
    (median of the timed reps, in seconds) is the leaf
    ``benchmarks.check_regression`` gates."""
    import jax
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.minplus.monotone import monotone_step, plateau_step
    from repro.kernels.minplus.tiled import minplus_chain_step

    rows_out = []
    tracked: dict = {"quick": bool(quick)}
    rng = np.random.default_rng(0)
    d1 = 1024 if quick else 4096
    reps = 5 if quick else 11
    chain = jax.jit(lambda r, p: minplus_chain_step(r[None], p[None])[0])
    mono = jax.jit(monotone_step)
    plat = jax.jit(plateau_step)
    prev = jnp.asarray((rng.random(d1) * 10).astype(np.float32))
    for dc1 in ((65,) if quick else (65, 513)):
        js = np.arange(dc1, dtype=np.float32)
        # integer-valued convex row: exact second difference 1 in f32
        convex = jnp.asarray(js * (js - 1.0) / 2.0)
        stair = jnp.asarray(np.repeat(
            (rng.random(max(dc1 // 8, 1)) * 5).astype(np.float32), 8)[:dc1])
        advers = jnp.asarray(rng.random(dc1).astype(np.float32))
        for name, fn, row in (("chain", chain, advers),
                              ("monotone_convex", mono, convex),
                              ("monotone_adversarial", mono, advers),
                              ("plateau_stair", plat, stair)):
            fn(row, prev).block_until_ready()
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                fn(row, prev).block_until_ready()
                times.append(time.perf_counter() - t0)
            p50 = sorted(times)[len(times) // 2]
            rows_out.append(f"minplus[{name};DC={dc1 - 1};D={d1 - 1}],"
                            f"{p50 * 1e6:.0f},")
            tracked[f"{name}_dc{dc1 - 1}"] = {"p50": p50}
    return rows_out, tracked


def _obs_probe(quick: bool = False):
    """Flight-recorder probe: one seeded OASiS episode on the fused jax
    engine plus one reactive episode under deterministic fleet churn,
    both run with a ``repro.obs`` recorder installed.

    Returns (CSV rows, tracked record).  The record carries the raw
    counter snapshot and four derived health figures:

    * ``row_cache_hit_rate``   — burst re-solve tiles served from the
      per-job ``RowCache`` (higher is better; gated inverted)
    * ``early_exit_frac``      — DP tiles actually visited / horizon
      tiles (lower is better: the monotone early-exit is working)
    * ``device_uploads``       — full-table host->device uploads on the
      commit path (lower is better: the slot-window add path holds)
    * ``preempted``            — checkpoint/restart preemptions under
      the seeded churn trace (deterministic; drift means the churn
      engine changed behaviour)

    All figures are deterministic in the seeds, so unlike the wall-clock
    leaves a drift here is semantic, not runner weather.
    """
    from repro import obs as obslib
    from repro.sim import engine
    from repro.sim.fleet import make_fleet_trace
    from repro.sim.workload import make_cluster, make_jobs

    # full mode needs T >= 3 TILE-slot blocks (TILE=64): with a 2-tile
    # horizon every commit dirties the visited tile and the row-cache
    # hit rate is identically zero — no signal to gate
    T, HK, n_jobs = (48, 6, 24) if quick else (192, 10, 64)
    cluster = make_cluster(T=T, H=HK, K=HK)
    jobs = make_jobs(n_jobs, T=T, seed=0, small=True)
    ob = obslib.Obs()
    t0 = time.perf_counter()
    engine.run(cluster, jobs, scheduler="oasis", impl="jax", obs=ob)
    # MTBF/MTTR scaled to the horizon so both modes see failures land on
    # RUNNING jobs (the scoreboard churn_trace at these dims fails
    # servers between the short jobs — zero preemptions, no signal)
    fleet = make_fleet_trace(cluster, seed=1, mtbf=T / 1.6, mttr=T / 12)
    engine.run(cluster, jobs, scheduler="dorm", fleet=fleet, obs=ob)
    wall = time.perf_counter() - t0
    c = dict(ob.metrics.snapshot()["counters"])
    tiles_total = c.get("decide.cache_tiles_total", 0.0)
    tiles_horizon = c.get("decide.tiles_horizon", 0.0)
    derived = {
        "row_cache_hit_rate": (c.get("decide.cache_tiles_valid", 0.0)
                               / tiles_total) if tiles_total else 0.0,
        "early_exit_frac": (c.get("decide.tiles_visited", 0.0)
                            / tiles_horizon) if tiles_horizon else 1.0,
        "device_uploads": c.get("price.device_uploads", 0.0),
        "preempted": c.get("engine.preemptions", 0.0),
    }
    tracked = {"T": T, "H": HK, "K": HK, "n_jobs": n_jobs,
               "quick": bool(quick), "counters": c, "derived": derived}
    rows = [f"obs_probe[jobs={n_jobs};T={T}],{wall * 1e6:.0f},"
            f"cache_hit={derived['row_cache_hit_rate']:.3f};"
            f"early_exit={derived['early_exit_frac']:.3f};"
            f"uploads={derived['device_uploads']:.0f};"
            f"preempted={derived['preempted']:.0f}"]
    return rows, tracked


def _setup_jax_cache() -> None:
    """Point jax at a persistent XLA compilation cache (honours an
    existing ``JAX_COMPILATION_CACHE_DIR``).  Wall-clock rows then measure
    the engine, not recompiles of bit-unchanged executables — and repeated
    bench runs become comparable instead of varying by several seconds of
    compile noise."""
    cache = os.environ.get("JAX_COMPILATION_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-jax")
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir", cache)
    except Exception:            # pragma: no cover - old jax / RO home
        pass


def main() -> None:
    _setup_jax_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: " + ",".join(SECTIONS))
    ap.add_argument("--json", default="BENCH_decision.json",
                    help="tracked stats file (bench_decision/v2): the "
                         "decision section records p50/p95 per backend + "
                         "sim-v2 wall clock, simscale records the "
                         "10x-scale per-scheduler wall clock; sections "
                         "merge into an existing file; empty disables")
    args = ap.parse_args()
    from benchmarks import figs

    which = set((args.only or ",".join(SECTIONS)).split(","))
    unknown = which - set(SECTIONS)
    if unknown:
        ap.error(f"unknown --only section(s): {sorted(unknown)}")
    rows = []
    t_all = time.time()
    if "fig3" in which:
        rows += figs.fig3_total_utility(
            sizes=(20, 40) if args.quick else (20, 40, 60, 80))
    if "fig4" in which:
        rows += figs.fig4_timeliness(n=30 if args.quick else 50)
    if "fig5" in which:
        rows += figs.fig5_perf_ratio(seeds=(0, 1) if args.quick
                                     else (0, 1, 2, 3, 4))
    if "fig6" in which:
        rows += figs.fig6_estimates(n=30 if args.quick else 60)
    if "latency" in which:
        rows += figs.latency_table(T=100 if args.quick else 300,
                                   n=10 if args.quick else 20)
    tracked: dict = {}
    if "decision" in which:
        dstats: dict = {}
        sstats: dict = {}
        rows += figs.decision_latency(n=60 if args.quick else 200,
                                      stats_out=dstats)
        rows += figs.sim_v2_speedup(
            **(dict(T=60, n=40) if args.quick else {}), stats_out=sstats)
        tracked["decision_seconds"] = {**dstats, "quick": bool(args.quick)}
        tracked["sim_v2"] = {**sstats, "quick": bool(args.quick)}
    if "simspeed" in which and "decision" not in which:
        rows += figs.sim_v2_speedup(
            **(dict(T=60, n=40) if args.quick else {}))
    if "scale" in which:
        rows += figs.fig3_scale(quick=args.quick)
    if "simscale" in which:
        # the tracked 10x configuration (incl. the oasis column on the
        # fused jit engine): never shrunk by --quick
        scstats: dict = {}
        rows += figs.fig3_scale(quick=False, include_oasis=True,
                                stats_out=scstats)
        tracked["sim_scale"] = scstats
        # the 100x rung (T=1000, 200+200 servers, 8000 jobs), oasis
        # included — the fused engine's scaling stays on the scoreboard
        from repro.sim import scenarios as _scen
        sc100: dict = {}
        rows += figs.fig3_scale(quick=False, include_oasis=True,
                                stats_out=sc100,
                                dims=_scen.SCALE_DIMS_100X,
                                tag="fig3_scale100x")
        tracked["sim_scale_100x"] = sc100
    if "simscale_quick" in which:
        # CI smoke: the shrunk scale instance with the oasis AND learned
        # columns, so the device-resident decision pipeline and the rl/
        # policy decision pipeline are exercised on every PR; kept as a
        # separate record (sim_scale_quick) so it is never diffed
        # against the full-instance baseline
        qstats: dict = {}
        rows += figs.fig3_scale(quick=True, include_oasis=True,
                                include_learned=True, stats_out=qstats)
        tracked["sim_scale_quick"] = qstats
    if "serving" in which:
        # the tracked continuous-serving configuration (>=20k-slot stream,
        # paper-scale fleet): never shrunk by --quick
        svstats: dict = {}
        rows += figs.serving_table(quick=False, stats_out=svstats)
        tracked["serving"] = svstats
    if "serving_quick" in which:
        # CI smoke: a short streamed trace through every scheduler; kept
        # as a separate record so it is never diffed against the
        # full-trace baseline
        sqstats: dict = {}
        rows += figs.serving_table(quick=True, stats_out=sqstats)
        tracked["serving_quick"] = sqstats
    if "churn" in which:
        # the tracked fleet-churn robustness configuration (full-size
        # jobs over the 40+40 fleet): never shrunk by --quick
        chstats: dict = {}
        rows += figs.churn_table(quick=False, stats_out=chstats)
        tracked["churn"] = chstats
    if "churn_quick" in which:
        # CI smoke: the shrunk churn instance through every scheduler
        # (capacity checks on under churn); kept as a separate record so
        # it is never diffed against the full-instance baseline
        cqstats: dict = {}
        rows += figs.churn_table(quick=True, stats_out=cqstats)
        tracked["churn_quick"] = cqstats
    if "rl" in which:
        # the learned-scheduler acceptance row: budgeted CPU training +
        # held-out eval vs FIFO (quality claim lives here; the
        # sim_scale_quick learned column is wall-clock only)
        rlstats: dict = {}
        rows += figs.rl_scoreboard(quick=args.quick, stats_out=rlstats)
        tracked["rl"] = rlstats
    if "minplus" in which:
        # structure-aware DP slot kernels (chain / monotone / plateau);
        # the tracked per-case p50s are regression-gated
        mp_rows, mp_tracked = _minplus_micro(quick=args.quick)
        rows += mp_rows
        tracked["minplus"] = mp_tracked
    if "obs" in which:
        # flight-recorder probe: deterministic efficiency counters
        # (row-cache hit rate, early-exit depth, uploads, preemptions)
        ob_rows, ob_tracked = _obs_probe(quick=args.quick)
        rows += ob_rows
        tracked["obs"] = ob_tracked
    if args.json and tracked:
        _merge_json(args.json, tracked)
    if "scenarios" in which:
        rows += figs.scenario_table(quick=args.quick)
    if "kernels" in which:
        rows += _kernel_micro()
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    print(f"# total benchmark wall time: {time.time()-t_all:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
