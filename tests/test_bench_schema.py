"""benchmarks/run.py bench_decision schema validation (v5; v2..v4
baselines read compatibly): a malformed section must abort the write
instead of poisoning the committed baseline (it used to surface only
later, via check_regression)."""
import json

import pytest

from benchmarks.run import _merge_json, validate_tracked


def _payload():
    return {
        "schema": "bench_decision/v5",
        "platform": "test", "python": "3",
        "decision_seconds": {
            "jax": {"p50": 0.01, "p95": 0.02, "mean": 0.012},
            "loop": {"p50": 0.05, "p95": 0.3, "mean": 0.09},
            "jax_cold_mean_seconds": 0.3,
            "quick": True,
        },
        "sim_v2": {"fifo": {"v1_seconds": 1.0, "v2_seconds": 0.2,
                            "speedup": 5.0},
                   "oasis_overhead_v2_seconds": 0.1, "quick": True},
        "sim_scale": {"T": 500, "H": 100, "K": 100, "n_jobs": 2000,
                      "quick": False,
                      "wall_seconds": {"fifo": 0.4, "oasis": 650.0},
                      "utility": {"fifo": 100.0, "oasis": 7000.0},
                      "decision": {"oasis": {"p50": 0.2, "mean": 0.3,
                                             "p95": None}}},
        "serving": {"H": 50, "K": 50, "window": 64, "slots": 20000,
                    "n_jobs": 4000, "quick": False,
                    "wall_seconds": {"fifo": 2.0, "oasis": 120.0},
                    "utility": {"fifo": 900.0, "oasis": 1000.0},
                    "decisions_per_sec": {"fifo": 2000.0, "oasis": 33.0},
                    "window_bytes": {"fifo": 0, "oasis": 256000},
                    "decision": {"oasis": {"p50": 0.02, "mean": 0.03,
                                           "p95": None}}},
        "churn": {"T": 100, "H": 40, "K": 40, "n_jobs": 120,
                  "quick": False, "levels": [0.05, 0.2],
                  "wall_seconds": {"fifo": 0.02, "oasis": 20.0},
                  "utility": {"fifo": {"none": 100.0, "frac=0.05": 100.0,
                                       "frac=0.2": 90.0}},
                  "retention": {"fifo": {"frac=0.05": 1.0,
                                         "frac=0.2": 0.9}},
                  "preempted": {"fifo": {"frac=0.05": 4, "frac=0.2": 35}},
                  "preempt_dropped": {"fifo": {"frac=0.05": 0,
                                               "frac=0.2": 0}}},
        "rl": {"quick": False, "train_seconds": 250.0,
               "train_iterations": 160, "eval_seeds": [5, 6, 7],
               "instance": {"T": 100, "H": 50, "K": 50, "n_jobs": 200},
               "utility": {"learned": 500.0, "fifo": 170.0},
               "per_seed": {"learned": {"5": 900.0},
                            "fifo": {"5": 300.0}}},
        "obs": {"T": 192, "H": 10, "K": 10, "n_jobs": 64, "quick": False,
                "counters": {"decide.decisions": 64,
                             "price.device_uploads": 1},
                "derived": {"row_cache_hit_rate": 0.03,
                            "early_exit_frac": 0.4,
                            "device_uploads": 1, "preempted": 2}},
    }


def test_valid_payload_passes():
    assert validate_tracked(_payload()) == []


def test_v2_schema_still_accepted():
    """Committed v2 baselines (without the serving/churn/obs sections)
    must keep validating — the v3..v5 bumps are read-compatible."""
    p = _payload()
    p["schema"] = "bench_decision/v2"
    del p["serving"]
    del p["churn"]
    del p["obs"]
    assert validate_tracked(p) == []


def test_v3_schema_still_accepted():
    """Committed v3 baselines (without the churn/obs sections) must keep
    validating — the v4/v5 bumps are read-compatible."""
    p = _payload()
    p["schema"] = "bench_decision/v3"
    del p["churn"]
    del p["obs"]
    assert validate_tracked(p) == []


def test_v4_schema_still_accepted():
    """Committed v4 baselines (without the obs section) must keep
    validating — the v5 bump is read-compatible."""
    p = _payload()
    p["schema"] = "bench_decision/v4"
    del p["obs"]
    assert validate_tracked(p) == []


def test_wrong_schema_flagged():
    p = _payload()
    p["schema"] = "bench_decision/v1"
    assert any("schema" in x for x in validate_tracked(p))


def test_unknown_section_flagged():
    p = _payload()
    p["sim_scael"] = {"oops": 1}                  # typo'd section name
    assert any("sim_scael" in x for x in validate_tracked(p))


def test_nan_and_non_numeric_leaves_flagged():
    p = _payload()
    p["sim_scale"]["wall_seconds"]["fifo"] = float("nan")
    assert any("sim_scale.wall_seconds" in x for x in validate_tracked(p))
    p = _payload()
    p["decision_seconds"]["jax"] = {"p50": "fast"}
    assert any("decision_seconds.jax" in x for x in validate_tracked(p))
    p = _payload()
    del p["decision_seconds"]["jax"]["p95"]       # incomplete stats
    assert any("decision_seconds.jax" in x for x in validate_tracked(p))


def test_scale_dims_type_checked():
    p = _payload()
    p["sim_scale"]["T"] = "500"
    assert any("sim_scale.T" in x for x in validate_tracked(p))


def test_serving_section_checked():
    p = _payload()
    p["serving"]["window"] = "64"
    assert any("serving.window" in x for x in validate_tracked(p))
    p = _payload()
    p["serving"]["decisions_per_sec"]["oasis"] = float("inf")
    assert any("serving.decisions_per_sec" in x
               for x in validate_tracked(p))
    p = _payload()
    p["serving"]["window_bytes"] = [0]
    assert any("serving.window_bytes" in x for x in validate_tracked(p))
    p = _payload()
    p["serving"]["decision"]["oasis"] = {"p50": "slow"}
    assert any("serving.decision.oasis" in x for x in validate_tracked(p))
    p = _payload()
    p["serving_quick"] = {**p.pop("serving"), "quick": True}
    assert validate_tracked(p) == []


def test_churn_section_checked():
    p = _payload()
    p["churn"]["T"] = "100"
    assert any("churn.T" in x for x in validate_tracked(p))
    p = _payload()
    p["churn"]["levels"] = []
    assert any("churn.levels" in x for x in validate_tracked(p))
    p = _payload()
    p["churn"]["levels"] = [0.05, "lots"]
    assert any("churn.levels" in x for x in validate_tracked(p))
    p = _payload()
    p["churn"]["retention"]["fifo"]["frac=0.2"] = float("nan")
    assert any("churn.retention.fifo" in x for x in validate_tracked(p))
    p = _payload()
    p["churn"]["retention"] = [0.9]
    assert any("churn.retention" in x for x in validate_tracked(p))
    p = _payload()
    p["churn"]["preempted"]["fifo"] = 35            # not nested per-variant
    assert any("churn.preempted.fifo" in x for x in validate_tracked(p))
    p = _payload()
    p["churn_quick"] = {**p.pop("churn"), "quick": True}
    assert validate_tracked(p) == []


def test_obs_section_checked():
    p = _payload()
    p["obs"]["T"] = "192"
    assert any("obs.T" in x for x in validate_tracked(p))
    p = _payload()
    p["obs"]["quick"] = "no"
    assert any("obs.quick" in x for x in validate_tracked(p))
    p = _payload()
    p["obs"]["counters"]["decide.decisions"] = float("nan")
    assert any("obs.counters" in x for x in validate_tracked(p))
    p = _payload()
    p["obs"]["derived"] = [0.03]
    assert any("obs.derived" in x for x in validate_tracked(p))


def test_corrupted_non_dict_sections_report_instead_of_raising():
    """The baseline file on disk can be arbitrarily corrupted (that is
    the validator's whole job) — a non-dict section must come back as a
    problem, never as an AttributeError."""
    for bad in ("corrupted", [1], 3):
        for sec in ("decision_seconds", "sim_v2", "sim_scale", "serving",
                    "churn", "rl", "obs"):
            p = {"schema": "bench_decision/v5", sec: bad}
            assert any(sec in x for x in validate_tracked(p))
    p = _payload()
    p["rl"]["per_seed"] = [1]
    assert any("rl.per_seed" in x for x in validate_tracked(p))
    p = _payload()
    p["sim_scale"]["decision"] = [1]
    assert any("sim_scale.decision" in x for x in validate_tracked(p))


def test_rl_section_checked():
    p = _payload()
    p["rl"]["train_seconds"] = None
    assert any("rl.train_seconds" in x for x in validate_tracked(p))
    p = _payload()
    p["rl"]["per_seed"]["learned"]["5"] = "big"
    assert any("per_seed.learned" in x for x in validate_tracked(p))


def test_merge_json_refuses_malformed_sections(tmp_path):
    path = tmp_path / "bench.json"
    good = {"sim_scale": _payload()["sim_scale"]}
    _merge_json(str(path), good)                  # writes fine
    assert json.loads(path.read_text())["sim_scale"]["T"] == 500
    before = path.read_text()
    bad = {"sim_scale": {**_payload()["sim_scale"],
                         "wall_seconds": {"fifo": float("nan")}}}
    with pytest.raises(SystemExit):
        _merge_json(str(path), bad)
    assert path.read_text() == before             # baseline untouched


def test_merge_json_merges_and_preserves_sections(tmp_path):
    path = tmp_path / "bench.json"
    _merge_json(str(path), {"sim_scale": _payload()["sim_scale"]})
    _merge_json(str(path), {"rl": _payload()["rl"]})
    doc = json.loads(path.read_text())
    assert "sim_scale" in doc and "rl" in doc     # sections accumulate
    assert doc["schema"] == "bench_decision/v5"


def test_merge_json_upgrades_old_baselines(tmp_path):
    """Merging fresh sections into a committed v2..v4 file keeps its
    sections and rewrites the schema tag as v5."""
    path = tmp_path / "bench.json"
    v2 = _payload()
    v2["schema"] = "bench_decision/v2"
    del v2["serving"]
    del v2["churn"]
    del v2["obs"]
    path.write_text(json.dumps(v2))
    _merge_json(str(path), {"serving": _payload()["serving"]})
    doc = json.loads(path.read_text())
    assert doc["schema"] == "bench_decision/v5"
    assert "sim_scale" in doc and "serving" in doc
    _merge_json(str(path), {"obs": _payload()["obs"]})
    doc = json.loads(path.read_text())
    assert doc["schema"] == "bench_decision/v5"
    assert "serving" in doc and "obs" in doc
