"""Flight-recorder observability layer (repro.obs).

Pins the four contracts the subsystem makes:

* disabled (the default) is a true no-op — runs are bit-identical with
  and without an installed recorder, nothing is emitted while no
  recorder is active, and the disabled hot-path helpers are cheap;
* enabled runs record a well-formed trace: Chrome-trace export carries
  the required fields, spans nest, the ring bounds memory with explicit
  drop accounting;
* the metrics registry round-trips snapshot()/reset() and validates;
* the engine integration (`run(..., obs=)` / `run_stream(..., obs=)`)
  populates the documented span/counter catalog and restores the
  module-global disabled state on return.
"""
import json
import time

import numpy as np
import pytest

from repro import obs as obslib
from repro.obs.metrics import Histogram, Registry
from repro.obs.trace import NULL_SPAN, Tracer
from repro.sim import engine
from repro.sim.fleet import make_fleet_trace
from repro.sim.workload import make_cluster, make_jobs


@pytest.fixture(autouse=True)
def _no_leak():
    """Every test must leave the process-global recorder uninstalled."""
    yield
    assert obslib.ENABLED is False
    assert obslib.current() is None


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_spans_nest_and_record_duration():
    tr = Tracer()
    with tr.span("outer", jid=1):
        with tr.span("inner"):
            time.sleep(0.001)
    evs = list(tr.events())
    # inner exits (and records) first
    assert [e["name"] for e in evs] == ["inner", "outer"]
    inner, outer = evs
    assert inner["depth"] == 1 and outer["depth"] == 0
    assert outer["dur_us"] >= inner["dur_us"] > 0
    # inner lies within outer's window
    assert outer["ts_us"] <= inner["ts_us"]
    assert (inner["ts_us"] + inner["dur_us"]
            <= outer["ts_us"] + outer["dur_us"])
    assert outer["args"] == {"jid": 1}


def test_span_set_merges_attrs():
    tr = Tracer()
    with tr.span("s", a=1) as sp:
        sp.set(b=2)
    (ev,) = tr.events()
    assert ev["args"] == {"a": 1, "b": 2}


def test_ring_bounds_memory_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["s6", "s7", "s8", "s9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_chrome_export_schema(tmp_path):
    tr = Tracer()
    with tr.span("decide", jid=7, impl="jax"):
        with tr.span("dp_sweep", arr=np.arange(3)):   # non-scalar arg
            pass
    tr.instant("jit_cold_compile", T_pad=128)
    path = tmp_path / "trace.json"
    n = tr.export_chrome(str(path), metrics={"counters": {"x": 1}})
    assert n == 3
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert doc["metrics"] == {"counters": {"x": 1}}
    evs = doc["traceEvents"]
    assert len(evs) == 3
    for ev in evs:
        assert set(ev) >= {"name", "cat", "ph", "ts", "pid", "tid"}
        assert ev["cat"] == "repro"
        assert isinstance(ev["ts"], (int, float))
    complete = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert len(complete) == 2 and len(instants) == 1
    for ev in complete:
        assert ev["dur"] >= 0
    assert instants[0]["s"] == "t"
    # args must be JSON scalars (non-scalars stringified)
    for ev in evs:
        for v in ev.get("args", {}).values():
            assert isinstance(v, (int, float, bool, str, type(None)))
    # nesting well-formed: child window inside parent window
    by_name = {e["name"]: e for e in complete}
    parent, child = by_name["decide"], by_name["dp_sweep"]
    assert parent["ts"] <= child["ts"]
    assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"] + 1e-6


def test_jsonl_export_round_trips(tmp_path):
    tr = Tracer()
    with tr.span("a", k="v"):
        pass
    path = tmp_path / "t.jsonl"
    assert tr.export_jsonl(str(path)) == 1
    (line,) = path.read_text().splitlines()
    ev = json.loads(line)
    assert ev["name"] == "a" and ev["args"] == {"k": "v"}


def test_dropped_events_recorded_in_chrome_export(tmp_path):
    tr = Tracer(capacity=2)
    for i in range(5):
        tr.instant(f"e{i}")
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["otherData"] == {"dropped_events": 3}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counters_gauges_histograms_snapshot_roundtrip():
    reg = Registry()
    reg.inc("a")
    reg.inc("a", 2)
    reg.set_gauge("g", 0.5)
    reg.observe("h", 0.002)
    reg.observe("h", 5.0)
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"g": 0.5}
    h = snap["histograms"]["h"]
    assert h["count"] == 2
    assert h["sum"] == pytest.approx(5.002)
    assert sum(h["counts"]) == 2
    assert len(h["counts"]) == len(h["edges"]) + 1   # +Inf overflow
    # snapshot is a deep copy: mutating it does not touch the registry
    snap["counters"]["a"] = 99
    assert reg.snapshot()["counters"]["a"] == 3
    reg.reset()
    empty = reg.snapshot()
    assert empty == {"counters": {}, "gauges": {}, "histograms": {}}


def test_histogram_buckets_cover_range():
    h = Histogram(edges=(0.1, 1.0))
    for v in (0.05, 0.5, 50.0):
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 3
    assert d["counts"] == [1, 1, 1]        # <=0.1, (0.1,1.0], +Inf
    assert d["sum"] == pytest.approx(50.55)
    with pytest.raises(ValueError):
        Histogram(edges=(1.0, 0.1))        # unsorted edges refused


def test_registry_validate_flags_non_finite():
    reg = Registry()
    reg.inc("ok")
    assert reg.validate() == []
    reg.set_gauge("bad", float("nan"))
    assert any("bad" in p for p in reg.validate())


# ---------------------------------------------------------------------------
# activation + disabled-mode contract
# ---------------------------------------------------------------------------

def test_disabled_helpers_are_noops():
    assert obslib.span("x") is NULL_SPAN
    with obslib.span("x") as sp:
        sp.set(a=1)                         # must not raise
    obslib.inc("c")
    obslib.observe("h", 1.0)
    obslib.set_gauge("g", 1.0)
    obslib.event("e")
    assert obslib.current() is None and obslib.ENABLED is False


def test_activate_scopes_and_restores():
    ob = obslib.Obs()
    with obslib.activate(ob):
        assert obslib.ENABLED and obslib.current() is ob
        obslib.inc("k")
        inner = obslib.Obs()
        with obslib.activate(inner):        # nested install
            assert obslib.current() is inner
        assert obslib.current() is ob       # restored, still enabled
        assert obslib.ENABLED
    assert obslib.ENABLED is False and obslib.current() is None
    assert ob.metrics.snapshot()["counters"] == {"k": 1}
    # activate(None) is a passthrough that changes nothing
    with obslib.activate(None) as got:
        assert got is None and obslib.ENABLED is False


def test_enable_disable_process_global():
    ob = obslib.enable()
    try:
        assert obslib.ENABLED and obslib.current() is ob
        obslib.inc("n")
    finally:
        obslib.disable()
    assert ob.metrics.snapshot()["counters"] == {"n": 1}


def test_disabled_overhead_micro_pin():
    """The disabled fast path must stay allocation-free and cheap: one
    module-global read per emission.  Pinned loosely (50x a float add)
    so real regressions (dict lookups, object churn) fail while CI
    scheduler noise does not."""
    N = 20000
    t0 = time.perf_counter()
    acc = 0.0
    for _ in range(N):
        acc += 1.0
    base = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(N):
        obslib.inc("c")
        obslib.span("s")
    cost = time.perf_counter() - t0
    assert cost < max(50 * base, 0.05), (cost, base)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _instance(T=24, HK=3, n=8):
    cluster = make_cluster(T=T, H=HK, K=HK)
    return cluster, make_jobs(n, T=T, seed=0, small=True)


def test_enabled_run_bit_identical_and_emits_catalog():
    cluster, jobs = _instance()
    r0 = engine.run(cluster, jobs, scheduler="oasis", impl="fast")
    ob = obslib.Obs()
    r1 = engine.run(cluster, jobs, scheduler="oasis", impl="fast", obs=ob)
    assert r0.summary() == r1.summary()
    assert r0.completion == r1.completion
    c = ob.metrics.snapshot()["counters"]
    assert c["decide.decisions"] == r1.accepted + (r1.n_jobs - r1.accepted)
    assert c["engine.arrivals"] == r1.n_jobs
    assert c["price.commits"] == r1.accepted
    names = {e["name"] for e in ob.tracer.events()}
    assert {"decide", "price.commit"} <= names
    hist = ob.metrics.snapshot()["histograms"]["decide.seconds"]
    assert hist["count"] == c["decide.decisions"]


def test_disabled_run_emits_nothing():
    cluster, jobs = _instance()
    ob = obslib.Obs()
    with obslib.activate(ob):
        pass                                # installed, but no run inside
    engine.run(cluster, jobs, scheduler="oasis", impl="fast")
    assert len(ob.tracer) == 0
    assert ob.metrics.snapshot()["counters"] == {}


def test_reactive_run_records_repack_and_ffwd():
    cluster, jobs = _instance()
    ob = obslib.Obs()
    r = engine.run(cluster, jobs, scheduler="drf", obs=ob)
    c = ob.metrics.snapshot()["counters"]
    assert c["engine.completions"] == r.completed
    assert c["engine.ffwd_slots"] >= 1
    names = {e["name"] for e in ob.tracer.events()}
    assert {"repack", "ffwd"} <= names
    # satellite: reactive repack wall time is the per-decision latency
    assert len(r.decision_seconds) >= 1
    assert all(d >= 0 for d in r.decision_seconds)


def test_churn_run_records_preemptions_and_live_frac():
    # bigger instance than the default: enough live jobs that a seeded
    # failure actually lands on one (the bench obs probe's quick dims)
    cluster, jobs = _instance(T=48, HK=6, n=24)
    fleet = make_fleet_trace(cluster, seed=1, mtbf=cluster.T / 1.6,
                             mttr=cluster.T / 12)
    ob = obslib.Obs()
    r = engine.run(cluster, jobs, scheduler="dorm", fleet=fleet, obs=ob)
    c = ob.metrics.snapshot()["counters"]
    assert c.get("engine.preemptions", 0) == r.preempted > 0
    assert "churn_step" in {e["name"] for e in ob.tracer.events()}
    s = r.summary()
    assert s["preempted"] == r.preempted
    assert s["preempt_dropped"] == r.preempt_dropped
    assert 0.0 < s["live_frac"] <= 1.0
    # churn-free runs report a fully-live fleet
    assert engine.run(cluster, jobs,
                      scheduler="dorm").summary()["live_frac"] == 1.0


def test_stream_run_bit_identical_and_counts():
    cluster, jobs = _instance()
    r0 = engine.run_stream(cluster, iter(jobs), scheduler="oasis",
                           impl="fast")
    ob = obslib.Obs()
    r1 = engine.run_stream(cluster, iter(jobs), scheduler="oasis",
                           impl="fast", obs=ob)
    assert r0.summary() == r1.summary()
    c = ob.metrics.snapshot()["counters"]
    assert c["engine.arrivals"] == r1.n_jobs
    assert c["price.window_advances"] >= 1
    assert "stream_advance" in {e["name"] for e in ob.tracer.events()}


def test_obs_export_embeds_metrics(tmp_path):
    cluster, jobs = _instance()
    ob = obslib.Obs()
    engine.run(cluster, jobs, scheduler="oasis", impl="fast", obs=ob)
    path = tmp_path / "run.json"
    n = ob.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) == n > 0
    assert doc["metrics"]["counters"]["decide.decisions"] >= 1
