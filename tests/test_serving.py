"""Continuous serving mode suite (streaming engine + rolling window).

Pins the contract of ``sim/engine.py``'s streaming section and the
``serving`` scenario:

* translation invariance — an OASiS stream whose jobs all arrive at slot
  ``s`` equals the episodic fixed-horizon run of the same jobs at slot 0
  exactly (utility, admissions, completions shifted by ``s``): the
  rolling window + window-local decisions change coordinates, never
  decisions;
* the reactive baselines are horizon-free already — streaming them over
  a finite trace reproduces the fixed-horizon ``run`` bit for bit;
* a streamed trace completes for every scheduler with price-state memory
  bounded by the window (``SimResult.window_bytes``), and the fused jax
  backend streams to the same decisions as the numpy one.
"""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.core import price_params_from_jobs
from repro.sim import engine, make_cluster, make_jobs, stream_jobs
from repro.sim.scenarios import REACTIVE

W = 24


def _jobs_at(arrival, n=10, seed=2):
    jobs = make_jobs(n, T=10, seed=seed, small=True)
    return [dataclasses.replace(j, jid=j.jid, arrival=arrival) for j in jobs]


def test_oasis_stream_is_translation_of_episodic():
    cluster = make_cluster(T=W, H=6, K=6)
    jobs0 = _jobs_at(0)
    params = price_params_from_jobs(jobs0, cluster)
    ep = engine.run(cluster, jobs0, scheduler="oasis", params=params,
                    quantum=0, check=True)
    shift = 5
    st = engine.run_stream(cluster, iter(_jobs_at(shift)), scheduler="oasis",
                           params=params, window=W, quantum=0, check=True)
    assert st.total_utility == ep.total_utility
    assert st.accepted == ep.accepted and st.completed == ep.completed
    assert st.completion == {j: c + shift for j, c in ep.completion.items()}
    assert st.window_bytes == W * (6 + 6) * 5 * 8


@pytest.mark.parametrize("scheduler", REACTIVE)
def test_reactive_stream_equals_fixed_horizon(scheduler):
    # ample T: every admitted job finishes well inside the fixed horizon,
    # so the episodic run has no end-of-horizon truncation to differ on
    cluster = make_cluster(T=200, H=8, K=8)
    jobs = make_jobs(25, T=30, seed=4, small=True)
    fixed = engine.run(cluster, jobs, scheduler=scheduler, check=True)
    streamed = engine.run_stream(cluster, iter(jobs), scheduler=scheduler,
                                 check=True)
    assert streamed.completion == fixed.completion
    assert streamed.accepted == fixed.accepted
    assert np.isclose(streamed.total_utility, fixed.total_utility)
    assert streamed.window_bytes == 0


def test_streamed_trace_completes_for_all_schedulers():
    """A diurnal x bursty open-ended trace runs to completion for every
    scheduler with memory bounded by the window — the serving scenario's
    acceptance shape at test scale."""
    H = K = 6
    cluster = make_cluster(T=W, H=H, K=K)
    for scheduler in ("oasis",) + REACTIVE:
        trace = stream_jobs(rate=0.15, seed=0, max_slots=250, small=True)
        kw = dict(quantum=0) if scheduler == "oasis" else {}
        r = engine.run_stream(cluster, trace, scheduler=scheduler, window=W,
                              check=True, **kw)
        assert r.n_jobs > 0
        assert r.completed <= r.accepted <= r.n_jobs
        assert max(r.completion.values(), default=0) < 250 + 10 * W
        if scheduler == "oasis":
            assert r.window_bytes == W * (H + K) * 5 * 8
        else:
            assert r.window_bytes == 0


def test_stream_jax_backend_matches_fast():
    """The fused jit engine over the device-resident rolling window makes
    the same streamed decisions as the numpy path."""
    cluster = make_cluster(T=W, H=5, K=5)
    jobs = list(itertools.islice(
        stream_jobs(rate=0.3, seed=6, small=True), 30))
    params = price_params_from_jobs(
        [dataclasses.replace(j, arrival=0) for j in jobs],
        dataclasses.replace(cluster, T=W))
    fast = engine.run_stream(cluster, iter(jobs), scheduler="oasis",
                             params=params, impl="fast", window=W,
                             quantum=0, check=True)
    fused = engine.run_stream(cluster, iter(jobs), scheduler="oasis",
                              params=params, impl="jax", window=W,
                              quantum=0, check=True)
    assert fused.accepted == fast.accepted
    assert fused.completion == fast.completion
    assert np.isclose(fused.total_utility, fast.total_utility)


def test_run_stream_learned_requires_policy():
    cluster = make_cluster(T=W, H=4, K=4)
    with pytest.raises(ValueError, match="needs a policy"):
        engine.run_stream(cluster, iter(()), scheduler="learned")
