"""Unit tests for the OASiS core: COST_t greedy optimality, DP optimality,
vectorized == reference, price-function properties (Appendix A)."""
import itertools
import math

import numpy as np
import pytest

from repro.core import (OASiS, best_schedule, best_schedule_ref,
                        price_params_from_jobs)
from repro.core.pricing import PriceState
from repro.core.subroutine import cost_t_ref, cost_t_rows
from repro.core.types import ClusterSpec, Job, SigmoidUtility
from repro.sim import make_cluster, make_jobs


def tiny_cluster(T=10, H=3, K=3, cap=8.0):
    w = np.full((H, 5), cap)
    s = np.full((K, 5), cap)
    return ClusterSpec(T=T, worker_caps=w, ps_caps=s)


def mk_job(jid=0, a=0, E=2, N=3, M=10, tau=0.02, e=0.05, b=1.0, B=4.0,
           g=(50.0, 1.0, 3.0)):
    return Job(jid=jid, arrival=a, epochs=E, num_chunks=N,
               minibatches_per_chunk=M, tau=tau, grad_size=e, worker_bw=b,
               ps_bw=B, worker_res=np.array([1.0, 2.0, 2.0, 1.0, b]),
               ps_res=np.array([0.0, 2.0, 2.0, 1.0, B]),
               utility=SigmoidUtility(*g))


def brute_force_cost_t(job, state, p, q, t, d):
    """Exhaustive optimal COST_t for tiny H/K: enumerate worker placements."""
    from repro.core.subroutine import _server_capacity, INF
    H, K = state.cluster.H, state.cluster.K
    D = job.workers_for(d)
    if d == 0:
        return 0.0
    if D > job.num_chunks:
        return INF
    wcap = _server_capacity(state.headroom_workers(t), job.worker_res)
    scap = _server_capacity(state.headroom_ps(t), job.ps_res)
    wcost = (p[t] * job.worker_res[None]).sum(1)
    scost = (q[t] * job.ps_res[None]).sum(1)
    best = INF
    ranges = [range(int(min(c, D)) + 1) for c in wcap]
    for y in itertools.product(*ranges):
        if sum(y) != D:
            continue
        Z = job.ps_for(D)
        zr = [range(int(min(c, Z)) + 1) for c in scap]
        for z in itertools.product(*zr):
            tz = sum(z)
            if tz > D or tz * job.ps_bw < D * job.worker_bw - 1e-9:
                continue
            c = sum(yi * wc for yi, wc in zip(y, wcost)) + \
                sum(zi * sc for zi, sc in zip(z, scost))
            best = min(best, c)
    return best


def test_cost_t_greedy_is_optimal():
    rng = np.random.default_rng(0)
    cluster = tiny_cluster()
    job = mk_job()
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    # random occupancy + random prices via random allocations
    state.g = rng.uniform(0, 4, state.g.shape)
    state.v = rng.uniform(0, 4, state.v.shape)
    p, q = state.worker_prices(), state.ps_prices()
    for t in range(0, 6):
        for d in range(0, 5):
            got, y, z = cost_t_ref(job, state, p, q, t, d)
            want = brute_force_cost_t(job, state, p, q, t, d)
            if math.isinf(want):
                assert math.isinf(got)
            else:
                assert got == pytest.approx(want, rel=1e-9), (t, d)


def test_dp_matches_exhaustive_split():
    """DP over workload splits == brute-force enumeration of splits."""
    cluster = tiny_cluster(T=6)
    job = mk_job(E=1, N=3, g=(40.0, 0.5, 2.0))
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    rng = np.random.default_rng(1)
    state.g = rng.uniform(0, 5, state.g.shape)
    p, q = state.worker_prices(), state.ps_prices()
    D = job.workload
    dcap = job.max_chunks_per_slot
    rows = cost_t_rows(job, state, p, q, dcap)
    # brute force: all ways to split D over slots [a, t_hat]
    best_payoff = 0.0
    for t_hat in range(job.arrival, cluster.T):
        n = t_hat - job.arrival + 1
        best_cost = math.inf
        for split in itertools.product(range(dcap + 1), repeat=n):
            if sum(split) != D:
                continue
            c = sum(rows[job.arrival + i, s] for i, s in enumerate(split))
            best_cost = min(best_cost, c)
        if math.isfinite(best_cost):
            payoff = job.utility(t_hat - job.arrival) - best_cost
            best_payoff = max(best_payoff, payoff)
    sched = best_schedule(job, state)
    got = sched.payoff if sched else 0.0
    assert got == pytest.approx(best_payoff, rel=1e-6, abs=1e-9)


def test_fast_equals_ref_on_random_instances():
    cluster = make_cluster(T=16, H=5, K=5)
    jobs = make_jobs(12, T=16, seed=7, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    for job in jobs:
        ref = best_schedule_ref(job, state)
        fast = best_schedule(job, state)
        assert (ref is None) == (fast is None)
        if ref is not None:
            assert fast.payoff == pytest.approx(ref.payoff, rel=1e-9)
            assert fast.cost == pytest.approx(ref.cost, rel=1e-9, abs=1e-12)
            assert fast.finish == ref.finish
            state.commit(job, ref.workers, ref.ps)


def test_jax_dp_equals_numpy():
    cluster = make_cluster(T=12, H=4, K=4)
    jobs = make_jobs(8, T=12, seed=3, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    for job in jobs[:5]:
        a = best_schedule(job, state)
        b = best_schedule(job, state, use_jax=True)
        assert (a is None) == (b is None)
        if a is not None:
            assert b.payoff == pytest.approx(a.payoff, rel=1e-5)
            state.commit(job, a.workers, a.ps)


def test_price_functions_appendix_a():
    """Empty cluster admits any job; exhausted resource rejects every job
    that needs it (Appendix A)."""
    cluster = tiny_cluster(T=8)
    job = mk_job(g=(10.0, 0.0, 1.0))   # modest constant utility
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    # (i) empty cluster -> prices == L -> admitted
    s = best_schedule(job, state)
    assert s is not None and s.payoff > 0
    # (iii) exhaust every resource at all times -> prices == U -> rejected
    state.g[:] = cluster.worker_caps[None]
    state.v[:] = cluster.ps_caps[None]
    assert best_schedule(job, state) is None


def test_quantum_schedules_feasible_and_close():
    cluster = make_cluster(T=20, H=8, K=8)
    jobs = make_jobs(6, T=20, seed=11, small=False)
    params = price_params_from_jobs(jobs, cluster)
    import dataclasses
    state = PriceState(cluster, params)
    for job in jobs[:3]:
        exact = best_schedule(job, state)
        coarse = best_schedule(dataclasses.replace(job, quantum=8), state)
        if exact is not None and coarse is not None:
            # coarse over-provisions: utility can only be <= exact's by a
            # bounded amount; payoff should be within 30%
            assert coarse.payoff <= exact.payoff + 1e-6
            assert coarse.payoff >= 0


def test_alg1_bookkeeping_matches_prices():
    cluster = make_cluster(T=16, H=5, K=5)
    jobs = make_jobs(10, T=16, seed=5, small=True)
    params = price_params_from_jobs(jobs, cluster)
    sched = OASiS(cluster, params)
    for j in jobs:
        sched.on_arrival(j)
    # allocations never exceed capacity (constraints (4)(5) across slots)
    assert np.all(sched.state.g <= cluster.worker_caps[None] + 1e-9)
    assert np.all(sched.state.v <= cluster.ps_caps[None] + 1e-9)
