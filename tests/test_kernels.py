"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.minplus.kernel import minplus_pallas, minplus_sweep_pallas
from repro.kernels.minplus.ref import minplus_ref, minplus_sweep_ref
from repro.kernels.ssd.ops import ssd_op
from repro.kernels.ssd.ref import ssd_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,Sq,Sk,H,KV,D", [
    (1, 64, 64, 2, 2, 64),
    (2, 128, 128, 4, 2, 64),
    (1, 130, 130, 4, 1, 128),     # ragged seq (padding path)
    (2, 96, 96, 8, 4, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 32, 0.0), (True, 0, 50.0), (False, 0, 0.0)])
def test_flash_attention_sweep(B, Sq, Sk, H, KV, D, dtype, causal, window, cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("d1,dc1", [(5, 2), (64, 8), (129, 17), (1000, 100),
                                    (4097, 257)])
@pytest.mark.parametrize("inf_frac", [0.0, 0.3])
def test_minplus_sweep(d1, dc1, inf_frac):
    rng = np.random.default_rng(d1)
    prev = rng.random(d1).astype(np.float32)
    row = rng.random(dc1).astype(np.float32)
    prev[rng.random(d1) < inf_frac] = np.inf
    row[rng.random(dc1) < inf_frac] = np.inf
    prev[0] = 0.0
    row[0] = 0.0
    o1, a1 = minplus_pallas(jnp.array(row), jnp.array(prev), interpret=True)
    o2, a2 = minplus_ref(jnp.array(row), jnp.array(prev))
    v1, v2 = np.asarray(o1), np.asarray(o2)
    assert np.all((np.isinf(v1) & np.isinf(v2)) | (np.abs(v1 - v2) < 1e-5))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("T,dc1,d1", [(3, 2, 6), (9, 17, 33), (16, 65, 300)])
@pytest.mark.parametrize("inf_frac", [0.0, 0.4])
def test_minplus_sweep_fused_kernel(T, dc1, d1, inf_frac):
    """The single-launch T-slot sweep (grid over slots, carried row in VMEM
    scratch) == a lax.scan of per-slot min-plus convolutions."""
    rng = np.random.default_rng(T * d1)
    rows = rng.random((T, dc1)).astype(np.float32)
    rows[rng.random((T, dc1)) < inf_frac] = np.inf
    rows[:, 0] = 0.0
    c1, a1 = minplus_sweep_pallas(jnp.array(rows), d1 - 1, interpret=True)
    c2, a2 = minplus_sweep_ref(jnp.array(rows), d1 - 1)
    v1, v2 = np.asarray(c1), np.asarray(c2)
    assert np.all((np.isinf(v1) & np.isinf(v2)) | (np.abs(v1 - v2) < 1e-5))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("b,L,H,P,G,N,chunk", [
    (1, 32, 2, 16, 1, 16, 16),
    (2, 64, 4, 32, 2, 32, 32),
    (1, 100, 4, 64, 1, 64, 64),   # ragged length (padding path)
    (2, 256, 8, 64, 4, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, L, H, P, G, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (b, L, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, L, G, N)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (b, L, G, N)) * 0.3).astype(dtype)
    got = ssd_op(x, dt, A, B, C, chunk=chunk, use_pallas=True)
    rep = H // G
    Bh = jnp.repeat(B[:, :, :, None, :], rep, 3).reshape(b, L, H, N)
    Ch = jnp.repeat(C[:, :, :, None, :], rep, 3).reshape(b, L, H, N)
    want = ssd_ref(
        x.transpose(0, 2, 1, 3).reshape(b * H, L, P).astype(jnp.float32),
        dt.transpose(0, 2, 1).reshape(b * H, L).astype(jnp.float32),
        jnp.tile(A, b),
        Bh.transpose(0, 2, 1, 3).reshape(b * H, L, N).astype(jnp.float32),
        Ch.transpose(0, 2, 1, 3).reshape(b * H, L, N).astype(jnp.float32))
    want = want.reshape(b, H, L, P).transpose(0, 2, 1, 3)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_model_chunked_ssd_matches_kernel():
    """models.mamba2.ssd_chunked (XLA path) == Pallas kernel == sequential."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(KEY, 5)
    b, L, H, P, G, N = 2, 96, 4, 32, 1, 32
    x = jax.random.normal(ks[0], (b, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, L, G, N)) * 0.3
    C = jax.random.normal(ks[4], (b, L, G, N)) * 0.3
    y_model, _ = ssd_chunked(x, dt, A, B, C, 32)
    y_kernel = ssd_op(x, dt, A, B, C, chunk=32, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,dc1,d1,start", [
    (64, 7, 21, 0), (128, 9, 33, 37), (192, 33, 129, 64),
    (128, 600, 800, 5),            # wide band: block-scan chain path
    (50, 7, 21, 0),                # rolling serving window: T % tile != 0
    (100, 9, 33, 12),              # partial trailing tile + dynamic start
    (129, 5, 17, 64),              # one slot past a tile boundary
])
@pytest.mark.parametrize("inf_frac", [0.0, 0.4])
def test_minplus_sweep_tiled_matches_cost(T, dc1, d1, start, inf_frac):
    """Horizon-tiled while_loop sweep == the unrolled full sweep, bit for
    bit, including a dynamic start tile over identity-prefix rows."""
    from repro.kernels.minplus.ref import minplus_sweep_cost
    from repro.kernels.minplus.tiled import minplus_sweep_tiled
    rng = np.random.default_rng(T + dc1 + start)
    rows = rng.random((T, dc1)).astype(np.float64)
    rows[rng.random((T, dc1)) < inf_frac] = np.inf
    rows[:, 0] = 0.0
    rows[:start, 1:] = np.inf              # identity prefix (pre-arrival)
    got = np.asarray(minplus_sweep_tiled(jnp.asarray(rows), d1 - 1,
                                         tile=64, start=start))
    want = np.asarray(minplus_sweep_cost(jnp.asarray(rows), d1 - 1))
    assert np.array_equal(got[start:], want[start:])


def test_minplus_chain_step_batched_lanes():
    """The lane-batched chain step equals per-lane reference sweeps."""
    from repro.kernels.minplus.tiled import minplus_chain_step
    rng = np.random.default_rng(5)
    B, dc1, d1 = 5, 11, 29
    row = rng.random((B, dc1)).astype(np.float32)
    prev = rng.random((B, d1)).astype(np.float32)
    row[rng.random((B, dc1)) < 0.3] = np.inf
    row[:, 0] = 0.0
    got = np.asarray(minplus_chain_step(jnp.asarray(row), jnp.asarray(prev)))
    for b in range(B):
        # direct oracle: new[d] = min_j row[j] + prev[d - j], f32 like the op
        want = np.full(d1, np.inf, np.float32)
        for d in range(d1):
            for j in range(min(dc1, d + 1)):
                want[d] = min(want[d], np.float32(row[b, j] + prev[b, d - j]))
        assert np.array_equal(got[b], want)
