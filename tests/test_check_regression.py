"""benchmarks/check_regression gate: leaf extraction for the scale,
serving, and churn sections (incl. the inverted higher-is-better
throughput/retention leaves), and the hard refusal on quick-vs-full
configuration mismatches (PR 4)."""
from benchmarks.check_regression import _leaves, _rate_leaves, check


def _doc(quick_dec=True, scale_T=500, oasis_p50=0.2, fifo_wall=1.0,
         quick_scale=False, serving_window=64, oasis_dps=40.0,
         serving_wall=100.0, oasis_ret=0.8, churn_levels=(0.05, 0.2)):
    return {
        "schema": "bench_decision/v4",
        "decision_seconds": {"jax": {"p50": 0.01}, "quick": quick_dec},
        "sim_scale": {
            "T": scale_T, "H": 100, "K": 100, "n_jobs": 2000,
            "quick": quick_scale,
            "wall_seconds": {"fifo": fifo_wall, "oasis": 600.0},
            "decision": {"oasis": {"p50": oasis_p50, "mean": 0.3}},
        },
        "serving": {
            "H": 50, "K": 50, "window": serving_window, "slots": 20000,
            "n_jobs": 4000, "quick": False,
            "wall_seconds": {"fifo": 2.0, "oasis": serving_wall},
            "decisions_per_sec": {"fifo": 2000.0, "oasis": oasis_dps},
            "window_bytes": {"fifo": 0, "oasis": 256000},
            "decision": {"oasis": {"p50": 0.02, "mean": 0.03}},
        },
        "churn": {
            "T": 100, "H": 40, "K": 40, "n_jobs": 120, "quick": False,
            "levels": list(churn_levels),
            "wall_seconds": {"fifo": 0.02, "oasis": 20.0},
            "utility": {"fifo": {"none": 100.0, "frac=0.2": 90.0},
                        "oasis": {"none": 200.0, "frac=0.2": 160.0}},
            "retention": {"fifo": {"frac=0.2": 0.9},
                          "oasis": {"frac=0.2": oasis_ret}},
            "preempted": {"fifo": {"frac=0.2": 35},
                          "oasis": {"frac=0.2": 55}},
            "preempt_dropped": {"fifo": {"frac=0.2": 0},
                                "oasis": {"frac=0.2": 7}},
        },
    }


def test_leaves_include_scale_decision_p50():
    paths = dict(_leaves(_doc()))
    assert paths["sim_scale.wall_seconds.oasis"] == 600.0
    assert paths["sim_scale.decision.oasis.p50"] == 0.2
    assert "sim_scale.decision.oasis.mean" not in paths   # p50 is the gate


def test_serving_leaves_and_rate_leaves():
    paths = dict(_leaves(_doc()))
    assert paths["serving.wall_seconds.oasis"] == 100.0
    assert paths["serving.decision.oasis.p50"] == 0.02
    # throughputs are higher-is-better: extracted separately, not as
    # lower-better wall leaves
    assert not any("decisions_per_sec" in p for p in paths)
    rates = dict(_rate_leaves(_doc()))
    assert rates == {"serving.decisions_per_sec.fifo": 2000.0,
                     "serving.decisions_per_sec.oasis": 40.0,
                     "churn.retention.fifo.frac=0.2": 0.9,
                     "churn.retention.oasis.frac=0.2": 0.8}


def test_serving_throughput_drop_gates_inverted():
    """The gate fires when throughput DROPPED by more than the ratio —
    and never when it improved."""
    base = _doc()
    slower = _doc(oasis_dps=10.0)                 # 4x throughput drop
    assert check(base, slower, ratio=2.0) == 1
    faster = _doc(oasis_dps=400.0)                # 10x improvement: fine
    assert check(base, faster, ratio=2.0) == 0
    # fifo sustains >1k/s (sub-ms per decision): below the noise floor,
    # its throughput column is never gated
    noisy = _doc()
    noisy["serving"]["decisions_per_sec"]["fifo"] = 1.0
    assert check(base, noisy, ratio=2.0) == 0


def test_serving_wall_regression_gates():
    assert check(_doc(), _doc(serving_wall=450.0), ratio=2.0) == 1


def test_churn_retention_drop_gates_inverted():
    """Retention is higher-is-better: the gate fires when a scheduler
    keeps a ratio-times smaller share of its churn-free utility than
    the baseline — and never when retention improved."""
    base = _doc()
    collapsed = _doc(oasis_ret=0.3)               # 0.8 -> 0.3: >2x drop
    assert check(base, collapsed, ratio=2.0) == 1
    better = _doc(oasis_ret=1.0)                  # improvement: fine
    assert check(base, better, ratio=2.0) == 0
    # churn retention never appears among the lower-is-better leaves
    assert not any("retention" in p for p in dict(_leaves(base)))


def test_churn_levels_mismatch_refuses():
    base, fresh = _doc(), _doc(churn_levels=(0.05, 0.5))
    assert check(base, fresh, ratio=2.0) == 2
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def test_churn_quick_section_never_gated():
    base, fresh = _doc(), _doc()
    base["churn_quick"] = {**base["churn"], "quick": True}
    fresh["churn_quick"] = {**fresh["churn"], "quick": True,
                            "retention": {"oasis": {"frac=0.2": 0.01}}}
    assert check(base, fresh, ratio=2.0) == 0


def test_v3_baseline_without_churn_not_gated():
    """Diffing a fresh v4 run against a committed v3 baseline (no churn
    section) must neither refuse nor gate the new retention leaves."""
    base = _doc()
    del base["churn"]
    base["schema"] = "bench_decision/v3"
    assert check(base, _doc(oasis_ret=0.01), ratio=2.0) == 0


def test_serving_dims_mismatch_refuses():
    base, fresh = _doc(), _doc(serving_window=32)
    assert check(base, fresh, ratio=2.0) == 2
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def test_serving_quick_section_never_gated():
    base, fresh = _doc(), _doc()
    fresh["serving_quick"] = {**fresh["serving"], "quick": True,
                              "wall_seconds": {"oasis": 9999.0}}
    base["serving_quick"] = {**base["serving"], "quick": True}
    assert check(base, fresh, ratio=2.0) == 0


def test_v2_baseline_without_serving_not_gated():
    """Diffing a fresh v3 run against a committed v2 baseline (no serving
    section) must neither refuse nor gate the new leaves."""
    base = _doc()
    del base["serving"]
    base["schema"] = "bench_decision/v2"
    assert check(base, _doc(oasis_dps=1.0), ratio=2.0) == 0


def test_matching_configs_compare_and_gate():
    base, fresh = _doc(), _doc(oasis_p50=0.25, fifo_wall=1.5)
    assert check(base, fresh, ratio=2.0) == 0
    worse = _doc(oasis_p50=0.9)                            # 4.5x regression
    assert check(base, worse, ratio=2.0) == 1


def test_quick_flag_mismatch_refuses():
    """A quick fresh section must never be silently diffed against a
    full-mode baseline: the gate refuses (exit 2) unless explicitly
    downgraded to a skip."""
    base, fresh = _doc(quick_dec=False), _doc(quick_dec=True)
    assert check(base, fresh, ratio=2.0) == 2
    assert check(fresh, base, ratio=2.0) == 2              # and vice versa
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def _with_minplus(doc, quick, chain_p50=0.006):
    doc["minplus"] = {"quick": quick,
                      "chain_dc64": {"p50": chain_p50},
                      "plateau_stair_dc64": {"p50": 0.002}}
    return doc


def test_minplus_leaves_gated():
    base = _with_minplus(_doc(), quick=False)
    paths = dict(_leaves(base))
    assert paths["minplus.chain_dc64.p50"] == 0.006
    assert paths["minplus.plateau_stair_dc64.p50"] == 0.002
    worse = _with_minplus(_doc(), quick=False, chain_p50=0.1)  # 16x slower
    assert check(base, worse, ratio=2.0) == 1
    assert check(base, _with_minplus(_doc(), quick=False), ratio=2.0) == 0


def test_minplus_quick_mismatch_refuses():
    """The minplus micro-bench measures different shapes in --quick mode:
    diffing quick against full must refuse (exit 2), not silently
    compare different workloads."""
    base = _with_minplus(_doc(), quick=False)
    fresh = _with_minplus(_doc(), quick=True)
    assert check(base, fresh, ratio=2.0) == 2
    assert check(fresh, base, ratio=2.0) == 2              # and vice versa
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def test_decision_stages_subrecord_never_gated():
    """The per-stage profiling sub-record rides inside decision sections
    as diagnostics: it must produce no gated leaves and regressing it
    must not fire the gate."""
    base, fresh = _doc(), _doc()
    base["sim_scale"]["decision"]["stages"] = {
        "row_build": 1.0, "dp_sweep": 2.0, "backtrack": 0.1,
        "placement": 0.1, "decisions": 100.0}
    fresh["sim_scale"]["decision"]["stages"] = {
        "row_build": 900.0, "dp_sweep": 900.0, "backtrack": 900.0,
        "placement": 900.0, "decisions": 100.0}
    assert not any("stages" in p for p in dict(_leaves(base)))
    assert check(base, fresh, ratio=2.0) == 0


def test_scale_dims_mismatch_refuses():
    base, fresh = _doc(), _doc(scale_T=150, quick_scale=True)
    assert check(base, fresh, ratio=2.0) == 2
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def test_missing_sections_are_not_gated():
    base = _doc()
    fresh = {"schema": "bench_decision/v2",
             "decision_seconds": {"jax": {"p50": 0.01}, "quick": True}}
    assert check(base, fresh, ratio=2.0) == 0


def test_section_missing_entirely_does_not_phantom_refuse():
    """A fresh file from e.g. `--only simscale` has no decision_seconds
    section at all; the quick-flag refusal must not fire on the fallback
    quick=False of the absent section — missing sections are reported as
    MISS, never a config mismatch."""
    base = _doc(quick_dec=True)
    fresh = {"schema": "bench_decision/v2", "sim_scale": _doc()["sim_scale"]}
    assert check(base, fresh, ratio=2.0) == 0
    assert check(fresh, base, ratio=2.0) == 0


def _with_obs(doc, quick=False, obs_T=192, hit=0.03, early=0.4, uploads=1):
    doc["obs"] = {"T": obs_T, "H": 10, "K": 10, "n_jobs": 64,
                  "quick": quick,
                  "counters": {"decide.decisions": 64.0,
                               "engine.preemptions": 2.0},
                  "derived": {"row_cache_hit_rate": hit,
                              "early_exit_frac": early,
                              "device_uploads": uploads,
                              "preempted": 2.0}}
    return doc


def test_obs_leaves_split_by_direction():
    """The flight-recorder derived figures: early_exit_frac and
    device_uploads gate lower-is-better, row_cache_hit_rate inverted;
    preempted and the raw counters are informational — no leaves."""
    doc = _with_obs(_doc())
    paths = dict(_leaves(doc))
    assert paths["obs.derived.early_exit_frac"] == 0.4
    assert paths["obs.derived.device_uploads"] == 1
    rates = dict(_rate_leaves(doc))
    assert rates["obs.derived.row_cache_hit_rate"] == 0.03
    every = {**paths, **rates}
    assert not any("preempted" in p for p in every)
    assert not any("counters" in p for p in every)


def test_obs_hit_rate_drop_gates_inverted():
    base = _with_obs(_doc())
    collapsed = _with_obs(_doc(), hit=0.01)       # 3x drop: cache broke
    assert check(base, collapsed, ratio=2.0) == 1
    better = _with_obs(_doc(), hit=0.3)           # improvement: fine
    assert check(base, better, ratio=2.0) == 0


def test_obs_efficiency_regression_gates():
    base = _with_obs(_doc())
    # early exit stopped firing (0.4 -> 0.95 of the horizon visited)
    assert check(base, _with_obs(_doc(), early=0.95), ratio=2.0) == 1
    # full-table uploads reappeared on the commit path
    assert check(base, _with_obs(_doc(), uploads=64), ratio=2.0) == 1
    assert check(base, _with_obs(_doc()), ratio=2.0) == 0


def test_obs_dims_mismatch_refuses():
    base, fresh = _with_obs(_doc()), _with_obs(_doc(), quick=True, obs_T=48)
    assert check(base, fresh, ratio=2.0) == 2
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def test_v4_baseline_without_obs_not_gated():
    """Diffing a fresh v5 run against a committed v4 baseline (no obs
    section) must neither refuse nor gate the new derived leaves."""
    base = _doc()
    base["schema"] = "bench_decision/v4"
    assert check(base, _with_obs(_doc(), hit=0.0001), ratio=2.0) == 0
