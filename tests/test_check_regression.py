"""benchmarks/check_regression gate: leaf extraction for the scale
sections (incl. the new oasis decision-latency leaves) and the hard
refusal on quick-vs-full configuration mismatches (PR 4)."""
from benchmarks.check_regression import _leaves, check


def _doc(quick_dec=True, scale_T=500, oasis_p50=0.2, fifo_wall=1.0,
         quick_scale=False):
    return {
        "schema": "bench_decision/v2",
        "decision_seconds": {"jax": {"p50": 0.01}, "quick": quick_dec},
        "sim_scale": {
            "T": scale_T, "H": 100, "K": 100, "n_jobs": 2000,
            "quick": quick_scale,
            "wall_seconds": {"fifo": fifo_wall, "oasis": 600.0},
            "decision": {"oasis": {"p50": oasis_p50, "mean": 0.3}},
        },
    }


def test_leaves_include_scale_decision_p50():
    paths = dict(_leaves(_doc()))
    assert paths["sim_scale.wall_seconds.oasis"] == 600.0
    assert paths["sim_scale.decision.oasis.p50"] == 0.2
    assert "sim_scale.decision.oasis.mean" not in paths   # p50 is the gate


def test_matching_configs_compare_and_gate():
    base, fresh = _doc(), _doc(oasis_p50=0.25, fifo_wall=1.5)
    assert check(base, fresh, ratio=2.0) == 0
    worse = _doc(oasis_p50=0.9)                            # 4.5x regression
    assert check(base, worse, ratio=2.0) == 1


def test_quick_flag_mismatch_refuses():
    """A quick fresh section must never be silently diffed against a
    full-mode baseline: the gate refuses (exit 2) unless explicitly
    downgraded to a skip."""
    base, fresh = _doc(quick_dec=False), _doc(quick_dec=True)
    assert check(base, fresh, ratio=2.0) == 2
    assert check(fresh, base, ratio=2.0) == 2              # and vice versa
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def test_scale_dims_mismatch_refuses():
    base, fresh = _doc(), _doc(scale_T=150, quick_scale=True)
    assert check(base, fresh, ratio=2.0) == 2
    assert check(base, fresh, ratio=2.0, allow_config_mismatch=True) == 0


def test_missing_sections_are_not_gated():
    base = _doc()
    fresh = {"schema": "bench_decision/v2",
             "decision_seconds": {"jax": {"p50": 0.01}, "quick": True}}
    assert check(base, fresh, ratio=2.0) == 0


def test_section_missing_entirely_does_not_phantom_refuse():
    """A fresh file from e.g. `--only simscale` has no decision_seconds
    section at all; the quick-flag refusal must not fire on the fallback
    quick=False of the absent section — missing sections are reported as
    MISS, never a config mismatch."""
    base = _doc(quick_dec=True)
    fresh = {"schema": "bench_decision/v2", "sim_scale": _doc()["sim_scale"]}
    assert check(base, fresh, ratio=2.0) == 0
    assert check(fresh, base, ratio=2.0) == 0
