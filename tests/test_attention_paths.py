"""Equivalence of the alternative attention execution paths: naive vs
chunked (XLA flash) vs MLA dense vs MLA chunked — all must agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mla as mla_mod
from repro.models.attention import _mask, _sdpa, _sdpa_chunked
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("Sq,Sk,chunk", [(64, 64, 16), (100, 100, 32)])
@pytest.mark.parametrize("causal,window,cap", [(True, 0, 0.0), (True, 24, 50.0),
                                               (False, 0, 0.0)])
def test_chunked_equals_naive(Sq, Sk, chunk, causal, window, cap):
    B, KV, G, D = 2, 2, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, KV, G, D))
    k = jax.random.normal(ks[1], (B, Sk, KV, D))
    v = jax.random.normal(ks[2], (B, Sk, KV, D))
    pos = jnp.arange(Sq)
    o_naive = _sdpa(q, k, v, _mask(pos, pos, causal, window, None), cap)
    o_chunk = _sdpa_chunked(q, k, v, pos, pos, causal, window, cap, None,
                            chunk)
    np.testing.assert_allclose(np.asarray(o_naive), np.asarray(o_chunk),
                               atol=1e-5, rtol=1e-5)


def _mla_cfg():
    return ModelConfig(
        name="mla-test", family="moe", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128, use_mla=True,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, dtype="float32", param_dtype="float32")


def test_mla_flash_equals_dense(monkeypatch):
    from repro.models.layers import init_params
    cfg = _mla_cfg()
    params = init_params(KEY, mla_mod.mla_specs(cfg))
    x = jax.random.normal(KEY, (2, 48, cfg.d_model)) * 0.3
    pos = jnp.arange(48)
    dense, _ = mla_mod.mla_attention(params, cfg, x, pos)
    monkeypatch.setattr(mla_mod, "FLASH_THRESHOLD", 8)
    flash, _ = mla_mod.mla_attention(params, cfg, x, pos)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               atol=2e-5, rtol=2e-5)


def test_mla_absorbed_decode_equals_dense_train():
    """The latent-space (absorbed) decode must match the expanded form —
    this is the identity MLA relies on for its cache compression."""
    cfg = _mla_cfg()
    from repro.models.layers import init_params
    params = init_params(KEY, mla_mod.mla_specs(cfg))
    B, S = 2, 12
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.3
    pos = jnp.arange(S)
    ref, _ = mla_mod.mla_attention(params, cfg, x, pos)
    cache = {"ckv": jnp.zeros((B, S, cfg.kv_lora_rank)),
             "kr": jnp.zeros((B, S, cfg.qk_rope_dim))}
    outs = []
    for i in range(S):
        o, cache = mla_mod.mla_attention(params, cfg, x[:, i:i + 1],
                                         jnp.arange(i, i + 1), cache=cache,
                                         cache_len=jnp.int32(i))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
