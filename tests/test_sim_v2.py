"""sim v2 (event-driven engine): equivalence against the v1 per-slot loop,
placement-backend equivalence, scenario hooks (cancellation, stragglers),
and the quantum-knob contract."""
import numpy as np
import pytest

from repro.core import baselines
from repro.sim import (engine, make_cluster, make_jobs, simulate,
                       simulate_reference)
from repro.sim.scenarios import (StragglerThroughput, cancellation_trace,
                                 make_hetero_cluster)

ALL = ["oasis", "fifo", "drf", "rrh", "dorm"]


def _assert_equivalent(a, b):
    assert a.accepted == b.accepted
    assert a.completed == b.completed
    assert a.completion == b.completion
    assert b.total_utility == pytest.approx(a.total_utility, rel=1e-9, abs=1e-9)
    assert b.utilization == pytest.approx(a.utilization, rel=1e-9, abs=1e-12)
    assert sorted(b.target_gap) == pytest.approx(sorted(a.target_gap))


@pytest.mark.parametrize("seed", range(5))
def test_engine_matches_v1_paper_scale(seed):
    """The paper's simulation setting (T=100, 100 servers, up to 200 jobs;
    job internals shrunk so Alg. 2 stays fast) — utilities, accept/complete
    counts, and completion slots identical for OASiS and every baseline."""
    cluster = make_cluster(T=100, H=50, K=50)
    jobs = make_jobs(200, T=100, seed=seed, small=True)
    for name in ALL:
        kw = dict(quantum=0) if name == "oasis" else {}
        a = simulate_reference(cluster, jobs, scheduler=name, check=True, **kw)
        b = simulate(cluster, jobs, scheduler=name, check=True, **kw)
        _assert_equivalent(a, b)


def test_engine_matches_v1_full_size_jobs():
    """One instance with full-size (paper-range) jobs, where allocations
    span many slots and DRF/Dorm repack heavily."""
    cluster = make_cluster(T=60, H=12, K=12)
    jobs = make_jobs(40, T=60, seed=9, small=False)
    for name in ALL:
        kw = dict(quantum=0) if name == "oasis" else {}
        a = simulate_reference(cluster, jobs, scheduler=name, check=True, **kw)
        b = simulate(cluster, jobs, scheduler=name, check=True, **kw)
        _assert_equivalent(a, b)


def test_place_fast_equals_loop():
    """The vectorized round-robin placement is bit-identical to the seed's
    per-server scan, including partial-fit rollbacks."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        S = int(rng.integers(1, 12))
        free = rng.uniform(0, 6, (S, 5))
        demand = rng.uniform(0, 3, 5)
        count = int(rng.integers(0, 12))
        f1, f2 = free.copy(), free.copy()
        a = baselines._place_loop(count, f1, demand)
        b = baselines._place_fast(count, f2, demand)
        assert (a is None) == (b is None)
        if a is not None:
            assert np.array_equal(a, b)
        assert np.array_equal(f1, f2)


def test_quantum_is_dp_only_knob():
    """`quantum` coarsens the Alg. 2 DP workload; reactive baselines
    schedule by total_work_slots/num_chunks, so their results must be
    exactly quantum-invariant while OASiS actually consumes the knob."""
    cluster = make_cluster(T=40, H=8, K=8)
    jobs = make_jobs(25, T=40, seed=2, small=True)
    for name in ["fifo", "drf", "rrh", "dorm"]:
        a = simulate(cluster, jobs, scheduler=name, check=False)
        b = simulate(cluster, jobs, scheduler=name, check=False, quantum=5)
        assert a.total_utility == b.total_utility
        assert a.completion == b.completion
    # OASiS: the engine threads quantum through to Job.workload
    big = make_jobs(6, T=40, seed=4, small=False)
    r = simulate(cluster, big, scheduler="oasis", check=True, quantum=7)
    assert r.accepted <= len(big)


def test_cancellation_consistent_across_schedulers():
    """Jobs that actually depart mid-run never appear in `completion`,
    stay capacity-feasible (check=True), and the books balance:
    completed + canceled <= accepted."""
    cluster = make_cluster(T=60, H=10, K=10)
    jobs = make_jobs(40, T=60, seed=5, small=True)
    cancels = cancellation_trace(jobs, frac=0.3, seed=5)
    hit_any = False
    for name in ALL:
        kw = dict(quantum=0) if name == "oasis" else {}
        r = simulate(cluster, jobs, scheduler=name, check=True,
                     cancellations=cancels, **kw)
        hit_any = hit_any or r.canceled > 0
        assert r.completed + r.canceled <= r.accepted
        # a completed job either wasn't targeted or finished before the
        # cancel slot — never after it
        for jid, tdone in r.completion.items():
            if jid in cancels:
                assert tdone < cancels[jid]
    assert hit_any


def test_cancellation_releases_oasis_allocation():
    """Single-job trace: cancelling mid-run must release the committed
    tail (prices drop via PriceState.release), zero the utility, and
    strictly lower the recorded utilization — with no other jobs there is
    nothing to backfill the freed slots."""
    from repro.core import price_params_from_jobs
    cluster = make_cluster(T=40, H=6, K=6)
    pool = make_jobs(10, T=20, seed=7, small=False)
    job = pool[2]                  # admissible alone; runs >= 3 slots
    assert job.min_duration >= 3
    params = price_params_from_jobs(pool, cluster)
    base = simulate(cluster, [job], scheduler="oasis", check=True, quantum=0,
                    params=params)
    assert base.accepted == 1 and base.completed == 1
    tdone = base.completion[job.jid]
    assert tdone >= job.arrival + 2
    r = simulate(cluster, [job], scheduler="oasis", check=True, quantum=0,
                 params=params, cancellations={job.jid: job.arrival + 1})
    assert r.canceled == 1 and r.completed == 0
    assert r.total_utility == 0.0
    assert r.utilization < base.utilization


def test_cancellation_boundary_slots_are_noops_everywhere():
    """A cancel at/before arrival or at/after T must not fire, and the
    rule must hold identically for OASiS and the reactive baselines."""
    cluster = make_cluster(T=40, H=8, K=8)
    jobs = make_jobs(15, T=30, seed=8, small=True)
    for name in ALL:
        kw = dict(quantum=0) if name == "oasis" else {}
        base = simulate(cluster, jobs, scheduler=name, check=True, **kw)
        noop = {j.jid: j.arrival for j in jobs[:5]}          # at arrival
        noop.update({j.jid: cluster.T + 3 for j in jobs[5:10]})  # past horizon
        r = simulate(cluster, jobs, scheduler=name, check=True,
                     cancellations=noop, **kw)
        assert r.canceled == 0
        assert r.completion == base.completion
        assert r.total_utility == pytest.approx(base.total_utility)


def test_straggler_throughput_degrades_and_detection_helps():
    cluster = make_cluster(T=50, H=10, K=10)
    jobs = make_jobs(30, T=50, seed=3, small=True)
    base = simulate(cluster, jobs, scheduler="fifo", check=False)
    res = {}
    for detect in (False, True):
        tp = StragglerThroughput(seed=3, slow_frac=0.4, slowdown=4.0,
                                 detect=detect)
        res[detect] = simulate(cluster, jobs, scheduler="fifo", check=False,
                               throughput=tp)
        # factors are valid multipliers
        j = jobs[0]
        for slot in range(5):
            assert 0.0 < tp(j, 4, slot) <= 1.0
    assert res[False].total_utility <= base.total_utility + 1e-9
    # excluding detected stragglers restores throughput -> no worse off
    assert res[True].total_utility >= res[False].total_utility - 1e-9


def test_straggler_perturbs_oasis_completions():
    """A committed OASiS schedule that under-delivers its work is not
    counted complete — completed <= accepted strictly under heavy
    perturbation, and never below zero utility."""
    cluster = make_cluster(T=50, H=10, K=10)
    jobs = make_jobs(30, T=50, seed=6, small=True)
    tp = StragglerThroughput(seed=6, slow_frac=0.5, slowdown=6.0, detect=False)
    base = simulate(cluster, jobs, scheduler="oasis", check=True, quantum=0)
    r = simulate(cluster, jobs, scheduler="oasis", check=True, quantum=0,
                 throughput=tp)
    assert r.accepted == base.accepted        # admission unchanged
    assert r.completed <= base.completed
    assert 0.0 <= r.total_utility <= base.total_utility + 1e-9


def test_hetero_cluster_runs_all_schedulers():
    cluster = make_hetero_cluster(T=40, H=12, K=12, seed=1)
    assert set(np.unique(cluster.worker_caps[:, 0])) <= {2.0, 4.0, 8.0}
    jobs = make_jobs(20, T=40, seed=1, small=True)
    for name in ALL:
        kw = dict(quantum=0) if name == "oasis" else {}
        r = simulate(cluster, jobs, scheduler=name, check=True, **kw)
        assert r.completed <= r.accepted <= len(jobs)
        assert r.total_utility >= 0


def test_arrivals_past_horizon_are_dropped_like_v1():
    """Jobs arriving at/after T never enter the simulation (the v1 loop's
    range(T) semantics) instead of crashing the plan-ahead subroutine."""
    cluster = make_cluster(T=30, H=6, K=6)
    jobs = make_jobs(20, T=60, seed=0, small=True)   # some arrivals >= 30
    assert any(j.arrival >= cluster.T for j in jobs)
    for name in ALL:
        kw = dict(quantum=0) if name == "oasis" else {}
        a = simulate_reference(cluster, jobs, scheduler=name, check=True, **kw)
        b = simulate(cluster, jobs, scheduler=name, check=True, **kw)
        _assert_equivalent(a, b)
        assert b.accepted < len(jobs)


def test_engine_idles_through_empty_traces():
    cluster = make_cluster(T=30, H=4, K=4)
    for name in ALL:
        r = engine.run(cluster, [], scheduler=name, check=True)
        assert r.accepted == r.completed == 0
        assert r.utilization == 0.0
