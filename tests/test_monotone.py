"""Monotone min-plus dispatch: bit-exactness against the chain oracle.

The structure-aware slot kernel (``kernels.minplus.monotone``) may take a
convexity-gated divide-and-conquer branch, a run-compressed plateau scan,
or fall back to the banded chain — and every branch must produce the SAME
floating-point sums as ``minplus_chain_step`` (the engine's reference),
bit for bit, on arbitrary rows: staircases, certified-convex curves,
+inf-infeasible tails, NaN/-inf poisoned rows, and tie-heavy plateaus.

A seeded randomized sweep always runs; the hypothesis variant (optional
dev dependency, requirements-dev.txt) explores adversarial rows when
available and skips cleanly otherwise.  The engine-level tests pin the
acceptance contract: with the monotone dispatch active the fallback
counter stays below 100% (the fast paths actually fire) and the decision
trajectory is bit-identical to the chain-only engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.minplus.monotone import (PATH_CHAIN, PATH_DNC,
                                            PATH_PLATEAU,
                                            convex_certificate,
                                            convex_certificate_np,
                                            monotone_dnc_step,
                                            monotone_path_ref,
                                            monotone_step,
                                            monotone_step_with_path,
                                            monotone_sweep,
                                            plateau_step_unrolled,
                                            run_count, run_count_np)
from repro.kernels.minplus.tiled import minplus_chain_step


def _chain(row, prev):
    """The engine's reference slot: lane-batched banded chain."""
    return np.asarray(minplus_chain_step(jnp.asarray(row)[None],
                                         jnp.asarray(prev)[None])[0])


# jit once per (shape, dtype): the dispatcher is built for use inside the
# engine's compiled decide loop — eagerly it re-traces every call, which
# at 60 randomized calls per test would dominate the suite's wall clock
_step = jax.jit(monotone_step)


def _mk_row(kind: str, rng, dc1: int, dtype):
    js = np.arange(dc1, dtype=np.float64)
    if kind == "random":
        row = rng.random(dc1)
    elif kind == "convex":
        row = js * (js - 1) / 2.0       # exact second difference 1
    elif kind == "stair":
        row = np.repeat(rng.random(max(dc1 // 8, 1)),
                        8)[:dc1].astype(np.float64)
        row = np.resize(row, dc1)
    elif kind == "inf_tail":
        row = rng.random(dc1)
        row[int(dc1 * 0.6):] = np.inf
    elif kind == "ties":
        row = np.round(rng.random(dc1) * 3) / 3.0
    else:
        raise AssertionError(kind)
    row[0] = 0.0                         # COST_t(0 passes) = 0
    return row.astype(dtype)


KINDS = ["random", "convex", "stair", "inf_tail", "ties"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("dc1,d1", [(9, 33), (33, 65), (64, 129)])
def test_monotone_step_matches_chain(kind, dtype, dc1, d1):
    """Every dispatch outcome == the chain, bit for bit."""
    rng = np.random.default_rng(dc1 * d1 + len(kind))
    row = _mk_row(kind, rng, dc1, dtype)
    prev = rng.random(d1).astype(dtype)
    prev[rng.random(d1) < 0.2] = np.inf
    prev[0] = 0.0
    got = np.asarray(_step(jnp.asarray(row), jnp.asarray(prev)))
    assert np.array_equal(got, _chain(row, prev)), kind


@pytest.mark.parametrize("kind,want_path", [
    ("convex", PATH_DNC), ("stair", PATH_PLATEAU), ("random", PATH_CHAIN),
])
def test_dispatch_path_matches_oracle(kind, want_path):
    """The device dispatch picks the branch the numpy oracle names (the
    host COST-row flags drive the same decision in ``cost_row_flags``)."""
    rng = np.random.default_rng(3)
    row = _mk_row(kind, rng, 48, np.float64)
    prev = rng.random(97)
    prev[0] = 0.0
    new, path = monotone_step_with_path(jnp.asarray(row), jnp.asarray(prev))
    ref = monotone_path_ref(row)
    assert ref == want_path
    # D&C may legally spill to chain (overflow guard); never the reverse
    assert int(path) == ref or (ref == PATH_DNC and int(path) == PATH_CHAIN)
    assert np.array_equal(np.asarray(new), _chain(row, prev))


def test_poisoned_rows_fall_back_to_chain():
    """NaN / -inf rows are not 'clean': dispatch must refuse the fast
    paths (whose run/convex algebra assumes ordered totals) and still
    return the chain's exact output."""
    rng = np.random.default_rng(11)
    prev = rng.random(33)
    prev[0] = 0.0
    for poison in (np.nan, -np.inf):
        row = rng.random(17)
        row[0] = 0.0
        row[5] = poison
        new, path = monotone_step_with_path(jnp.asarray(row),
                                            jnp.asarray(prev))
        assert int(path) == PATH_CHAIN
        assert np.array_equal(np.asarray(new), _chain(row, prev),
                              equal_nan=True)


def test_convex_certificate_is_exact():
    """The certificate is a *certificate*: exact compensated second
    differences, no tolerance — a one-ulp dent must decertify."""
    js = np.arange(32, dtype=np.float64)
    row = js * js
    assert bool(convex_certificate(jnp.asarray(row)))
    assert bool(convex_certificate_np(row))
    # knife edge: a linear row (flat 2nd differences) certifies; one ulp
    # up at an interior point makes its triple exactly -2 ulp — must
    # decertify.  float32 so the perturbation survives device transfer
    # regardless of the ambient x64 mode.
    lin = (js * 3.0).astype(np.float32)
    assert bool(convex_certificate(jnp.asarray(lin)))
    dent = lin.copy()
    dent[7] = np.nextafter(dent[7], np.float32(np.inf))
    assert not bool(convex_certificate(jnp.asarray(dent)))
    assert not bool(convex_certificate_np(dent))
    # infeasible suffix stays certified; an interior +inf hole does not
    tail = row.copy()
    tail[20:] = np.inf
    assert bool(convex_certificate(jnp.asarray(tail)))
    hole = row.copy()
    hole[5] = np.inf
    assert not bool(convex_certificate(jnp.asarray(hole)))


def test_run_count_matches_np():
    rng = np.random.default_rng(4)
    rows = np.repeat(rng.random((8, 6)), 5, axis=1)[:, :29]
    dev = np.asarray(jax.vmap(run_count)(jnp.asarray(rows)))
    assert np.array_equal(dev, run_count_np(rows))


@pytest.mark.parametrize("r_max", [4, 16])
def test_plateau_unrolled_matches_chain(r_max):
    """The r_max-bounded unrolled plateau scan (the engine's in-loop
    form) == chain whenever the row actually fits in r_max runs."""
    rng = np.random.default_rng(r_max)
    vals = rng.random(r_max)
    vals[0] = 0.0                        # COST_t(0 passes) = 0, same run
    row = np.repeat(vals, 7)[:r_max * 7 - 3]
    prev = rng.random(129)
    prev[0] = 0.0
    assert int(run_count_np(row)) <= r_max
    got = np.asarray(plateau_step_unrolled(jnp.asarray(row),
                                           jnp.asarray(prev), r_max))
    assert np.array_equal(got, _chain(row, prev))


@pytest.mark.parametrize("dc1,d1", [(9, 33), (65, 129), (130, 200)])
def test_plateau_pallas_matches_chain(dc1, d1):
    """The run-compressed Pallas kernel (doubling-table window minima)
    == chain, bit for bit, including the +inf lane-padding run and
    non-128-multiple shapes."""
    from repro.kernels.minplus.kernel import minplus_plateau_pallas
    rng = np.random.default_rng(dc1 + d1)
    row = np.repeat(rng.random(8), (dc1 + 7) // 8)[:dc1].astype(np.float32)
    row[0] = 0.0
    row[-3:] = np.inf                    # infeasible tail = one more run
    prev = rng.random(d1).astype(np.float32)
    prev[rng.random(d1) < 0.3] = np.inf
    prev[0] = 0.0
    assert int(run_count_np(row)) <= 16
    got = np.asarray(minplus_plateau_pallas(
        jnp.asarray(row), jnp.asarray(prev), r_max=16, interpret=True))
    assert np.array_equal(got, _chain(row, prev))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_minplus_monotone_dispatch_equals_minplus(use_pallas):
    """ops.minplus_monotone == ops.minplus cost output on every row kind
    (the Pallas entry host-gates on run count; the jnp entry runs the
    full dispatcher)."""
    from repro.kernels.minplus.ops import minplus, minplus_monotone
    rng = np.random.default_rng(21)
    for kind in KINDS:
        row = _mk_row(kind, rng, 40, np.float32)
        prev = rng.random(101).astype(np.float32)
        prev[0] = 0.0
        want = np.asarray(minplus(jnp.asarray(row), jnp.asarray(prev),
                                  use_pallas=use_pallas)[0])
        got = np.asarray(minplus_monotone(jnp.asarray(row),
                                          jnp.asarray(prev),
                                          use_pallas=use_pallas))
        assert np.array_equal(got, want), kind


def test_monotone_sweep_matches_sweep_cost():
    from repro.kernels.minplus.ref import minplus_sweep_cost
    rng = np.random.default_rng(8)
    T, dc1, d1 = 40, 13, 57
    rows = np.repeat(rng.random((T, 4)), 4, axis=1)[:, :dc1]
    rows[rng.random((T, dc1)) < 0.2] = np.inf
    rows[:, 0] = 0.0
    got = np.asarray(monotone_sweep(jnp.asarray(rows), d1 - 1))
    want = np.asarray(minplus_sweep_cost(jnp.asarray(rows), d1 - 1))
    assert np.array_equal(got, want)


def test_monotone_dnc_overflow_is_flagged_not_wrong():
    """When the D&C interval buffer would overflow it must say so (the
    dispatcher then reruns the chain) — never return a wrong value."""
    rng = np.random.default_rng(5)
    js = np.arange(24, dtype=np.float64)
    row = js * (js + 3) / 2
    prev = rng.random(49)
    new, ovf = monotone_dnc_step(jnp.asarray(row), jnp.asarray(prev))
    if not bool(ovf):
        assert np.array_equal(np.asarray(new), _chain(row, prev))


# -- randomized sweep (chain equivalence on arbitrary rows) ------------------

@pytest.mark.parametrize("seed", range(6))
def test_monotone_matches_chain_randomized(seed):
    """Arbitrary rows — random run structure, random +inf placement,
    random dtype — dispatched through every branch, == chain bitwise.
    Shapes come from a small fixed set so the jit compilations amortize
    across seeds (a fresh shape costs ~1s of XLA compile each)."""
    rng = np.random.default_rng(seed)
    for _ in range(10):
        dc1 = int(rng.choice([5, 17, 64]))
        d1 = int(rng.choice([33, 129]))
        dtype = np.float32 if rng.integers(2) else np.float64
        nvals = int(rng.integers(1, dc1 + 1))
        row = rng.choice(rng.random(nvals), size=dc1).astype(dtype)
        row[rng.random(dc1) < rng.random() * 0.5] = np.inf
        row[0] = 0.0
        prev = rng.random(d1).astype(dtype)
        prev[rng.random(d1) < 0.3] = np.inf
        got = np.asarray(_step(jnp.asarray(row), jnp.asarray(prev)))
        assert np.array_equal(got, _chain(row, prev)), (seed, dc1, d1)


# -- engine acceptance: fast paths fire, trajectory pinned -------------------

def test_engine_monotone_fallback_below_100_percent():
    """Paper-scale instance with the monotone dispatch active: the
    per-launch path counters must show the plateau path actually firing
    (fallback < 100%) AND the trajectory must equal the chain-only
    engine (REPRO_MONOTONE_BAND=0) exactly."""
    import os
    from repro.core.schedule_jax import (monotone_counters_reset,
                                         monotone_counters_snapshot)
    from repro.sim import make_cluster, make_jobs, simulate
    cluster = make_cluster(T=100, H=50, K=50)
    jobs = make_jobs(200, T=100, seed=0, small=True)
    monotone_counters_reset()
    a = simulate(cluster, jobs, scheduler="oasis", impl="jax", quantum=0)
    snap = monotone_counters_snapshot()
    total = sum(snap.values())
    assert total > 0, "monotone dispatch never active at paper scale"
    assert snap["chain"] < total, f"fallback at 100%: {snap}"
    old = os.environ.get("REPRO_MONOTONE_BAND")
    os.environ["REPRO_MONOTONE_BAND"] = "0"
    try:
        b = simulate(cluster, jobs, scheduler="oasis", impl="jax",
                     quantum=0)
    finally:
        if old is None:
            del os.environ["REPRO_MONOTONE_BAND"]
        else:
            os.environ["REPRO_MONOTONE_BAND"] = old
    assert a.accepted == b.accepted
    assert a.completion == b.completion
    assert a.total_utility == b.total_utility          # bit-identical


# -- hypothesis variant ------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           dc1=st.sampled_from([5, 17, 64]), d1=st.sampled_from([33, 129]),
           nvals=st.integers(1, 12), inf_frac=st.floats(0.0, 0.6),
           f32=st.booleans())
    def test_monotone_matches_chain_hypothesis(seed, dc1, d1, nvals,
                                               inf_frac, f32):
        rng = np.random.default_rng(seed)
        dtype = np.float32 if f32 else np.float64
        row = rng.choice(rng.random(nvals), size=dc1).astype(dtype)
        row[rng.random(dc1) < inf_frac] = np.inf
        row[0] = 0.0
        prev = rng.random(d1).astype(dtype)
        prev[rng.random(d1) < inf_frac] = np.inf
        got = np.asarray(_step(jnp.asarray(row), jnp.asarray(prev)))
        assert np.array_equal(got, _chain(row, prev))
