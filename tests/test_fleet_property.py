"""Hypothesis properties of the fleet-churn machinery.

``hypothesis`` is an optional dev dependency (requirements-dev.txt);
this module skips cleanly at collection when it is absent, matching
``tests/test_property.py``.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import best_schedule
from repro.core.pricing import PriceState, price_params_from_jobs
from repro.sim import engine
from repro.sim.fleet import churn_trace
from repro.sim.workload import make_cluster, make_jobs

ALL = ("oasis", "fifo", "drf", "rrh", "dorm")


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 50), srv=st.integers(0, 2),
       pool=st.sampled_from(["worker", "ps"]), t0=st.integers(0, 12))
def test_block_unblock_inverts_from_any_state(seed, srv, pool, t0):
    """From an arbitrarily-populated price state, block_server followed
    by unblock_server restores the usage tables bit-exactly (unblock
    removes exactly the content it finds: x - x == 0 bitwise; the
    engine's recover path relies on this after victims release)."""
    cluster = make_cluster(T=16, H=3, K=3)
    jobs = make_jobs(5, T=16, seed=seed, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    committed = []
    for j in jobs:
        s = best_schedule(j, state)
        if s is not None:
            state.commit(j, s.workers, s.ps)
            committed.append((j, s))
    # the engine's failure protocol: victims on the dead server release
    # their tails from t0 onward BEFORE the block fills it
    for j, s in committed:
        alloc = s.workers if pool == "worker" else s.ps
        if any(a is not None and a[srv] > 0
               for tt, a in alloc.items() if tt >= t0):
            state.release(j,
                          {tt: y for tt, y in s.workers.items() if tt >= t0},
                          {tt: z for tt, z in s.ps.items() if tt >= t0})
    g0 = state._g_host.copy()
    v0 = state._v_host.copy()
    state.block_server(pool, srv, t0)
    state.unblock_server(pool, srv, t0)
    assert np.array_equal(state._g_host, g0)
    assert np.array_equal(state._v_host, v0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 40))
def test_commit_release_inverts_on_fresh_state(seed):
    """Preemption releases invert commits bit-exactly on fresh slots
    (d - d == 0): commit then release restores exact zeros."""
    cluster = make_cluster(T=16, H=3, K=3)
    jobs = make_jobs(4, T=16, seed=seed, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    g0 = state._g_host.copy()
    v0 = state._v_host.copy()
    committed = []
    for j in jobs:
        s = best_schedule(j, state)
        if s is not None:
            state.commit(j, s.workers, s.ps)
            committed.append((j, s))
    for j, s in reversed(committed):
        state.release(j, s.workers, s.ps)
    assert np.array_equal(state._g_host, g0)
    assert np.array_equal(state._v_host, v0)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 30), frac=st.sampled_from([0.2, 0.3, 0.5]),
       scheduler=st.sampled_from(list(ALL)))
def test_no_overcommit_on_surviving_fleet(seed, frac, scheduler):
    """Whatever the failure pattern, every commitment stays within the
    live fleet's capacity (engine check=True asserts per event slot,
    against the shrunken effective caps on the reactive paths)."""
    cluster = make_cluster(T=40, H=6, K=6)
    jobs = make_jobs(14, T=40, seed=seed, small=True)
    tr = churn_trace(cluster, frac=frac, seed=seed + 7)
    r = engine.run(cluster, jobs, scheduler=scheduler, check=True, fleet=tr)
    assert r.n_jobs == 14
