"""Continuous-batching serving tests: rows are swapped online and every
request's output matches the same request decoded alone (batch purity)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import decode_step, init_cache, init_model
from repro.serve.batcher import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(7)


def _setup(arch="starcoder2_3b", batch=3, max_len=48):
    cfg = get_smoke(arch).scaled(dtype="float32", param_dtype="float32")
    params = init_model(KEY, cfg)
    step = jax.jit(lambda t, c, l: decode_step(params, cfg, t, c, l, None))
    cache = init_cache(cfg, batch, max_len, dtype=jnp.float32)
    return cfg, params, step, cache


def _solo_decode(cfg, params, prompt, max_new, max_len=48):
    cache = init_cache(cfg, 1, max_len, dtype=jnp.float32)
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    out = []
    cur = None
    for i in range(len(prompt) + max_new - 1):
        t = toks[:, i:i + 1] if i < len(prompt) else cur
        lg, cache = decode_step(params, cfg, t, cache, jnp.int32(i), None)
        if i >= len(prompt) - 1:
            cur = jnp.argmax(lg[:, :, :cfg.vocab_size], -1)
            out.append(int(cur[0, 0]))
            if len(out) >= max_new:
                break
    return out


def test_batcher_matches_solo_decoding():
    cfg, params, step, cache = _setup()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=pl),
                    max_new=4) for i, pl in enumerate([5, 3, 7, 4, 6])]
    bat = ContinuousBatcher(batch=3, max_len=48, decode_fn=step)
    for r in reqs:
        bat.submit(r)
    bat.run(cache)
    assert len(bat.done) == len(reqs)
    for r in reqs:
        solo = _solo_decode(cfg, params, r.prompt, r.max_new)
        assert r.output == solo, (r.rid, r.output, solo)


def test_batcher_overlaps_requests():
    """More requests than rows: later requests start only after a row
    frees; total steps < sum of independent lengths (actual batching)."""
    cfg, params, step, cache = _setup(batch=2)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=4),
                    max_new=3) for i in range(4)]
    bat = ContinuousBatcher(batch=2, max_len=48, decode_fn=step)
    for r in reqs:
        bat.submit(r)
    bat.run(cache)
    assert len(bat.done) == 4
    serial_steps = sum(len(r.prompt) + r.max_new for r in reqs)
    assert bat.step_no < serial_steps
    # rows 3/4 started strictly after 1/2
    starts = sorted(r.started_step for r in reqs)
    assert starts[2] > starts[0]
