"""Hypothesis property tests for the vectorized repack kernels
(core/repack.py) against the greedy reference loops.

``hypothesis`` is an optional dev dependency (requirements-dev.txt); this
module skips cleanly at collection when it is absent so ``pytest -x -q``
still runs the rest of the suite (tests/test_repack.py carries the
always-on randomized equivalence coverage).
"""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import baselines
from repro.core.baselines import BASELINES
from repro.core.types import ClusterSpec, Job, SigmoidUtility


def _assert_steps_equal(a, b, ctx):
    assert set(a) == set(b), f"{ctx}: placed-job sets differ"
    for jid in a:
        assert np.array_equal(a[jid][0], b[jid][0]), f"{ctx}: y differs jid={jid}"
        assert np.array_equal(a[jid][1], b[jid][1]), f"{ctx}: z differs jid={jid}"


@st.composite
def _hyp_instance(draw):
    """Arbitrary heterogeneous instances: tiny pools force full-pool
    rejection, tiny PS capacities force PS-placement rollback, zero
    demands and zero capacities hit the degenerate fit paths."""
    H = draw(st.integers(1, 5))
    K = draw(st.integers(1, 5))
    caps = st.floats(0.0, 8.0, allow_nan=False, width=64)
    wcaps = np.array([[draw(caps) for _ in range(5)] for _ in range(H)])
    scaps = np.array([[draw(caps) for _ in range(5)] for _ in range(K)])
    n = draw(st.integers(1, 6))
    dem = st.floats(0.0, 4.0, allow_nan=False, width=64)
    jobs = []
    for jid in range(n):
        jobs.append(Job(
            jid=jid, arrival=0, epochs=1,
            num_chunks=draw(st.integers(1, 5)),
            minibatches_per_chunk=3, tau=0.01, grad_size=0.1,
            worker_bw=draw(st.floats(0.1, 5.0, allow_nan=False)),
            ps_bw=draw(st.floats(0.1, 8.0, allow_nan=False)),
            worker_res=np.array([draw(dem) for _ in range(5)]),
            ps_res=np.array([draw(dem) for _ in range(5)]),
            utility=SigmoidUtility(10.0, 0.1, 4.0)))
    return ClusterSpec(T=4, worker_caps=wcaps, ps_caps=scaps), jobs


@settings(max_examples=120, deadline=None)
@given(inst=_hyp_instance(), name=st.sampled_from(["drf", "dorm", "rrh",
                                                   "fifo"]))
def test_kernel_equals_reference(inst, name):
    """Property: on arbitrary capacities/demands (including zero demands,
    over-demand rejection, and PS-rollback territory) the kernel step
    equals the reference step exactly, and both leave consistent
    scheduler state for a follow-up event."""
    cluster, jobs = inst
    A = BASELINES[name](cluster)
    B = BASELINES[name](cluster)
    for j in jobs:
        ra, rb = A.on_arrival(j, 0), B.on_arrival(j, 0)
        assert ra == rb
    a, b = A.step_kernel(0), B.step_reference(0)
    _assert_steps_equal(a, b, name)
    # follow-up event: complete one placed job (if any) and re-step
    if a:
        jid = next(iter(a))
        A.on_completion(jid, 1)
        B.on_completion(jid, 1)
        _assert_steps_equal(A.step_kernel(1), B.step_reference(1),
                            f"{name} post-completion")


@settings(max_examples=60, deadline=None)
@given(count=st.integers(0, 9),
       free=st.lists(st.lists(st.floats(0, 5, allow_nan=False, width=64),
                              min_size=3, max_size=3), min_size=1, max_size=6),
       demand=st.lists(st.floats(0, 3, allow_nan=False, width=64),
                       min_size=3, max_size=3))
def test_place_fast_equals_loop(count, free, demand):
    f1 = np.array(free)
    f2 = f1.copy()
    d = np.array(demand)
    a = baselines._place_loop(count, f1, d)
    b = baselines._place_fast(count, f2, d)
    assert (a is None) == (b is None)
    if a is not None:
        assert np.array_equal(a, b)
    assert np.array_equal(f1, f2)


@settings(max_examples=40, deadline=None)
@given(b=st.floats(0.1, 5.0, allow_nan=False),
       B=st.floats(0.1, 8.0, allow_nan=False), c=st.integers(1, 50))
def test_ps_for_scalar_matches_job(b, B, c):
    from repro.core.repack import _ps_for
    job = Job(jid=0, arrival=0, epochs=1, num_chunks=4,
              minibatches_per_chunk=1, tau=0.01, grad_size=0.1,
              worker_bw=b, ps_bw=B, worker_res=np.ones(5), ps_res=np.ones(5),
              utility=SigmoidUtility(1.0, 0.0, 1.0))
    assert _ps_for(c, b, B) == job.ps_for(c) == math.ceil(c * b / B - 1e-9)
