"""Competitive-ratio validation (Theorem 4): on exhaustively-solvable
instances, OPT / OASiS must lie in [1, 2*alpha]."""
import numpy as np
import pytest

from repro.core import OASiS, price_params_from_jobs
from repro.core.offline_opt import offline_optimum
from repro.sim import make_cluster, make_jobs, simulate


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_competitive_ratio_bound(seed):
    cluster = make_cluster(T=6, H=2, K=2, scale=0.6)
    jobs = make_jobs(5, T=6, seed=seed, small=True)
    # Theorem 4's bound is stated for the literal (un-floored) U/L values
    params = price_params_from_jobs(jobs, cluster, floor_frac=0.0)
    sched = OASiS(cluster, params)
    for j in sorted(jobs, key=lambda x: x.arrival):
        sched.on_arrival(j)
    online = sched.total_utility
    opt = offline_optimum(cluster, jobs, time_limit=60.0)
    alpha = params.alpha
    # weak duality: OPT >= online (allow tiny solver tolerance)
    assert opt >= online - 1e-6 * max(1.0, abs(opt))
    if online > 1e-9:
        ratio = opt / online
        assert ratio <= 2 * alpha + 1e-6, (ratio, alpha)


def test_offline_opt_sanity_single_job():
    """One trivially-schedulable job: OPT equals the utility at the
    fastest feasible completion (ceil(work / N) slots of work)."""
    import math
    cluster = make_cluster(T=6, H=2, K=2)
    jobs = make_jobs(1, T=6, seed=9, small=True)
    job = jobs[0]
    opt = offline_optimum(cluster, jobs, time_limit=30.0)
    min_slots = math.ceil(job.total_work_slots / job.num_chunks)
    best = job.utility(min_slots - 1)        # t_hat = a + min_slots - 1
    assert opt == pytest.approx(best, rel=1e-3)


def test_oasis_beats_baselines_under_scarcity():
    """Fig. 3's qualitative claim at a paper-like load point (the paper
    uses H=K=50, T<=300 with hundreds of jobs; scaled proportionally).
    Averaged over seeds like the paper's plots — individual draws vary."""
    results = {}
    for seed in (2, 3, 4):
        cluster = make_cluster(T=100, H=20, K=20)
        jobs = make_jobs(60, T=100, seed=seed, small=False)
        for name in ["oasis", "fifo", "drf", "rrh", "dorm"]:
            kw = dict(quantum=0) if name == "oasis" else {}
            r = simulate(cluster, jobs, scheduler=name, check=True, **kw)
            results.setdefault(name, []).append(r.total_utility)
    means = {k: float(np.mean(v)) for k, v in results.items()}
    assert means["oasis"] >= max(v for k, v in means.items() if k != "oasis"), means
