"""Equivalence + edge-case suite for the fused jit Alg. 2 engine.

The fused engine (``best_schedule_fused`` / ``best_schedule_fused_batch``,
reached via ``impl="jax"``) must make identical accept/reject decisions and
produce the same utilities (within 1e-6) as ``best_schedule_ref``, the
paper-faithful oracle — including on degenerate inputs: empty server pools,
worker-only jobs (zero PS demand), and jobs whose dcap is 0.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (OASiS, best_schedule, best_schedule_ref,
                        price_params_from_jobs)
from repro.core.pricing import PriceState
from repro.core.schedule_jax import (best_schedule_fused,
                                     best_schedule_fused_batch, dp_sweep_jax)
from repro.core.subroutine import (_greedy_cost_for_counts, cost_t_rows,
                                   cost_t_rows_loop, minplus_band)
from repro.core.types import ClusterSpec, Job, SigmoidUtility
from repro.sim import make_cluster, make_jobs, simulate


def mk_job(jid=0, a=0, E=2, N=3, M=10, tau=0.02, e=0.05, b=1.0, B=4.0,
           g=(50.0, 1.0, 3.0), w=None, s=None):
    return Job(jid=jid, arrival=a, epochs=E, num_chunks=N,
               minibatches_per_chunk=M, tau=tau, grad_size=e, worker_bw=b,
               ps_bw=B,
               worker_res=np.array([1.0, 2.0, 2.0, 1.0, b]) if w is None else w,
               ps_res=np.array([0.0, 2.0, 2.0, 1.0, B]) if s is None else s,
               utility=SigmoidUtility(*g))


def assert_same_decision(job, state, ref, got):
    assert (ref is None) == (got is None), f"accept/reject differ jid={job.jid}"
    if ref is not None:
        assert got.finish == ref.finish, job.jid
        assert got.payoff == pytest.approx(ref.payoff, rel=1e-6, abs=1e-9)
        assert got.cost == pytest.approx(ref.cost, rel=1e-6, abs=1e-9)
        assert got.utility == pytest.approx(ref.utility, rel=1e-6)
        # placements fulfil the same per-slot worker counts
        for t, y in got.workers.items():
            assert y.sum() == ref.workers[t].sum(), (job.jid, t)


@pytest.mark.parametrize("seed,T,H,K", [(0, 12, 4, 4), (7, 16, 5, 5),
                                        (21, 10, 3, 2)])
def test_fused_equals_ref_randomized(seed, T, H, K):
    """Randomized clusters/jobs, prices evolving via ref commits."""
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(10, T=T, seed=seed, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    for job in jobs:
        ref = best_schedule_ref(job, state)
        got = best_schedule_fused(job, state)
        assert_same_decision(job, state, ref, got)
        if ref is not None:
            state.commit(job, ref.workers, ref.ps)


def test_fused_pallas_sweep_path_equals_ref():
    """use_pallas=True (single-launch sweep kernel, interpret mode on CPU):
    decisions must match ref; f32 kernel => looser payoff tolerance.  The
    d_left == 0 backtrack guard in the wrapper protects the mixed-precision
    (f64 rows / f32 cost table) argmin recovery."""
    cluster = make_cluster(T=10, H=3, K=3)
    jobs = make_jobs(6, T=10, seed=1, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    for job in jobs:
        ref = best_schedule_ref(job, state)
        got = best_schedule_fused(job, state, use_pallas=True)
        assert (ref is None) == (got is None), job.jid
        if ref is not None:
            assert got.finish == ref.finish
            assert got.payoff == pytest.approx(ref.payoff, rel=1e-4, abs=1e-6)
            state.commit(job, ref.workers, ref.ps)


def test_fused_batch_equals_ref_at_fixed_state():
    cluster = make_cluster(T=14, H=4, K=4)
    jobs = make_jobs(8, T=14, seed=5, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    cands = best_schedule_fused_batch(jobs, state)
    for job, got in zip(jobs, cands):
        ref = best_schedule_ref(job, state)
        assert_same_decision(job, state, ref, got)


def test_fused_engine_empty_ps_pool():
    """K = 0: every job needing PS bandwidth must be rejected, not crash."""
    cluster = ClusterSpec(T=8, worker_caps=np.full((3, 5), 16.0),
                          ps_caps=np.zeros((0, 5)))
    job = mk_job()
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    assert best_schedule_ref(job, state) is None
    assert best_schedule_fused(job, state) is None
    assert best_schedule(job, state) is None


def test_fused_engine_empty_worker_pool():
    cluster = ClusterSpec(T=8, worker_caps=np.zeros((0, 5)),
                          ps_caps=np.full((3, 5), 16.0))
    job = mk_job()
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    assert best_schedule_ref(job, state) is None
    assert best_schedule_fused(job, state) is None
    assert best_schedule(job, state) is None


def test_fused_engine_zero_ps_demand():
    """Worker-only jobs (all-zero ps_res) are legal: pricing must not divide
    by zero and all three backends must agree."""
    cluster = make_cluster(T=10, H=3, K=3)
    job = mk_job(s=np.zeros(5))
    params = price_params_from_jobs([job], cluster)   # regression: ssum == 0
    state = PriceState(cluster, params)
    ref = best_schedule_ref(job, state)
    assert_same_decision(job, state, ref, best_schedule_fused(job, state))
    assert_same_decision(job, state, ref, best_schedule(job, state))
    assert ref is not None and ref.cost >= 0


def test_fused_engine_dcap_zero():
    """A job whose single-slot chunk time exceeds N can never run: dcap = 0."""
    job = mk_job(N=1, M=100, tau=0.5)
    assert min(job.max_chunks_per_slot, job.workload) == 0
    cluster = make_cluster(T=8, H=3, K=3)
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    assert best_schedule_ref(job, state) is None
    assert best_schedule_fused(job, state) is None
    assert best_schedule(job, state) is None


def test_greedy_cost_empty_pool_no_crash():
    """Regression: empty server pool used to index scost[-1] and crash."""
    out = _greedy_cost_for_counts(np.array([], np.int64), np.array([]),
                                  np.array([]), np.array([0, 1, 5]))
    assert out[0] == 0.0 and np.isinf(out[1]) and np.isinf(out[2])


def test_vectorized_rows_match_seed_loop():
    """The whole-array COST-row builder == the seed per-slot-loop builder."""
    cluster = make_cluster(T=12, H=4, K=4)
    jobs = make_jobs(6, T=12, seed=9, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    rng = np.random.default_rng(0)
    # random occupancy, but only on resources the pool actually has —
    # allocations on zero-capacity resources are unreachable via commit()
    state.g = rng.uniform(0, 3, state.g.shape) * (cluster.worker_caps[None] > 0)
    state.v = rng.uniform(0, 3, state.v.shape) * (cluster.ps_caps[None] > 0)
    p, q = state.worker_prices(), state.ps_prices()
    for job in jobs:
        dcap = min(job.max_chunks_per_slot, job.workload)
        if dcap == 0:
            continue
        fast = cost_t_rows(job, state, p, q, dcap)
        loop = cost_t_rows_loop(job, state, p, q, dcap)
        both_inf = np.isinf(fast) & np.isinf(loop)
        assert np.all(both_inf | (np.abs(fast - loop) < 1e-9)), job.jid


def test_on_arrivals_equals_sequential_on_arrival():
    """Batched admission == sequential Alg. 1, job for job."""
    cluster = make_cluster(T=18, H=5, K=5)
    jobs = make_jobs(20, T=18, seed=13, small=True)
    params = price_params_from_jobs(jobs, cluster)
    seq = OASiS(cluster, params, impl="jax")
    for j in sorted(jobs, key=lambda x: (x.arrival, x.jid)):
        seq.on_arrival(j)
    bat = OASiS(cluster, params, impl="jax")
    by_slot = {}
    for j in jobs:
        by_slot.setdefault(j.arrival, []).append(j)
    for t in range(cluster.T):
        bat.on_arrivals(by_slot.get(t, []))
    assert set(seq.accepted) == set(bat.accepted)
    assert bat.total_utility == pytest.approx(seq.total_utility, rel=1e-9)
    for jid, s in seq.accepted.items():
        assert bat.accepted[jid].finish == s.finish


def test_simulator_capacity_sweep_jax_impl():
    """Every allocation the fused engine commits stays within capacity at
    every slot (simulator asserts via _check_capacity), including with a
    worker-only job in the mix."""
    cluster = make_cluster(T=20, H=6, K=6)
    jobs = make_jobs(24, T=20, seed=3, small=True)
    jobs.append(dataclasses.replace(jobs[0], jid=len(jobs),
                                    ps_res=np.zeros(5)))
    r = simulate(cluster, jobs, scheduler="oasis", impl="jax", check=True)
    r2 = simulate(cluster, jobs, scheduler="oasis", impl="fast", check=True)
    assert r.accepted == r2.accepted
    assert r.total_utility == pytest.approx(r2.total_utility, rel=1e-9)


def test_jax_equals_fast_on_tie_heavy_workload():
    """Regression for the float32 downcast: identical constant-utility jobs
    on identical servers produce payoff ties across many finish slots; the
    jax engine must resolve them exactly like the float64 numpy path."""
    w = np.full((4, 5), 20.0)
    s = np.full((4, 5), 20.0)
    cluster = ClusterSpec(T=12, worker_caps=w, ps_caps=s)
    jobs = [mk_job(jid=i, a=i % 3, g=(10.0, 0.0, 1.0)) for i in range(8)]
    params = price_params_from_jobs(jobs, cluster)
    fast = OASiS(cluster, params, impl="fast")
    fz = OASiS(cluster, params, impl="jax")
    for j in jobs:
        fast.on_arrival(j)
        fz.on_arrival(j)
    assert set(fast.accepted) == set(fz.accepted)
    assert sorted(fast.rejected) == sorted(fz.rejected)
    for jid in fast.accepted:
        assert fz.accepted[jid].finish == fast.accepted[jid].finish
    assert fz.total_utility == pytest.approx(fast.total_utility, rel=1e-9)


@pytest.mark.parametrize("seed", range(5))
def test_backend_trajectories_identical_paper_scale(seed):
    """The acceptance contract: on the 5 seeded paper-scale equivalence
    instances (the same T=100 / 50+50 / 200-job setting test_sim_v2 pins
    v1-vs-v2 on), the fused jax engine — burst-batched, tiled, row-cached
    — reproduces the ref oracle's whole trajectory BIT-identically:
    accept set, completion slots, and total utility (exact float
    equality, not approx) — with the monotone min-plus dispatch active
    (the path counters must show its fast paths firing, i.e. the chain
    fallback stays below 100% on every instance)."""
    from repro.core.schedule_jax import (monotone_counters_reset,
                                         monotone_counters_snapshot)
    from repro.sim import simulate
    cluster = make_cluster(T=100, H=50, K=50)
    jobs = make_jobs(200, T=100, seed=seed, small=True)
    a = simulate(cluster, jobs, scheduler="oasis", impl="ref", quantum=0)
    monotone_counters_reset()
    b = simulate(cluster, jobs, scheduler="oasis", impl="jax", quantum=0)
    snap = monotone_counters_snapshot()
    assert sum(snap.values()) > 0, "monotone dispatch inactive"
    assert snap["chain"] < sum(snap.values()), f"fallback at 100%: {snap}"
    assert a.completion == b.completion
    assert a.accepted == b.accepted
    assert a.total_utility == b.total_utility


def test_on_arrivals_burst_equals_sequential_full_size_jobs():
    """Regression for the split-tie trajectory fork: with full-size jobs
    the DP cost sits on near-zero tie plateaus, and two launch shapes
    (lane-batched burst vs B=1 sequential) can disagree in the last ulps
    of a DP cell.  The eps-banded backtrack (_SPLIT_TOL) must keep the
    burst path's placements — not just its accept set — identical to the
    sequential path, or the committed price trajectories fork."""
    from repro.sim.engine import _with_quantum
    T, H, K = 60, 40, 40
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = [_with_quantum(j, 0)
            for j in make_jobs(100, T=T, seed=0, small=False)]
    params = price_params_from_jobs(jobs, cluster)
    seq = OASiS(cluster, params, impl="jax")
    for j in sorted(jobs, key=lambda x: (x.arrival, x.jid)):
        seq.on_arrival(j)
    bat = OASiS(cluster, params, impl="jax")
    by_slot = {}
    for j in jobs:
        by_slot.setdefault(j.arrival, []).append(j)
    for t in range(T):
        bat.on_arrivals(sorted(by_slot.get(t, []), key=lambda x: x.jid))
    assert set(seq.accepted) == set(bat.accepted)
    assert bat.total_utility == seq.total_utility       # exact
    for jid, s in seq.accepted.items():
        b = bat.accepted[jid]
        assert b.finish == s.finish
        for t in s.workers:
            assert np.array_equal(b.workers[t], s.workers[t]), (jid, t)
            assert np.array_equal(b.ps[t], s.ps[t]), (jid, t)


def test_dp_sweep_jax_respects_x64():
    """dp_sweep_jax keeps float64 when jax_enable_x64 is on (the seed cast
    everything to float32, silently diverging near ties)."""
    import jax
    from jax.experimental import enable_x64
    rng = np.random.default_rng(1)
    rows = rng.random((6, 5))
    rows[:, 0] = 0.0
    # values differing only at 1e-9 — indistinguishable in float32
    rows[2, 1] = 0.5
    rows[3, 1] = 0.5 + 1e-9
    with enable_x64(True):
        cost, split = dp_sweep_jax(rows, 8)
    prev = np.full(9, np.inf)
    prev[0] = 0.0
    for i in range(6):
        want, arg = minplus_band(prev, rows[i])
        both_inf = np.isinf(want) & np.isinf(cost[i])
        assert np.all(both_inf | (np.abs(cost[i] - want) < 1e-12)), i
        assert np.array_equal(split[i], arg), i
        prev = want
