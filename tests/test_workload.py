"""Property-style (seeded) tests for the workload/cluster generator
(sim/workload.py): arrival-process bounds and burstiness, and feasibility
of every generated job on the generated cluster."""
import numpy as np
import pytest

from repro.sim import make_cluster, make_jobs
from repro.sim.workload import _arrivals


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("T,n", [(50, 80), (100, 200)])
def test_arrivals_stay_within_horizon(seed, T, n):
    jobs = make_jobs(n, T=T, seed=seed)
    arr = np.array([j.arrival for j in jobs])
    assert np.all(arr >= 0)
    assert np.all(arr < T)
    assert np.all(arr[:-1] <= arr[1:]), "jobs are emitted in arrival order"


def test_burst_windows_raise_rate():
    """The nonhomogeneous process concentrates mass: burst windows carry a
    x4 rate, and the final T//10 slots are damped to ~nothing — so the
    busiest window must far exceed the uniform share and the tail must see
    almost none of the arrivals."""
    T, n = 200, 4000
    rng = np.random.default_rng(42)
    arr = _arrivals(n, T, rng)
    counts = np.bincount(arr, minlength=T)
    width = max(2, T // 20)
    window = np.convolve(counts, np.ones(2 * width), mode="valid")
    uniform_window = n * (2 * width) / T
    assert window.max() > 2.0 * uniform_window, "no burst window detected"
    tail = counts[-T // 10:].sum()
    assert tail < 0.02 * n, f"tail arrivals not damped: {tail}/{n}"


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("small", [True, False])
def test_generated_jobs_feasible_on_generated_cluster(seed, small):
    """Every job must be schedulable in principle: each worker/PS instance
    fits on at least one server of the generated fleet, the per-job
    parameter ranges hold, and the fastest possible duration fits the
    horizon with room for the paper's target completion times."""
    T = 60
    cluster = make_cluster(T=T, H=10, K=10)
    jobs = make_jobs(40, T=T, seed=seed, small=small)
    for job in jobs:
        # paper Table-I ranges
        assert (1 <= job.epochs <= 200) and (1 <= job.num_chunks <= 100)
        assert 0 < job.tau and 0 < job.grad_size
        assert 0.1 <= job.worker_bw <= 5.0 and 5.0 <= job.ps_bw <= 20.0
        # one worker fits on some worker server, one PS on some PS server
        assert np.any(np.all(cluster.worker_caps >= job.worker_res[None] - 1e-9,
                             axis=1)), "worker demand exceeds every server"
        assert np.any(np.all(cluster.ps_caps >= job.ps_res[None] - 1e-9,
                             axis=1)), "PS demand exceeds every server"
        assert job.ps_res[0] == 0.0, "PS instances must not demand GPUs"
        # normalization keeps per-chunk time << one slot (Sec. III-B) and
        # the fastest duration within the paper's target band
        assert job.min_duration <= 0.9 * job.epochs + 1
        assert job.chunk_time <= 1.0 + 1e-9
        # enough PS bandwidth exists to feed the max worker fleet
        assert job.ps_for(job.num_chunks) <= job.num_chunks


def test_jobs_complete_under_ample_capacity():
    """On an oversized cluster a simple admit-all baseline finishes every
    job — the generator never emits impossible work."""
    from repro.sim import simulate
    T = 80
    cluster = make_cluster(T=T, H=40, K=40)
    jobs = make_jobs(15, T=40, seed=11, small=True)
    r = simulate(cluster, jobs, scheduler="dorm", check=True)
    assert r.accepted == len(jobs)
    assert r.completed == len(jobs)
