"""Property-style (seeded) tests for the workload/cluster generator
(sim/workload.py): arrival-process bounds and burstiness, feasibility
of every generated job on the generated cluster, and the open-ended
``stream_jobs`` serving trace (reproducibility, ordering, rate shape)."""
import itertools

import numpy as np
import pytest

from repro.sim import make_cluster, make_jobs, stream_jobs
from repro.sim.workload import _arrivals, _burst_profile


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("T,n", [(50, 80), (100, 200)])
def test_arrivals_stay_within_horizon(seed, T, n):
    jobs = make_jobs(n, T=T, seed=seed)
    arr = np.array([j.arrival for j in jobs])
    assert np.all(arr >= 0)
    assert np.all(arr < T)
    assert np.all(arr[:-1] <= arr[1:]), "jobs are emitted in arrival order"


def test_burst_windows_raise_rate():
    """The nonhomogeneous process concentrates mass: burst windows carry a
    x4 rate, and the final T//10 slots are damped to ~nothing — so the
    busiest window must far exceed the uniform share and the tail must see
    almost none of the arrivals."""
    T, n = 200, 4000
    rng = np.random.default_rng(42)
    arr = _arrivals(n, T, rng)
    counts = np.bincount(arr, minlength=T)
    width = max(2, T // 20)
    window = np.convolve(counts, np.ones(2 * width), mode="valid")
    uniform_window = n * (2 * width) / T
    assert window.max() > 2.0 * uniform_window, "no burst window detected"
    tail = counts[-T // 10:].sum()
    assert tail < 0.02 * n, f"tail arrivals not damped: {tail}/{n}"


@pytest.mark.parametrize("seed", range(30))
def test_edge_bursts_keep_full_mass(seed):
    """Regression: burst windows used to be clipped at the trace edges
    (``base[max(0, c-width):c+width]``), so a burst centered near 0 or T
    silently lost up to half its slot mass.  Windows now wrap (indices
    mod T): every burst boosts exactly ``2*width`` slots regardless of
    where its center lands."""
    T = 40                      # small T => centers frequently near edges
    width = max(2, T // 20)
    n_bursts = max(1, T // 40)
    rng = np.random.default_rng(seed)
    base = _burst_profile(T, rng)
    # n_bursts == 1 here, so boosted slots are exactly the x4 ones
    assert n_bursts == 1
    assert (base == 4.0).sum() == 2 * width, (
        f"burst lost mass at the edge: {(base == 4.0).sum()} boosted "
        f"slots, expected {2 * width}")
    assert np.all((base == 1.0) | (base == 4.0))


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("small", [True, False])
def test_generated_jobs_feasible_on_generated_cluster(seed, small):
    """Every job must be schedulable in principle: each worker/PS instance
    fits on at least one server of the generated fleet, the per-job
    parameter ranges hold, and the fastest possible duration fits the
    horizon with room for the paper's target completion times."""
    T = 60
    cluster = make_cluster(T=T, H=10, K=10)
    jobs = make_jobs(40, T=T, seed=seed, small=small)
    for job in jobs:
        # paper Table-I ranges
        assert (1 <= job.epochs <= 200) and (1 <= job.num_chunks <= 100)
        assert 0 < job.tau and 0 < job.grad_size
        assert 0.1 <= job.worker_bw <= 5.0 and 5.0 <= job.ps_bw <= 20.0
        # one worker fits on some worker server, one PS on some PS server
        assert np.any(np.all(cluster.worker_caps >= job.worker_res[None] - 1e-9,
                             axis=1)), "worker demand exceeds every server"
        assert np.any(np.all(cluster.ps_caps >= job.ps_res[None] - 1e-9,
                             axis=1)), "PS demand exceeds every server"
        assert job.ps_res[0] == 0.0, "PS instances must not demand GPUs"
        # normalization keeps per-chunk time << one slot (Sec. III-B) and
        # the fastest duration within the paper's target band
        assert job.min_duration <= 0.9 * job.epochs + 1
        assert job.chunk_time <= 1.0 + 1e-9
        # enough PS bandwidth exists to feed the max worker fleet
        assert job.ps_for(job.num_chunks) <= job.num_chunks


def test_jobs_complete_under_ample_capacity():
    """On an oversized cluster a simple admit-all baseline finishes every
    job — the generator never emits impossible work."""
    from repro.sim import simulate
    T = 80
    cluster = make_cluster(T=T, H=40, K=40)
    jobs = make_jobs(15, T=40, seed=11, small=True)
    r = simulate(cluster, jobs, scheduler="dorm", check=True)
    assert r.accepted == len(jobs)
    assert r.completed == len(jobs)


# -- the open-ended serving stream -----------------------------------------

def test_stream_jobs_reproducible_and_ordered():
    """The stream is a pure function of the seed: two generators with the
    same seed replay the identical trace (the per-scheduler fairness
    contract of the serving scenario); jids are sequential and arrivals
    nondecreasing."""
    a = list(stream_jobs(rate=0.5, seed=7, max_slots=400))
    b = list(stream_jobs(rate=0.5, seed=7, max_slots=400))
    assert len(a) == len(b) > 0
    for ja, jb in zip(a, b):
        assert ja.jid == jb.jid and ja.arrival == jb.arrival
        assert ja.epochs == jb.epochs and ja.tau == jb.tau
        np.testing.assert_array_equal(ja.worker_res, jb.worker_res)
    arr = np.array([j.arrival for j in a])
    assert np.all(arr[:-1] <= arr[1:])
    assert [j.jid for j in a] == list(range(len(a)))
    assert arr.max() < 400
    c = list(stream_jobs(rate=0.5, seed=8, max_slots=400))
    assert [j.arrival for j in a] != [j.arrival for j in c], \
        "different seeds must give different traces"


def test_stream_jobs_prefix_stable_and_unbounded():
    """``max_slots`` only truncates the arrival clock: the bounded trace
    is an exact prefix of the unbounded stream (same seed), and the
    unbounded generator keeps producing (O(1) memory, never materialised)."""
    bounded = list(stream_jobs(rate=0.5, seed=3, max_slots=200))
    unbounded = stream_jobs(rate=0.5, seed=3)
    prefix = list(itertools.islice(unbounded, len(bounded)))
    assert [(j.jid, j.arrival) for j in bounded] == \
        [(j.jid, j.arrival) for j in prefix]
    later = next(unbounded)     # generator keeps producing past the cut
    assert later.jid == len(bounded) and later.arrival >= 200


def test_stream_jobs_diurnal_rate_shape():
    """Arrivals follow the diurnal sinusoid: with bursts disabled, the
    half-period around the peak must collect measurably more jobs than
    the half-period around the trough."""
    period = 200
    jobs = list(stream_jobs(rate=1.0, seed=0, max_slots=10 * period,
                            diurnal_period=period, diurnal_amp=0.8,
                            burst_prob=0.0))
    arr = np.array([j.arrival for j in jobs])
    phase = (arr % period) / period
    peak = ((phase > 0.05) & (phase < 0.45)).sum()      # sin > 0 half
    trough = ((phase > 0.55) & (phase < 0.95)).sum()    # sin < 0 half
    assert peak > 1.5 * trough, (peak, trough)


def test_stream_jobs_feasible_on_cluster():
    """Streamed jobs use the same Table-I sampler as ``make_jobs``: every
    one fits the paper-scale fleet."""
    cluster = make_cluster(T=64, H=10, K=10)
    for job in itertools.islice(stream_jobs(rate=0.5, seed=1), 60):
        assert np.any(np.all(cluster.worker_caps >= job.worker_res[None]
                             - 1e-9, axis=1))
        assert np.any(np.all(cluster.ps_caps >= job.ps_res[None] - 1e-9,
                             axis=1))
        assert job.ps_res[0] == 0.0
