"""Fault tolerance, checkpointing, elasticity, straggler handling, data
pipeline determinism, gradient compression."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, DataPipeline, PipelineState
from repro.runtime.driver import FaultInjector, run_with_restarts
from repro.runtime.elastic import dp_width
from repro.runtime.straggler import (BoundedStaleness, StragglerConfig,
                                     StragglerMonitor)
from repro.train.compress import ErrorFeedback, quantize_int8, dequantize


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int32)}}
    ckpt.save(str(tmp_path), 7, tree, extra={"pipeline": {"step": 3}})
    out, extra = ckpt.restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
    assert extra["pipeline"]["step"] == 3
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"a": np.arange(8, dtype=np.float32)}
    path = ckpt.save(str(tmp_path), 1, tree)
    data = np.load(path / "data.npz")
    bad = {k: data[k].copy() for k in data.files}
    bad["a"][0] = 999.0
    np.savez(path / "data.npz", **bad)
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 1, tree)


def test_checkpoint_retention(tmp_path):
    tree = {"a": np.zeros(2)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep_last=3)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("ckpt_*"))
    assert steps == [3, 4, 5]


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = {"w": np.random.rand(64, 64).astype(np.float32)}
    saver.save_async(10, tree)
    saver.wait()
    out, _ = ckpt.restore(str(tmp_path), 10, tree)
    np.testing.assert_array_equal(out["w"], tree["w"])


def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=5)
    p1 = DataPipeline(cfg)
    batches = [p1.next_batch() for _ in range(5)]
    # resume from step 3
    p2 = DataPipeline(cfg, PipelineState(step=3))
    b3 = p2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    # worker slices partition the batch
    sl0 = p1.worker_slice(batches[0], 0, 2)
    sl1 = p1.worker_slice(batches[0], 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([sl0["tokens"], sl1["tokens"]]), batches[0]["tokens"])


def test_restart_on_injected_failures(tmp_path):
    """Training survives node failures and NaNs; loss trace continues."""
    cfg = DataConfig(vocab_size=31, seq_len=8, global_batch=2, seed=1)
    pipeline = DataPipeline(cfg)
    state = {"w": np.zeros(4, np.float32), "step_sum": np.zeros(1, np.float32)}

    def train_fn(state, batch, step):
        state = dict(state)
        state["w"] = state["w"] + 0.1
        state["step_sum"] = state["step_sum"] + batch["tokens"].mean()
        return state, float(np.abs(state["w"]).mean())

    inj = FaultInjector(fail_at=[15, 37])
    out = run_with_restarts(train_fn, state, pipeline, str(tmp_path),
                            total_steps=50, save_every=10, injector=inj)
    assert out["final_step"] == 50
    assert out["restarts"] == 2
    # deterministic data path: state reflects exactly 50 effective steps
    ref_pipeline = DataPipeline(cfg)
    ref = {"w": np.zeros(4, np.float32), "step_sum": np.zeros(1, np.float32)}
    for s in range(50):
        ref, _ = train_fn(ref, ref_pipeline.next_batch(), s)
    np.testing.assert_allclose(out["state"]["w"], ref["w"], rtol=1e-6)


def test_cross_mesh_restore(tmp_path):
    """Checkpoint taken with one sharding restores through another."""
    tree = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    ckpt.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec
    sh = {"w": NamedSharding(mesh, PartitionSpec(None, None))}
    out, _ = ckpt.restore(str(tmp_path), 1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


def test_straggler_monitor():
    mon = StragglerMonitor(4, StragglerConfig(min_samples=2))
    for step in range(4):
        for w in range(4):
            mon.record(w, 1.0 if w != 2 else 3.5)
    assert mon.stragglers() == [2]
    assert mon.healthy_workers() == [0, 1, 3]


def test_bounded_staleness_order():
    bs = BoundedStaleness(staleness=1)
    assert bs.push("g0") is None
    assert bs.push("g1") == "g0"
    assert bs.push("g2") == "g1"


def test_dp_width():
    assert dp_width(5, 8) == 4
    assert dp_width(16, 8) == 8
    assert dp_width(1, 8) == 1


def test_int8_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    # plain quantization: biased per step; EF: residual carries the error
    res = ErrorFeedback.init({"g": g})
    acc_plain = np.zeros(256)
    acc_ef = np.zeros(256)
    acc_true = np.zeros(256)
    for step in range(50):
        gs = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        q, s = quantize_int8(gs)
        acc_plain += np.asarray(dequantize(q, s))
        out, res = ErrorFeedback.apply({"g": gs}, res)
        acc_ef += np.asarray(out["g"])
        acc_true += np.asarray(gs)
    err_plain = np.abs(acc_plain - acc_true).mean()
    err_ef = np.abs(acc_ef - acc_true).mean()
    assert err_ef <= err_plain * 1.05
    # EF residual stays bounded
    assert float(jnp.abs(res["g"]).max()) < 1.0
