"""Vectorized repack kernels (core/repack.py) vs the greedy reference
loops (``step_reference``): exact placement equality on seeded
paper-scale instances and randomized adversarial instances, the engine's
``dirty``-gated repack skipping, and the stateless throughput
rate-matrix contract."""
import numpy as np
import pytest

from repro.core import baselines
from repro.core.baselines import BASELINES, DRF, FIFO, RRH
from repro.core.types import ClusterSpec, Job, SigmoidUtility
from repro.sim import engine, make_cluster, make_jobs, simulate
from repro.sim.scenarios import StragglerThroughput, make_hetero_cluster

REACTIVE = ["fifo", "drf", "rrh", "dorm"]


def _assert_steps_equal(a, b, ctx):
    assert set(a) == set(b), f"{ctx}: placed-job sets differ"
    for jid in a:
        assert np.array_equal(a[jid][0], b[jid][0]), f"{ctx}: y differs jid={jid}"
        assert np.array_equal(a[jid][1], b[jid][1]), f"{ctx}: z differs jid={jid}"


def _replay_compare(cluster, jobs, name, fixed_workers=8, churn_seed=None):
    """Drive a kernel-backed and a reference-backed scheduler through the
    same event sequence and assert every repack's placements are exactly
    equal.  Completions follow the kernel's own allocation (identical to
    the reference's by the running equality); ``churn_seed`` adds random
    mid-run completions to exercise pool removal."""
    A = BASELINES[name](cluster, fixed_workers=fixed_workers)
    B = BASELINES[name](cluster, fixed_workers=fixed_workers)
    by_slot = {}
    for j in jobs:
        if j.arrival < cluster.T:
            by_slot.setdefault(j.arrival, []).append(j)
    remaining = {}
    rng = np.random.default_rng(churn_seed) if churn_seed is not None else None
    steps = 0
    for t in range(cluster.T):
        for job in by_slot.get(t, ()):
            ra, rb = A.on_arrival(job, t), B.on_arrival(job, t)
            assert ra == rb
            if ra:
                remaining[job.jid] = job.total_work_slots
        a = A.step_kernel(t)
        b = B.step_reference(t)
        _assert_steps_equal(a, b, f"{name} t={t}")
        steps += 1
        done = []
        for jid, (y, _) in a.items():
            remaining[jid] -= float(y.sum())
            if remaining[jid] <= 1e-9:
                done.append(jid)
        if rng is not None and remaining and rng.random() < 0.3:
            jid = list(remaining)[int(rng.integers(len(remaining)))]
            if jid not in done:
                done.append(jid)
        for jid in done:
            A.on_completion(jid, t)
            B.on_completion(jid, t)
            del remaining[jid]
    assert steps == cluster.T


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("name", ["drf", "dorm", "rrh"])
def test_kernel_placements_equal_reference_paper_scale(seed, name):
    """The acceptance instances: fig3-shaped T=100, 50+50 servers, 200
    jobs (small internals), five seeds — every repack's placements from
    the vectorized kernels equal ``step_reference`` exactly."""
    cluster = make_cluster(T=100, H=50, K=50)
    jobs = make_jobs(200, T=100, seed=seed, small=True)
    _replay_compare(cluster, jobs, name)


@pytest.mark.parametrize("name", REACTIVE)
def test_kernel_placements_equal_reference_full_size(name):
    """Full-size (paper-range) jobs, where DRF/Dorm repack hundreds of
    chunks per event and PS placements span servers."""
    cluster = make_cluster(T=60, H=12, K=12)
    jobs = make_jobs(30, T=60, seed=3, small=False)
    _replay_compare(cluster, jobs, name, churn_seed=1)


@pytest.mark.parametrize("name", REACTIVE)
def test_kernel_placements_equal_reference_hetero_fleet(name):
    """Heterogeneous worker fleet: per-server capacities differ, so
    first-fit cursors and block envelopes see non-uniform rows."""
    cluster = make_hetero_cluster(T=50, H=17, K=9, seed=4)
    jobs = make_jobs(40, T=50, seed=4, small=False)
    _replay_compare(cluster, jobs, name, churn_seed=2)


def _random_instance(rng, tight_ps=False, tight_pool=False):
    H = int(rng.integers(1, 7))
    K = int(rng.integers(1, 7))
    scale = 0.35 if tight_pool else 1.0
    wcaps = rng.uniform(0.5, 8.0, (H, 5)) * scale
    scaps = rng.uniform(0.05 if tight_ps else 0.5, 6.0, (K, 5)) * \
        (0.25 if tight_ps else 1.0)
    cluster = ClusterSpec(T=8, worker_caps=wcaps, ps_caps=scaps)
    jobs = []
    for jid in range(int(rng.integers(1, 9))):
        w = rng.uniform(0, 3.0, 5)
        if rng.random() < 0.3:
            w[rng.integers(0, 5)] = 0.0        # zero-demand resources
        s = rng.uniform(0, 2.0, 5)
        jobs.append(Job(
            jid=jid, arrival=0, epochs=1,
            num_chunks=int(rng.integers(1, 7)),
            minibatches_per_chunk=5, tau=0.01, grad_size=0.1,
            worker_bw=float(rng.uniform(0.1, 5.0)),
            ps_bw=float(rng.uniform(0.2, 8.0)),
            worker_res=w, ps_res=s,
            utility=SigmoidUtility(10.0, 0.1, 4.0)))
    return cluster, jobs


@pytest.mark.parametrize("mode", ["plain", "tight_ps", "tight_pool"])
def test_kernel_equals_reference_randomized(mode):
    """300 randomized instances per regime: ``tight_ps`` forces
    PS-placement rollbacks (worker success then PS failure), and
    ``tight_pool`` forces full-pool rejections; placements must match
    the reference exactly in every case."""
    rng = np.random.default_rng({"plain": 0, "tight_ps": 1, "tight_pool": 2}[mode])
    saw_placement = saw_rejection = False
    for _ in range(300):
        cluster, jobs = _random_instance(
            rng, tight_ps=mode == "tight_ps", tight_pool=mode == "tight_pool")
        for name in ("drf", "dorm"):
            A = BASELINES[name](cluster)
            B = BASELINES[name](cluster)
            for j in jobs:
                A.on_arrival(j, 0)
                B.on_arrival(j, 0)
            a = A.step_kernel(0)
            b = B.step_reference(0)
            _assert_steps_equal(a, b, f"{name} {mode}")
            saw_placement = saw_placement or bool(a)
            placed = sum(int(y.sum()) for y, _ in a.values())
            wanted = sum(j.num_chunks for j in jobs)
            saw_rejection = saw_rejection or placed < wanted
    assert saw_placement and saw_rejection   # both regimes actually exercised


def test_kernel_ps_rollback_exact():
    """A hand-built instance where the worker chunk fits but the PS
    demand cannot be placed: the kernel must roll the worker placement
    back and block the job, exactly like the reference."""
    wcaps = np.full((2, 5), 10.0)
    scaps = np.full((1, 5), 1.0)               # PS pool too small
    cluster = ClusterSpec(T=4, worker_caps=wcaps, ps_caps=scaps)
    j0 = Job(jid=0, arrival=0, epochs=1, num_chunks=3,
             minibatches_per_chunk=5, tau=0.01, grad_size=0.1,
             worker_bw=4.0, ps_bw=4.0,          # 1 PS per worker chunk
             worker_res=np.full(5, 1.0), ps_res=np.full(5, 2.0),
             utility=SigmoidUtility(10.0, 0.1, 4.0))
    for name in ("drf", "dorm"):
        A = BASELINES[name](cluster)
        B = BASELINES[name](cluster)
        A.on_arrival(j0, 0)
        B.on_arrival(j0, 0)
        a, b = A.step_kernel(0), B.step_reference(0)
        _assert_steps_equal(a, b, name)
        assert a == {}                          # PS rollback blocked the job


def test_engine_paper_scale_end_to_end_matches_reference_impl():
    """Engine runs with the kernel vs the reference repack implementation
    produce identical results (utilities, completion slots)."""
    cluster = make_cluster(T=60, H=10, K=10)
    jobs = make_jobs(50, T=60, seed=11, small=True)
    for name in REACTIVE:
        a = simulate(cluster, jobs, scheduler=name, check=True)
        assert baselines.REPACK_IMPL == "kernel"
        baselines.REPACK_IMPL = "reference"
        try:
            b = simulate(cluster, jobs, scheduler=name, check=True)
        finally:
            baselines.REPACK_IMPL = "kernel"
        assert a.completion == b.completion
        assert a.total_utility == pytest.approx(b.total_utility, abs=1e-9)


# ---------------------------------------------------------------------------
# dirty wiring: no-op events must not trigger repacks.
# ---------------------------------------------------------------------------

def _counting(name, calls):
    base = BASELINES[name]

    class Counting(base):
        def step(self, t):
            calls.append(t)
            return super().step(t)

    return Counting


@pytest.mark.parametrize("name", ["fifo", "rrh"])
def test_noop_completion_skips_repack(name, monkeypatch):
    """With ample capacity nothing ever waits under FIFO/RRH, so the only
    repacks are at arrival slots: completions must not add any."""
    calls = []
    monkeypatch.setitem(BASELINES, name, _counting(name, calls))
    cluster = make_cluster(T=80, H=40, K=40)
    jobs = make_jobs(8, T=40, seed=5, small=True)
    r = engine.run(cluster, jobs, scheduler=name, check=True)
    assert r.completed == r.accepted > 0
    arrival_slots = {j.arrival for j in jobs if j.arrival < cluster.T}
    assert set(calls) <= arrival_slots          # no completion-slot repacks
    assert len(calls) <= len(arrival_slots)


def test_waiting_queue_completion_still_repacks(monkeypatch):
    """The converse guard: when jobs are queued, a completion must mark
    the scheduler dirty and trigger a repack (otherwise waiting jobs
    would never start)."""
    calls = []
    monkeypatch.setitem(BASELINES, "fifo", _counting("fifo", calls))
    cluster = make_cluster(T=100, H=2, K=2)     # tiny pool: queue builds
    jobs = make_jobs(12, T=30, seed=7, small=True)
    r = engine.run(cluster, jobs, scheduler="fifo", check=True)
    arrival_slots = {j.arrival for j in jobs if j.arrival < cluster.T}
    assert set(calls) - arrival_slots           # some repack at a completion
    assert r.completed > 0


def test_dirty_flag_contract_unit():
    """Scheduler-level contract of the three no-op cases."""
    cluster = make_cluster(T=40, H=20, K=20)
    jobs = make_jobs(6, T=20, seed=1, small=True)
    # FIFO: completion with an empty wait queue leaves dirty unset
    f = FIFO(cluster)
    for j in jobs:
        f.on_arrival(j, 0)
    assert f.dirty
    f.step(0)
    f.dirty = False
    running = [j for j in jobs if j.jid in f.alloc]
    assert running
    f.on_completion(running[0].jid, 1)
    assert not f.dirty                          # nothing was waiting
    # RRH: a rejected arrival leaves dirty unset
    r = RRH(cluster, threshold=float("inf"))
    r.dirty = False
    assert r.on_arrival(jobs[0], 0) is False
    assert not r.dirty
    # DRF: any completion with live jobs dirties
    d = DRF(cluster)
    for j in jobs:
        d.on_arrival(j, 0)
    d.step(0)
    d.dirty = False
    d.on_completion(jobs[0].jid, 1)
    assert d.dirty


# ---------------------------------------------------------------------------
# stateless throughput rate matrix.
# ---------------------------------------------------------------------------

def test_rate_matrix_equals_call_and_engine_paths_agree():
    cluster = make_cluster(T=50, H=10, K=10)
    jobs = make_jobs(30, T=50, seed=3, small=True)
    tp = StragglerThroughput(seed=3, slow_frac=0.4, slowdown=4.0, detect=False)
    assert tp.stateless
    job = jobs[0]
    mat = tp.rate_matrix(job, 4, 7, 9)
    ref = [StragglerThroughput(seed=3, slow_frac=0.4, slowdown=4.0,
                               detect=False)(job, 4, 7 + i) for i in range(9)]
    assert np.allclose(mat, ref, rtol=0, atol=0)    # bit-equal draws
    assert np.all((0.0 < mat) & (mat <= 1.0))
    # engine: matrix path (stateless) vs per-slot column path (plain fn)
    a = simulate(cluster, jobs, scheduler="fifo", check=False, throughput=tp)
    plain = StragglerThroughput(seed=3, slow_frac=0.4, slowdown=4.0,
                                detect=False)
    col = lambda job, n, t: plain(job, n, t)        # no .stateless attr
    b = simulate(cluster, jobs, scheduler="fifo", check=False, throughput=col)
    assert a.completion == b.completion
    assert a.total_utility == pytest.approx(b.total_utility, rel=1e-9)


def test_rate_matrix_requires_stateless():
    tp = StragglerThroughput(seed=0, detect=True)
    assert not tp.stateless
    job = make_jobs(1, T=10, seed=0, small=True)[0]
    with pytest.raises(RuntimeError):
        tp.rate_matrix(job, 2, 0, 4)


# The hypothesis property tests for the kernels live in
# tests/test_repack_property.py (whole-module skip when hypothesis is
# absent, per the repo convention) so this module always runs.
