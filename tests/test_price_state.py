"""PriceState device-residency suite (PR 4).

Pins the contract of the dual-representation price state
(`core/pricing.py`):

* ``release`` exactly inverts ``commit`` on the host mirror — bit-equal
  ``g``/``v``, version bumped twice (hypothesis property, dyadic demands
  so float adds are exact);
* the host mirror stays bit-consistent with the device residency across
  interleaved fused-engine decisions and direct commits/releases;
* a full jax-impl run performs O(1) full host→device uploads
  (``device_uploads``), not one per accepted job — the tentpole claim;
* handing out the mutable host arrays (``.g``/``.v`` reads, rebinds)
  conservatively drops and re-uploads the residency.
"""
import numpy as np
import pytest

from repro.core import OASiS, price_params_from_jobs
from repro.core.pricing import PriceState, size_bucket
from repro.core.types import Job, SigmoidUtility
from repro.sim import make_cluster, make_jobs


def _mk_job(jid, wres, sres):
    return Job(jid=jid, arrival=0, epochs=2, num_chunks=3,
               minibatches_per_chunk=10, tau=0.02, grad_size=0.05,
               worker_bw=1.0, ps_bw=4.0, worker_res=np.asarray(wres, float),
               ps_res=np.asarray(sres, float),
               utility=SigmoidUtility(50.0, 1.0, 3.0))


def _state(T=12, H=4, K=4):
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(8, T=T, seed=0, small=True)
    return PriceState(cluster, price_params_from_jobs(jobs, cluster))


def _alloc(rng, T, S, n_slots):
    slots = rng.choice(T, size=min(n_slots, T), replace=False)
    return {int(t): rng.integers(0, 4, size=S).astype(np.int64)
            for t in slots}


def test_release_inverts_commit_randomized():
    """(g + d) - d == g bitwise for dyadic demands; version bumped twice."""
    rng = np.random.default_rng(0)
    state = _state()
    T, H, K = state.cluster.T, state.cluster.H, state.cluster.K
    # a prior commit so the inversion starts from a non-zero tensor
    base = _mk_job(0, rng.integers(0, 8, 5) / 4.0, rng.integers(0, 8, 5) / 4.0)
    state.commit(base, _alloc(rng, T, H, 3), _alloc(rng, T, K, 2))
    for trial in range(25):
        job = _mk_job(trial + 1, rng.integers(0, 16, 5) / 4.0,
                      rng.integers(0, 16, 5) / 4.0)
        workers = _alloc(rng, T, H, int(rng.integers(1, T)))
        ps = _alloc(rng, T, K, int(rng.integers(1, T)))
        g0, v0 = state.g.copy(), state.v.copy()
        ver0 = state.version
        state.commit(job, workers, ps)
        assert state.version == ver0 + 1
        state.release(job, workers, ps)
        assert state.version == ver0 + 2
        assert np.array_equal(state.g, g0), "release did not invert commit (g)"
        assert np.array_equal(state.v, v0), "release did not invert commit (v)"


def test_commit_semantics_match_dense_sum():
    """commit accumulates exactly y*res / z*res at the committed slots."""
    rng = np.random.default_rng(1)
    state = _state()
    T, H, K = state.cluster.T, state.cluster.H, state.cluster.K
    want_g = np.zeros((T, H, 5))
    want_v = np.zeros((T, K, 5))
    for jid in range(5):
        job = _mk_job(jid, rng.integers(0, 8, 5) / 4.0,
                      rng.integers(0, 8, 5) / 4.0)
        workers = _alloc(rng, T, H, int(rng.integers(1, 5)))
        ps = _alloc(rng, T, K, int(rng.integers(1, 5)))
        state.commit(job, workers, ps)
        for t, y in workers.items():
            want_g[t] += y[:, None] * job.worker_res[None, :]
        for t, z in ps.items():
            want_v[t] += z[:, None] * job.ps_res[None, :]
    assert np.array_equal(state.g, want_g)
    assert np.array_equal(state.v, want_v)


def test_window_prices_match_full_tables():
    rng = np.random.default_rng(2)
    state = _state()
    job = _mk_job(0, rng.integers(1, 8, 5) / 4.0, rng.integers(1, 8, 5) / 4.0)
    state.commit(job, _alloc(rng, state.cluster.T, state.cluster.H, 4),
                 _alloc(rng, state.cluster.T, state.cluster.K, 4))
    slots = np.array([0, 3, 7])
    assert np.array_equal(state.worker_prices_at(slots),
                          state.worker_prices()[slots])
    assert np.array_equal(state.ps_prices_at(slots), state.ps_prices()[slots])


def test_capacity_ok_and_gpu_slot_usage():
    rng = np.random.default_rng(3)
    state = _state()
    job = _mk_job(0, rng.integers(1, 8, 5) / 4.0, rng.integers(1, 8, 5) / 4.0)
    state.commit(job, _alloc(rng, state.cluster.T, state.cluster.H, 4),
                 _alloc(rng, state.cluster.T, state.cluster.K, 4))
    assert np.array_equal(state.gpu_slot_usage(), state.g[:, :, 0].sum(axis=1))
    ok_w, ok_ps = state.capacity_ok()
    assert ok_w == bool(np.all(state.g <= state.cluster.worker_caps[None] + 1e-6))
    assert ok_ps == bool(np.all(state.v <= state.cluster.ps_caps[None] + 1e-6))
    state.g[:] = state.cluster.worker_caps[None] + 1.0     # force violation
    assert state.capacity_ok() == (False, ok_ps)


def test_device_mirror_consistent_after_interleaved_commits():
    """Interleave device-resident commits/releases with direct host-path
    bookkeeping: the download of the residency must stay bit-equal to the
    host mirror (CPU float64)."""
    rng = np.random.default_rng(4)
    state = _state()
    T, H, K = state.cluster.T, state.cluster.H, state.cluster.K
    dev = state.device_state()                     # residency begins: 1 upload
    assert state.device_uploads == 1
    trace = []
    for jid in range(6):
        job = _mk_job(jid, rng.integers(0, 8, 5) / 4.0,
                      rng.integers(0, 8, 5) / 4.0)
        workers = _alloc(rng, T, H, int(rng.integers(1, 6)))
        ps = _alloc(rng, T, K, int(rng.integers(1, 6)))
        state.commit(job, workers, ps)
        trace.append((job, workers, ps))
        if jid % 2:                                # interleave releases
            state.release(*trace.pop(0))
    dev = state.device_state()
    assert state.device_uploads == 1, "interleaved commits forced re-uploads"
    assert np.array_equal(np.asarray(dev[0]), state._g_host)
    assert np.array_equal(np.asarray(dev[1]), state._v_host)


def test_jax_impl_run_is_o1_uploads():
    """The tentpole claim: a whole impl="jax" simulation performs O(1)
    full host→device state syncs, not one per accepted job."""
    cluster = make_cluster(T=40, H=8, K=8)
    jobs = make_jobs(40, T=40, seed=3, small=True)
    params = price_params_from_jobs(jobs, cluster)
    sched = OASiS(cluster, params, impl="jax")
    by_slot = {}
    for j in jobs:
        by_slot.setdefault(j.arrival, []).append(j)
    for t in sorted(by_slot):
        sched.on_arrivals(by_slot[t])
    assert len(sched.accepted) > 5, "degenerate instance"
    assert sched.state.device_uploads == 1, (
        f"{sched.state.device_uploads} uploads for "
        f"{len(sched.accepted)} accepted jobs — the per-accept re-upload "
        f"is back")


def test_host_reads_and_rebinds_invalidate_residency():
    rng = np.random.default_rng(5)
    state = _state()
    state.device_state()
    assert state.device_uploads == 1
    # reading .g hands out the mutable mirror -> residency dropped
    g = state.g
    g[3] += 1.0
    dev = state.device_state()
    assert state.device_uploads == 2
    assert np.array_equal(np.asarray(dev[0]), state._g_host)
    # rebinding likewise
    state.v = rng.uniform(0, 2, state._v_host.shape)
    dev = state.device_state()
    assert state.device_uploads == 3
    assert np.array_equal(np.asarray(dev[1]), state._v_host)


def test_commit_window_at_horizon_edges():
    """Bucketed windows near t = T-1 and windows wider than T stay in
    bounds and land on the right slots."""
    rng = np.random.default_rng(6)
    state = _state(T=10)
    state.device_state()
    job = _mk_job(0, rng.integers(1, 8, 5) / 4.0, rng.integers(1, 8, 5) / 4.0)
    y = np.ones(state.cluster.H, dtype=np.int64)
    z = np.ones(state.cluster.K, dtype=np.int64)
    state.commit(job, {9: y}, {9: z})                        # last slot
    state.commit(job, {0: y, 9: y}, {0: z, 9: z})            # window == T
    want = np.zeros((10, state.cluster.H, 5))
    want[9] += y[:, None] * job.worker_res[None, :]
    for t in (0, 9):
        want[t] += y[:, None] * job.worker_res[None, :]
    dev = state.device_state()
    assert state.device_uploads == 1
    assert np.array_equal(np.asarray(dev[0]), want)
    assert np.array_equal(state._g_host, want)


# ---------------------------------------------------------------------------
# rolling window (continuous serving mode)
# ---------------------------------------------------------------------------

def _mirror_commit(full, win, origin, job, workers, ps, release=False):
    """Apply the same logical commit to the windowed state (window-local
    slots) and the fixed-horizon state (absolute slots)."""
    op_w = win.release if release else win.commit
    op_f = full.release if release else full.commit
    op_w(job, workers, ps)
    op_f(job, {origin + t: y for t, y in workers.items()},
         {origin + t: z for t, z in ps.items()})


def test_rolling_window_matches_fixed_horizon_deterministic():
    """A windowed PriceState driven by ``advance`` + window-local commits
    stays bit-equal to the fixed-horizon state on the overlapping slots —
    host mirror AND device residency — while the slid-out slots retire
    into exact aggregates."""
    rng = np.random.default_rng(8)
    T, W = 40, 12
    cluster = make_cluster(T=T, H=4, K=4)
    params = price_params_from_jobs(make_jobs(8, T=T, seed=0, small=True),
                                    cluster)
    full = PriceState(cluster, params)
    win = PriceState(cluster, params, window=W)
    full.device_state()
    win.device_state()
    origin = 0
    for step in range(12):
        origin += int(rng.integers(0, 5))
        win.advance(origin)
        live = max(min(T - origin, W), 0)
        if live:
            job = _mk_job(step, rng.integers(0, 8, 5) / 4.0,
                          rng.integers(0, 8, 5) / 4.0)
            slots = rng.choice(live, size=min(3, live), replace=False)
            workers = {int(t): rng.integers(0, 4, 4).astype(np.int64)
                       for t in slots}
            ps = {int(t): rng.integers(0, 4, 4).astype(np.int64)
                  for t in slots}
            _mirror_commit(full, win, origin, job, workers, ps)
            if step % 3 == 2:
                _mirror_commit(full, win, origin, job, workers, ps,
                               release=True)
        ov = max(min(T - origin, W), 0)
        assert np.array_equal(win._g_host[:ov],
                              full._g_host[origin:origin + ov])
        assert np.array_equal(win._v_host[:ov],
                              full._v_host[origin:origin + ov])
        dw, df = win.device_state(), full.device_state()
        assert np.array_equal(np.asarray(dw[0])[:ov],
                              np.asarray(df[0])[origin:origin + ov])
        assert np.array_equal(np.asarray(dw[1])[:ov],
                              np.asarray(df[1])[origin:origin + ov])
    assert win.device_uploads == 1, "advance() must slide on-device, not re-upload"
    assert win.retired_slots == origin
    # dyadic demands -> every float add is exact, so the retired GPU-slot
    # aggregate equals the full table's prefix sum bit for bit
    assert win.retired_gpu_slots == full._g_host[:min(origin, T), :, 0].sum()


def test_advance_is_o1_uploads_and_monotone():
    """The serving-loop invariant: thousands of advances never re-upload
    the residency (O(1) full syncs per run), and the clock cannot move
    backwards."""
    state = _state(T=12)
    win = PriceState(state.cluster, state.params, window=6)
    win.device_state()
    rng = np.random.default_rng(9)
    now = 0
    for step in range(200):
        now += int(rng.integers(0, 3))
        win.advance(now)
        job = _mk_job(step, rng.integers(0, 8, 5) / 4.0,
                      rng.integers(0, 8, 5) / 4.0)
        win.commit(job, {int(rng.integers(0, 6)):
                         rng.integers(0, 3, 4).astype(np.int64)}, {})
        dev = win.device_state()
        assert np.array_equal(np.asarray(dev[0]), win._g_host)
    assert win.device_uploads == 1
    assert win.retired_slots == now
    with pytest.raises(ValueError):
        win.advance(now - 1)


def test_window_geq_T_is_episodic():
    """window >= T clamps to the full horizon: the windowed state is the
    fixed-horizon state (the safety rail for every existing suite)."""
    state = _state(T=10)
    win = PriceState(state.cluster, state.params, window=99)
    assert win.horizon == 10
    assert win.window == 10
    assert win._g_host.shape == state._g_host.shape


def test_size_bucket_monotone():
    prev = 0
    for n in range(1, 400):
        b = size_bucket(n, floor=8, step=64)
        assert b >= n and b >= prev
        prev = b


# ---------------------------------------------------------------------------
# hypothesis property: release inverts commit on arbitrary dyadic traces
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @st.composite
    def _commit_case(draw):
        T = draw(st.integers(2, 16))
        H = draw(st.integers(1, 5))
        K = draw(st.integers(1, 5))
        dyadic = st.integers(0, 32).map(lambda q: q / 4.0)
        wres = np.array([draw(dyadic) for _ in range(5)])
        sres = np.array([draw(dyadic) for _ in range(5)])
        n_slots = draw(st.integers(1, T))
        slots = draw(st.permutations(range(T)))[:n_slots]
        workers = {t: np.array([draw(st.integers(0, 7)) for _ in range(H)],
                               dtype=np.int64) for t in slots}
        ps_slots = draw(st.permutations(range(T)))[:draw(st.integers(1, T))]
        ps = {t: np.array([draw(st.integers(0, 7)) for _ in range(K)],
                          dtype=np.int64) for t in ps_slots}
        prior = draw(st.integers(0, 3))
        return T, H, K, wres, sres, workers, ps, prior

    @settings(max_examples=40, deadline=None)
    @given(_commit_case())
    def test_hypothesis_release_inverts_commit(case):
        T, H, K, wres, sres, workers, ps, prior = case
        cluster = make_cluster(T=T, H=H, K=K)
        jobs = make_jobs(4, T=T, seed=0, small=True)
        state = PriceState(cluster, price_params_from_jobs(jobs, cluster))
        rng = np.random.default_rng(7)
        for jid in range(prior):                   # arbitrary starting tensor
            pj = _mk_job(100 + jid, rng.integers(0, 8, 5) / 4.0,
                         rng.integers(0, 8, 5) / 4.0)
            state.commit(pj, _alloc(rng, T, H, 2), _alloc(rng, T, K, 2))
        job = _mk_job(0, wres, sres)
        g0, v0 = state._g_host.copy(), state._v_host.copy()
        ver0 = state.version
        state.commit(job, workers, ps)
        state.release(job, workers, ps)
        assert state.version == ver0 + 2
        assert np.array_equal(state._g_host, g0)
        assert np.array_equal(state._v_host, v0)
if HAVE_HYPOTHESIS:
    @st.composite
    def _window_trace(draw):
        T = draw(st.integers(6, 20))
        W = draw(st.integers(2, T))
        n_steps = draw(st.integers(1, 6))
        steps = [(draw(st.integers(0, 3)),          # advance increment
                  draw(st.integers(0, 2 ** 16)),    # per-step alloc seed
                  draw(st.booleans()))              # release after commit?
                 for _ in range(n_steps)]
        return T, W, steps

    @settings(max_examples=30, deadline=None)
    @given(_window_trace())
    def test_hypothesis_rolling_window_equals_fixed_horizon(case):
        """advance() + commit/release on the window == the fixed-horizon
        PriceState on the overlapping slots, bit for bit, in both the
        host mirror and the device residency — with O(1) uploads."""
        T, W, steps = case
        cluster = make_cluster(T=T, H=3, K=3)
        params = price_params_from_jobs(
            make_jobs(4, T=T, seed=0, small=True), cluster)
        full = PriceState(cluster, params)
        win = PriceState(cluster, params, window=W)
        full.device_state()
        win.device_state()
        origin = 0
        for adv, jseed, do_release in steps:
            origin += adv
            win.advance(origin)
            live = max(min(T - origin, win.horizon), 0)
            if live:
                rng = np.random.default_rng(jseed)
                job = _mk_job(jseed, rng.integers(0, 8, 5) / 4.0,
                              rng.integers(0, 8, 5) / 4.0)
                workers = {int(t): rng.integers(0, 4, 3).astype(np.int64)
                           for t in rng.choice(live, size=min(2, live),
                                               replace=False)}
                ps = {int(t): rng.integers(0, 4, 3).astype(np.int64)
                      for t in rng.choice(live, size=min(2, live),
                                          replace=False)}
                _mirror_commit(full, win, origin, job, workers, ps)
                if do_release:
                    _mirror_commit(full, win, origin, job, workers, ps,
                                   release=True)
            ov = max(min(T - origin, win.horizon), 0)
            assert np.array_equal(win._g_host[:ov],
                                  full._g_host[origin:origin + ov])
            assert np.array_equal(win._v_host[:ov],
                                  full._v_host[origin:origin + ov])
            dw, df = win.device_state(), full.device_state()
            assert np.array_equal(np.asarray(dw[0])[:ov],
                                  np.asarray(df[0])[origin:origin + ov])
            assert np.array_equal(np.asarray(dw[1])[:ov],
                                  np.asarray(df[1])[origin:origin + ov])
        assert win.device_uploads == 1 and full.device_uploads == 1
        assert win.retired_slots == origin
else:                                                # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_release_inverts_commit():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_rolling_window_equals_fixed_horizon():
        pass
