"""End-to-end behaviour of the full system: simulation pipeline, the
scheduler->trainer integration path, and the dry-run machinery (on a
small forced-device-count subprocess)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import job_from_arch, price_params_from_jobs
from repro.core.oasis import OASiS
from repro.sim import make_cluster, make_jobs, simulate


def test_simulation_all_schedulers_feasible():
    cluster = make_cluster(T=40, H=8, K=8)
    jobs = make_jobs(25, T=40, seed=2, small=True)
    for name in ["oasis", "fifo", "drf", "rrh", "dorm"]:
        r = simulate(cluster, jobs, scheduler=name, check=True)
        assert r.total_utility >= 0
        assert r.completed <= r.accepted <= len(jobs)


def test_job_from_arch_closes_the_loop():
    """Roofline terms of an arch become a schedulable Job."""
    job = job_from_arch("starcoder2-3b", arrival=0, flops_per_token=6 * 3e9,
                        param_bytes=12e9, tokens_per_step=2 ** 19,
                        target_steps=1000)
    cluster = make_cluster(T=50, H=10, K=10)
    params = price_params_from_jobs([job], cluster)
    sched = OASiS(cluster, params)
    s = sched.on_arrival(job)
    assert s is not None, "arch-derived job should be schedulable on an empty cluster"
    assert s.utility > 0


def test_oasis_decision_latency_polynomial():
    """Thm 3 practical check: decisions are sub-second at paper scale."""
    cluster = make_cluster(T=100, H=50, K=50)
    jobs = make_jobs(10, T=100, seed=4, small=False)
    r = simulate(cluster, jobs, scheduler="oasis", check=False, quantum=0)
    assert np.mean(r.decision_seconds) < 1.0, np.mean(r.decision_seconds)


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    """lower+compile a reduced arch on forced 8-device meshes (subprocess
    because device count locks at first jax use)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax
from repro.configs import get_smoke
from repro.launch.dryrun import lower_cell
mesh = jax.make_mesh((2, 4), ("data", "model"))
mesh_m = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for m in (mesh, mesh_m):
    for kind, seq, gb in [("train", 64, 8), ("decode", 64, 8)]:
        r = lower_cell(get_smoke("olmoe_1b_7b"), "t", seq, gb, kind, m)
        assert r["flops"] > 0
        assert r["collectives"]["count"] > 0
print("SUBPROCESS_OK")
"""
    env = dict(os.environ)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.join(
                             os.path.dirname(__file__), ".."), env=env,
                         timeout=600)
    assert "SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]


def test_sharding_rules_valid_for_all_archs():
    """Every param of every arch gets a legal sharding on the production
    mesh topology (validated structurally against a 16x16 shape)."""
    import jax
    from repro.configs import ARCHS, get_config
    from repro.models.layers import is_spec
    from repro.models.model import model_specs
    from repro.parallel.sharding import _spec_for, logical_rules

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = logical_rules(FakeMesh())
    for arch in ARCHS:
        cfg = get_config(arch)
        specs, _ = jax.tree_util.tree_flatten(model_specs(cfg),
                                              is_leaf=is_spec)
        for s in specs:
            spec = _spec_for(tuple(s.shape), tuple(s.axes), FakeMesh(), rules)
            for dim, entry in zip(s.shape, spec):
                if entry is not None:
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    prod = 1
                    for a in axes:
                        prod *= FakeMesh.shape[a]
                    assert dim % prod == 0


def test_dryrun_artifacts_complete():
    """If the production sweep has been run, all 34 cells x 2 meshes exist
    and report finite numbers (skips when artifacts are absent)."""
    base = os.path.join(os.path.dirname(__file__), "..", "experiments")
    root = os.path.join(base, "final")
    if not os.path.isdir(root):
        root = os.path.join(base, "dryrun")
    if not os.path.isdir(root):
        pytest.skip("dry-run artifacts not generated")
    from repro.configs import all_cells
    files = [f for f in os.listdir(root)
             if f.endswith(".json") and not f.endswith(".probe.json")]
    if len(files) < 10:
        pytest.skip("partial dry-run")
    expected = len(all_cells()) * 2
    assert len(files) == expected, (len(files), expected)
    for f in files:
        r = json.load(open(os.path.join(root, f)))
        assert r["flops"] > 0
        assert r["memory"]["temp_bytes"] >= 0
