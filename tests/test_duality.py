"""Primal-dual machinery tests (Appendix E): the allocation-cost
relationship of Lemma 2 and the weak-duality sandwich of Lemma 1,
measured on live instances via OASiS(track_duality=True)."""
import pytest

from repro.core import OASiS, price_params_from_jobs
from repro.sim import make_cluster, make_jobs


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_lemma2_allocation_cost_relationship(seed):
    """For every accepted job: ΔP >= ΔD / alpha (Lemma 2).  Alpha uses the
    literal price-function bounds the lemma is stated for.  The lemma's
    differential form assumes per-instance demand << server capacity
    (paper Appendix E "w_i^r << c_h^r"), hence scale=6."""
    cluster = make_cluster(T=16, H=4, K=4, scale=6.0)
    jobs = make_jobs(12, T=16, seed=seed, small=True)
    params = price_params_from_jobs(jobs, cluster, floor_frac=0.0)
    alpha = params.alpha
    sched = OASiS(cluster, params, track_duality=True)
    for j in sorted(jobs, key=lambda x: x.arrival):
        sched.on_arrival(j)
    assert sched.primal_deltas, "no job accepted — degenerate instance"
    for dp, dd in zip(sched.primal_deltas, sched.dual_deltas):
        # Lemma 2 (allowing small numerical slack on the price integrals;
        # the discrete allocation-cost relationship holds when per-job
        # demand is small vs capacity, which the generator guarantees)
        assert dp >= dd / alpha - 1e-6 * max(1.0, abs(dd)), (dp, dd, alpha)


@pytest.mark.parametrize("seed", [0, 1])
def test_lemma1_duality_sandwich(seed):
    """D_I >= P_I (weak duality on the tracked increments) and every
    accepted job has positive payoff (complementary slackness side)."""
    cluster = make_cluster(T=16, H=4, K=4, scale=6.0)
    jobs = make_jobs(12, T=16, seed=seed, small=True)
    params = price_params_from_jobs(jobs, cluster, floor_frac=0.0)
    sched = OASiS(cluster, params, track_duality=True)
    for j in sorted(jobs, key=lambda x: x.arrival):
        sched.on_arrival(j)
    P = sum(sched.primal_deltas)
    # D_I = D_0 + sum of dual increments; D_0 >= 0, so sum(dd) + D_0 >= P
    # requires checking the increments dominate the primal ones in total
    D_incr = sum(sched.dual_deltas)
    assert D_incr >= P - 1e-9 * max(1.0, P), (D_incr, P)
    for jid, s in sched.accepted.items():
        assert s.payoff > 0
