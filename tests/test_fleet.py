"""Fleet-churn subsystem tests (sim/fleet.py + the engine's churn paths).

Pins:
* zero-churn exactness — an empty ``FleetTrace`` produces bit-identical
  trajectories to ``fleet=None`` for every scheduler, episodic AND
  streaming, across seeded paper-scale instances;
* no over-commit on the surviving fleet (churned runs execute with
  ``check=True``; the reactive driver additionally validates against the
  shrunken effective capacities);
* price-state inversion properties (commit→release on fresh slots is
  bit-exact; ``block_server``/``unblock_server`` round-trips exactly);
* cancellation x churn composition: cancelling a job the shrunken fleet
  already preempted-and-dropped is a no-op, not a double subtraction.
"""
import itertools

import numpy as np
import pytest

from repro.core.pricing import PriceState, price_params_from_jobs
from repro.core.types import ClusterSpec, Job, SigmoidUtility
from repro.sim import engine
from repro.sim.fleet import (DOWN_GRACEFUL, DOWN_LOSSY, UP, FleetEvent,
                             FleetState, FleetTrace, churn_trace,
                             make_fleet_trace)
from repro.sim.workload import make_cluster, make_jobs, stream_jobs

ALL = ("oasis", "fifo", "drf", "rrh", "dorm")


# ---------------------------------------------------------------------------
# fleet.py unit behaviour
# ---------------------------------------------------------------------------

def test_empty_trace_is_falsy():
    assert not FleetTrace()
    assert not FleetTrace(())
    assert FleetTrace((FleetEvent(3, "fail", "worker", 0),))


def test_make_fleet_trace_deterministic_and_well_formed():
    cluster = make_cluster(T=80, H=6, K=6)
    a = make_fleet_trace(cluster, seed=4, mtbf=120.0, mttr=10.0)
    b = make_fleet_trace(cluster, seed=4, mtbf=120.0, mttr=10.0)
    assert a.events == b.events
    c = make_fleet_trace(cluster, seed=5, mtbf=120.0, mttr=10.0)
    assert a.events != c.events
    for ev in a.events:
        assert 0 <= ev.slot < 80
        assert ev.kind in ("fail", "recover", "drain_start", "drain_end")
        assert ev.pool in ("worker", "ps")


def test_churn_trace_fails_exact_fraction_of_each_pool():
    cluster = make_cluster(T=100, H=40, K=40)
    tr = churn_trace(cluster, frac=0.20, seed=1)
    fails = [e for e in tr.events if e.kind == "fail"]
    assert sum(1 for e in fails if e.pool == "worker") == 8
    assert sum(1 for e in fails if e.pool == "ps") == 8
    # one failure per chosen server, inside the mid-run window
    assert len({(e.pool, e.server) for e in fails}) == len(fails)
    assert all(100 // 8 <= e.slot < 100 for e in fails)


def test_fleet_state_caps_and_recovery():
    cluster = make_cluster(T=50, H=4, K=4)
    tr = FleetTrace((FleetEvent(10, "fail", "worker", 1),
                     FleetEvent(20, "recover", "worker", 1)))
    fs = FleetState(cluster, tr)
    assert fs.live_frac == 1.0 and fs.down_servers() == []
    assert fs.step(10) == [("worker", 1, DOWN_LOSSY)]
    assert fs.down_servers() == [("worker", 1)]
    assert np.all(fs.worker_caps[1] == 0.0)
    assert np.array_equal(fs.worker_caps[0], cluster.worker_caps[0])
    assert fs.live_frac < 1.0
    assert fs.step(15) == []                     # no transition between
    assert fs.step(20) == [("worker", 1, UP)]
    assert np.array_equal(fs.worker_caps, cluster.worker_caps)
    assert fs.live_frac == 1.0


def test_drain_windows_are_graceful():
    cluster = make_cluster(T=100, H=10, K=10)
    tr = make_fleet_trace(cluster, seed=0, mtbf=1e9,  # failures off
                          drain_every=30, drain_duration=8, drain_frac=0.2)
    kinds = {e.kind for e in tr.events}
    assert kinds <= {"drain_start", "drain_end"}
    fs = FleetState(cluster, tr)
    first = min(e.slot for e in tr.events)
    trans = fs.step(first)
    assert trans and all(kind == DOWN_GRACEFUL for _, _, kind in trans)


# ---------------------------------------------------------------------------
# zero-churn exactness: empty trace == no fleet argument, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ALL)
@pytest.mark.parametrize("seed", range(5))
def test_zero_churn_bit_identity_episodic(scheduler, seed):
    cluster = make_cluster(T=100, H=50, K=50)
    jobs = make_jobs(200, T=100, seed=seed, small=True)
    a = engine.run(cluster, jobs, scheduler=scheduler, check=False)
    b = engine.run(cluster, jobs, scheduler=scheduler, check=False,
                   fleet=FleetTrace())
    assert a.total_utility == b.total_utility
    assert a.completion == b.completion
    assert a.accepted == b.accepted
    assert a.utilization == b.utilization
    assert b.preempted == 0 and b.preempt_dropped == 0


@pytest.mark.parametrize("scheduler", ALL)
@pytest.mark.parametrize("seed", range(5))
def test_zero_churn_bit_identity_streaming(scheduler, seed):
    cluster = make_cluster(T=32, H=12, K=12)

    def trace():
        return itertools.islice(
            stream_jobs(rate=0.3, seed=seed, small=True), 60)

    a = engine.run_stream(cluster, trace(), scheduler=scheduler, window=32,
                          check=False)
    b = engine.run_stream(cluster, trace(), scheduler=scheduler, window=32,
                          check=False, fleet=FleetTrace())
    assert a.total_utility == b.total_utility
    assert a.completion == b.completion
    assert a.accepted == b.accepted
    assert a.utilization == b.utilization
    assert b.preempted == 0 and b.preempt_dropped == 0


# ---------------------------------------------------------------------------
# churned runs: counters plumb through, no over-commit on survivors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ALL)
def test_churn_run_feasible_on_surviving_fleet(scheduler):
    """check=True validates every repack/commit against the *effective*
    (shrunken) capacities; a plan onto a failed server would assert."""
    cluster = make_cluster(T=60, H=12, K=12)
    jobs = make_jobs(30, T=60, seed=0, small=True)
    tr = churn_trace(cluster, frac=0.25, seed=2)
    r = engine.run(cluster, jobs, scheduler=scheduler, check=True, fleet=tr)
    assert r.preempted >= 0 and r.completed > 0


@pytest.mark.parametrize("scheduler", ("oasis", "fifo", "rrh"))
def test_churn_run_stream_feasible(scheduler):
    cluster = make_cluster(T=32, H=8, K=8)
    tr = churn_trace(cluster, frac=0.25, seed=2, T=120)
    jobs = itertools.islice(stream_jobs(rate=0.4, seed=0, small=True), 40)
    r = engine.run_stream(cluster, jobs, scheduler=scheduler, window=32,
                          check=True, fleet=tr)
    assert r.completed > 0


def test_churn_preempts_under_load():
    """A dense instance where the seeded failures demonstrably hit
    running allocations (the scenario/benchmark configuration)."""
    cluster = make_cluster(T=60, H=12, K=12)
    jobs = make_jobs(30, T=60, seed=0, small=True)
    tr = churn_trace(cluster, frac=0.25, seed=2)
    pre = {s: engine.run(cluster, jobs, scheduler=s, check=True,
                         fleet=tr).preempted for s in ALL}
    assert any(v > 0 for v in pre.values()), pre


def test_checkpoint_rollback_delays_completion():
    """A lossy failure rolls victims back to the last checkpoint
    boundary: with everything else fixed, no completion may move
    earlier, and the failure's victims finish no earlier than before."""
    cluster = make_cluster(T=60, H=12, K=12)
    jobs = make_jobs(30, T=60, seed=0, small=True)
    tr = churn_trace(cluster, frac=0.25, seed=2)
    base = engine.run(cluster, jobs, scheduler="fifo", check=False)
    churned = engine.run(cluster, jobs, scheduler="fifo", check=True,
                         fleet=tr)
    assert churned.preempted > 0
    for jid, t in churned.completion.items():
        if jid in base.completion:
            assert t >= base.completion[jid]


# ---------------------------------------------------------------------------
# cancellation x churn composition
# ---------------------------------------------------------------------------

def _lone_job(T=40):
    # min_duration 8 slots, so the slot-3 failure hits it mid-flight
    return Job(jid=0, arrival=0, epochs=6, num_chunks=4,
               minibatches_per_chunk=10, tau=0.02, grad_size=0.05,
               worker_bw=1.0, ps_bw=4.0,
               worker_res=np.array([1.0, 1.0, 1.0, 1.0, 1.0]),
               ps_res=np.array([0.0, 1.0, 1.0, 1.0, 4.0]),
               utility=SigmoidUtility(50.0, 5.0, 10.0))


def test_cancel_of_dropped_victim_is_noop():
    """All workers fail mid-run, so the preempted job cannot be
    re-admitted (zero worker capacity + sharply decayed shifted
    utility) and is dropped.  Its later cancellation slot must then be
    a no-op — not a second release of an already-released commitment
    (which would corrupt the price state / books)."""
    caps = np.full((2, 5), 8.0)
    cluster = ClusterSpec(T=40, worker_caps=caps.copy(),
                          ps_caps=caps.copy())
    job = _lone_job()
    tr = FleetTrace((FleetEvent(3, "fail", "worker", 0),
                     FleetEvent(3, "fail", "worker", 1),
                     FleetEvent(30, "recover", "worker", 0),
                     FleetEvent(30, "recover", "worker", 1)))
    r = engine.run(cluster, [job], scheduler="oasis", check=True,
                   fleet=tr, cancellations={0: 20})
    assert r.preempted == 1
    assert r.preempt_dropped == 1
    assert r.canceled == 0                       # nothing left to cancel
    assert r.completed == 0
    assert r.total_utility == 0.0


def test_cancel_of_requeued_victim_still_releases():
    """Reactive path: the victim stays enrolled (re-queued, not
    dropped), so a later cancellation is real and must release it."""
    caps = np.full((2, 5), 8.0)
    cluster = ClusterSpec(T=40, worker_caps=caps.copy(),
                          ps_caps=caps.copy())
    job = _lone_job()
    tr = FleetTrace((FleetEvent(3, "fail", "worker", 0),
                     FleetEvent(3, "fail", "worker", 1),
                     FleetEvent(30, "recover", "worker", 0),
                     FleetEvent(30, "recover", "worker", 1)))
    r = engine.run(cluster, [job], scheduler="fifo", check=True,
                   fleet=tr, cancellations={0: 20})
    assert r.preempted == 1
    assert r.canceled == 1
    assert r.completed == 0


# ---------------------------------------------------------------------------
# price-state inversion properties
# ---------------------------------------------------------------------------

def _price_state(T=16, H=3, K=3):
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(6, T=T, seed=0, small=True)
    params = price_params_from_jobs(jobs, cluster)
    return cluster, jobs, PriceState(cluster, params)


def test_block_unblock_roundtrip_is_bit_exact():
    """The engine's failure protocol: victims on the dead server release
    their tails first, THEN the server is blocked; on recovery, unblock
    removes exactly the content it finds (x - x == 0 bitwise), restoring
    the post-release usage arrays exactly."""
    cluster, jobs, state = _price_state()
    from repro.core import best_schedule
    committed = []
    for j in jobs[:3]:
        s = best_schedule(j, state)
        if s is not None:
            state.commit(j, s.workers, s.ps)
            committed.append((j, s))
    # victims: release every schedule that touches worker server 1
    for j, s in committed:
        if any(y[1] > 0 for y in s.workers.values()):
            state.release(j, s.workers, s.ps)
    g0 = state._g_host.copy()
    v0 = state._v_host.copy()
    amt = state.block_server("worker", 1, 0)
    assert amt >= 0.0
    # blocked: the server's headroom is gone on every slot
    assert np.all(state._g_host[:, 1, :] >= cluster.worker_caps[1] - 1e-9)
    state.unblock_server("worker", 1, 0)
    assert np.array_equal(state._g_host, g0)
    assert np.array_equal(state._v_host, v0)
    # and PS pool round-trips the same way
    state.block_server("ps", 2, 0)
    state.unblock_server("ps", 2, 0)
    assert np.array_equal(state._v_host, v0)


def test_commit_release_roundtrip_on_fresh_slots_is_bit_exact():
    """d - d == 0 bitwise: committing then releasing the same placement
    on fresh (all-zero) slots restores exact zeros."""
    cluster, jobs, state = _price_state()
    from repro.core import best_schedule
    g0 = state._g_host.copy()
    v0 = state._v_host.copy()
    j = jobs[0]
    s = best_schedule(j, state)
    assert s is not None
    state.commit(j, s.workers, s.ps)
    assert not np.array_equal(state._g_host, g0)
    state.release(j, s.workers, s.ps)
    assert np.array_equal(state._g_host, g0)
    assert np.array_equal(state._v_host, v0)


# hypothesis-driven inversion/feasibility properties live in
# tests/test_fleet_property.py (skips cleanly when hypothesis is absent,
# matching tests/test_property.py)
