"""rl/ env: exact equivalence against sim.engine.run for every scheduler
(replay policies), reward accounting, action clamping/feasibility, and
the SimResult.summary() contract."""
import numpy as np
import pytest

from repro.rl.env import (OBS_DIM, ClusterSchedulingEnv, ReplayPolicy,
                          engine_action, expert_env_action, observe,
                          paper_instance, run_episode)
from repro.sim import engine, make_cluster, make_jobs

ALL = ["oasis", "fifo", "drf", "rrh", "dorm"]


def _paper_instance(seed):
    # the rl/ subsystem's own instance family, equivalence-suite variant
    return paper_instance(seed, small=True)


def _assert_same(a, b):
    assert a.accepted == b.accepted
    assert a.completed == b.completed
    assert a.completion == b.completion          # completion slots exact
    assert a.total_utility == b.total_utility    # bit-for-bit
    assert a.utilization == b.utilization


@pytest.mark.parametrize("seed", range(5))
def test_env_replays_every_scheduler_exactly(seed):
    """Driving the env with a policy that replays the scheduler's own
    decisions reproduces ``sim.engine.run`` bit-for-bit on the seeded
    paper-scale instances — OASiS and all four reactive baselines."""
    cluster, jobs = _paper_instance(seed)
    for name in ALL:
        kw = dict(quantum=0) if name == "oasis" else {}
        base = engine.run(cluster, jobs, scheduler=name, check=True, **kw)
        env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, jobs),
                                   scheduler=name, check=True, **kw)
        r = run_episode(env, ReplayPolicy())
        _assert_same(base, r)


def test_learned_replaying_fifo_counts_is_fifo():
    """The learned scheduler's expert fallback is FIFO's counts: the
    replay policy through scheduler="learned" equals the FIFO run."""
    cluster, jobs = _paper_instance(1)
    base = engine.run(cluster, jobs, scheduler="fifo", check=True)
    env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, jobs),
                               scheduler="learned", check=True)
    _assert_same(base, run_episode(env, ReplayPolicy()))


def test_engine_policy_kwarg_matches_env_replay():
    """engine.run(policy=...) and the env are the same decision stream."""
    cluster, jobs = _paper_instance(2)
    for name in ("fifo", "drf", "oasis"):
        kw = dict(quantum=0) if name == "oasis" else {}
        via_engine = engine.run(cluster, jobs, scheduler=name, check=True,
                                policy=lambda dp: dp.expert, **kw)
        base = engine.run(cluster, jobs, scheduler=name, check=True, **kw)
        _assert_same(base, via_engine)


def test_rewards_sum_to_total_utility():
    cluster, jobs = _paper_instance(3)
    env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, jobs),
                               scheduler="learned")
    obs, info = env.reset()
    total, done = 0.0, False
    rng = np.random.default_rng(0)
    while not done:
        a = np.array([rng.integers(0, 33), rng.integers(0, 4)])
        obs, rew, done, _, info = env.step(a)
        total += rew
    assert total == pytest.approx(env.result.total_utility, abs=1e-6)
    assert info["summary"]["total_utility"] == pytest.approx(total, abs=1e-6)


def test_random_actions_stay_feasible():
    """check=True makes the engine assert capacity feasibility on every
    repack; arbitrary (including absurd) actions must never trip it."""
    cluster = make_cluster(T=40, H=6, K=6)
    jobs = make_jobs(60, T=40, seed=4, small=False)
    env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, jobs),
                               scheduler="learned", check=True)
    obs, info = env.reset()
    rng = np.random.default_rng(1)
    done = info.get("empty_trace", False)
    while not done:
        a = np.array([rng.integers(0, 500), rng.integers(0, 50)])
        obs, _, done, _, info = env.step(a)
    assert env.result.accepted <= len(jobs)


def test_engine_action_clamps_to_feasibility_envelope():
    cluster, jobs = _paper_instance(0)
    env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, jobs),
                               scheduler="learned")
    env.reset()
    dp = env._dp
    job = dp.job
    assert engine_action(dp, 0) is None
    assert engine_action(dp, (0, 3)) is None
    w, p = engine_action(dp, (10 ** 6, 0))
    assert w == job.num_chunks                  # constraint (3)
    assert p == job.ps_for(w)                   # constraints (6)(7)
    w, p = engine_action(dp, (1, 2))
    assert w == 1 and p == job.ps_for(1) + 2


def test_observation_shape_and_finiteness():
    cluster, jobs = _paper_instance(0)
    for name in ("learned", "oasis"):
        kw = dict(quantum=0) if name == "oasis" else {}
        env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, jobs),
                                   scheduler=name, **kw)
        obs, info = env.reset()
        assert obs.shape == (OBS_DIM,) and obs.dtype == np.float32
        assert np.isfinite(obs).all()
        assert observe(env._dp, cluster) == pytest.approx(obs)
        exp = expert_env_action(env._dp)
        assert exp.shape == (2,) and exp[0] >= 0


def test_empty_trace_episode():
    cluster = make_cluster(T=20, H=4, K=4)
    env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, []),
                               scheduler="learned")
    obs, info = env.reset()
    assert info.get("empty_trace")
    obs, rew, done, _, info = env.step(np.array([3, 0]))
    assert done and rew == 0.0
    assert info["summary"]["n_jobs"] == 0


def test_summary_contract():
    cluster, jobs = _paper_instance(0)
    r = engine.run(cluster, jobs, scheduler="fifo", check=False)
    s = r.summary()
    assert s["accepted"] == r.accepted and s["n_jobs"] == len(jobs)
    assert 0.0 <= s["accept_rate"] <= 1.0
    assert 0.0 <= s["completion_rate"] <= s["accept_rate"]
    lat = [r.completion[j] - r.arrivals[j] for j in r.completion]
    assert s["mean_latency"] == pytest.approx(np.mean(lat))
    assert s["p50_latency"] == pytest.approx(np.percentile(lat, 50))
    assert s["p95_latency"] == pytest.approx(np.percentile(lat, 95))
    # no completions -> latency stats are None, not NaN
    empty = engine.run(cluster, [], scheduler="fifo", check=False)
    assert empty.summary()["mean_latency"] is None


def test_property_no_capacity_violating_admission():
    """Hypothesis: whatever the action stream, every allocation the env's
    step() commits stays within cluster capacity (the engine asserts it
    at every repack under check=True) and admitted counts respect the
    per-job envelope."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    cluster = make_cluster(T=30, H=4, K=4)
    jobs = make_jobs(25, T=30, seed=7, small=False)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.integers(-3, 400), st.integers(0, 9)),
                    min_size=25, max_size=25),
           st.integers(0, 3))
    def inner(actions, slack_extra):
        env = ClusterSchedulingEnv(instance_fn=lambda s: (cluster, jobs),
                                   scheduler="learned", check=True)
        obs, info = env.reset()
        done = info.get("empty_trace", False)
        i = 0
        while not done:
            w, slack = actions[i % len(actions)]
            dp = env._dp
            sent = engine_action(dp, (w, slack + slack_extra))
            if sent is not None:
                nw, nps = sent
                assert 1 <= nw <= dp.job.num_chunks
                assert nps >= dp.job.ps_for(nw)
            obs, _, done, _, info = env.step((w, slack + slack_extra))
            i += 1
        assert env.result.accepted + len(
            [a for a in actions[:i] if a[0] <= 0]) >= 0  # episode completed

    inner()
