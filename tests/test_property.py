"""Hypothesis property tests on the scheduler's invariants.

``hypothesis`` is an optional dev dependency (requirements-dev.txt); this
module skips cleanly at collection when it is absent so ``pytest -x -q``
still runs the rest of the suite.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import best_schedule, price_params_from_jobs
from repro.core.pricing import PriceState
from repro.core.types import ClusterSpec, Job, SigmoidUtility


def build(T, H, K, cap, E, N, M, tau, e, b, B, g1, g2, g3, a):
    cluster = ClusterSpec(T=T, worker_caps=np.full((H, 5), cap),
                          ps_caps=np.full((K, 5), cap))
    job = Job(jid=0, arrival=a, epochs=E, num_chunks=N,
              minibatches_per_chunk=M, tau=tau, grad_size=e, worker_bw=b,
              ps_bw=B, worker_res=np.array([1.0, 1.5, 2.0, 1.0, b]),
              ps_res=np.array([0.0, 1.0, 2.0, 1.0, B]),
              utility=SigmoidUtility(g1, g2, g3))
    return cluster, job


job_strategy = st.tuples(
    st.integers(4, 14),              # T
    st.integers(1, 4),               # H
    st.integers(1, 4),               # K
    st.floats(4.0, 32.0),            # cap
    st.integers(1, 4),               # E
    st.integers(1, 6),               # N
    st.integers(2, 30),              # M
    st.floats(0.001, 0.05),          # tau
    st.floats(0.005, 0.2),           # e
    st.floats(0.5, 4.0),             # b
    st.floats(2.0, 16.0),            # B
    st.floats(1.0, 100.0),           # g1
    st.floats(0.0, 5.0),             # g2
    st.floats(1.0, 12.0),            # g3
    st.integers(0, 3),               # arrival
)


@settings(max_examples=60, deadline=None)
@given(job_strategy)
def test_schedule_feasibility_invariants(args):
    """Any returned schedule satisfies constraints (2)(3)(6)(7) + capacity."""
    cluster, job = build(*args)
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    s = best_schedule(job, state)
    if s is None:
        return
    total_work = 0.0
    for t, y in s.workers.items():
        W = int(y.sum())
        total_work += W
        assert t >= job.arrival                              # (9)
        assert W <= job.num_chunks                           # (3)
        z = s.ps[t]
        Z = int(z.sum())
        assert Z <= W                                        # (7)
        assert Z * job.ps_bw >= W * job.worker_bw - 1e-9     # (6)
        assert np.all(y[:, None] * job.worker_res[None] <=
                      cluster.worker_caps + 1e-9)            # (4)
        assert np.all(z[:, None] * job.ps_res[None] <=
                      cluster.ps_caps + 1e-9)                # (5)
    assert total_work >= job.total_work_slots - 1e-9         # (2)
    assert s.finish == max(s.workers)                        # (8)
    # payoff consistency
    assert s.payoff == pytest.approx(
        job.utility(s.finish - job.arrival) - s.cost, rel=1e-6, abs=1e-9)
    assert s.payoff > 0


@settings(max_examples=30, deadline=None)
@given(job_strategy, st.floats(0.1, 0.9))
def test_payoff_monotone_in_prices(args, frac):
    """Raising allocations (hence prices) never increases the best payoff."""
    cluster, job = build(*args)
    params = price_params_from_jobs([job], cluster)
    s_empty = best_schedule(job, PriceState(cluster, params))
    state = PriceState(cluster, params)
    state.g[:] = cluster.worker_caps[None] * frac
    state.v[:] = cluster.ps_caps[None] * frac
    s_busy = best_schedule(job, state)
    p0 = s_empty.payoff if s_empty else 0.0
    p1 = s_busy.payoff if s_busy else 0.0
    assert p1 <= p0 + 1e-9


@settings(max_examples=30, deadline=None)
@given(job_strategy)
def test_utility_nonincreasing(args):
    _, job = build(*args)
    vals = [job.utility(d) for d in range(0, 20)]
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-12
    assert all(v >= 0 for v in vals)


@settings(max_examples=20, deadline=None)
@given(job_strategy, st.integers(2, 8))
def test_quantum_never_beats_exact(args, q):
    """Coarse DP over-provisions => its payoff cannot exceed the exact DP."""
    import dataclasses
    cluster, job = build(*args)
    params = price_params_from_jobs([job], cluster)
    state = PriceState(cluster, params)
    exact = best_schedule(job, state)
    coarse = best_schedule(dataclasses.replace(job, quantum=q), state)
    pe = exact.payoff if exact else 0.0
    pc = coarse.payoff if coarse else 0.0
    assert pc <= pe + 1e-6
