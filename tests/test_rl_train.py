"""rl/ policy network and training loop: shapes, determinism, checkpoint
round-trip through ckpt/checkpoint.py, the level action parametrization,
and a 2-iteration REINFORCE smoke (requires optax; policy inference does
not)."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.rl import policy as pol  # noqa: E402
from repro.rl.env import OBS_DIM  # noqa: E402
from repro.sim import engine, make_cluster, make_jobs  # noqa: E402


@pytest.fixture(scope="module")
def cfg_params():
    cfg = pol.PolicyConfig(d_model=32)
    params = pol.policy_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_policy_shapes_and_determinism(cfg_params):
    cfg, params = cfg_params
    obs = jnp.asarray(np.random.default_rng(0).random(OBS_DIM),
                      jnp.float32)
    lw, ls = pol.policy_logits(params, obs, cfg)
    assert lw.shape == (cfg.n_worker_actions,)
    assert ls.shape == (cfg.ps_slack_levels,)
    assert bool(jnp.isfinite(lw).all()) and bool(jnp.isfinite(ls).all())
    a1, lp1 = pol.sample_action(params, obs, jax.random.PRNGKey(3), cfg)
    a2, lp2 = pol.sample_action(params, obs, jax.random.PRNGKey(3), cfg)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert float(lp1) == float(lp2)
    assert float(lp1) <= 0.0
    g = pol.greedy_action(params, obs, cfg)
    assert 0 <= int(g[0]) < cfg.n_worker_actions
    logp, ent = pol.action_log_prob(params, obs, a1, cfg)
    assert float(logp) == pytest.approx(float(lp1), abs=1e-5)
    assert float(ent) >= 0.0


def test_level_to_workers_mapping():
    cfg = pol.PolicyConfig()
    assert cfg.worker_levels[cfg.expert_level] == 1.0
    assert cfg.level_to_workers(0, 8) == 0            # reject level
    assert cfg.level_to_workers(cfg.expert_level, 8) == 8
    hi = len(cfg.worker_levels) - 1
    assert cfg.level_to_workers(hi, 8) == int(cfg.worker_levels[hi] * 8)
    assert cfg.level_to_workers(hi, 1000) == cfg.max_workers   # capped
    assert cfg.level_to_workers(1, 1) == 1            # never rounds to 0
    assert cfg.level_to_workers(2, 0) == 0            # expert rejected


def test_checkpoint_round_trip(tmp_path, cfg_params):
    cfg, params = cfg_params
    pol.save_policy(str(tmp_path), params, cfg, step=7,
                    extra={"note": "test"})
    re_params, re_cfg, extra = pol.load_policy(str(tmp_path))
    assert re_cfg == cfg
    assert extra["note"] == "test"
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(re_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(FileNotFoundError):
        pol.load_policy(str(tmp_path / "nope"))


def test_learned_decider_drives_engine(cfg_params):
    cfg, params = cfg_params
    cluster = make_cluster(T=30, H=6, K=6)
    jobs = make_jobs(20, T=30, seed=0, small=False)
    dec = pol.LearnedDecider(params, cfg, cluster, greedy=True)
    r = engine.run(cluster, jobs, scheduler="learned", check=True,
                   policy=dec)
    assert r.accepted <= len(jobs)
    assert len(r.decision_seconds) > 0           # policy latency recorded
    # deterministic: greedy decider reruns to the identical result
    dec2 = pol.LearnedDecider(params, cfg, cluster, greedy=True)
    r2 = engine.run(cluster, jobs, scheduler="learned", check=True,
                    policy=dec2)
    assert r.completion == r2.completion
    assert r.total_utility == r2.total_utility


def test_learned_without_policy_raises():
    cluster = make_cluster(T=10, H=2, K=2)
    with pytest.raises(ValueError, match="policy"):
        engine.run(cluster, [], scheduler="learned")


def test_train_two_iterations_smoke():
    pytest.importorskip("optax")
    from repro.rl.train import TrainConfig, evaluate, train

    cfg = TrainConfig(iterations=2, batch=3, T=32, H=8, K=8, n_jobs=24,
                      train_seeds=(100, 101), val_every=0,
                      bc_episodes=2, bc_steps=5)
    pcfg = pol.PolicyConfig(d_model=32, max_workers=16)
    params, history = train(cfg, pcfg, log=None)
    assert len(history) == 2
    assert all(np.isfinite(h["loss"]) for h in history)
    assert all(np.isfinite(h["mean_utility"]) for h in history)
    ev = evaluate(params, pcfg, seeds=(9,), cfg=cfg,
                  schedulers=("learned", "fifo"))
    assert set(ev) == {"learned", "fifo"}
    for stats in ev.values():
        assert np.isfinite(stats["mean_utility"])


def test_expert_level_threshold():
    pytest.importorskip("optax")
    from repro.rl.env import F_BEST_UTILITY
    from repro.rl.train import TrainConfig, _expert_level

    cfg = TrainConfig(admit_threshold=10.0)
    pcfg = pol.PolicyConfig()
    obs = np.zeros(OBS_DIM, np.float32)
    obs[F_BEST_UTILITY] = 0.02                   # utility 2 < 10: reject
    assert _expert_level(obs, 8, pcfg, cfg) == 0
    obs[F_BEST_UTILITY] = 0.5                    # utility 50: admit at x1
    assert _expert_level(obs, 8, pcfg, cfg) == pcfg.expert_level
    assert _expert_level(obs, 0, pcfg, cfg) == 0  # expert already rejects
