"""Per-architecture smoke tests (reduced configs, CPU) + structural
param-count checks against published sizes + decode==train consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import decode_step, forward_train, init_cache, init_model
from repro.models.layers import is_spec
from repro.models.model import encdec_prepare, model_specs

KEY = jax.random.PRNGKey(0)

NOMINAL = {"whisper-large-v3": 1.5e9, "olmoe-1b-7b": 6.9e9,
           "deepseek-v3-671b": 671e9, "granite-34b": 34e9,
           "gemma2-27b": 27e9, "starcoder2-3b": 3e9, "gemma2-9b": 9e9,
           "mamba2-370m": 370e6, "pixtral-12b": 12e9, "zamba2-7b": 7e9}


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    cfg.validate()
    specs, _ = jax.tree_util.tree_flatten(model_specs(cfg), is_leaf=is_spec)
    n = sum(int(np.prod(s.shape)) for s in specs)
    assert abs(n / NOMINAL[cfg.name] - 1.0) < 0.12, \
        f"{cfg.name}: {n/1e9:.2f}B vs nominal {NOMINAL[cfg.name]/1e9:.2f}B"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_one_train_step(arch):
    """Reduced config: forward + one SGD step on CPU, shapes + finite."""
    cfg = get_smoke(arch)
    cfg.validate()
    params = init_model(KEY, cfg)
    batch = _batch(cfg, KEY)
    batch["labels"] = batch["tokens"]

    from repro.train.steps import TrainHyper, loss_fn
    def loss_only(p):
        l, m = loss_fn(p, cfg, batch, TrainHyper())
        return l
    loss, grads = jax.value_and_grad(loss_only)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0
    logits, aux = forward_train(params, cfg, batch)
    B, S = batch["tokens"].shape
    from repro.models.layers import padded_vocab
    assert logits.shape == (B, S, padded_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["granite_34b", "gemma2_9b", "mamba2_370m",
                                  "deepseek_v3_671b", "zamba2_7b",
                                  "whisper_large_v3"])
def test_decode_matches_train_forward(arch):
    """Step-by-step decode reproduces the training forward logits."""
    cfg = get_smoke(arch).scaled(dtype="float32", param_dtype="float32")
    if cfg.n_experts:
        cfg = cfg.scaled(capacity_factor=float(cfg.n_experts))  # no drops
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    extras = {}
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        batch["frames"] = frames
        enc, cross = encdec_prepare(params, cfg, frames)
        extras["enc"] = enc
        cache["decoder"]["cross"] = cross
    ref, _ = forward_train(params, cfg, batch)
    step = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l, extras))
    outs = []
    for i in range(S):
        lg, cache = step(params, toks[:, i:i + 1], cache, jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - ref))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-2, rel


def test_rolling_window_cache_matches_full():
    """Gemma-style local layer: rolling cache == full-cache attention."""
    cfg = get_smoke("gemma2_9b").scaled(dtype="float32", param_dtype="float32",
                                        sliding_window=8)
    params = init_model(KEY, cfg)
    B, S = 1, 24
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    ref, _ = forward_train(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, B, S, dtype=jnp.float32)   # local cache size = 8
    step = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l, None))
    outs = []
    for i in range(S):
        lg, cache = step(params, toks[:, i:i + 1], cache, jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - ref))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-2, rel
