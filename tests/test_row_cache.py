"""Row-cache invalidation properties.

The incremental COST-row path has two halves that must agree with the
from-scratch oracle after ANY interleaving of price-state mutations:

* host: ``PriceState.dirty_spans_since`` + ``cost_t_rows(..., slots=...)``
  must reconstruct exactly ``cost_t_rows`` recomputed from scratch;
* device: ``RowCache.sync`` + ``best_schedule_fused(row_cache=...)`` must
  make bit-identical decisions to the cache-free fused engine.

A seeded randomized sweep always runs; the hypothesis variant (optional
dev dependency, requirements-dev.txt) explores adversarial interleavings
when available and skips cleanly otherwise.
"""
import numpy as np
import pytest

from repro.core import price_params_from_jobs
from repro.core.pricing import PriceState
from repro.core.subroutine import cost_t_rows
from repro.sim import make_cluster, make_jobs


def _rand_alloc(rng, T, S, max_count=2):
    """A random slot->counts allocation dict over a contiguous range."""
    t0 = int(rng.integers(0, T))
    t1 = int(rng.integers(t0, min(t0 + 6, T)))
    return {t: rng.integers(0, max_count + 1, size=S).astype(np.int64)
            for t in range(t0, t1 + 1)}


def _apply_random_ops(rng, state, jobs, committed, n_ops, allow_advance):
    """Mutate ``state`` with a random commit/release/advance sequence."""
    T = state.horizon
    H, K = state.cluster.H, state.cluster.K
    for _ in range(n_ops):
        op = rng.integers(0, 3 if allow_advance else 2)
        if op == 0:                                # commit
            job = jobs[int(rng.integers(0, len(jobs)))]
            w = _rand_alloc(rng, T, H)
            z = _rand_alloc(rng, T, K, max_count=1)
            state.commit(job, w, z)
            committed.append((job, w, z))
        elif op == 1 and committed:                # release an earlier commit
            job, w, z = committed.pop(int(rng.integers(0, len(committed))))
            state.release(job, w, z)
        elif op == 2:                              # slide the window
            state.advance(state.origin + int(rng.integers(1, 4)))
            committed.clear()                      # slots re-indexed


def _host_roundtrip(seed: int, window, n_rounds: int = 6, n_ops: int = 3):
    """Cached-incremental host rows == from-scratch rows after every round."""
    T = 24
    cluster = make_cluster(T=T, H=3, K=3)
    jobs = make_jobs(6, T=T, seed=seed, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params, window=window)
    job = jobs[0]
    dcap = min(job.max_chunks_per_slot, job.workload)
    if dcap == 0:
        pytest.skip("degenerate job")
    rng = np.random.default_rng(seed)

    def scratch():
        return cost_t_rows(job, state, state.worker_prices(),
                           state.ps_prices(), dcap)

    cached = scratch()
    version = state.version
    committed = []
    for _ in range(n_rounds):
        _apply_random_ops(rng, state, jobs, committed, n_ops,
                          allow_advance=window is not None)
        spans = state.dirty_spans_since(version)
        p, q = state.worker_prices(), state.ps_prices()
        if spans is None:                          # unknowable: full rebuild
            cached = cost_t_rows(job, state, p, q, dcap)
        elif spans:
            slots = np.unique(np.concatenate(
                [np.arange(t0, t1) for t0, t1 in spans]))
            slots = slots[slots < state.horizon]
            cached[slots] = cost_t_rows(job, state, p, q, dcap, slots=slots)
        version = state.version
        want = scratch()
        assert np.array_equal(cached, want), (seed, window)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("window", [None, 16])
def test_host_row_cache_randomized(seed, window):
    _host_roundtrip(seed, window)


@pytest.mark.parametrize("seed", range(3))
def test_device_row_cache_randomized(seed):
    """Cache-served fused decisions == cache-free fused decisions, bit for
    bit, across interleaved commits/releases/advances."""
    from repro.core.schedule_jax import RowCache, best_schedule_fused
    T = 24
    cluster = make_cluster(T=T, H=3, K=3)
    jobs = make_jobs(6, T=T, seed=100 + seed, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    job = jobs[0]
    cache = RowCache.empty(state, job)
    if cache is None:
        pytest.skip("degenerate job")
    rng = np.random.default_rng(seed)
    committed = []
    for rounds in range(5):
        cache.sync(state)
        got = best_schedule_fused(job, state, row_cache=cache)
        want = best_schedule_fused(job, state)
        assert (got is None) == (want is None), seed
        if want is not None:
            assert got.finish == want.finish
            assert got.cost == want.cost           # bit-identical
            assert got.payoff == want.payoff
            for t in want.workers:
                assert np.array_equal(got.workers[t], want.workers[t])
                assert np.array_equal(got.ps[t], want.ps[t])
        _apply_random_ops(rng, state, jobs, committed, n_ops=3,
                          allow_advance=rounds == 3)


def test_dirty_span_log_semantics():
    """dirty_spans_since: exact spans for commits, None past the floor."""
    cluster = make_cluster(T=16, H=2, K=2)
    jobs = make_jobs(3, T=16, seed=0, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    v0 = state.version
    assert state.dirty_spans_since(v0) == []
    w = {4: np.array([1, 0], np.int64), 6: np.array([0, 1], np.int64)}
    z = {5: np.array([1, 0], np.int64)}
    state.commit(jobs[0], w, z)
    spans = state.dirty_spans_since(v0)
    assert spans is not None and len(spans) == 2
    covered = set()
    for t0, t1 in spans:
        covered.update(range(t0, t1))
    assert {4, 5, 6} <= covered                    # every touched slot dirty
    assert state.dirty_spans_since(state.version) == []
    # advance re-indexes slots: older versions become unknowable
    state.advance(2)
    assert state.dirty_spans_since(v0) is None
    assert state.dirty_spans_since(state.version) == []
    # mutable g/v access invalidates even current-version caches
    v1 = state.version
    _ = state.g
    assert state.dirty_spans_since(v1) is None


def test_row_cache_reuses_valid_tiles():
    """After sync, only tiles overlapping the dirty spans are invalid."""
    from repro.core.schedule_jax import TILE, RowCache, best_schedule_fused
    T = 2 * TILE + 2                               # multi-tile horizon
    cluster = make_cluster(T=T, H=3, K=3)
    jobs = make_jobs(6, T=T, seed=2, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    job = jobs[0]
    cache = RowCache.empty(state, job)
    assert cache is not None and len(cache.valid) >= 3
    assert not cache.valid.any()
    best_schedule_fused(job, state, row_cache=cache)
    assert cache.valid.any()                       # visited tiles recorded
    valid_before = cache.valid.copy()
    # a commit inside tile 0 dirties only tile 0
    state.commit(jobs[1], {1: np.array([1, 0, 0], np.int64)}, {})
    cache.sync(state)
    assert not cache.valid[0]
    assert np.array_equal(cache.valid[1:], valid_before[1:])


def _table_roundtrip(seed: int, n_rounds: int = 4, n_ops: int = 3,
                     drop_residency: bool = False):
    """Order-cache property: the sorted-order/cumsum tables the engine
    leaves in ``RowCache.tables`` — span-patched via ``_sorted_fill`` on
    re-solves, or served stale-free from a fresh build — must equal a
    from-scratch ``_sorted_fill_lanes`` re-sort at the current state
    version, bit for bit, after ANY interleaving of commit/release/
    advance.  ``drop_residency=True`` touches the host-mutable ``state.g``
    between rounds so ``patch_spans`` turns unknowable and the rebuild
    (rather than patch) path is the one under test."""
    import jax
    import jax.numpy as jnp
    from repro.core import schedule_jax as S

    T = 24
    cluster = make_cluster(T=T, H=3, K=3)
    jobs = make_jobs(6, T=T, seed=seed % 997, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    job = jobs[0]
    cache = S.RowCache.empty(state, job)
    if cache is None:
        pytest.skip("degenerate job")
    rng = np.random.default_rng(seed)
    committed = []
    with S._x64_context("auto"):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        for rounds in range(n_rounds):
            cache.sync(state)
            S.best_schedule_fused(job, state, row_cache=cache)
            if cache.tables is None:
                pytest.skip("order-cache footprint gate off at this shape")
            assert cache.tables_version == state.version, (seed, rounds)
            got = tuple(np.asarray(t) for t in S._tabs_get(cache.tables))
            # from-scratch reference at the CURRENT state: one fused
            # full-table build over this job's lane
            T_now = state.horizon
            T_pad = S._pad_tiles(T_now)
            m_pad, _ = S._shape_bucket(job)
            psd = S._padded_state(state, dtype, T_pad)
            la, _ = S._job_arrays_tiled(job, state, T_now, T_pad, m_pad,
                                        dtype)
            resbw = jnp.asarray(la[0], dtype)
            full = S._sorted_fill_lanes(psd[9], psd[10], psd[0], psd[1],
                                        psd[2], psd[3], resbw[None])
            want = tuple(np.asarray(t[0]) for t in full)
            for k, (g_t, w_t) in enumerate(zip(got, want)):
                assert np.array_equal(g_t, w_t), (seed, rounds, k)
            _apply_random_ops(rng, state, jobs, committed, n_ops,
                              allow_advance=rounds == n_rounds - 2)
            if drop_residency:
                _ = state.g      # host access: spans unknowable -> rebuild


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("drop_residency", [False, True])
def test_order_cache_tables_randomized(seed, drop_residency):
    """Patched sorted-order/cumsum tables == full re-sorts (both the
    device span-patch path and the residency-drop rebuild path)."""
    _table_roundtrip(200 + seed, drop_residency=drop_residency)


def test_order_cache_gate_off_keeps_inline_path(monkeypatch):
    """Above the REPRO_ORDER_CACHE_MAX footprint the engine must not
    build tables at all (the decide loop keeps the inline per-tile
    argsorts) — and decisions stay bit-identical either way."""
    from repro.core.schedule_jax import RowCache, best_schedule_fused
    T = 24
    cluster = make_cluster(T=T, H=3, K=3)
    jobs = make_jobs(6, T=T, seed=7, small=True)
    params = price_params_from_jobs(jobs, cluster)
    state = PriceState(cluster, params)
    job = jobs[0]
    cache = RowCache.empty(state, job)
    if cache is None:
        pytest.skip("degenerate job")
    want = best_schedule_fused(job, state)
    monkeypatch.setenv("REPRO_ORDER_CACHE_MAX", "1")
    got = best_schedule_fused(job, state, row_cache=cache)
    assert cache.tables is None and cache.tables_version == -1
    assert (got is None) == (want is None)
    if want is not None:
        assert got.cost == want.cost and got.finish == want.finish


# -- hypothesis variant ------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           window=st.sampled_from([None, 12, 16]),
           n_rounds=st.integers(1, 8),
           n_ops=st.integers(1, 5))
    def test_host_row_cache_hypothesis(seed, window, n_rounds, n_ops):
        _host_roundtrip(seed, window, n_rounds=n_rounds, n_ops=n_ops)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           n_rounds=st.integers(1, 5),
           n_ops=st.integers(1, 4),
           drop_residency=st.booleans())
    def test_order_cache_tables_hypothesis(seed, n_rounds, n_ops,
                                           drop_residency):
        _table_roundtrip(seed, n_rounds=n_rounds, n_ops=n_ops,
                         drop_residency=drop_residency)
