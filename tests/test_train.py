"""Training-loop numerics: loss decreases, optimizer behaves, elastic
re-meshing preserves training, serve path produces sane samples."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, DataPipeline
from repro.models import init_model
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig, apply_updates, init_opt, schedule
from repro.train.steps import TrainHyper, cross_entropy, loss_fn

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_size=256, dtype="float32", param_dtype="float32",
                   remat=False)


def test_cross_entropy_matches_naive():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 8, 40))
    labels = jax.random.randint(key, (2, 8), 0, 32)
    got = cross_entropy(logits, labels, vocab=32, z_coef=0.0)
    lp = jax.nn.log_softmax(
        jnp.where(jnp.arange(40)[None, None] >= 32, -1e30, logits), -1)
    want = -jnp.take_along_axis(lp, labels[..., None], -1).mean()
    assert float(got) == pytest.approx(float(want), rel=1e-3)


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(
        1e-4, rel=1e-3)


def test_loss_decreases_tiny_model():
    cfg = TINY
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=0, n_chunks=64)
    pipeline = DataPipeline(data)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                        weight_decay=0.0)
    opt = init_opt(params, opt_cfg)
    hyper = TrainHyper()

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, hyper)
        params, opt, om = apply_updates(params, grads, opt, opt_cfg)
        return params, opt, metrics["ce"]

    losses = []
    for _ in range(40):
        b = pipeline.next_batch()
        params, opt, ce = step(params, opt,
                               {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(ce))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.8, (first, last)


def test_grad_compress_training_still_converges():
    cfg = TINY
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=0, n_chunks=64)
    pipeline = DataPipeline(data)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                        weight_decay=0.0)
    opt = init_opt(params, opt_cfg)
    hyper = TrainHyper(grad_compress=True)
    from repro.train.compress import compress_grads

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, hyper)
        grads = compress_grads(grads)
        params, opt, om = apply_updates(params, grads, opt, opt_cfg)
        return params, opt, metrics["ce"]

    losses = []
    for _ in range(40):
        b = pipeline.next_batch()
        params, opt, ce = step(params, opt,
                               {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(ce))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.85


def test_elastic_trainer_changes_width(tmp_path):
    """ElasticTrainer follows a worker-count plan and keeps improving."""
    import numpy as np
    from repro.runtime.elastic import ElasticTrainer, SlotPlan

    cfg = TINY
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=0, n_chunks=64)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                        weight_decay=0.0)

    def make_step(mesh):
        from repro.train.steps import make_train_step
        fn, in_sh, out_sh = make_train_step(cfg, mesh, opt_cfg)
        jfn = jax.jit(fn)
        def wrapped(params, opt, batch):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            return jfn(params, opt, batch)
        return wrapped, in_sh[0], in_sh[1]

    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = None
    from repro.train.optimizer import init_opt as _io
    opt = _io(params, opt_cfg)
    trainer = ElasticTrainer(cfg, opt_cfg, data, str(tmp_path), make_step,
                             steps_per_slot=10)
    plan = [SlotPlan(0, 4), SlotPlan(1, 8), SlotPlan(2, 2)]
    out = trainer.run(plan, params, opt)
    assert out["steps"] == 30
    ces = [m["ce"] for m in trainer.metrics_log]
    assert np.mean(ces[-5:]) < np.mean(ces[:5])
    assert len(trainer.mesh_history) == 3


def test_grad_accum_matches_single_step():
    """Microbatched gradient accumulation == single-shot step (bitwise-
    tight in fp32): same params, same metrics, any k dividing the batch."""
    import jax
    from repro.train.steps import make_train_step
    cfg = TINY
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt_cfg = OptConfig(lr=1e-3)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt(params, opt_cfg)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
    ref = None
    for k in (1, 2, 4):
        fn, _, _ = make_train_step(cfg, mesh, opt_cfg, TrainHyper(grad_accum=k))
        p2, _, m = jax.jit(fn)(params, opt, batch)
        leaf = jax.tree_util.tree_leaves(p2)[0]
        if ref is None:
            ref = (leaf, float(m["ce"]))
        else:
            assert float(m["ce"]) == pytest.approx(ref[1], rel=1e-6)
            assert float(jnp.max(jnp.abs(leaf - ref[0]))) < 1e-5
