"""Markdown link check over the repo's docs surface.

Every relative link and intra-document anchor in README.md, ROADMAP.md,
and docs/ must resolve: a renamed file or a reworded heading breaks the
docs silently otherwise.  External (http/mailto) links are not fetched —
this is a structural check, not a crawler.  Runs in tier-1 and as the
lint job's ``docs link check`` step.
"""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(
    [REPO / "README.md", REPO / "ROADMAP.md"] + list((REPO / "docs").glob("*.md")))

# inline markdown links [text](target); images share the syntax
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _anchor_slug(heading: str) -> str:
    """GitHub's heading -> #fragment rule: lowercase, drop punctuation
    (keeping word chars and hyphens), spaces to hyphens."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def _anchors(md: Path) -> set:
    return {_anchor_slug(h) for h in _HEADING.findall(md.read_text())}


def _links(md: Path):
    text = _CODE_FENCE.sub("", md.read_text())
    return _LINK.findall(text)


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_markdown_links_resolve(md):
    assert md.exists(), f"doc file vanished: {md}"
    problems = []
    for target in _links(md):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if path_part and REPO not in dest.parents and dest != REPO:
            # GitHub-relative URL escaping the checkout (e.g. the
            # ../../actions/... CI badge) — not a repo file; skip
            continue
        if not dest.exists():
            problems.append(f"{target}: file not found ({dest})")
            continue
        if fragment and dest.suffix == ".md" and \
                fragment not in _anchors(dest):
            problems.append(f"{target}: no heading anchors to "
                            f"#{fragment} in {dest.name}")
    assert not problems, (
        f"{md.relative_to(REPO)} has dead links:\n  " + "\n  ".join(problems))


def test_docs_are_linked_from_readme():
    """Every file in docs/ must be reachable from the README (the docs
    layer's entry point)."""
    readme = (REPO / "README.md").read_text()
    missing = [p.name for p in (REPO / "docs").glob("*.md")
               if f"docs/{p.name}" not in readme]
    assert not missing, f"docs/ files not linked from README.md: {missing}"
