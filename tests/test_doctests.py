"""Tier-1 wiring for the documented API examples.

Runs the doctest snippets of the five public entry points (the
``pytest --doctest-modules`` subset the docs promise stays runnable:
README / docs/PAPER_MAP.md link into these docstrings).  Kept as an
explicit module list so the plain ``pytest -x -q`` tier-1 invocation
collects them without changing global collection flags — and so a
docstring edit that silently drops every example fails loudly
(``attempted > 0``) instead of passing vacuously.
"""
import doctest
import importlib

import pytest

DOCUMENTED_MODULES = (
    "repro.core.oasis",
    "repro.core.pricing",
    "repro.sim.engine",
    "repro.sim.scenarios",
    "benchmarks.run",
)


@pytest.mark.parametrize("name", DOCUMENTED_MODULES)
def test_module_doctests(name):
    mod = importlib.import_module(name)
    result = doctest.testmod(
        mod, optionflags=doctest.NORMALIZE_WHITESPACE, verbose=False)
    assert result.attempted > 0, f"no doctest examples collected in {name}"
    assert result.failed == 0, (
        f"{result.failed}/{result.attempted} doctest example(s) failed "
        f"in {name} (run python -m doctest -v on the module for detail)")
