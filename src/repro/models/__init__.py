from .config import ModelConfig, smoke_variant
from .model import (decode_step, forward_train, init_cache, init_model,
                    model_axes, model_specs, prefill)
from .layers import param_count
