"""Unified model configuration covering all assigned architecture families:
dense / MoE / MLA-MoE / SSM (Mamba2 SSD) / hybrid (Zamba2) / enc-dec
(Whisper) / VLM backbone (Pixtral).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000

    # ---- attention flavour ----
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = global only
    local_global: bool = False     # gemma2 alternating local/global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    post_norms: bool = False       # gemma2 post-attn/post-mlp norms
    qk_norm: bool = False

    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0        # leading dense FFN layers (deepseek: 3)
    capacity_factor: float = 1.25
    router_type: str = "softmax"   # softmax | sigmoid (deepseek-v3)
    router_aux_coef: float = 0.01

    # ---- MLA (deepseek-v3) ----
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ---- multi-token prediction (deepseek-v3) ----
    mtp_depth: int = 0

    # ---- SSM (mamba2 SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_groups: int = 1

    # ---- hybrid (zamba2) ----
    hybrid_period: int = 0         # shared attention block every k SSM layers

    # ---- enc-dec (whisper) ----
    n_encoder_layers: int = 0
    encoder_seq: int = 0           # audio frames after the (stubbed) conv frontend

    # ---- VLM (pixtral) ----
    n_patches: int = 0             # stubbed image patch embeddings per sample

    # ---- numerics / execution ----
    gated_mlp: bool = True         # SwiGLU-style; False = fc1/act/fc2
    act: str = "silu"              # silu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    unroll: bool = False           # python-loop layers instead of lax.scan
                                   # (probe compiles: XLA cost analysis
                                   # counts a scan body once; unrolled
                                   # graphs count every layer)
    attn_impl: str = "chunked"     # chunked | naive | pallas
    attn_chunk: int = 1024

    # ------------------------------------------------------------------
    @property
    def qk_head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, idx: int) -> bool:
        return self.n_experts > 0 and idx >= self.n_dense_layers

    def validate(self) -> None:
        if self.family in ("dense", "moe", "encdec"):
            assert self.n_heads > 0 and self.head_dim > 0 or self.use_mla
            if not self.use_mla:
                assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.local_global:
            assert self.n_layers % 2 == 0 and self.sliding_window > 0

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64,
        vocab_size=256,
        d_ff=128 if cfg.d_ff else 0,
        rope_theta=cfg.rope_theta,
        name=cfg.name + "-smoke",
    )
    if cfg.use_mla:
        kw.update(n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    elif cfg.n_heads:
        kv = max(1, min(cfg.n_kv_heads, 2))
        kw.update(n_heads=4, n_kv_heads=kv if 4 % kv == 0 else 1, head_dim=16)
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=2, moe_d_ff=64,
                  n_dense_layers=min(cfg.n_dense_layers, 1),
                  n_shared_experts=cfg.n_shared_experts)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.local_global:
        kw.update(sliding_window=32)
    if cfg.hybrid_period:
        kw.update(hybrid_period=2, n_layers=5, n_heads=4, n_kv_heads=2,
                  head_dim=16)
    if cfg.n_encoder_layers:
        kw.update(n_encoder_layers=2, encoder_seq=16)
    if cfg.n_patches:
        kw.update(n_patches=4)
    if cfg.mtp_depth:
        kw.update(mtp_depth=1)
    return cfg.scaled(**kw)
