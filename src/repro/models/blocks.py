"""Per-family layer blocks composed from attention/MLA/MoE/Mamba2 pieces.

Each block is (specs_fn, body_fn).  ``body_fn(p, cfg, h, ctx, cache)``
returns ``(h, new_cache, aux)``.  ``ctx`` carries positions, mode,
cache_len, encoder states; blocks are scanned over stacked layer params
by ``model.py``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, attn_specs
from .layers import P, activation, apply_norm, norm_spec
from .mamba2 import mamba_block, mamba_specs
from .mla import mla_attention, mla_specs
from .moe import moe_block, moe_specs

Aux = jax.Array


def mlp_specs(cfg, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "wi": P((d, f), ("embed", "mlp")),
        "wo": P((f, d), ("mlp", "embed")),
    }
    if cfg.gated_mlp:
        s["wg"] = P((d, f), ("embed", "mlp"))
    return s


def mlp(params: Dict, cfg, x: jax.Array) -> jax.Array:
    dt = x.dtype
    act = activation(cfg.act)
    if "wg" in params:
        h = act(x @ params["wg"].astype(dt)) * (x @ params["wi"].astype(dt))
    else:
        h = act(x @ params["wi"].astype(dt))
    return h @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# dense decoder layer (granite / starcoder2 / pixtral / gemma2-sublayer)
# ---------------------------------------------------------------------------

def dense_layer_specs(cfg) -> Dict:
    s = {
        "ln_attn": norm_spec(cfg),
        "attn": attn_specs(cfg),
        "ln_mlp": norm_spec(cfg),
        "mlp": mlp_specs(cfg),
    }
    if cfg.post_norms:
        s["ln_attn_post"] = norm_spec(cfg)
        s["ln_mlp_post"] = norm_spec(cfg)
    return s


def _con_cache(ctx: Dict, new_cache):
    fn = ctx.get("constrain_cache")
    if fn is None or new_cache is None:
        return new_cache
    return jax.tree_util.tree_map(fn, new_cache)


def dense_layer(p: Dict, cfg, h: jax.Array, ctx: Dict, cache: Optional[Dict],
                window: int = 0) -> Tuple[jax.Array, Optional[Dict], Aux]:
    a_in = apply_norm(p["ln_attn"], h, cfg)
    a_out, new_cache = attention(
        p["attn"], cfg, a_in, ctx["positions"], window=window,
        causal=ctx.get("causal", True), cache=cache,
        cache_len=ctx.get("cache_len"), return_cache=ctx.get("return_cache", False),
        use_rope=ctx.get("use_rope", True),
        constrain_qkv=ctx.get("constrain_qkv"))
    new_cache = _con_cache(ctx, new_cache)
    if cfg.post_norms:
        a_out = apply_norm(p["ln_attn_post"], a_out, cfg)
    h = h + a_out
    m_in = apply_norm(p["ln_mlp"], h, cfg)
    m_out = mlp(p["mlp"], cfg, m_in)
    if cfg.post_norms:
        m_out = apply_norm(p["ln_mlp_post"], m_out, cfg)
    return h + m_out, new_cache, jnp.zeros((), jnp.float32)


def gemma_pair_specs(cfg) -> Dict:
    return {"local": dense_layer_specs(cfg), "global": dense_layer_specs(cfg)}


def gemma_pair(p: Dict, cfg, h: jax.Array, ctx: Dict, cache: Optional[Dict]
               ) -> Tuple[jax.Array, Optional[Dict], Aux]:
    c_l = cache.get("local") if cache else None
    c_g = cache.get("global") if cache else None
    h, nc_l, _ = dense_layer(p["local"], cfg, h, ctx, c_l, window=cfg.sliding_window)
    h, nc_g, _ = dense_layer(p["global"], cfg, h, ctx, c_g, window=0)
    new_cache = None
    if nc_l is not None or nc_g is not None:
        new_cache = {"local": nc_l, "global": nc_g}
    return h, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# MoE decoder layer (olmoe) and MLA+MoE layer (deepseek-v3)
# ---------------------------------------------------------------------------

def moe_layer_specs(cfg) -> Dict:
    return {
        "ln_attn": norm_spec(cfg),
        "attn": attn_specs(cfg),
        "ln_mlp": norm_spec(cfg),
        "moe": moe_specs(cfg),
    }


def moe_layer(p: Dict, cfg, h: jax.Array, ctx: Dict, cache: Optional[Dict]
              ) -> Tuple[jax.Array, Optional[Dict], Aux]:
    a_in = apply_norm(p["ln_attn"], h, cfg)
    a_out, new_cache = attention(
        p["attn"], cfg, a_in, ctx["positions"], cache=cache,
        cache_len=ctx.get("cache_len"), return_cache=ctx.get("return_cache", False),
        constrain_qkv=ctx.get("constrain_qkv"))
    new_cache = _con_cache(ctx, new_cache)
    h = h + a_out
    m_in = apply_norm(p["ln_mlp"], h, cfg)
    m_out, aux = moe_block(p["moe"], cfg, m_in)
    return h + m_out, new_cache, aux


def mla_dense_specs(cfg) -> Dict:
    return {
        "ln_attn": norm_spec(cfg),
        "attn": mla_specs(cfg),
        "ln_mlp": norm_spec(cfg),
        "mlp": mlp_specs(cfg),
    }


def mla_moe_specs(cfg) -> Dict:
    return {
        "ln_attn": norm_spec(cfg),
        "attn": mla_specs(cfg),
        "ln_mlp": norm_spec(cfg),
        "moe": moe_specs(cfg),
    }


def mla_layer(p: Dict, cfg, h: jax.Array, ctx: Dict, cache: Optional[Dict]
              ) -> Tuple[jax.Array, Optional[Dict], Aux]:
    a_in = apply_norm(p["ln_attn"], h, cfg)
    a_out, new_cache = mla_attention(
        p["attn"], cfg, a_in, ctx["positions"], cache=cache,
        cache_len=ctx.get("cache_len"), return_cache=ctx.get("return_cache", False))
    new_cache = _con_cache(ctx, new_cache)
    h = h + a_out
    m_in = apply_norm(p["ln_mlp"], h, cfg)
    if "moe" in p:
        m_out, aux = moe_block(p["moe"], cfg, m_in)
    else:
        m_out, aux = mlp(p["mlp"], cfg, m_in), jnp.zeros((), jnp.float32)
    return h + m_out, new_cache, aux


# ---------------------------------------------------------------------------
# SSM layer (mamba2) and hybrid period (zamba2)
# ---------------------------------------------------------------------------

def ssm_layer_specs(cfg) -> Dict:
    return {"ln": norm_spec(cfg), "mamba": mamba_specs(cfg)}


def ssm_layer(p: Dict, cfg, h: jax.Array, ctx: Dict, cache: Optional[Dict]
              ) -> Tuple[jax.Array, Optional[Dict], Aux]:
    x = apply_norm(p["ln"], h, cfg)
    out, new_cache = mamba_block(p["mamba"], cfg, x, cache=cache,
                                 want_cache=ctx.get("return_cache", False),
                                 constrain=ctx.get("constrain_ssm"))
    new_cache = _con_cache(ctx, new_cache)
    return h + out, new_cache, jnp.zeros((), jnp.float32)


def shared_attn_specs(cfg) -> Dict:
    """Zamba2 shared transformer block (weights reused at every period):
    input is concat(current hidden, initial embedding) fused by a linear."""
    d = cfg.d_model
    return {
        "fuse": P((2 * d, d), ("embed", "embed")),
        "layer": dense_layer_specs(cfg),
    }


def zamba_period_specs(cfg) -> Dict:
    return {"ssm": [ssm_layer_specs(cfg) for _ in range(cfg.hybrid_period)]}


def zamba_period(p: Dict, shared: Dict, cfg, h: jax.Array, ctx: Dict,
                 cache: Optional[Dict]) -> Tuple[jax.Array, Optional[Dict], Aux]:
    new_cache: Dict[str, Any] = {"ssm": [], "attn": None}
    for i in range(cfg.hybrid_period):
        c = cache["ssm"][i] if cache else None
        h, nc, _ = ssm_layer(p["ssm"][i], cfg, h, ctx, c)
        new_cache["ssm"].append(nc)
    fused = jnp.concatenate([h, ctx["h0"]], axis=-1) @ shared["fuse"].astype(h.dtype)
    a_c = cache["attn"] if cache else None
    out, nc_a, _ = dense_layer(shared["layer"], cfg, fused, ctx, a_c)
    new_cache["attn"] = nc_a
    h = h + (out - fused)          # residual of the shared block only
    if all(c is None for c in new_cache["ssm"]) and nc_a is None:
        new_cache = None
    return h, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# whisper encoder/decoder layers
# ---------------------------------------------------------------------------

def enc_layer_specs(cfg) -> Dict:
    return {
        "ln_attn": norm_spec(cfg),
        "attn": attn_specs(cfg),
        "ln_mlp": norm_spec(cfg),
        "mlp": mlp_specs(cfg),
    }


def enc_layer(p: Dict, cfg, h: jax.Array, ctx: Dict
              ) -> Tuple[jax.Array, None, Aux]:
    a_in = apply_norm(p["ln_attn"], h, cfg)
    a_out, _ = attention(p["attn"], cfg, a_in, ctx["enc_positions"],
                         causal=False, use_rope=False)
    h = h + a_out
    m_in = apply_norm(p["ln_mlp"], h, cfg)
    return h + mlp(p["mlp"], cfg, m_in), None, jnp.zeros((), jnp.float32)


def dec_layer_specs(cfg) -> Dict:
    return {
        "ln_self": norm_spec(cfg),
        "self_attn": attn_specs(cfg),
        "ln_cross": norm_spec(cfg),
        "cross_attn": attn_specs(cfg),
        "ln_mlp": norm_spec(cfg),
        "mlp": mlp_specs(cfg),
    }


def dec_layer(p: Dict, cfg, h: jax.Array, ctx: Dict, cache: Optional[Dict]
              ) -> Tuple[jax.Array, Optional[Dict], Aux]:
    self_c = cache.get("self") if cache else None
    a_in = apply_norm(p["ln_self"], h, cfg)
    a_out, nc_self = attention(
        p["self_attn"], cfg, a_in, ctx["positions"], cache=self_c,
        cache_len=ctx.get("cache_len"), use_rope=False,
        return_cache=ctx.get("return_cache", False))
    nc_self = _con_cache(ctx, nc_self)
    h = h + a_out
    c_in = apply_norm(p["ln_cross"], h, cfg)
    if cache is not None and "cross" in cache and cache["cross"] is not None:
        # decode: reuse precomputed cross K/V (no update)
        from .attention import _sdpa, _mask
        kc, vc = cache["cross"]["k"], cache["cross"]["v"]
        B, S, _ = c_in.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (c_in @ p["cross_attn"]["wq"].astype(c_in.dtype)).reshape(
            B, S, KV, H // KV, hd)
        kpos = jnp.arange(kc.shape[1])
        o = _sdpa(q, kc, vc, _mask(ctx["positions"], kpos, False, 0, None),
                  0.0)
        c_out = o.reshape(B, S, H * hd).astype(c_in.dtype) @ \
            p["cross_attn"]["wo"].astype(c_in.dtype)
        nc_cross = cache["cross"]
    else:
        c_out, nc_cross = attention(
            p["cross_attn"], cfg, c_in, ctx["positions"], causal=False,
            use_rope=False, kv_src=ctx["enc"], kv_positions=ctx["enc_positions"],
            return_cache=ctx.get("return_cache", False))
        nc_cross = _con_cache(ctx, nc_cross)
    h = h + c_out
    m_in = apply_norm(p["ln_mlp"], h, cfg)
    new_cache = None
    if nc_self is not None or nc_cross is not None:
        new_cache = {"self": nc_self, "cross": nc_cross}
    return h + mlp(p["mlp"], cfg, m_in), new_cache, jnp.zeros((), jnp.float32)
