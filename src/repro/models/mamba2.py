"""Mamba2 — SSD (state-space duality) block (arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute inside fixed-size chunks, linear recurrent state passing between
chunks (mirrored by the Pallas kernel in ``repro.kernels.ssd``).  Decode
is the O(1) recurrence over the (H, P, N) state.

Tensor-parallel layout (EXPERIMENTS.md §Perf hillclimb #3): the reference
implementation packs [z | x | B | C | dt] into one in_proj whose output
dim cannot be sharded semantically, forcing the whole block to be
TP-replicated (per-layer weight all-gathers dominated the collective
term).  Projections are split so the large d_inner-sized pieces shard
over the ``model`` axis — SSD heads are independent, so compute shards
cleanly; only the small grouped B/C projections stay replicated:

  z_proj, x_proj : (d, d_inner)   sharded on d_inner (H*P heads)
  bc_proj        : (d, 2*G*N)     replicated (small)
  dt_proj        : (d, H)         sharded on heads
  depthwise conv : x-part sharded on channels, B/C-part replicated
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import P, rmsnorm


def mamba_specs(cfg) -> Dict:
    d, din = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    return {
        "z_proj": P((d, din), ("embed", "mlp")),
        "x_proj": P((d, din), ("embed", "mlp")),
        "bc_proj": P((d, 2 * G * N), ("embed", None)),
        "dt_proj": P((d, H), ("embed", "heads")),
        "conv_x_w": P((cfg.ssm_conv, din), (None, "mlp"), scale=0.3),
        "conv_x_b": P((din,), ("mlp",), "zeros"),
        "conv_bc_w": P((cfg.ssm_conv, 2 * G * N), (None, None), scale=0.3),
        "conv_bc_b": P((2 * G * N,), (None,), "zeros"),
        "A_log": P((H,), (None,), "small_a"),
        "D": P((H,), (None,), "ones"),
        "dt_bias": P((H,), (None,), "zeros"),
        "gate_norm": P((din,), ("mlp",), "zeros"),
        "out_proj": P((din, d), ("mlp", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, kernel size K.  x: (B, L, C); w: (K, C).
    Returns (y, new_tail) where tail is the last K-1 inputs for decode."""
    K = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    y = jax.nn.silu(y + b[None, None, :])
    new_tail = xp[:, -(K - 1):, :]
    return y, new_tail


def _conv_step(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the depthwise conv.  x: (B, 1, C)."""
    K = w.shape[0]
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)   # (B, K, C)
    y = sum(xp[:, -K + i, :] * w[i][None, :] for i in range(K))
    y = jax.nn.silu(y + b[None, :])
    return y, xp[:, -(K - 1):, :]


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD core.  x: (b, L, H, P); dt: (b, L, H); A: (H,) < 0;
    B, C: (b, L, G, N).  Returns (y (b,L,H,P), final_state (b,H,P,N)).
    """
    b, L, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    xc = x.reshape(b, nc, chunk, H, Pd)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, G, N)
    Cc = C.reshape(b, nc, chunk, G, N)

    a = dtc * A[None, None, None, :]                      # log-decay per step
    a_cum = jnp.cumsum(a, axis=2)                         # (b,nc,Q,H)
    # intra-chunk "attention":  M[i,j] = exp(a_cum[i]-a_cum[j]) * (C_i . B_j) * dt_j
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]    # (b,nc,Q,Q,H)
    qpos = jnp.arange(chunk)
    causal = qpos[:, None] >= qpos[None, :]
    # mask BEFORE exp: the non-causal region has seg > 0 and can overflow;
    # exp-then-where poisons the backward pass with inf * 0 = NaN.
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    CB = jnp.einsum("bcqgn,bckgn->bcqkg", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    CB = jnp.repeat(CB, rep, axis=-1) if G != H else CB   # (b,nc,Q,Q,H)
    M = CB * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xc.astype(jnp.float32))

    # chunk-level states: S_c = sum_j exp(a_cum[last]-a_cum[j]) dt_j B_j x_j^T
    last = a_cum[:, :, -1:, :]                            # (b,nc,1,H)
    w_in = jnp.exp(last - a_cum) * dtc                    # (b,nc,Q,H)
    # expand groups to heads: (b,nc,Q,G,N) -> (b,nc,Q,H,N), h = g*rep + r
    Bh = jnp.repeat(Bc[:, :, :, :, None, :], rep, axis=4).reshape(b, nc, chunk, H, N) \
        if G != H else Bc
    Ch = jnp.repeat(Cc[:, :, :, :, None, :], rep, axis=4).reshape(b, nc, chunk, H, N) \
        if G != H else Cc
    S_chunk = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w_in,
                         Bh.astype(jnp.float32), xc.astype(jnp.float32))
    chunk_decay = jnp.exp(last[:, :, 0, :])               # (b,nc,H)

    def scan_fn(state, inp):
        s_c, dec = inp                                    # (b,H,P,N), (b,H)
        out_state = state                                 # state BEFORE chunk
        new_state = state * dec[:, :, None, None] + s_c
        return new_state, out_state

    s0 = init_state if init_state is not None else jnp.zeros((b, H, Pd, N), jnp.float32)
    final, states_before = jax.lax.scan(
        scan_fn, s0, (S_chunk.transpose(1, 0, 2, 3, 4),
                      chunk_decay.transpose(1, 0, 2)))
    states_before = states_before.transpose(1, 0, 2, 3, 4)  # (b,nc,H,P,N)

    # inter-chunk contribution: y_j += exp(a_cum[j]) * C_j . state_before
    w_out = jnp.exp(a_cum)                                # (b,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch.astype(jnp.float32),
                         states_before, w_out)
    y = (y_intra + y_inter).reshape(b, Lp, H, Pd)[:, :L]
    return y, final


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence.  state: (b,H,P,N); x: (b,H,P); dt: (b,H);
    B, C: (b,G,N).  Returns (y (b,H,P), new_state)."""
    H = x.shape[1]
    G = B.shape[1]
    rep = H // G
    Bh = jnp.repeat(B[:, :, None, :], rep, axis=2).reshape(B.shape[0], H, -1)
    Ch = jnp.repeat(C[:, :, None, :], rep, axis=2).reshape(C.shape[0], H, -1)
    decay = jnp.exp(dt * A[None, :])                      # (b,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32),
                     Bh.astype(jnp.float32))
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    return y, new_state


def mamba_block(params: Dict, cfg, h: jax.Array, *,
                cache: Optional[Dict] = None, want_cache: bool = False,
                constrain=None) -> Tuple[jax.Array, Optional[Dict]]:
    """Full Mamba2 block.
    cache = {"state": (b,H,P,N), "conv_x": (b,K-1,din), "conv_bc": (b,K-1,2GN)}.
    """
    Bsz, L, _ = h.shape
    din = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_head_dim
    dt_ = h.dtype
    z = h @ params["z_proj"].astype(dt_)
    xr = h @ params["x_proj"].astype(dt_)
    bc = h @ params["bc_proj"].astype(dt_)
    dt_raw = h @ params["dt_proj"].astype(dt_)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if cache is not None and L == 1:
        xc, new_cx = _conv_step(xr, params["conv_x_w"], params["conv_x_b"],
                                cache["conv_x"])
        bcc, new_cbc = _conv_step(bc, params["conv_bc_w"], params["conv_bc_b"],
                                  cache["conv_bc"])
        x = xc.reshape(Bsz, H, Pd)
        Bv = bcc[..., :G * N].reshape(Bsz, G, N)
        Cv = bcc[..., G * N:].reshape(Bsz, G, N)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + params["dt_bias"][None, :])
        yssm, new_state = ssd_decode_step(cache["state"], x, dt, A, Bv, Cv)
        yssm = yssm + x.astype(jnp.float32) * params["D"][None, :, None]
        yssm = yssm.reshape(Bsz, 1, din).astype(dt_)
        new_cache = {"state": new_state, "conv_x": new_cx, "conv_bc": new_cbc}
    else:
        tail_x = cache["conv_x"] if cache is not None else None
        tail_bc = cache["conv_bc"] if cache is not None else None
        xc, new_cx = _causal_conv(xr, params["conv_x_w"], params["conv_x_b"],
                                  tail_x)
        bcc, new_cbc = _causal_conv(bc, params["conv_bc_w"],
                                    params["conv_bc_b"], tail_bc)
        x = xc.reshape(Bsz, L, H, Pd)
        Bv = bcc[..., :G * N].reshape(Bsz, L, G, N)
        Cv = bcc[..., G * N:].reshape(Bsz, L, G, N)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])
        if constrain is not None:
            # head-shard the SSD internals: the (b, chunks, Q, Q, H) decay
            # tensors dominate live memory if XLA keeps them seq-sharded
            x = constrain(x)
            dt = constrain(dt)
        init = cache["state"] if cache is not None else None
        yssm, final_state = ssd_chunked(x, dt, A, Bv, Cv, cfg.ssm_chunk, init)
        yssm = yssm + x.astype(jnp.float32) * params["D"][None, None, :, None]
        yssm = yssm.reshape(Bsz, L, din).astype(dt_)
        if cache is not None or want_cache:
            new_cache = {"state": final_state, "conv_x": new_cx,
                         "conv_bc": new_cbc}
        else:
            new_cache = None
    # gated norm + out projection
    y = rmsnorm(yssm * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return y @ params["out_proj"].astype(dt_), new_cache
