"""Model assembly: init / train forward / prefill / decode for all families.

Layers are organized into *groups* of identical structure; each group's
parameters are stacked along a leading "layers" axis and executed with
``jax.lax.scan`` (small HLO, fast compiles, natural remat unit).  Caches
are stacked the same way and threaded through the scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .config import ModelConfig
from .layers import (P, apply_norm, axes_tree, init_params, norm_spec,
                     padded_vocab, sinusoidal_positions, softcap)


@dataclasses.dataclass(frozen=True)
class GroupDef:
    name: str
    n: int                                   # scan length
    specs: Dict                              # per-step param specs
    body: Callable                           # (p, cfg, h, ctx, cache) -> (h, cache, aux)
    has_cache: bool = True


def group_defs(cfg: ModelConfig) -> List[GroupDef]:
    f = cfg.family
    if f == "dense":
        if cfg.local_global:
            return [GroupDef("pairs", cfg.n_layers // 2, blocks.gemma_pair_specs(cfg),
                             blocks.gemma_pair)]
        return [GroupDef("layers", cfg.n_layers, blocks.dense_layer_specs(cfg),
                         blocks.dense_layer)]
    if f == "moe":
        if cfg.use_mla:
            defs = []
            if cfg.n_dense_layers:
                defs.append(GroupDef("dense", cfg.n_dense_layers,
                                     blocks.mla_dense_specs(cfg), blocks.mla_layer))
            defs.append(GroupDef("moe", cfg.n_layers - cfg.n_dense_layers,
                                 blocks.mla_moe_specs(cfg), blocks.mla_layer))
            return defs
        return [GroupDef("layers", cfg.n_layers, blocks.moe_layer_specs(cfg),
                         blocks.moe_layer)]
    if f == "ssm":
        return [GroupDef("layers", cfg.n_layers, blocks.ssm_layer_specs(cfg),
                         blocks.ssm_layer)]
    if f == "hybrid":
        per = cfg.hybrid_period
        n_periods = cfg.n_layers // per
        tail = cfg.n_layers - n_periods * per
        defs = [GroupDef("periods", n_periods, blocks.zamba_period_specs(cfg),
                         None)]  # body bound later (needs shared params)
        if tail:
            defs.append(GroupDef("tail", tail, blocks.ssm_layer_specs(cfg),
                                 blocks.ssm_layer))
        return defs
    if f == "encdec":
        return [GroupDef("encoder", cfg.n_encoder_layers, blocks.enc_layer_specs(cfg),
                         blocks.enc_layer, has_cache=False),
                GroupDef("decoder", cfg.n_layers, blocks.dec_layer_specs(cfg),
                         blocks.dec_layer)]
    raise ValueError(f"unknown family {f}")


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def _stack_specs(specs: Dict, n: int) -> Dict:
    def bump(p: P) -> P:
        return P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale)
    return jax.tree_util.tree_map(bump, specs,
                                  is_leaf=lambda x: isinstance(x, P))


def model_specs(cfg: ModelConfig) -> Dict:
    vp = padded_vocab(cfg.vocab_size)
    specs: Dict[str, Any] = {
        "embed": P((vp, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "final_norm": norm_spec(cfg),
        "groups": {g.name: _stack_specs(g.specs, g.n) for g in group_defs(cfg)},
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((cfg.d_model, vp), ("embed", "vocab"))
    if cfg.family == "hybrid":
        specs["shared_block"] = blocks.shared_attn_specs(cfg)
    if cfg.mtp_depth:
        specs["mtp"] = {
            "proj": P((2 * cfg.d_model, cfg.d_model), ("embed", "embed")),
            "norm_h": norm_spec(cfg),
            "norm_e": norm_spec(cfg),
            "layer": blocks.mla_dense_specs(cfg) if cfg.use_mla
            else blocks.dense_layer_specs(cfg),
        }
    return specs


def init_model(key: jax.Array, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.param_dtype)
    return init_params(key, model_specs(cfg), dtype=dt)


def model_axes(cfg: ModelConfig) -> Dict:
    return axes_tree(model_specs(cfg))


# ---------------------------------------------------------------------------
# scan machinery
# ---------------------------------------------------------------------------

def _scan_group(gdef: GroupDef, params: Dict, cfg: ModelConfig, h: jax.Array,
                ctx: Dict, cache: Optional[Dict], shared: Optional[Dict]
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    body = gdef.body

    con = ctx.get("constrain")

    def step(carry, xs):
        hh = carry
        p, c = xs
        if con is not None:
            hh = con(hh)
        if gdef.name == "periods":
            hh2, nc, aux = blocks.zamba_period(p, shared, cfg, hh, ctx, c)
        elif gdef.has_cache:
            hh2, nc, aux = body(p, cfg, hh, ctx, c)
        else:
            hh2, nc, aux = body(p, cfg, hh, ctx)
        if con is not None:
            hh2 = con(hh2)
        return hh2, (nc, aux)

    fn = jax.checkpoint(step) if cfg.remat else step
    if cfg.unroll:
        caches, auxes = [], []
        for i in range(gdef.n):
            p_i = jax.tree_util.tree_map(lambda x: x[i], params)
            c_i = None if cache is None else jax.tree_util.tree_map(
                lambda x: x[i], cache)
            h, (nc_i, aux_i) = fn(h, (p_i, c_i))
            caches.append(nc_i)
            auxes.append(aux_i)
        aux = jnp.stack(auxes)
        if all(x is None for x in jax.tree_util.tree_leaves(caches)):
            new_cache = None
        else:
            new_cache = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *caches)
    else:
        h, (new_cache, aux) = jax.lax.scan(fn, h, (params, cache))
        if new_cache is not None and all(
                x is None for x in jax.tree_util.tree_leaves(new_cache)):
            new_cache = None
    return h, new_cache, aux.sum()


def _embed(params: Dict, cfg: ModelConfig, tokens: jax.Array,
           patch_embeds: Optional[jax.Array] = None) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    if cfg.name.startswith("gemma"):
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.n_patches and patch_embeds is not None:
        pe = patch_embeds.astype(dt)
        h = jnp.concatenate([pe, h[:, cfg.n_patches:]], axis=1)
    return h


def _logits(params: Dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = apply_norm(params["final_norm"], h, cfg)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].astype(h.dtype).T
    else:
        logits = h @ params["lm_head"].astype(h.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits


def _run_encoder(params: Dict, cfg: ModelConfig, frames: jax.Array,
                 ctx: Dict) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    enc_pos = jnp.arange(frames.shape[1])
    h = frames.astype(dt) + sinusoidal_positions(frames.shape[1],
                                                 cfg.d_model).astype(dt)
    ctx = dict(ctx, enc_positions=enc_pos)
    h, _, _ = _scan_group([g for g in group_defs(cfg) if g.name == "encoder"][0],
                          params["groups"]["encoder"], cfg, h, ctx, None, None)
    ctx["enc"] = h
    return h, ctx


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward_train(params: Dict, cfg: ModelConfig, batch: Dict,
                  constrain=None, constrain_ssm=None, constrain_qkv=None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (logits (B,S,Vpad) fp32, aux dict incl. optional mtp logits).
    ``constrain`` (optional) re-asserts the batch sharding of the hidden
    state inside each scanned layer — without it XLA may shard the
    remat-saved activation stack on the layer dim (or replicate it)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)
    ctx: Dict[str, Any] = {"positions": positions, "mode": "train",
                           "return_cache": False, "constrain": constrain,
                           "constrain_ssm": constrain_ssm,
                           "constrain_qkv": constrain_qkv}
    if cfg.family == "encdec":
        _, ctx = _run_encoder(params, cfg, batch["frames"], ctx)
        if cfg.norm == "layernorm":
            pass
        dt = jnp.dtype(cfg.dtype)
        h = params["embed"].astype(dt)[tokens] + sinusoidal_positions(
            S, cfg.d_model).astype(dt)
    else:
        h = _embed(params, cfg, tokens, batch.get("patch_embeds"))
    ctx["h0"] = h
    aux_total = jnp.zeros((), jnp.float32)
    shared = params.get("shared_block")
    for g in group_defs(cfg):
        if g.name == "encoder":
            continue
        h, _, aux = _scan_group(g, params["groups"][g.name], cfg, h, ctx,
                                None, shared)
        aux_total = aux_total + aux
    logits = _logits(params, cfg, h)
    aux: Dict[str, jax.Array] = {"moe_aux": aux_total}
    if cfg.mtp_depth:
        aux["mtp_logits"] = _mtp_logits(params, cfg, h, tokens)
    return logits, aux


def _mtp_logits(params: Dict, cfg: ModelConfig, h: jax.Array,
                tokens: jax.Array) -> jax.Array:
    """DeepSeek-V3 multi-token prediction (depth 1): combine the trunk
    hidden state at position t with the embedding of token t+1, run one
    extra layer, and predict token t+2 through the shared head."""
    mtp = params["mtp"]
    dt = h.dtype
    nxt = jnp.roll(tokens, -1, axis=1)
    e = params["embed"].astype(dt)[nxt]
    hin = jnp.concatenate([apply_norm(mtp["norm_h"], h, cfg),
                           apply_norm(mtp["norm_e"], e, cfg)], axis=-1)
    hm = hin @ mtp["proj"].astype(dt)
    ctx = {"positions": jnp.arange(h.shape[1]), "mode": "train",
           "return_cache": False}
    if cfg.use_mla:
        hm, _, _ = blocks.mla_layer(mtp["layer"], cfg, hm, ctx, None)
    else:
        hm, _, _ = blocks.dense_layer(mtp["layer"], cfg, hm, ctx, None)
    return _logits(params, cfg, hm)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    """Stacked per-group decode caches."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def kv(n: int, length: int) -> Dict:
        return {"k": jnp.zeros((n, batch, length, KV, hd), dtype),
                "v": jnp.zeros((n, batch, length, KV, hd), dtype)}

    def ssm(n: int) -> Dict:
        return {"state": jnp.zeros((n, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                    cfg.ssm_state), jnp.float32),
                "conv_x": jnp.zeros((n, batch, cfg.ssm_conv - 1, cfg.d_inner),
                                    dtype),
                "conv_bc": jnp.zeros((n, batch, cfg.ssm_conv - 1,
                                      2 * cfg.ssm_groups * cfg.ssm_state),
                                     dtype)}

    caches: Dict[str, Any] = {}
    for g in group_defs(cfg):
        if g.name == "encoder":
            continue
        if g.name == "pairs":
            local_len = min(max_len, cfg.sliding_window)
            caches[g.name] = {"local": kv(g.n, local_len),
                              "global": kv(g.n, max_len)}
        elif g.name in ("layers", "dense", "moe") and cfg.use_mla:
            caches[g.name] = {
                "ckv": jnp.zeros((g.n, batch, max_len, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((g.n, batch, max_len, cfg.qk_rope_dim), dtype)}
        elif cfg.family == "ssm":
            caches[g.name] = ssm(g.n)
        elif g.name == "periods":
            caches[g.name] = {
                "ssm": [ssm(g.n) for _ in range(cfg.hybrid_period)],
                "attn": kv(g.n, max_len)}
        elif g.name == "tail":
            caches[g.name] = ssm(g.n)
        elif g.name == "decoder":
            caches[g.name] = {"self": kv(g.n, max_len),
                              "cross": kv(g.n, cfg.encoder_seq)}
        else:
            caches[g.name] = kv(g.n, max_len)
    return caches


def encdec_prepare(params: Dict, cfg: ModelConfig, frames: jax.Array
                   ) -> Tuple[jax.Array, Dict]:
    """Run the encoder once and precompute per-decoder-layer cross K/V
    (the serving fast path: cross-attention K/V are static during decode)."""
    enc, _ = _run_encoder(params, cfg, frames, {})
    dec_p = params["groups"]["decoder"]
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def one_layer(p):
        B, Se, _ = enc.shape
        k = (enc @ p["cross_attn"]["wk"].astype(enc.dtype)).reshape(B, Se, KV, hd)
        v = (enc @ p["cross_attn"]["wv"].astype(enc.dtype)).reshape(B, Se, KV, hd)
        return {"k": k, "v": v}

    cross = jax.vmap(one_layer)(dec_p)
    return enc, cross


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, max_len: int,
            constrain=None, constrain_cache=None, constrain_ssm=None
            ) -> Tuple[jax.Array, Dict]:
    """Forward over the prompt; returns (last-position logits, cache).

    ``constrain``/``constrain_cache`` re-assert batch/seq shardings of the
    hidden state and the per-layer cache entries inside the scan (see
    forward_train; without them the stacked cache/remat buffers lose the
    batch sharding)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)
    ctx: Dict[str, Any] = {"positions": positions, "mode": "prefill",
                           "return_cache": True, "constrain": constrain,
                           "constrain_cache": constrain_cache,
                           "constrain_ssm": constrain_ssm}
    if cfg.family == "encdec":
        _, ctx = _run_encoder(params, cfg, batch["frames"], ctx)
        dt = jnp.dtype(cfg.dtype)
        h = params["embed"].astype(dt)[tokens] + sinusoidal_positions(
            S, cfg.d_model).astype(dt)
    else:
        h = _embed(params, cfg, tokens, batch.get("patch_embeds"))
    ctx["h0"] = h
    shared = params.get("shared_block")
    cache_out: Dict[str, Any] = {}
    for g in group_defs(cfg):
        if g.name == "encoder":
            continue
        h, nc, _ = _scan_group(g, params["groups"][g.name], cfg, h, ctx, None,
                               shared)
        cache_out[g.name] = nc
    logits = _logits(params, cfg, h[:, -1:])
    return logits, cache_out


def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict, cache_len: jax.Array,
                batch_extras: Optional[Dict] = None
                ) -> Tuple[jax.Array, Dict]:
    """One decode step.  tokens: (B, 1); cache from init_cache/prefill.
    ``cache_len`` may be a scalar (synchronized batch) or a (B,) vector
    of per-row positions (continuous batching)."""
    B, S = tokens.shape
    if jnp.ndim(cache_len) == 1:
        positions = cache_len[:, None] + jnp.arange(S)[None, :]
    else:
        positions = cache_len + jnp.arange(S)
    ctx: Dict[str, Any] = {"positions": positions, "mode": "decode",
                           "cache_len": cache_len, "return_cache": True}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        ctx["enc"] = (batch_extras or {}).get("enc")
        ctx["enc_positions"] = jnp.arange(cfg.encoder_seq)
        max_len = cache["decoder"]["self"]["k"].shape[2]
        pos_tab = sinusoidal_positions(max_len, cfg.d_model).astype(dt)
        h = params["embed"].astype(dt)[tokens] + pos_tab[positions][None]
    else:
        h = _embed(params, cfg, tokens)
    ctx["h0"] = h
    shared = params.get("shared_block")
    new_cache: Dict[str, Any] = {}
    for g in group_defs(cfg):
        if g.name == "encoder":
            continue
        h, nc, _ = _scan_group(g, params["groups"][g.name], cfg, h, ctx,
                               cache[g.name], shared)
        new_cache[g.name] = nc
    logits = _logits(params, cfg, h)
    return logits, new_cache
