"""Attention: GQA + RoPE + sliding-window + logit softcap.

Two XLA implementations with identical math:
  * ``naive``   — materializes (Sq, Sk) scores; used for tiny smoke shapes
                  and as the oracle for the chunked path / Pallas kernel.
  * ``chunked`` — flash-style online-softmax scan over KV chunks; O(S) live
                  memory, the default for training/prefill.  Mirrors the
                  Pallas TPU kernel in ``repro.kernels.flash_attention``.

Decode attends a single new token against a (possibly windowed) KV cache.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import P, apply_rope, softcap

NEG_INF = -1e30


def attn_specs(cfg, cross: bool = False) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": P((d, H * hd), ("embed", "heads")),
        "wk": P((d, KV * hd), ("embed", "kv")),
        "wv": P((d, KV * hd), ("embed", "kv")),
        "wo": P((H * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        specs["qn"] = P((hd,), (None,), "zeros")
        specs["kn"] = P((hd,), (None,), "zeros")
    return specs


def _mask(qpos: jax.Array, kpos: jax.Array, causal: bool, window: int,
          kv_len: Optional[jax.Array]) -> jax.Array:
    """(..., Sq, Sk) boolean validity mask."""
    m = jnp.ones((qpos.shape[-1], kpos.shape[-1]), dtype=bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:
        m &= kpos[None, :] < kv_len
    return m


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
          cap: float) -> jax.Array:
    """q: (B,Sq,KV,G,D); k/v: (B,Sk,KV,D); mask: (Sq,Sk) or (B,Sq,Sk)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = softcap(s, cap)
    m = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, qpos: jax.Array,
                  kpos: jax.Array, causal: bool, window: int, cap: float,
                  kv_len: Optional[jax.Array], chunk: int) -> jax.Array:
    """Online-softmax over KV chunks (flash-attention recurrence in XLA)."""
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    if kv_len is None:
        kv_len = Sk              # always mask the chunk padding
    chunk = min(chunk, Sk)
    n = (Sk + chunk - 1) // chunk
    pad = n * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max // 2)
    kc = k.reshape(B, n, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(n, chunk)
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kj.astype(jnp.float32)) * scale
        s = softcap(s, cap)
        msk = _mask(qpos, pj, causal, window, kv_len)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4)          # (B,Sq,KV,G,D)


def attention(params: Dict, cfg, x: jax.Array, positions: jax.Array, *,
              window: int = 0, causal: bool = True, use_rope: bool = True,
              kv_src: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              cache: Optional[Dict] = None,
              cache_len: Optional[jax.Array] = None,
              return_cache: bool = False,
              constrain_qkv=None) -> Tuple[jax.Array, Optional[Dict]]:
    """General attention entry point.

    * self-attention train/prefill: cache=None (return_cache to build one)
    * cross-attention:              kv_src = encoder states (cache optional)
    * decode:                       x is (B,1,D), cache holds K/V, cache_len
                                    is the number of valid positions.
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, hd)
    src = x if kv_src is None else kv_src
    new_cache = None
    if cache is not None and kv_src is None:
        # decode: append new K/V.  Caches smaller than the stream roll over
        # (sliding-window layers keep only the last `window` entries; keys
        # are stored post-RoPE so slot order does not matter).
        k_new = (src @ params["wk"].astype(dt)).reshape(B, S, KV, hd)
        v_new = (src @ params["wv"].astype(dt)).reshape(B, S, KV, hd)
        if "qn" in params:
            from .layers import rmsnorm
            q = rmsnorm(q, params["qn"], cfg.norm_eps)
            k_new = rmsnorm(k_new, params["kn"], cfg.norm_eps)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k_new = apply_rope(k_new, positions, cfg.rope_theta)
        size = cache["k"].shape[1]
        write_idx = cache_len % size
        if jnp.ndim(cache_len) == 1:
            # per-row positions (continuous batching): vmap the row writes
            upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
                c, n, (i, 0, 0)))
            k = upd(cache["k"], k_new.astype(cache["k"].dtype), write_idx)
            v = upd(cache["v"], v_new.astype(cache["v"].dtype), write_idx)
        else:
            k = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype),
                (0, write_idx, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype),
                (0, write_idx, 0, 0))
        new_cache = {"k": k, "v": v}
        kpos = jnp.arange(size)
        qg = q.reshape(B, S, KV, G, hd)
        valid = jnp.minimum(cache_len + S, size)
        if jnp.ndim(cache_len) == 1:
            msk = kpos[None, None, :] < valid[:, None, None]     # (B,1,size)
            msk = jnp.broadcast_to(msk, (B, S, size))
        else:
            msk = jnp.broadcast_to(kpos[None, :] < valid, (S, size))
        o = _sdpa(qg, k, v, msk, cfg.attn_logit_softcap)
    else:
        k = (src @ params["wk"].astype(dt)).reshape(B, -1, KV, hd)
        v = (src @ params["wv"].astype(dt)).reshape(B, -1, KV, hd)
        if "qn" in params:
            from .layers import rmsnorm
            q = rmsnorm(q, params["qn"], cfg.norm_eps)
            k = rmsnorm(k, params["kn"], cfg.norm_eps)
        kpos = kv_positions if kv_positions is not None else positions
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            if kv_src is None:
                k = apply_rope(k, kpos, cfg.rope_theta)
        if return_cache:
            new_cache = {"k": k, "v": v}
        qg = q.reshape(B, S, KV, G, hd)
        if constrain_qkv is not None:
            # assert head sharding through the reshape: the chunked-softmax
            # score blocks (B, KV, G, Sq, C) otherwise replicate heads
            qg, k, v = constrain_qkv(qg), constrain_qkv(k), constrain_qkv(v)
        if cfg.attn_impl == "naive" or S * k.shape[1] <= 256 * 256:
            o = _sdpa(qg, k, v, _mask(positions, kpos, causal, window, None),
                      cfg.attn_logit_softcap)
        else:
            o = _sdpa_chunked(qg, k, v, positions, kpos, causal, window,
                              cfg.attn_logit_softcap, None, cfg.attn_chunk)
    # both paths yield (B, Sq, KV, G, D)
    o = o.reshape(B, S, H * hd).astype(dt)
    return o @ params["wo"].astype(dt), new_cache
