"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are low-rank compressed; the KV cache stores only the
latent ``c_kv`` (kv_lora_rank) plus a shared RoPE key (qk_rope_dim).
Decode uses the *absorbed* formulation: the up-projection ``W^{UK}`` is
folded into the query so attention runs in latent space — the memory
win that makes 32k/500k caches practical.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import NEG_INF, _mask
from .layers import P, apply_rope, rmsnorm

# sequences longer than this use the chunked online-softmax path
# (module-level so tests can exercise both paths at small sizes)
FLASH_THRESHOLD = 4096


def mla_specs(cfg) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": P((d, cfg.q_lora_rank), ("embed", "lora")),
        "q_norm": P((cfg.q_lora_rank,), (None,), "zeros"),
        "wq_b": P((cfg.q_lora_rank, H * (nope + rope)), ("lora", "heads")),
        "wkv_a": P((d, cfg.kv_lora_rank + rope), ("embed", "lora")),
        "kv_norm": P((cfg.kv_lora_rank,), (None,), "zeros"),
        "wkv_b": P((cfg.kv_lora_rank, H * (nope + vd)), ("lora", "heads")),
        "wo": P((H * vd, d), ("heads", "embed")),
    }


def _project_q(params: Dict, cfg, x: jax.Array, positions: jax.Array):
    B, S, _ = x.shape
    H, nope, rope = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    dt = x.dtype
    cq = rmsnorm(x @ params["wq_a"].astype(dt), params["q_norm"], cfg.norm_eps)
    q = (cq @ params["wq_b"].astype(dt)).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(params: Dict, cfg, x: jax.Array, positions: jax.Array):
    """Compressed cache entries: (c_kv normalized, k_rope rotated)."""
    dt = x.dtype
    kvr = x @ params["wkv_a"].astype(dt)
    ckv, k_rope = kvr[..., :cfg.kv_lora_rank], kvr[..., cfg.kv_lora_rank:]
    ckv = rmsnorm(ckv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope


def mla_attention(params: Dict, cfg, x: jax.Array, positions: jax.Array, *,
                  cache: Optional[Dict] = None,
                  cache_len: Optional[jax.Array] = None,
                  return_cache: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lr = cfg.kv_lora_rank
    dt = x.dtype
    scale = 1.0 / math.sqrt(nope + rope)
    q_nope, q_rope = _project_q(params, cfg, x, positions)
    wkv_b = params["wkv_b"].astype(dt).reshape(lr, H, nope + vd)
    wk_b, wv_b = wkv_b[..., :nope], wkv_b[..., nope:]

    if cache is not None:
        # ---- decode: absorbed attention in latent space -------------------
        ckv_new, kr_new = _latent_kv(params, cfg, x, positions)
        if jnp.ndim(cache_len) == 1:    # per-row positions (batcher)
            upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
                c, n, (i, 0)))
            ckv = upd(cache["ckv"], ckv_new.astype(cache["ckv"].dtype),
                      cache_len)
            kr = upd(cache["kr"], kr_new.astype(cache["kr"].dtype), cache_len)
        else:
            ckv = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv_new.astype(cache["ckv"].dtype),
                (0, cache_len, 0))
            kr = jax.lax.dynamic_update_slice(
                cache["kr"], kr_new.astype(cache["kr"].dtype),
                (0, cache_len, 0))
        new_cache = {"ckv": ckv, "kr": kr}
        # fold W^{UK} into q:  (B,S,H,nope) x (lr,H,nope) -> (B,S,H,lr)
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope.astype(jnp.float32),
                           wk_b.astype(jnp.float32))
        s_lat = jnp.einsum("bshl,btl->bhst", q_lat, ckv.astype(jnp.float32))
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                            kr.astype(jnp.float32))
        s = (s_lat + s_rope) * scale
        kpos = jnp.arange(ckv.shape[1])
        if jnp.ndim(cache_len) == 1:
            msk = jnp.broadcast_to(
                kpos[None, None, :] < (cache_len + S)[:, None, None],
                (B, S, ckv.shape[1]))
            s = jnp.where(msk[:, None], s, NEG_INF)
        else:
            msk = _mask(positions, kpos, False, 0, cache_len + S)
            s = jnp.where(msk[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btl->bshl", p, ckv.astype(jnp.float32))
        o = jnp.einsum("bshl,lhv->bshv", ctx, wv_b.astype(jnp.float32))
    else:
        # ---- train/prefill ------------------------------------------------
        ckv, k_rope = _latent_kv(params, cfg, x, positions)
        new_cache = {"ckv": ckv, "kr": k_rope} if return_cache else None
        if S <= FLASH_THRESHOLD:
            k_nope = jnp.einsum("btl,lhn->bthn", ckv.astype(jnp.float32),
                                wk_b.astype(jnp.float32))
            v = jnp.einsum("btl,lhv->bthv", ckv.astype(jnp.float32),
                           wv_b.astype(jnp.float32))
            s = (jnp.einsum("bshn,bthn->bhst", q_nope.astype(jnp.float32), k_nope)
                 + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                              k_rope.astype(jnp.float32))) * scale
            msk = _mask(positions, positions, True, 0, None)
            s = jnp.where(msk[None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhst,bthv->bshv", p, v)
        else:
            o = _mla_flash(cfg, q_nope, q_rope, ckv, k_rope, wk_b, wv_b,
                           positions, scale)
    out = o.reshape(B, S, H * vd).astype(dt) @ params["wo"].astype(dt)
    return out, new_cache


def _mla_flash(cfg, q_nope, q_rope, ckv, k_rope, wk_b, wv_b, positions,
               scale, chunk: int = 2048):
    """Online-softmax over KV chunks; K/V expanded from the latent per
    chunk (compute-optimal prefill form; decode uses the absorbed form)."""
    B, S, H, nope = q_nope.shape
    vd = wv_b.shape[-1]
    T = ckv.shape[1]
    n = (T + chunk - 1) // chunk
    pad = n * chunk - T
    if pad:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    kpos = jnp.pad(positions, (0, pad),
                   constant_values=jnp.iinfo(jnp.int32).max // 2)
    ckv_c = ckv.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    kr_c = k_rope.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    pc = kpos.reshape(n, chunk)
    qn = q_nope.astype(jnp.float32)
    qr = q_rope.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        cj, rj, pj = xs
        k_nope = jnp.einsum("bcl,lhn->bchn", cj.astype(jnp.float32),
                            wk_b.astype(jnp.float32))
        vj = jnp.einsum("bcl,lhv->bchv", cj.astype(jnp.float32),
                        wv_b.astype(jnp.float32))
        s = (jnp.einsum("bshn,bchn->bhsc", qn, k_nope)
             + jnp.einsum("bshr,bcr->bhsc", qr, rj.astype(jnp.float32))) * scale
        msk = positions[:, None] >= pj[None, :]
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhsc,bchv->bhsv", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ckv_c, kr_c, pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3)             # (B,S,H,vd)
