"""Parameter specs, initializers and elementary layers.

Parameters are built from *specs*: a nested dict whose leaves are
``P(shape, axes, init, scale)``.  ``axes`` are *logical* axis names
(``embed``, ``heads``, ``mlp``, ``vocab``, ``experts``, ...) mapped to
mesh axes by ``repro.parallel.sharding`` — the one place distribution
policy lives.  ``init_params`` materializes a spec tree; ``axes_tree``
extracts the matching logical-axes tree for pjit shardings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small_a
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, P)


def init_params(key: jax.Array, specs: Dict, dtype=jnp.float32) -> Dict:
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        elif spec.init == "small_a":   # mamba A_log init: log(uniform[1,16])
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1.0, 16.0)
            out.append(jnp.log(u).astype(dtype))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def axes_tree(specs: Dict) -> Dict:
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=is_spec)


def shapes_tree(specs: Dict) -> Dict:
    return jax.tree_util.tree_map(lambda s: s.shape, specs, is_leaf=is_spec)


def param_count(params: Dict) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# elementary ops
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
            plus_one: bool = True) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    w = weight.astype(jnp.float32)
    w = 1.0 + w if plus_one else w
    return (x * w).astype(dt)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_spec(cfg, dim: Optional[int] = None) -> Dict:
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": P((d,), (None,), "ones"), "b": P((d,), (None,), "zeros")}
    return {"w": P((d,), (None,), "zeros")}   # rmsnorm stored as (1 + w)


def apply_norm(params: Dict, x: jax.Array, cfg) -> jax.Array:
    if "b" in params:
        return layernorm(x, params["w"], params["b"], cfg.norm_eps)
    return rmsnorm(x, params["w"], cfg.norm_eps)


def activation(name: str):
    if name == "gelu":
        return jax.nn.gelu
    return jax.nn.silu


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # (..., S, D/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (no params)."""
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=1)
    return jnp.asarray(out, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# vocab padding for clean TP sharding
# ---------------------------------------------------------------------------

def padded_vocab(vocab_size: int, multiple: int = 256) -> int:
    return ((vocab_size + multiple - 1) // multiple) * multiple
