"""Mixture-of-Experts with token-choice top-k routing and capacity-bounded
scatter dispatch (expert-parallel friendly).

Dispatch avoids the dense (tokens, experts, capacity) one-hot tensor:
tokens are scattered into a per-expert buffer (E, C, d) using their
rank-within-expert (a cumsum over assignment one-hots), expert FFNs run as
one batched einsum over stacked weights, and results gather back.  With
``experts -> model`` sharding XLA lowers the scatter/gather into
all-to-all exchanges — the TPU-native analogue of PS-style gradient
sharding.  Tokens beyond capacity are dropped (standard Switch-style).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import P, activation


def moe_specs(cfg) -> Dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    specs = {
        "router": P((d, E), ("embed", "experts"), scale=0.02),
        "wg": P((E, d, f), ("experts", "embed", "expert_mlp")),
        "wi": P((E, d, f), ("experts", "embed", "expert_mlp")),
        "wo": P((E, f, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        specs["shared"] = {
            "wg": P((d, fs), ("embed", "mlp")),
            "wi": P((d, fs), ("embed", "mlp")),
            "wo": P((fs, d), ("mlp", "embed")),
        }
    return specs


def _router_probs(cfg, logits: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Top-k expert ids and combine weights; (T, k) each."""
    if cfg.router_type == "sigmoid":           # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        w, ids = jax.lax.top_k(scores, cfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return ids, w


def _rank_in_expert(flat_ids: jax.Array, n_experts: int) -> jax.Array:
    """rank[j] = number of i < j with flat_ids[i] == flat_ids[j].

    Stable-sort the assignments by expert, compute the position within
    each sorted segment with a 1-D running maximum of segment starts,
    and scatter back through the inverse permutation."""
    tk = flat_ids.shape[0]
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    idx = jnp.arange(tk)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_ids[1:] != sorted_ids[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    rank_sorted = idx - seg_start
    return jnp.zeros(tk, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def _rank_in_expert_ref(flat_ids: jax.Array, n_experts: int) -> jax.Array:
    """Reference O(TK*E) one-hot cumsum ranking (test oracle)."""
    onehot = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.int32)
    cum = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(cum, flat_ids[:, None], axis=1)[:, 0]


def moe_block(params: Dict, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    dt = x.dtype
    xt = x.reshape(T, d)
    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)
    ids, w = _router_probs(cfg, logits)                    # (T,k)

    # load-balancing auxiliary loss (Switch/OLMoE style)
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)                                # mean router prob
    ce = jnp.zeros(E).at[ids.reshape(-1)].add(1.0) / (T * k)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    cap = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    flat_ids = ids.reshape(-1)                             # (T*k,)
    flat_w = w.reshape(-1)
    # rank of each assignment within its expert (# prior hits on that
    # expert).  Sort-based: O(TK log TK) total.  The textbook one-hot
    # cumsum is O(TK * E) and its reduce-window lowering dominated the
    # whole step's HLO FLOPs (5.7e14/device for olmoe train_4k — see
    # EXPERIMENTS.md §Perf hillclimb #1), so it is kept only as a
    # reference implementation in tests.
    rank = _rank_in_expert(flat_ids, E)
    keep = rank < cap
    slot = flat_ids * cap + jnp.where(keep, rank, 0)       # (T*k,)

    buf = jnp.zeros((E * cap, d), dt)
    tok_idx = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[tok_idx], 0))
    xe = buf.reshape(E, cap, d)

    act = activation(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", xe, params["wg"].astype(dt))) * \
        jnp.einsum("ecd,edf->ecf", xe, params["wi"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dt))

    gathered = ye.reshape(E * cap, d)[slot]                # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0) * flat_w[:, None].astype(dt)
    out = jnp.zeros((T, d), dt).at[tok_idx].add(gathered)

    if cfg.n_shared_experts:
        sh = params["shared"]
        hs = act(xt @ sh["wg"].astype(dt)) * (xt @ sh["wi"].astype(dt))
        out = out + hs @ sh["wo"].astype(dt)
    return out.reshape(B, S, d), aux
