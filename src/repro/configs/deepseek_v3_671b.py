"""DeepSeek-V3 671B (arXiv:2412.19437) — MLA + 1 shared/256 routed top-8 MoE
+ multi-token prediction.  bf16 params (see DESIGN.md memory note)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432,                    # dense FFN in the first 3 layers
    vocab_size=129280,
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    n_dense_layers=3, router_type="sigmoid", capacity_factor=1.0,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    mtp_depth=1, tie_embeddings=False,
    param_dtype="bfloat16",
)
