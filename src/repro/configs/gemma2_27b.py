"""Gemma2-27B (arXiv:2408.00118) — alternating local(4096)/global attention,
attn+final logit softcaps, post-norms."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    local_global=True, sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, post_norms=True,
    act="gelu", rope_theta=10000.0,
)
