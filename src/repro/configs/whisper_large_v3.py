"""Whisper large-v3 (arXiv:2212.04356) — encoder-decoder audio transformer.
Conv frontend is a STUB per the assignment: input_specs provides
precomputed (B, 1500, d_model) frame embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_encoder_layers=32, encoder_seq=1500,
    d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    norm="layernorm", act="gelu", tie_embeddings=True,
    gated_mlp=False,
)
