"""Gemma2-9B (arXiv:2408.00118) — alternating local/global, softcaps."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    local_global=True, sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, post_norms=True,
    act="gelu", rope_theta=10000.0,
)
