"""StarCoder2-3B (arXiv:2402.19173) — dense GQA kv=2, RoPE."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab_size=49152,
    act="gelu", rope_theta=999999.0, norm="layernorm",
    gated_mlp=False,
)
