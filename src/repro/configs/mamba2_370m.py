"""Mamba2-370M (arXiv:2405.21060) — attention-free SSD."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    ssm_groups=1,
)
