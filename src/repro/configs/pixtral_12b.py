"""Pixtral-12B (hf:mistralai/Pixtral-12B-2409) — mistral-nemo decoder
backbone; vision frontend STUBBED to precomputed patch embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    n_patches=256, rope_theta=1000000000.0, tie_embeddings=False,
)
