"""Zamba2-7B (arXiv:2411.15242) — Mamba2 backbone + shared attention block
every 6 SSM layers (weights reused, concat-skip input)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    ssm_groups=1, hybrid_period=6,
)
