"""Assigned architecture configs.  ``get_config(name)`` returns the full
published config; ``get_smoke(name)`` a reduced same-family variant for
CPU tests.  ``SHAPES`` defines the assigned input-shape cells."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from ..models.config import ModelConfig, smoke_variant

ARCHS = [
    "whisper_large_v3", "olmoe_1b_7b", "deepseek_v3_671b", "granite_34b",
    "gemma2_27b", "starcoder2_3b", "gemma2_9b", "mamba2_370m",
    "pixtral_12b", "zamba2_7b",
]

# (shape_name, seq_len, global_batch, kind)
SHAPES: List[Tuple[str, int, int, str]] = [
    ("train_4k", 4096, 256, "train"),
    ("prefill_32k", 32768, 32, "prefill"),
    ("decode_32k", 32768, 128, "decode"),
    ("long_500k", 524288, 1, "decode"),
]

# long_500k only for sub-quadratic families (see DESIGN.md §Arch-applicability)
LONG_OK = {"mamba2_370m", "zamba2_7b", "gemma2_9b", "gemma2_27b"}


def norm_name(name: str) -> str:
    return name.replace("-", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{norm_name(name)}", __package__)
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    return smoke_variant(get_config(name))


def cells(arch: str) -> List[Tuple[str, int, int, str]]:
    out = []
    for shape, seq, gb, kind in SHAPES:
        if shape == "long_500k" and norm_name(arch) not in LONG_OK:
            continue
        out.append((shape, seq, gb, kind))
    return out


def all_cells() -> List[Tuple[str, str, int, int, str]]:
    return [(a, *c) for a in ARCHS for c in cells(a)]
