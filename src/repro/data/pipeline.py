"""Deterministic, resumable, sharded synthetic data pipeline.

Serves the role of the paper's HDFS data-chunk layer (Sec. III-B): the
token stream is split into *chunks*; each data-parallel worker reads the
chunks assigned to it for the current slot.  The stream is a seeded
Markov-ish token process with induction structure so language models
actually reduce loss on it (used by examples/ and the e2e tests).

State is an explicit (epoch, step) cursor — checkpointable, and
re-shardable when the worker count changes (elastic re-mesh): chunk
assignment is a pure function of (step, n_workers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_chunks: int = 1024          # dataset chunks (paper's N_i)


class SyntheticStream:
    """Zipf unigrams + copy/induction patterns => learnable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        probs = 1.0 / np.arange(1, cfg.vocab_size + 1) ** 1.1
        self._probs = probs / probs.sum()

    def chunk(self, chunk_id: int) -> np.ndarray:
        """One deterministic chunk of tokens: (seq_len + 1,) per sample row."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + chunk_id)
        toks = rng.choice(cfg.vocab_size, size=cfg.seq_len + 1, p=self._probs)
        # induction: repeat a motif so in-context copying is learnable
        mlen = int(rng.integers(4, 12))
        motif = rng.choice(cfg.vocab_size, size=mlen, p=self._probs)
        pos = 0
        while pos + mlen < cfg.seq_len:
            toks[pos:pos + mlen] = motif
            pos += int(rng.integers(mlen, 4 * mlen))
        return toks.astype(np.int32)


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: Dict) -> "PipelineState":
        return cls(step=int(d["step"]))


class DataPipeline:
    """Batch iterator with explicit cursor; assignment is worker-count
    agnostic so elastic rescale replays no data and skips none."""

    def __init__(self, cfg: DataConfig, state: Optional[PipelineState] = None):
        self.cfg = cfg
        self.stream = SyntheticStream(cfg)
        self.state = state or PipelineState()

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        base = self.state.step * cfg.global_batch
        for i in range(cfg.global_batch):
            chunk_id = (base + i) % cfg.n_chunks
            rows.append(self.stream.chunk(chunk_id))
        arr = np.stack(rows)                              # (B, S+1)
        self.state.step += 1
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:].copy()}

    # -- elastic view: per-worker shard of the global batch ----------------
    def worker_slice(self, batch: Dict[str, np.ndarray], worker: int,
                     n_workers: int) -> Dict[str, np.ndarray]:
        assert self.cfg.global_batch % n_workers == 0
        per = self.cfg.global_batch // n_workers
        sl = slice(worker * per, (worker + 1) * per)
        return {k: v[sl] for k, v in batch.items()}
