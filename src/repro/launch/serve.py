"""Serving launcher: batched prefill + decode over a sharded cache.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2_3b \
        --smoke --batch 4 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from ..configs import get_config, get_smoke
    from ..models import decode_step, init_cache, init_model
    from ..models.model import encdec_prepare, prefill

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    max_len = args.prompt_len + args.gen
    toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, args.batch, max_len)
    extras = {}
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (args.batch, cfg.encoder_seq,
                                         cfg.d_model)) * 0.1
        enc, cross = encdec_prepare(params, cfg, frames)
        extras["enc"] = enc
        cache["decoder"]["cross"] = cross
    step = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l, extras))
    # teacher-forced prefill via the decode path keeps the cache exact for
    # every family (attention, SSM state, hybrid) without a pad/copy step
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = step(params, toks[:, i:i + 1], cache, jnp.int32(i))
    tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1)
    out = [tok]
    for i in range(args.gen - 1):
        logits, cache = step(params, tok, cache,
                             jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1)
        out.append(tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, 1))
    print(f"{cfg.name}: generated {gen.shape} in {dt:.1f}s "
          f"({args.batch*(args.prompt_len+args.gen)/dt:.1f} tok/s)")
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
