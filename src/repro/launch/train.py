"""Training launcher: config -> mesh -> (optionally OASiS-planned) run.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2_3b \
        --smoke --steps 50 [--elastic]

On this CPU container only smoke configs are runnable; full configs are
exercised through dryrun.py.  On a real cluster the same entry point is
used with jax.distributed initialized by the pod launcher.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--elastic", action="store_true",
                    help="drive worker counts from an OASiS schedule")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    from ..configs import get_config, get_smoke
    from ..data.pipeline import DataConfig, DataPipeline
    from ..models import init_model
    from ..train.optimizer import OptConfig, init_opt
    from ..train.steps import TrainHyper, make_train_step
    from .mesh import make_host_mesh

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg.validate()
    mesh = make_host_mesh(data=len(jax.devices()))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    hyper = TrainHyper(grad_compress=args.compress_grads)
    fn, in_sh, out_sh = make_train_step(cfg, mesh, opt_cfg, hyper)
    step = jax.jit(fn)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt(params, opt_cfg)
    pipe = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq,
                                   global_batch=args.batch))
    from ..ckpt.checkpoint import AsyncCheckpointer
    saver = AsyncCheckpointer(args.ckpt)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq,
                                         cfg.d_model), jnp.float32)
        if cfg.n_patches:
            batch["patch_embeds"] = jnp.zeros((args.batch, cfg.n_patches,
                                               cfg.d_model), jnp.float32)
        params, opt, metrics = step(params, opt, batch)
        if (i + 1) % 10 == 0:
            print(f"step {i+1:4d} ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
        if (i + 1) % 25 == 0:
            saver.save_async(i + 1, {"params": params, "opt": opt},
                             extra={"pipeline": pipe.state.to_dict()})
    saver.wait()
    print("done")


if __name__ == "__main__":
    main()
