import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract roofline inputs.

For each cell:
  * train shapes  -> pjit(train_step)   .lower(params, opt, batch).compile()
  * prefill shape -> pjit(prefill_step) .lower(params, batch).compile()
  * decode shapes -> pjit(decode_step)  .lower(params, tok, cache, len).compile()

Everything is ShapeDtypeStruct — no arrays are allocated.  Results
(memory analysis, cost analysis, per-collective byte counts parsed from
the optimized HLO) are written to experiments/dryrun/*.json for
benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out DIR]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCHS, cells, get_config, norm_name
from ..models.config import ModelConfig
from ..models.layers import shapes_tree
from ..models.model import model_specs
from ..models import model_axes
from .mesh import make_production_mesh

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}


def _parse_bytes(type_str: str) -> int:
    """Sum byte sizes of all tensor shapes in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind output bytes summed over ops in optimized HLO."""
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = (.*?) (" + "|".join(COLLECTIVES)
                     + r")[\-a-z]*\(", line)
        if m:
            ty, kind = m.group(1), m.group(2)
            out[kind] += _parse_bytes(ty)
            out["count"] += 1
    return out


def params_shape_structs(cfg: ModelConfig):
    from ..models.layers import P, is_spec
    dt = jnp.dtype(cfg.param_dtype)

    def one(p):
        return jax.ShapeDtypeStruct(p.shape, dt)

    return jax.tree_util.tree_map(one, model_specs(cfg),
                                  is_leaf=is_spec)


def lower_cell(cfg: ModelConfig, shape_name: str, seq: int, gbatch: int,
               kind: str, mesh, accum: int = 1) -> dict:
    from ..train.optimizer import OptConfig, OptState
    from ..train.steps import input_specs, make_train_step
    from ..serve.steps import decode_input_specs, make_decode_step, \
        make_prefill_step
    from ..parallel.sharding import cache_shardings, \
        param_shardings
    from jax.sharding import NamedSharding, PartitionSpec

    p_structs = params_shape_structs(cfg)
    p_shard = param_shardings(model_axes(cfg), shapes_tree(model_specs(cfg)),
                              mesh)
    repl = NamedSharding(mesh, PartitionSpec())

    def in_batch_shard(tree):
        """Shard dim0 (global batch) when divisible, else replicate."""
        from ..parallel.sharding import logical_rules, _axis_size
        rules = logical_rules(mesh)
        ax = rules["batch"]

        def one(s):
            if s.shape and s.shape[0] % _axis_size(mesh, ax) == 0:
                return NamedSharding(mesh, PartitionSpec(
                    ax if len(ax) > 1 else ax[0],
                    *([None] * (len(s.shape) - 1))))
            return repl
        return jax.tree_util.tree_map(one, tree)

    t0 = time.time()
    if kind == "train":
        from ..train.steps import TrainHyper
        opt_cfg = OptConfig(moment_dtype="bfloat16"
                            if cfg.param_dtype == "bfloat16" else "float32")
        step, in_sh, out_sh = make_train_step(cfg, mesh, opt_cfg,
                                              TrainHyper(grad_accum=accum))
        mdt = jnp.dtype(opt_cfg.moment_dtype)
        opt_structs = OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_structs),
            nu=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_structs))
        batch = input_specs(cfg, seq, gbatch, "train")
        fn = jax.jit(step, in_shardings=(p_shard, in_sh[1], in_batch_shard(batch)),
                     out_shardings=out_sh, donate_argnums=(0, 1))
        lowered = fn.lower(p_structs, opt_structs, batch)
    elif kind == "prefill":
        step, in_sh, _ = make_prefill_step(cfg, mesh, gbatch, seq)
        batch = input_specs(cfg, seq, gbatch, "prefill")
        fn = jax.jit(step, in_shardings=(p_shard, in_batch_shard(batch)))
        lowered = fn.lower(p_structs, batch)
    else:  # decode
        step, in_sh, out_sh, c_shapes = make_decode_step(cfg, mesh, gbatch, seq)
        tok, cache, extras = decode_input_specs(cfg, gbatch, seq)
        c_shard = cache_shardings(c_shapes, mesh)
        fn = jax.jit(step,
                     in_shardings=(p_shard, in_batch_shard(tok), c_shard, repl,
                                   in_batch_shard(extras)),
                     donate_argnums=(2,))
        lowered = fn.lower(p_structs, tok, cache,
                           jax.ShapeDtypeStruct((), jnp.int32), extras)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.devices.size
    result = {
        "arch": cfg.name, "shape": shape_name, "kind": kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "seq": seq, "global_batch": gbatch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        },
    }
    return result


def probe_variants(cfg: ModelConfig):
    """Small same-structure configs for scan-body cost extrapolation.

    XLA's cost analysis counts a while/scan body ONCE (not x trip count),
    so per-cell FLOPs/bytes/collectives are recovered by solving the
    linear model  cost = a + sum_g b_g * n_g  from #groups+1 probe
    compiles (exact for homogeneous stacks).  Probe variants run with
    ``unroll=True`` so every layer is counted.  Returns
    (variants=[(label, cfg, counts)], full_counts)."""
    cfg = cfg.scaled(unroll=True)
    out = []
    if cfg.family == "encdec":
        full = {"encoder": cfg.n_encoder_layers, "decoder": cfg.n_layers}
        out.append(("p0", cfg.scaled(n_encoder_layers=1, n_layers=1),
                    {"encoder": 1, "decoder": 1}))
        out.append(("pe", cfg.scaled(n_encoder_layers=2, n_layers=1),
                    {"encoder": 2, "decoder": 1}))
        out.append(("pd", cfg.scaled(n_encoder_layers=1, n_layers=2),
                    {"encoder": 1, "decoder": 2}))
    elif cfg.family == "hybrid":
        per = cfg.hybrid_period
        full = {"periods": cfg.n_layers // per,
                "tail": cfg.n_layers - (cfg.n_layers // per) * per}
        out.append(("p0", cfg.scaled(n_layers=per + 1),
                    {"periods": 1, "tail": 1}))
        out.append(("pp", cfg.scaled(n_layers=2 * per + 1),
                    {"periods": 2, "tail": 1}))
        out.append(("pt", cfg.scaled(n_layers=per + 2),
                    {"periods": 1, "tail": 2}))
    elif cfg.use_mla and cfg.n_dense_layers:
        full = {"dense": cfg.n_dense_layers,
                "moe": cfg.n_layers - cfg.n_dense_layers}
        out.append(("p0", cfg.scaled(n_dense_layers=1, n_layers=2),
                    {"dense": 1, "moe": 1}))
        out.append(("pd", cfg.scaled(n_dense_layers=2, n_layers=3),
                    {"dense": 2, "moe": 1}))
        out.append(("pm", cfg.scaled(n_dense_layers=1, n_layers=3),
                    {"dense": 1, "moe": 2}))
    elif cfg.local_global:
        full = {"pairs": cfg.n_layers // 2}
        out.append(("p0", cfg.scaled(n_layers=2), {"pairs": 1}))
        out.append(("p1", cfg.scaled(n_layers=4), {"pairs": 2}))
    else:
        full = {"layers": cfg.n_layers}
        out.append(("p0", cfg.scaled(n_layers=1), {"layers": 1}))
        out.append(("p1", cfg.scaled(n_layers=2), {"layers": 2}))
    return out, full


def run_probes(args, meshes, out_dir: Path) -> None:
    import numpy as np
    keys = ["flops", "bytes_accessed"]
    for arch in ARCHS:
        if args.arch and norm_name(args.arch) != arch:
            continue
        cfg = get_config(arch)
        variants, full = probe_variants(cfg)
        groups = sorted(full)
        for shape_name, seq, gbatch, kind in cells(arch):
            if args.shape and args.shape != shape_name:
                continue
            for mesh_name, mesh in meshes:
                tag = f"{arch}_{shape_name}_{mesh_name}"
                try:
                    rows, rhs = [], []
                    coll_rhs = []
                    for label, vcfg, counts in variants:
                        r = lower_cell(vcfg, shape_name, seq, gbatch, kind,
                                       mesh)
                        rows.append([1.0] + [float(counts[g]) for g in groups])
                        rhs.append([r["flops"], r["bytes_accessed"]])
                        coll_rhs.append([float(r["collectives"][c])
                                         for c in COLLECTIVES])
                    A = np.array(rows)
                    sol, *_ = np.linalg.lstsq(A, np.array(rhs), rcond=None)
                    csol, *_ = np.linalg.lstsq(A, np.array(coll_rhs),
                                               rcond=None)
                    fullvec = np.array([1.0] + [float(full[g]) for g in groups])
                    corr = fullvec @ sol
                    ccorr = np.maximum(fullvec @ csol, 0.0)
                    out = {
                        "arch": cfg.name, "shape": shape_name,
                        "mesh_name": mesh_name,
                        "flops_corrected": float(corr[0]),
                        "bytes_corrected": float(corr[1]),
                        "collectives_corrected": {
                            c: float(v) for c, v in zip(COLLECTIVES, ccorr)},
                    }
                    (out_dir / f"{tag}.probe.json").write_text(
                        json.dumps(out, indent=1))
                    print(f"PROBE {tag:46s} flops={corr[0]:.3e} "
                          f"bytes={corr[1]:.3e}", flush=True)
                except Exception as e:  # noqa: BLE001
                    print(f"PROBE-FAIL {tag}: {type(e).__name__}: {e}",
                          flush=True)
                    (out_dir / f"{tag}.probe.err").write_text(
                        traceback.format_exc())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--probes", action="store_true",
                    help="run scan-body cost extrapolation probes")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches for train cells")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    if args.probes:
        run_probes(args, meshes, out_dir)
        return

    n_ok = n_fail = 0
    for arch in ARCHS:
        if args.arch and norm_name(args.arch) != arch:
            continue
        cfg = get_config(arch)
        for shape_name, seq, gbatch, kind in cells(arch):
            if args.shape and args.shape != shape_name:
                continue
            for mesh_name, mesh in meshes:
                tag = f"{arch}_{shape_name}_{mesh_name}"
                path = out_dir / f"{tag}.json"
                try:
                    res = lower_cell(cfg, shape_name, seq, gbatch, kind, mesh,
                                     accum=args.accum)
                    path.write_text(json.dumps(res, indent=1))
                    mb = res["memory"]
                    per_dev = (mb["argument_bytes"] + mb["temp_bytes"] +
                               max(0, mb["output_bytes"] - mb["alias_bytes"]))
                    print(f"OK   {tag:48s} compile={res['compile_s']:7.1f}s "
                          f"flops={res['flops']:.3e} "
                          f"mem/dev~{per_dev/2**30:.2f}GiB "
                          f"coll={res['collectives']['count']}", flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001 — report and continue
                    n_fail += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                    (out_dir / f"{tag}.err").write_text(traceback.format_exc())
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
