"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis only
carries data parallelism (gradient all-reduce over DCI), model/expert
parallelism stays within a pod's ICI domain.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (device count is locked at first use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))
