"""Serving steps: prefill and decode with sharded KV caches.

``make_decode_step`` / ``make_prefill_step`` return (fn, in_shardings,
out_shardings) for pjit — consumed by the serving driver and the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig
from ..models.layers import shapes_tree
from ..models.model import model_specs
from ..models import model_axes
from ..parallel.sharding import (batch_sharding, cache_shardings,
                                 param_shardings)


def serve_param_shardings(cfg: ModelConfig, mesh: Mesh):
    return param_shardings(model_axes(cfg), shapes_tree(model_specs(cfg)), mesh)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree for the decode cache (no allocation)."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))
    return shapes


def make_decode_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    p_shard = serve_param_shardings(cfg, mesh)
    b_shard = batch_sharding(mesh)
    repl = NamedSharding(mesh, PartitionSpec())
    c_shapes = cache_specs(cfg, batch, max_len)
    c_shard = cache_shardings(c_shapes, mesh)

    extras_shard = {}
    if cfg.family == "encdec":
        extras_shard["enc"] = b_shard

    def step(params, tokens, cache, cache_len, extras):
        logits, new_cache = decode_step(params, cfg, tokens, cache, cache_len,
                                        extras)
        return logits, new_cache

    in_sh = (p_shard, b_shard, c_shard, repl, extras_shard)
    out_sh = (b_shard, c_shard)
    return step, in_sh, out_sh, c_shapes


def make_cache_constrain(cfg: ModelConfig, mesh: Mesh):
    """Per-layer cache-entry sharding asserted inside the prefill scan:
    batch over dp; KV heads over model when divisible, else the length
    dim (flash-decoding layout) — mirrors ``cache_shardings``."""
    from ..parallel.sharding import _axis_size, logical_rules
    rules = logical_rules(mesh)
    batch_ax = rules["batch"]
    msize = mesh.shape["model"]

    def fn(x):
        if not hasattr(x, "ndim") or x.ndim < 2:
            return x
        spec = [None] * x.ndim
        if x.shape[0] % _axis_size(mesh, batch_ax) == 0:
            spec[0] = batch_ax if len(batch_ax) > 1 else batch_ax[0]
        if x.ndim == 4:        # (B, S, KV, hd)
            if x.shape[2] % msize == 0:
                spec[2] = "model"
            elif x.shape[1] % msize == 0:
                spec[1] = "model"
        elif x.ndim == 3:      # (B, S, r) latent caches
            if x.shape[1] % msize == 0:
                spec[1] = "model"
        import jax
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*spec)))

    return fn


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int):
    import jax
    from ..parallel.sharding import with_batch_constraint
    p_shard = serve_param_shardings(cfg, mesh)
    b_shard = batch_sharding(mesh)
    con_cache = make_cache_constrain(cfg, mesh)

    def con_h(x):
        if x.ndim == 3 and x.shape[1] % mesh.shape["model"] == 0:
            from jax.sharding import NamedSharding, PartitionSpec
            from ..parallel.sharding import logical_rules
            rules = logical_rules(mesh)
            b = rules["batch"] if len(rules["batch"]) > 1 else rules["batch"][0]
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, PartitionSpec(b, "model", None)))
        return with_batch_constraint(x, mesh)

    def step(params, inputs):
        logits, cache = prefill(params, cfg, inputs, seq, constrain=con_h,
                                constrain_cache=con_cache)
        return logits, cache

    in_sh = (p_shard, {"tokens": b_shard} | (
        {"frames": b_shard} if cfg.family == "encdec" else {}) | (
        {"patch_embeds": b_shard} if cfg.n_patches else {}))
    out_sh = None
    return step, in_sh, out_sh


def decode_input_specs(cfg: ModelConfig, batch: int, max_len: int
                       ) -> Tuple[Dict, Any, Dict]:
    sd = jax.ShapeDtypeStruct
    tokens = sd((batch, 1), jnp.int32)
    cache = cache_specs(cfg, batch, max_len)
    extras = {}
    if cfg.family == "encdec":
        extras["enc"] = sd((batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return tokens, cache, extras
