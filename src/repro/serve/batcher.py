"""Continuous batching for decode serving.

Requests arrive online (like the paper's jobs); the batcher keeps a
fixed-width decode batch full by swapping finished rows for queued
requests at step granularity.  Rows are independent in the KV cache —
a released row's slots are overwritten by the next request's prefill
(teacher-forced through the decode path, which keeps every family's
cache semantics exact: attention K/V, MLA latents, SSM states).

This is the serving analogue of the paper's elastic worker allocation:
slot occupancy is the resource, per-request utility is latency-shaped.

Row isolation: attention/MLA caches are masked by each row's own length,
so stale entries beyond the cursor are invisible and rows can be reused
without clearing (verified in tests/test_batcher.py against solo
decoding).  SSM/hybrid rows additionally need their recurrent state
zeroed on admit — pass a reset hook for those families.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (P,) int32
    max_new: int
    arrived_step: int = 0
    # filled by the batcher
    output: Optional[List[int]] = None
    started_step: int = -1
    finished_step: int = -1


@dataclasses.dataclass
class _Row:
    req: Optional[Request] = None
    pos: int = 0                   # next cache position for this row
    prompt_left: int = 0


class ContinuousBatcher:
    """Drives decode_step with per-row request management.

    decode_fn(tokens (B,1), cache, cache_len (B,)) -> (logits, cache).
    The per-row cache length is handled via per-row positions: tokens are
    written at each row's own offset — realized by running rows at a
    common step index but masking finished rows (simple, correct for the
    row-independent caches used here).
    """

    def __init__(self, batch: int, max_len: int, decode_fn: Callable,
                 eos_id: int = -1):
        self.batch = batch
        self.max_len = max_len
        self.decode_fn = decode_fn
        self.eos_id = eos_id
        self.rows = [_Row() for _ in range(batch)]
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.step_no = 0

    def submit(self, req: Request) -> None:
        req.arrived_step = self.step_no
        self.queue.append(req)

    def _admit(self) -> None:
        for row in self.rows:
            if row.req is None and self.queue:
                req = self.queue.pop(0)
                req.output = []
                req.started_step = self.step_no
                row.req = req
                row.pos = 0
                row.prompt_left = len(req.prompt)

    @property
    def active(self) -> int:
        return sum(r.req is not None for r in self.rows)

    def step(self, cache, pad_token: int = 0):
        """One global decode step; returns (cache, finished this step)."""
        self._admit()
        toks = np.full((self.batch, 1), pad_token, np.int32)
        for i, row in enumerate(self.rows):
            if row.req is None:
                continue
            if row.prompt_left > 0:     # teacher-forced prefill
                toks[i, 0] = row.req.prompt[len(row.req.prompt) -
                                            row.prompt_left]
            elif row.req.output:
                toks[i, 0] = row.req.output[-1]
            else:
                toks[i, 0] = row.req.prompt[-1]
        positions = np.array([r.pos for r in self.rows], np.int32)
        logits, cache = self.decode_fn(jnp.asarray(toks), cache,
                                       jnp.asarray(positions))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        finished = []
        for i, row in enumerate(self.rows):
            if row.req is None:
                continue
            row.pos += 1
            if row.prompt_left > 1:
                row.prompt_left -= 1
                continue
            if row.prompt_left == 1:
                row.prompt_left = 0     # prompt consumed; first output next
            row.req.output.append(int(nxt[i]))
            done = (len(row.req.output) >= row.req.max_new
                    or int(nxt[i]) == self.eos_id
                    or row.pos >= self.max_len - 1)
            if done:
                row.req.finished_step = self.step_no
                finished.append(row.req)
                self.done.append(row.req)
                row.req = None
        self.step_no += 1
        return cache, finished

    def run(self, cache, max_steps: int = 10000):
        while (self.queue or self.active) and self.step_no < max_steps:
            cache, _ = self.step(cache)
        return cache
