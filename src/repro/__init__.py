"""repro - OASiS online ML-cluster scheduling + multi-pod JAX training framework."""

__version__ = "0.1.0"
