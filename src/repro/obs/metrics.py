"""Process-local metrics registry: counters, gauges, histograms.

Zero-dependency companion to :mod:`repro.obs.trace`.  A
:class:`Registry` is a plain dict-backed accumulator — no background
threads, no exporters — whose whole state round-trips through
``snapshot()`` / ``reset()``.  Metric names are dotted strings
(``"price.device_uploads"``); the catalog the repro engine emits is
documented in ``docs/OBSERVABILITY.md``.

Histograms use fixed buckets: the upper edges are pinned at first
``observe()`` (or pre-declared via :meth:`Registry.histogram`) and a
``+Inf`` overflow bucket is always implied, so merging or diffing two
snapshots never has to reconcile edge sets.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

# default histogram edges: exponential, centred on the sub-ms..minutes
# range the decision/latency observations live in (seconds)
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-5, 1e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0, 60.0)


class Histogram:
    """Fixed-bucket histogram with count/sum, Prometheus-style."""

    __slots__ = ("edges", "counts", "count", "sum")

    def __init__(self, edges: Sequence[float] = DEFAULT_BUCKETS):
        if list(edges) != sorted(edges):
            raise ValueError(f"histogram edges must be sorted: {edges!r}")
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts: List[int] = [0] * (len(self.edges) + 1)  # +Inf overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        lo, hi = 0, len(self.edges)
        while lo < hi:                      # first edge >= v
            mid = (lo + hi) // 2
            if self.edges[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1

    def to_dict(self) -> dict:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "count": self.count, "sum": self.sum}


class Registry:
    """Process-local counters + gauges + histograms.

    All mutators are O(1) dict operations; ``snapshot()`` returns plain
    JSON-serialisable data (safe to embed in a bench record or a
    Chrome-trace export) and ``reset()`` zeroes everything in place.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- mutators ------------------------------------------------------
    def inc(self, name: str, n: float = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def histogram(self, name: str,
                  edges: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        """Pre-declare (or fetch) a histogram with explicit edges."""
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(edges)
        return h

    def observe(self, name: str, value: float) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        h.observe(value)

    # -- accessors -----------------------------------------------------
    def counter(self, name: str) -> float:
        return self._counters.get(name, 0)

    def gauge(self, name: str) -> Optional[float]:
        return self._gauges.get(name)

    def snapshot(self) -> dict:
        """JSON-serialisable view of the whole registry."""
        return {
            "counters": dict(self._counters),
            "gauges": dict(self._gauges),
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._hists.items())},
        }

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()

    def validate(self) -> List[str]:
        """Return a list of problems (non-finite values); empty if clean."""
        bad = []
        for name, v in self._counters.items():
            if not math.isfinite(v):
                bad.append(f"counter {name} is {v!r}")
        for name, v in self._gauges.items():
            if not math.isfinite(v):
                bad.append(f"gauge {name} is {v!r}")
        return bad
