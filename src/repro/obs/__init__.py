"""Flight-recorder observability: structured tracing + metrics.

Zero-dependency, disabled by default.  The hot paths in the engine
guard every emission behind the module-level :data:`ENABLED` flag::

    from .. import obs as _obs
    ...
    if _obs.ENABLED:
        _obs.inc("price.device_uploads")
    with _obs.span("repack", t=t):
        ...

When no :class:`Obs` is active, ``span()`` hands back a shared no-op
singleton and the counter helpers return immediately — no allocation,
no dict lookups — so instrumented code paths stay bit-identical and
within noise of the uninstrumented build (pinned by
``tests/test_obs.py`` and the decision bench).

Activation is scoped: ``engine.run(..., obs=ob)`` installs ``ob`` for
the duration of the run via :func:`activate`, restoring the previous
state on exit; :func:`enable` installs a process-global recorder for
CLI use (``examples/cluster_sim.py --trace out.json``).  See
``docs/OBSERVABILITY.md`` for the span/metric catalog.
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterator, Optional, Union

from .metrics import DEFAULT_BUCKETS, Histogram, Registry
from .trace import NULL_SPAN, NullSpan, Span, Tracer

__all__ = [
    "ENABLED", "Obs", "activate", "current", "disable", "enable",
    "event", "inc", "observe", "set_gauge", "span",
    "DEFAULT_BUCKETS", "Histogram", "Registry",
    "NULL_SPAN", "NullSpan", "Span", "Tracer",
]

# single check the hot paths read before touching anything else.  True
# exactly while a recorder is installed (scoped or global).
ENABLED: bool = False

_CURRENT: Optional["Obs"] = None


class Obs:
    """One tracer + one metrics registry, recorded together."""

    def __init__(self, capacity: int = 65536):
        self.tracer = Tracer(capacity=capacity)
        self.metrics = Registry()

    def export_chrome(self, path: str) -> int:
        """Chrome-trace file with the metrics snapshot embedded."""
        return self.tracer.export_chrome(
            path, metrics=self.metrics.snapshot())

    def reset(self) -> None:
        self.tracer.clear()
        self.metrics.reset()


def current() -> Optional[Obs]:
    return _CURRENT


def enable(ob: Optional[Obs] = None, capacity: int = 65536) -> Obs:
    """Install ``ob`` (or a fresh recorder) process-globally."""
    global _CURRENT, ENABLED
    _CURRENT = ob if ob is not None else Obs(capacity=capacity)
    ENABLED = True
    return _CURRENT


def disable() -> None:
    global _CURRENT, ENABLED
    _CURRENT = None
    ENABLED = False


@contextlib.contextmanager
def activate(ob: Optional[Obs]) -> Iterator[Optional[Obs]]:
    """Scoped install: ``with activate(ob): ...``.

    ``activate(None)`` is a no-op passthrough so call sites can thread
    an optional ``obs=`` parameter without branching."""
    global _CURRENT, ENABLED
    if ob is None:
        yield _CURRENT
        return
    prev = _CURRENT
    _CURRENT = ob
    ENABLED = True
    try:
        yield ob
    finally:
        _CURRENT = prev
        ENABLED = prev is not None


# -- hot-path helpers (no-ops unless ENABLED) --------------------------

def span(name: str, **attrs: Any) -> Union[Span, NullSpan]:
    ob = _CURRENT
    if ob is None:
        return NULL_SPAN
    return ob.tracer.span(name, **attrs)


def event(name: str, **attrs: Any) -> None:
    ob = _CURRENT
    if ob is not None:
        ob.tracer.instant(name, **attrs)


def inc(name: str, n: float = 1) -> None:
    ob = _CURRENT
    if ob is not None:
        ob.metrics.inc(name, n)


def observe(name: str, value: float) -> None:
    ob = _CURRENT
    if ob is not None:
        ob.metrics.observe(name, value)


def set_gauge(name: str, value: float) -> None:
    ob = _CURRENT
    if ob is not None:
        ob.metrics.set_gauge(name, value)
