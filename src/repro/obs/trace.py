"""Nestable wall-clock spans in a bounded in-memory ring.

A :class:`Tracer` records *complete* span events — name, start, wall
duration, nesting depth, free-form attributes — into a ``deque`` ring
(oldest events are dropped once ``capacity`` is hit; ``dropped`` counts
the loss, so an export is never silently partial).  Timestamps come
from ``time.perf_counter_ns`` relative to the tracer's construction,
which keeps them monotone and immune to wall-clock steps.

Two export formats:

* :meth:`Tracer.export_jsonl` — one JSON object per line, trivially
  greppable / ``pandas.read_json(lines=True)``-able.
* :meth:`Tracer.export_chrome` — the Chrome-trace / Perfetto
  ``traceEvents`` array (``ph: "X"`` complete events, microsecond
  units).  Open the file at https://ui.perfetto.dev or
  ``chrome://tracing``.  Extra top-level keys are legal in the format,
  so a metrics snapshot can ride along in the same file.

Spans are re-entrant per-thread in the trivial sense (a per-tracer
depth counter tracks lexical nesting); the engine is single-threaded,
so no locking is attempted.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional


class Span:
    """Context manager recording one complete event on exit.

    ``set(**attrs)`` attaches attributes discovered mid-span (e.g. how
    many slots a fast-forward actually skipped)."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = 0
        self._depth = 0

    def set(self, **attrs: Any) -> None:
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        tr = self._tracer
        self._depth = tr._depth
        tr._depth += 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter_ns()
        tr = self._tracer
        tr._depth -= 1
        tr._record(self.name, self._t0, t1 - self._t0, self._depth,
                   self.attrs)


class NullSpan:
    """Shared no-op stand-in returned while tracing is disabled."""

    __slots__ = ()

    def set(self, **attrs: Any) -> None:
        pass

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


NULL_SPAN = NullSpan()


class Tracer:
    """Bounded ring of finished spans + instant events."""

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self.dropped = 0
        self._depth = 0
        self._epoch_ns = time.perf_counter_ns()

    # -- recording -----------------------------------------------------
    def span(self, name: str, **attrs: Any) -> Span:
        return Span(self, name, attrs or None)

    def instant(self, name: str, **attrs: Any) -> None:
        """Record a zero-duration marker (Chrome-trace ``ph: "i"``)."""
        self._record(name, time.perf_counter_ns(), None, self._depth,
                     attrs or None)

    def _record(self, name: str, t0_ns: int, dur_ns: Optional[int],
                depth: int, attrs: Optional[Dict[str, Any]]) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(
            (name, t0_ns - self._epoch_ns, dur_ns, depth, attrs))

    # -- access / export ----------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> Iterator[dict]:
        """Yield recorded events as dicts (microsecond floats)."""
        for name, rel_ns, dur_ns, depth, attrs in list(self._events):
            ev = {"name": name, "ts_us": rel_ns / 1e3,
                  "dur_us": None if dur_ns is None else dur_ns / 1e3,
                  "depth": depth}
            if attrs:
                ev["args"] = attrs
            yield ev

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0

    def export_jsonl(self, path: str) -> int:
        """Write one JSON object per event; returns the event count."""
        n = 0
        with open(path, "w") as fh:
            for ev in self.events():
                fh.write(json.dumps(ev, default=str) + "\n")
                n += 1
        return n

    def chrome_events(self) -> List[dict]:
        """Events in Chrome-trace ``traceEvents`` form."""
        pid = os.getpid()
        tid = threading.get_ident() % 10000
        out = []
        for name, rel_ns, dur_ns, depth, attrs in list(self._events):
            ev: Dict[str, Any] = {
                "name": name, "cat": "repro",
                "ph": "X" if dur_ns is not None else "i",
                "ts": rel_ns / 1e3, "pid": pid, "tid": tid,
            }
            if dur_ns is not None:
                ev["dur"] = dur_ns / 1e3
            else:
                ev["s"] = "t"          # instant scope: thread
            if attrs:
                ev["args"] = {k: str(v) if not isinstance(
                    v, (int, float, bool, str, type(None))) else v
                    for k, v in attrs.items()}
            out.append(ev)
        return out

    def export_chrome(self, path: str,
                      metrics: Optional[dict] = None) -> int:
        """Write a Perfetto-loadable trace; returns the event count.

        ``metrics`` (a ``Registry.snapshot()``) is embedded as an extra
        top-level key — Chrome-trace viewers ignore unknown keys, and it
        lets one artifact carry both the timeline and the counters."""
        evs = self.chrome_events()
        doc: Dict[str, Any] = {"traceEvents": evs, "displayTimeUnit": "ms"}
        if self.dropped:
            doc["otherData"] = {"dropped_events": self.dropped}
        if metrics is not None:
            doc["metrics"] = metrics
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return len(evs)
