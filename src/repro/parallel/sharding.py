"""Logical-axis -> mesh-axis sharding policy (the one place it lives).

Parallelism map (DP/FSDP/TP/EP/pod):
  batch        -> ("pod", "data")      data parallel across pods and the
                                       data axis (DP)
  embed        -> "data"               parameter fsdp/ZeRO-3 sharding: XLA
                                       all-gathers weights per layer and
                                       reduce-scatters grads (the TPU-native
                                       analogue of the paper's parameter
                                       servers — see DESIGN.md §3)
  heads/kv/mlp/vocab -> "model"        tensor parallel (TP)
  experts      -> "model"              expert parallel (EP)
  layers/lora/state/... -> replicated

A logical dim is sharded only when its size divides the mesh axis product
(e.g. granite's kv=1 stays replicated); this keeps every (arch x mesh)
combination lowerable without per-arch hand-tuning.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def logical_rules(mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    return {
        "batch": batch,
        "embed": ("data",),
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": (),
        "lora": (),
        "layers": (),
        "conv": (),
        "state": (),
        "seq": (),
    }


def _axis_size(mesh: Mesh, names: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[n] for n in names])) if names else 1


def _spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              mesh: Mesh, rules: Dict[str, Tuple[str, ...]]) -> PartitionSpec:
    used = set()
    entries = []
    for dim, ax in zip(shape, axes):
        mesh_axes: Tuple[str, ...] = ()
        if ax is not None:
            mesh_axes = tuple(rules.get(ax, ()))
        # drop if not divisible or mesh axis already consumed by another dim
        if mesh_axes and (any(m in used for m in mesh_axes)
                          or dim % _axis_size(mesh, mesh_axes) != 0):
            mesh_axes = ()
        if mesh_axes:
            used.update(mesh_axes)
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def param_shardings(axes_tree: Dict, shapes_tree: Dict, mesh: Mesh) -> Dict:
    """Build a NamedSharding tree matching the params tree."""
    rules = logical_rules(mesh)

    def one(axes, shape):
        return NamedSharding(mesh, _spec_for(tuple(shape), tuple(axes), mesh, rules))

    return jax.tree_util.tree_map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def batch_sharding(mesh: Mesh) -> NamedSharding:
    rules = logical_rules(mesh)
    return NamedSharding(mesh, PartitionSpec(rules["batch"]))


def with_batch_constraint(x: jax.Array, mesh: Mesh) -> jax.Array:
    rules = logical_rules(mesh)
    spec = PartitionSpec(rules["batch"], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def cache_shardings(cache_tree, mesh: Mesh):
    """Decode caches.  Layout conventions (see models.model.init_cache):
      k/v   : (layers, B, L, KV, hd) -> batch over dp; KV over model if it
              divides, else L over model (flash-decoding split — the
              softmax over the sharded length becomes a tiny all-reduce)
      ckv/kr: (layers, B, L, r)      -> batch over dp, L over model
      state : (layers, B, H, P, N)   -> batch over dp, H over model
      conv  : (layers, B, K-1, C)    -> batch over dp
    Any dim that does not divide its mesh axes falls back to replicated.
    """
    rules = logical_rules(mesh)
    model = rules["heads"]
    batch = rules["batch"]

    def fits(dim, axes):
        return dim % _axis_size(mesh, axes) == 0

    def one(path, x):
        shape = tuple(x.shape)
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        spec = [None] * len(shape)
        if len(shape) >= 2 and fits(shape[1], batch):
            spec[1] = batch if len(batch) > 1 else batch[0]
        if name in ("k", "v") and len(shape) == 5:
            if fits(shape[3], model):
                spec[3] = model[0]
            elif fits(shape[2], model):
                spec[2] = model[0]
        elif name in ("ckv", "kr") and len(shape) == 4:
            if fits(shape[2], model):
                spec[2] = model[0]
        elif name == "state" and len(shape) == 5:
            if fits(shape[2], model):
                spec[2] = model[0]
        return NamedSharding(mesh, PartitionSpec(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
