from .sharding import (batch_sharding, logical_rules, param_shardings,
                       with_batch_constraint)
