from .simulator import SimResult, simulate
from .workload import make_cluster, make_jobs
