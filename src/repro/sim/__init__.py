from . import engine, scenarios
from .engine import SimResult
from .simulator import simulate, simulate_reference
from .workload import make_cluster, make_jobs, stream_jobs

__all__ = ["engine", "scenarios", "SimResult", "simulate",
           "simulate_reference", "make_cluster", "make_jobs", "stream_jobs"]
