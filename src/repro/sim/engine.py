"""Event-driven trace simulator (sim v2).

The v1 simulator (`sim/simulator.py`, kept as ``simulate_reference``) steps
every slot in Python and re-plans / re-accounts per job per slot.  This
engine only enters Python on *events* — arrival bursts, completions,
cancellations — and does everything between events as whole-array numpy
ops over dense per-job state:

* **Reactive baselines** (FIFO/DRF/RRH/Dorm): between two events the
  scheduler's ``step(t)`` output is constant (running jobs keep their
  placement; waiting jobs face unchanged free capacity; DRF/Dorm repack
  deterministically from an unchanged job set), so the engine replans only
  at event slots and fast-forwards work progress with one vectorized
  update: per-job completion slots are ``ceil(remaining / rate)`` over the
  whole live set, and the clock jumps to the earliest completion or the
  next event.  Even at event slots the repack is skipped when the
  scheduler's ``dirty`` flag says the event cannot change the plan (a
  completion with an empty wait queue under FIFO/RRH, a rejected RRH
  arrival): the previous allocation, pruned of departed jobs, is provably
  what ``step`` would return.  The repacks themselves run on the
  vectorized batch-round kernels of ``core/repack.py`` (placement-equal
  to the seed's greedy loops, ``tests/test_repack.py``).
* **OASiS**: schedules are committed at arrival, so arrivals are the only
  plan events; arrival bursts go through the batched (vmapped on
  ``impl="jax"``) ``on_arrivals`` path, per-slot GPU usage is read
  straight off the price-state's allocation tensor
  (``PriceState.gpu_slot_usage``), and capacity feasibility is one
  whole-state comparison (``PriceState.capacity_ok``) instead of a
  per-slot Python walk.  On ``impl="jax"`` the price state is
  device-resident with commits streamed as slot-window adds, so the whole
  run performs O(1) full host↔device syncs (``PriceState.device_uploads``).

On cancellation-free, unperturbed workloads the engine is equivalence-
tested against the v1 loop (utilities, accept/complete counts, completion
slots) in ``tests/test_sim_v2.py``.  Two scenario hooks go beyond v1:

* ``cancellations``: ``{jid: slot}`` — the job departs mid-run at
  ``slot``; its remaining allocation is released (OASiS: prices drop via
  ``PriceState.release``) and it earns no utility.  A slot at/before the
  job's arrival or at/after T is a no-op (the job runs, resp. finishes,
  normally) — identically for every scheduler.
* ``throughput``: ``fn(job, n_workers, slot) -> factor in (0, 1]`` — a
  per-(job, slot) multiplicative work-rate perturbation (e.g. stragglers,
  ``sim/scenarios.py``).  Under perturbation rates vary per slot; if the
  fn declares itself ``stateless`` and provides ``rate_matrix(job,
  n_workers, t0, h)``, the engine precomputes a ``(n_live,
  horizon_chunk)`` rate matrix per plan span and detects completions via
  row cumsums, consuming only the slots up to the earliest completion
  (the discarded suffix is recomputed after the replan — safe exactly
  because the fn is stateless).  Stateful fns (straggler detection) are
  called per (job, slot) in the original order, one slot at a time, still
  vectorized across jobs.  An OASiS job whose committed schedule
  under-delivers its total work is *not* completed and earns nothing.

Both loops are written as *decision generators*: every per-arrival
admission is a decision point that can be handed to an external decider.
``run(..., policy=None)`` consumes the generator internally with each
scheduler's own decisions — that path never yields and is the unchanged
sim-v2 semantics the equivalence suites pin.  ``run(..., policy=fn)``
(or driving :func:`decisions` step by step, as the rl/ env does) yields a
:class:`DecisionPoint` per arrival and applies the answer through the
same machinery: for ``scheduler="learned"`` the action is the per-job
(worker, PS) count or reject; for the named schedulers the action gates
admission while allocation follows the scheduler's own kernels, so a
policy replaying the expert action reproduces ``run`` exactly
(tests/test_rl_env.py).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import (Callable, Dict, Generator, Iterable, List, Optional,
                    Sequence, Tuple)

import numpy as np

from ..core.baselines import BASELINES, Learned, ReactiveScheduler
from ..core.oasis import OASiS
from ..core.pricing import PriceParams, price_params_from_jobs
from ..core.types import ClusterSpec, Job, Schedule, SigmoidUtility
from .fleet import DOWN_LOSSY, UP, FleetState, FleetTrace
from .. import obs as _obslib

ThroughputFn = Callable[[Job, int, int], float]

# slots of look-ahead in DecisionPoint capacity windows (rl/ observations)
DECISION_WINDOW = 8

# default checkpoint cadence for fleet churn, in slots: victims of a lossy
# failure roll back to the last multiple of this on the global clock — the
# slot-level analogue of runtime/driver.py::run_with_restarts(save_every=20)
CKPT_INTERVAL = 20


@dataclasses.dataclass
class SimResult:
    name: str
    total_utility: float
    accepted: int
    completed: int
    n_jobs: int
    completion: Dict[int, int]              # jid -> completion slot
    target_gap: List[float]                 # (t_done - a) - gamma3 per job
    decision_seconds: List[float]
    utilization: float                      # mean worker-pool GPU utilization
    canceled: int = 0                       # jobs departed mid-run (sim v2)
    # fleet churn (sim/fleet.py): preemption events suffered by admitted
    # jobs, and how many of those victims the shrunken fleet could not
    # re-admit (OASiS drops them; reactive baselines re-queue, never drop)
    preempted: int = 0
    preempt_dropped: int = 0
    # worker-pool GPU fraction still alive at the end of the run (1.0 on
    # churn-free runs — see FleetState.live_frac)
    live_frac: float = 1.0
    arrivals: Dict[int, int] = dataclasses.field(default_factory=dict)
    # streaming runs only: host bytes of the price-state's rolling window
    # (the peak-RSS proxy the serving benchmark records); None episodic,
    # 0 for the reactive baselines (they keep no slot-indexed state)
    window_bytes: Optional[int] = None

    def summary(self) -> Dict[str, object]:
        """Episode-level digest: accept/completion rates, latency
        percentiles (completion slot minus arrival), total utility.
        Shared by ``examples/cluster_sim.py`` and the rl/ env's terminal
        info dict; latency stats are ``None`` when nothing completed."""
        lat = np.array([self.completion[j] - self.arrivals[j]
                        for j in self.completion if j in self.arrivals],
                       dtype=float)
        n = max(self.n_jobs, 1)
        return {
            "scheduler": self.name,
            "n_jobs": self.n_jobs,
            "accepted": self.accepted,
            "completed": self.completed,
            "canceled": self.canceled,
            "preempted": self.preempted,
            "preempt_dropped": self.preempt_dropped,
            "live_frac": float(self.live_frac),
            "accept_rate": self.accepted / n,
            "completion_rate": self.completed / n,
            "total_utility": float(self.total_utility),
            "mean_latency": float(lat.mean()) if lat.size else None,
            "p50_latency": float(np.percentile(lat, 50)) if lat.size else None,
            "p95_latency": float(np.percentile(lat, 95)) if lat.size else None,
            "utilization": float(self.utilization),
        }


@dataclasses.dataclass
class DecisionPoint:
    """One per-arrival admission decision, yielded by :func:`decisions`.

    ``expert`` is the action that replays the wrapped scheduler's own
    decision — ``(n_workers, n_ps)`` with ``n_workers == 0`` meaning
    reject.  For plan-ahead OASiS the counts carry no meaning beyond
    admit/reject (the commitment is ``candidate``, Alg. 2's best
    schedule); for the reactive baselines the counts are informational
    (allocation follows the scheduler's own repack) and only
    ``scheduler="learned"`` consumes them literally.

    ``free_frac_workers``/``free_frac_ps`` are (DECISION_WINDOW, R)
    per-slot *free* capacity fractions of each pool over ``[t, t+W)``
    (slots at/after T read 0.0 — there is no capacity past the horizon);
    the reactive baselines' allocation is constant between events, so the
    current snapshot is tiled across the window.
    """

    job: Job
    t: int
    scheduler: str
    expert: Tuple[int, int]
    candidate: Optional[Schedule]
    utility_so_far: float
    n_running: int
    n_waiting: int
    accepted: int
    rejected: int
    free_frac_workers: np.ndarray
    free_frac_ps: np.ndarray
    # fleet churn (sim/fleet.py): fraction of the worker pool's GPU
    # capacity currently alive, and whether this decision re-admits a
    # preempted victim (its remaining work already rescaled).  Both keep
    # their defaults on churn-free runs, so the zero-churn observation
    # stream is unchanged.
    live_frac: float = 1.0
    preempted: bool = False


def _as_counts(action) -> Tuple[int, int]:
    """Normalize a decider's answer to ``(n_workers, n_ps)``; ``n_ps``
    of -1 means "derive the minimum feasible PS count"."""
    if action is None or action is False:
        return 0, -1
    if isinstance(action, (tuple, list, np.ndarray)):
        a = np.asarray(action).ravel()
        return max(int(a[0]), 0), int(a[1]) if a.size > 1 else -1
    return max(int(action), 0), -1


def _free_window(used_w: np.ndarray, used_s: np.ndarray,
                 cluster: ClusterSpec, t: int,
                 t_max: Optional[int] = ...) -> Tuple[np.ndarray, np.ndarray]:
    """(W, R) per-slot free-capacity fractions of both pools from
    per-slot pool-total usage (slots at/after ``t_max`` read 0.0 — no
    capacity past the horizon; ``t_max=None`` means open-ended, the
    streaming mode, and the default reads the episodic ``cluster.T``).
    A (R,) snapshot is tiled across the window (the reactive baselines'
    allocation is constant between events)."""
    W = DECISION_WINDOW
    cap_w = np.maximum(cluster.worker_caps.sum(axis=0), 1e-9)
    cap_s = np.maximum(cluster.ps_caps.sum(axis=0), 1e-9)
    fw = np.zeros((W, cap_w.shape[0]))
    fs = np.zeros((W, cap_s.shape[0]))
    if used_w.ndim == 1:
        used_w = np.tile(used_w, (W, 1))
        used_s = np.tile(used_s, (W, 1))
    fw[:used_w.shape[0]] = np.clip(1.0 - used_w / cap_w, 0.0, 1.0)
    fs[:used_s.shape[0]] = np.clip(1.0 - used_s / cap_s, 0.0, 1.0)
    if t_max is Ellipsis:
        t_max = cluster.T
    if t_max is not None:
        live = max(min(t_max - t, W), 0)
        fw[live:] = 0.0
        fs[live:] = 0.0
    return fw, fs


def _with_quantum(job: Job, quantum: Optional[int]) -> Job:
    """Workload quantization is a DP-granularity knob (``Job.workload``);
    it is applied uniformly here but only the OASiS subroutine reads it —
    reactive baselines schedule by ``total_work_slots``/``num_chunks``,
    which are quantum-independent (asserted in tests/test_sim_v2.py)."""
    if quantum is None:
        return job
    q = quantum if quantum > 0 else max(
        1, math.ceil(job.epochs * job.num_chunks / 1200))
    return dataclasses.replace(job, quantum=q)


def _shift_utility(u: Callable[[float], float],
                   shift: int) -> Callable[[float], float]:
    """Utility of a victim re-admitted ``shift`` slots after its original
    arrival: the engine evaluates durations from the *re-admission* slot,
    so the original ``f(d)`` becomes ``f(d + shift)`` — for the paper's
    sigmoid that is the same curve with the deadline pulled ``shift``
    slots closer.  Shifting always from the original job's utility (not
    the previous shifted copy) keeps repeated preemptions exact."""
    if not shift:
        return u
    if isinstance(u, SigmoidUtility):
        return dataclasses.replace(u, gamma3=u.gamma3 - shift)
    return lambda d, _u=u, _s=shift: _u(d + _s)


def _target_gaps(jmap: Dict[int, Job], completion: Dict[int, int]) -> List[float]:
    gaps = []
    for jid, tdone in completion.items():
        u = jmap[jid].utility
        if getattr(u, "gamma2", 0) > 0:
            gaps.append((tdone - jmap[jid].arrival) - u.gamma3)
    return gaps


def _group_events(jobs: Sequence[Job], cancellations: Optional[Dict[int, int]],
                  T: int) -> Tuple[Dict[int, List[Job]], Dict[int, List[int]]]:
    by_slot: Dict[int, List[Job]] = {}
    arrival = {}
    for j in jobs:
        if j.arrival >= T:          # v1 semantics: never seen by the sim
            continue
        by_slot.setdefault(j.arrival, []).append(j)
        arrival[j.jid] = j.arrival
    cancel_slot: Dict[int, List[int]] = {}
    for jid, c in (cancellations or {}).items():
        # a departure takes effect only for a job already admitted before
        # slot c and still inside the horizon; cancelling at/before arrival
        # or at/after T is a no-op (the job runs, resp. completes, normally)
        if jid in arrival and arrival[jid] < c < T:
            cancel_slot.setdefault(int(c), []).append(jid)
    return by_slot, cancel_slot


def _check_alloc(cluster: ClusterSpec, jmap: Dict[int, Job],
                 alloc: Dict[int, tuple],
                 worker_caps: Optional[np.ndarray] = None,
                 ps_caps: Optional[np.ndarray] = None) -> None:
    """Whole-array capacity feasibility of one allocation snapshot.

    ``worker_caps``/``ps_caps`` override the cluster's static capacities
    with the surviving fleet's effective arrays under churn — down
    servers then have 0-rows, so any placement on them trips the assert."""
    if not alloc:
        return
    wc = cluster.worker_caps if worker_caps is None else worker_caps
    pc = cluster.ps_caps if ps_caps is None else ps_caps
    ids = list(alloc)
    ys = np.stack([alloc[j][0] for j in ids]).astype(float)        # (n, H)
    wres = np.stack([jmap[j].worker_res for j in ids])             # (n, R)
    assert np.all(ys.T @ wres <= wc + 1e-6), \
        "worker capacity violated"
    zs = [(j, alloc[j][1]) for j in ids if alloc[j][1] is not None]
    if zs:
        zmat = np.stack([z for _, z in zs]).astype(float)
        sres = np.stack([jmap[j].ps_res for j, _ in zs])
        assert np.all(zmat.T @ sres <= pc + 1e-6), \
            "PS capacity violated"


def decisions(cluster: ClusterSpec, jobs: Sequence[Job],
              scheduler: str = "oasis",
              params: Optional[PriceParams] = None, impl: str = "fast",
              fixed_workers: int = 8, check: bool = True,
              quantum: Optional[int] = None,
              cancellations: Optional[Dict[int, int]] = None,
              throughput: Optional[ThroughputFn] = None,
              fleet: Optional[FleetTrace] = None,
              ckpt_interval: int = CKPT_INTERVAL
              ) -> Generator[DecisionPoint, object, SimResult]:
    """The engine as a stepwise decision process (the rl/ env's substrate).

    Yields a :class:`DecisionPoint` per arrival; the caller ``send``s the
    action — ``(n_workers, n_ps)``, a bare worker count, or ``None``/0 to
    reject — and the final :class:`SimResult` is the generator's return
    value (``StopIteration.value``).  With a non-empty ``fleet`` trace,
    victim re-admissions are decision points too (``preempted=True``).
    """
    if scheduler == "oasis":
        return _drive_oasis(cluster, jobs, params, impl, check, quantum,
                            cancellations, throughput, decide=True,
                            fleet=fleet, ckpt_interval=ckpt_interval)
    return _drive_reactive(cluster, jobs, scheduler, fixed_workers, check,
                           quantum, cancellations, throughput, decide=True,
                           fleet=fleet, ckpt_interval=ckpt_interval)


def _exhaust(gen) -> SimResult:
    try:
        next(gen)
    except StopIteration as e:
        return e.value
    raise RuntimeError("engine yielded a decision point without a policy")


def run(cluster: ClusterSpec, jobs: Sequence[Job], scheduler: str = "oasis",
        params: Optional[PriceParams] = None, impl: str = "fast",
        fixed_workers: int = 8, check: bool = True,
        quantum: Optional[int] = None,
        cancellations: Optional[Dict[int, int]] = None,
        throughput: Optional[ThroughputFn] = None,
        fleet: Optional[FleetTrace] = None,
        ckpt_interval: int = CKPT_INTERVAL,
        policy: Optional[Callable[[DecisionPoint], object]] = None,
        obs: Optional["_obslib.Obs"] = None
        ) -> SimResult:
    """Drive ``scheduler`` through the trace event-by-event.

    Same contract as the v1 ``simulate`` plus the scenario hooks
    documented in the module docstring.  ``policy`` (required for
    ``scheduler="learned"``) answers each per-arrival decision point —
    see :func:`decisions`; without one the scheduler decides for itself
    on the exact pre-existing code path (no generator yields).
    ``obs`` installs a flight recorder (``repro.obs.Obs``) for the
    duration of the run — spans and counters land in it and tracing is
    torn back down on return; ``None`` (the default) records nothing.

    Example — the same four-job trace under a reactive baseline and
    OASiS (price params derived from the trace when not given)::

        >>> from repro.sim import engine
        >>> from repro.sim.workload import make_cluster, make_jobs
        >>> cluster = make_cluster(T=20, H=3, K=3)
        >>> jobs = make_jobs(4, T=20, seed=0, small=True)
        >>> r = engine.run(cluster, jobs, scheduler="fifo")
        >>> (r.n_jobs, r.accepted, r.completed)
        (4, 4, 4)
        >>> r = engine.run(cluster, jobs, scheduler="oasis")
        >>> r.accepted, r.total_utility > 0
        (4, True)
    """
    if scheduler == "learned" and policy is None:
        raise ValueError(
            "scheduler='learned' needs a policy — pass engine.run(..., "
            "policy=...) (see repro.rl.policy.LearnedDecider) or train one "
            "via repro.rl.train")
    with _obslib.activate(obs):
        if policy is None:
            if scheduler == "oasis":
                return _exhaust(_drive_oasis(cluster, jobs, params, impl,
                                             check, quantum, cancellations,
                                             throughput, decide=False,
                                             fleet=fleet,
                                             ckpt_interval=ckpt_interval))
            return _exhaust(_drive_reactive(cluster, jobs, scheduler,
                                            fixed_workers, check, quantum,
                                            cancellations, throughput,
                                            decide=False, fleet=fleet,
                                            ckpt_interval=ckpt_interval))
        gen = decisions(cluster, jobs, scheduler=scheduler, params=params,
                        impl=impl, fixed_workers=fixed_workers, check=check,
                        quantum=quantum, cancellations=cancellations,
                        throughput=throughput, fleet=fleet,
                        ckpt_interval=ckpt_interval)
        policy_seconds: List[float] = []
        try:
            dp = next(gen)
            while True:
                t0 = time.perf_counter()
                action = policy(dp)
                policy_seconds.append(time.perf_counter() - t0)
                dp = gen.send(action)
        except StopIteration as e:
            result = e.value
            if not result.decision_seconds:  # reactive decide-paths: none
                result.decision_seconds = policy_seconds
            return result


# ---------------------------------------------------------------------------
# OASiS (plan-ahead): arrivals and cancellations are the only events.
# ---------------------------------------------------------------------------

def _oasis_decision_point(osched: OASiS, cluster: ClusterSpec, job: Job,
                          t: int, cand: Optional[Schedule],
                          utility_so_far: float, live_frac: float = 1.0,
                          preempted: bool = False) -> DecisionPoint:
    g_win, v_win = osched.state.alloc_window(t, DECISION_WINDOW)
    fw, fs = _free_window(g_win, v_win, cluster, t)
    n_running = sum(1 for s in osched.accepted.values() if s.finish >= t)
    return DecisionPoint(
        job=job, t=t, scheduler="oasis",
        expert=(1, 0) if cand is not None else (0, 0), candidate=cand,
        utility_so_far=utility_so_far, n_running=n_running, n_waiting=0,
        accepted=len(osched.accepted), rejected=len(osched.rejected),
        free_frac_workers=fw, free_frac_ps=fs,
        live_frac=live_frac, preempted=preempted)


def _x64_run(impl: str, decide: bool):
    """One x64 context held across a whole jax-engine run (CPU only).

    Every ``enable_x64`` entry/exit inside ``best_schedule_fused`` flips
    the thread-local config, and each flip knocks subsequent jit calls
    off their C fast path — milliseconds of python dispatch per
    decision.  Holding one context open makes the per-decision entries
    no-ops (``_x64_context`` short-circuits when x64 is already on)
    without changing any computed value.  Skipped in stepwise
    (``decide``) mode: those generators suspend into caller policy code
    that must not inherit the flag."""
    import contextlib
    if impl != "jax" or decide:
        return contextlib.nullcontext()
    import jax
    if jax.default_backend() != "cpu" or jax.config.jax_enable_x64:
        return contextlib.nullcontext()
    from jax.experimental import enable_x64
    return enable_x64(True)


def _drive_oasis(cluster: ClusterSpec, jobs: Sequence[Job],
                 params: Optional[PriceParams], impl: str, check: bool,
                 quantum: Optional[int],
                 cancellations: Optional[Dict[int, int]],
                 throughput: Optional[ThroughputFn], decide: bool,
                 fleet: Optional[FleetTrace] = None,
                 ckpt_interval: int = CKPT_INTERVAL
                 ) -> Generator[DecisionPoint, object, SimResult]:
    with _x64_run(impl, decide):
        result = yield from _drive_oasis_gen(
            cluster, jobs, params, impl, check, quantum, cancellations,
            throughput, decide, fleet=fleet, ckpt_interval=ckpt_interval)
    return result


def _drive_oasis_gen(cluster: ClusterSpec, jobs: Sequence[Job],
                     params: Optional[PriceParams], impl: str, check: bool,
                     quantum: Optional[int],
                     cancellations: Optional[Dict[int, int]],
                     throughput: Optional[ThroughputFn], decide: bool,
                     fleet: Optional[FleetTrace] = None,
                     ckpt_interval: int = CKPT_INTERVAL
                     ) -> Generator[DecisionPoint, object, SimResult]:
    T = cluster.T
    jmap = {j.jid: j for j in jobs}
    by_slot, cancel_slot = _group_events(jobs, cancellations, T)
    params = params or price_params_from_jobs(jobs, cluster)
    osched = OASiS(cluster, params, impl=impl)

    total_gpu = max(float(cluster.worker_caps[:, 0].sum()), 1e-9)
    canceled: set = set()
    # fleet churn: every churn branch below is gated on a non-empty trace,
    # so the empty-trace run is an exact no-op (tests/test_fleet.py pins
    # bit-identity against the pre-churn engine)
    churn = fleet is not None and bool(fleet)
    fs = FleetState(cluster, fleet) if churn else None
    # current job copy per jid: re-admitted victims are rescaled replicas
    # (work_scale < 1); identical to jmap on churn-free runs
    ljobs = dict(jmap) if churn else jmap
    ck = max(int(ckpt_interval), 1)
    forced_completion: Dict[int, int] = {}
    blocked_gpu = 0.0          # filler GPU-slot area on down servers
    n_preempted = 0
    n_dropped = 0

    slots = set(by_slot) | set(cancel_slot)
    if churn:
        slots |= set(fs.event_slots)
    for t in sorted(slots):
        if churn:
            trans = fs.step(t)
            _cs = _obslib.span("churn_step", t=t, transitions=len(trans))
            _cs.__enter__()
            # recoveries first: restored headroom is visible to this
            # slot's re-admissions and arrivals
            for pool, srv, kind in trans:
                if kind == UP:
                    blocked_gpu -= osched.state.unblock_server(pool, srv, t)
            victims: Dict[int, str] = {}
            for pool, srv, kind in trans:
                if kind == UP:
                    continue
                for jid, sched in osched.accepted.items():
                    if jid in victims or jid in canceled or sched.finish < t:
                        continue
                    alloc = sched.workers if pool == "worker" else sched.ps
                    if any(tt >= t and a[srv] > 0
                           for tt, a in alloc.items()):
                        victims[jid] = kind
            readmit: List[Job] = []
            for jid, kind in victims.items():
                sched = osched.accepted.pop(jid)
                jcur = ljobs[jid]
                tail_w = {tt: y for tt, y in sched.workers.items()
                          if tt >= t}
                tail_z = {tt: z for tt, z in sched.ps.items() if tt >= t}
                osched.state.release(jcur, tail_w, tail_z)
                osched.total_utility -= sched.utility
                n_preempted += 1
                if _obslib.ENABLED:
                    _obslib.inc("engine.preemptions")
                # checkpoint boundary: lossy failures roll back to the
                # last global ckpt_interval multiple, graceful drains
                # checkpoint at drain start (no work lost)
                cb = (t // ck) * ck if kind == DOWN_LOSSY else t
                delivered = sum(float(y.sum())
                                for tt, y in sched.workers.items()
                                if tt < cb)
                rem = jcur.total_work_slots - delivered
                if rem <= 1e-9:
                    # the checkpoint already covers all work: the job is
                    # done as of its last delivering slot, no re-admission
                    done = [tt for tt, y in sched.workers.items()
                            if tt < cb and y.sum() > 0]
                    forced_completion[jid] = max(done) if done \
                        else max(cb - 1, 0)
                    continue
                scale = jcur.work_scale * rem / jcur.total_work_slots
                orig = jmap[jid]
                readmit.append(dataclasses.replace(
                    jcur, arrival=t, work_scale=scale,
                    utility=_shift_utility(orig.utility,
                                           t - orig.arrival)))
            # block AFTER the victims' tails are released (their content
            # is then exactly the fill) and BEFORE re-admission (Alg. 2
            # must not plan onto the dead servers)
            for pool, srv, kind in trans:
                if kind != UP:
                    blocked_gpu += osched.state.block_server(pool, srv, t)
            _cs.set(victims=len(victims), readmits=len(readmit))
            _cs.__exit__(None, None, None)
            for job_r in readmit:
                ljobs[job_r.jid] = job_r
                if decide:
                    cand = osched.propose(job_r)
                    action = yield _oasis_decision_point(
                        osched, cluster, job_r, t, cand,
                        osched.total_utility, live_frac=fs.live_frac,
                        preempted=True)
                    nw, _ = _as_counts(action)
                    sched = osched._resolve(job_r,
                                            cand if nw > 0 else None)
                else:
                    sched = osched.on_arrival(job_r)
                if sched is None:
                    n_dropped += 1
                    if _obslib.ENABLED:
                        _obslib.inc("engine.preempt_dropped")
        for jid in cancel_slot.get(t, ()):
            sched = osched.accepted.get(jid)
            if sched is None or sched.finish < t or jid in canceled:
                # finished / never admitted / already departed — includes
                # victims the shrunken fleet dropped: their commitment is
                # gone, so the cancellation must be (and is) a no-op
                continue
            tail_w = {tt: y for tt, y in sched.workers.items() if tt >= t}
            tail_z = {tt: z for tt, z in sched.ps.items() if tt >= t}
            osched.state.release(ljobs[jid], tail_w, tail_z)
            canceled.add(jid)
        batch = [_with_quantum(job, quantum) for job in by_slot.get(t, ())]
        if churn:
            for job in batch:
                ljobs[job.jid] = job
        if _obslib.ENABLED and batch:
            _obslib.inc("engine.arrivals", len(batch))
        if decide:
            # stepwise: propose at current prices, let the decider gate
            # the commitment.  Sequential per-job decisions are exactly
            # the batched path's semantics (on_arrivals is equivalence-
            # tested against sequential on_arrival), with the external
            # action substituted for Alg. 1's payoff test.
            for job in sorted(batch, key=lambda j: j.arrival):
                cand = osched.propose(job)
                action = yield _oasis_decision_point(
                    osched, cluster, job, t, cand, osched.total_utility,
                    live_frac=fs.live_frac if churn else 1.0)
                nw, _ = _as_counts(action)
                osched._resolve(job, cand if nw > 0 else None)
        elif batch:
            with _obslib.span("arrival_burst", t=t, n=len(batch)):
                osched.on_arrivals(batch)
        if check:
            # whole-state comparison on the price-state's own books — no
            # per-schedule Python walk and no device→host churn on the
            # jax path (the host mirror is maintained incrementally)
            ok_w, ok_ps = osched.state.capacity_ok()
            assert ok_w, "worker capacity violated"
            assert ok_ps, "PS capacity violated"

    completion: Dict[int, int] = {}
    for jid, sched in osched.accepted.items():
        if jid in canceled:
            continue
        if throughput is None:
            completion[jid] = sched.finish
            continue
        # perturbed work accounting over the committed slots (under churn
        # the live copy carries only the post-checkpoint work, and the
        # committed schedule is exactly its final segment)
        job = ljobs[jid]
        slots = sorted(sched.workers)
        w = np.array([float(sched.workers[tt].sum()) for tt in slots])
        f = np.array([throughput(job, int(c), tt)
                      for tt, c in zip(slots, w)])
        cum = np.cumsum(w * f)
        hit = np.flatnonzero(cum >= job.total_work_slots - 1e-9)
        if hit.size:                            # else: under-delivered
            completion[jid] = slots[int(hit[0])]
    completion.update(forced_completion)

    if not canceled and throughput is None and not churn:
        total_utility = osched.total_utility    # bit-exact vs v1
    else:
        # evaluate utility at the *actual* completion slot (under
        # perturbation it can differ from the committed finish; under
        # churn from the re-admission-shifted curve), always against the
        # ORIGINAL job's utility and arrival — matching the reactive
        # path's convention
        total_utility = sum(jmap[jid].utility(tdone - jmap[jid].arrival)
                            for jid, tdone in completion.items())
    # per-slot GPU usage straight off the allocation tensor (commits add,
    # cancellation releases subtract), replacing the per-schedule dict walk
    gpu_slots = osched.state.gpu_slot_usage()
    if churn and T:
        # subtract the capacity-block filler on down servers — it is in
        # the allocation tensor (that is what starves Alg. 2 of headroom)
        # but is not real usage
        utilization = float((gpu_slots.sum() - blocked_gpu)
                            / (total_gpu * T))
    else:
        utilization = float(np.mean(gpu_slots / total_gpu)) if T else 0.0
    return SimResult(name="oasis", total_utility=total_utility,
                     accepted=len(osched.accepted) + len(forced_completion),
                     completed=len(completion),
                     n_jobs=len(jobs), completion=completion,
                     target_gap=_target_gaps(jmap, completion),
                     decision_seconds=osched.decision_seconds,
                     utilization=utilization,
                     canceled=len(canceled),
                     preempted=n_preempted, preempt_dropped=n_dropped,
                     live_frac=fs.live_frac if churn else 1.0,
                     arrivals={j.jid: j.arrival for j in jobs
                               if j.arrival < T})


# ---------------------------------------------------------------------------
# Reactive baselines: replan at events, fast-forward in between.
# ---------------------------------------------------------------------------

# horizon chunk for the stateless-throughput rate matrix (slots per block)
_RATE_BLOCK = 64


def _pool_usage(cur_alloc: Dict[int, tuple], jmap: Dict[int, Job],
                cluster: ClusterSpec) -> Tuple[np.ndarray, np.ndarray]:
    """(R,) total worker/PS-pool usage of one allocation snapshot."""
    used_w = np.zeros(cluster.worker_caps.shape[1])
    used_s = np.zeros(cluster.ps_caps.shape[1])
    for jid, (y, z) in cur_alloc.items():
        used_w += float(y.sum()) * jmap[jid].worker_res
        if z is not None:
            used_s += float(z.sum()) * jmap[jid].ps_res
    return used_w, used_s


def _reactive_decision_point(rsched: ReactiveScheduler, cluster: ClusterSpec,
                             job: Job, t: int, scheduler: str,
                             cur_alloc: Dict[int, tuple],
                             usage: Tuple[np.ndarray, np.ndarray],
                             n_admitted: int,
                             n_rejected: int, n_live: int,
                             utility_so_far: float,
                             t_max: Optional[int] = ...,
                             live_frac: float = 1.0) -> DecisionPoint:
    fw, fs = _free_window(*usage, cluster, t, t_max=t_max)
    admit = rsched.would_admit(job, t)
    nw, nps = rsched._counts(job)
    return DecisionPoint(
        job=job, t=t, scheduler=scheduler,
        expert=(nw, nps) if admit else (0, 0), candidate=None,
        utility_so_far=utility_so_far,
        n_running=len(cur_alloc), n_waiting=n_live - len(cur_alloc),
        accepted=n_admitted, rejected=n_rejected,
        free_frac_workers=fw, free_frac_ps=fs, live_frac=live_frac)


def _drive_reactive(cluster: ClusterSpec, jobs: Sequence[Job], scheduler: str,
                    fixed_workers: int, check: bool, quantum: Optional[int],
                    cancellations: Optional[Dict[int, int]],
                    throughput: Optional[ThroughputFn], decide: bool,
                    fleet: Optional[FleetTrace] = None,
                    ckpt_interval: int = CKPT_INTERVAL
                    ) -> Generator[DecisionPoint, object, SimResult]:
    T = cluster.T
    src = {j.jid: _with_quantum(j, quantum) for j in jobs}
    jmap = dict(src)
    by_slot, cancel_slot = _group_events(src.values(), cancellations, T)
    rsched: ReactiveScheduler = BASELINES[scheduler](
        cluster, fixed_workers=fixed_workers)

    total_gpu = max(float(cluster.worker_caps[:, 0].sum()), 1e-9)
    admitted: List[int] = []
    remaining: Dict[int, float] = {}
    completion: Dict[int, int] = {}
    canceled: set = set()
    total_utility = 0.0
    util_sum = 0.0
    # fleet churn (all branches gated on a non-empty trace — the empty
    # trace is an exact no-op).  ``ckpt_rem`` is each admitted job's
    # remaining work at its last checkpoint: lossy failures roll
    # ``remaining`` back to it, graceful drains refresh it first.
    churn = fleet is not None and bool(fleet)
    fs = FleetState(cluster, fleet) if churn else None
    ckpt_rem: Dict[int, float] = {}
    ck = max(int(ckpt_interval), 1)
    n_preempted = 0
    # reactive per-event replan wall clocks (the repacks) — the
    # apples-to-apples counterpart of OASiS's decision_seconds.  In
    # stepwise (decide) mode the list stays empty so ``run`` can fill it
    # with the caller policy's inference latency instead.
    decision_seconds: List[float] = []

    # ``dirty`` gating: the scheduler tells us whether the last event can
    # change its next repack (arrivals and repack-relevant completions
    # set it; a completion with an empty wait queue under FIFO/RRH or a
    # rejected RRH arrival leaves it unset).  On clean events the engine
    # reuses the previous allocation — pruned of departed jobs — instead
    # of repacking: between events capacity and the job set are unchanged
    # so a fresh ``step`` provably returns the same placements.
    cur_alloc: Dict[int, tuple] = {}
    ids: List[int] = []
    counts = np.zeros(0)
    plan_gpu = 0.0
    stale = True            # derived arrays need a rebuild (alloc changed)
    # stateless throughput fns expose a vectorized per-slot factor matrix;
    # stateful ones (e.g. straggler detection) must be called slot by slot
    use_matrix = (throughput is not None
                  and getattr(throughput, "stateless", False)
                  and callable(getattr(throughput, "rate_matrix", None)))

    event_set = set(by_slot) | set(cancel_slot)
    if churn:
        event_set |= set(fs.event_slots)
    events = sorted(event_set)
    ei = 0
    n_rejected = 0
    t = events[0] if events else T
    while t < T:
        while ei < len(events) and events[ei] <= t:
            ei += 1
        if churn:
            trans = fs.step(t)
            if trans:
                _cs = _obslib.span("churn_step", t=t,
                                   transitions=len(trans))
                _cs.__enter__()
                for pool, srv, kind in trans:
                    if kind == UP:
                        continue
                    if pool == "worker":
                        vs = [jid for jid, (y, _) in cur_alloc.items()
                              if y[srv] > 0]
                    else:
                        vs = [jid for jid, (_, z) in cur_alloc.items()
                              if z is not None and z[srv] > 0]
                    for jid in vs:
                        if kind == DOWN_LOSSY:
                            # crash: work since the last checkpoint lost
                            remaining[jid] = ckpt_rem.get(
                                jid, jmap[jid].total_work_slots)
                        else:
                            # drain: checkpoint taken at drain start
                            ckpt_rem[jid] = remaining[jid]
                        rsched.preempt(jid, t)
                        cur_alloc.pop(jid, None)
                        n_preempted += 1
                        if _obslib.ENABLED:
                            _obslib.inc("engine.preemptions")
                # repack over the survivors: victims stay enrolled, so
                # the scheduler's own queue/resume order re-places them
                rsched.set_capacity(fs.worker_caps, fs.ps_caps)
                stale = True
                _cs.__exit__(None, None, None)
        arrivals_now = by_slot.pop(t, ())
        if _obslib.ENABLED and arrivals_now:
            _obslib.inc("engine.arrivals", len(arrivals_now))
        if decide and arrivals_now:
            # one usage snapshot for the whole arrival burst: admissions
            # do not change the previous allocation until the repack,
            # and cancellations at this slot are processed afterwards
            usage = _pool_usage(cur_alloc, jmap, cluster)
        for job in arrivals_now:
            if decide:
                action = yield _reactive_decision_point(
                    rsched, cluster, job, t, scheduler, cur_alloc, usage,
                    len(admitted), n_rejected, len(remaining), total_utility,
                    live_frac=fs.live_frac if churn else 1.0)
                nw, nps = _as_counts(action)
                if nw <= 0:
                    n_rejected += 1
                    continue
                if isinstance(rsched, Learned):
                    # clamp to the job's own feasibility envelope: at most
                    # N_i concurrent workers (constraint (3)), at least
                    # the bandwidth-matched PS count (constraints (6)(7))
                    nw = min(nw, job.num_chunks)
                    nps = max(nps, job.ps_for(nw))
                    rsched.set_counts(job.jid, nw, nps)
                rsched.enroll(job, t)
                admitted.append(job.jid)
                remaining[job.jid] = job.total_work_slots
            elif rsched.on_arrival(job, t):
                admitted.append(job.jid)
                remaining[job.jid] = job.total_work_slots
            else:
                n_rejected += 1
        cancels_now = cancel_slot.get(t, ())
        for jid in cancels_now:
            if jid in remaining:                # admitted, still running
                rsched.on_completion(jid, t)    # drop from pool, no utility
                del remaining[jid]
                canceled.add(jid)
                cur_alloc.pop(jid, None)
                if churn:
                    ckpt_rem.pop(jid, None)
                stale = True
        if rsched.dirty:
            t0_rp = time.perf_counter()
            with _obslib.span("repack", t=t, scheduler=scheduler,
                              n_live=len(remaining)):
                cur_alloc = dict(rsched.step(t))
            if not decide:
                decision_seconds.append(time.perf_counter() - t0_rp)
            rsched.dirty = False
            stale = True
            if check:       # a pruned reuse stays feasible by construction
                if churn:   # ...against the surviving fleet's capacity
                    _check_alloc(cluster, jmap, cur_alloc,
                                 fs.worker_caps, fs.ps_caps)
                else:
                    _check_alloc(cluster, jmap, cur_alloc)
        elif _obslib.ENABLED and (arrivals_now or cancels_now
                                  or (churn and trans)):
            # an event landed but the scheduler proved the last repack
            # still optimal — the engine skipped a full replan
            _obslib.inc("repack.dirty_skips")
        if stale:
            ids = list(cur_alloc)
            counts = np.array([float(cur_alloc[j][0].sum()) for j in ids])
            plan_gpu = float(counts @ np.array(
                [jmap[j].worker_res[0] for j in ids])) if ids else 0.0
            stale = False
        _ff = _obslib.span("ffwd", t=t, n_live=len(ids))
        _ff.__enter__()
        next_ev = events[ei] if ei < len(events) else T
        horizon = min(next_ev, T) - t

        if throughput is None:
            rem = np.array([remaining[j] for j in ids])
            active = counts > 0
            slots_left = np.full(len(ids), np.inf)
            if active.any():
                slots_left[active] = np.maximum(
                    np.ceil((rem[active] - 1e-9) / counts[active]), 1.0)
            span = int(min(float(slots_left.min()) if ids else np.inf,
                           float(horizon)))
            span = max(span, 1)
            consumed = counts * span
        elif use_matrix and ids:
            # whole-block rate matrix: factors for every (live job, slot)
            # in one pass, completion detection via row cumsums; only the
            # slots up to the earliest completion are consumed, the rest
            # are recomputed after the replan (the fn is stateless)
            h = min(horizon, _RATE_BLOCK)
            M = np.empty((len(ids), h))
            for i, jid_ in enumerate(ids):
                M[i] = throughput.rate_matrix(jmap[jid_], int(counts[i]), t, h)
            M *= counts[:, None]
            cum = np.cumsum(M, axis=1)
            rem = np.array([remaining[j] for j in ids])
            hits = cum >= rem[:, None] - 1e-9
            first = np.where(hits.any(axis=1), hits.argmax(axis=1), h)
            k = int(first.min())
            span = k + 1 if k < h else h
            consumed = cum[:, span - 1]
        elif use_matrix:
            span = min(horizon, _RATE_BLOCK)
            consumed = counts                   # no live jobs: empty array
        else:
            # stateful fn: advance one slot, still vectorized across jobs
            consumed = counts * np.array(
                [throughput(jmap[j], int(c), t) for j, c in zip(ids, counts)]) \
                if ids else counts
            span = 1

        util_sum += (plan_gpu / total_gpu) * span
        t_end = t + span - 1                    # last slot run with this plan
        if churn and ids:
            # record the checkpoint crossed inside this span (if any):
            # work is consumed uniformly over the span under the exact
            # rate model, so the boundary's share is (cb - t) / span
            cb = ((t + span) // ck) * ck
            if cb > t:
                frac = (cb - t) / span
                for j, used in zip(ids, consumed):
                    ckpt_rem[j] = max(remaining[j] - float(used) * frac,
                                      0.0)
        done_now = []
        for j, used in zip(ids, consumed):
            remaining[j] -= used
            if remaining[j] <= 1e-9:
                done_now.append(j)
        for jid in done_now:
            completion[jid] = t_end
            total_utility += jmap[jid].utility(t_end - jmap[jid].arrival)
            rsched.on_completion(jid, t_end)
            del remaining[jid]
            cur_alloc.pop(jid, None)
            if churn:
                ckpt_rem.pop(jid, None)
            stale = True
        t += span
        _ff.set(slots=span, completed=len(done_now))
        _ff.__exit__(None, None, None)
        if _obslib.ENABLED:
            _obslib.inc("engine.ffwd_slots", span)
            if done_now:
                _obslib.inc("engine.completions", len(done_now))
    return SimResult(name=scheduler, total_utility=total_utility,
                     accepted=len(admitted), completed=len(completion),
                     n_jobs=len(jobs), completion=completion,
                     target_gap=_target_gaps(jmap, completion),
                     decision_seconds=decision_seconds,
                     utilization=util_sum / T if T else 0.0,
                     canceled=len(canceled), preempted=n_preempted,
                     live_frac=fs.live_frac if churn else 1.0,
                     arrivals={j.jid: j.arrival for j in src.values()
                               if j.arrival < T})


# ---------------------------------------------------------------------------
# Continuous serving mode: open-ended arrival streams over a rolling
# price-state window.  Total simulated time is unbounded — all state is
# O(window) + O(live jobs) + O(decided jobs) dicts; nothing allocates a
# (total-time, ...) array.
# ---------------------------------------------------------------------------

def stream_price_params(sample: Sequence[Job], cluster: ClusterSpec,
                        window: int, floor_frac: float = 0.05) -> PriceParams:
    """U/L price-bound estimates for a streamed run, from a warmup sample.

    The paper's estimator is horizon-relative (worst-case utility at
    ``f_i(T - a_i)``); in serving mode the analogue of the horizon is the
    scheduling window, so the sample is evaluated arrival-free against a
    ``T=window`` view of the cluster — "estimated from past experience"
    (Sec. IV-B), exactly the operator knob Fig. 6 sweeps."""
    view = dataclasses.replace(cluster, T=int(window))
    sample0 = [dataclasses.replace(j, arrival=0) for j in sample]
    return price_params_from_jobs(sample0, view, floor_frac=floor_frac)


def stream_decisions(cluster: ClusterSpec, jobs: Iterable[Job],
                     scheduler: str = "oasis",
                     params: Optional[PriceParams] = None,
                     impl: str = "fast", window: int = 64,
                     fixed_workers: int = 8, check: bool = False,
                     quantum: Optional[int] = None,
                     warmup_sample: int = 256,
                     fleet: Optional[FleetTrace] = None,
                     ckpt_interval: int = CKPT_INTERVAL
                     ) -> Generator[DecisionPoint, object, SimResult]:
    """Streaming analogue of :func:`decisions`.

    ``jobs`` is any iterable (typically ``sim.workload.stream_jobs``)
    yielding jobs in nondecreasing arrival order; it is consumed lazily
    and never materialised.  ``cluster.T`` is ignored as a trace bound —
    the run ends when the iterable does and every admitted job has run to
    completion or provable starvation.  For OASiS the price state keeps a
    ``window``-slot rolling horizon (``PriceState.advance``); decisions
    are made in window-local coordinates (the arriving job is translated
    to arrival 0) and committed slots are translated back to the absolute
    clock, so per-decision cost is O(window), independent of trace
    length.  When ``params`` is omitted they are estimated from the first
    ``warmup_sample`` jobs via :func:`stream_price_params` (the sample is
    replayed, not dropped)."""
    if scheduler == "oasis":
        if params is None:
            it = iter(jobs)
            sample = list(itertools.islice(it, warmup_sample))
            params = stream_price_params(sample, cluster, window)
            jobs = itertools.chain(sample, it)
        return _drive_oasis_stream(cluster, jobs, params, impl, window,
                                   check, quantum, decide=True, fleet=fleet,
                                   ckpt_interval=ckpt_interval)
    return _drive_reactive_stream(cluster, jobs, scheduler, fixed_workers,
                                  check, quantum, decide=True, fleet=fleet,
                                  ckpt_interval=ckpt_interval)


def run_stream(cluster: ClusterSpec, jobs: Iterable[Job],
               scheduler: str = "oasis",
               params: Optional[PriceParams] = None, impl: str = "fast",
               window: int = 64, fixed_workers: int = 8, check: bool = False,
               quantum: Optional[int] = None, warmup_sample: int = 256,
               fleet: Optional[FleetTrace] = None,
               ckpt_interval: int = CKPT_INTERVAL,
               policy: Optional[Callable[[DecisionPoint], object]] = None,
               obs: Optional["_obslib.Obs"] = None
               ) -> SimResult:
    """Drive ``scheduler`` over an open-ended arrival stream.

    The streaming counterpart of :func:`run` — same scheduler kernels,
    same admission semantics, no horizon: completion slots are absolute,
    ``utilization`` is a running aggregate over the elapsed clock, and
    memory stays bounded by the window (``SimResult.window_bytes``).
    ``policy`` answers each decision point as in :func:`run` (required
    for ``scheduler="learned"``).

    Example — a short bounded slice of an open-ended stream through the
    rolling 16-slot price window::

        >>> import itertools
        >>> from repro.sim import engine
        >>> from repro.sim.workload import make_cluster, stream_jobs
        >>> cluster = make_cluster(T=20, H=3, K=3)
        >>> arrivals = itertools.islice(
        ...     stream_jobs(rate=0.5, seed=1, small=True), 12)
        >>> r = engine.run_stream(cluster, arrivals, scheduler="oasis",
        ...                       window=16)
        >>> (r.n_jobs, r.accepted, r.window_bytes is not None)
        (12, 12, True)
    """
    if scheduler == "learned" and policy is None:
        raise ValueError(
            "scheduler='learned' needs a policy — pass engine.run_stream("
            "..., policy=...) (see repro.rl.policy.LearnedDecider) or "
            "train one via repro.rl.train")
    with _obslib.activate(obs):
        if policy is None:
            if scheduler == "oasis":
                if params is None:
                    it = iter(jobs)
                    sample = list(itertools.islice(it, warmup_sample))
                    params = stream_price_params(sample, cluster, window)
                    jobs = itertools.chain(sample, it)
                return _exhaust(_drive_oasis_stream(
                    cluster, jobs, params, impl, window, check, quantum,
                    decide=False, fleet=fleet,
                    ckpt_interval=ckpt_interval))
            return _exhaust(_drive_reactive_stream(
                cluster, jobs, scheduler, fixed_workers, check, quantum,
                decide=False, fleet=fleet, ckpt_interval=ckpt_interval))
        gen = stream_decisions(cluster, jobs, scheduler=scheduler,
                               params=params, impl=impl, window=window,
                               fixed_workers=fixed_workers, check=check,
                               quantum=quantum, warmup_sample=warmup_sample,
                               fleet=fleet, ckpt_interval=ckpt_interval)
        policy_seconds: List[float] = []
        try:
            dp = next(gen)
            while True:
                t0 = time.perf_counter()
                action = policy(dp)
                policy_seconds.append(time.perf_counter() - t0)
                dp = gen.send(action)
        except StopIteration as e:
            result = e.value
            if not result.decision_seconds:
                result.decision_seconds = policy_seconds
            return result


def _drive_oasis_stream(cluster: ClusterSpec, jobs: Iterable[Job],
                        params: PriceParams, impl: str, window: int,
                        check: bool, quantum: Optional[int], decide: bool,
                        fleet: Optional[FleetTrace] = None,
                        ckpt_interval: int = CKPT_INTERVAL
                        ) -> Generator[DecisionPoint, object, SimResult]:
    with _x64_run(impl, decide):
        result = yield from _drive_oasis_stream_gen(
            cluster, jobs, params, impl, window, check, quantum, decide,
            fleet=fleet, ckpt_interval=ckpt_interval)
    return result


def _drive_oasis_stream_gen(cluster: ClusterSpec, jobs: Iterable[Job],
                            params: PriceParams, impl: str, window: int,
                            check: bool, quantum: Optional[int],
                            decide: bool,
                            fleet: Optional[FleetTrace] = None,
                            ckpt_interval: int = CKPT_INTERVAL
                            ) -> Generator[DecisionPoint, object, SimResult]:
    osched = OASiS(cluster, params, impl=impl, window=window)
    state = osched.state
    jmap: Dict[int, Job] = {}
    arrivals: Dict[int, int] = {}
    completion: Dict[int, int] = {}
    # absolute finish of still-running accepted jobs; entries (and their
    # committed Schedule in osched.accepted, which holds local slots that
    # go stale as the window slides) are pruned once the clock passes
    # them, keeping live state O(window-worth of jobs)
    active: Dict[int, int] = {}
    n_accepted = 0
    n_rejected = 0
    n_jobs = 0
    t = 0
    # fleet churn: trace slots are absolute; re-blocks after every
    # advance keep down servers at zero headroom across window slides
    churn = fleet is not None and bool(fleet)
    fs = FleetState(cluster, fleet) if churn else None
    fe: List[int] = fs.event_slots if churn else []
    fi = 0
    ljobs: Dict[int, Job] = {}          # live (quantized/rescaled) copies
    admit_origin: Dict[int, int] = {}   # absolute slot of live commitment
    ck = max(int(ckpt_interval), 1)
    blocked_gpu = 0.0
    n_preempted = 0
    n_dropped = 0
    it = iter(jobs)
    nxt = next(it, None)
    while True:
        ta = int(nxt.arrival) if nxt is not None else None
        tf = fe[fi] if fi < len(fe) else None
        if ta is None and (tf is None or not active):
            break                       # fleet events can't touch anything
        t = ta if (tf is None or (ta is not None and ta <= tf)) else tf
        batch: List[Job] = []
        if ta is not None and ta == t:
            while nxt is not None and int(nxt.arrival) == t:
                batch.append(nxt)
                nxt = next(it, None)
        with _obslib.span("stream_advance", t=t):
            state.advance(t)
        for jid in [j for j, fin in active.items() if fin < t]:
            del active[jid]
            osched.accepted.pop(jid, None)
            admit_origin.pop(jid, None)
            ljobs.pop(jid, None)
        if churn:
            # slots freshly opened by the slide start at zero — refill
            # every currently-down server to caps (idempotent elsewhere)
            for pool, srv in fs.down_servers():
                blocked_gpu += state.block_server(pool, srv, 0)
        if churn and tf is not None and tf == t:
            fi += 1
            trans = fs.step(t)
            _cs = _obslib.span("churn_step", t=t, transitions=len(trans))
            _cs.__enter__()
            for pool, srv, kind in trans:
                if kind == UP:
                    blocked_gpu -= state.unblock_server(pool, srv, 0)
            victims: Dict[int, str] = {}
            for pool, srv, kind in trans:
                if kind == UP:
                    continue
                for jid in active:
                    if jid in victims:
                        continue
                    sched = osched.accepted.get(jid)
                    if sched is None:
                        continue
                    shift = t - admit_origin[jid]
                    alloc = sched.workers if pool == "worker" else sched.ps
                    if any(s >= shift and a[srv] > 0
                           for s, a in alloc.items()):
                        victims[jid] = kind
            readmit: List[Tuple[int, Job]] = []
            for jid, kind in victims.items():
                sched = osched.accepted.pop(jid)
                ao = admit_origin[jid]
                shift = t - ao
                jcur = ljobs[jid]
                # the commitment's slots are local to its admission; the
                # window has since slid by ``shift``
                tail_w = {s - shift: y for s, y in sched.workers.items()
                          if s >= shift}
                tail_z = {s - shift: z for s, z in sched.ps.items()
                          if s >= shift}
                state.release(jcur, tail_w, tail_z)
                osched.total_utility -= sched.utility
                n_preempted += 1
                if _obslib.ENABLED:
                    _obslib.inc("engine.preemptions")
                del active[jid]
                cb = (t // ck) * ck if kind == DOWN_LOSSY else t
                delivered = sum(float(y.sum())
                                for s, y in sched.workers.items()
                                if s + ao < cb)
                rem = jcur.total_work_slots - delivered
                if rem <= 1e-9:
                    done = [s + ao for s, y in sched.workers.items()
                            if s + ao < cb and y.sum() > 0]
                    completion[jid] = max(done) if done else max(cb - 1, 0)
                    admit_origin.pop(jid, None)
                    ljobs.pop(jid, None)
                    continue
                scale = jcur.work_scale * rem / jcur.total_work_slots
                orig = jmap[jid]
                readmit.append((jid, dataclasses.replace(
                    jcur, arrival=0, work_scale=scale,
                    utility=_shift_utility(orig.utility,
                                           t - int(orig.arrival)))))
            for pool, srv, kind in trans:
                if kind != UP:
                    blocked_gpu += state.block_server(pool, srv, 0)
            _cs.set(victims=len(victims), readmits=len(readmit))
            _cs.__exit__(None, None, None)
            for jid, loc in readmit:
                ljobs[jid] = loc
                if decide:
                    cand = osched.propose(loc)
                    g_win, v_win = state.alloc_window(0, DECISION_WINDOW)
                    fw, fsw = _free_window(g_win, v_win, cluster, t,
                                           t_max=None)
                    action = yield DecisionPoint(
                        job=jmap[jid], t=t, scheduler="oasis",
                        expert=(1, 0) if cand is not None else (0, 0),
                        candidate=cand,
                        utility_so_far=osched.total_utility,
                        n_running=len(active), n_waiting=0,
                        accepted=n_accepted, rejected=n_rejected,
                        free_frac_workers=fw, free_frac_ps=fsw,
                        live_frac=fs.live_frac, preempted=True)
                    nw, _ = _as_counts(action)
                    sched = osched._resolve(loc, cand if nw > 0 else None)
                else:
                    sched = osched.on_arrival(loc)
                if sched is not None:
                    active[jid] = t + sched.finish
                    completion[jid] = t + sched.finish
                    admit_origin[jid] = t
                else:
                    # the shrunken fleet can't fit it: the job departs
                    # with no utility (subtracted above)
                    n_dropped += 1
                    if _obslib.ENABLED:
                        _obslib.inc("engine.preempt_dropped")
                    n_accepted -= 1
                    n_rejected += 1
                    completion.pop(jid, None)
                    admit_origin.pop(jid, None)
                    ljobs.pop(jid, None)
        # window-local coordinates: the job arrives at local slot 0 (its
        # durations — hence utility — are translation-invariant)
        local = [dataclasses.replace(_with_quantum(j, quantum), arrival=0)
                 for j in batch]
        for j in batch:
            jmap[j.jid] = j
            arrivals[j.jid] = int(j.arrival)
        n_jobs += len(batch)
        if _obslib.ENABLED and batch:
            _obslib.inc("engine.arrivals", len(batch))
        if decide:
            for job, loc in zip(batch, local):
                cand = osched.propose(loc)
                g_win, v_win = state.alloc_window(0, DECISION_WINDOW)
                fw, fsw = _free_window(g_win, v_win, cluster, t, t_max=None)
                action = yield DecisionPoint(
                    job=job, t=t, scheduler="oasis",
                    expert=(1, 0) if cand is not None else (0, 0),
                    candidate=cand, utility_so_far=osched.total_utility,
                    n_running=len(active), n_waiting=0,
                    accepted=n_accepted, rejected=n_rejected,
                    free_frac_workers=fw, free_frac_ps=fsw,
                    live_frac=fs.live_frac if churn else 1.0)
                nw, _ = _as_counts(action)
                sched = osched._resolve(loc, cand if nw > 0 else None)
                if sched is not None:
                    n_accepted += 1
                    active[job.jid] = t + sched.finish
                    completion[job.jid] = t + sched.finish
                    if churn:
                        ljobs[job.jid] = loc
                        admit_origin[job.jid] = t
                else:
                    n_rejected += 1
        elif batch:
            with _obslib.span("arrival_burst", t=t, n=len(batch)):
                scheds = osched.on_arrivals(local)
            for job, loc, sched in zip(batch, local, scheds):
                if sched is not None:
                    n_accepted += 1
                    active[job.jid] = t + sched.finish
                    completion[job.jid] = t + sched.finish
                    if churn:
                        ljobs[job.jid] = loc
                        admit_origin[job.jid] = t
                else:
                    n_rejected += 1
        if check:
            ok_w, ok_ps = state.capacity_ok()
            assert ok_w, "worker capacity violated"
            assert ok_ps, "PS capacity violated"
    # elapsed clock: through the last committed completion (tail work
    # beyond the final arrival still occupies the cluster)
    t_end = max(max(completion.values(), default=0) + 1, t + 1, 1)
    total_gpu = max(float(cluster.worker_caps[:, 0].sum()), 1e-9)
    gpu_slots = state.retired_gpu_slots + float(state.gpu_slot_usage().sum())
    if churn:
        gpu_slots -= blocked_gpu        # capacity-block filler, not usage
    return SimResult(name="oasis", total_utility=osched.total_utility,
                     accepted=n_accepted, completed=len(completion),
                     n_jobs=n_jobs, completion=completion,
                     target_gap=_target_gaps(jmap, completion),
                     decision_seconds=osched.decision_seconds,
                     utilization=gpu_slots / (total_gpu * t_end),
                     preempted=n_preempted, preempt_dropped=n_dropped,
                     live_frac=fs.live_frac if churn else 1.0,
                     arrivals=arrivals, window_bytes=state.window_bytes)


def _drive_reactive_stream(cluster: ClusterSpec, jobs: Iterable[Job],
                           scheduler: str, fixed_workers: int, check: bool,
                           quantum: Optional[int], decide: bool,
                           fleet: Optional[FleetTrace] = None,
                           ckpt_interval: int = CKPT_INTERVAL
                           ) -> Generator[DecisionPoint, object, SimResult]:
    rsched: ReactiveScheduler = BASELINES[scheduler](
        cluster, fixed_workers=fixed_workers)
    total_gpu = max(float(cluster.worker_caps[:, 0].sum()), 1e-9)
    jmap: Dict[int, Job] = {}
    arrivals: Dict[int, int] = {}
    admitted: List[int] = []
    remaining: Dict[int, float] = {}
    completion: Dict[int, int] = {}
    total_utility = 0.0
    util_sum = 0.0
    cur_alloc: Dict[int, tuple] = {}
    ids: List[int] = []
    counts = np.zeros(0)
    plan_gpu = 0.0
    stale = True
    n_rejected = 0
    n_jobs = 0
    # fleet churn (same machinery as the episodic reactive driver)
    churn = fleet is not None and bool(fleet)
    fs = FleetState(cluster, fleet) if churn else None
    fe: List[int] = fs.event_slots if churn else []
    fi = 0
    ckpt_rem: Dict[int, float] = {}
    ck = max(int(ckpt_interval), 1)
    n_preempted = 0
    # per-event repack wall clocks (see _drive_reactive); empty in
    # stepwise mode so the policy's latency takes the slot instead
    decision_seconds: List[float] = []

    it = iter(jobs)
    nxt = next(it, None)
    t = int(nxt.arrival) if nxt is not None else 0
    while nxt is not None or remaining:
        if churn:
            changed = False
            while fi < len(fe) and fe[fi] <= t:
                with _obslib.span("churn_step", t=fe[fi]):
                    for pool, srv, kind in fs.step(fe[fi]):
                        if kind == UP:
                            continue
                        if pool == "worker":
                            vs = [jid for jid, (y, _) in cur_alloc.items()
                                  if y[srv] > 0]
                        else:
                            vs = [jid for jid, (_, z) in cur_alloc.items()
                                  if z is not None and z[srv] > 0]
                        for jid in vs:
                            if kind == DOWN_LOSSY:
                                remaining[jid] = ckpt_rem.get(
                                    jid, jmap[jid].total_work_slots)
                            else:
                                ckpt_rem[jid] = remaining[jid]
                            rsched.preempt(jid, t)
                            cur_alloc.pop(jid, None)
                            n_preempted += 1
                            if _obslib.ENABLED:
                                _obslib.inc("engine.preemptions")
                changed = True
                fi += 1
            if changed:
                rsched.set_capacity(fs.worker_caps, fs.ps_caps)
                stale = True
        burst: List[Job] = []
        while nxt is not None and int(nxt.arrival) <= t:
            burst.append(_with_quantum(nxt, quantum))
            nxt = next(it, None)
        if _obslib.ENABLED and burst:
            _obslib.inc("engine.arrivals", len(burst))
        if decide and burst:
            usage = _pool_usage(cur_alloc, jmap, cluster)
        for job in burst:
            n_jobs += 1
            jmap[job.jid] = job
            arrivals[job.jid] = int(job.arrival)
            if decide:
                action = yield _reactive_decision_point(
                    rsched, cluster, job, t, scheduler, cur_alloc, usage,
                    len(admitted), n_rejected, len(remaining), total_utility,
                    t_max=None,
                    live_frac=fs.live_frac if churn else 1.0)
                nw, nps = _as_counts(action)
                if nw <= 0:
                    n_rejected += 1
                    continue
                if isinstance(rsched, Learned):
                    nw = min(nw, job.num_chunks)
                    nps = max(nps, job.ps_for(nw))
                    rsched.set_counts(job.jid, nw, nps)
                rsched.enroll(job, t)
                admitted.append(job.jid)
                remaining[job.jid] = job.total_work_slots
            elif rsched.on_arrival(job, t):
                admitted.append(job.jid)
                remaining[job.jid] = job.total_work_slots
            else:
                n_rejected += 1
        if rsched.dirty:
            t0_rp = time.perf_counter()
            with _obslib.span("repack", t=t, scheduler=scheduler,
                              n_live=len(remaining)):
                cur_alloc = dict(rsched.step(t))
            if not decide:
                decision_seconds.append(time.perf_counter() - t0_rp)
            rsched.dirty = False
            stale = True
            if check:
                if churn:
                    _check_alloc(cluster, jmap, cur_alloc,
                                 fs.worker_caps, fs.ps_caps)
                else:
                    _check_alloc(cluster, jmap, cur_alloc)
        elif _obslib.ENABLED and (burst or (churn and changed)):
            _obslib.inc("repack.dirty_skips")
        if stale:
            ids = list(cur_alloc)
            counts = np.array([float(cur_alloc[j][0].sum()) for j in ids])
            plan_gpu = float(counts @ np.array(
                [jmap[j].worker_res[0] for j in ids])) if ids else 0.0
            stale = False

        rem = np.array([remaining[j] for j in ids])
        active = counts > 0
        slots_left = np.full(len(ids), np.inf)
        if active.any():
            slots_left[active] = np.maximum(
                np.ceil((rem[active] - 1e-9) / counts[active]), 1.0)
        earliest = float(slots_left.min()) if ids else math.inf
        horizon = (int(nxt.arrival) - t) if nxt is not None else math.inf
        if churn and fi < len(fe):
            # the next fleet event bounds the plan's validity (and can
            # un-starve a waiting queue by restoring capacity)
            horizon = min(horizon, fe[fi] - t)
        if not math.isfinite(earliest) and not math.isfinite(horizon):
            # no future arrivals and no live job is progressing: the plan
            # can never change again — the waiting jobs are starved for
            # good, so the stream is done (they simply never complete)
            break
        span = max(int(min(earliest, horizon)), 1)
        consumed = counts * span
        util_sum += (plan_gpu / total_gpu) * span
        t_end = t + span - 1
        if churn and ids:
            cb = ((t + span) // ck) * ck
            if cb > t:
                frac = (cb - t) / span
                for j, used in zip(ids, consumed):
                    ckpt_rem[j] = max(remaining[j] - float(used) * frac,
                                      0.0)
        done_now = []
        for j, used in zip(ids, consumed):
            remaining[j] -= used
            if remaining[j] <= 1e-9:
                done_now.append(j)
        for jid in done_now:
            completion[jid] = t_end
            total_utility += jmap[jid].utility(t_end - jmap[jid].arrival)
            rsched.on_completion(jid, t_end)
            del remaining[jid]
            cur_alloc.pop(jid, None)
            if churn:
                ckpt_rem.pop(jid, None)
            stale = True
        t += span
        if _obslib.ENABLED:
            _obslib.inc("engine.ffwd_slots", span)
            if done_now:
                _obslib.inc("engine.completions", len(done_now))
    return SimResult(name=scheduler, total_utility=total_utility,
                     accepted=len(admitted), completed=len(completion),
                     n_jobs=n_jobs, completion=completion,
                     target_gap=_target_gaps(jmap, completion),
                     decision_seconds=decision_seconds,
                     utilization=util_sum / max(t, 1),
                     preempted=n_preempted,
                     live_frac=fs.live_frac if churn else 1.0,
                     arrivals=arrivals, window_bytes=0)
