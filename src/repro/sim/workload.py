"""Workload/cluster generator following the paper's simulation settings
(Sec. V-A): EC2-C4-like worker servers, P2/G3-like PS servers, job
parameter ranges, Google-trace-style bursty arrivals, sigmoid utilities.

Two arrival processes share the per-job sampler (``_sample_job``):

* ``make_jobs`` — the finite episodic trace (nonhomogeneous Poisson over
  ``[0, T)`` with a few x4-rate burst windows), unchanged semantics;
* ``stream_jobs`` — the open-ended serving trace: a generator yielding
  jobs in arrival order from a per-slot Poisson process whose rate is a
  diurnal sinusoid overlaid with occasional heavy-tailed (Pareto) burst
  episodes.  Streamed (never materialised), seeded, and reproducible —
  the same seed replays the identical trace for every scheduler.
"""
from __future__ import annotations

import math
from typing import Iterator, List, Optional

import numpy as np

from ..core.types import ClusterSpec, Job, SigmoidUtility

# resource order: gpu, cpu, mem(GB), storage(GB), bw(Gbps)
_C4_LIKE = np.array([8.0, 36.0, 60.0, 400.0, 25.0])      # worker servers
_P2_LIKE = np.array([0.0, 64.0, 488.0, 800.0, 25.0])     # PS servers (no GPU used)
_G3_LIKE = np.array([0.0, 64.0, 488.0, 800.0, 50.0])


def make_cluster(T: int = 100, H: int = 50, K: int = 50,
                 scale: float = 1.0, rng: Optional[np.random.Generator] = None
                 ) -> ClusterSpec:
    rng = rng or np.random.default_rng(0)
    worker_caps = np.tile(_C4_LIKE, (H, 1)) * scale
    # GPUs per worker server: paper uses GPU servers; give each 8 GPUs
    ps_rows = [(_P2_LIKE if rng.random() < 0.5 else _G3_LIKE) for _ in range(K)]
    ps_caps = np.stack(ps_rows) * scale
    ps_caps[:, 0] = 0.0
    return ClusterSpec(T=T, worker_caps=worker_caps, ps_caps=ps_caps)


def _burst_profile(T: int, rng: np.random.Generator) -> np.ndarray:
    """Per-slot rate multipliers: a few x4-rate burst windows.

    Burst windows *wrap* at the trace edges (indices taken mod T), so a
    burst centered near 0 or T keeps its full 2*width slot mass instead
    of being clipped — arrival-rate properties hold at the boundaries.
    """
    base = np.ones(T)
    n_bursts = max(1, T // 40)
    width = max(2, T // 20)
    for _ in range(n_bursts):
        c = rng.integers(0, T)
        idx = np.arange(c - width, c + width) % T
        base[idx] *= 4.0
    return base


def _arrivals(n_jobs: int, T: int, rng: np.random.Generator) -> np.ndarray:
    """Bursty arrivals à la the Google cluster trace: a nonhomogeneous
    Poisson process with a few high-rate windows."""
    base = _burst_profile(T, rng)
    base[-max(1, T // 10):] = 0.05 * base[-max(1, T // 10):]  # few arrivals near T
    probs = base / base.sum()
    return np.sort(rng.choice(T, size=n_jobs, p=probs, replace=True))


def _sample_job(jid: int, arrival: int, rng: np.random.Generator,
                small: bool, time_insensitive: float,
                time_sensitive: float) -> Job:
    """One job from the paper's Table-I parameter ranges (shared by the
    episodic ``make_jobs`` and the open-ended ``stream_jobs``; the rng
    draw order is exactly ``make_jobs``'s original per-job body)."""
    if small:
        E = int(rng.integers(1, 4))
        N = int(rng.integers(1, 5))
        M = int(rng.integers(5, 20))
    else:
        E = int(rng.integers(50, 201))
        N = int(rng.integers(5, 101))
        M = int(rng.integers(10, 101))
    tau = float(rng.uniform(0.001, 0.1))
    e = float(rng.uniform(30, 575)) / 1000.0          # GB
    b = float(rng.uniform(0.1, 5.0))                  # Gbps -> GB/slot units
    B = float(rng.uniform(5.0, 20.0))
    # Normalize per-chunk time so the *fastest possible duration*
    # E*M*(tau+2e/b) lands in [2, 16] slots, consistent with the paper's
    # target completion times gamma3 in [1, 15] and its testbed jobs
    # (40 min - 2 h on 20-min slots).  Keeps chunk_time << 1 slot, the
    # paper's own assumption in Sec. III-B.
    ct = M * (tau + 2 * e / b)
    min_dur = E * ct
    target = float(rng.uniform(2.0, 16.0))
    # keep per-chunk time << slot length (paper Sec. III-B assumption);
    # binds only for tiny-E test jobs.
    target = min(target, 0.9 * E)
    scale = target / min_dur
    tau *= scale
    e *= scale
    w = np.array([float(rng.integers(0, 5)), float(rng.integers(1, 11)),
                  float(rng.uniform(2, 32)), float(rng.uniform(5, 10)), b])
    s = np.array([0.0, float(rng.integers(1, 11)),
                  float(rng.uniform(2, 32)), float(rng.uniform(5, 10)), B])
    u = rng.random()
    gamma1 = float(rng.uniform(1, 100))
    if u < time_insensitive:
        gamma2 = 0.0
    elif u < time_insensitive + time_sensitive:
        gamma2 = float(rng.uniform(0.01, 1.0))
    else:
        gamma2 = float(rng.uniform(4.0, 6.0))
    # gamma3 is the job's *target completion time* (paper: in [1,15]);
    # couple it to the fastest achievable duration so targets are
    # meaningful (reachable when scheduled promptly, missed otherwise).
    min_dur_slots = max(1.0, target - 1.0)
    gamma3 = float(np.clip(min_dur_slots * rng.uniform(1.0, 2.5), 1, 40))
    return Job(jid=jid, arrival=arrival, epochs=E, num_chunks=N,
               minibatches_per_chunk=M, tau=tau, grad_size=e, worker_bw=b,
               ps_bw=B, worker_res=w, ps_res=s,
               utility=SigmoidUtility(gamma1, gamma2, gamma3))


def make_jobs(n_jobs: int, T: int = 100, seed: int = 0,
              time_insensitive: float = 0.10, time_sensitive: float = 0.55,
              small: bool = False) -> List[Job]:
    """Paper ranges: E in [50,200], N in [5,100], M in [10,100],
    tau in [0.001,0.1] slots, e in [30,575] MB; worker 0-4 GPU / 1-10 vCPU /
    2-32 GB / 5-10 GB / 0.1-5 Gbps; PS 1-10 vCPU / 2-32 GB / 5-10 GB /
    5-20 Gbps.  ``small=True`` shrinks E,N for fast tests/offline-opt."""
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(n_jobs, max(T - 1, 1), rng)
    return [_sample_job(jid, int(arrivals[jid]), rng, small,
                        time_insensitive, time_sensitive)
            for jid in range(n_jobs)]


def stream_jobs(rate: float = 0.2, seed: int = 0,
                max_slots: Optional[int] = None, *,
                diurnal_period: int = 288, diurnal_amp: float = 0.6,
                burst_prob: float = 0.01, burst_mean_len: int = 12,
                burst_tail: float = 1.5, burst_cap: float = 8.0,
                small: bool = False, time_insensitive: float = 0.10,
                time_sensitive: float = 0.55) -> Iterator[Job]:
    """Open-ended arrival stream for the continuous serving mode.

    Per-slot Poisson counts with intensity

        lambda(t) = rate * (1 + diurnal_amp * sin(2*pi*t/diurnal_period))
                         * burst(t)

    where ``burst(t)`` is 1 outside burst episodes; an episode starts
    with probability ``burst_prob`` per slot, lasts a geometric
    ``burst_mean_len`` slots, and multiplies the rate by a heavy-tailed
    ``min(1 + Pareto(burst_tail), burst_cap)`` amplitude — the diurnal x
    bursty shape of production serving traffic.  Jobs are yielded in
    nondecreasing arrival order with sequential jids; the generator is a
    pure function of ``seed`` and never materialises the trace, so it
    runs in O(1) memory for arbitrarily long horizons.  ``max_slots``
    bounds the arrival clock (jobs may still *finish* later); ``None``
    streams forever.
    """
    rng = np.random.default_rng(seed)
    jid = 0
    t = 0
    burst_left = 0
    burst_amp = 1.0
    while max_slots is None or t < max_slots:
        if burst_left == 0 and rng.random() < burst_prob:
            burst_left = int(rng.geometric(1.0 / max(burst_mean_len, 1)))
            burst_amp = float(min(1.0 + rng.pareto(burst_tail), burst_cap))
        mult = burst_amp if burst_left > 0 else 1.0
        if burst_left > 0:
            burst_left -= 1
        lam = rate * (1.0 + diurnal_amp
                      * math.sin(2.0 * math.pi * t / diurnal_period)) * mult
        for _ in range(int(rng.poisson(max(lam, 0.0)))):
            yield _sample_job(jid, t, rng, small,
                              time_insensitive, time_sensitive)
            jid += 1
        t += 1
