"""Fleet churn model: server failures, recoveries, maintenance drains.

The paper (and the sim before this module) treats capacity as immortal:
``ClusterSpec`` is a constant over the whole horizon.  Real clusters
churn — nodes die and come back, operators drain racks for maintenance —
so this module makes those events first-class:

* :class:`FleetEvent` / :class:`FleetTrace` — a seeded, immutable event
  trace.  :func:`make_fleet_trace` samples per-server-**class**
  exponential MTBF/MTTR failure processes (servers sharing a capacity
  row share a class, so e.g. big-memory nodes can be configured flakier
  than the C4-likes) plus scheduled maintenance-drain windows over a
  rotating slice of the worker fleet.  :func:`churn_trace` is the
  scoreboard generator: *exactly* ``frac`` of each pool fails once
  mid-horizon ("utility retention under k% fleet churn").
* :class:`FleetState` — the run-time view: it folds the events into
  per-server up/down state and exposes the *effective* (masked) capacity
  arrays plus per-slot transitions for the engine.  A server is down
  while failed **or** inside any drain window; a ``fail`` is *lossy*
  (victims lose work back to their last checkpoint — the
  ``runtime/driver.py::run_with_restarts`` semantics on the slot clock)
  while a ``drain_start`` is *graceful* (a checkpoint is taken at drain
  start, so victims keep all work done before the drain).

The empty trace is an exact no-op: ``FleetTrace()`` is falsy, the engine
never enters a churn branch, and every scheduler's trajectory stays
bit-identical to the churn-free run (tests/test_fleet.py pins this).

Example — a 20%-churn trace over a paper-scale fleet::

    >>> from repro.sim.fleet import churn_trace, FleetState
    >>> from repro.sim.workload import make_cluster
    >>> cluster = make_cluster(T=100, H=50, K=50)
    >>> trace = churn_trace(cluster, frac=0.2, seed=0)
    >>> sum(1 for e in trace.events
    ...     if e.kind == "fail" and e.pool == "worker")
    10
    >>> fs = FleetState(cluster, trace)
    >>> fs.live_frac                   # everything starts alive
    1.0
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.types import ClusterSpec

# transition kinds FleetState.step reports to the engine
DOWN_LOSSY = "down_lossy"        # crash: work since last checkpoint lost
DOWN_GRACEFUL = "down_graceful"  # drain: checkpoint taken at drain start
UP = "up"                        # capacity restored


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """One fleet transition: a server fails/recovers or a drain window
    opens/closes.  ``pool`` is ``"worker"`` or ``"ps"``; ``server`` the
    row index into that pool's capacity array."""

    slot: int
    kind: str          # "fail" | "recover" | "drain_start" | "drain_end"
    pool: str          # "worker" | "ps"
    server: int


@dataclasses.dataclass(frozen=True)
class FleetTrace:
    """An immutable, slot-ordered fleet event trace.  Falsy when empty —
    the engine uses that as the churn on/off switch, and the empty trace
    is pinned to be an exact no-op."""

    events: Tuple[FleetEvent, ...] = ()

    def __bool__(self) -> bool:
        return bool(self.events)

    @property
    def slots(self) -> List[int]:
        return sorted({e.slot for e in self.events})


def _server_classes(caps: np.ndarray) -> np.ndarray:
    """Class index per server: servers with identical capacity rows share
    a class (first-seen order)."""
    seen: Dict[bytes, int] = {}
    return np.array([seen.setdefault(caps[i].tobytes(), len(seen))
                     for i in range(caps.shape[0])], dtype=np.int64)


def make_fleet_trace(cluster: ClusterSpec, T: Optional[int] = None,
                     seed: int = 0, mtbf: float = 400.0, mttr: float = 25.0,
                     class_mtbf: Optional[Mapping[int, float]] = None,
                     class_mttr: Optional[Mapping[int, float]] = None,
                     include_ps: bool = True,
                     drain_every: Optional[int] = None,
                     drain_duration: int = 10,
                     drain_frac: float = 0.1) -> FleetTrace:
    """Seeded failure/recovery + maintenance-drain trace.

    Each server runs an alternating-renewal process: up-times are
    exponential with the server **class**'s MTBF, down-times exponential
    with its MTTR (classes = distinct capacity rows, overridable per
    class index via ``class_mtbf``/``class_mttr``).  With ``drain_every``
    set, every ``drain_every`` slots a rotating ``drain_frac`` slice of
    the worker fleet is drained for ``drain_duration`` slots (graceful:
    the engine checkpoints victims at drain start).
    """
    T = cluster.T if T is None else int(T)
    rng = np.random.default_rng(seed)
    events: List[FleetEvent] = []
    pools = [("worker", cluster.worker_caps)]
    if include_ps:
        pools.append(("ps", cluster.ps_caps))
    for pool, caps in pools:
        cls = _server_classes(caps)
        for s in range(caps.shape[0]):
            mb = float((class_mtbf or {}).get(int(cls[s]), mtbf))
            mr = float((class_mttr or {}).get(int(cls[s]), mttr))
            t = rng.exponential(mb)
            while t < T:
                fail = max(1, int(math.ceil(t)))
                if fail >= T:
                    break
                dur = max(1, int(round(rng.exponential(mr))))
                events.append(FleetEvent(fail, "fail", pool, s))
                rec = fail + dur
                if rec < T:
                    events.append(FleetEvent(rec, "recover", pool, s))
                t = rec + rng.exponential(mb)
    if drain_every:
        H = cluster.H
        k = max(1, int(round(drain_frac * H)))
        start, idx = int(drain_every), 0
        while start < T - 1 and H:
            for j in range(k):
                s = (idx + j) % H
                events.append(FleetEvent(start, "drain_start", "worker", s))
                end = start + int(drain_duration)
                if end < T:
                    events.append(FleetEvent(end, "drain_end", "worker", s))
            idx += k
            start += int(drain_every)
    events.sort(key=lambda e: (e.slot, e.pool, e.server, e.kind))
    return FleetTrace(tuple(events))


def churn_trace(cluster: ClusterSpec, frac: float, seed: int = 0,
                T: Optional[int] = None,
                recover: bool = True) -> FleetTrace:
    """The scoreboard trace: exactly ``round(frac * pool_size)`` servers
    of each pool fail once, at a uniform slot in the middle ~3/4 of the
    horizon, each down for an exponential (mean ``T/6``) repair time
    (dropped past the horizon when ``recover`` and the draw run long).
    Deterministic in ``(cluster dims, frac, seed)``."""
    T = cluster.T if T is None else int(T)
    rng = np.random.default_rng(seed)
    events: List[FleetEvent] = []
    lo, hi = max(1, T // 8), max(2, (7 * T) // 8)
    for pool, n in (("worker", cluster.H), ("ps", cluster.K)):
        k = int(round(frac * n))
        if k <= 0:
            continue
        servers = rng.choice(n, size=min(k, n), replace=False)
        for s in sorted(int(x) for x in servers):
            fail = int(rng.integers(lo, hi))
            events.append(FleetEvent(fail, "fail", pool, s))
            if recover:
                rec = fail + max(1, int(round(rng.exponential(T / 6.0))))
                if rec < T:
                    events.append(FleetEvent(rec, "recover", pool, s))
    events.sort(key=lambda e: (e.slot, e.pool, e.server, e.kind))
    return FleetTrace(tuple(events))


class FleetState:
    """Run-time fold of a :class:`FleetTrace`: per-server up/down state,
    effective (masked) capacity arrays, and per-slot transitions.

    A server is *down* while failed or inside ≥1 drain window; the two
    conditions compose (a crash during a drain keeps the server down
    past ``drain_end`` until its ``recover``).  :meth:`step` applies all
    events at one slot and returns the servers whose up/down state
    actually flipped, tagged lossy (``fail`` among the slot's events for
    that server) or graceful.
    """

    def __init__(self, cluster: ClusterSpec, trace: FleetTrace):
        self.cluster = cluster
        self._failed = {"worker": np.zeros(cluster.H, dtype=bool),
                        "ps": np.zeros(cluster.K, dtype=bool)}
        self._drains = {"worker": np.zeros(cluster.H, dtype=np.int64),
                        "ps": np.zeros(cluster.K, dtype=np.int64)}
        self._by_slot: Dict[int, List[FleetEvent]] = {}
        for ev in trace.events:
            self._by_slot.setdefault(int(ev.slot), []).append(ev)
        self.event_slots: List[int] = sorted(self._by_slot)
        self._caps = {"worker": cluster.worker_caps, "ps": cluster.ps_caps}
        self._eff: Dict[str, np.ndarray] = {}
        self._gpu_total = max(float(cluster.worker_caps[:, 0].sum()), 1e-9)

    def _is_down(self, pool: str, server: int) -> bool:
        return bool(self._failed[pool][server]
                    or self._drains[pool][server] > 0)

    def step(self, t: int) -> List[Tuple[str, int, str]]:
        """Apply every event at slot ``t``; return ``(pool, server,
        transition)`` for servers whose up/down state flipped, lossy
        transitions first (a server hit by both a ``fail`` and a
        ``drain_start`` in the same slot is a crash)."""
        evs = self._by_slot.get(int(t))
        if not evs:
            return []
        prior: Dict[Tuple[str, int], bool] = {}
        lossy: set = set()
        for ev in evs:
            key = (ev.pool, ev.server)
            if key not in prior:
                prior[key] = self._is_down(*key)
            if ev.kind == "fail":
                self._failed[ev.pool][ev.server] = True
                lossy.add(key)
            elif ev.kind == "recover":
                self._failed[ev.pool][ev.server] = False
            elif ev.kind == "drain_start":
                self._drains[ev.pool][ev.server] += 1
            elif ev.kind == "drain_end":
                self._drains[ev.pool][ev.server] = max(
                    0, self._drains[ev.pool][ev.server] - 1)
            else:                               # pragma: no cover
                raise ValueError(f"unknown fleet event kind {ev.kind!r}")
        out: List[Tuple[str, int, str]] = []
        for (pool, srv), was_down in sorted(prior.items()):
            now_down = self._is_down(pool, srv)
            if now_down and not was_down:
                kind = DOWN_LOSSY if (pool, srv) in lossy else DOWN_GRACEFUL
                out.append((pool, srv, kind))
            elif was_down and not now_down:
                out.append((pool, srv, UP))
        if out:
            self._eff.clear()                   # masked caps changed
        # lossy first: victim classification must see crashes before drains
        out.sort(key=lambda x: (x[2] != DOWN_LOSSY, x[0], x[1]))
        return out

    def down_servers(self) -> List[Tuple[str, int]]:
        """Currently-down ``(pool, server)`` pairs, deterministic order."""
        out = []
        for pool in ("worker", "ps"):
            down = self._failed[pool] | (self._drains[pool] > 0)
            out.extend((pool, int(s)) for s in np.flatnonzero(down))
        return out

    def _effective(self, pool: str) -> np.ndarray:
        eff = self._eff.get(pool)
        if eff is None:
            up = ~(self._failed[pool] | (self._drains[pool] > 0))
            eff = self._caps[pool] * up[:, None].astype(float)
            self._eff[pool] = eff
        return eff

    @property
    def worker_caps(self) -> np.ndarray:
        """(H, R) effective worker capacities (0-rows for down servers)."""
        return self._effective("worker")

    @property
    def ps_caps(self) -> np.ndarray:
        return self._effective("ps")

    @property
    def live_frac(self) -> float:
        """Fraction of the worker pool's GPU capacity currently alive —
        the rl/ env's churn observation feature."""
        return float(self.worker_caps[:, 0].sum() / self._gpu_total)
