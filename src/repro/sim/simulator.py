"""Trace-driven cluster simulation (paper Sec. V-A).

``simulate`` is a thin wrapper over the event-driven sim-v2 engine
(`sim/engine.py`); ``simulate_reference`` is the original per-slot Python
loop, kept as the equivalence oracle (tests/test_sim_v2.py) and the
baseline for the sim-v2 speedup benchmark (`benchmarks.figs.sim_v2_speedup`).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.baselines import BASELINES, ReactiveScheduler
from ..core.oasis import OASiS
from ..core.pricing import PriceParams, price_params_from_jobs
from ..core.types import ClusterSpec, Job
from . import engine
from .engine import SimResult

__all__ = ["SimResult", "simulate", "simulate_reference"]


def simulate(cluster: ClusterSpec, jobs: Sequence[Job], scheduler: str = "oasis",
             params: Optional[PriceParams] = None, impl: str = "fast",
             fixed_workers: int = 8, check: bool = True,
             quantum: Optional[int] = None,
             cancellations: Optional[Dict[int, int]] = None,
             throughput: Optional[engine.ThroughputFn] = None) -> SimResult:
    """Drive ``scheduler`` through T slots on the sim-v2 event engine.

    Equivalent to the v1 per-slot loop (``simulate_reference``) on
    cancellation-free, unperturbed workloads; ``cancellations`` and
    ``throughput`` are sim-v2 scenario hooks (see ``sim/engine.py``).
    """
    return engine.run(cluster, jobs, scheduler=scheduler, params=params,
                      impl=impl, fixed_workers=fixed_workers, check=check,
                      quantum=quantum, cancellations=cancellations,
                      throughput=throughput)


def _check_capacity(cluster: ClusterSpec, jobs: Dict[int, Job],
                    alloc: Dict[int, tuple]) -> None:
    used_w = np.zeros_like(cluster.worker_caps, dtype=float)
    used_s = np.zeros_like(cluster.ps_caps, dtype=float)
    for jid, (y, z) in alloc.items():
        job = jobs[jid]
        used_w += y[:, None] * job.worker_res[None]
        if z is not None:
            used_s += z[:, None] * job.ps_res[None]
    assert np.all(used_w <= cluster.worker_caps + 1e-6), "worker capacity violated"
    assert np.all(used_s <= cluster.ps_caps + 1e-6), "PS capacity violated"


def simulate_reference(cluster: ClusterSpec, jobs: Sequence[Job],
                       scheduler: str = "oasis",
                       params: Optional[PriceParams] = None, impl: str = "fast",
                       fixed_workers: int = 8, check: bool = True,
                       quantum: Optional[int] = None,
                       seed_placement: bool = True) -> SimResult:
    """The v1 per-slot simulation loop (equivalence oracle for sim v2).

    ``seed_placement=True`` additionally pins the baselines' greedy repack
    loops (``step_reference``) and runs their round-robin placement
    through the seed's per-server Python scan, so this is the pre-sim-v2
    code path end to end (the honest baseline for
    ``benchmarks.figs.sim_v2_speedup``; placements are identical to the
    vectorized kernels either way, see ``tests/test_repack.py``).
    """
    from ..core import baselines as _baselines
    if seed_placement and (_baselines.PLACE_IMPL != "loop"
                           or _baselines.REPACK_IMPL != "reference"):
        saved = (_baselines.PLACE_IMPL, _baselines.REPACK_IMPL)
        _baselines.PLACE_IMPL = "loop"
        _baselines.REPACK_IMPL = "reference"
        try:
            return simulate_reference(cluster, jobs, scheduler=scheduler,
                                      params=params, impl=impl,
                                      fixed_workers=fixed_workers, check=check,
                                      quantum=quantum, seed_placement=True)
        finally:
            _baselines.PLACE_IMPL, _baselines.REPACK_IMPL = saved
    jmap = {j.jid: j for j in jobs}
    by_slot: Dict[int, List[Job]] = {}
    for j in jobs:
        by_slot.setdefault(j.arrival, []).append(j)

    total_gpu = max(float(cluster.worker_caps[:, 0].sum()), 1e-9)
    util_acc = []

    if scheduler == "oasis":
        params = params or price_params_from_jobs(jobs, cluster)
        osched = OASiS(cluster, params, impl=impl)
        completion: Dict[int, int] = {}
        for t in range(cluster.T):
            batch = [engine._with_quantum(job, quantum)
                     for job in by_slot.get(t, [])]
            # batched arrivals (vmapped engine under impl="jax"; exact
            # sequential Alg. 1 semantics either way)
            for job, s in zip(batch, osched.on_arrivals(batch)):
                if s is not None:
                    completion[job.jid] = s.finish
            alloc = osched.allocation_at(t)
            if check:
                _check_capacity(cluster, jmap, alloc)
            gpu = sum(float(y.sum()) * jmap[jid].worker_res[0]
                      for jid, (y, _) in alloc.items())
            util_acc.append(gpu / total_gpu)
        gaps = []
        for jid, tdone in completion.items():
            u = jmap[jid].utility
            if getattr(u, "gamma2", 0) > 0:
                gaps.append((tdone - jmap[jid].arrival) - u.gamma3)
        return SimResult(name="oasis", total_utility=osched.total_utility,
                         accepted=len(osched.accepted), completed=len(completion),
                         n_jobs=len(jobs), completion=completion, target_gap=gaps,
                         decision_seconds=osched.decision_seconds,
                         utilization=float(np.mean(util_acc)) if util_acc else 0.0,
                         arrivals={j.jid: j.arrival for j in jobs
                                   if j.arrival < cluster.T})

    cls = BASELINES[scheduler]
    rsched: ReactiveScheduler = cls(cluster, fixed_workers=fixed_workers)
    admitted: List[int] = []
    work_done: Dict[int, float] = {}
    completion = {}
    total_utility = 0.0
    for t in range(cluster.T):
        for job in by_slot.get(t, []):
            if rsched.on_arrival(job, t):
                admitted.append(job.jid)
                work_done[job.jid] = 0.0
        alloc = rsched.step(t)
        if check:
            _check_capacity(cluster, jmap, alloc)
        gpu = 0.0
        done_now = []
        for jid, (y, z) in alloc.items():
            job = jmap[jid]
            gpu += float(y.sum()) * job.worker_res[0]
            # W workers provide W worker-slots of work per slot
            work_done[jid] += float(y.sum())
            if work_done[jid] >= job.total_work_slots - 1e-9:
                done_now.append(jid)
        util_acc.append(gpu / total_gpu)
        for jid in done_now:
            completion[jid] = t
            total_utility += jmap[jid].utility(t - jmap[jid].arrival)
            rsched.on_completion(jid, t)
    gaps = []
    for jid, tdone in completion.items():
        u = jmap[jid].utility
        if getattr(u, "gamma2", 0) > 0:
            gaps.append((tdone - jmap[jid].arrival) - u.gamma3)
    return SimResult(name=scheduler, total_utility=total_utility,
                     accepted=len(admitted), completed=len(completion),
                     n_jobs=len(jobs), completion=completion, target_gap=gaps,
                     decision_seconds=[],
                     utilization=float(np.mean(util_acc)) if util_acc else 0.0,
                     arrivals={j.jid: j.arrival for j in jobs
                               if j.arrival < cluster.T})
