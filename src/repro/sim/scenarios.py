"""Scenario library for sim v2 (paper Sec. V-A and beyond).

Each scenario builds (cluster, jobs, per-run kwargs) for the event engine
and is runnable from ``python -m benchmarks.run --only scenarios`` or
``python examples/cluster_sim.py --scenario NAME``:

* ``hetero``    — heterogeneous GPU cluster: 8-GPU C4-like, 4-GPU
  mid-range, and 2-GPU high-memory worker classes instead of the paper's
  uniform fleet.
* ``cancel``    — a fraction of admitted jobs departs mid-run; the engine
  releases their allocation (OASiS: dual prices drop) and they earn no
  utility.
* ``straggler`` — per-worker step-time perturbation with persistent slow
  workers; throughput follows the synchronous-training model of
  ``runtime/straggler.py`` (a slot is as fast as its slowest participating
  worker) with and without EMA straggler detection + exclusion.
* ``misest``    — OASiS under mis-estimated U/L price bounds, the Fig. 6
  sweep, on the v2 engine.
* ``scale``     — the fig3-shaped workload at T=500, 100+100 servers,
  2000 jobs; far beyond the v1 per-slot loop's practical ceiling.
* ``serving``   — the continuous-traffic mode: an open-ended diurnal x
  bursty arrival stream (``workload.stream_jobs``) over a paper-scale
  fleet, driven through ``engine.run_stream`` with a rolling price-state
  window; records sustained decisions/sec and the window-bytes memory
  proxy per scheduler.
* ``churn``     — fleet churn: a seeded fraction of each server pool
  fails mid-run (``fleet.churn_trace``); running jobs are preempted with
  checkpoint/restart cost and re-admitted through each scheduler's own
  path.  Reports utility **retention** (churned / churn-free utility,
  higher is better) per scheduler at each churn level.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.pricing import price_params_from_jobs
from ..core.types import ClusterSpec, Job
from ..runtime.straggler import StragglerConfig, StragglerMonitor
from . import engine
from .fleet import churn_trace
from .workload import _P2_LIKE, make_cluster, make_jobs, stream_jobs

REACTIVE = ("fifo", "drf", "rrh", "dorm")
ALL_SCHEDULERS = ("oasis",) + REACTIVE

# worker-server classes for heterogeneous clusters
# resource order: gpu, cpu, mem(GB), storage(GB), bw(Gbps)
_GPU8 = np.array([8.0, 36.0, 60.0, 400.0, 25.0])     # the paper's C4-like
_GPU4 = np.array([4.0, 24.0, 48.0, 300.0, 25.0])     # mid-range
_GPU2_BIGMEM = np.array([2.0, 48.0, 192.0, 600.0, 50.0])


def make_hetero_cluster(T: int = 100, H: int = 50, K: int = 50,
                        mix=(0.4, 0.4, 0.2), seed: int = 0) -> ClusterSpec:
    """A worker fleet mixing the three GPU server classes by ``mix``."""
    rng = np.random.default_rng(seed)
    classes = np.stack([_GPU8, _GPU4, _GPU2_BIGMEM])
    rows = classes[rng.choice(3, size=H, p=np.asarray(mix) / sum(mix))]
    ps = np.tile(_P2_LIKE, (K, 1))
    ps[:, 0] = 0.0
    return ClusterSpec(T=T, worker_caps=rows, ps_caps=ps)


def cancellation_trace(jobs: Sequence[Job], frac: float = 0.25,
                       seed: int = 0) -> Dict[int, int]:
    """Pick ``frac`` of the jobs to depart mid-run, at a slot strictly
    after arrival (the engine requires cancel_slot > arrival) and within
    roughly the job's plausible lifetime."""
    rng = np.random.default_rng(seed)
    chosen = rng.choice(len(jobs), size=max(1, int(frac * len(jobs))),
                        replace=False)
    out = {}
    for idx in chosen:
        job = jobs[idx]
        horizon = max(2, int(2 * job.min_duration))
        out[job.jid] = job.arrival + int(rng.integers(1, horizon + 1))
    return out


class StragglerThroughput:
    """Per-(job, slot) throughput factor from a per-worker step-time model.

    Each job draws a persistent set of slow workers (``slow_frac`` of its
    max pool, ``slowdown``x step time).  In a synchronous slot the job
    progresses at the pace of its slowest participating worker, so the
    undetected factor is ~1/slowdown whenever a slow worker participates.
    With ``detect=True`` a ``runtime.straggler.StragglerMonitor`` sees the
    per-worker step times; flagged workers are excluded from the next
    slot's mesh (the paper-consistent down-scale mitigation), sacrificing
    their work share to restore full-speed steps for the rest.
    """

    def __init__(self, seed: int = 0, slow_frac: float = 0.15,
                 slowdown: float = 3.0, jitter: float = 0.05,
                 detect: bool = True,
                 cfg: Optional[StragglerConfig] = None):
        self.seed = seed
        self.slow_frac = slow_frac
        self.slowdown = slowdown
        self.jitter = jitter
        self.detect = detect
        self.cfg = cfg or StragglerConfig()
        self._slow: Dict[int, np.ndarray] = {}
        self._monitors: Dict[int, StragglerMonitor] = {}
        # without detection the factor is a pure function of (job, slot):
        # the engine may then precompute whole (n_live, horizon) rate
        # blocks via ``rate_matrix`` instead of calling per job per slot
        self.stateless = not detect

    def _job_state(self, job: Job):
        if job.jid not in self._slow:
            rng = np.random.default_rng((self.seed, job.jid))
            self._slow[job.jid] = rng.random(job.num_chunks) < self.slow_frac
            self._monitors[job.jid] = StragglerMonitor(job.num_chunks, self.cfg)
        return self._slow[job.jid], self._monitors[job.jid]

    def __call__(self, job: Job, n_workers: int, slot: int) -> float:
        if n_workers <= 0:
            return 1.0
        slow, monitor = self._job_state(job)
        n = min(n_workers, len(slow))
        rng = np.random.default_rng((self.seed, job.jid, slot))
        times = 1.0 + self.jitter * rng.random(n)
        times[slow[:n]] *= self.slowdown
        include = np.ones(n, dtype=bool)
        if self.detect:
            flagged = [w for w in monitor.stragglers() if w < n]
            include[flagged] = False
        for w in range(n):                      # monitor sees this slot
            monitor.record(w, float(times[w]))
        if not include.any():
            include[:] = True                   # never stall completely
        pace = float(times[include].max())      # synchronous: slowest wins
        return min(1.0, include.sum() / (n * pace))

    def rate_matrix(self, job: Job, n_workers: int, t0: int,
                    h: int) -> np.ndarray:
        """Factors for slots ``[t0, t0 + h)`` at a fixed worker count.

        Only valid when ``stateless`` (detect=False): the draws are seeded
        per (job, slot), so the values equal ``__call__`` slot by slot and
        are independent of block boundaries — the engine may discard and
        recompute any suffix after a replan.  (The monitor bookkeeping
        ``__call__`` performs is skipped; nothing reads it undetected.)
        """
        if not self.stateless:
            raise RuntimeError("rate_matrix requires detect=False")
        if n_workers <= 0:
            return np.ones(h)
        slow, _ = self._job_state(job)
        n = min(n_workers, len(slow))
        sl = slow[:n]
        out = np.empty(h)
        for i in range(h):
            rng = np.random.default_rng((self.seed, job.jid, t0 + i))
            times = 1.0 + self.jitter * rng.random(n)
            times[sl] *= self.slowdown
            pace = float(times.max())
            out[i] = min(1.0, n / (n * pace))
        return out


@dataclasses.dataclass(frozen=True)
class ScenarioResult:
    scenario: str
    scheduler: str
    variant: str
    utility: float
    accepted: int
    completed: int
    canceled: int
    utilization: float
    wall_seconds: float
    # per-decision latency stats (plan-ahead schedulers only; None for the
    # reactive baselines, which have no per-arrival decision procedure)
    decision_p50: Optional[float] = None
    decision_mean: Optional[float] = None
    decision_p95: Optional[float] = None
    # serving-mode extras: sustained arrival-decision throughput over the
    # whole streamed trace, the price-state's resident window footprint
    # (the peak-RSS proxy — 0 for the reactive baselines, which keep no
    # price tables), and the trace's realized job count
    decisions_per_sec: Optional[float] = None
    window_bytes: Optional[int] = None
    n_jobs: Optional[int] = None
    # churn-scenario extras: utility retention vs. the same scheduler's
    # churn-free run (higher is better; 1.0 = unhurt), the preemption
    # counters from the fleet-churn engine, and the end-of-run surviving
    # worker-GPU fraction (SimResult.live_frac)
    retention: Optional[float] = None
    preempted: Optional[int] = None
    preempt_dropped: Optional[int] = None
    live_frac: Optional[float] = None


def _row(scenario: str, variant: str, r: engine.SimResult,
         wall: float) -> ScenarioResult:
    dec = np.asarray(r.decision_seconds)
    stats = {}
    if dec.size:
        stats = dict(decision_p50=float(np.percentile(dec, 50)),
                     decision_mean=float(dec.mean()),
                     decision_p95=float(np.percentile(dec, 95)))
    return ScenarioResult(scenario=scenario, scheduler=r.name, variant=variant,
                          utility=r.total_utility, accepted=r.accepted,
                          completed=r.completed, canceled=r.canceled,
                          utilization=r.utilization, wall_seconds=wall,
                          **stats)


def _timed(scenario: str, variant: str, *args, **kw) -> ScenarioResult:
    t0 = time.perf_counter()
    r = engine.run(*args, **kw)
    return _row(scenario, variant, r, time.perf_counter() - t0)


def run_hetero(seed: int = 0, quick: bool = False) -> List[ScenarioResult]:
    T, H, n = (60, 20, 40) if quick else (100, 50, 120)
    cluster = make_hetero_cluster(T=T, H=H, K=H, seed=seed)
    jobs = make_jobs(n, T=T, seed=seed, small=quick)
    return [_timed("hetero", "mixed-fleet", cluster, jobs, scheduler=s,
                   check=False, quantum=0 if s == "oasis" else None)
            for s in ALL_SCHEDULERS]


def run_cancel(seed: int = 0, quick: bool = False,
               frac: float = 0.25) -> List[ScenarioResult]:
    T, H, n = (60, 16, 40) if quick else (100, 40, 120)
    cluster = make_cluster(T=T, H=H, K=H)
    jobs = make_jobs(n, T=T, seed=seed, small=quick)
    cancels = cancellation_trace(jobs, frac=frac, seed=seed)
    rows = []
    for s in ALL_SCHEDULERS:
        q = 0 if s == "oasis" else None
        rows.append(_timed("cancel", "none", cluster, jobs, scheduler=s,
                           check=False, quantum=q))
        rows.append(_timed("cancel", f"frac={frac}", cluster, jobs,
                           scheduler=s, check=False, quantum=q,
                           cancellations=cancels))
    return rows


def run_straggler(seed: int = 0, quick: bool = False,
                  slow_frac: float = 0.15,
                  slowdown: float = 3.0) -> List[ScenarioResult]:
    T, H, n = (60, 16, 30) if quick else (100, 40, 100)
    cluster = make_cluster(T=T, H=H, K=H)
    jobs = make_jobs(n, T=T, seed=seed, small=quick)
    rows = []
    for s in ("oasis", "fifo", "drf"):
        q = 0 if s == "oasis" else None
        rows.append(_timed("straggler", "none", cluster, jobs, scheduler=s,
                           check=False, quantum=q))
        for detect, label in [(False, "undetected"), (True, "detected")]:
            tp = StragglerThroughput(seed=seed, slow_frac=slow_frac,
                                     slowdown=slowdown, detect=detect)
            rows.append(_timed("straggler", label, cluster, jobs, scheduler=s,
                               check=False, quantum=q, throughput=tp))
    return rows


def run_misest(seed: int = 0, quick: bool = False,
               factors=(0.25, 0.5, 1.0, 2.0, 4.0)) -> List[ScenarioResult]:
    T, H, n = (60, 16, 40) if quick else (100, 20, 60)
    cluster = make_cluster(T=T, H=H, K=H)
    jobs = make_jobs(n, T=T, seed=seed, small=quick)
    exact = price_params_from_jobs(jobs, cluster)
    return [_timed("misest", f"x{f}", cluster, jobs, scheduler="oasis",
                   params=exact.scaled(f), check=False, quantum=0)
            for f in factors]


# the tracked 10x-scale instance (and its --quick shrink); the benchmark
# harness records these dims alongside the wall clocks in
# BENCH_decision.json, so they live here, next to the code that uses them
SCALE_DIMS = {"T": 500, "H": 100, "K": 100, "n": 2000}
SCALE_DIMS_QUICK = {"T": 150, "H": 30, "K": 30, "n": 300}
# two orders of magnitude past the paper setting — the scoreboard's
# upper rung (benchmarks.run --only simscale records it alongside the
# 10x instance; see docs/BENCHMARKS.md)
SCALE_DIMS_100X = {"T": 1000, "H": 200, "K": 200, "n": 8000}


def run_scale(seed: int = 0, quick: bool = False,
              schedulers: Sequence[str] = ("fifo", "rrh", "drf", "dorm"),
              T: int = SCALE_DIMS["T"], H: int = SCALE_DIMS["H"],
              K: int = SCALE_DIMS["K"],
              n: int = SCALE_DIMS["n"],
              policy_ckpt: Optional[str] = None) -> List[ScenarioResult]:
    """The fig3-shaped workload an order of magnitude past the paper's
    T=100 / 100-server / 200-job setting.  Reactive baselines by default;
    pass ``schedulers=("oasis", ...)`` to include the (decision-bound)
    OASiS run — it uses the fused jit engine against the device-resident
    price state (``impl="jax"``), the configuration the ``sim_scale``
    record in BENCH_decision.json tracks.  ``"learned"`` runs the rl/
    policy scheduler: the checkpoint at ``policy_ckpt`` if given, else a
    deterministic seed-initialized (untrained) net — the CI smoke's
    stand-in, which exercises the whole decision pipeline and records
    its wall clock/latency, not scheduling quality.

    Example — the same workload shape at toy dims (the tracked instances
    use ``SCALE_DIMS`` / ``SCALE_DIMS_100X``)::

        >>> from repro.sim import scenarios
        >>> rows = scenarios.run_scale(T=30, H=4, K=4, n=6,
        ...                            schedulers=("fifo",))
        >>> [(r.scheduler, r.variant, r.accepted) for r in rows]
        [('fifo', 'T=30;n=6', 6)]
    """
    if quick:
        T, H, K, n = (SCALE_DIMS_QUICK[k] for k in ("T", "H", "K", "n"))
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(n, T=T, seed=seed, small=False)

    def _kwargs(s: str) -> dict:
        if s == "oasis":
            return dict(quantum=0, impl="jax")
        if s == "learned":
            from ..rl import policy as rl_policy
            if policy_ckpt:
                params, pcfg, _ = rl_policy.load_policy(policy_ckpt)
                return dict(policy=rl_policy.LearnedDecider(
                    params, pcfg, cluster))
            return dict(policy=rl_policy.default_policy(cluster))
        return {}

    return [_timed("scale", f"T={T};n={n}", cluster, jobs, scheduler=s,
                   check=True, **_kwargs(s))
            for s in schedulers]


# the tracked continuous-serving instance (and its --quick shrink): a
# paper-scale fleet under an open-ended diurnal x bursty stream.  "slots"
# is the arrival-clock length — at 20k slots the full-horizon price state
# would need (20000, H+K, 5) float64 tables (~160 MB); the rolling window
# keeps (window, H+K, 5) resident (~256 KB) regardless of trace length.
SERVING_DIMS = {"H": 50, "K": 50, "window": 64, "slots": 20000, "rate": 0.2}
SERVING_DIMS_QUICK = {"H": 12, "K": 12, "window": 32, "slots": 600,
                      "rate": 0.1}


def run_serving(seed: int = 0, quick: bool = False,
                schedulers: Sequence[str] = ALL_SCHEDULERS,
                slots: Optional[int] = None, window: Optional[int] = None,
                rate: Optional[float] = None,
                policy_ckpt: Optional[str] = None) -> List[ScenarioResult]:
    """Continuous serving mode: every scheduler consumes the *same* seeded
    open-ended stream (regenerated per scheduler — ``stream_jobs`` is a
    pure function of the seed) through ``engine.run_stream``.  OASiS runs
    the fused jit engine over a rolling ``window``-slot price state whose
    memory is independent of trace length; the reactive baselines are
    horizon-free already.  Rows carry sustained decisions/sec and the
    resident window bytes next to the usual quality columns."""
    dims = SERVING_DIMS_QUICK if quick else SERVING_DIMS
    W = int(window if window is not None else dims["window"])
    n_slots = int(slots if slots is not None else dims["slots"])
    lam = float(rate if rate is not None else dims["rate"])
    cluster = make_cluster(T=W, H=dims["H"], K=dims["K"])

    def _kwargs(s: str) -> dict:
        if s == "oasis":
            return dict(impl="jax", quantum=0)
        if s == "learned":
            from ..rl import policy as rl_policy
            if policy_ckpt:
                params, pcfg, _ = rl_policy.load_policy(policy_ckpt)
                return dict(policy=rl_policy.LearnedDecider(
                    params, pcfg, cluster))
            return dict(policy=rl_policy.default_policy(cluster))
        return {}

    rows = []
    for s in schedulers:
        trace = stream_jobs(rate=lam, seed=seed, max_slots=n_slots,
                            small=quick)
        t0 = time.perf_counter()
        r = engine.run_stream(cluster, trace, scheduler=s, window=W,
                              check=(s == "oasis"), **_kwargs(s))
        wall = time.perf_counter() - t0
        row = _row("serving", f"W={W};slots={n_slots}", r, wall)
        rows.append(dataclasses.replace(
            row, decisions_per_sec=r.n_jobs / max(wall, 1e-9),
            window_bytes=r.window_bytes, n_jobs=r.n_jobs))
        if s in ("oasis", "learned") and r.window_bytes is not None:
            # the acceptance bar: price-state memory bounded by the window,
            # never by the trace length (two f64 tables, 5 resources)
            expect = W * (dims["H"] + dims["K"]) * 5 * 8
            assert r.window_bytes == expect, (r.window_bytes, expect)
    return rows


# the tracked fleet-churn instance (and its --quick shrink).  Full-size
# jobs (small=False) so the fleet actually sustains load — with toy jobs
# everything completes within a slot or two of arrival and failures never
# hit a running allocation.  "levels" are the per-pool failure fractions
# of ``fleet.churn_trace``.
CHURN_DIMS = {"T": 100, "H": 40, "K": 40, "n": 120, "levels": (0.05, 0.20)}
CHURN_DIMS_QUICK = {"T": 60, "H": 10, "K": 10, "n": 60,
                    "levels": (0.05, 0.20)}


def run_churn(seed: int = 0, quick: bool = False,
              schedulers: Sequence[str] = ALL_SCHEDULERS,
              levels: Optional[Sequence[float]] = None) -> List[ScenarioResult]:
    """Utility retention under k% fleet churn, per scheduler.

    Every scheduler faces the *same* seeded failure trace at each level
    (``fleet.churn_trace``: ``round(frac * pool)`` servers of each pool
    fail once mid-run, then recover).  The ``"none"`` rows are the
    churn-free anchors; the ``frac=...`` rows carry ``retention`` =
    churned / churn-free utility (higher is better) plus the engine's
    preemption counters.  The engine runs with ``check=True`` under
    churn, so a capacity violation on the surviving fleet fails loudly.
    """
    dims = CHURN_DIMS_QUICK if quick else CHURN_DIMS
    T, H, K, n = dims["T"], dims["H"], dims["K"], dims["n"]
    lv = tuple(levels if levels is not None else dims["levels"])
    cluster = make_cluster(T=T, H=H, K=K)
    jobs = make_jobs(n, T=T, seed=seed, small=quick)
    jmap = {j.jid: j for j in jobs}
    traces = {f: churn_trace(cluster, frac=f, seed=seed + 1) for f in lv}

    def _realized(r: engine.SimResult) -> float:
        # utility evaluated at the *actual* completion slot against the
        # original job — the accounting the churn engine path uses.  The
        # reactive drivers already accrue utility this way; for OASiS the
        # churn-free SimResult carries the committed (planned-finish)
        # total instead, which auto-quantum over-provisioning can beat,
        # so retention must re-anchor on the realized value.
        return sum(jmap[jid].utility(t - jmap[jid].arrival)
                   for jid, t in r.completion.items())

    rows = []
    for s in schedulers:
        q = 0 if s == "oasis" else None
        t0 = time.perf_counter()
        rb = engine.run(cluster, jobs, scheduler=s, check=False, quantum=q)
        rows.append(_row("churn", "none", rb, time.perf_counter() - t0))
        anchor = _realized(rb)
        for f in lv:
            t0 = time.perf_counter()
            r = engine.run(cluster, jobs, scheduler=s, quantum=q,
                           check=True, fleet=traces[f])
            row = _row("churn", f"frac={f}", r, time.perf_counter() - t0)
            ret = r.total_utility / anchor if anchor > 0 else 1.0
            rows.append(dataclasses.replace(
                row, retention=ret, preempted=r.preempted,
                preempt_dropped=r.preempt_dropped,
                live_frac=r.live_frac))
    return rows


SCENARIOS = {
    "hetero": run_hetero,
    "cancel": run_cancel,
    "straggler": run_straggler,
    "misest": run_misest,
    "scale": run_scale,
    "serving": run_serving,
    "churn": run_churn,
}


def run_scenario(name: str, seed: int = 0,
                 quick: bool = False, **kw) -> List[ScenarioResult]:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(SCENARIOS)}")
    return SCENARIOS[name](seed=seed, quick=quick, **kw)
