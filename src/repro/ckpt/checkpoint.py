"""Async, integrity-checked checkpointing with cross-mesh restore.

Layout per step directory:
  ckpt_<step>/
    manifest.json   {step, tree structure, shapes, dtypes, crc32 per leaf,
                     pipeline state, extra metadata}
    data.npz        flat leaf arrays (key = leaf path)

Design points for 1000+ node operation (scaled-down faithfully here):
  * writes go to a temp dir + atomic rename — a crash mid-write never
    corrupts the latest checkpoint (restart-safety);
  * an async writer thread keeps the training loop running during saves;
  * restore is sharding-agnostic: arrays are placed through
    ``jax.device_put`` with the *target* sharding, so a checkpoint taken
    on one mesh restores onto another (elastic re-mesh, §runtime.elastic);
  * keep_last bounds disk usage; crc32 detects bit-rot.
"""
from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out, jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None,
         keep_last: int = 3) -> Path:
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f".tmp_ckpt_{step}"
    final = root / f"ckpt_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, _ = _flatten(tree)
    arrays = {k: v for k, v in leaves}
    np.savez(tmp / "data.npz", **arrays)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": zlib.crc32(v.tobytes()) & 0xFFFFFFFF}
                   for k, v in leaves},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                     # atomic publish
    # retention
    all_ckpts = sorted((p for p in root.glob("ckpt_*")),
                       key=lambda p: int(p.name.split("_")[1]))
    for old in all_ckpts[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    return final


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; ``wait()`` flushes."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def run():
            try:
                save(self.ckpt_dir, step, host_tree, extra, self.keep_last)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def latest_step(ckpt_dir: str) -> Optional[int]:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("ckpt_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None, verify: bool = True
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree``; if ``shardings`` is
    given each leaf is device_put with its target sharding (cross-mesh)."""
    path = Path(ckpt_dir) / f"ckpt_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "data.npz")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (p, leaf), sh in zip(leaves, shard_leaves):
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if verify:
            want = manifest["leaves"][key]["crc32"]
            got = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if want != got:
                raise IOError(f"checksum mismatch for {key}")
        arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
