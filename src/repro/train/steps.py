"""pjit-able train step: loss (CE + z-loss + MoE aux + MTP), backward,
optional int8 error-feedback gradient compression, AdamW update.

``make_train_step(cfg, mesh, ...)`` returns (fn, in_shardings,
out_shardings) ready for ``jax.jit(..).lower(..)`` — used by both the real
trainer and the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models import forward_train, model_axes, model_specs
from ..models.config import ModelConfig
from ..models.layers import padded_vocab, shapes_tree
from ..parallel.sharding import (batch_sharding, param_shardings,
                                 with_batch_constraint)
from .compress import compress_grads
from .optimizer import OptConfig, OptState, apply_updates


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    z_loss: float = 1e-4
    mtp_weight: float = 0.3
    grad_compress: bool = False
    grad_accum: int = 1       # microbatches per step (activation memory / k)


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int,
                  z_coef: float) -> jax.Array:
    """Mean CE over tokens; ignores labels < 0; masks padded vocab tail.

    Sharding-friendly: no gather over the (model-sharded) vocab dim — the
    label logit is extracted with a fused one-hot contraction so the only
    cross-shard traffic is a scalar-per-token all-reduce.
    """
    vpad = logits.shape[-1]
    if vpad > vocab:
        iota = jax.lax.broadcasted_iota(jnp.int32, (vpad,), 0)
        logits = logits + jnp.where(iota >= vocab, -1e30, 0.0
                                    ).astype(logits.dtype)[None, None, :]
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), vpad, dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * mask
    z = z_coef * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll.sum() + z.sum()) / denom


def loss_fn(params: Any, cfg: ModelConfig, batch: Dict, hyper: TrainHyper,
            constrain=None, constrain_h=None, constrain_ssm=None,
            constrain_qkv=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_train(params, cfg, batch, constrain=constrain_h,
                                constrain_ssm=constrain_ssm,
                                constrain_qkv=constrain_qkv)
    if constrain is not None:
        logits = constrain(logits)
        if "mtp_logits" in aux:
            aux["mtp_logits"] = constrain(aux["mtp_logits"])
    loss = cross_entropy(logits, batch["labels"], cfg.vocab_size, hyper.z_loss)
    metrics = {"ce": loss}
    loss = loss + aux.get("moe_aux", 0.0)
    if "mtp_logits" in aux:
        # MTP predicts token t+2: labels shifted one more position
        lbl = batch["labels"]
        mtp_labels = jnp.concatenate(
            [lbl[:, 1:], jnp.full_like(lbl[:, :1], -1)], axis=1)
        mtp_loss = cross_entropy(aux["mtp_logits"], mtp_labels, cfg.vocab_size,
                                 0.0)
        loss = loss + hyper.mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, mesh: Mesh, opt_cfg: OptConfig,
                    hyper: TrainHyper = TrainHyper()):
    """Returns (train_step, in_shardings, out_shardings)."""
    specs = model_specs(cfg)
    p_shard = param_shardings(model_axes(cfg), shapes_tree(specs), mesh)
    b_shard = batch_sharding(mesh)
    repl = NamedSharding(mesh, PartitionSpec())

    from ..parallel.sharding import logical_rules
    rules = logical_rules(mesh)
    vpad = padded_vocab(cfg.vocab_size)
    logit_spec = PartitionSpec(
        rules["batch"] if len(rules["batch"]) > 1 else rules["batch"][0], None,
        rules["vocab"][0] if vpad % mesh.shape["model"] == 0 else None)

    def constrain(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, logit_spec))

    # Sequence-parallel residual stream (Korthikanti et al.): the hidden
    # state saved by remat between layers is sharded over (batch, seq);
    # XLA all-gathers the seq dim on entry to attention and reduce-scatters
    # after — trading a per-layer collective for 16x less live activation
    # memory.
    h_spec = PartitionSpec(
        rules["batch"] if len(rules["batch"]) > 1 else rules["batch"][0],
        "model", None)

    def constrain_h(x):
        if x.ndim == 3 and x.shape[1] % mesh.shape["model"] == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, h_spec))
        return with_batch_constraint(x, mesh)

    bax = rules["batch"] if len(rules["batch"]) > 1 else rules["batch"][0]

    def constrain_ssm(x):
        # (B, L, H, P) or (B, L, H): shard heads over the model axis
        if x.shape[2] % mesh.shape["model"] == 0:
            spec = [bax, None, "model"] + [None] * (x.ndim - 3)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, PartitionSpec(*spec)))
        return x

    # Measured on zamba2-7b train_4k: head-sharding the SSD internals
    # saves 3.7 GiB/dev but adds +25 GiB/dev collective volume (the
    # decay tensors are consumed seq-sharded either side) — a net loss;
    # disabled by default, kept for the §Perf record.
    constrain_ssm = None

    def constrain_qkv(x):
        # q: (B,S,KV,G,hd) / k,v: (B,S,KV,hd) — shard KV heads over the
        # model axis, or the GQA group dim for MQA (KV=1)
        msize = mesh.shape["model"]
        spec = [bax, None, None, None, None][:x.ndim]
        if x.shape[2] % msize == 0:
            spec[2] = "model"
        elif x.ndim == 5 and x.shape[3] % msize == 0:
            spec[3] = "model"
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*spec)))

    # Measured on granite-34b train_4k (MQA, G=48): 22.9 -> 25.2 GiB/dev —
    # XLA's propagated sharding already beat the manual constraint.
    # REFUTED; disabled (kept for the §Perf record).
    constrain_qkv = None

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch):
        batch = {k: with_batch_constraint(v, mesh) for k, v in batch.items()}
        k_acc = hyper.grad_accum
        if k_acc > 1:
            # microbatching: scan over global-batch slices; activation
            # memory scales 1/k, grads accumulate in fp32, FLOPs unchanged
            micro = {k: v.reshape((k_acc, v.shape[0] // k_acc) + v.shape[1:])
                     for k, v in batch.items()}

            def mb_step(carry, mb):
                g_acc, m_acc = carry
                mb = {k: with_batch_constraint(v, mesh)
                      for k, v in mb.items()}
                (loss, metrics), grads = grad_fn(
                    params, cfg, mb, hyper, constrain, constrain_h,
                    constrain_ssm, constrain_qkv)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / k_acc,
                    g_acc, grads)
                m_acc = jax.tree_util.tree_map(
                    lambda a, m: a + m / k_acc, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"ce": 0.0, "loss": 0.0}
            if cfg.mtp_depth:
                m0["mtp"] = 0.0
            m0 = {k: jnp.zeros((), jnp.float32) for k in m0}
            (grads, metrics), _ = jax.lax.scan(mb_step, (g0, m0), micro)
            grads = jax.tree_util.tree_map(
                lambda g, p: g.astype(p.dtype), grads, params)
        else:
            (loss, metrics), grads = grad_fn(
                params, cfg, batch, hyper, constrain, constrain_h,
                constrain_ssm, constrain_qkv)
        if hyper.grad_compress:
            grads = compress_grads(grads)
        new_params, new_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics.update(opt_metrics)
        return new_params, new_state, metrics

    opt_shard = OptState(step=repl, mu=p_shard, nu=p_shard)
    batch_fields = {"tokens": b_shard, "labels": b_shard}
    if cfg.family == "encdec":
        batch_fields["frames"] = b_shard
    if cfg.n_patches:
        batch_fields["patch_embeds"] = b_shard
    in_sh = (p_shard, opt_shard, batch_fields)
    metric_keys = ["ce", "loss", "grad_norm", "lr"]
    if cfg.mtp_depth:
        metric_keys.append("mtp")
    out_sh = (p_shard, opt_shard, {k: repl for k in metric_keys})
    return train_step, in_sh, out_sh


def input_specs(cfg: ModelConfig, seq: int, global_batch: int,
                kind: str = "train") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = global_batch, seq
    sd = jax.ShapeDtypeStruct
    if kind in ("train",):
        out = {"tokens": sd((B, S), jnp.int32), "labels": sd((B, S), jnp.int32)}
    elif kind == "prefill":
        out = {"tokens": sd((B, S), jnp.int32)}
    else:  # decode: one new token, cache built separately
        out = {"tokens": sd((B, 1), jnp.int32)}
    if cfg.family == "encdec" and kind != "decode":
        out["frames"] = sd((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.n_patches and kind == "train":
        out["patch_embeds"] = sd((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return out
