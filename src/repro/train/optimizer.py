"""AdamW with global-norm clipping, warmup-cosine schedule, and optional
low-precision (bf16) first/second moments for memory-constrained giants
(deepseek-v3-671b).  Pure JAX pytree implementation — optimizer state
inherits the parameters' sharding (ZeRO-like under fsdp rules)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt(params: Any, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree_util.tree_map(zeros, params),
                    nu=jax.tree_util.tree_map(zeros, params))


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params: Any, grads: Any, state: OptState, cfg: OptConfig
                  ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
        mhat = m32 / (1 - b1 ** step)
        vhat = v32 / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(td, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(td, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(td, [n[2] for n in new])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
