"""int8 error-feedback gradient compression.

A distributed-optimization trick the paper's PS framework motivates
(gradient exchange dominates worker<->PS bandwidth, eq. (6)): quantize
per-tensor to int8 with a shared fp32 scale before the data-parallel
reduction, keep the quantization residual locally and add it back next
step (error feedback preserves convergence).

Under pjit/SPMD the reduction itself is emitted by XLA; quantizing the
grads shrinks the reduce-scatter payload 4x.  The pure function below is
also used directly by shard_map-based tests to verify numerics.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any) -> Any:
    """Round-trip int8 quantization (stateless form used inside train_step;
    the residual-carrying form lives in ``ErrorFeedback``)."""
    def one(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        return dequantize(q, s).astype(g.dtype)
    return jax.tree_util.tree_map(one, grads)


class ErrorFeedback:
    """Stateful residual accumulator: g_t' = Q(g_t + r_{t-1});
    r_t = (g_t + r_{t-1}) - g_t'.  State is a pytree like grads."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def apply(grads: Any, residual: Any) -> Tuple[Any, Any]:
        def one(g, r):
            x = g.astype(jnp.float32) + r
            q, s = quantize_int8(x)
            deq = dequantize(q, s)
            return deq.astype(g.dtype), x - deq
        pairs = jax.tree_util.tree_map(one, grads, residual)
        outer = jax.tree_util.tree_structure(grads)
        flat = jax.tree_util.tree_leaves(pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_g = jax.tree_util.tree_unflatten(outer, [p[0] for p in flat])
        new_r = jax.tree_util.tree_unflatten(outer, [p[1] for p in flat])
        return new_g, new_r
