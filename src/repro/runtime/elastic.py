"""Elastic runtime: OASiS schedules -> per-slot worker counts -> re-meshed
training.

This is the execution-side half of the paper's core idea ("adjusted
numbers of concurrent workers ... dynamically adjusted during the course
of the job").  At each slot boundary the runtime:

  1. reads the slot's worker count W_t from the job's OASiS schedule,
  2. checkpoints (async flush -> sync point),
  3. rebuilds the device mesh with dp width W_t,
  4. restores params/optimizer through the new shardings
     (``ckpt.restore`` is sharding-agnostic),
  5. resumes the data pipeline cursor — chunk assignment is worker-count
     independent, so no sample is replayed or skipped (the asynchronous-
     training property the paper relies on, mapped to sync SPMD).

On one host, "workers" are dp slices of the host mesh; on a real cluster
the same code drives jax.distributed with per-pod process groups.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax

from ..ckpt import checkpoint as ckpt
from ..core.types import Schedule
from ..data.pipeline import DataConfig, DataPipeline


@dataclasses.dataclass
class SlotPlan:
    slot: int
    n_workers: int


def schedule_to_plan(schedule: Schedule) -> List[SlotPlan]:
    plan = []
    for t in sorted(schedule.workers):
        plan.append(SlotPlan(slot=t, n_workers=int(schedule.workers[t].sum())))
    return plan


def dp_width(n_workers: int, n_devices: int) -> int:
    """Largest power-of-two dp width <= min(workers, devices)."""
    w = max(1, min(n_workers, n_devices))
    return 1 << (w.bit_length() - 1)


class ElasticTrainer:
    """Drives train_step across slots with re-meshing between them."""

    def __init__(self, cfg, opt_cfg, data_cfg: DataConfig, ckpt_dir: str,
                 make_step: Callable, steps_per_slot: int = 50):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.ckpt_dir = ckpt_dir
        self.make_step = make_step          # (mesh) -> (fn, p_shard, o_shard)
        self.steps_per_slot = steps_per_slot
        self.checkpointer = ckpt.AsyncCheckpointer(ckpt_dir)
        self.metrics_log: List[Dict] = []
        self.mesh_history: List[int] = []

    def run(self, plan: List[SlotPlan], params, opt_state,
            pipeline: Optional[DataPipeline] = None) -> Dict[str, Any]:
        pipeline = pipeline or DataPipeline(self.data_cfg)
        step_no = 0
        for slot in plan:
            width = dp_width(slot.n_workers, len(jax.devices()))
            self.mesh_history.append(width)
            mesh = jax.make_mesh((width, 1), ("data", "model"))
            fn, p_shard, o_shard = self.make_step(mesh)
            params = jax.device_put(params, p_shard)
            opt_state = jax.device_put(opt_state, o_shard)
            for _ in range(self.steps_per_slot):
                batch = pipeline.next_batch()
                params, opt_state, metrics = fn(params, opt_state, batch)
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()})
                step_no += 1
            self.checkpointer.save_async(
                step_no, {"params": params, "opt": opt_state},
                extra={"pipeline": pipeline.state.to_dict(),
                       "slot": slot.slot})
        self.checkpointer.wait()
        return {"params": params, "opt": opt_state, "steps": step_no,
                "pipeline": pipeline}
