"""Straggler mitigation.

The paper's asynchronous PS training tolerates slow workers natively; on
a synchronous TPU mesh a straggler stalls every step.  Mitigations here:

1. **Detection** — per-worker step-time EMA; a worker whose EMA exceeds
   ``threshold`` x the median is flagged.
2. **Slot-boundary down-scale** — flagged workers are excluded from the
   next slot's mesh (the OASiS schedule's worker count is met by the
   remaining capacity or re-planned by the scheduler; prices make the
   replacement decision economically consistent).
3. **Bounded-staleness fallback** — optional gradient-accumulation mode
   where a late microbatch is applied one step behind (the PS-style
   asynchrony knob; numerics validated in tests/test_runtime.py).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ema: float = 0.7
    threshold: float = 1.8       # x median EMA
    min_samples: int = 3


class StragglerMonitor:
    def __init__(self, n_workers: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.emas = np.zeros(n_workers)
        self.counts = np.zeros(n_workers, dtype=int)

    def record(self, worker: int, step_seconds: float) -> None:
        a = self.cfg.ema
        if self.counts[worker] == 0:
            self.emas[worker] = step_seconds
        else:
            self.emas[worker] = a * self.emas[worker] + (1 - a) * step_seconds
        self.counts[worker] += 1

    def stragglers(self) -> List[int]:
        ready = self.counts >= self.cfg.min_samples
        if ready.sum() < 2:
            return []
        med = float(np.median(self.emas[ready]))
        if med <= 0:
            return []
        return [int(i) for i in np.nonzero(
            ready & (self.emas > self.cfg.threshold * med))[0]]

    def healthy_workers(self) -> List[int]:
        bad = set(self.stragglers())
        return [i for i in range(len(self.emas)) if i not in bad]


class BoundedStaleness:
    """Apply gradients at most ``staleness`` steps late (PS-style async).
    grads enter as host arrays; ``push`` returns the (possibly stale)
    gradient to apply this step, or None while the pipe fills."""

    def __init__(self, staleness: int = 1):
        assert staleness >= 0
        self.staleness = staleness
        self.queue: List = []

    def push(self, grad):
        self.queue.append(grad)
        if len(self.queue) > self.staleness:
            return self.queue.pop(0)
        return None
