"""Fault-tolerant training driver: checkpoint/restart supervision.

``run_with_restarts`` executes a training function under supervision;
on failure (node loss is simulated by exceptions / injected faults) it
restores the latest checkpoint — including the data-pipeline cursor —
and continues.  NaN loss is treated as a fault (restore + LR notch), the
standard large-run recipe.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional


from ..ckpt import checkpoint as ckpt
from ..data.pipeline import DataPipeline, PipelineState


class FaultInjector:
    """Deterministic fault schedule for tests: raises at given steps."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


def run_with_restarts(train_fn: Callable, init_state: Dict, pipeline: DataPipeline,
                      ckpt_dir: str, total_steps: int, save_every: int = 20,
                      max_restarts: int = 5,
                      injector: Optional[FaultInjector] = None) -> Dict:
    """train_fn(state, batch, step) -> (state, loss: float).  state is a
    pytree with everything that must survive a restart."""
    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    state = init_state
    step = 0
    restarts = 0
    # resume if a checkpoint exists (crash-restart entry point)
    last = ckpt.latest_step(ckpt_dir)
    if last is not None:
        state, extra = ckpt.restore(ckpt_dir, last, init_state)
        pipeline.state = PipelineState.from_dict(extra["pipeline"])
        step = last
    losses = []
    while step < total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            batch = pipeline.next_batch()
            state, loss = train_fn(state, batch, step)
            if not math.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
            losses.append(loss)
            step += 1
            if step % save_every == 0:
                saver.save_async(step, state,
                                 extra={"pipeline": pipeline.state.to_dict()})
        except (RuntimeError, FloatingPointError) as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            last = ckpt.latest_step(ckpt_dir)
            if last is None:                   # nothing saved yet: restart cold
                state = init_state
                pipeline.state = PipelineState(0)
                step = 0
                continue
            saver.wait()
            state, extra = ckpt.restore(ckpt_dir, last, state)
            pipeline.state = PipelineState.from_dict(extra["pipeline"])
            step = last
    saver.wait()
    return {"state": state, "losses": losses, "restarts": restarts,
            "final_step": step}
