"""Pallas TPU kernel for the Mamba2 SSD chunk scan (arXiv:2405.21060 §6).

Per (batch*head) row the sequence is processed in chunks of Q steps:
quadratic attention-like compute inside the chunk (MXU: C@B^T and
score@X matmuls) and a (P, N) recurrent state carried across chunks in
VMEM scratch — the chunk dimension is the innermost (sequential) grid
axis, mirroring ``models.mamba2.ssd_chunked``.

Inputs are pre-expanded per head (groups broadcast in ops.py):
  x: (BH, L, P); dt: (BH, L); A: (BH,); B,C: (BH, L, N)
Output: y (BH, L, P) with the D skip-connection left to the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,)
    A = a_ref[0, 0]                           # scalar (this head)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    a = dt * A                                # per-step log decay (Q,)
    a_cum = jnp.cumsum(a)                     # (Q,)
    seg = a_cum[:, None] - a_cum[None, :]     # (Q, Q)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(qpos >= kpos, jnp.exp(seg), 0.0)
    scores = jnp.dot(c, b.T) * decay * dt[None, :]          # (Q, Q)
    y_intra = jnp.dot(scores, x)                            # (Q, P)

    state = state_scr[...]                                  # (P, N)
    y_inter = jnp.dot(c, state.T) * jnp.exp(a_cum)[:, None]  # (Q, P)

    last = a_cum[-1]
    w_in = jnp.exp(last - a_cum) * dt                       # (Q,)
    state_scr[...] = state * jnp.exp(last) + jnp.dot((x * w_in[:, None]).T, b)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, *, chunk: int = 128,
               interpret: bool = True) -> jax.Array:
    """x: (BH, L, P); dt: (BH, L); A: (BH,); B/C: (BH, L, N); L % chunk == 0
    (caller pads).  Returns y: (BH, L, P)."""
    BH, L, P = x.shape
    N = B.shape[-1]
    assert L % chunk == 0, "pad L to a chunk multiple in ops.py"
    nc = L // chunk
    dt2 = dt[:, None, :].reshape(BH, nc, chunk)        # blocks (1,1,chunk)
    a2 = A[:, None]                                    # (BH, 1)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt2, a2, B, C)
    return out
