"""Pure-jnp oracle for the SSD kernel: the sequential recurrence
    s_t = exp(dt_t * A) * s_{t-1} + dt_t * B_t x_t^T;   y_t = C_t . s_t
computed step by step (no chunking) — the ground truth both for the
Pallas kernel and for ``models.mamba2.ssd_chunked``."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array) -> jax.Array:
    """x: (BH, L, P); dt: (BH, L); A: (BH,); B/C: (BH, L, N) -> y (BH, L, P)."""
    BH, L, P = x.shape
    N = B.shape[-1]

    def step(s, inp):
        xt, dtt, bt, ct = inp                       # (BH,P),(BH,),(BH,N),(BH,N)
        decay = jnp.exp(dtt * A)                    # (BH,)
        s = s * decay[:, None, None] + dtt[:, None, None] * \
            jnp.einsum("bp,bn->bpn", xt, bt)
        y = jnp.einsum("bpn,bn->bp", s, ct)
        return s, y

    s0 = jnp.zeros((BH, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)
