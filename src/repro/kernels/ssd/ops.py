"""Jit'd SSD entry: handles group->head broadcast, chunk padding, head
layout; selects Pallas (interpret off-TPU) or the jnp reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_pallas
from .ref import ssd_ref


def ssd_op(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
           C: jax.Array, *, chunk: int = 128, use_pallas: bool = True
           ) -> jax.Array:
    """Model-layout wrapper.  x: (b, L, H, P); dt: (b, L, H); A: (H,);
    B/C: (b, L, G, N).  Returns (b, L, H, P)."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B[:, :, :, None, :], rep, axis=3).reshape(b, L, H, N) \
        if G != H else B
    Ch = jnp.repeat(C[:, :, :, None, :], rep, axis=3).reshape(b, L, H, N) \
        if G != H else C
    xf = x.transpose(0, 2, 1, 3).reshape(b * H, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(b * H, L)
    Af = jnp.tile(A, b)
    Bf = Bh.transpose(0, 2, 1, 3).reshape(b * H, L, N)
    Cf = Ch.transpose(0, 2, 1, 3).reshape(b * H, L, N)
    pad = (-L) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, pad)))
        Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
        Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
    if use_pallas:
        interpret = jax.default_backend() != "tpu"
        y = ssd_pallas(xf, dtf, Af, Bf, Cf, chunk=chunk, interpret=interpret)
    else:
        y = ssd_ref(xf, dtf, Af, Bf, Cf)
    y = y[:, :L].reshape(b, H, L, P).transpose(0, 2, 1, 3)
    return y
