"""Jit'd entry point: Pallas on TPU, interpret-mode elsewhere, with the
pure-jnp reference available for oracle checks."""
from __future__ import annotations

import jax

from .kernel import (minplus_pallas, minplus_plateau_pallas,
                     minplus_sweep_pallas)
from .monotone import monotone_step, run_count_np
from .ref import minplus_ref, minplus_sweep_ref


def minplus(row: jax.Array, prev: jax.Array, use_pallas: bool = True):
    if not use_pallas:
        return minplus_ref(row, prev)
    interpret = jax.default_backend() != "tpu"
    return minplus_pallas(row, prev, interpret=interpret)


def minplus_sweep(rows: jax.Array, d_total: int, use_pallas: bool = True):
    """Full T-slot DP sweep.  Pallas: one kernel launch with the carry row in
    VMEM scratch; ref: a ``lax.scan`` of per-slot min-plus convolutions."""
    if not use_pallas:
        return minplus_sweep_ref(rows, d_total)
    interpret = jax.default_backend() != "tpu"
    return minplus_sweep_pallas(rows, d_total, interpret=interpret)


def minplus_monotone(row: jax.Array, prev: jax.Array,
                     use_pallas: bool = True, r_max: int = 16):
    """Structure-aware min-plus slot ``new[d] = min_j row[j] + prev[d-j]``
    (cost-only — no argmin).

    Non-Pallas: the full jnp dispatcher from ``monotone.py``
    (certified-convex D&C / run-compressed plateau / chain fallback).
    Pallas: the run-compressed plateau kernel when the row compresses
    into at most ``r_max`` runs (checked host-side — this entry is
    eager, like a decision-time call), else the chain kernel.  Every
    path is bit-identical to ``minplus(...)``'s cost output."""
    if not use_pallas:
        return monotone_step(row, prev)
    interpret = jax.default_backend() != "tpu"
    import numpy as np
    if int(run_count_np(np.asarray(row))) <= r_max:
        return minplus_plateau_pallas(row, prev, r_max=r_max,
                                      interpret=interpret)
    return minplus_pallas(row, prev, interpret=interpret)[0]
