"""Jit'd entry point: Pallas on TPU, interpret-mode elsewhere, with the
pure-jnp reference available for oracle checks."""
from __future__ import annotations

import jax

from .kernel import minplus_pallas
from .ref import minplus_ref


def minplus(row: jax.Array, prev: jax.Array, use_pallas: bool = True):
    if not use_pallas:
        return minplus_ref(row, prev)
    interpret = jax.default_backend() != "tpu"
    return minplus_pallas(row, prev, interpret=interpret)
