"""Jit'd entry point: Pallas on TPU, interpret-mode elsewhere, with the
pure-jnp reference available for oracle checks."""
from __future__ import annotations

import jax

from .kernel import minplus_pallas, minplus_sweep_pallas
from .ref import minplus_ref, minplus_sweep_ref


def minplus(row: jax.Array, prev: jax.Array, use_pallas: bool = True):
    if not use_pallas:
        return minplus_ref(row, prev)
    interpret = jax.default_backend() != "tpu"
    return minplus_pallas(row, prev, interpret=interpret)


def minplus_sweep(rows: jax.Array, d_total: int, use_pallas: bool = True):
    """Full T-slot DP sweep.  Pallas: one kernel launch with the carry row in
    VMEM scratch; ref: a ``lax.scan`` of per-slot min-plus convolutions."""
    if not use_pallas:
        return minplus_sweep_ref(rows, d_total)
    interpret = jax.default_backend() != "tpu"
    return minplus_sweep_pallas(rows, d_total, interpret=interpret)
