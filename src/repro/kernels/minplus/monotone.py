"""Monotone (SMAWK-style) and run-compressed min-plus DP slot kernels.

``minplus_chain_step`` evaluates every candidate ``row[j] + prev[d - j]``
— O(DC * D) work per slot.  The candidate matrix ``A[d][i] =
prev[i] + row[d - i]`` is a (banded, extended-real) Monge matrix whenever
``row`` is convex: the ``prev`` terms cancel in the quadrangle
inequality, so the leftmost argmin per row is nondecreasing in ``d`` and
the row minima are a totally-monotone problem solvable by SMAWK-style
divide and conquer in O((D + DC) log D) candidate evaluations
(:func:`monotone_dnc_step`).

Two properties make the fast paths safe to substitute bit-for-bit:

* **Exact convexity certificate.**  Rounding is monotone, so the D&C
  bound propagation is only sound when the *real-arithmetic* values of
  the FP row are convex — an ulp-level violation can shift a rounded
  argmin outside the scanned range.  :func:`convex_certificate`
  therefore decides ``row[j] + row[j+2] - 2*row[j+1] >= 0`` EXACTLY
  with error-free TwoSum expansions (Knuth/Shewchuk), never with a
  rounded comparison.  Anything uncertifiable falls back.
* **Dual-split bounds.**  A rounded argmin can sit strictly left of the
  exact leftmost argmin, so the D&C recursion propagates the RIGHTMOST
  rounded argmin as the left child's upper bound and the LEFTMOST as
  the right child's lower bound; either range then always contains an
  exact argmin, and ``min`` of the rounded candidates over any range
  containing an exact argmin equals the chain's value bit-for-bit.

Real COST_t rows from the paper's Alg. 2 are *staircases* — greedy
fill cost composed with ``W(d) = ceil(alpha * d)`` — which are NOT
convex (each step lands a negative second difference), but they
compress into few bitwise-equal runs.  :func:`plateau_step` exploits
that structure directly: ``row[j]`` is a single constant ``c_w`` per
run, so ``min_{j in run} fl(c_w + prev[d-j]) = fl(c_w + min_j
prev[d-j])`` by monotonicity of rounding, and the per-run window
minimum comes from a power-of-two doubling table of the padded carry
(two contiguous slices per run — no gathers).  O((D + DC) * (L + log))
for L runs, bit-exact for ANY row.

:func:`monotone_step` dispatches: certified-convex rows take the D&C,
run-compressible rows the plateau scan, everything else the chain —
and the choice is observable (path codes) so the engine can count
fallbacks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .tiled import minplus_chain_step

# dispatcher path codes (returned by monotone_step_with_path)
PATH_DNC = 0
PATH_PLATEAU = 1
PATH_CHAIN = 2

# default run-count gate: the plateau scan costs ~2 fused passes per run
# plus the doubling-table build, the chain one pass per band tap; below
# a third of the band the plateau wins on CPU XLA (see the ``minplus``
# micro-bench section)
_PLATEAU_FRACTION = 3


# ---------------------------------------------------------------------------
# Exact convexity certificate
# ---------------------------------------------------------------------------

def _two_sum(a, b):
    """Error-free transform: returns (s, e) with s = fl(a+b), s+e = a+b
    exactly (Knuth's TwoSum, branch-free, valid in any IEEE precision)."""
    s = a + b
    a1 = s - b
    b1 = s - a1
    return s, (a - a1) + (b - b1)


def _nonneg_sum3(x, y, z):
    """Exact ``x + y + z >= 0`` for finite floats, elementwise.

    Grows the expansion [x] by y then z (Shewchuk's grow-expansion):
    the three output components are nonoverlapping with the last the
    largest, so the sign of the exact sum is the sign of the first
    nonzero component from the top.  Overflow to inf poisons the
    residuals with NaNs, whose comparisons are all False — i.e. the
    certificate conservatively fails.
    """
    s, e = _two_sum(x, y)
    q1, h0 = _two_sum(z, e)
    q2, h1 = _two_sum(q1, s)
    return jnp.where(q2 != 0, q2 > 0, jnp.where(h1 != 0, h1 > 0, h0 >= 0))


def convex_certificate(row: jax.Array) -> jax.Array:
    """True iff ``row`` (..., DC+1) is certifiably convex in EXACT
    arithmetic over its FP values: a finite prefix (inf only as a
    suffix, no NaN / -inf anywhere) whose exact second differences are
    all nonnegative.  This is the soundness condition for
    :func:`monotone_dnc_step` — a rounded >= would admit ulp-level
    concavities that break the Monge argmin monotonicity."""
    f = jnp.isfinite(row)
    clean = jnp.all((row == row) & (row > -jnp.inf), axis=-1)
    suffix_ok = jnp.all(f[..., 1:] <= f[..., :-1], axis=-1)
    if row.shape[-1] < 3:
        return clean & suffix_ok
    x, c, y = row[..., :-2], row[..., 1:-1], row[..., 2:]
    tri = _nonneg_sum3(x, y, -2.0 * c)
    # only triples fully inside the finite prefix constrain convexity
    # (given suffix_ok, isfinite(y) implies x and c are finite too)
    tri_ok = jnp.all(jnp.where(jnp.isfinite(y), tri, True), axis=-1)
    return clean & suffix_ok & tri_ok


def run_count(row: jax.Array) -> jax.Array:
    """Number of maximal runs of bitwise-equal consecutive values."""
    if row.shape[-1] < 2:
        return jnp.ones(row.shape[:-1], jnp.int32)
    neq = row[..., 1:] != row[..., :-1]
    return 1 + jnp.sum(neq, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Plateau (run-compressed) path
# ---------------------------------------------------------------------------

def _ilog2(n: jax.Array) -> jax.Array:
    """floor(log2(n)) for traced positive int32."""
    return 31 - jax.lax.clz(n.astype(jnp.int32))


def plateau_step(row: jax.Array, prev: jax.Array) -> jax.Array:
    """Run-compressed min-plus slot: bit-exact for any (DC+1,) ``row``
    and (D+1,) ``prev`` free of NaN/-inf; cost scales with the number
    of runs, not the band width.

    Within a run ``row[j]`` is one constant, so the run's best
    candidate is ``fl(c_w + min_{j in run} prev[d - j])`` — a window
    minimum of the left-inf-padded carry served by a power-of-two
    doubling table with two contiguous dynamic slices per run.
    """
    dc1 = row.shape[0]
    d1 = prev.shape[0]
    dt = prev.dtype
    js = jnp.arange(dc1, dtype=jnp.int32)
    if dc1 > 1:
        neq = row[1:] != row[:-1]
        rid = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(neq).astype(jnp.int32)])
        n_runs = rid[-1] + 1
    else:
        rid = jnp.zeros((1,), jnp.int32)
        n_runs = jnp.int32(1)
    starts = jnp.full((dc1,), dc1 - 1, jnp.int32).at[rid].min(js)
    ends = jnp.zeros((dc1,), jnp.int32).at[rid].max(js)

    # doubling table over the padded carry: tab[k][i] = min prev_pad[i:i+2^k]
    width = dc1 + d1
    prev_pad = jnp.concatenate([jnp.full((dc1,), jnp.inf, dt), prev])
    kmax = (dc1 - 1).bit_length() + 1 if dc1 > 1 else 1
    tabs = [prev_pad]
    for k in range(1, kmax):
        s = 1 << (k - 1)
        nxt = jnp.minimum(tabs[-1][:width - s], tabs[-1][s:])
        tabs.append(jnp.concatenate([nxt, jnp.full((s,), jnp.inf, dt)]))
    tab = jnp.concatenate(tabs)                   # (kmax * width,)

    def run(w, new):
        s_w = starts[w]
        e_w = ends[w]
        c_w = row[s_w]
        kw = _ilog2(e_w - s_w + 1)
        base = kw * width + dc1
        lo = jax.lax.dynamic_slice(tab, (base - e_w,), (d1,))
        hi = jax.lax.dynamic_slice(
            tab, (base - s_w - jnp.left_shift(1, kw) + 1,), (d1,))
        return jnp.minimum(new, c_w + jnp.minimum(lo, hi))

    return jax.lax.fori_loop(
        0, n_runs, run, jnp.full((d1,), jnp.inf, dt))


def plateau_step_unrolled(row: jax.Array, prev: jax.Array,
                          r_max: int) -> jax.Array:
    """:func:`plateau_step` with the run loop statically unrolled to
    ``r_max`` iterations — the engine's in-scan variant, where a
    ``fori_loop``'s ~10 us/iteration dispatch overhead on CPU XLA would
    eat the win.  ONLY sound when ``row`` has at most ``r_max`` runs
    (and no NaN / -inf): the per-tile gate in ``core.schedule_jax``
    checks exactly that before routing here.  Unroll slots beyond the
    actual run count contribute +inf (their garbage window reads are
    masked before the min), so any run count <= ``r_max`` is bit-exact.
    """
    dc1 = row.shape[0]
    d1 = prev.shape[0]
    dt = prev.dtype
    js = jnp.arange(dc1, dtype=jnp.int32)
    if dc1 > 1:
        neq = row[1:] != row[:-1]
        rid = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(neq).astype(jnp.int32)])
        n_runs = rid[-1] + 1
    else:
        rid = jnp.zeros((1,), jnp.int32)
        n_runs = jnp.int32(1)
    rid_c = jnp.clip(rid, 0, r_max - 1)          # identity when sound
    starts = jnp.full((r_max,), dc1 - 1, jnp.int32).at[rid_c].min(js)
    ends = jnp.zeros((r_max,), jnp.int32).at[rid_c].max(js)

    width = dc1 + d1
    prev_pad = jnp.concatenate([jnp.full((dc1,), jnp.inf, dt), prev])
    kmax = (dc1 - 1).bit_length() + 1 if dc1 > 1 else 1
    tabs = [prev_pad]
    for k in range(1, kmax):
        s = 1 << (k - 1)
        nxt = jnp.minimum(tabs[-1][:width - s], tabs[-1][s:])
        tabs.append(jnp.concatenate([nxt, jnp.full((s,), jnp.inf, dt)]))
    tab = jnp.concatenate(tabs)                   # (kmax * width,)

    new = jnp.full((d1,), jnp.inf, dt)
    for w in range(r_max):
        s_w = starts[w]
        e_w = ends[w]
        c_w = row[s_w]
        kw = _ilog2(e_w - s_w + 1)
        base = kw * width + dc1
        lo = jax.lax.dynamic_slice(tab, (base - e_w,), (d1,))
        hi = jax.lax.dynamic_slice(
            tab, (base - s_w - jnp.left_shift(1, kw) + 1,), (d1,))
        cand = c_w + jnp.minimum(lo, hi)
        new = jnp.minimum(new, jnp.where(w < n_runs, cand, jnp.inf))
    return new


# ---------------------------------------------------------------------------
# Convex divide-and-conquer (SMAWK-style) path
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dnc_levels(d1: int):
    """Static binary-recursion structure over [0, d1): per level, the
    segment midpoints (each d is a midpoint at exactly one level), each
    cell's segment id at that level, and left/right-of-mid masks."""
    segs = [(0, d1)]
    levels = []
    while segs:
        mids = []
        segid = np.zeros(d1, np.int32)
        left = np.zeros(d1, bool)
        right = np.zeros(d1, bool)
        nxt = []
        for si, (s, e) in enumerate(segs):
            mid = (s + e) // 2
            mids.append(mid)
            segid[s:mid] = si
            left[s:mid] = True
            segid[mid + 1:e] = si
            right[mid + 1:e] = True
            if s < mid:
                nxt.append((s, mid))
            if mid + 1 < e:
                nxt.append((mid + 1, e))
        levels.append((np.asarray(mids, np.int32), segid, left, right))
        segs = nxt
    return tuple(levels)


def monotone_dnc_step(row: jax.Array, prev: jax.Array):
    """Row minima of the banded Monge matrix ``A[d][i] = prev[i] +
    row[d - i]`` by level-synchronous divide and conquer.  Returns
    ``(new, overflow)``; ``new`` equals the chain bit-for-bit whenever
    ``row`` passes :func:`convex_certificate` and ``overflow`` is
    False.  ``overflow`` flags a (tie-driven) candidate-buffer spill —
    the caller must then discard ``new`` and use the chain.

    Each level scans, for every midpoint ``d``, the candidate range
    ``[max(lo_d, d - m', 0), min(hi_d, d, P)]`` (``m'``/``P``: last
    finite index of row/prev — candidates outside are +inf and rows
    beyond ``P + m'`` are skipped at zero cost), then tightens the
    children's bounds with the dual-split rule from the module
    docstring.  All-inf midpoints propagate their unshrunk range: the
    monotonicity theorem only covers rows with a finite minimum.
    """
    from jax.ops import segment_max, segment_min

    dc1 = row.shape[0]
    d1 = prev.shape[0]
    dt = prev.dtype
    mprime = jnp.max(jnp.where(jnp.isfinite(row),
                               jnp.arange(dc1, dtype=jnp.int32), -1))
    pmax = jnp.max(jnp.where(jnp.isfinite(prev),
                             jnp.arange(d1, dtype=jnp.int32), -1))
    lo_b = jnp.zeros((d1,), jnp.int32)
    hi_b = jnp.full((d1,), d1 - 1, jnp.int32)
    new = jnp.full((d1,), jnp.inf, dt)
    overflow = jnp.bool_(False)

    for mids_np, segid_np, left_np, right_np in _dnc_levels(d1):
        n_seg = len(mids_np)
        cap = d1 + n_seg + 64
        mids = jnp.asarray(mids_np)
        lo_m = jnp.maximum(jnp.maximum(lo_b[mids], mids - mprime), 0)
        hi_m = jnp.minimum(jnp.minimum(hi_b[mids], mids), pmax)
        w = jnp.maximum(hi_m - lo_m + 1, 0)
        off = jnp.cumsum(w) - w                          # exclusive prefix
        total = off[-1] + w[-1]
        overflow = overflow | (total > cap)
        pos = jnp.arange(cap, dtype=jnp.int32)
        seg = jnp.clip(jnp.searchsorted(off, pos, side="right").astype(
            jnp.int32) - 1, 0, n_seg - 1)
        i_idx = lo_m[seg] + pos - off[seg]
        valid = (pos < total) & (i_idx >= lo_m[seg]) & (i_idx <= hi_m[seg])
        i_c = jnp.clip(i_idx, 0, d1 - 1)
        j_c = jnp.clip(mids[seg] - i_c, 0, dc1 - 1)
        vals = jnp.where(valid, row[j_c] + prev[i_c],
                         jnp.asarray(jnp.inf, dt))
        segmin = segment_min(vals, seg, num_segments=n_seg,
                             indices_are_sorted=True)
        new = new.at[mids].set(segmin)
        ismin = valid & (vals == segmin[seg])
        arg_l = segment_min(jnp.where(ismin, i_c, d1), seg,
                            num_segments=n_seg, indices_are_sorted=True)
        arg_r = segment_max(jnp.where(ismin, i_c, -1), seg,
                            num_segments=n_seg, indices_are_sorted=True)
        has = (w > 0) & jnp.isfinite(segmin)
        arg_l = jnp.where(has, arg_l, lo_m).astype(jnp.int32)
        arg_r = jnp.where(has, arg_r, hi_m).astype(jnp.int32)
        segid = jnp.asarray(segid_np)
        hi_b = jnp.where(jnp.asarray(left_np),
                         jnp.minimum(hi_b, arg_r[segid]), hi_b)
        lo_b = jnp.where(jnp.asarray(right_np),
                         jnp.maximum(lo_b, arg_l[segid]), lo_b)
    return new, overflow


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def monotone_step_with_path(row: jax.Array, prev: jax.Array, *,
                            plateau_max: int | None = None):
    """One DP slot ``new[d] = min_j row[j] + prev[d - j]`` with the
    structure-aware dispatch: certified-convex rows -> D&C, rows with
    at most ``plateau_max`` runs -> plateau scan, else -> chain.
    Returns ``(new, path)`` with ``path`` one of PATH_DNC /
    PATH_PLATEAU / PATH_CHAIN (the path actually used — a D&C buffer
    spill reports PATH_CHAIN).  Bit-exact vs ``minplus_chain_step`` on
    every path for any inputs."""
    dc1 = row.shape[0]
    if plateau_max is None:
        plateau_max = max(dc1 // _PLATEAU_FRACTION, 1)
    clean = jnp.all((row == row) & (row > -jnp.inf)) & \
        jnp.all((prev == prev) & (prev > -jnp.inf))
    convex = convex_certificate(row) & clean
    plat = clean & (run_count(row) <= plateau_max)

    def chain(_):
        return minplus_chain_step(row[None], prev[None])[0], jnp.int32(
            PATH_CHAIN)

    def dnc(_):
        new, ovf = monotone_dnc_step(row, prev)
        return jax.lax.cond(
            ovf, chain, lambda _: (new, jnp.int32(PATH_DNC)), None)

    def plateau(_):
        return plateau_step(row, prev), jnp.int32(PATH_PLATEAU)

    branch = jnp.where(convex, 0, jnp.where(plat, 1, 2))
    return jax.lax.switch(branch, [dnc, plateau, chain], None)


def monotone_step(row: jax.Array, prev: jax.Array, *,
                  plateau_max: int | None = None) -> jax.Array:
    """Value-only form of :func:`monotone_step_with_path`."""
    return monotone_step_with_path(row, prev, plateau_max=plateau_max)[0]


def monotone_sweep(rows: jax.Array, d_total: int) -> jax.Array:
    """Cost-only T-slot DP sweep through the monotone dispatcher;
    bit-identical to ``minplus_sweep_cost`` on any input."""
    d1 = d_total + 1
    init = jnp.full((d1,), jnp.inf, rows.dtype).at[0].set(0.0)

    def slot(prev, row):
        new = monotone_step(row, prev)
        return new, new

    _, costs = jax.lax.scan(slot, init, rows)
    return costs


# ---------------------------------------------------------------------------
# Numpy oracles (dispatch decisions + flags for the host COST-row path)
# ---------------------------------------------------------------------------

def _two_sum_np(a, b):
    """Host-side :func:`_two_sum` (same exact arithmetic)."""
    s = a + b
    a1 = s - b
    b1 = s - a1
    return s, (a - a1) + (b - b1)


def _nonneg_sum3_np(x, y, z):
    with np.errstate(invalid="ignore"):
        s, e = _two_sum_np(x, y)
        q1, h0 = _two_sum_np(z, e)
        q2, h1 = _two_sum_np(q1, s)
    return np.where(q2 != 0, q2 > 0, np.where(h1 != 0, h1 > 0, h0 >= 0))


def convex_certificate_np(rows: np.ndarray) -> np.ndarray:
    """Host-side :func:`convex_certificate` (same exact arithmetic),
    vectorized over leading axes of (..., DC+1) COST rows."""
    rows = np.asarray(rows)
    f = np.isfinite(rows)
    with np.errstate(invalid="ignore"):
        clean = np.all((rows == rows) & (rows > -np.inf), axis=-1)
    suffix_ok = np.all(f[..., 1:] <= f[..., :-1], axis=-1)
    if rows.shape[-1] < 3:
        return clean & suffix_ok
    x, c, y = rows[..., :-2], rows[..., 1:-1], rows[..., 2:]
    tri = _nonneg_sum3_np(x, y, -2.0 * c)
    tri_ok = np.all(np.where(np.isfinite(y), tri, True), axis=-1)
    return clean & suffix_ok & tri_ok


def run_count_np(rows: np.ndarray) -> np.ndarray:
    rows = np.asarray(rows)
    if rows.shape[-1] < 2:
        return np.ones(rows.shape[:-1], np.int32)
    return (1 + np.sum(rows[..., 1:] != rows[..., :-1], axis=-1)).astype(
        np.int32)


def monotone_path_ref(row: np.ndarray, plateau_max: int | None = None) -> int:
    """Numpy oracle for the dispatch decision (ignoring D&C overflow):
    which path :func:`monotone_step_with_path` selects for ``row``."""
    row = np.asarray(row)
    dc1 = row.shape[-1]
    if plateau_max is None:
        plateau_max = max(dc1 // _PLATEAU_FRACTION, 1)
    if bool(convex_certificate_np(row)):
        return PATH_DNC
    with np.errstate(invalid="ignore"):
        clean = bool(np.all((row == row) & (row > -np.inf)))
    if clean and int(run_count_np(row)) <= plateau_max:
        return PATH_PLATEAU
    return PATH_CHAIN
