"""Pure-jnp oracle for the banded min-plus convolution."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def minplus_ref(row: jax.Array, prev: jax.Array):
    """new[d] = min_{d'} row[d'] + prev[d-d'];  returns (new, argmin)."""
    d1 = prev.shape[0]
    dc1 = row.shape[0]
    ids = jnp.arange(d1)[:, None] - jnp.arange(dc1)[None, :]
    prev_ext = jnp.append(prev.astype(jnp.float32), jnp.inf)
    cand = row.astype(jnp.float32)[None, :] + prev_ext[jnp.where(ids >= 0, ids, -1)]
    cand = jnp.where(ids >= 0, cand, jnp.inf)
    arg = jnp.argmin(cand, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(cand, arg[:, None], axis=1)[:, 0], arg


# below this many candidate cells per slot the (D+1, DC+1) matrix inner is
# cheaper than a window scan (scan steps have fixed per-iteration overhead)
_MATRIX_CELLS = 32768


def minplus_sweep_ref(rows: jax.Array, d_total: int):
    """T-slot DP sweep: scan over slots, banded min-plus per slot.

    rows: (T, DC+1); returns (cost (T, D+1), split (T, D+1) int32) for the
    recurrence new_t[d] = min_d' rows[t, d'] + new_{t-1}[d - d'] with
    new_{-1} = [0, inf, ...].  Dtype-preserving (float64 under x64 — the
    fused engine's exactness relies on it); argmin keeps the smallest d'
    like ``np.argmin``.

    Two inner forms with identical outputs, chosen by static size: small
    slots build the (D+1, DC+1) candidate matrix and argmin it; large slots
    slide contiguous windows of the left-padded carry over a scan — ~4x
    faster on CPU XLA than the gather matrix and O(D) memory.
    """
    d1 = d_total + 1
    dc1 = rows.shape[1]
    init = jnp.full((d1,), jnp.inf, rows.dtype).at[0].set(0.0)

    if d1 * dc1 <= _MATRIX_CELLS:
        ids = jnp.arange(d1)[:, None] - jnp.arange(dc1)[None, :]

        def slot(prev, row):
            prev_ext = jnp.append(prev, jnp.asarray(jnp.inf, prev.dtype))
            cand = row[None, :] + prev_ext[jnp.where(ids >= 0, ids, -1)]
            cand = jnp.where(ids >= 0, cand, jnp.inf)
            arg = jnp.argmin(cand, axis=1).astype(jnp.int32)
            new = jnp.take_along_axis(cand, arg[:, None], axis=1)[:, 0]
            return new, (new, arg)
    else:
        def slot(prev, row):
            # prev_pad[k] = prev[k - dc1]; window j starts at dc1 - j
            prev_pad = jnp.concatenate(
                [jnp.full((dc1,), jnp.inf, prev.dtype), prev])

            def step(carry, j):
                best, arg = carry
                win = jax.lax.dynamic_slice(prev_pad, (dc1 - j,), (d1,))
                cand = row[j] + win
                take = cand < best
                return (jnp.where(take, cand, best),
                        jnp.where(take, j.astype(jnp.int32), arg)), None

            (new, arg), _ = jax.lax.scan(
                step, (jnp.full((d1,), jnp.inf, prev.dtype),
                       jnp.zeros((d1,), jnp.int32)), jnp.arange(dc1))
            return new, (new, arg)

    _, (costs, args) = jax.lax.scan(slot, init, rows)
    return costs, args


# fully-unrolled chains above this band width blow up compile time; fall
# back to dynamically-indexed blocks of this many taps per scan step
_UNROLL_MAX = 512
_CHAIN_BLOCK = 32


def minplus_sweep_cost(rows: jax.Array, d_total: int) -> jax.Array:
    """Cost-only T-slot DP sweep (no argmin carry): returns (T, D+1).

    The fused engine's hot path: because each slot's body is an unrolled
    chain of STATIC slices of the left-padded carry —
    ``min_j row[j] + prev_pad[DC+1-j : …+D+1]`` — XLA fuses it into one
    vectorised loop instead of a per-tap scan (~6x faster on CPU).  Split
    decisions are recovered afterwards from the stored cost table: the
    argmin over the same candidate values at the backtracked cells, which
    reproduces the carried argmin exactly (first minimum wins in both).
    """
    d1 = d_total + 1
    dc1 = rows.shape[1]
    init = jnp.full((d1,), jnp.inf, rows.dtype).at[0].set(0.0)

    if dc1 <= _UNROLL_MAX:
        def slot(prev, row):
            prev_pad = jnp.concatenate(
                [jnp.full((dc1,), jnp.inf, prev.dtype), prev])
            cands = [row[j] + jax.lax.slice(prev_pad, (dc1 - j,),
                                            (dc1 - j + d1,))
                     for j in range(dc1)]
            new = functools.reduce(jnp.minimum, cands)
            return new, new
    else:
        blk = _CHAIN_BLOCK
        nb = (dc1 + blk - 1) // blk

        def slot(prev, row):
            rowp = jnp.concatenate(
                [row, jnp.full((nb * blk - dc1,), jnp.inf, row.dtype)])
            prev_pad = jnp.concatenate(
                [jnp.full((nb * blk,), jnp.inf, prev.dtype), prev])

            def step(best, b):
                # taps j = b*blk + i share one dynamically-positioned window
                base = nb * blk - b * blk
                win = jax.lax.dynamic_slice(
                    prev_pad, (base - (blk - 1),), (d1 + blk - 1,))
                rb = jax.lax.dynamic_slice(rowp, (b * blk,), (blk,))
                for i in range(blk):
                    best = jnp.minimum(best, rb[i] + jax.lax.slice(
                        win, (blk - 1 - i,), (blk - 1 - i + d1,)))
                return best, None

            new, _ = jax.lax.scan(
                step, jnp.full((d1,), jnp.inf, prev.dtype), jnp.arange(nb))
            return new, new

    _, costs = jax.lax.scan(slot, init, rows)
    return costs
