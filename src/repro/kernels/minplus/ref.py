"""Pure-jnp oracle for the banded min-plus convolution."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(row: jax.Array, prev: jax.Array):
    """new[d] = min_{d'} row[d'] + prev[d-d'];  returns (new, argmin)."""
    d1 = prev.shape[0]
    dc1 = row.shape[0]
    ids = jnp.arange(d1)[:, None] - jnp.arange(dc1)[None, :]
    prev_ext = jnp.append(prev.astype(jnp.float32), jnp.inf)
    cand = row.astype(jnp.float32)[None, :] + prev_ext[jnp.where(ids >= 0, ids, -1)]
    cand = jnp.where(ids >= 0, cand, jnp.inf)
    arg = jnp.argmin(cand, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(cand, arg[:, None], axis=1)[:, 0], arg
