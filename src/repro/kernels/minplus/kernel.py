"""Pallas TPU kernel for the banded min-plus (tropical) convolution at the
heart of the paper's DP subroutine (Alg. 2):

    new[d]  = min_{d' in [0, DC]} row[d'] + prev[d - d']
    arg[d]  = argmin_{d'} (same)

This is the only super-linear term in OASiS (O(T N^2 E^2), Theorem 3) —
the paper's hot spot.  Min-plus is not a ring matmul, so the MXU cannot
be used; the kernel targets the VPU with lane-aligned (multiple-of-128)
blocks.  ``prev`` is small enough (D <= ~32k floats) to live fully in
VMEM; the output is blocked over d and each block slides a window over
the left-padded ``prev``.

Layout: 2-D (1, L) row vectors — keeps the last dimension on lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_D = 512


def _minplus_kernel(row_ref, prevpad_ref, out_ref, arg_ref, *, dc1: int,
                    block: int):
    """row: (1, DCpad); prevpad: (1, D1 + DCpad); out/arg: (1, block)."""
    i = pl.program_id(0)
    row = row_ref[0, :]                      # (DCpad,)
    base = i * block
    best = jnp.full((block,), jnp.inf, jnp.float32)
    arg = jnp.zeros((block,), jnp.int32)

    def body(j, carry):
        best, arg = carry
        # new[d] = row[j] + prev[d - j]  -> window starts at base + DCpad-... :
        # prevpad[k] = prev[k - dcpad]; for output offset o in [0, block):
        #   prev[base + o - j] = prevpad[base + o - j + dcpad]
        start = base + dc1 - 1 - j
        window = jax.lax.dynamic_slice(prevpad_ref[0, :], (start,), (block,))
        cand = row[j] + window
        take = cand < best
        return jnp.where(take, cand, best), jnp.where(take, j, arg)

    best, arg = jax.lax.fori_loop(0, dc1, body, (best, arg))
    out_ref[0, :] = best
    arg_ref[0, :] = arg


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_pallas(row: jax.Array, prev: jax.Array, *, interpret: bool = True):
    """row: (DC+1,) float32 (+inf for infeasible); prev: (D+1,).
    Returns (new (D+1,), argmin (D+1,)).  Sizes are padded to 128 lanes."""
    d1 = prev.shape[0]
    dc1 = row.shape[0]
    block = min(BLOCK_D, ((d1 + 127) // 128) * 128)
    d1p = ((d1 + block - 1) // block) * block
    # prevpad[k] = prev[k - (dc1-1)]; +inf outside
    prevpad = jnp.full((1, d1p + dc1 - 1 + block), jnp.inf, jnp.float32)
    prevpad = jax.lax.dynamic_update_slice(
        prevpad, prev.astype(jnp.float32)[None, :], (0, dc1 - 1))
    rowp = row.astype(jnp.float32)[None, :]
    grid = (d1p // block,)
    out, arg = pl.pallas_call(
        functools.partial(_minplus_kernel, dc1=dc1, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, dc1), lambda i: (0, 0)),
            pl.BlockSpec((1, prevpad.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d1p), jnp.float32),
            jax.ShapeDtypeStruct((1, d1p), jnp.int32),
        ],
        interpret=interpret,
    )(rowp, prevpad)
    return out[0, :d1], arg[0, :d1]


# ---------------------------------------------------------------------------
# Fused T-slot DP sweep: ONE kernel launch for the whole Alg. 2 recurrence
#     cost_t[d] = min_{d'} rows[t, d'] + cost_{t-1}[d - d']
# The grid iterates over slots (sequential "arbitrary" semantics on TPU); the
# carried row cost_{t-1} lives in a VMEM scratch buffer across grid steps, so
# the sweep costs one launch instead of T tiny ones under ``lax.scan``.
# ---------------------------------------------------------------------------

def _minplus_sweep_kernel(rows_ref, out_ref, arg_ref, prev_ref, *, dc1p: int,
                          d1p: int):
    """rows block: (1, dc1p); out/arg blocks: (1, d1p); prev scratch holds the
    left-inf-padded carry: prev[k] = prev_ref[0, k + dc1p - 1]."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, dc1p + d1p), 1)
        prev_ref[0, :] = jnp.where(lane[0] == dc1p - 1, 0.0, jnp.inf
                                   ).astype(jnp.float32)

    row = rows_ref[0, :]                       # (dc1p,), +inf beyond DC
    best = jnp.full((d1p,), jnp.inf, jnp.float32)
    arg = jnp.zeros((d1p,), jnp.int32)

    def body(j, carry):
        best, arg = carry
        window = jax.lax.dynamic_slice(prev_ref[0, :], (dc1p - 1 - j,), (d1p,))
        cand = row[j] + window
        take = cand < best
        return jnp.where(take, cand, best), jnp.where(take, j, arg)

    best, arg = jax.lax.fori_loop(0, dc1p, body, (best, arg))
    out_ref[0, :] = best
    arg_ref[0, :] = arg
    prev_ref[0, dc1p - 1:dc1p - 1 + d1p] = best     # carry to slot t+1


@functools.partial(jax.jit, static_argnames=("d_total", "interpret"))
def minplus_sweep_pallas(rows: jax.Array, d_total: int, *,
                         interpret: bool = True):
    """rows: (T, DC+1) float32 (+inf infeasible).  Returns
    (cost (T, D+1) float32, split (T, D+1) int32) for the full DP sweep with
    init carry [0, inf, ...] — one kernel launch for all T slots."""
    T, dc1 = rows.shape
    d1 = d_total + 1
    dc1p = ((dc1 + 127) // 128) * 128
    d1p = ((d1 + 127) // 128) * 128
    rowsp = jnp.full((T, dc1p), jnp.inf, jnp.float32)
    rowsp = jax.lax.dynamic_update_slice(
        rowsp, rows.astype(jnp.float32), (0, 0))
    out, arg = pl.pallas_call(
        functools.partial(_minplus_sweep_kernel, dc1p=dc1p, d1p=d1p),
        grid=(T,),
        in_specs=[pl.BlockSpec((1, dc1p), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, d1p), lambda i: (i, 0)),
            pl.BlockSpec((1, d1p), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, d1p), jnp.float32),
            jax.ShapeDtypeStruct((T, d1p), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, dc1p + d1p), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(rowsp)
    return out[:, :d1], arg[:, :d1]


# ---------------------------------------------------------------------------
# Run-compressed (plateau) slot: the Pallas variant of the monotone path.
# Real COST_t rows are staircases (see kernels/minplus/monotone.py), so the
# row collapses into L bitwise-equal runs; each run's best candidate is its
# constant plus a window minimum of the carry, served from a power-of-two
# doubling table in VMEM scratch — O((D + DC) * (L + log DC)) VPU work
# instead of the chain's O(D * DC), bit-exact for any row (monotonicity of
# rounding: fl(c + min prev) == min fl(c + prev) for a constant c).
# ---------------------------------------------------------------------------

def _minplus_plateau_kernel(row_ref, prevpad_ref, out_ref, tab_ref, *,
                            dc1p: int, d1p: int, kmax: int, r_max: int):
    """row: (1, dc1p); prevpad: (1, dc1p + d1p) left-inf-padded carry;
    out: (1, d1p); tab scratch: (kmax, dc1p + d1p) doubling table with
    tab[k][i] = min prevpad[i : i + 2^k]."""
    row = row_ref[0, :]
    tab_ref[0, :] = prevpad_ref[0, :]
    for k in range(1, kmax):
        s = 1 << (k - 1)
        lvl = tab_ref[k - 1, :]
        shifted = jnp.concatenate(
            [lvl[s:], jnp.full((s,), jnp.inf, jnp.float32)])
        tab_ref[k, :] = jnp.minimum(lvl, shifted)

    js = jax.lax.broadcasted_iota(jnp.int32, (1, dc1p), 1)[0]
    neq = jnp.concatenate(
        [jnp.zeros((1,), jnp.bool_), row[1:] != row[:-1]])
    rid = jnp.cumsum(neq.astype(jnp.int32))
    n_runs = rid[dc1p - 1] + 1

    def run(w, best):
        mask = rid == w
        s_w = jnp.min(jnp.where(mask, js, dc1p))
        e_w = jnp.max(jnp.where(mask, js, -1))
        c_w = jnp.min(jnp.where(mask, row, jnp.inf))
        kw = 31 - jax.lax.clz(jnp.maximum(e_w - s_w + 1, 1))
        # window min of prevpad[d + dc1p - e_w : d + dc1p - s_w + 1] as
        # two (overlapping) power-of-two slices of level kw
        lo = jax.lax.dynamic_slice(
            tab_ref[...], (kw, dc1p - e_w), (1, d1p))[0]
        hi = jax.lax.dynamic_slice(
            tab_ref[...], (kw, dc1p - s_w - (1 << kw) + 1), (1, d1p))[0]
        cand = c_w + jnp.minimum(lo, hi)
        return jnp.minimum(best, jnp.where(w < n_runs, cand, jnp.inf))

    out_ref[0, :] = jax.lax.fori_loop(
        0, r_max, run, jnp.full((d1p,), jnp.inf, jnp.float32))


@functools.partial(jax.jit, static_argnames=("r_max", "interpret"))
def minplus_plateau_pallas(row: jax.Array, prev: jax.Array, *,
                           r_max: int = 16, interpret: bool = True):
    """row: (DC+1,) float32 (+inf infeasible); prev: (D+1,).  Returns
    ``new (D+1,)`` — cost-only, no argmin (the engine backtracks from
    stored DP columns, not per-slot args).  ONLY sound when ``row`` has
    at most ``r_max`` maximal runs of bitwise-equal values; the caller
    gates on :func:`repro.kernels.minplus.monotone.run_count`.  Lane
    padding appends one +inf run, which is accounted for internally."""
    d1 = prev.shape[0]
    dc1 = row.shape[0]
    dc1p = ((dc1 + 127) // 128) * 128
    d1p = ((d1 + 127) // 128) * 128
    rowp = jnp.full((1, dc1p), jnp.inf, jnp.float32)
    rowp = jax.lax.dynamic_update_slice(
        rowp, row.astype(jnp.float32)[None, :], (0, 0))
    prevpad = jnp.full((1, dc1p + d1p), jnp.inf, jnp.float32)
    prevpad = jax.lax.dynamic_update_slice(
        prevpad, prev.astype(jnp.float32)[None, :], (0, dc1p))
    kmax = (dc1p - 1).bit_length() + 1 if dc1p > 1 else 1
    r_eff = r_max + (1 if dc1p > dc1 else 0)
    out, = pl.pallas_call(
        functools.partial(_minplus_plateau_kernel, dc1p=dc1p, d1p=d1p,
                          kmax=kmax, r_max=r_eff),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, dc1p), lambda i: (0, 0)),
            pl.BlockSpec((1, dc1p + d1p), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, d1p), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, d1p), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((kmax, dc1p + d1p), jnp.float32)],
        interpret=interpret,
    )(rowp, prevpad)
    return out[0, :d1]
