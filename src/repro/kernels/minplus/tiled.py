"""Horizon-tiled min-plus DP building blocks.

The full-horizon sweeps in ``ref.py`` process all ``T`` slots
unconditionally, so a decision's DP cost scales linearly in the horizon
even when the job's utility has decayed to nothing long before ``T``.
The tiled engine (``core/schedule_jax``) instead walks the horizon in
``TILE``-slot blocks inside a ``lax.while_loop``, skipping the blocks
before the job's arrival and stopping as soon as no remaining slot can
beat the incumbent payoff (an exact bound — see the engine docstring).

This module holds the batched per-slot/per-tile primitives that make the
tile body cheap and keeps them independently testable against
``minplus_sweep_cost``:

* ``minplus_chain_step``  — one DP slot for a whole lane batch,
  ``new[b, d] = min_j rows[b, j] + prev[b, d - j]``, as an unrolled (or
  block-scanned, for wide bands) chain of static slices of the
  left-padded carry: the same candidate ordering as the reference scan,
  so costs are bit-identical in any dtype.
* ``minplus_tile``        — a ``TILE``-slot chain segment: scan of
  ``minplus_chain_step`` over the tile, returning every intermediate
  column (the engine stores them for the split backtrack).
* ``minplus_sweep_tiled`` — a full sweep built from tiles with a dynamic
  ``start`` slot, equal to ``minplus_sweep_cost`` on identity prefixes;
  the oracle form the kernel tests pin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Tile width shared with the fused engine: big enough that per-tile fixed
# costs (price slices, argsorts, while_loop bookkeeping) amortize, small
# enough that the early-exit check fires with useful granularity.
TILE = 64

# fully-unrolled chains above this band width blow up compile time; fall
# back to dynamically-indexed blocks of this many taps (same thresholds
# as the untiled ref sweep).
_UNROLL_MAX = 512
_CHAIN_BLOCK = 32


def minplus_chain_step(row: jax.Array, prev: jax.Array) -> jax.Array:
    """One banded min-plus DP slot for a batch of lanes.

    row: (B, DC+1) slot costs; prev: (B, D+1) carry.  Returns
    ``new[b, d] = min_j row[b, j] + prev[b, d - j]`` (out-of-range
    ``d - j`` contributes +inf), evaluated as a chain of static slices of
    the left-padded carry so XLA fuses it into one vectorised loop.
    """
    B, dc1 = row.shape
    d1 = prev.shape[1]
    prev_pad = jnp.concatenate(
        [jnp.full((B, dc1), jnp.inf, prev.dtype), prev], axis=1)
    if dc1 <= _UNROLL_MAX:
        cands = [row[:, j:j + 1] + jax.lax.slice(
            prev_pad, (0, dc1 - j), (B, dc1 - j + d1)) for j in range(dc1)]
        return functools.reduce(jnp.minimum, cands)
    blk = _CHAIN_BLOCK
    nb = (dc1 + blk - 1) // blk
    rowp = jnp.concatenate(
        [row, jnp.full((B, nb * blk - dc1), jnp.inf, row.dtype)], axis=1)
    prev_pad = jnp.concatenate(
        [jnp.full((B, nb * blk), jnp.inf, prev.dtype), prev], axis=1)

    def step(best, b):
        base = nb * blk - b * blk
        win = jax.lax.dynamic_slice(
            prev_pad, (0, base - (blk - 1)), (B, d1 + blk - 1))
        rb = jax.lax.dynamic_slice(rowp, (0, b * blk), (B, blk))
        for i in range(blk):
            best = jnp.minimum(best, rb[:, i:i + 1] + jax.lax.slice(
                win, (0, blk - 1 - i), (B, blk - 1 - i + d1)))
        return best, None

    best, _ = jax.lax.scan(
        step, jnp.full((B, d1), jnp.inf, prev.dtype), jnp.arange(nb))
    return best


def minplus_tile(rows_tile: jax.Array, prev: jax.Array):
    """One tile of the DP sweep for a lane batch.

    rows_tile: (TILE', B, DC+1) slot-major tile of COST rows; prev:
    (B, D+1) carry entering the tile.  Returns ``(carry_out, cols)``
    with ``cols`` (TILE', B, D+1) — the DP column after each slot, which
    the engine stores for the split backtrack.
    """
    def slot(carry, row):
        new = minplus_chain_step(row, carry)
        return new, new

    return jax.lax.scan(slot, prev, rows_tile)


def minplus_sweep_tiled(rows: jax.Array, d_total: int, *, tile: int = TILE,
                        start=0) -> jax.Array:
    """Cost-only sweep over (T, DC+1) rows, processed ``tile`` slots at a
    time from the tile containing ``start`` (a traced value is fine).

    Slots before ``start`` must be identity rows (``[0, inf, ...]``) —
    the DP carry is unchanged there, which is how the engine encodes
    pre-arrival slots — so the result rows from ``start`` on equal
    ``minplus_sweep_cost``'s; earlier rows are returned as +inf (they are
    never inspected).  A trailing partial tile is padded with identity
    rows inside the sweep (the carry passes through them unchanged), so
    any horizon length works.
    """
    T, dc1 = rows.shape
    rem = T % tile
    if rem:
        ident = jnp.full((tile - rem, dc1), jnp.inf, rows.dtype
                         ).at[:, 0].set(0.0)
        rows = jnp.concatenate([rows, ident], axis=0)
    T_pad = rows.shape[0]
    n_tiles = T_pad // tile
    d1 = d_total + 1
    init = jnp.full((1, d1), jnp.inf, rows.dtype).at[0, 0].set(0.0)
    cost = jnp.full((T_pad, d1), jnp.inf, rows.dtype)
    k0 = jnp.asarray(start, jnp.int32) // tile

    def body(carry):
        k, prev, cost = carry
        t0 = k * tile
        zero = jnp.zeros_like(t0)
        seg = jax.lax.dynamic_slice(rows, (t0, zero), (tile, dc1))
        prev, cols = minplus_tile(seg[:, None, :], prev)
        cost = jax.lax.dynamic_update_slice(cost, cols[:, 0, :], (t0, zero))
        return k + 1, prev, cost

    _, _, cost = jax.lax.while_loop(
        lambda c: c[0] < n_tiles, body, (k0, init, cost))
    return cost[:T]
