"""Jit'd flash-attention entry: Pallas (interpret on CPU) or XLA oracle."""
from __future__ import annotations

import jax

from .kernel import flash_attention
from .ref import attention_ref


def attention_op(q, k, v, *, causal=True, window=0, softcap=0.0,
                 use_pallas: bool = True):
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    interpret = jax.default_backend() != "tpu"
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, interpret=interpret)
