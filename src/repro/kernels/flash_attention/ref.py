"""Pure-jnp oracle for flash attention (naive full-scores softmax)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
