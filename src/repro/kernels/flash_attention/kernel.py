"""Pallas TPU flash attention (forward) with GQA, causal masking, sliding
window and logit softcap — the fused kernel behind
``models.attention._sdpa_chunked`` (same online-softmax recurrence).

Grid: (batch*heads, Sq blocks, Sk blocks); the last dimension iterates
sequentially on a TPU core so the (m, l, acc) running statistics live in
VMEM scratch across KV steps.  Block shapes are (BLOCK_Q, head_dim) /
(BLOCK_K, head_dim) with head_dim expected MXU-aligned (64/128/256);
scores use the MXU via jnp.dot in fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, softcap: float,
               seq_k: int, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                  # (BK, D)
    s = jnp.dot(q, k.T) * scale                       # (BQ, BK)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                              "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) with H % KV == 0.
    Returns (B, Sq, H, D) in q.dtype."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    sq_p = ((Sq + bq - 1) // bq) * bq
    sk_p = ((Sk + bk - 1) // bk) * bk
    qr = jnp.pad(q, ((0, 0), (0, sq_p - Sq), (0, 0), (0, 0)))
    kr = jnp.pad(k, ((0, 0), (0, sk_p - Sk), (0, 0), (0, 0)))
    vr = jnp.pad(v, ((0, 0), (0, sk_p - Sk), (0, 0), (0, 0)))
    qr = qr.transpose(0, 2, 1, 3).reshape(B * H, sq_p, D)
    kr = kr.transpose(0, 2, 1, 3).reshape(B * KV, sk_p, D)
    vr = vr.transpose(0, 2, 1, 3).reshape(B * KV, sk_p, D)

    kv_row = lambda bh: (bh // H) * KV + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, seq_k=Sk,
                          block_q=bq, block_k=bk),
        grid=(B * H, sq_p // bq, sk_p // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, sq_p, D).transpose(0, 2, 1, 3)
    return out[:, :Sq]
