"""Vectorized repack kernels for the reactive baselines (DRF/Dorm/RRH).

The reference implementations (kept verbatim as ``step_reference`` in
``core/baselines.py``) repack the whole live job set one chunk at a time:
every event triggers O(jobs x chunks) Python-level ``_place(1, ...)``
calls, each a freshly-allocated ``(S, R)`` array scan that restarts from
server 0.  At the fig3-shaped 10x scale (T=500, 100+100 servers, 2000
jobs) DRF and Dorm each burn ~80 s in that loop — the baselines, not
OASiS, became the simulation bottleneck once the sim-v2 event engine
landed.

This module re-derives the same repacks as **batch-round kernels** over
dense per-job state (demand rows, chunk counts, shares and first-fit
cursors are flat per-job vectors gathered from a ``DensePool``, not
``Job`` objects), built on three invariants of the greedy loops:

1.  **Free capacity is non-increasing within one repack.**  Successful
    placements subtract demand; the only additions are the PS-failure
    rollbacks, which restore exactly what the same turn subtracted.
    Hence (a) a job that once fails (no fitting worker server, or a PS
    rollback) can never succeed later in the same ``step`` call — the
    reference's futile retries for already-failed jobs, the dominant
    interpreter cost, are dropped without changing a single placement —
    and (b) each job's first-fit server index is *monotone
    non-decreasing*, so the reference's from-0 rescan per chunk
    collapses to a per-job **cursor** that only ever moves right and is
    validated at use.  Total cursor movement is bounded by the server
    count per job per repack, instead of per chunk.

2.  **Whole-set failure is detectable against capacity envelopes.**
    Servers are grouped into blocks carrying per-resource upper bounds
    on free capacity (stale-high is sound — placements only subtract —
    and bounds are tightened lazily when a scan through a passing block
    comes up empty).  A job demanding more than a block's bound in any
    resource skips the whole block in O(R), which is how the large
    hopeless tail of a saturated cluster — the reference's dominant
    cost — is retired in a handful of comparisons per job.

3.  **DRF's progressive filling is a lazy heap over linear shares.**
    ``share(count) = max(count * w / total_w)`` is strictly monotone in
    the chunk count, so the reference's ``min(candidates, key=shares)``
    pick is a ``(share, arrival-index)`` heap pop — first-minimum
    tie-break preserved — with stale entries skipped on pop.

All float updates replay the reference op-for-op on Python scalars
(IEEE-754 doubles, the same arithmetic numpy applies elementwise), so
placements match the greedy loops exactly; the single semantic deviation
is that a sub-ULP capacity wobble from a PS rollback (``x - d + d > x``)
can no longer resurrect a previously unfit server for a job whose cursor
moved past it — beyond the loops' own 1e-9 slack and unobserved on any
tested instance.  Exact equality of placements against
``step_reference`` is enforced on the seeded paper-scale instances and
on randomized adversarial instances (full-pool rejection, PS-placement
rollback, heterogeneous fleets) in ``tests/test_repack.py``.

The placement primitives ``_place_fast`` / ``_place_loop`` live here too
(moved from ``core/baselines.py``, which re-exports them): they are the
shared bottom layer of the reference loops, the RRH/FIFO kernels, and
the multi-instance PS path.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import Job
from .. import obs as _obs

Placement = Tuple[np.ndarray, np.ndarray]


# ---------------------------------------------------------------------------
# Placement primitives (round-robin onto servers).
# ---------------------------------------------------------------------------

def _place_fast(count: int, free: np.ndarray, demand: np.ndarray
                ) -> Optional[np.ndarray]:
    """Each round places one instance on every server (in index order) that
    still fits the demand; rounds repeat until all instances are placed or
    no server fits.  The whole round's fit mask is one array op — server
    rows are independent, so checking before the round equals checking at
    each visit, bit for bit, including the 1e-9 slack and the sequential
    ``free -= demand`` float updates of the per-server loop."""
    S = free.shape[0]
    out = np.zeros(S, dtype=np.int64)
    if count == 0:
        return out
    placed = 0
    while placed < count:
        fits = np.flatnonzero(np.all(free >= demand[None] - 1e-9, axis=1))
        if fits.size == 0:
            # rollback
            free += out[:, None] * demand[None]
            return None
        take = fits[:count - placed]
        free[take] -= demand[None]
        out[take] += 1
        placed += take.size
    return out


def _place_loop(count: int, free: np.ndarray, demand: np.ndarray
                ) -> Optional[np.ndarray]:
    """The seed's per-server scan (v1 baseline; see baselines.PLACE_IMPL)."""
    S = free.shape[0]
    out = np.zeros(S, dtype=np.int64)
    if count == 0:
        return out
    placed = 0
    for rounds in range(count):
        progressed = False
        for srv in range(S):
            if placed >= count:
                break
            if np.all(free[srv] >= demand - 1e-9):
                free[srv] -= demand
                out[srv] += 1
                placed += 1
                progressed = True
        if placed >= count:
            break
        if not progressed:
            # rollback
            for srv in range(S):
                free[srv] += out[srv] * demand
            return None
    if placed < count:
        for srv in range(S):
            free[srv] += out[srv] * demand
        return None
    return out


# ---------------------------------------------------------------------------
# Dense per-job state, maintained incrementally across events.
# ---------------------------------------------------------------------------

class DensePool:
    """Row-per-job scheduler state, updated on arrival/completion.

    Demands are stored as Python float tuples: the kernels' hot loops run
    scalar IEEE-754 arithmetic (bit-identical to numpy's elementwise
    ops) where per-call numpy overhead would dominate, and rebuilding
    this state from ``Job`` objects on every event would cost more
    interpreter time than the kernels themselves at scale.
    """

    def __init__(self, R: int):
        self._R = R
        self.wres: Dict[int, Tuple[float, ...]] = {}   # worker demand
        self.sres: Dict[int, Tuple[float, ...]] = {}   # PS demand
        self.maxc: Dict[int, int] = {}
        self.bw: Dict[int, float] = {}
        self.psbw: Dict[int, float] = {}

    def add(self, job: Job) -> None:
        jid = job.jid
        self.wres[jid] = tuple(float(v) for v in job.worker_res)
        self.sres[jid] = tuple(float(v) for v in job.ps_res)
        self.maxc[jid] = int(job.num_chunks)
        self.bw[jid] = float(job.worker_bw)
        self.psbw[jid] = float(job.ps_bw)

    def remove(self, jid: int) -> None:
        self.wres.pop(jid, None)
        self.sres.pop(jid, None)
        self.maxc.pop(jid, None)
        self.bw.pop(jid, None)
        self.psbw.pop(jid, None)


def _ps_for(count: int, bw: float, psbw: float) -> int:
    """``Job.ps_for`` with the exact scalar arithmetic of the dataclass
    (ceil(count * b / B - 1e-9); 0 workers need 0 parameter servers)."""
    if count == 0:
        return 0
    return math.ceil(count * bw / psbw - 1e-9)


class _CursorPool:
    """One server pool with per-job monotone first-fit cursors.

    ``free`` is a list of per-server Python float lists; ``find(j)``
    resumes job ``j``'s scan at its cursor — sound because capacity is
    non-increasing, so servers the cursor passed can never fit again.
    A two-level envelope accelerates the scan: servers are grouped into
    blocks of ``_BLOCK`` and each block keeps a per-resource upper bound
    on its free capacity.  A block whose bound is below the demand in
    any resource cannot contain a fit and is skipped in O(R); bounds are
    allowed to go stale high (sound, placements only subtract) and are
    tightened lazily whenever a walk through a passing block comes up
    empty.  Whole-pool rejection — the saturated cluster's hopeless tail
    that dominates reference runtime — thus costs O(S / _BLOCK * R)
    scalar compares per job instead of a fresh array scan per retry."""

    _BLOCK = 8

    def __init__(self, caps: np.ndarray, demands: List[Tuple[float, ...]]):
        self.free: List[List[float]] = [list(map(float, row)) for row in caps]
        self.S = len(self.free)
        self.R = caps.shape[1] if self.S else 0
        self._r5 = self.R == 5                # unrolled hot path
        self.d = demands
        self.dm = [tuple(v - 1e-9 for v in d) for d in demands]
        self.cursor = [0] * len(demands)
        B = self._BLOCK
        self._nb = (self.S + B - 1) // B
        self._benv = [[max(row[r] for row in self.free[b * B:b * B + B])
                       for r in range(self.R)]
                      for b in range(self._nb)]
        self._mut = [0] * self._nb            # block mutation counters
        self._tightened = [0] * self._nb      # mutation count at last tighten

    def _tighten(self, b: int) -> None:
        if self._tightened[b] == self._mut[b]:
            return                            # bound already exact
        B = self._BLOCK
        self._benv[b] = [max(row[r] for row in self.free[b * B:b * B + B])
                         for r in range(self.R)]
        self._tightened[b] = self._mut[b]

    def find(self, j: int) -> int:
        """First server fitting job ``j``'s demand (reference slack:
        ``free >= d - 1e-9``), or -1; advances the cursor."""
        s = self.cursor[j]
        S = self.S
        if s >= S:
            return -1
        dm = self.dm[j]
        free = self.free
        B = self._BLOCK
        R = self.R
        r5 = self._r5
        if r5:
            d0, d1, d2, d3, d4 = dm
        for b in range(s // B, self._nb):
            env = self._benv[b]
            if r5:
                if (d0 > env[0] or d1 > env[1] or d2 > env[2]
                        or d3 > env[3] or d4 > env[4]):
                    continue                  # no server in block can fit
            else:
                if any(dm[r] > env[r] for r in range(R)):
                    continue
            lo = s if b == s // B else b * B
            hi = min(S, b * B + B)
            if r5:
                for srv in range(lo, hi):
                    row = free[srv]
                    if (row[0] < d0 or row[1] < d1 or row[2] < d2
                            or row[3] < d3 or row[4] < d4):
                        continue
                    self.cursor[j] = srv
                    return srv
            else:
                for srv in range(lo, hi):
                    row = free[srv]
                    for fv, dv in zip(row, dm):
                        if fv < dv:
                            break
                    else:
                        self.cursor[j] = srv
                        return srv
            self._tighten(b)                  # bound was stale: pay it down
        self.cursor[j] = S
        return -1

    def take(self, s: int, j: int) -> None:
        row = self.free[s]
        d = self.d[j]
        for r in range(self.R):
            row[r] -= d[r]
        self._mut[s // self._BLOCK] += 1

    def give(self, s: int, j: int) -> None:
        """PS-failure rollback: the exact inverse float ops of ``take``.
        Re-raises the block bound, which may have been tightened from the
        temporarily-reduced row, so it stays a sound upper bound."""
        row = self.free[s]
        d = self.d[j]
        b = s // self._BLOCK
        env = self._benv[b]
        for r in range(self.R):
            row[r] += d[r]
            if row[r] > env[r]:
                env[r] = row[r]
        self._mut[b] += 1


class _PSCursor(_CursorPool):
    """PS-side placement.  ``_place_fast(need, ...)`` takes the ``need``
    lowest-index fitting servers per round; for the ubiquitous ``need ==
    1`` case that is exactly the cursor's first fit.  Larger requests
    (and their partial-placement rollbacks) run the same scan per
    instance with a within-call reset: one call's instances restart from
    the cursor, a sound lower bound, as ``_place_fast`` rounds restart
    from server 0."""

    def place(self, j: int, need: int) -> Optional[Dict[int, int]]:
        if need == 1:
            s = self.find(j)
            if s < 0:
                return None
            self.take(s, j)
            return {s: 1}
        # multi-instance: a _place_fast round spreads over fitting servers
        # in index order (one instance each), rounds repeat until placed
        out: Dict[int, int] = {}
        dm = self.dm[j]
        start = self.cursor[j]
        placed = 0
        while placed < need:
            round_any = False
            s = start
            while s < self.S and placed < need:
                row = self.free[s]
                for fv, dv in zip(row, dm):
                    if fv < dv:
                        break
                else:
                    self.take(s, j)
                    out[s] = out.get(s, 0) + 1
                    placed += 1
                    round_any = True
                s += 1
            if not round_any:
                for srv, cnt in out.items():
                    for _ in range(cnt):
                        self.give(srv, j)
                return None
        return out


def _emit(jids: Sequence[int], counts: List[int], H: int, K: int,
          ys: List[Optional[Dict[int, int]]],
          zs: List[Optional[Dict[int, int]]]) -> Dict[int, Placement]:
    out: Dict[int, Placement] = {}
    for i, jid in enumerate(jids):
        if counts[i] <= 0:
            continue
        y = np.zeros(H, dtype=np.int64)
        for s, c in ys[i].items():
            y[s] = c
        z = np.zeros(K, dtype=np.int64)
        if zs[i]:
            for s, c in zs[i].items():
                z[s] = c
        out[jid] = (y, z)
    return out


# ---------------------------------------------------------------------------
# DRF: progressive filling as a lazy heap over linear shares.
# ---------------------------------------------------------------------------

def drf_repack(worker_caps: np.ndarray, ps_caps: np.ndarray, pool: DensePool,
               jids: Sequence[int]) -> Dict[int, Placement]:
    """Dominant-resource progressive filling over the whole live set.

    The pick sequence replicates the reference exactly: the next job is
    the heap minimum of ``(share, arrival index)`` — the same
    first-minimum tie-break as ``min()`` over the arrival-ordered
    candidate list — its chunk goes to the cursor's first-fit server,
    and a job blocks at its first failed pick, the same turn the
    reference would block it on (failed picks mutate nothing, so
    skipping the reference's further retries is placement-identical).
    """
    n = len(jids)
    if n == 0:
        return {}
    H, K = worker_caps.shape[0], ps_caps.shape[0]
    total_w = np.maximum(worker_caps.sum(axis=0), 1e-9)
    tot_sc = tuple(float(v) for v in total_w)
    W = [pool.wres[j] for j in jids]
    Sd = [pool.sres[j] for j in jids]
    maxc = [pool.maxc[j] for j in jids]
    bw = [pool.bw[j] for j in jids]
    psbw = [pool.psbw[j] for j in jids]

    wp = _CursorPool(worker_caps, W)
    ps = _PSCursor(ps_caps, Sd)
    counts = [0] * n
    zsum = [0] * n
    shares = [0.0] * n
    ys: List[Optional[Dict[int, int]]] = [None] * n
    zs: List[Optional[Dict[int, int]]] = [None] * n
    heap = [(0.0, i) for i in range(n)]       # already heap-ordered
    blocked = [False] * n
    n_blocked = 0
    while heap and n_blocked < n:
        share, j = heapq.heappop(heap)
        if blocked[j] or share != shares[j]:
            continue                          # stale entry
        if counts[j] >= maxc[j]:
            blocked[j] = True
            n_blocked += 1
            continue
        s = wp.find(j)
        if s < 0:
            blocked[j] = True                 # no fit anywhere: blocked
            n_blocked += 1
            if _obs.ENABLED:
                _obs.inc("repack.futile_elisions")
            continue
        wp.take(s, j)
        need = _ps_for(counts[j] + 1, bw[j], psbw[j]) - zsum[j]
        if need > 0:
            z = ps.place(j, need)
            if z is None:                     # PS rollback -> job blocks
                wp.give(s, j)
                blocked[j] = True
                n_blocked += 1
                if _obs.ENABLED:
                    _obs.inc("repack.futile_elisions")
                continue
            if zs[j] is None:
                zs[j] = z
            else:
                for srv, cnt in z.items():
                    zs[j][srv] = zs[j].get(srv, 0) + cnt
            zsum[j] += need
        counts[j] += 1
        if ys[j] is None:
            ys[j] = {s: 1}
        else:
            ys[j][s] = ys[j].get(s, 0) + 1
        c = counts[j]
        # exact reference arithmetic: max(count * w_r / total_r), scalar
        # IEEE doubles == numpy elementwise
        sh = max(c * w / tw for w, tw in zip(W[j], tot_sc))
        shares[j] = sh
        heapq.heappush(heap, (sh, j))
    return _emit(jids, counts, H, K, ys, zs)


# ---------------------------------------------------------------------------
# Dorm: round-robin water filling as whole-round passes.
# ---------------------------------------------------------------------------

def dorm_repack(worker_caps: np.ndarray, ps_caps: np.ndarray, pool: DensePool,
                jids: Sequence[int]) -> Dict[int, Placement]:
    """Round-robin water filling: each round walks the still-active jobs
    in arrival order and places one chunk each; a job leaves the active
    set when it reaches its chunk count or first fails (worker or PS) —
    futile-retry elision per the module invariant.  The reference's
    no-progress termination is implied: while any job is active, every
    round makes progress."""
    n = len(jids)
    if n == 0:
        return {}
    H, K = worker_caps.shape[0], ps_caps.shape[0]
    W = [pool.wres[j] for j in jids]
    Sd = [pool.sres[j] for j in jids]
    maxc = [pool.maxc[j] for j in jids]
    bw = [pool.bw[j] for j in jids]
    psbw = [pool.psbw[j] for j in jids]

    wp = _CursorPool(worker_caps, W)
    ps = _PSCursor(ps_caps, Sd)
    counts = [0] * n
    zsum = [0] * n
    ys: List[Optional[Dict[int, int]]] = [None] * n
    zs: List[Optional[Dict[int, int]]] = [None] * n
    active = list(range(n))
    while active:
        if _obs.ENABLED:
            _obs.inc("repack.rounds")
        nxt = []
        for j in active:
            if counts[j] >= maxc[j]:
                continue                      # reached its chunk count
            s = wp.find(j)
            if s < 0:
                if _obs.ENABLED:
                    _obs.inc("repack.futile_elisions")
                continue                      # no server fits, ever again
            wp.take(s, j)
            need = _ps_for(counts[j] + 1, bw[j], psbw[j]) - zsum[j]
            if need > 0:
                z = ps.place(j, need)
                if z is None:
                    wp.give(s, j)
                    if _obs.ENABLED:
                        _obs.inc("repack.futile_elisions")
                    continue                  # PS rollback -> job is done
                if zs[j] is None:
                    zs[j] = z
                else:
                    for srv, cnt in z.items():
                        zs[j][srv] = zs[j].get(srv, 0) + cnt
                zsum[j] += need
            counts[j] += 1
            if ys[j] is None:
                ys[j] = {s: 1}
            else:
                ys[j][s] = ys[j].get(s, 0) + 1
            nxt.append(j)
        active = nxt
    return _emit(jids, counts, H, K, ys, zs)


# ---------------------------------------------------------------------------
# RRH / FIFO helpers: batched keep-allocation deduction + resume order.
# ---------------------------------------------------------------------------

def deduct_running(free: np.ndarray, allocs: List[np.ndarray],
                   demands: List[np.ndarray]) -> None:
    """``free -= sum_i alloc_i[:, None] * demand_i[None]`` as one einsum.

    Summation order differs from the reference's per-job loop only in
    float associativity (well inside the placement slack)."""
    if allocs:
        free -= np.einsum("ns,nr->sr", np.stack(allocs).astype(float),
                          np.stack(demands))


def rrh_resume_order(jobs: Sequence[Job],
                     meta: Sequence[Tuple[int, int, int, float]],
                     t: int) -> np.ndarray:
    """Payoff-density order for RRH's paused jobs: the utilities are
    Python callables (one call per job, as in the reference), but the
    sort runs once over the whole batch; ``kind="stable"`` reproduces
    ``sorted``'s tie behaviour on identical float keys."""
    dens = np.array([-job.utility(dur + (t - job.arrival)) / denom
                     for job, (nw, nps, dur, denom) in zip(jobs, meta)])
    return np.argsort(dens, kind="stable")
