"""OASiS core: online primal-dual job scheduling (the paper's contribution)."""
from .types import ClusterSpec, Job, Schedule, SigmoidUtility, job_from_arch
from .pricing import PriceParams, PriceState, price_params_from_jobs
from .subroutine import best_schedule, best_schedule_ref
from .oasis import OASiS
from .baselines import BASELINES, DRF, Dorm, FIFO, RRH
