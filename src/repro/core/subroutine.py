"""Alg. 2 — dual subroutine deriving the best schedule for one job.

Implementations with identical outputs (tests assert so):

* ``best_schedule_ref``  — loop-faithful transcription of the paper's
  pseudocode (COST_t greedy, DP_COST recursion).  The test oracle.
* ``best_schedule``      — vectorized: COST_t rows for all (t, d) via
  sort + prefix sums (the greedy fills cheapest servers first, so its
  cost is a prefix sum), DP via banded min-plus convolution.  With
  ``use_jax=True`` the whole pipeline runs as one jit-compiled XLA
  computation (``schedule_jax.best_schedule_fused``); with
  ``rows_impl="loop"`` the seed's per-slot-loop COST-row builder is used
  (kept only as the decision-latency benchmark baseline).

All return ``None`` when no schedule has positive payoff (job rejected).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .pricing import PriceState
from .types import Job, R, Schedule

INF = float("inf")


# ---------------------------------------------------------------------------
# Reference (paper-faithful) implementation
# ---------------------------------------------------------------------------

def _server_capacity(headroom: np.ndarray, demand: np.ndarray) -> np.ndarray:
    """Per-server max instances: min_r floor(headroom_r / demand_r) (30)(31)."""
    servers = headroom.shape[0]
    cap = np.full(servers, np.iinfo(np.int64).max, dtype=np.int64)
    for r in range(R):
        if demand[r] > 0:
            cap = np.minimum(cap, np.floor(headroom[:, r] / demand[r] + 1e-9).astype(np.int64))
    return np.maximum(cap, 0)


def cost_t_ref(job: Job, state: PriceState, p: np.ndarray, q: np.ndarray,
               t: int, d: int) -> Tuple[float, np.ndarray, np.ndarray]:
    """COST_t(t, d): greedy optimal deployment (Alg. 2 lines 21-44)."""
    H, K = state.cluster.H, state.cluster.K
    y = np.zeros(H, dtype=np.int64)
    z = np.zeros(K, dtype=np.int64)
    if d == 0:
        return 0.0, y, z
    D = job.workers_for(d)
    if D > job.num_chunks:           # constraint (3) can never be met
        return INF, y, z
    # --- workers: cheapest server first -----------------------------------
    w_cost = (p[t] * job.worker_res[None, :]).sum(axis=1)      # (H,)
    w_cap = _server_capacity(state.headroom_workers(t), job.worker_res)
    order = np.argsort(w_cost, kind="stable")
    remaining = D
    for h in order:
        if remaining <= 0:
            break
        take = min(int(w_cap[h]), job.num_chunks - int(y.sum()), remaining)
        y[h] = take
        remaining -= take
    if remaining > 0:
        return INF, y, z
    W = int(y.sum())
    # --- parameter servers -------------------------------------------------
    target = job.ps_for(W)
    s_cost = (q[t] * job.ps_res[None, :]).sum(axis=1)          # (K,)
    s_cap = _server_capacity(state.headroom_ps(t), job.ps_res)
    order_k = np.argsort(s_cost, kind="stable")
    for k in order_k:
        deployed = int(z.sum())
        take = min(int(s_cap[k]), target - deployed, W - deployed)
        if take <= 0:
            continue
        z[k] = take
    if z.sum() * job.ps_bw < W * job.worker_bw - 1e-9:          # line 39
        return INF, y, z
    cost = float((y * w_cost).sum() + (z * s_cost).sum())
    return cost, y, z


def best_schedule_ref(job: Job, state: PriceState) -> Optional[Schedule]:
    """Alg. 2: enumerate deadlines, DP over workload splits."""
    T = state.horizon      # window-local lookahead (== cluster.T episodic)
    a = job.arrival
    Dtot = job.workload
    dcap = min(job.max_chunks_per_slot, Dtot)
    p = state.worker_prices()
    q = state.ps_prices()
    # cost_t rows
    rows = np.full((T, dcap + 1), INF)
    for t in range(a, T):
        for d in range(dcap + 1):
            rows[t, d], _, _ = cost_t_ref(job, state, p, q, t, d)
    # DP: cost[t][d] = min_{d'} rows[t][d'] + cost[t-1][d-d']
    cost = np.full((T, Dtot + 1), INF)
    split = np.zeros((T, Dtot + 1), dtype=np.int64)
    prev = np.full(Dtot + 1, INF)
    prev[0] = 0.0
    best_payoff, best_t = 0.0, -1
    for t in range(a, T):
        for d in range(Dtot + 1):
            lim = min(d, dcap)
            best_c, best_d = INF, 0
            for dp in range(lim + 1):
                c = rows[t, dp] + prev[d - dp]
                if c < best_c - 1e-12:
                    best_c, best_d = c, dp
            cost[t, d] = best_c
            split[t, d] = best_d
        prev = cost[t]
        if cost[t, Dtot] < INF:
            payoff = job.utility(t - a) - cost[t, Dtot]
            if payoff > best_payoff + 1e-12:
                best_payoff, best_t = payoff, t
    if best_t < 0:
        return None
    return _extract(job, state, p, q, split, best_t, best_payoff,
                    cost[best_t, Dtot])


def _extract(job: Job, state: PriceState, p: np.ndarray, q: np.ndarray,
             split: np.ndarray, t_hat: int, payoff: float, total_cost: float
             ) -> Schedule:
    """Backtrack the DP split table and re-run the greedy per slot."""
    workers, ps = {}, {}
    d_rem = job.workload
    for t in range(t_hat, job.arrival - 1, -1):
        d = int(split[t, d_rem])
        if d > 0:
            c, y, z = cost_t_ref(job, state, p, q, t, d)
            assert c < INF
            workers[t] = y
            ps[t] = z
        d_rem -= d
    assert d_rem == 0, f"backtrack failed: {d_rem} chunk-passes unassigned"
    return Schedule(jid=job.jid, workers=workers, ps=ps, finish=t_hat,
                    cost=total_cost, payoff=payoff,
                    utility=job.utility(t_hat - job.arrival))


# ---------------------------------------------------------------------------
# Vectorized implementation
# ---------------------------------------------------------------------------

# Per-server instance caps are clamped here before prefix-summing.  A job with
# zero demand on a pool has unbounded per-server capacity; summing int64 max
# across servers overflows and flips the pool's total capacity negative, which
# silently rejected legal worker-only jobs in the seed implementation.
_CAP_CLAMP = np.int64(1) << 40


def _prefix_tables(prices: np.ndarray, headroom: np.ndarray, demand: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sorted per-slot unit costs + prefix sums of capacity and cost.

    Returns (ccap (T, S), ccost (T, S), scost (T, S)) where column j holds the
    cumulative capacity/cost over the j+1 cheapest servers at each slot.
    Whole-array over (T, S, R): no Python loop over slots or resources.
    """
    unit = (prices * demand[None, None, :]).sum(axis=2)   # (T, S)
    pos = demand > 0
    if pos.any():
        with np.errstate(divide="ignore", invalid="ignore"):
            per_r = np.floor(headroom[:, :, pos] / demand[pos][None, None, :]
                             + 1e-9)
        cap = np.minimum(per_r.min(axis=2), float(_CAP_CLAMP))
        cap = np.maximum(cap, 0).astype(np.int64)
    else:
        cap = np.full(unit.shape, _CAP_CLAMP, dtype=np.int64)
    order = np.argsort(unit, axis=1, kind="stable")
    scost = np.take_along_axis(unit, order, axis=1)
    scap = np.take_along_axis(cap, order, axis=1)
    ccap = np.cumsum(scap, axis=1)
    ccost = np.cumsum(scap * scost, axis=1)
    return ccap, ccost, scost


def _greedy_cost_for_counts(ccap: np.ndarray, ccost: np.ndarray, scost: np.ndarray,
                            counts: np.ndarray) -> np.ndarray:
    """Cost of greedily placing ``counts[j]`` instances at each slot row.

    ccap/ccost/scost: (S,) prefix tables for ONE slot; counts: (M,) wanted
    instance totals.  Returns (M,) costs (inf where counts exceed capacity).
    """
    out = np.full(counts.shape, INF)
    cz = counts == 0
    out[cz] = 0.0
    if ccap.size == 0:                      # empty server pool: only 0 fits
        return out
    total = ccap[-1]
    ok = counts <= total
    idx = np.searchsorted(ccap, counts, side="left")   # first prefix covering
    idx = np.minimum(idx, len(ccap) - 1)
    prev_cap = np.where(idx > 0, ccap[np.maximum(idx - 1, 0)], 0)
    prev_cost = np.where(idx > 0, ccost[np.maximum(idx - 1, 0)], 0.0)
    vals = prev_cost + (counts - prev_cap) * scost[idx]
    sel = ok & ~cz
    out[sel] = vals[sel]
    return out


def _greedy_cost_rows(ccap: np.ndarray, ccost: np.ndarray, scost: np.ndarray,
                      counts: np.ndarray) -> np.ndarray:
    """Batched greedy cost: all slots at once.

    ccap/ccost/scost: (T, S) prefix tables; counts: (M,) or (T, M) wanted
    totals per slot.  Returns (T, M) costs.  The per-row searchsorted is
    flattened into one global call by offsetting each row into a disjoint
    integer range (caps are clamped below the offset stride).
    """
    T, S = ccap.shape
    counts = np.broadcast_to(counts, (T, counts.shape[-1])
                             if counts.ndim == 1 else counts.shape)
    M = counts.shape[1]
    out = np.full((T, M), INF)
    out[counts == 0] = 0.0
    if S == 0:                              # empty server pool: only 0 fits
        return out
    stride = np.int64(_CAP_CLAMP) * (S + 1)   # > any row's total capacity
    base = np.arange(T, dtype=np.int64) * stride
    flat = (ccap + base[:, None]).ravel()
    idx = np.searchsorted(flat, (counts + base[:, None]).ravel(),
                          side="left").reshape(T, M)
    idx -= np.arange(T, dtype=np.int64)[:, None] * S
    # gather from zero-prepended prefixes: index i yields prefix over i servers
    pad_cap = np.concatenate([np.zeros((T, 1), np.int64), ccap], axis=1)
    pad_cost = np.concatenate([np.zeros((T, 1)), ccost], axis=1)
    prev_cap = np.take_along_axis(pad_cap, idx, axis=1)
    prev_cost = np.take_along_axis(pad_cost, idx, axis=1)
    marg = np.take_along_axis(scost, np.minimum(idx, S - 1), axis=1)
    vals = prev_cost + (counts - prev_cap) * marg
    sel = (counts <= ccap[:, -1:]) & (counts > 0)
    out[sel] = vals[sel]
    return out


def workload_tables(job: Job, dcap: int) -> Tuple[np.ndarray, np.ndarray]:
    """(W, Z): workers and PS targets for d = 0..dcap, vectorized.

    Elementwise identical to ``job.workers_for`` / ``job.ps_for``.
    """
    ds = np.arange(dcap + 1, dtype=np.float64)
    W = np.ceil(ds * job.quantum * job.chunk_time - 1e-9).astype(np.int64)
    W[0] = 0
    Z = np.ceil(W * job.worker_bw / job.ps_bw - 1e-9).astype(np.int64)
    Z[W == 0] = 0
    return W, Z


def cost_t_rows(job: Job, state: PriceState, p: np.ndarray, q: np.ndarray,
                dcap: int, slots: Optional[np.ndarray] = None) -> np.ndarray:
    """rows[t, d] = COST_t(t, d) for every slot and d in [0, dcap].

    Fully vectorized over (t, d): capacity tables, the cost sort, and the
    prefix-sum greedy costs are whole-array ops — no per-slot Python loop.

    ``slots`` (sorted 1-D slot indices) restricts the computation to those
    slots, returning ``(len(slots), dcap + 1)`` — the host-side form of
    the partial recompute the fused engine's row cache does per dirty
    tile, and bit-identical to ``cost_t_rows(...)[slots]``.
    """
    a = job.arrival
    # read-only access to the host mirrors (not the mutable ``g``/``v``
    # views, which would drop the device residency and row caches)
    if slots is None:
        g_s, v_s, p_s, q_s = state._g_host, state._v_host, p, q
    else:
        slots = np.asarray(slots, np.int64)
        g_s, v_s = state._g_host[slots], state._v_host[slots]
        p_s, q_s = p[slots], q[slots]
    n = p_s.shape[0]
    wc_cap, wc_cost, wc_scost = _prefix_tables(
        p_s, state.cluster.worker_caps[None] - g_s, job.worker_res)
    ps_cap, ps_cost, ps_scost = _prefix_tables(
        q_s, state.cluster.ps_caps[None] - v_s, job.ps_res)
    W, Z = workload_tables(job, dcap)                        # (M,)
    feas_n = W <= job.num_chunks
    w_costs = _greedy_cost_rows(wc_cap, wc_cost, wc_scost, W)      # (n, M)
    # PS deployed = min(target, W, pool capacity); feasible iff >= (b/B) W
    pool = ps_cap[:, -1:] if ps_cap.shape[1] else np.zeros((n, 1), np.int64)
    deploy = np.minimum(np.minimum(Z, W)[None, :], pool)           # (n, M)
    feas_ps = deploy * job.ps_bw >= W[None, :] * job.worker_bw - 1e-9
    z_costs = _greedy_cost_rows(ps_cap, ps_cost, ps_scost, deploy)
    rows = np.where(feas_n[None, :] & feas_ps, w_costs + z_costs, INF)
    rows[:, 0] = 0.0
    if slots is None:
        rows[:a] = INF
    else:
        rows[slots < a] = INF
    return rows


def cost_row_flags(rows: np.ndarray, plateau_max: Optional[int] = None
                   ) -> Dict[str, np.ndarray]:
    """Structure flags for a block of COST_t rows — which min-plus path
    each row is eligible for (see ``kernels/minplus/monotone.py``).

    Returns per-row arrays: ``convex`` (exact-arithmetic convexity
    certificate — the soundness condition for the SMAWK-style D&C),
    ``runs`` (maximal bitwise-equal run count — the plateau path's cost
    measure), and ``path`` (the PATH_DNC / PATH_PLATEAU / PATH_CHAIN
    code the dispatcher would pick).  Real COST_t rows are staircases —
    greedy fill composed with ``W(d) = ceil(alpha d)`` — so ``convex``
    is almost never set and ``runs`` is what decides the fast path.
    """
    from repro.kernels.minplus.monotone import (
        PATH_CHAIN, PATH_DNC, PATH_PLATEAU, _PLATEAU_FRACTION,
        convex_certificate_np, run_count_np)
    rows = np.asarray(rows)
    if plateau_max is None:
        plateau_max = max(rows.shape[-1] // _PLATEAU_FRACTION, 1)
    convex = convex_certificate_np(rows)
    runs = run_count_np(rows)
    with np.errstate(invalid="ignore"):
        clean = np.all((rows == rows) & (rows > -np.inf), axis=-1)
    path = np.where(convex, PATH_DNC,
                    np.where(clean & (runs <= plateau_max),
                             PATH_PLATEAU, PATH_CHAIN)).astype(np.int32)
    return {"convex": convex, "runs": runs, "path": path}


# ---------------------------------------------------------------------------
# Seed baseline (per-slot Python loop) — kept verbatim for the decision-
# latency benchmark so speedups stay measurable against the original path.
# ---------------------------------------------------------------------------

def _prefix_tables_loop(prices, headroom, demand, t0):
    T = prices.shape[0]
    unit = (prices * demand[None, None, :]).sum(axis=2)   # (T, S)
    cap = np.zeros(unit.shape, dtype=np.int64)
    full = np.full(unit.shape[1], _CAP_CLAMP, dtype=np.int64)
    for t in range(t0, T):
        c = full.copy()
        for r in range(R):
            if demand[r] > 0:
                c = np.minimum(c, np.floor(headroom[t, :, r] / demand[r] + 1e-9).astype(np.int64))
        cap[t] = np.maximum(c, 0)
    order = np.argsort(unit, axis=1, kind="stable")
    scost = np.take_along_axis(unit, order, axis=1)
    scap = np.take_along_axis(cap, order, axis=1)
    ccap = np.cumsum(scap, axis=1)
    ccost = np.cumsum(scap * scost, axis=1)
    return ccap, ccost, scost


def cost_t_rows_loop(job: Job, state: PriceState, p: np.ndarray, q: np.ndarray,
                     dcap: int) -> np.ndarray:
    """Seed implementation of ``cost_t_rows``: Python loop over slots."""
    T = state.horizon      # window-local lookahead (== cluster.T episodic)
    a = job.arrival
    rows = np.full((T, dcap + 1), INF)
    wc_cap, wc_cost, wc_scost = _prefix_tables_loop(
        p, state.cluster.worker_caps[None] - state.g, job.worker_res, a)
    ps_cap, ps_cost, ps_scost = _prefix_tables_loop(
        q, state.cluster.ps_caps[None] - state.v, job.ps_res, a)
    ds = np.arange(dcap + 1)
    W = np.array([job.workers_for(int(d)) for d in ds])      # (M,)
    feas_n = W <= job.num_chunks
    Z = np.array([job.ps_for(int(w)) for w in W])
    for t in range(a, T):
        w_costs = _greedy_cost_for_counts(wc_cap[t], wc_cost[t], wc_scost[t], W)
        pool = ps_cap[t, -1] if ps_cap.shape[1] else 0
        deploy = np.minimum(np.minimum(Z, W), pool)
        feas_ps = deploy * job.ps_bw >= W * job.worker_bw - 1e-9
        z_costs = _greedy_cost_for_counts(ps_cap[t], ps_cost[t], ps_scost[t], deploy)
        row = np.where(feas_n & feas_ps, w_costs + z_costs, INF)
        row[0] = 0.0
        rows[t] = row
    return rows


def minplus_band(prev: np.ndarray, row: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """new[d] = min_{d'} row[d'] + prev[d - d']; returns (new, argmin)."""
    D = prev.shape[0] - 1
    dcap = row.shape[0] - 1
    ids = np.arange(D + 1)[:, None] - np.arange(dcap + 1)[None, :]   # (D+1, dcap+1)
    prev_ext = np.append(prev, INF)
    cand = row[None, :] + prev_ext[np.where(ids >= 0, ids, -1)]
    cand = np.where(ids >= 0, cand, INF)
    arg = np.argmin(cand, axis=1)
    return cand[np.arange(D + 1), arg], arg


def best_schedule(job: Job, state: PriceState, *, use_jax: bool = False,
                  rows_impl: str = "fast") -> Optional[Schedule]:
    """Vectorized Alg. 2.

    ``use_jax=True`` delegates the whole pipeline to the fused jit engine in
    ``schedule_jax`` (one XLA computation per decision).  ``rows_impl`` picks
    the COST-row builder for the numpy path: ``"fast"`` (whole-array) or
    ``"loop"`` (the seed per-slot baseline, kept for benchmarks).
    """
    if use_jax:
        from .schedule_jax import best_schedule_fused
        return best_schedule_fused(job, state)
    T = state.horizon      # window-local lookahead (== cluster.T episodic)
    a = job.arrival
    Dtot = job.workload
    dcap = min(job.max_chunks_per_slot, Dtot)
    if dcap == 0:
        return None
    p = state.worker_prices()
    q = state.ps_prices()
    rows_fn = cost_t_rows_loop if rows_impl == "loop" else cost_t_rows
    rows = rows_fn(job, state, p, q, dcap)
    cost_tab = np.full((T - a, Dtot + 1), INF)
    split = np.zeros((T - a, Dtot + 1), dtype=np.int64)
    prev = np.full(Dtot + 1, INF)
    prev[0] = 0.0
    for i, t in enumerate(range(a, T)):
        cost_tab[i], split[i] = minplus_band(prev, rows[t])
        prev = cost_tab[i]
    best_payoff, best_i = 0.0, -1
    finite = cost_tab[:, Dtot] < INF
    for i in np.nonzero(finite)[0]:
        payoff = job.utility(i) - cost_tab[i, Dtot]
        if payoff > best_payoff + 1e-12:
            best_payoff, best_i = payoff, int(i)
    if best_i < 0:
        return None
    full_split = np.zeros((T, Dtot + 1), dtype=np.int64)
    full_split[a:] = split
    return _extract(job, state, p, q, full_split, a + best_i, best_payoff,
                    float(cost_tab[best_i, Dtot]))
