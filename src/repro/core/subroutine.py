"""Alg. 2 — dual subroutine deriving the best schedule for one job.

Two implementations with identical outputs (tests assert so):

* ``best_schedule_ref``  — loop-faithful transcription of the paper's
  pseudocode (COST_t greedy, DP_COST recursion).  The test oracle.
* ``best_schedule``      — vectorized: COST_t rows for all (t, d) via
  sort + prefix sums (the greedy fills cheapest servers first, so its
  cost is a prefix sum), DP via banded min-plus convolution.

Both return ``None`` when no schedule has positive payoff (job rejected).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

from .pricing import PriceState
from .types import ClusterSpec, Job, R, Schedule

INF = float("inf")


# ---------------------------------------------------------------------------
# Reference (paper-faithful) implementation
# ---------------------------------------------------------------------------

def _server_capacity(headroom: np.ndarray, demand: np.ndarray) -> np.ndarray:
    """Per-server max instances: min_r floor(headroom_r / demand_r) (30)(31)."""
    servers = headroom.shape[0]
    cap = np.full(servers, np.iinfo(np.int64).max, dtype=np.int64)
    for r in range(R):
        if demand[r] > 0:
            cap = np.minimum(cap, np.floor(headroom[:, r] / demand[r] + 1e-9).astype(np.int64))
    return np.maximum(cap, 0)


def cost_t_ref(job: Job, state: PriceState, p: np.ndarray, q: np.ndarray,
               t: int, d: int) -> Tuple[float, np.ndarray, np.ndarray]:
    """COST_t(t, d): greedy optimal deployment (Alg. 2 lines 21-44)."""
    H, K = state.cluster.H, state.cluster.K
    y = np.zeros(H, dtype=np.int64)
    z = np.zeros(K, dtype=np.int64)
    if d == 0:
        return 0.0, y, z
    D = job.workers_for(d)
    if D > job.num_chunks:           # constraint (3) can never be met
        return INF, y, z
    # --- workers: cheapest server first -----------------------------------
    w_cost = (p[t] * job.worker_res[None, :]).sum(axis=1)      # (H,)
    w_cap = _server_capacity(state.headroom_workers(t), job.worker_res)
    order = np.argsort(w_cost, kind="stable")
    remaining = D
    for h in order:
        if remaining <= 0:
            break
        take = min(int(w_cap[h]), job.num_chunks - int(y.sum()), remaining)
        y[h] = take
        remaining -= take
    if remaining > 0:
        return INF, y, z
    W = int(y.sum())
    # --- parameter servers -------------------------------------------------
    target = job.ps_for(W)
    s_cost = (q[t] * job.ps_res[None, :]).sum(axis=1)          # (K,)
    s_cap = _server_capacity(state.headroom_ps(t), job.ps_res)
    order_k = np.argsort(s_cost, kind="stable")
    for k in order_k:
        deployed = int(z.sum())
        take = min(int(s_cap[k]), target - deployed, W - deployed)
        if take <= 0:
            continue
        z[k] = take
    if z.sum() * job.ps_bw < W * job.worker_bw - 1e-9:          # line 39
        return INF, y, z
    cost = float((y * w_cost).sum() + (z * s_cost).sum())
    return cost, y, z


def best_schedule_ref(job: Job, state: PriceState) -> Optional[Schedule]:
    """Alg. 2: enumerate deadlines, DP over workload splits."""
    T = state.cluster.T
    a = job.arrival
    Dtot = job.workload
    dcap = min(job.max_chunks_per_slot, Dtot)
    p = state.worker_prices()
    q = state.ps_prices()
    # cost_t rows
    rows = np.full((T, dcap + 1), INF)
    for t in range(a, T):
        for d in range(dcap + 1):
            rows[t, d], _, _ = cost_t_ref(job, state, p, q, t, d)
    # DP: cost[t][d] = min_{d'} rows[t][d'] + cost[t-1][d-d']
    cost = np.full((T, Dtot + 1), INF)
    split = np.zeros((T, Dtot + 1), dtype=np.int64)
    prev = np.full(Dtot + 1, INF)
    prev[0] = 0.0
    best_payoff, best_t = 0.0, -1
    for t in range(a, T):
        for d in range(Dtot + 1):
            lim = min(d, dcap)
            best_c, best_d = INF, 0
            for dp in range(lim + 1):
                c = rows[t, dp] + prev[d - dp]
                if c < best_c - 1e-12:
                    best_c, best_d = c, dp
            cost[t, d] = best_c
            split[t, d] = best_d
        prev = cost[t]
        if cost[t, Dtot] < INF:
            payoff = job.utility(t - a) - cost[t, Dtot]
            if payoff > best_payoff + 1e-12:
                best_payoff, best_t = payoff, t
    if best_t < 0:
        return None
    return _extract(job, state, p, q, split, best_t, best_payoff,
                    cost[best_t, Dtot])


def _extract(job: Job, state: PriceState, p: np.ndarray, q: np.ndarray,
             split: np.ndarray, t_hat: int, payoff: float, total_cost: float
             ) -> Schedule:
    """Backtrack the DP split table and re-run the greedy per slot."""
    workers, ps = {}, {}
    d_rem = job.workload
    for t in range(t_hat, job.arrival - 1, -1):
        d = int(split[t, d_rem])
        if d > 0:
            c, y, z = cost_t_ref(job, state, p, q, t, d)
            assert c < INF
            workers[t] = y
            ps[t] = z
        d_rem -= d
    assert d_rem == 0, f"backtrack failed: {d_rem} chunk-passes unassigned"
    return Schedule(jid=job.jid, workers=workers, ps=ps, finish=t_hat,
                    cost=total_cost, payoff=payoff,
                    utility=job.utility(t_hat - job.arrival))


# ---------------------------------------------------------------------------
# Vectorized implementation
# ---------------------------------------------------------------------------

def _prefix_tables(prices: np.ndarray, headroom: np.ndarray, demand: np.ndarray,
                   t0: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sorted per-slot unit costs + prefix sums of capacity and cost.

    Returns (ccap (T, S), ccost (T, S), scost (T, S)) where column j holds the
    cumulative capacity/cost over the j+1 cheapest servers at each slot.
    """
    T = prices.shape[0]
    unit = (prices * demand[None, None, :]).sum(axis=2)   # (T, S)
    cap = np.zeros(unit.shape, dtype=np.int64)
    full = np.full(unit.shape[1], np.iinfo(np.int64).max, dtype=np.int64)
    for t in range(t0, T):
        c = full.copy()
        for r in range(R):
            if demand[r] > 0:
                c = np.minimum(c, np.floor(headroom[t, :, r] / demand[r] + 1e-9).astype(np.int64))
        cap[t] = np.maximum(c, 0)
    order = np.argsort(unit, axis=1, kind="stable")
    scost = np.take_along_axis(unit, order, axis=1)
    scap = np.take_along_axis(cap, order, axis=1)
    ccap = np.cumsum(scap, axis=1)
    ccost = np.cumsum(scap * scost, axis=1)
    return ccap, ccost, scost


def _greedy_cost_for_counts(ccap: np.ndarray, ccost: np.ndarray, scost: np.ndarray,
                            counts: np.ndarray) -> np.ndarray:
    """Cost of greedily placing ``counts[j]`` instances at each slot row.

    ccap/ccost/scost: (S,) prefix tables for ONE slot; counts: (M,) wanted
    instance totals.  Returns (M,) costs (inf where counts exceed capacity).
    """
    total = ccap[-1] if ccap.size else 0
    out = np.full(counts.shape, INF)
    ok = counts <= total
    cz = counts == 0
    out[cz] = 0.0
    idx = np.searchsorted(ccap, counts, side="left")   # first prefix covering
    idx = np.minimum(idx, len(ccap) - 1)
    prev_cap = np.where(idx > 0, ccap[np.maximum(idx - 1, 0)], 0)
    prev_cost = np.where(idx > 0, ccost[np.maximum(idx - 1, 0)], 0.0)
    vals = prev_cost + (counts - prev_cap) * scost[idx]
    sel = ok & ~cz
    out[sel] = vals[sel]
    return out


def cost_t_rows(job: Job, state: PriceState, p: np.ndarray, q: np.ndarray,
                dcap: int) -> np.ndarray:
    """rows[t, d] = COST_t(t, d) for every slot and d in [0, dcap]."""
    T = state.cluster.T
    a = job.arrival
    rows = np.full((T, dcap + 1), INF)
    wc_cap, wc_cost, wc_scost = _prefix_tables(
        p, state.cluster.worker_caps[None] - state.g, job.worker_res, a)
    ps_cap, ps_cost, ps_scost = _prefix_tables(
        q, state.cluster.ps_caps[None] - state.v, job.ps_res, a)
    ds = np.arange(dcap + 1)
    W = np.array([job.workers_for(int(d)) for d in ds])      # (M,)
    feas_n = W <= job.num_chunks
    Z = np.array([job.ps_for(int(w)) for w in W])
    for t in range(a, T):
        w_costs = _greedy_cost_for_counts(wc_cap[t], wc_cost[t], wc_scost[t], W)
        # PS deployed = min(target, W, pool capacity); feasible iff >= (b/B) W
        pool = ps_cap[t, -1] if ps_cap.shape[1] else 0
        deploy = np.minimum(np.minimum(Z, W), pool)
        feas_ps = deploy * job.ps_bw >= W * job.worker_bw - 1e-9
        z_costs = _greedy_cost_for_counts(ps_cap[t], ps_cost[t], ps_scost[t], deploy)
        row = np.where(feas_n & feas_ps, w_costs + z_costs, INF)
        row[0] = 0.0
        rows[t] = row
    return rows


def minplus_band(prev: np.ndarray, row: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """new[d] = min_{d'} row[d'] + prev[d - d']; returns (new, argmin)."""
    D = prev.shape[0] - 1
    dcap = row.shape[0] - 1
    ids = np.arange(D + 1)[:, None] - np.arange(dcap + 1)[None, :]   # (D+1, dcap+1)
    prev_ext = np.append(prev, INF)
    cand = row[None, :] + prev_ext[np.where(ids >= 0, ids, -1)]
    cand = np.where(ids >= 0, cand, INF)
    arg = np.argmin(cand, axis=1)
    return cand[np.arange(D + 1), arg], arg


def best_schedule(job: Job, state: PriceState, *, use_jax: bool = False
                  ) -> Optional[Schedule]:
    """Vectorized Alg. 2 (numpy min-plus; optionally the JAX/Pallas path)."""
    T = state.cluster.T
    a = job.arrival
    Dtot = job.workload
    dcap = min(job.max_chunks_per_slot, Dtot)
    if dcap == 0:
        return None
    p = state.worker_prices()
    q = state.ps_prices()
    rows = cost_t_rows(job, state, p, q, dcap)
    if use_jax:
        from .schedule_jax import dp_sweep_jax
        cost_tab, split = dp_sweep_jax(rows[a:], Dtot)
    else:
        cost_tab = np.full((T - a, Dtot + 1), INF)
        split = np.zeros((T - a, Dtot + 1), dtype=np.int64)
        prev = np.full(Dtot + 1, INF)
        prev[0] = 0.0
        for i, t in enumerate(range(a, T)):
            cost_tab[i], split[i] = minplus_band(prev, rows[t])
            prev = cost_tab[i]
    best_payoff, best_i = 0.0, -1
    finite = cost_tab[:, Dtot] < INF
    for i in np.nonzero(finite)[0]:
        payoff = job.utility(i) - cost_tab[i, Dtot]
        if payoff > best_payoff + 1e-12:
            best_payoff, best_i = payoff, int(i)
    if best_i < 0:
        return None
    full_split = np.zeros((T, Dtot + 1), dtype=np.int64)
    full_split[a:] = split
    return _extract(job, state, p, q, full_split, a + best_i, best_payoff,
                    float(cost_tab[best_i, Dtot]))
