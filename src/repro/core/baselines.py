"""Baseline schedulers from the paper's evaluation (Sec. V-A):

FIFO, DRF (dominant-resource fairness), RRH (risk-reward heuristic),
and a Dorm-like utilization-maximizing repacker.  All are *reactive*
slot-steppers sharing one interface so the simulator can drive any of
them interchangeably with OASiS.

Each scheduler carries two repack implementations:

* ``step_reference`` — the seed's greedy loops, verbatim: one
  ``_place(1, ...)`` call per chunk, O(jobs x chunks) interpreter
  iterations per repack.  Kept as the equivalence oracle and the honest
  v1 baseline (``simulate_reference`` pins it via ``REPACK_IMPL``).
* ``step_kernel`` — the vectorized batch-round kernels from
  ``core/repack.py`` (the default): dense ``(n, R)`` demand arrays,
  masked whole-round passes, futile-retry elision.  Placement-for-
  placement equal to the reference (``tests/test_repack.py``).

``dirty`` tracks whether the next ``step`` can differ from the last one:
arrivals and repack-relevant completions set it, no-op events (a
completion with an empty wait queue under FIFO/RRH, a rejected RRH
arrival) leave it unset so the sim engine can skip the repack entirely.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import repack
from .repack import DensePool, _place_fast, _place_loop  # noqa: F401  (re-export)
from .types import ClusterSpec, Job


# Placement backend switch: "fast" (whole-pool array ops, the default) or
# "loop" (the seed's per-server Python scan, kept as the honest baseline
# for `simulate_reference` / the sim-v2 speedup benchmark).  Both produce
# bit-identical placements (tests/test_sim_v2.py::test_place_fast_equals_loop).
PLACE_IMPL = "fast"

# Repack backend switch: "kernel" (vectorized batch-round kernels from
# core/repack.py, the default) or "reference" (the seed's greedy loops).
# ``simulate_reference`` pins "reference" for the honest v1 code path.
REPACK_IMPL = "kernel"


def _place(count: int, free: np.ndarray, demand: np.ndarray) -> Optional[np.ndarray]:
    """Round-robin placement of ``count`` instances onto servers.

    free: (S, R) remaining capacity (mutated on success).  Returns per-server
    counts or None if the pool cannot host all instances.
    """
    if PLACE_IMPL == "loop":
        return _place_loop(count, free, demand)
    return _place_fast(count, free, demand)


class ReactiveScheduler:
    """Base class: admit-all, allocate per slot.

    Admission is split into ``would_admit`` (the pure decision) and
    ``enroll`` (the state mutation) so an external decider — the rl/
    subsystem's learned policy, or a replay policy asserting env/engine
    equivalence — can substitute its own decision while reusing the
    scheduler's allocation machinery.  ``on_arrival`` composes the two and
    is the unchanged entry point for the simulators.
    """

    name = "base"

    def __init__(self, cluster: ClusterSpec, fixed_workers: int = 8):
        self.cluster = cluster
        self.fixed_workers = fixed_workers
        self.jobs: Dict[int, Job] = {}
        self.unfinished: List[int] = []    # insertion == arrival order
        self.alloc: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.pool = DensePool(cluster.worker_caps.shape[1])
        self.dirty = True
        # Effective capacities every repack packs against.  They default
        # to the cluster's own arrays (the *same objects* — the zero-churn
        # paths stay bit-identical) and are swapped for masked copies by
        # ``set_capacity`` when the fleet-churn engine takes servers down.
        self.worker_caps = cluster.worker_caps
        self.ps_caps = cluster.ps_caps

    # -- events -------------------------------------------------------------
    def would_admit(self, job: Job, t: int) -> bool:
        """The scheduler's own admission decision (no state change)."""
        return True          # admit-all

    def enroll(self, job: Job, t: int) -> None:
        """Admit ``job`` unconditionally (bookkeeping only)."""
        self.jobs[job.jid] = job
        self.unfinished.append(job.jid)
        self.pool.add(job)
        self.dirty = True

    def on_arrival(self, job: Job, t: int) -> bool:
        if not self.would_admit(job, t):
            return False
        self.enroll(job, t)
        return True

    def on_completion(self, jid: int, t: int) -> None:
        if jid in self.unfinished:
            self.unfinished.remove(jid)
        self.alloc.pop(jid, None)
        self.pool.remove(jid)
        # never clear an already-pending dirty (e.g. an arrival in the
        # same event batch that has not been stepped yet)
        self.dirty = self.dirty or self._completion_dirties()

    # -- fleet churn (sim/fleet.py) -----------------------------------------
    def set_capacity(self, worker_caps: np.ndarray,
                     ps_caps: np.ndarray) -> None:
        """Swap in the surviving fleet's effective capacity arrays
        (``FleetState.worker_caps``/``ps_caps``: dead servers masked to
        0-rows).  Every repack thereafter packs against the survivors."""
        self.worker_caps = worker_caps
        self.ps_caps = ps_caps
        self.dirty = True

    def preempt(self, jid: int, t: int) -> None:
        """Evict ``jid``'s allocation (its servers died); the job stays
        enrolled — ``unfinished`` keeps its arrival position, RRH keeps
        its admission ``_meta`` — so the next repack re-queues it through
        the scheduler's own resume order."""
        self.alloc.pop(jid, None)
        self.dirty = True

    def _completion_dirties(self) -> bool:
        """Can this completion change the next ``step`` output?  Freed
        capacity triggers a whole-set repack (DRF/Dorm) as long as
        anything is still live; FIFO/RRH refine this to "something is
        waiting" (running jobs keep their placement)."""
        return bool(self.unfinished)

    def _counts(self, job: Job) -> Tuple[int, int]:
        n = min(self.fixed_workers, job.num_chunks)
        return n, job.ps_for(n)

    def step(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        if REPACK_IMPL == "reference":
            return self.step_reference(t)
        return self.step_kernel(t)

    def step_reference(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        raise NotImplementedError

    def step_kernel(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        raise NotImplementedError


class FIFO(ReactiveScheduler):
    """Jobs served strictly in arrival order with fixed worker counts."""

    name = "fifo"

    def _completion_dirties(self) -> bool:
        # running jobs keep their placement; only a waiting job can use
        # the freed capacity
        return any(j not in self.alloc for j in self.unfinished)

    def step_reference(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.worker_caps.astype(float).copy()
        free_s = self.ps_caps.astype(float).copy()
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        # running jobs keep their placement (deduct first)
        for jid in self.unfinished:
            if jid in self.alloc:
                y, z = self.alloc[jid]
                job = self.jobs[jid]
                free_w -= y[:, None] * job.worker_res[None]
                free_s -= z[:, None] * job.ps_res[None]
                out[jid] = (y, z)
        # admit queued jobs head-of-line
        for jid in self.unfinished:
            if jid in self.alloc:
                continue
            job = self.jobs[jid]
            nw, nps = self._counts(job)
            y = _place(nw, free_w, job.worker_res)
            if y is None:
                break                        # FIFO head-of-line blocking
            z = _place(nps, free_s, job.ps_res)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                break
            self.alloc[jid] = (y, z)
            out[jid] = (y, z)
        return out

    def step_kernel(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.worker_caps.astype(float).copy()
        free_s = self.ps_caps.astype(float).copy()
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        running = [j for j in self.unfinished if j in self.alloc]
        repack.deduct_running(free_w, [self.alloc[j][0] for j in running],
                              [self.jobs[j].worker_res for j in running])
        repack.deduct_running(free_s, [self.alloc[j][1] for j in running],
                              [self.jobs[j].ps_res for j in running])
        out.update((j, self.alloc[j]) for j in running)
        for jid in self.unfinished:
            if jid in self.alloc:
                continue
            job = self.jobs[jid]
            nw, nps = self._counts(job)
            y = _place_fast(nw, free_w, job.worker_res)
            if y is None:
                break                        # FIFO head-of-line blocking
            z = _place_fast(nps, free_s, job.ps_res)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                break
            self.alloc[jid] = (y, z)
            out[jid] = (y, z)
        return out


class DRF(ReactiveScheduler):
    """Dominant-resource max-min fairness via progressive filling."""

    name = "drf"

    def step_reference(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.worker_caps.astype(float).copy()
        free_s = self.ps_caps.astype(float).copy()
        total_w = np.maximum(self.worker_caps.sum(axis=0), 1e-9)
        counts = {jid: 0 for jid in self.unfinished}
        shares = {jid: 0.0 for jid in self.unfinished}
        placements = {jid: (np.zeros(self.cluster.H, dtype=np.int64),
                            np.zeros(self.cluster.K, dtype=np.int64))
                      for jid in self.unfinished}
        blocked: set = set()
        while len(blocked) < len(counts):
            cand = [j for j in self.unfinished if j not in blocked]
            if not cand:
                break
            jid = min(cand, key=lambda j: shares[j])
            job = self.jobs[jid]
            if counts[jid] >= job.num_chunks:
                blocked.add(jid)
                continue
            y = _place(1, free_w, job.worker_res)
            if y is None:
                blocked.add(jid)
                continue
            need_ps = job.ps_for(counts[jid] + 1) - int(placements[jid][1].sum())
            z = _place(need_ps, free_s, job.ps_res) if need_ps > 0 else np.zeros(
                self.cluster.K, dtype=np.int64)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                blocked.add(jid)
                continue
            counts[jid] += 1
            placements[jid] = (placements[jid][0] + y, placements[jid][1] + z)
            dom = np.max(counts[jid] * job.worker_res / total_w)
            shares[jid] = float(dom)
        return {j: pl for j, pl in placements.items() if pl[0].sum() > 0}

    def step_kernel(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        return repack.drf_repack(self.worker_caps, self.ps_caps,
                                 self.pool, self.unfinished)


class RRH(ReactiveScheduler):
    """Risk-reward heuristic [Irwin et al., HPDC'04 as used in the paper]:
    admit iff estimated utility minus a delay cost clears a threshold;
    running jobs keep fixed counts, paused jobs resume by payoff density."""

    name = "rrh"

    def __init__(self, cluster: ClusterSpec, fixed_workers: int = 8,
                 delay_penalty: float = 0.5, threshold: float = 0.0):
        super().__init__(cluster, fixed_workers)
        self.delay_penalty = delay_penalty
        self.threshold = threshold
        # jid -> (nw, nps, est duration, payoff-density denominator); the
        # static parts of the resume-order key, precomputed at admission
        self._meta: Dict[int, Tuple[int, int, int, float]] = {}

    def would_admit(self, job: Job, t: int) -> bool:
        nw, _ = self._counts(job)
        est_dur = math.ceil(job.total_work_slots / max(nw, 1))
        backlog = len(self.unfinished)
        reward = job.utility(est_dur) - self.delay_penalty * backlog
        return reward > self.threshold

    def enroll(self, job: Job, t: int) -> None:
        nw, nps = self._counts(job)
        est_dur = math.ceil(job.total_work_slots / max(nw, 1))
        self._meta[job.jid] = (nw, nps, est_dur,
                               max(nw * job.worker_res.sum(), 1e-9))
        super().enroll(job, t)

    def on_completion(self, jid: int, t: int) -> None:
        super().on_completion(jid, t)
        self._meta.pop(jid, None)

    def _completion_dirties(self) -> bool:
        # no paused job to resume -> freed capacity changes nothing
        return any(j not in self.alloc for j in self.unfinished)

    def step_reference(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.worker_caps.astype(float).copy()
        free_s = self.ps_caps.astype(float).copy()
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for jid in self.unfinished:           # running keep allocation
            if jid in self.alloc:
                y, z = self.alloc[jid]
                job = self.jobs[jid]
                free_w -= y[:, None] * job.worker_res[None]
                free_s -= z[:, None] * job.ps_res[None]
                out[jid] = (y, z)
        # resume/start paused jobs in order of payoff density
        waiting = [j for j in self.unfinished if j not in self.alloc]
        def density(jid: int) -> float:
            job = self.jobs[jid]
            nw, _ = self._counts(job)
            dur = math.ceil(job.total_work_slots / max(nw, 1))
            return -job.utility(dur + (t - job.arrival)) / max(
                nw * job.worker_res.sum(), 1e-9)
        for jid in sorted(waiting, key=density):
            job = self.jobs[jid]
            nw, nps = self._counts(job)
            y = _place(nw, free_w, job.worker_res)
            if y is None:
                continue
            z = _place(nps, free_s, job.ps_res)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                continue
            self.alloc[jid] = (y, z)
            out[jid] = (y, z)
        return out

    def step_kernel(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.worker_caps.astype(float).copy()
        free_s = self.ps_caps.astype(float).copy()
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        running = [j for j in self.unfinished if j in self.alloc]
        repack.deduct_running(free_w, [self.alloc[j][0] for j in running],
                              [self.jobs[j].worker_res for j in running])
        repack.deduct_running(free_s, [self.alloc[j][1] for j in running],
                              [self.jobs[j].ps_res for j in running])
        out.update((j, self.alloc[j]) for j in running)
        waiting = [j for j in self.unfinished if j not in self.alloc]
        order = repack.rrh_resume_order([self.jobs[j] for j in waiting],
                                        [self._meta[j] for j in waiting], t)
        for i in order:
            jid = waiting[int(i)]
            job = self.jobs[jid]
            nw, nps, _, _ = self._meta[jid]
            y = _place_fast(nw, free_w, job.worker_res)
            if y is None:
                continue
            z = _place_fast(nps, free_s, job.ps_res)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                continue
            self.alloc[jid] = (y, z)
            out[jid] = (y, z)
        return out


class Dorm(ReactiveScheduler):
    """Dorm-like repacking: on each event maximize cluster utilization
    subject to round-robin fairness (MILP of [18] approximated greedily)."""

    name = "dorm"

    def step_reference(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.worker_caps.astype(float).copy()
        free_s = self.ps_caps.astype(float).copy()
        placements = {jid: (np.zeros(self.cluster.H, dtype=np.int64),
                            np.zeros(self.cluster.K, dtype=np.int64))
                      for jid in self.unfinished}
        counts = {jid: 0 for jid in self.unfinished}
        progress = True
        while progress:                       # round-robin water filling
            progress = False
            for jid in self.unfinished:
                job = self.jobs[jid]
                if counts[jid] >= job.num_chunks:
                    continue
                y = _place(1, free_w, job.worker_res)
                if y is None:
                    continue
                need_ps = job.ps_for(counts[jid] + 1) - int(placements[jid][1].sum())
                z = _place(need_ps, free_s, job.ps_res) if need_ps > 0 else np.zeros(
                    self.cluster.K, dtype=np.int64)
                if z is None:
                    free_w += y[:, None] * job.worker_res[None]
                    continue
                counts[jid] += 1
                placements[jid] = (placements[jid][0] + y, placements[jid][1] + z)
                progress = True
        return {j: pl for j, pl in placements.items() if pl[0].sum() > 0}

    def step_kernel(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        return repack.dorm_repack(self.worker_caps, self.ps_caps,
                                  self.pool, self.unfinished)


class Learned(FIFO):
    """FIFO allocation machinery with *per-job* worker/PS counts chosen by
    an external policy at admission (the rl/ subsystem's action space).

    A job admitted with counts ``(nw, nps)`` holds exactly that allocation
    from the moment it fits until completion; waiting jobs start in
    arrival order with FIFO head-of-line blocking.  With no counts set
    this degenerates to FIFO verbatim (``_counts`` falls back to the
    fixed-worker rule), which is the anchor of the env/engine equivalence
    suite: a policy that replays FIFO's counts must reproduce the FIFO
    run bit-for-bit.
    """

    name = "learned"

    def __init__(self, cluster: ClusterSpec, fixed_workers: int = 8):
        super().__init__(cluster, fixed_workers=fixed_workers)
        self.counts_for: Dict[int, Tuple[int, int]] = {}

    def set_counts(self, jid: int, nw: int, nps: int) -> None:
        """Pin the worker/PS counts the next ``step`` will allocate."""
        self.counts_for[jid] = (int(nw), int(nps))

    def _counts(self, job: Job) -> Tuple[int, int]:
        if job.jid in self.counts_for:
            return self.counts_for[job.jid]
        return super()._counts(job)

    def on_completion(self, jid: int, t: int) -> None:
        super().on_completion(jid, t)
        self.counts_for.pop(jid, None)


BASELINES = {"fifo": FIFO, "drf": DRF, "rrh": RRH, "dorm": Dorm,
             "learned": Learned}
