"""Baseline schedulers from the paper's evaluation (Sec. V-A):

FIFO, DRF (dominant-resource fairness), RRH (risk-reward heuristic),
and a Dorm-like utilization-maximizing repacker.  All are *reactive*
slot-steppers sharing one interface so the simulator can drive any of
them interchangeably with OASiS.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .types import ClusterSpec, Job, R


# Placement backend switch: "fast" (whole-pool array ops, the default) or
# "loop" (the seed's per-server Python scan, kept as the honest baseline
# for `simulate_reference` / the sim-v2 speedup benchmark).  Both produce
# bit-identical placements (tests/test_sim_v2.py::test_place_fast_equals_loop).
PLACE_IMPL = "fast"


def _place(count: int, free: np.ndarray, demand: np.ndarray) -> Optional[np.ndarray]:
    """Round-robin placement of ``count`` instances onto servers.

    free: (S, R) remaining capacity (mutated on success).  Returns per-server
    counts or None if the pool cannot host all instances.
    """
    if PLACE_IMPL == "loop":
        return _place_loop(count, free, demand)
    return _place_fast(count, free, demand)


def _place_fast(count: int, free: np.ndarray, demand: np.ndarray
                ) -> Optional[np.ndarray]:
    """Each round places one instance on every server (in index order) that
    still fits the demand; rounds repeat until all instances are placed or
    no server fits.  The whole round's fit mask is one array op — server
    rows are independent, so checking before the round equals checking at
    each visit, bit for bit, including the 1e-9 slack and the sequential
    ``free -= demand`` float updates of the per-server loop."""
    S = free.shape[0]
    out = np.zeros(S, dtype=np.int64)
    if count == 0:
        return out
    placed = 0
    while placed < count:
        fits = np.flatnonzero(np.all(free >= demand[None] - 1e-9, axis=1))
        if fits.size == 0:
            # rollback
            free += out[:, None] * demand[None]
            return None
        take = fits[:count - placed]
        free[take] -= demand[None]
        out[take] += 1
        placed += take.size
    return out


def _place_loop(count: int, free: np.ndarray, demand: np.ndarray
                ) -> Optional[np.ndarray]:
    """The seed's per-server scan (v1 baseline; see PLACE_IMPL)."""
    S = free.shape[0]
    out = np.zeros(S, dtype=np.int64)
    if count == 0:
        return out
    placed = 0
    for rounds in range(count):
        progressed = False
        for srv in range(S):
            if placed >= count:
                break
            if np.all(free[srv] >= demand - 1e-9):
                free[srv] -= demand
                out[srv] += 1
                placed += 1
                progressed = True
        if placed >= count:
            break
        if not progressed:
            # rollback
            for srv in range(S):
                free[srv] += out[srv] * demand
            return None
    if placed < count:
        for srv in range(S):
            free[srv] += out[srv] * demand
        return None
    return out


class ReactiveScheduler:
    """Base class: admit-all, allocate per slot."""

    name = "base"

    def __init__(self, cluster: ClusterSpec, fixed_workers: int = 8):
        self.cluster = cluster
        self.fixed_workers = fixed_workers
        self.jobs: Dict[int, Job] = {}
        self.unfinished: List[int] = []    # insertion == arrival order
        self.alloc: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.dirty = True

    # -- events -------------------------------------------------------------
    def on_arrival(self, job: Job, t: int) -> bool:
        self.jobs[job.jid] = job
        self.unfinished.append(job.jid)
        self.dirty = True
        return True          # admit-all

    def on_completion(self, jid: int, t: int) -> None:
        if jid in self.unfinished:
            self.unfinished.remove(jid)
        self.alloc.pop(jid, None)
        self.dirty = True

    def _counts(self, job: Job) -> Tuple[int, int]:
        n = min(self.fixed_workers, job.num_chunks)
        return n, job.ps_for(n)

    def step(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        raise NotImplementedError


class FIFO(ReactiveScheduler):
    """Jobs served strictly in arrival order with fixed worker counts."""

    name = "fifo"

    def step(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.cluster.worker_caps.astype(float).copy()
        free_s = self.cluster.ps_caps.astype(float).copy()
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        # running jobs keep their placement (deduct first)
        for jid in self.unfinished:
            if jid in self.alloc:
                y, z = self.alloc[jid]
                job = self.jobs[jid]
                free_w -= y[:, None] * job.worker_res[None]
                free_s -= z[:, None] * job.ps_res[None]
                out[jid] = (y, z)
        # admit queued jobs head-of-line
        for jid in self.unfinished:
            if jid in self.alloc:
                continue
            job = self.jobs[jid]
            nw, nps = self._counts(job)
            y = _place(nw, free_w, job.worker_res)
            if y is None:
                break                        # FIFO head-of-line blocking
            z = _place(nps, free_s, job.ps_res)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                break
            self.alloc[jid] = (y, z)
            out[jid] = (y, z)
        return out


class DRF(ReactiveScheduler):
    """Dominant-resource max-min fairness via progressive filling."""

    name = "drf"

    def step(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.cluster.worker_caps.astype(float).copy()
        free_s = self.cluster.ps_caps.astype(float).copy()
        total_w = np.maximum(self.cluster.worker_caps.sum(axis=0), 1e-9)
        counts = {jid: 0 for jid in self.unfinished}
        shares = {jid: 0.0 for jid in self.unfinished}
        placements = {jid: (np.zeros(self.cluster.H, dtype=np.int64),
                            np.zeros(self.cluster.K, dtype=np.int64))
                      for jid in self.unfinished}
        blocked: set = set()
        while len(blocked) < len(counts):
            cand = [j for j in self.unfinished if j not in blocked]
            if not cand:
                break
            jid = min(cand, key=lambda j: shares[j])
            job = self.jobs[jid]
            if counts[jid] >= job.num_chunks:
                blocked.add(jid)
                continue
            y = _place(1, free_w, job.worker_res)
            if y is None:
                blocked.add(jid)
                continue
            need_ps = job.ps_for(counts[jid] + 1) - int(placements[jid][1].sum())
            z = _place(need_ps, free_s, job.ps_res) if need_ps > 0 else np.zeros(
                self.cluster.K, dtype=np.int64)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                blocked.add(jid)
                continue
            counts[jid] += 1
            placements[jid] = (placements[jid][0] + y, placements[jid][1] + z)
            dom = np.max(counts[jid] * job.worker_res / total_w)
            shares[jid] = float(dom)
        return {j: pl for j, pl in placements.items() if pl[0].sum() > 0}


class RRH(ReactiveScheduler):
    """Risk-reward heuristic [Irwin et al., HPDC'04 as used in the paper]:
    admit iff estimated utility minus a delay cost clears a threshold;
    running jobs keep fixed counts, paused jobs resume by payoff density."""

    name = "rrh"

    def __init__(self, cluster: ClusterSpec, fixed_workers: int = 8,
                 delay_penalty: float = 0.5, threshold: float = 0.0):
        super().__init__(cluster, fixed_workers)
        self.delay_penalty = delay_penalty
        self.threshold = threshold

    def on_arrival(self, job: Job, t: int) -> bool:
        nw, _ = self._counts(job)
        est_dur = math.ceil(job.total_work_slots / max(nw, 1))
        backlog = len(self.unfinished)
        reward = job.utility(est_dur) - self.delay_penalty * backlog
        if reward <= self.threshold:
            return False
        return super().on_arrival(job, t)

    def step(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.cluster.worker_caps.astype(float).copy()
        free_s = self.cluster.ps_caps.astype(float).copy()
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for jid in self.unfinished:           # running keep allocation
            if jid in self.alloc:
                y, z = self.alloc[jid]
                job = self.jobs[jid]
                free_w -= y[:, None] * job.worker_res[None]
                free_s -= z[:, None] * job.ps_res[None]
                out[jid] = (y, z)
        # resume/start paused jobs in order of payoff density
        waiting = [j for j in self.unfinished if j not in self.alloc]
        def density(jid: int) -> float:
            job = self.jobs[jid]
            nw, _ = self._counts(job)
            dur = math.ceil(job.total_work_slots / max(nw, 1))
            return -job.utility(dur + (t - job.arrival)) / max(
                nw * job.worker_res.sum(), 1e-9)
        for jid in sorted(waiting, key=density):
            job = self.jobs[jid]
            nw, nps = self._counts(job)
            y = _place(nw, free_w, job.worker_res)
            if y is None:
                continue
            z = _place(nps, free_s, job.ps_res)
            if z is None:
                free_w += y[:, None] * job.worker_res[None]
                continue
            self.alloc[jid] = (y, z)
            out[jid] = (y, z)
        return out


class Dorm(ReactiveScheduler):
    """Dorm-like repacking: on each event maximize cluster utilization
    subject to round-robin fairness (MILP of [18] approximated greedily)."""

    name = "dorm"

    def step(self, t: int) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        free_w = self.cluster.worker_caps.astype(float).copy()
        free_s = self.cluster.ps_caps.astype(float).copy()
        placements = {jid: (np.zeros(self.cluster.H, dtype=np.int64),
                            np.zeros(self.cluster.K, dtype=np.int64))
                      for jid in self.unfinished}
        counts = {jid: 0 for jid in self.unfinished}
        progress = True
        while progress:                       # round-robin water filling
            progress = False
            for jid in self.unfinished:
                job = self.jobs[jid]
                if counts[jid] >= job.num_chunks:
                    continue
                y = _place(1, free_w, job.worker_res)
                if y is None:
                    continue
                need_ps = job.ps_for(counts[jid] + 1) - int(placements[jid][1].sum())
                z = _place(need_ps, free_s, job.ps_res) if need_ps > 0 else np.zeros(
                    self.cluster.K, dtype=np.int64)
                if z is None:
                    free_w += y[:, None] * job.worker_res[None]
                    continue
                counts[jid] += 1
                placements[jid] = (placements[jid][0] + y, placements[jid][1] + z)
                progress = True
        return {j: pl for j, pl in placements.items() if pl[0].sum() > 0}


BASELINES = {"fifo": FIFO, "drf": DRF, "rrh": RRH, "dorm": Dorm}
