"""Exact offline optimum of problem (1)-(13) for small instances.

Linearizes the completion-time argmax (8) with finish indicators
u_{i,t} (job i finishes at slot t):  maximize sum u_{i,t} f_i(t - a_i)
s.t. work after the declared finish is forbidden.  Solved with scipy's
HiGHS MILP.  Used by benchmarks/fig5 (performance ratio) and the
competitive-ratio tests.  The paper reports 2 days for 10 jobs with a
generic solver; keep instances tiny.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np
from scipy import optimize, sparse

from .types import ClusterSpec, Job, R


def offline_optimum(cluster: ClusterSpec, jobs: Sequence[Job],
                    time_limit: float = 120.0) -> float:
    T, H, K = cluster.T, cluster.H, cluster.K
    I = len(jobs)
    # variable layout: y[i,h,t] | z[i,k,t] | u[i,t]
    ny, nz, nu = I * H * T, I * K * T, I * T
    n = ny + nz + nu

    def yi(i, h, t):
        return (i * H + h) * T + t

    def zi(i, k, t):
        return ny + (i * K + k) * T + t

    def ui(i, t):
        return ny + nz + i * T + t

    c = np.zeros(n)
    for i, job in enumerate(jobs):
        for t in range(job.arrival, T):
            c[ui(i, t)] = -job.utility(t - job.arrival)   # milp minimizes

    rows, cols, vals, lbs, ubs = [], [], [], [], []
    ridx = 0

    def add_row(entries, lb, ub):
        nonlocal ridx
        for col, v in entries:
            rows.append(ridx)
            cols.append(col)
            vals.append(v)
        lbs.append(lb)
        ubs.append(ub)
        ridx += 1

    big_w = [max(1, int(j.num_chunks)) for j in jobs]
    for i, job in enumerate(jobs):
        work = job.total_work_slots                     # E N M (tau+2e/b)
        # (2): sum_t,h y >= work * x_i  with x_i = sum_t u
        ent = [(yi(i, h, t), 1.0) for h in range(H) for t in range(job.arrival, T)]
        ent += [(ui(i, t), -work) for t in range(job.arrival, T)]
        add_row(ent, 0.0, np.inf)
        # (17): sum_t u <= 1
        add_row([(ui(i, t), 1.0) for t in range(job.arrival, T)], 0.0, 1.0)
        for t in range(job.arrival, T):
            # (3) + finish coupling: sum_h y_iht <= N_i * sum_{t'>=t} u_it'
            ent = [(yi(i, h, t), 1.0) for h in range(H)]
            ent += [(ui(i, tp), -float(big_w[i])) for tp in range(t, T)]
            add_row(ent, -np.inf, 0.0)
            # (6): b_i sum_h y <= B_i sum_k z
            ent = [(yi(i, h, t), job.worker_bw) for h in range(H)]
            ent += [(zi(i, k, t), -job.ps_bw) for k in range(K)]
            add_row(ent, -np.inf, 0.0)
            # (7): sum_k z <= sum_h y
            ent = [(zi(i, k, t), 1.0) for k in range(K)]
            ent += [(yi(i, h, t), -1.0) for h in range(H)]
            add_row(ent, -np.inf, 0.0)
    # capacities (4)(5)
    for t in range(T):
        for r in range(R):
            for h in range(H):
                ent = [(yi(i, h, t), jobs[i].worker_res[r]) for i in range(I)
                       if jobs[i].worker_res[r] > 0]
                if ent:
                    add_row(ent, -np.inf, float(cluster.worker_caps[h, r]))
            for k in range(K):
                ent = [(zi(i, k, t), jobs[i].ps_res[r]) for i in range(I)
                       if jobs[i].ps_res[r] > 0]
                if ent:
                    add_row(ent, -np.inf, float(cluster.ps_caps[k, r]))

    A = sparse.coo_matrix((vals, (rows, cols)), shape=(ridx, n))
    lb = np.zeros(n)
    ub = np.zeros(n)
    for i, job in enumerate(jobs):
        for t in range(T):
            active = t >= job.arrival
            for h in range(H):
                ub[yi(i, h, t)] = job.num_chunks if active else 0.0
            for k in range(K):
                ub[zi(i, k, t)] = job.num_chunks if active else 0.0
            ub[ui(i, t)] = 1.0 if active else 0.0
    res = optimize.milp(
        c, constraints=optimize.LinearConstraint(A, np.array(lbs), np.array(ubs)),
        integrality=np.ones(n), bounds=optimize.Bounds(lb, ub),
        options={"time_limit": time_limit, "mip_rel_gap": 1e-6})
    if res.status not in (0, 1) or res.x is None:
        raise RuntimeError(f"MILP failed: {res.message}")
    return float(-res.fun)
