"""Alg. 1 — OASiS online admission + scheduling loop."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from .pricing import PriceParams, PriceState
from .subroutine import best_schedule, best_schedule_ref
from .types import ClusterSpec, Job, Schedule


class OASiS:
    """Online scheduler: admit iff the best schedule has positive payoff.

    ``impl`` selects the dual-subroutine backend:
      * ``"ref"``    — loop-faithful Alg. 2 (test oracle; slow)
      * ``"fast"``   — vectorized numpy (default)
      * ``"jax"``    — vectorized with the JAX/Pallas min-plus DP sweep
    """

    def __init__(self, cluster: ClusterSpec, params: PriceParams,
                 impl: str = "fast", track_duality: bool = False):
        self.cluster = cluster
        self.state = PriceState(cluster, params)
        self.impl = impl
        self.accepted: Dict[int, Schedule] = {}
        self.rejected: List[int] = []
        self.total_utility = 0.0
        self.decision_seconds: List[float] = []
        # Lemma-2 instrumentation: per-accepted-job primal/dual increments
        # (P_i - P_{i-1}, D_i - D_{i-1}); tests assert the allocation-cost
        # relationship  ΔP >= ΔD / alpha  that drives Theorem 4.
        self.track_duality = track_duality
        self.primal_deltas: List[float] = []
        self.dual_deltas: List[float] = []

    # -- Alg. 1 "upon arrival of job i" ------------------------------------
    def on_arrival(self, job: Job) -> Optional[Schedule]:
        t0 = time.perf_counter()
        if self.impl == "ref":
            sched = best_schedule_ref(job, self.state)
        elif self.impl == "jax":
            sched = best_schedule(job, self.state, use_jax=True)
        else:
            sched = best_schedule(job, self.state)
        self.decision_seconds.append(time.perf_counter() - t0)
        if sched is None:                       # mu_i <= 0 -> reject
            self.rejected.append(job.jid)
            return None
        # lines 5-11: commit allocations, bump prices
        if self.track_duality:
            p0 = self.state.worker_prices()
            q0 = self.state.ps_prices()
        self.state.commit(job, sched.workers, sched.ps)
        if self.track_duality:
            p1 = self.state.worker_prices()
            q1 = self.state.ps_prices()
            # ΔD = mu_i + Σ (p' - p) c_h + Σ (q' - q) c_k   (Lemma 2)
            d_delta = sched.payoff
            d_delta += float(((p1 - p0) *
                              self.cluster.worker_caps[None]).sum())
            d_delta += float(((q1 - q0) * self.cluster.ps_caps[None]).sum())
            self.primal_deltas.append(sched.utility)
            self.dual_deltas.append(d_delta)
        self.accepted[job.jid] = sched
        self.total_utility += sched.utility
        return sched

    # -- views used by the simulator ---------------------------------------
    def allocation_at(self, t: int) -> Dict[int, tuple]:
        out = {}
        for jid, sched in self.accepted.items():
            if t in sched.workers:
                out[jid] = (sched.workers[t], sched.ps.get(t))
        return out
