"""Alg. 1 — OASiS online admission + scheduling loop.

With ``impl="jax"`` decisions stream through the persistent fused engine
(`core/schedule_jax.py`): compiled executables are keyed by shape bucket
and read dual prices directly from the device-resident ``PriceState``
(`core/pricing.py`), whose ``commit``/``release`` apply jit slot-window
adds instead of re-uploading the full allocation tables per accepted job.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from .pricing import PriceParams, PriceState
from .subroutine import best_schedule, best_schedule_ref
from .types import ClusterSpec, Job, Schedule
from .. import obs as _obs


class OASiS:
    """Online scheduler: admit iff the best schedule has positive payoff.

    ``impl`` selects the dual-subroutine backend:
      * ``"ref"``    — loop-faithful Alg. 2 (test oracle; slow)
      * ``"fast"``   — vectorized numpy (default)
      * ``"jax"``    — fused jit engine (one XLA call per decision; Pallas
                       min-plus sweep kernel on TPU) with vmapped batching
                       via :meth:`on_arrivals`
      * ``"loop"``   — the seed's per-slot-loop numpy path (benchmark
                       baseline only)

    Example — one Alg. 1 pass over a tiny trace::

        >>> from repro.core.oasis import OASiS
        >>> from repro.core.pricing import price_params_from_jobs
        >>> from repro.sim.workload import make_cluster, make_jobs
        >>> cluster = make_cluster(T=20, H=3, K=3)
        >>> jobs = sorted(make_jobs(4, T=20, seed=0, small=True),
        ...               key=lambda j: j.arrival)
        >>> sched = OASiS(cluster, price_params_from_jobs(jobs, cluster))
        >>> plans = sched.on_arrivals(jobs)
        >>> [p is not None for p in plans]     # admission decisions
        [True, True, True, True]
        >>> sorted(sched.accepted)
        [0, 1, 2, 3]
        >>> cap = sum(j.utility.gamma1 for j in jobs)   # sigmoid sup
        >>> 0 < sched.total_utility <= cap
        True
    """

    def __init__(self, cluster: ClusterSpec, params: PriceParams,
                 impl: str = "fast", track_duality: bool = False,
                 batch_threshold: int = 2, window: Optional[int] = None):
        self.cluster = cluster
        # ``window`` bounds the price-state's resident slots for the
        # continuous serving mode (sim/engine.py ``run_stream``): decisions
        # then index window-local slots and the caller is responsible for
        # ``state.advance``-ing the origin to each arrival's slot.  The
        # default keeps the full fixed-horizon tables.
        self.state = PriceState(cluster, params, window=window)
        self.impl = impl
        # min batch size before on_arrivals uses the vmapped engine
        self.batch_threshold = max(2, batch_threshold)
        self.accepted: Dict[int, Schedule] = {}
        self.rejected: List[int] = []
        self.total_utility = 0.0
        self.decision_seconds: List[float] = []
        # Lemma-2 instrumentation: per-accepted-job primal/dual increments
        # (P_i - P_{i-1}, D_i - D_{i-1}); tests assert the allocation-cost
        # relationship  ΔP >= ΔD / alpha  that drives Theorem 4.
        self.track_duality = track_duality
        self.primal_deltas: List[float] = []
        self.dual_deltas: List[float] = []

    # -- Alg. 1 "upon arrival of job i" ------------------------------------
    def propose(self, job: Job) -> Optional[Schedule]:
        """Alg. 2 candidate at current prices (no commitment, no state
        change beyond latency accounting).  ``None`` means no schedule has
        positive payoff — Alg. 1 would reject.  Split from ``on_arrival``
        so an external decider (the rl/ env's admission gate) can veto or
        confirm the commitment."""
        t0 = time.perf_counter()
        with _obs.span("decide", jid=job.jid, impl=self.impl):
            if self.impl == "ref":
                sched = best_schedule_ref(job, self.state)
            elif self.impl == "jax":
                sched = best_schedule(job, self.state, use_jax=True)
            elif self.impl == "loop":
                sched = best_schedule(job, self.state, rows_impl="loop")
            else:
                sched = best_schedule(job, self.state)
        dt = time.perf_counter() - t0
        self.decision_seconds.append(dt)
        if _obs.ENABLED:
            _obs.inc("decide.decisions")
            _obs.observe("decide.seconds", dt)
        return sched

    def on_arrival(self, job: Job) -> Optional[Schedule]:
        return self._resolve(job, self.propose(job))

    def on_arrivals(self, jobs: List[Job]) -> List[Optional[Schedule]]:
        """Batched arrivals: decide the whole burst in one engine launch
        per shape bucket, then commit sequentially.

        Alg. 1 semantics are preserved exactly.  Candidates are speculative
        (computed at the prices in effect when the batch starts):

        * a REJECTED candidate is final — commits only raise prices and
          shrink headroom, so every schedule's payoff can only decrease and
          a non-positive maximum stays non-positive;
        * an ACCEPTED candidate is used as-is only while no other job from
          the batch has been admitted; once prices move it is re-solved
          against the updated state — *incrementally*: the speculative
          pass's COST rows are cached per job (``RowCache``), the price
          state's dirty-slot log says which slots earlier commits touched,
          and the re-solve recomputes only those tiles.

        The result is identical, job for job, to calling ``on_arrival`` in
        sequence (stable arrival order).
        """
        order = sorted(range(len(jobs)), key=lambda i: jobs[i].arrival)
        out: List[Optional[Schedule]] = [None] * len(jobs)
        if self.impl != "jax" or len(jobs) < self.batch_threshold:
            for i in order:
                out[i] = self.on_arrival(jobs[i])
            return out
        import jax
        import jax.numpy as jnp
        from .schedule_jax import (_materialize, _state_arrays, _x64_context,
                                   best_schedule_fused, decide_burst)
        times: List[float] = []
        with _obs.span("decide_burst", n=len(jobs), impl=self.impl):
            pends = decide_burst([jobs[i] for i in order], self.state,
                                 timings=times)
        prices_moved = False
        with _x64_context("auto"):
            dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
            for pos, (i, pend) in enumerate(zip(order, pends)):
                if pend is None:                  # dcap == 0: trivial reject
                    self.decision_seconds.append(times[pos])
                    out[i] = self._resolve(jobs[i], None)
                elif pend.best_t < 0 or not prices_moved:
                    # speculative reject is final; speculative accept is
                    # valid while no earlier job in the burst committed
                    sched = None
                    t0 = time.perf_counter()
                    if pend.best_t >= 0:
                        sd = _state_arrays(self.state, dtype)
                        sched = _materialize(pend, self.state, sd, dtype)
                    self.decision_seconds.append(
                        times[pos] + time.perf_counter() - t0)
                    out[i] = self._resolve(jobs[i], sched)
                    prices_moved = prices_moved or out[i] is not None
                else:
                    # prices moved: incremental re-solve over cached rows
                    t0 = time.perf_counter()
                    with _obs.span("decide.row_cache_sync",
                                   jid=jobs[i].jid):
                        pend.cache.sync(self.state)
                    with _obs.span("decide.resolve", jid=jobs[i].jid):
                        sched = best_schedule_fused(jobs[i], self.state,
                                                    row_cache=pend.cache)
                    # the speculative batch share spent on this job is real
                    # per-decision cost too — don't under-report latency
                    self.decision_seconds.append(
                        time.perf_counter() - t0 + times[pos])
                    out[i] = self._resolve(jobs[i], sched)
        if _obs.ENABLED:
            _obs.inc("decide.decisions", len(jobs))
        return out

    def _resolve(self, job: Job, sched: Optional[Schedule]
                 ) -> Optional[Schedule]:
        """Alg. 1 lines 5-11: admit iff positive payoff, commit, bump prices."""
        if sched is None:                       # mu_i <= 0 -> reject
            self.rejected.append(job.jid)
            return None
        if self.track_duality:
            # prices move only inside the committed slot window, so the
            # Lemma-2 increments are computed from those slots alone
            # (elementwise prices: unchanged entries difference to exactly
            # 0.0) instead of materializing the full (T,H,R)+(T,K,R)
            # exponential tables twice per accepted job
            w_slots = np.fromiter(sched.workers.keys(), dtype=np.int64,
                                  count=len(sched.workers))
            z_slots = np.fromiter(sched.ps.keys(), dtype=np.int64,
                                  count=len(sched.ps))
            p0 = self.state.worker_prices_at(w_slots)
            q0 = self.state.ps_prices_at(z_slots)
        self.state.commit(job, sched.workers, sched.ps)
        if self.track_duality:
            p1 = self.state.worker_prices_at(w_slots)
            q1 = self.state.ps_prices_at(z_slots)
            # ΔD = mu_i + Σ (p' - p) c_h + Σ (q' - q) c_k   (Lemma 2)
            d_delta = sched.payoff
            d_delta += float(((p1 - p0) *
                              self.cluster.worker_caps[None]).sum())
            d_delta += float(((q1 - q0) * self.cluster.ps_caps[None]).sum())
            self.primal_deltas.append(sched.utility)
            self.dual_deltas.append(d_delta)
        self.accepted[job.jid] = sched
        self.total_utility += sched.utility
        return sched

    # -- views used by the simulator ---------------------------------------
    def allocation_at(self, t: int) -> Dict[int, tuple]:
        out = {}
        for jid, sched in self.accepted.items():
            if t in sched.workers:
                out[jid] = (sched.workers[t], sched.ps.get(t))
        return out
