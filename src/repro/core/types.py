"""Core data types for the OASiS scheduler (paper Sec. III).

Resources are abstract vectors of length R.  The paper's simulation uses
R = 5: GPU, vCPU, memory (GB), storage (GB), bandwidth (Gbps).  Worker
resource demands are ``w`` (on the H pool), parameter-server demands are
``s`` (on the K pool).  All times are measured in scheduling slots.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

RESOURCES = ("gpu", "cpu", "mem", "storage", "bw")
R = len(RESOURCES)
BW = RESOURCES.index("bw")


@dataclasses.dataclass(frozen=True)
class SigmoidUtility:
    """f_i(d) = gamma1 / (1 + exp(gamma2 * (d - gamma3))) (paper Sec. V-A).

    gamma2 = 0  -> time-insensitive (constant utility gamma1 / 2 * 2 = gamma1/ (1+1)).
    Note the paper uses the same form; at gamma2 = 0 the utility is a
    constant gamma1 / 2 for every completion time.
    """

    gamma1: float  # priority in [1, 100]
    gamma2: float  # decay factor (0 | [0.01,1] | [4,6])
    gamma3: float  # target completion duration in slots

    def __call__(self, duration: float) -> float:
        z = self.gamma2 * (duration - self.gamma3)
        # numerically-stable evaluation of gamma1 / (1 + exp(z))
        if z >= 0:
            ez = math.exp(-min(z, 50.0))
            return self.gamma1 * ez / (1.0 + ez)
        return self.gamma1 / (1.0 + math.exp(max(z, -50.0)))


@dataclasses.dataclass(frozen=True)
class Job:
    """One training job (paper Table I)."""

    jid: int
    arrival: int                  # a_i, slot index in [0, T)
    epochs: int                   # E_i
    num_chunks: int               # N_i  (also max concurrent workers)
    minibatches_per_chunk: int    # M_i
    tau: float                    # per-mini-batch train time, in slots
    grad_size: float              # e_i, same units as bandwidth*slot
    worker_bw: float              # b_i
    ps_bw: float                  # B_i
    worker_res: np.ndarray        # w_i^r, shape (R,)
    ps_res: np.ndarray            # s_i^r, shape (R,)
    utility: Callable[[float], float]
    # Workload quantization for the DP (1 = exact paper formulation).  A
    # quantum of q groups q chunk-passes into one DP unit; the schedule then
    # over-provisions by < one quantum (still feasible, slightly costlier).
    quantum: int = 1
    # Fraction of the job's workload still to run.  The fleet-churn engine
    # re-admits a preempted job as a scaled copy carrying only the work not
    # covered by its last checkpoint (sim/fleet.py); per-unit quantities
    # (chunk_time, workers_for, ps_for) are scale-free.  The default 1.0
    # multiplies through as an IEEE identity, keeping every derived value
    # bit-identical to the pre-churn definition.
    work_scale: float = 1.0

    # ---- derived quantities --------------------------------------------
    @property
    def chunk_time(self) -> float:
        """Slots a single worker needs for one chunk-pass: M(tau + 2e/b)."""
        return self.minibatches_per_chunk * (self.tau + 2.0 * self.grad_size / self.worker_bw)

    @property
    def total_work_slots(self) -> float:
        """E_i N_i M_i (tau + 2e/b): total worker-slots of work (RHS of (2)),
        scaled by ``work_scale`` (1.0 — exact — except for churn restarts)."""
        return self.work_scale * self.epochs * self.num_chunks * self.chunk_time

    @property
    def workload(self) -> int:
        """DP units: ceil(work_scale * E_i * N_i / quantum) chunk-pass groups."""
        return math.ceil(self.work_scale * self.epochs * self.num_chunks
                         / self.quantum)

    @property
    def min_duration(self) -> int:
        """Fastest possible completion: N_i workers at all times -> ceil(E_i M_i (tau+2e/b))."""
        return max(1, math.ceil(self.work_scale * self.epochs
                                * self.minibatches_per_chunk
                                * (self.tau + 2.0 * self.grad_size / self.worker_bw)))

    def workers_for(self, d: int) -> int:
        """Minimum workers to fulfil d workload units within one slot:
        ceil(d * quantum * chunk_time)."""
        if d == 0:
            return 0
        return math.ceil(d * self.quantum * self.chunk_time - 1e-9)

    def ps_for(self, num_workers: int) -> int:
        """Minimum parameter servers for W workers: ceil(W * b/B) (constraints (6)(7))."""
        if num_workers == 0:
            return 0
        return math.ceil(num_workers * self.worker_bw / self.ps_bw - 1e-9)

    @property
    def max_chunks_per_slot(self) -> int:
        """Largest d with workers_for(d) <= N_i (constraint (3))."""
        hi = int(self.num_chunks / (self.quantum * self.chunk_time)) + 2
        d = 0
        for cand in range(hi, -1, -1):
            if self.workers_for(cand) <= self.num_chunks:
                d = cand
                break
        return d


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """H worker servers and K parameter-server machines with capacities."""

    T: int
    worker_caps: np.ndarray  # (H, R) = c_h^r
    ps_caps: np.ndarray      # (K, R) = c_k^r

    @property
    def H(self) -> int:
        return self.worker_caps.shape[0]

    @property
    def K(self) -> int:
        return self.ps_caps.shape[0]


@dataclasses.dataclass
class Schedule:
    """A feasible schedule l for one job: worker/PS placements per slot."""

    jid: int
    # maps slot t -> (y[t] of shape (H,), z[t] of shape (K,))
    workers: dict  # {t: np.ndarray(H, int)}
    ps: dict       # {t: np.ndarray(K, int)}
    finish: int    # \hat t_i (slot index of last active slot)
    cost: float    # dual resource cost of the schedule
    payoff: float  # utility - cost ( = mu_i when positive)
    utility: float

    def chunks_done(self, job: Job) -> int:
        total = 0
        for t, y in self.workers.items():
            w = int(y.sum())
            # workers fulfil floor(W / chunk_time) chunk passes in one slot;
            # the schedule construction guarantees >= the planned d.
            total += w
        return total


def job_from_arch(name: str, arrival: int, *, flops_per_token: float,
                  param_bytes: float, tokens_per_step: int, target_steps: int,
                  chip_flops: float = 197e12, chip_bw: float = 50e9,
                  utility: Optional[Callable[[float], float]] = None,
                  slot_seconds: float = 1200.0) -> Job:
    """Derive a scheduler Job from an architecture's roofline terms.

    Closes the loop between the execution layer (dry-run FLOPs / bytes)
    and the scheduling layer: tau_i comes from compute time per step on a
    single worker-chip; e_i from the gradient (= param) bytes exchanged.
    One "chunk" = 100 training steps; one "mini-batch" = 1 step.
    """
    step_sec = flops_per_token * tokens_per_step / chip_flops
    tau = step_sec / slot_seconds
    m_per_chunk = 100
    n_chunks = max(1, target_steps // m_per_chunk)
    e = param_bytes / chip_bw / slot_seconds    # gradient exchange time unit
    w = np.array([4.0, 8.0, 32.0, 10.0, 5.0])
    s = np.array([0.0, 8.0, 32.0, 10.0, 20.0])
    util = utility or SigmoidUtility(50.0, 0.05, max(2 * n_chunks, 4))
    return Job(jid=-1, arrival=arrival, epochs=1, num_chunks=n_chunks,
               minibatches_per_chunk=m_per_chunk, tau=tau, grad_size=e,
               worker_bw=1.0, ps_bw=4.0, worker_res=w, ps_res=s, utility=util)
