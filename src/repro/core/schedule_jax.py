"""Fused, jit-compiled JAX backend for the Alg. 2 dual subroutine.

``best_schedule_fused`` runs the WHOLE per-arrival pipeline as one XLA
computation: dual prices from the allocation state, per-server capacity +
sorted prefix-sum greedy COST_t rows for all (t, d), the banded min-plus DP
sweep over slots, the payoff argmax with the reference tie rule, the
split-table backtrack, and the greedy placement extraction.  Nothing
re-enters Python between stages, so a decision costs one dispatch instead of
O(T) interpreter round-trips.

``best_schedule_fused_batch`` vmaps the same core over a padded batch of
jobs (shared price state) — the speculative half of ``OASiS.on_arrivals``.

Precision: on CPU the engine runs under ``jax.experimental.enable_x64`` by
default so its decisions match the float64 numpy/reference paths exactly;
on TPU it runs float32 (f64 is unsupported there) with the Pallas min-plus
sweep kernel.  An ambient ``jax_enable_x64`` setting is always respected.

``dp_sweep_jax`` (the seed's DP-only entry point) is kept for micro-benches
and backward compatibility; it now follows ``jax_enable_x64`` instead of
silently downcasting to float32, and its Pallas path is the single-launch
sweep kernel rather than a ``lax.scan`` of tiny launches.
"""
from __future__ import annotations

import functools
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.minplus.kernel import minplus_sweep_pallas
from ..kernels.minplus.ref import minplus_sweep_cost, minplus_sweep_ref
from .pricing import PriceState, size_bucket as _bucket
from .types import Job, R, Schedule

# Stand-in for "unbounded" per-server instance capacity (job has no demand
# on some resource): big enough to never bind, small enough that prefix sums
# of it stay exact-ish in f32 comparisons against tiny instance counts.
_BIG_CAP = 1.0e9
_PAY_EPS = 1e-12        # payoff tie epsilon — same as the reference path


# ---------------------------------------------------------------------------
# Seed-compatible DP-only entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("d_total", "use_pallas"))
def _sweep(rows: jax.Array, d_total: int, use_pallas: bool
           ) -> Tuple[jax.Array, jax.Array]:
    if use_pallas:
        interpret = jax.default_backend() != "tpu"
        return minplus_sweep_pallas(rows, d_total, interpret=interpret)
    return minplus_sweep_ref(rows, d_total)


def dp_sweep_jax(rows: np.ndarray, d_total: int, use_pallas: bool = False
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """rows: (T', dcap+1) with +inf; returns (cost (T', D+1), split (T', D+1)).

    Runs in float64 when ``jax_enable_x64`` is on (the numpy path's dtype),
    float32 otherwise.  The Pallas path is always float32 (TPU VPU kernel).
    """
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    rows_j = jnp.asarray(np.nan_to_num(rows, posinf=np.inf), dtype)
    costs, args = _sweep(rows_j, int(d_total), bool(use_pallas))
    return np.asarray(costs, np.float64), np.asarray(args, np.int64)


# ---------------------------------------------------------------------------
# Fused engine core (pure jnp; shapes static per (T, H, K, M, D1) bucket)
# ---------------------------------------------------------------------------

def _prefix_tables_jnp(prices: jax.Array, headroom: jax.Array,
                       demand: jax.Array):
    """Per-slot sorted unit costs + prefix sums (whole-array, all slots).

    Returns (order, scap, scost, ccap, ccost), each (T, S)."""
    unit = (prices * demand[None, None, :]).sum(axis=2)          # (T, S)
    safe = jnp.where(demand > 0, demand, 1.0)
    per_r = jnp.where(demand[None, None, :] > 0,
                      jnp.floor(headroom / safe[None, None, :] + 1e-9),
                      _BIG_CAP)
    cap = jnp.clip(jnp.min(per_r, axis=2), 0.0, _BIG_CAP)        # (T, S)
    order = jnp.argsort(unit, axis=1, stable=True)
    scost = jnp.take_along_axis(unit, order, axis=1)
    scap = jnp.take_along_axis(cap, order, axis=1)
    ccap = jnp.cumsum(scap, axis=1)
    ccost = jnp.cumsum(scap * scost, axis=1)
    return order, scap, scost, ccap, ccost


def _greedy_cost_jnp(ccap: jax.Array, ccost: jax.Array, scost: jax.Array,
                     counts: jax.Array) -> jax.Array:
    """Greedy (cheapest-first) deployment cost for ``counts`` (T, M) at every
    slot, from (T, S) prefix tables.  +inf where counts exceed capacity."""
    S = ccap.shape[1]
    # first prefix covering each count (== np.searchsorted side="left")
    idx = (ccap[:, :, None] < counts[:, None, :]).sum(axis=1)    # (T, M)
    zcol = jnp.zeros((ccap.shape[0], 1), ccap.dtype)
    prev_cap = jnp.take_along_axis(jnp.concatenate([zcol, ccap], 1), idx, 1)
    prev_cost = jnp.take_along_axis(jnp.concatenate([zcol, ccost], 1), idx, 1)
    marg = jnp.take_along_axis(scost, jnp.minimum(idx, S - 1), 1)
    vals = prev_cost + (counts - prev_cap) * marg
    return jnp.where(counts == 0, 0.0,
                     jnp.where(counts <= ccap[:, -1:], vals, jnp.inf))


def _greedy_place_jnp(order: jax.Array, scap: jax.Array, ccap: jax.Array,
                      count: jax.Array) -> jax.Array:
    """Per-server instance counts for a greedy fill of ``count`` (T,) at each
    slot: cheapest servers first, each up to its capacity.  Returns (T, S)
    int32 in ORIGINAL server order."""
    prev = jnp.concatenate(
        [jnp.zeros((ccap.shape[0], 1), ccap.dtype), ccap[:, :-1]], axis=1)
    take = jnp.clip(count[:, None] - prev, 0.0, scap)            # sorted order
    inv = jnp.argsort(order, axis=1, stable=True)                # rank of h
    return jnp.round(jnp.take_along_axis(take, inv, axis=1)).astype(jnp.int32)


def _decide_core(sd, jd, *, d1: int, use_pallas: bool):
    """One Alg. 2 decision, fully fused.

    sd: state arrays (g (T,H,R), v (T,K,R), wcaps (H,R), scaps (K,R),
        U1 (R,), U2 (R,), L1 (), L2 ())
    jd: bundled job arrays (resbw (2R+2,) = [wres, sres, wbw, psbw],
        WZ (2, M) i32, u (T,), meta (3,) i32 = [a, nchunks, d_tot])
    d1: static — DP columns (padded D_total + 1).

    Returns (best_t i32 (-1 = reject), payoff, total_cost, d_left i32 —
    workload still unassigned after the backtrack, 0 for any sound accept —
    d_slots (T,) i32, y (T, H) i32, z (T, K) i32).
    """
    g, v, wcaps, scaps, U1, U2, L1, L2 = sd
    resbw, WZ, u, meta = jd
    wres, sres = resbw[:R], resbw[R:2 * R]
    wbw, psbw = resbw[2 * R], resbw[2 * R + 1]
    W, Z = WZ[0], WZ[1]
    a, nchunks, d_tot = meta[0], meta[1], meta[2]
    T = g.shape[0]
    M = W.shape[0]
    dt = g.dtype

    # dual prices p = L1 (U1/L1)^(g/c), q = L2 (U2/L2)^(v/c)   (eq. 22, 25)
    p = L1 * jnp.maximum(U1 / L1, 1.0 + 1e-9)[None, None, :] ** (
        g / jnp.maximum(wcaps, 1e-12)[None])
    q = L2 * jnp.maximum(U2 / L2, 1.0 + 1e-9)[None, None, :] ** (
        v / jnp.maximum(scaps, 1e-12)[None])

    w_order, w_scap, w_scost, w_ccap, w_ccost = _prefix_tables_jnp(
        p, wcaps[None] - g, wres)
    s_order, s_scap, s_scost, s_ccap, s_ccost = _prefix_tables_jnp(
        q, scaps[None] - v, sres)

    # COST_t rows for all (t, d)
    Wt = jnp.broadcast_to(W.astype(dt)[None, :], (T, M))
    w_costs = _greedy_cost_jnp(w_ccap, w_ccost, w_scost, Wt)
    pool = s_ccap[:, -1:]                                        # (T, 1)
    deploy = jnp.minimum(jnp.minimum(Z, W).astype(dt)[None, :], pool)
    feas_n = (W <= nchunks)[None, :]
    feas_ps = deploy * psbw >= Wt * wbw - 1e-9
    z_costs = _greedy_cost_jnp(s_ccap, s_ccost, s_scost, deploy)
    rows = jnp.where(feas_n & feas_ps, w_costs + z_costs, jnp.inf)
    rows = rows.at[:, 0].set(0.0)
    # slots before arrival carry the DP unchanged: row = [0, inf, ...]
    ts = jnp.arange(T, dtype=jnp.int32)
    pre = (ts[:, None] < a) & (jnp.arange(M)[None, :] > 0)
    rows = jnp.where(pre, jnp.inf, rows)

    # banded min-plus DP over slots (cost only; splits recovered below)
    if use_pallas:
        cost_tab = minplus_sweep_pallas(
            rows, d1 - 1, interpret=jax.default_backend() != "tpu")[0]
        cost_tab = cost_tab.astype(dt)
    else:
        cost_tab = minplus_sweep_cost(rows, d1 - 1)

    # payoff argmax with the reference tie rule (> best + eps switches)
    costD = jnp.take(cost_tab, d_tot, axis=1)                    # (T,)
    payoff_t = jnp.where(jnp.isfinite(costD) & (ts >= a), u - costD, -jnp.inf)

    def _pick(carry, x):
        best, best_t = carry
        pt, t = x
        switch = pt > best + _PAY_EPS
        return (jnp.where(switch, pt, best),
                jnp.where(switch, t, best_t)), None

    (best_payoff, best_t), _ = jax.lax.scan(
        _pick, (jnp.asarray(0.0, dt), jnp.int32(-1)), (payoff_t, ts))

    # backtrack from best_t down to arrival, recomputing each slot's split
    # as argmin_j rows[t, j] + cost_{t-1}[d_rem - j] over the stored table —
    # the same first-minimum the carried DP argmin would have produced
    init_row = jnp.full((d1,), jnp.inf, dt).at[0].set(0.0)
    prev_tab = jnp.concatenate([init_row[None, :], cost_tab[:-1]], axis=0)
    js = jnp.arange(M)

    def _back(d_rem, x):
        row, prev, t = x
        idx = d_rem - js
        vals = jnp.where(idx >= 0, row + prev[jnp.clip(idx, 0, d1 - 1)],
                         jnp.inf)
        d_here = jnp.where(t <= best_t,
                           jnp.argmin(vals).astype(jnp.int32), 0)
        return d_rem - d_here, d_here

    d_left, d_slots = jax.lax.scan(_back, d_tot, (rows, prev_tab, ts),
                                   reverse=True)

    # greedy placements for the chosen per-slot counts
    W_slots = jnp.take(W, d_slots)
    Z_slots = jnp.take(Z, d_slots)
    deploy_slots = jnp.minimum(jnp.minimum(Z_slots, W_slots).astype(dt),
                               pool[:, 0])
    y = _greedy_place_jnp(w_order, w_scap, w_ccap, W_slots.astype(dt))
    z = _greedy_place_jnp(s_order, s_scap, s_ccap, deploy_slots)

    total_cost = jnp.take(costD, jnp.maximum(best_t, 0))
    return best_t, best_payoff, total_cost, d_left, d_slots, y, z


@functools.partial(jax.jit, static_argnames=("d1", "use_pallas"))
def _decide_one(sd, jd, d1: int, use_pallas: bool):
    return _decide_core(sd, jd, d1=d1, use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("d1",))
def _decide_many(sd, jds, d1: int):
    return jax.vmap(
        lambda jd: _decide_core(sd, jd, d1=d1, use_pallas=False))(jds)


# ---------------------------------------------------------------------------
# Python wrappers: padding, bucketing, Schedule construction
# ---------------------------------------------------------------------------

def _state_arrays(state: PriceState, dtype):
    """Engine view of the price state: the device-resident allocation
    tensors plus static caps/params (``PriceState.device_state``).

    The first call per state uploads the full tensors once; afterwards
    ``commit``/``release`` maintain the residency with streamed slot-window
    adds, so a sequential simulation performs O(1) full uploads instead of
    re-uploading (T,H,R)+(T,K,R) after every accepted job."""
    return state.device_state(dtype)


def _job_arrays(job: Job, T: int, m_pad: int, dtype):
    """Pad the per-job tables to the ``m_pad`` bucket and bundle them into
    four device arrays (res+bw, W/Z, utilities, int metadata) to keep the
    per-decision host→device transfer count low.  Padded d entries get a
    sentinel worker count larger than any N so they are infeasible."""
    from .subroutine import workload_tables
    dcap = min(job.max_chunks_per_slot, job.workload)
    W, Z = workload_tables(job, dcap)
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    WZ[0, :dcap + 1] = W
    WZ[1, :dcap + 1] = Z
    a = job.arrival
    u = np.array([job.utility(t - a) if t >= a else 0.0 for t in range(T)])
    resbw = np.concatenate([job.worker_res, job.ps_res,
                            [job.worker_bw, job.ps_bw]])
    meta = np.array([a, job.num_chunks, job.workload], np.int32)
    return (jnp.asarray(resbw, dtype), jnp.asarray(WZ), jnp.asarray(u, dtype),
            jnp.asarray(meta))


def _reject_job_arrays(T: int, m_pad: int, dtype):
    """A batch-padding dummy whose every d > 0 is infeasible (nchunks = -1)."""
    resbw = np.zeros(2 * R + 2)
    resbw[-2:] = 1.0
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    return (jnp.asarray(resbw, dtype), jnp.asarray(WZ),
            jnp.zeros((T,), dtype), jnp.asarray(np.array([0, -1, 1], np.int32)))


def _x64_context(precision: str):
    """Engine precision policy.  "auto": float64 on CPU (exact agreement with
    the numpy paths), float32 on TPU.  An ambient jax_enable_x64 always wins.
    """
    import contextlib
    from jax.experimental import enable_x64
    if precision == "x64":
        return enable_x64(True)
    if precision == "auto" and jax.default_backend() == "cpu":
        return enable_x64(True)
    return contextlib.nullcontext()


def _schedule_from_outputs(job: Job, state: PriceState, best_t: int,
                           cost: float, d_left: int, d_slots: np.ndarray,
                           y: np.ndarray, z: np.ndarray
                           ) -> Optional[Schedule]:
    if best_t < 0:
        return None
    # mirrors _extract's backtrack assert: an accepted schedule must place
    # the whole workload (guards e.g. mixed-precision pallas-on-CPU runs)
    assert d_left == 0, \
        f"fused backtrack failed: {d_left} chunk-passes unassigned"
    H, K = state.cluster.H, state.cluster.K
    workers, ps = {}, {}
    for t in range(job.arrival, best_t + 1):
        if d_slots[t] > 0:
            workers[t] = y[t, :H].astype(np.int64)
            ps[t] = z[t, :K].astype(np.int64)
    utility = job.utility(best_t - job.arrival)
    return Schedule(jid=job.jid, workers=workers, ps=ps, finish=int(best_t),
                    cost=float(cost), payoff=utility - float(cost),
                    utility=utility)


def best_schedule_fused(job: Job, state: PriceState, *,
                        use_pallas: Optional[bool] = None,
                        precision: str = "auto") -> Optional[Schedule]:
    """Alg. 2 for one job as a single fused jit call."""
    dcap = min(job.max_chunks_per_slot, job.workload)
    if dcap == 0:
        return None
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    T = state.horizon      # window-local lookahead (== cluster.T episodic)
    m_pad = _bucket(dcap + 1, step=64)
    d1 = _bucket(job.workload + 1, step=256)
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        sd = _state_arrays(state, dtype)
        jd = _job_arrays(job, T, m_pad, dtype)
        best_t, _, cost, d_left, d_slots, y, z = _decide_one(
            sd, jd, d1=d1, use_pallas=bool(use_pallas))
        return _schedule_from_outputs(
            job, state, int(best_t), float(cost), int(d_left),
            np.asarray(d_slots), np.asarray(y), np.asarray(z))


def best_schedule_fused_batch(jobs: Sequence[Job], state: PriceState, *,
                              precision: str = "auto",
                              timings: Optional[List[float]] = None
                              ) -> List[Optional[Schedule]]:
    """Speculative batched Alg. 2: vmapped jit calls for all jobs at the
    CURRENT prices.  Jobs are grouped by (dcap, workload) shape bucket and
    each group is decided in one vmapped call — batching a burst must not
    pad a small job up to the burst's largest DP table (the sweep cost is
    linear in both padded axes).  Commit order / price updates are the
    caller's job (``OASiS.on_arrivals`` re-solves any job whose prices
    moved).

    ``timings``, when given, is filled in place with each job's share of
    its own shape group's wall time (len(jobs) entries) — a fair
    per-decision latency attribution for the scheduler's stats."""
    out: List[Optional[Schedule]] = [None] * len(jobs)
    if timings is not None:
        timings[:] = [0.0] * len(jobs)
    groups = {}
    for i, j in enumerate(jobs):
        dcap = min(j.max_chunks_per_slot, j.workload)
        if dcap == 0:
            continue
        key = (_bucket(dcap + 1, step=64), _bucket(j.workload + 1, step=256))
        groups.setdefault(key, []).append((i, j))
    if not groups:
        return out
    T = state.horizon      # window-local lookahead (== cluster.T episodic)
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        sd = _state_arrays(state, dtype)
        for (m_pad, d1), live in groups.items():
            t0 = time.perf_counter()
            b_pad = _bucket(len(live), floor=1, step=8)
            jds = [_job_arrays(j, T, m_pad, dtype) for _, j in live]
            jds += [_reject_job_arrays(T, m_pad, dtype)] * (b_pad - len(live))
            stacked = tuple(jnp.stack(cols) for cols in zip(*jds))
            best_t, _, cost, d_left, d_slots, y, z = _decide_many(
                sd, stacked, d1=d1)
            best_t = np.asarray(best_t)
            cost = np.asarray(cost)
            d_left = np.asarray(d_left)
            d_slots = np.asarray(d_slots)
            y, z = np.asarray(y), np.asarray(z)
            for bi, (i, job) in enumerate(live):
                out[i] = _schedule_from_outputs(
                    job, state, int(best_t[bi]), float(cost[bi]),
                    int(d_left[bi]), d_slots[bi], y[bi], z[bi])
            if timings is not None:
                share = (time.perf_counter() - t0) / len(live)
                for i, _ in live:
                    timings[i] = share
    return out
