"""Fused, jit-compiled JAX backend for the Alg. 2 dual subroutine.

The engine runs the WHOLE per-arrival pipeline as XLA computations: dual
prices from the allocation state, per-server capacity + sorted prefix-sum
greedy COST_t rows, the banded min-plus DP sweep over slots, the payoff
argmax with the reference tie rule, the split-table backtrack, and the
greedy placement extraction.

**Tiled decision core** (``_decide_tiled``): the horizon is walked in
``TILE``-slot blocks inside a ``lax.while_loop``, natively batched over a
lane axis so an entire arrival burst is one device launch:

* blocks before the earliest arrival in the batch are skipped outright
  (their COST rows are the DP identity ``[0, inf, ...]``);
* after each block the loop exits early once **no remaining slot can beat
  the incumbent payoff for any lane** — exact, not heuristic, because the
  suffix maximum of the utility curve bounds future payoffs from above and
  every schedule's cost is bounded below by the LIVE price-floor bound
  ``workload * min_d(workers_for(d)/d) * min over feasible slots of the
  cheapest single-worker slot cost`` at the current prices (>= the static
  ``L1 * sum(worker_res)`` floor, and far tighter once the cluster fills
  up).  The reference tie rule (``> best + 1e-12``) therefore cannot
  switch on any skipped slot and decisions stay bit-identical to
  ``best_schedule_ref``;
* COST rows can be served from a :class:`RowCache` — a commit only moves
  prices inside the committed slot window, so re-solves (the sequential
  half of ``OASiS.on_arrivals``) recompute only dirtied tiles.

Placement is extracted by a second, small jit (``_place_slots``) over
just the slots of the accepted schedule that actually deploy, so the
decision loop never materializes placement tables for slots it will
not use.

``best_schedule_fused_batch`` decides a padded batch of jobs (shared
price state) in one launch per shape bucket — the speculative half of
``OASiS.on_arrivals``.

Precision: on CPU the engine runs under ``jax.experimental.enable_x64``
by default so its decisions match the float64 numpy/reference paths
exactly; on TPU it runs float32 (f64 is unsupported there) with the
Pallas min-plus sweep kernel via the legacy monolithic core
(``use_pallas=True`` keeps that path compiled and equivalence-tested).

``dp_sweep_jax`` (the seed's DP-only entry point) is kept for
micro-benches and backward compatibility.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
import weakref
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.minplus.kernel import minplus_sweep_pallas
from ..kernels.minplus.ref import minplus_sweep_cost, minplus_sweep_ref
from ..kernels.minplus.tiled import TILE, minplus_chain_step
from .pricing import PriceState, size_bucket as _bucket
from .types import Job, R, Schedule

# Stand-in for "unbounded" per-server instance capacity (job has no demand
# on some resource): big enough to never bind, small enough that prefix sums
# of it stay exact-ish in f32 comparisons against tiny instance counts.
_BIG_CAP = 1.0e9
_PAY_EPS = 1e-12        # payoff tie epsilon — same as the reference path
# safety margin on the price-floor cost lower bound: the bound is proved
# in exact arithmetic; scale it down so float64 rounding in the engine's
# prefix sums can never push a computed cost below it
_LB_MARGIN = 0.999
# split-tie band for the backtrack argmin: XLA vectorizes the same f64
# pipeline differently per launch shape (lane count, cache path), so two
# launches over identical state can disagree in the LAST ULPS of a DP
# cell.  An exact argmin then flips between equally-optimal splits and
# the committed placements — hence the whole price trajectory — fork
# between the burst and sequential paths.  Snapping the backtrack to the
# first index within this RELATIVE band of the minimum makes the split a
# function of the (macroscopically) optimal set, not of ulp noise: costs
# are nonnegative sums of ≲1e3 rounded f64 terms, so cross-launch noise
# on an exact tie stays ≲1e-13 relative, while genuinely distinct splits
# differ by far more than 1e-12 relative.  Decisions (best_t) are
# already protected the same way by _PAY_EPS.
_SPLIT_TOL = 1e-12
# Lane cap per launch: bounds the (B, T_pad, D+1) DP table memory.  On a
# single-core CPU backend the DP sweep is memory-bandwidth bound and lane
# fusion scales SUPERLINEARLY in wall clock (8 fused lanes measured ~2.7x
# the cost of 8 singleton launches at paper-10x shapes), so bursts there
# decide lane-by-lane — still speculative, still one RowCache per job —
# while parallel backends get real fusion.  Override with REPRO_BURST_LANES.
_MAX_LANES = int(os.environ.get(
    "REPRO_BURST_LANES", "8" if jax.default_backend() == "tpu" else "1"))


# ---------------------------------------------------------------------------
# Seed-compatible DP-only entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("d_total", "use_pallas"))
def _sweep(rows: jax.Array, d_total: int, use_pallas: bool
           ) -> Tuple[jax.Array, jax.Array]:
    if use_pallas:
        interpret = jax.default_backend() != "tpu"
        return minplus_sweep_pallas(rows, d_total, interpret=interpret)
    return minplus_sweep_ref(rows, d_total)


def dp_sweep_jax(rows: np.ndarray, d_total: int, use_pallas: bool = False
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """rows: (T', dcap+1) with +inf; returns (cost (T', D+1), split (T', D+1)).

    Runs in float64 when ``jax_enable_x64`` is on (the numpy path's dtype),
    float32 otherwise.  The Pallas path is always float32 (TPU VPU kernel).
    """
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    rows_j = jnp.asarray(np.nan_to_num(rows, posinf=np.inf), dtype)
    costs, args = _sweep(rows_j, int(d_total), bool(use_pallas))
    return np.asarray(costs, np.float64), np.asarray(args, np.int64)


# ---------------------------------------------------------------------------
# Shared single-lane helpers (also used by the legacy Pallas core)
# ---------------------------------------------------------------------------

def _price_pow(ratio: jax.Array, x: jax.Array) -> jax.Array:
    """``ratio ** x`` computed as ``exp(x * log(ratio))``.

    XLA's CPU backend lowers a broadcast ``pow`` with a non-constant base
    to per-element libm calls (~100 ns each), which made the per-tile
    price tables the single largest cost of a fused decision launch; the
    explicit exp/log form vectorizes.  ``ratio`` is clamped to
    ``1 + 1e-9`` upstream so the log is always finite, and ``x == 0``
    still yields exactly 1.  Every price computation in this module must
    go through this helper — mixing it with ``**`` would produce
    last-ulp price disagreements between the decision and placement
    paths.
    """
    return jnp.exp(x * jnp.log(ratio))


def _prefix_tables_jnp(prices: jax.Array, headroom: jax.Array,
                       demand: jax.Array):
    """Per-slot sorted unit costs + prefix sums (whole-array, all slots).

    Returns (order, scap, scost, ccap, ccost), each (T, S)."""
    unit = (prices * demand[None, None, :]).sum(axis=2)          # (T, S)
    safe = jnp.where(demand > 0, demand, 1.0)
    per_r = jnp.where(demand[None, None, :] > 0,
                      jnp.floor(headroom / safe[None, None, :] + 1e-9),
                      _BIG_CAP)
    cap = jnp.clip(jnp.min(per_r, axis=2), 0.0, _BIG_CAP)        # (T, S)
    order = jnp.argsort(unit, axis=1, stable=True)
    scost = jnp.take_along_axis(unit, order, axis=1)
    scap = jnp.take_along_axis(cap, order, axis=1)
    ccap = jnp.cumsum(scap, axis=1)
    ccost = jnp.cumsum(scap * scost, axis=1)
    return order, scap, scost, ccap, ccost


def _greedy_cost_jnp(ccap: jax.Array, ccost: jax.Array, scost: jax.Array,
                     counts: jax.Array) -> jax.Array:
    """Greedy (cheapest-first) deployment cost for ``counts`` (T, M) at every
    slot, from (T, S) prefix tables.  +inf where counts exceed capacity."""
    S = ccap.shape[1]
    # first prefix covering each count (== np.searchsorted side="left";
    # binary search, not the quadratic (T, S, M) comparison tensor)
    idx = jax.vmap(
        functools.partial(jnp.searchsorted, side="left"))(ccap, counts)
    zcol = jnp.zeros((ccap.shape[0], 1), ccap.dtype)
    prev_cap = jnp.take_along_axis(jnp.concatenate([zcol, ccap], 1), idx, 1)
    prev_cost = jnp.take_along_axis(jnp.concatenate([zcol, ccost], 1), idx, 1)
    marg = jnp.take_along_axis(scost, jnp.minimum(idx, S - 1), 1)
    vals = prev_cost + (counts - prev_cap) * marg
    return jnp.where(counts == 0, 0.0,
                     jnp.where(counts <= ccap[:, -1:], vals, jnp.inf))


def _greedy_place_jnp(order: jax.Array, scap: jax.Array, ccap: jax.Array,
                      count: jax.Array) -> jax.Array:
    """Per-server instance counts for a greedy fill of ``count`` (T,) at each
    slot: cheapest servers first, each up to its capacity.  Returns (T, S)
    int32 in ORIGINAL server order."""
    prev = jnp.concatenate(
        [jnp.zeros((ccap.shape[0], 1), ccap.dtype), ccap[:, :-1]], axis=1)
    take = jnp.clip(count[:, None] - prev, 0.0, scap)            # sorted order
    inv = jnp.argsort(order, axis=1, stable=True)                # rank of h
    return jnp.round(jnp.take_along_axis(take, inv, axis=1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Batched (lane-axis) helpers for the tiled core
# ---------------------------------------------------------------------------

def _prefix_tables_b(prices: jax.Array, headroom: jax.Array,
                     demand: jax.Array):
    """Lane-batched prefix tables for one tile.

    prices/headroom: (TILE, S, R) shared across lanes; demand: (B, R) per
    lane.  Returns (scost, ccap, ccost), each (B, TILE, S) — the greedy
    cost tables only (placement order is extracted by ``_place_slots``,
    never in the decision loop)."""
    unit = (prices[None] * demand[:, None, None, :]).sum(axis=3)
    safe = jnp.where(demand > 0, demand, 1.0)
    per_r = jnp.where(demand[:, None, None, :] > 0,
                      jnp.floor(headroom[None] / safe[:, None, None, :]
                                + 1e-9),
                      _BIG_CAP)
    cap = jnp.clip(jnp.min(per_r, axis=3), 0.0, _BIG_CAP)
    order = jnp.argsort(unit, axis=2, stable=True)
    scost = jnp.take_along_axis(unit, order, axis=2)
    scap = jnp.take_along_axis(cap, order, axis=2)
    ccap = jnp.cumsum(scap, axis=2)
    ccost = jnp.cumsum(scap * scost, axis=2)
    return scost, ccap, ccost


def _greedy_cost_b(ccap: jax.Array, ccost: jax.Array, scost: jax.Array,
                   counts: jax.Array) -> jax.Array:
    """Lane-batched greedy cost: (B, TILE, S) tables, (B, TILE, M) counts."""
    S = ccap.shape[2]
    # first prefix covering each count.  ``searchsorted`` (binary search)
    # returns exactly ``(ccap < counts).sum(axis=2)`` — ``ccap`` is a
    # nondecreasing cumsum — but skips materializing the (B, TILE, S, M)
    # comparison tensor, which was ~10x the cost of everything else here.
    idx = jax.vmap(jax.vmap(
        functools.partial(jnp.searchsorted, side="left")))(ccap, counts)
    zcol = jnp.zeros(ccap.shape[:2] + (1,), ccap.dtype)
    prev_cap = jnp.take_along_axis(
        jnp.concatenate([zcol, ccap], -1), idx, -1)
    prev_cost = jnp.take_along_axis(
        jnp.concatenate([zcol, ccost], -1), idx, -1)
    marg = jnp.take_along_axis(scost, jnp.minimum(idx, S - 1), -1)
    vals = prev_cost + (counts - prev_cap) * marg
    return jnp.where(counts == 0, 0.0,
                     jnp.where(counts <= ccap[..., -1:], vals, jnp.inf))


# ---------------------------------------------------------------------------
# Tiled, batched decision core
# ---------------------------------------------------------------------------

def _decide_tiled_core(sd, jd, rows_init, valid_tiles, *, T: int, d1: int,
                       use_cache: bool):
    """Alg. 2 decisions for a lane batch, horizon-tiled with exact early
    exit (module docstring).

    sd: PADDED state arrays from ``_pad_state`` (g (T_pad,H,R),
        v (T_pad,K,R), wcaps (H,R), scaps (K,R), U1 (R,), U2 (R,),
        L1 (), L2 (), pmin (T_pad, R) — the per-slot minimum worker
        price for the live cost floor, precomputed per state version)
    jd: lane-batched job arrays —
        resbw (B, 2R+2) = [wres, sres, wbw, psbw],
        WZ (B, 2, M) i32, u (B, T_pad), usmax (B, T_pad) suffix-max of u,
        meta (B, 3) i32 = [a, nchunks, d_tot], lb (B,) — the price-free
        lower-bound base from ``_cost_lower_bound`` (a live price floor
        is multiplied in on device).
    rows_init/valid_tiles: ``use_cache`` row cache — (B, T_pad, M) rows at
        the current prices plus a (B, n_tiles) tile-validity mask; a tile
        is recomputed unless it is valid for EVERY lane.  Scalars when
        ``use_cache`` is False.
    T: static — the real (unpadded) horizon.
    d1: static — DP columns (padded D_total + 1).

    Returns (best_t i32 (-1 = reject), payoff, total_cost, d_left i32,
    d_slots (B, T_pad) i32, rows (B, T_pad, M) — the refreshed row cache —
    k0, k_end i32: the visited tile range [k0, k_end)).
    """
    g, v, wcaps, scaps, U1, U2, L1, L2, pmin = sd
    resbw, WZ, u, usmax, meta, lb = jd
    B = resbw.shape[0]
    T_pad = u.shape[1]
    n_tiles = T_pad // TILE
    M = WZ.shape[2]
    dt = g.dtype
    wres, sres = resbw[:, :R], resbw[:, R:2 * R]
    wbw, psbw = resbw[:, 2 * R], resbw[:, 2 * R + 1]
    W, Z = WZ[:, 0], WZ[:, 1]                                    # (B, M) i32
    a, nchunks, d_tot = meta[:, 0], meta[:, 1], meta[:, 2]

    # dual price bases p = L1 (U1/L1)^(g/c), q = L2 (U2/L2)^(v/c) (eq. 22/25)
    ratio1 = jnp.maximum(U1 / L1, 1.0 + 1e-9)
    ratio2 = jnp.maximum(U2 / L2, 1.0 + 1e-9)
    cw = jnp.maximum(wcaps, 1e-12)
    cs = jnp.maximum(scaps, 1e-12)
    Wf = W.astype(dt)
    deploy_target = jnp.minimum(Z, W).astype(dt)                 # (B, M)
    feas_n = (W <= nchunks[:, None])[:, None, :]                 # (B, 1, M)
    ms = jnp.arange(M)

    def rows_for_tile(t0):
        """COST_t rows for slots [t0, t0+TILE), all lanes: (B, TILE, M)."""
        zero = jnp.zeros_like(t0)
        g_t = jax.lax.dynamic_slice(
            g, (t0, zero, zero), (TILE,) + g.shape[1:])
        v_t = jax.lax.dynamic_slice(
            v, (t0, zero, zero), (TILE,) + v.shape[1:])
        p = L1 * _price_pow(ratio1[None, None, :], g_t / cw[None])
        q = L2 * _price_pow(ratio2[None, None, :], v_t / cs[None])
        w_scost, w_ccap, w_ccost = _prefix_tables_b(
            p, wcaps[None] - g_t, wres)
        s_scost, s_ccap, s_ccost = _prefix_tables_b(
            q, scaps[None] - v_t, sres)
        Wt = jnp.broadcast_to(Wf[:, None, :], (B, TILE, M))
        w_costs = _greedy_cost_b(w_ccap, w_ccost, w_scost, Wt)
        pool = s_ccap[..., -1:]                                  # (B, TILE, 1)
        deploy = jnp.minimum(deploy_target[:, None, :], pool)
        feas_ps = deploy * psbw[:, None, None] >= Wt * wbw[:, None, None] - 1e-9
        z_costs = _greedy_cost_b(s_ccap, s_ccost, s_scost, deploy)
        rows = jnp.where(feas_n & feas_ps, w_costs + z_costs, jnp.inf)
        rows = rows.at[:, :, 0].set(0.0)
        # pre-arrival and beyond-horizon slots carry the DP unchanged
        ts = t0 + jnp.arange(TILE, dtype=jnp.int32)
        dead = (ts[None, :] < a[:, None]) | (ts >= T)[None, :]
        return jnp.where(dead[:, :, None] & (ms > 0)[None, None, :],
                         jnp.inf, rows)

    a_min = jnp.min(a)
    init_col = jnp.full((B, d1), jnp.inf, dt).at[:, 0].set(0.0)
    if use_cache:
        rows_buf0 = rows_init
    else:
        rows_buf0 = jnp.full((B, T_pad, M), jnp.inf, dt).at[:, :, 0].set(0.0)
    cost_buf0 = jnp.full((B, T_pad, d1), jnp.inf, dt)
    k0 = jnp.min(a).astype(jnp.int32) // TILE
    t_start = k0 * TILE

    # Live early-exit cost floor.  ``lb`` from the host is the price-free
    # base workload * min_d(W(d)/d) (times _LB_MARGIN); every worker a
    # schedule deploys in slot s costs >= sum_r wres_r * min_h p[s,h,r],
    # so ANY schedule's total cost is >= base * min over the job's
    # feasible slots of that floor — the static L1 bound with the
    # *actual* current prices in place of the price floor, exact for the
    # same reason and far tighter once the cluster fills up.  ``pmin``
    # (the per-slot minimum worker price, (T_pad, R)) is computed once
    # per state version in ``_pad_state``, not per launch.
    wslot = jnp.einsum("tr,br->bt", pmin, wres)
    ts_all = jnp.arange(T_pad, dtype=jnp.int32)
    feas_t = (ts_all[None, :] >= a[:, None]) & (ts_all < T)[None, :]
    fmin = jnp.min(jnp.where(feas_t, wslot, jnp.inf), axis=1)    # (B,)
    lb = jnp.where(lb > 0, lb * fmin, 0.0)

    def cond(c):
        k, _, best, _, _, _ = c
        t_next = jnp.clip(k * TILE, 0, T_pad - 1)
        um = jax.lax.dynamic_slice_in_dim(usmax, t_next, 1, axis=1)[:, 0]
        active = um > best + _PAY_EPS + lb
        return (k < n_tiles) & jnp.any(active)

    def body(c):
        k, prev, best, best_t, cost_buf, rows_buf = c
        t0 = k * TILE
        zero = jnp.zeros_like(t0)
        if use_cache:
            tile_ok = jnp.all(
                jax.lax.dynamic_slice_in_dim(valid_tiles, k, 1, axis=1))
            rows_tile = jax.lax.cond(
                tile_ok,
                lambda: jax.lax.dynamic_slice(
                    rows_init, (zero, t0, zero), (B, TILE, M)),
                lambda: rows_for_tile(t0))
        else:
            rows_tile = rows_for_tile(t0)
        u_tile = jax.lax.dynamic_slice(u, (zero, t0), (B, TILE))
        ts_tile = t0 + jnp.arange(TILE, dtype=jnp.int32)

        def slot(carry, x):
            prev, best, best_t = carry
            row, u_t, t = x

            def live(_):
                new = minplus_chain_step(row, prev)
                costD = jnp.take_along_axis(new, d_tot[:, None],
                                            axis=1)[:, 0]
                pay = jnp.where(jnp.isfinite(costD) & (t >= a) & (t < T),
                                u_t - costD, -jnp.inf)
                switch = pay > best + _PAY_EPS
                return (new, jnp.where(switch, pay, best),
                        jnp.where(switch, t, best_t))

            def dead(_):
                # slots before every lane's arrival (or past the horizon)
                # have the identity row [0, inf, ...]: the chain step
                # would return ``prev`` bit-for-bit, so skip it at
                # runtime — with single-lane launches this skips the DP
                # for the whole pre-arrival prefix of the first tile
                return (prev, best, best_t)

            new, best, best_t = jax.lax.cond(
                (t >= a_min) & (t < T), live, dead, None)
            return (new, best, best_t), new

        (prev, best, best_t), cols = jax.lax.scan(
            slot, (prev, best, best_t),
            (jnp.swapaxes(rows_tile, 0, 1), u_tile.T, ts_tile))
        cost_buf = jax.lax.dynamic_update_slice(
            cost_buf, jnp.swapaxes(cols, 0, 1), (zero, t0, zero))
        rows_buf = jax.lax.dynamic_update_slice(
            rows_buf, rows_tile, (zero, t0, zero))
        return k + 1, prev, best, best_t, cost_buf, rows_buf

    k_end, _, best, best_t, cost_buf, rows_buf = jax.lax.while_loop(
        cond, body,
        (k0, init_col, jnp.zeros((B,), dt), jnp.full((B,), -1, jnp.int32),
         cost_buf0, rows_buf0))
    return best_t, best, rows_buf, cost_buf, k0, k_end


@functools.partial(jax.jit, static_argnames=("T", "d1", "use_cache"))
def _decide_tiled(sd, jd, rows_init, valid_tiles, T: int, d1: int,
                  use_cache: bool):
    return _decide_tiled_core(sd, jd, rows_init, valid_tiles, T=T, d1=d1,
                              use_cache=use_cache)


@jax.jit
def _backtrack(rows_lane: jax.Array, cost_lane: jax.Array, best_t, d_tot,
               t_start):
    """Split recovery for ONE accepted lane, from the decision loop's
    stored row/cost tables (device-resident; rejects never pay this).

    Walks t from the horizon down to 0, recomputing each slot's split as
    the FIRST j with rows[t, j] + cost_{t-1}[d_rem - j] within
    ``_SPLIT_TOL`` of the minimum — an exact argmin would make the split
    (and so the committed placements) a function of launch-shape ulp
    noise; see the ``_SPLIT_TOL`` note.  ``t_start`` is the first slot
    the decision loop processed (earlier slots carry the DP identity).
    Returns (total_cost, d_left, d_slots (T_pad,) i32)."""
    T_pad, M = rows_lane.shape
    d1 = cost_lane.shape[1]
    dt = cost_lane.dtype
    init_col = jnp.full((d1,), jnp.inf, dt).at[0].set(0.0)
    js = jnp.arange(M)
    ts = jnp.arange(T_pad, dtype=jnp.int32)

    def _back(d_rem, t):
        def live(_):
            row = jax.lax.dynamic_slice_in_dim(rows_lane, t, 1, axis=0)[0]
            prev = jax.lax.dynamic_slice_in_dim(
                cost_lane, jnp.maximum(t - 1, 0), 1, axis=0)[0]
            prev = jnp.where(t <= t_start, init_col, prev)
            idx = d_rem - js
            vals = jnp.where(idx >= 0, row + prev[jnp.clip(idx, 0, d1 - 1)],
                             jnp.inf)
            m = jnp.min(vals)
            band = vals <= m * (1.0 + _SPLIT_TOL)
            return jnp.argmax(band).astype(jnp.int32)
        # slots past the chosen finish place nothing — skip their row/col
        # loads entirely (identical to computing and forcing d_here = 0)
        d_here = jax.lax.cond(t <= best_t, live,
                              lambda _: jnp.int32(0), None)
        return d_rem - d_here, d_here

    d_left, d_slots = jax.lax.scan(_back, d_tot, ts, reverse=True)
    bt = jnp.clip(best_t, 0, T_pad - 1)
    col = jax.lax.dynamic_slice_in_dim(cost_lane, bt, 1, axis=0)[0]
    total_cost = col[jnp.minimum(d_tot, d1 - 1)]
    return total_cost, d_left, d_slots


@functools.partial(jax.jit, static_argnames=("wa",))
def _place_slots(sd, resbw, Wc, Zc, ts, wa: int):
    """Greedy placements for the ACTIVE slots of an accepted schedule.

    ``ts``: (wa,) i32 slot indices with a nonzero split (padded by
    repeating the last index; padding lanes carry ``Wc = 0`` and are
    discarded by the caller).  ``Wc``/``Zc``: per-slot worker / PS-target
    counts (wa,) from the decided split.  Returns (y (wa, H'), z (wa, K'))
    int32 — the same cheapest-first fills the reference ``cost_t_ref``
    greedy produces.  Each slot's fill depends only on that slot's state
    column, so gathering the active subset is bit-identical to slicing
    the whole [arrival, finish] window and discarding the idle slots."""
    g, v, wcaps, scaps, U1, U2, L1, L2 = sd
    g_w = jnp.take(g, ts, axis=0)
    v_w = jnp.take(v, ts, axis=0)
    wres, sres = resbw[:R], resbw[R:2 * R]
    p = L1 * _price_pow(jnp.maximum(U1 / L1, 1.0 + 1e-9)[None, None, :],
                        g_w / jnp.maximum(wcaps, 1e-12)[None])
    q = L2 * _price_pow(jnp.maximum(U2 / L2, 1.0 + 1e-9)[None, None, :],
                        v_w / jnp.maximum(scaps, 1e-12)[None])
    w_order, w_scap, _, w_ccap, _ = _prefix_tables_jnp(
        p, wcaps[None] - g_w, wres)
    s_order, s_scap, _, s_ccap, _ = _prefix_tables_jnp(
        q, scaps[None] - v_w, sres)
    y = _greedy_place_jnp(w_order, w_scap, w_ccap, Wc)
    pool = s_ccap[:, -1]
    deploy = jnp.minimum(jnp.minimum(Zc, Wc), pool)
    z = _greedy_place_jnp(s_order, s_scap, s_ccap, deploy)
    return y, z


# ---------------------------------------------------------------------------
# Legacy monolithic core — kept for the TPU/Pallas path (use_pallas=True)
# ---------------------------------------------------------------------------

def _decide_core(sd, jd, *, d1: int, use_pallas: bool):
    """One Alg. 2 decision, fully fused, whole horizon in one block.

    sd: state arrays (g (T,H,R), v (T,K,R), wcaps (H,R), scaps (K,R),
        U1 (R,), U2 (R,), L1 (), L2 ())
    jd: bundled job arrays (resbw (2R+2,) = [wres, sres, wbw, psbw],
        WZ (2, M) i32, u (T,), meta (3,) i32 = [a, nchunks, workload])
    d1: static — DP columns (padded D_total + 1).

    Returns (best_t i32 (-1 = reject), payoff, total_cost, d_left i32 —
    workload still unassigned after the backtrack, 0 for any sound accept —
    d_slots (T,) i32, y (T, H) i32, z (T, K) i32).
    """
    g, v, wcaps, scaps, U1, U2, L1, L2 = sd
    resbw, WZ, u, meta = jd
    wres, sres = resbw[:R], resbw[R:2 * R]
    wbw, psbw = resbw[2 * R], resbw[2 * R + 1]
    W, Z = WZ[0], WZ[1]
    a, nchunks, d_tot = meta[0], meta[1], meta[2]
    T = g.shape[0]
    M = W.shape[0]
    dt = g.dtype

    # dual prices p = L1 (U1/L1)^(g/c), q = L2 (U2/L2)^(v/c)   (eq. 22, 25)
    p = L1 * _price_pow(jnp.maximum(U1 / L1, 1.0 + 1e-9)[None, None, :],
                        g / jnp.maximum(wcaps, 1e-12)[None])
    q = L2 * _price_pow(jnp.maximum(U2 / L2, 1.0 + 1e-9)[None, None, :],
                        v / jnp.maximum(scaps, 1e-12)[None])

    w_order, w_scap, w_scost, w_ccap, w_ccost = _prefix_tables_jnp(
        p, wcaps[None] - g, wres)
    s_order, s_scap, s_scost, s_ccap, s_ccost = _prefix_tables_jnp(
        q, scaps[None] - v, sres)

    # COST_t rows for all (t, d)
    Wt = jnp.broadcast_to(W.astype(dt)[None, :], (T, M))
    w_costs = _greedy_cost_jnp(w_ccap, w_ccost, w_scost, Wt)
    pool = s_ccap[:, -1:]                                        # (T, 1)
    deploy = jnp.minimum(jnp.minimum(Z, W).astype(dt)[None, :], pool)
    feas_n = (W <= nchunks)[None, :]
    feas_ps = deploy * psbw >= Wt * wbw - 1e-9
    z_costs = _greedy_cost_jnp(s_ccap, s_ccost, s_scost, deploy)
    rows = jnp.where(feas_n & feas_ps, w_costs + z_costs, jnp.inf)
    rows = rows.at[:, 0].set(0.0)
    # slots before arrival carry the DP unchanged: row = [0, inf, ...]
    ts = jnp.arange(T, dtype=jnp.int32)
    pre = (ts[:, None] < a) & (jnp.arange(M)[None, :] > 0)
    rows = jnp.where(pre, jnp.inf, rows)

    # banded min-plus DP over slots (cost only; splits recovered below)
    if use_pallas:
        cost_tab = minplus_sweep_pallas(
            rows, d1 - 1, interpret=jax.default_backend() != "tpu")[0]
        cost_tab = cost_tab.astype(dt)
    else:
        cost_tab = minplus_sweep_cost(rows, d1 - 1)

    # payoff argmax with the reference tie rule (> best + eps switches)
    costD = jnp.take(cost_tab, d_tot, axis=1)                    # (T,)
    payoff_t = jnp.where(jnp.isfinite(costD) & (ts >= a), u - costD, -jnp.inf)

    def _pick(carry, x):
        best, best_t = carry
        pt, t = x
        switch = pt > best + _PAY_EPS
        return (jnp.where(switch, pt, best),
                jnp.where(switch, t, best_t)), None

    (best_payoff, best_t), _ = jax.lax.scan(
        _pick, (jnp.asarray(0.0, dt), jnp.int32(-1)), (payoff_t, ts))

    # backtrack from best_t down to arrival, recomputing each slot's split
    # as argmin_j rows[t, j] + cost_{t-1}[d_rem - j] over the stored table —
    # the same first-minimum the carried DP argmin would have produced
    init_row = jnp.full((d1,), jnp.inf, dt).at[0].set(0.0)
    prev_tab = jnp.concatenate([init_row[None, :], cost_tab[:-1]], axis=0)
    js = jnp.arange(M)

    def _back(d_rem, x):
        row, prev, t = x
        idx = d_rem - js
        vals = jnp.where(idx >= 0, row + prev[jnp.clip(idx, 0, d1 - 1)],
                         jnp.inf)
        d_here = jnp.where(t <= best_t,
                           jnp.argmin(vals).astype(jnp.int32), 0)
        return d_rem - d_here, d_here

    d_left, d_slots = jax.lax.scan(_back, d_tot, (rows, prev_tab, ts),
                                   reverse=True)

    # greedy placements for the chosen per-slot counts
    W_slots = jnp.take(W, d_slots)
    Z_slots = jnp.take(Z, d_slots)
    deploy_slots = jnp.minimum(jnp.minimum(Z_slots, W_slots).astype(dt),
                               pool[:, 0])
    y = _greedy_place_jnp(w_order, w_scap, w_ccap, W_slots.astype(dt))
    z = _greedy_place_jnp(s_order, s_scap, s_ccap, deploy_slots)

    total_cost = jnp.take(costD, jnp.maximum(best_t, 0))
    return best_t, best_payoff, total_cost, d_left, d_slots, y, z


@functools.partial(jax.jit, static_argnames=("d1", "use_pallas"))
def _decide_one(sd, jd, d1: int, use_pallas: bool):
    return _decide_core(sd, jd, d1=d1, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Row cache (incremental COST-row maintenance)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RowCache:
    """Per-job COST-row cache across price-state versions.

    ``rows`` holds the (T_pad, m_pad) COST_t table the engine computed
    at ``version``; ``valid`` marks which ``TILE``-slot blocks of it are
    both *visited* (actually computed, not the identity placeholder) and
    *fresh* (no commit/release has moved prices inside them since).  The
    engine recomputes exactly the invalid tiles (``use_cache`` path of
    ``_decide_tiled``); :meth:`sync` invalidates against the price
    state's dirty-slot log (``PriceState.dirty_spans_since``)."""
    rows: Optional[jax.Array]       # (T_pad, m_pad) device-resident
    valid: np.ndarray               # (n_tiles,) bool, host
    version: int
    m_pad: int
    d1: int

    @classmethod
    def empty(cls, state: PriceState, job: Job) -> Optional["RowCache"]:
        """A cache with no valid tiles (first decision fills it).  None
        for dcap-0 jobs (the engine rejects those without solving)."""
        key = _shape_bucket(job)
        if key is None:
            return None
        m_pad, d1 = key
        n_tiles = _pad_tiles(state.horizon) // TILE
        return cls(rows=None, valid=np.zeros(n_tiles, bool),
                   version=state.version, m_pad=m_pad, d1=d1)

    def invalidate_spans(self, spans) -> None:
        """Mark every tile overlapping a dirtied [t0, t1) slot span stale."""
        for t0, t1 in spans:
            k0 = max(int(t0) // TILE, 0)
            k1 = min((int(t1) - 1) // TILE + 1, len(self.valid))
            self.valid[k0:k1] = False

    def invalidate_all(self) -> None:
        self.valid[:] = False

    def sync(self, state: PriceState) -> "RowCache":
        """Invalidate whatever ``state`` has dirtied since ``version``.

        Uses the commit/release dirty-slot log; an unknown delta (window
        slide, log trimmed) invalidates everything.  Returns self."""
        if state.version != self.version:
            spans = state.dirty_spans_since(self.version)
            if spans is None:
                self.invalidate_all()
            else:
                self.invalidate_spans(spans)
            self.version = state.version
        return self


# ---------------------------------------------------------------------------
# Python wrappers: padding, bucketing, Schedule construction
# ---------------------------------------------------------------------------

def _state_arrays(state: PriceState, dtype):
    """Engine view of the price state: the device-resident allocation
    tensors plus static caps/params (``PriceState.device_state``).

    The first call per state uploads the full tensors once; afterwards
    ``commit``/``release`` maintain the residency with streamed slot-window
    adds, so a sequential simulation performs O(1) full uploads instead of
    re-uploading (T,H,R)+(T,K,R) after every accepted job."""
    return state.device_state(dtype)


@functools.partial(jax.jit, static_argnames=("T_pad",))
def _pad_state(g, v, wcaps, U1, L1, T_pad: int):
    """Tile-pad the allocation tensors and precompute the live-floor
    minimum worker price ``pmin`` (module docstring: every deployed
    worker in slot s costs >= sum_r wres_r * min_h p[s,h,r]; with
    ratio >= 1, min_h ratio^(g/c) == ratio^(min_h g/c), so the floor
    needs only (T_pad, R) pows)."""
    T = g.shape[0]
    g = jnp.pad(g, ((0, T_pad - T), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, T_pad - T), (0, 0), (0, 0)))
    ratio1 = jnp.maximum(U1 / L1, 1.0 + 1e-9)
    umin = jnp.min(g / jnp.maximum(wcaps, 1e-12)[None], axis=1)
    pmin = L1 * _price_pow(ratio1[None, :], umin)
    return g, v, pmin


@functools.partial(jax.jit, static_argnames=("span",))
def _pad_patch(g_pad, v_pad, pmin, g, v, wcaps, U1, L1, t0, span: int):
    """Refresh one dirty slot span of the padded-state cache in place:
    re-slice ``g``/``v`` and recompute the ``pmin`` floor rows with the
    exact ``_pad_state`` formula, so the patched tensors are bit-identical
    to a from-scratch pad at the new state version."""
    zero = jnp.zeros_like(t0)
    g_s = jax.lax.dynamic_slice(g, (t0, zero, zero), (span,) + g.shape[1:])
    v_s = jax.lax.dynamic_slice(v, (t0, zero, zero), (span,) + v.shape[1:])
    ratio1 = jnp.maximum(U1 / L1, 1.0 + 1e-9)
    umin = jnp.min(g_s / jnp.maximum(wcaps, 1e-12)[None], axis=1)
    pmin_s = L1 * _price_pow(ratio1[None, :], umin)
    g_pad = jax.lax.dynamic_update_slice(g_pad, g_s, (t0, zero, zero))
    v_pad = jax.lax.dynamic_update_slice(v_pad, v_s, (t0, zero, zero))
    pmin = jax.lax.dynamic_update_slice(pmin, pmin_s, (t0, zero))
    return g_pad, v_pad, pmin


_pad_cache: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

# full-repad fallback threshold: more dirty spans than this and the
# span-by-span patching would launch more kernels than one full pad
_PATCH_MAX_SPANS = 8


def _padded_state(state: PriceState, dtype, T_pad: int):
    """``_state_arrays`` extended with the decide core's per-launch
    prologue — tile padding + the live-floor price ``pmin`` — computed
    once per (state version, dtype) and reused across every decision
    launch until the next commit/release, instead of inside each one.

    Between consecutive versions the cache is patched incrementally:
    ``PriceState.dirty_spans_since`` names the slots the commits touched
    and ``_pad_patch`` refreshes just those rows (the same maintenance
    contract ``RowCache`` uses).  Falls back to a full re-pad when the
    delta is unknowable or fragmented."""
    g, v, wcaps, scaps, U1, U2, L1, L2 = _state_arrays(state, dtype)
    key = (state.version, T_pad, jnp.dtype(dtype).name)
    hit = _pad_cache.get(state)
    if hit is not None and hit[0] == key:
        return hit[1]
    T = g.shape[0]
    if hit is not None and hit[0][1:] == key[1:]:
        spans = state.dirty_spans_since(hit[0][0])
        if spans is not None and len(spans) <= _PATCH_MAX_SPANS:
            g_pad, v_pad, pmin = hit[1][0], hit[1][1], hit[1][8]
            for s0, s1 in spans:
                span = _bucket(max(s1 - s0, 1), floor=8, step=64)
                if span > T:
                    break
                start = min(max(int(s0), 0), T - span)
                g_pad, v_pad, pmin = _pad_patch(
                    g_pad, v_pad, pmin, g, v, wcaps, U1, L1,
                    jnp.int32(start), span)
            else:
                hit = (key, (g_pad, v_pad, wcaps, scaps, U1, U2, L1, L2,
                             pmin))
                _pad_cache[state] = hit
                return hit[1]
    g_pad, v_pad, pmin = _pad_state(g, v, wcaps, U1, L1, T_pad=T_pad)
    hit = (key, (g_pad, v_pad, wcaps, scaps, U1, U2, L1, L2, pmin))
    _pad_cache[state] = hit
    return hit[1]


def _pad_tiles(T: int) -> int:
    return ((T + TILE - 1) // TILE) * TILE


def _utility_curve(job: Job, T: int, T_pad: int) -> np.ndarray:
    u = np.zeros(T_pad)
    a = job.arrival
    u[a:T] = [job.utility(t - a) for t in range(a, T)]
    return u


def _cost_lower_bound(job: Job, state: PriceState, W: np.ndarray) -> float:
    """Price-free base of the cost lower bound: workload * min_d W(d)/d.

    Any split's total worker-slots is >= workload * min_d W(d)/d, so ANY
    schedule's cost is >= this base times the cheapest single-worker slot
    cost over the job's feasible window — the device side of
    ``_decide_tiled_core`` multiplies in that live price floor (which is
    itself >= L1 * sum(worker_res), the old static bound).  Scaled by
    ``_LB_MARGIN`` so engine float64 rounding stays above the bound."""
    if len(W) < 2:
        return 0.0
    per_unit = float(np.min(W[1:] / np.arange(1, len(W), dtype=np.float64)))
    return _LB_MARGIN * job.workload * per_unit


def _job_arrays_tiled(job: Job, state: PriceState, T: int, T_pad: int,
                      m_pad: int, dtype):
    """Lane arrays for the tiled core.  Padded d entries get a sentinel
    worker count larger than any N so they are infeasible."""
    from .subroutine import workload_tables
    dcap = min(job.max_chunks_per_slot, job.workload)
    W, Z = workload_tables(job, dcap)
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    WZ[0, :dcap + 1] = W
    WZ[1, :dcap + 1] = Z
    u = _utility_curve(job, T, T_pad)
    usmax = np.maximum.accumulate(u[::-1])[::-1].copy()
    lb = _cost_lower_bound(job, state, W)
    resbw = np.concatenate([job.worker_res, job.ps_res,
                            [job.worker_bw, job.ps_bw]])
    meta = np.array([job.arrival, job.num_chunks, job.workload], np.int32)
    return (resbw.astype(np.float64), WZ, u, usmax, meta, np.float64(lb)), (W, Z)


def _reject_lane(T: int, T_pad: int, m_pad: int):
    """A batch-padding dummy: infeasible everywhere (nchunks = -1), arrival
    at T so it never drags the start tile down, zero utility so it never
    keeps the early-exit loop alive."""
    resbw = np.zeros(2 * R + 2)
    resbw[-2:] = 1.0
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    meta = np.array([T, -1, 1], np.int32)
    z = np.zeros(T_pad)
    return (resbw, WZ, z, z, meta, np.float64(0.0)), (WZ[0, :1], WZ[1, :1])


def _stack_lanes(lanes, dtype):
    cols = list(zip(*lanes))
    return (jnp.asarray(np.stack(cols[0]), dtype),      # resbw
            jnp.asarray(np.stack(cols[1])),             # WZ
            jnp.asarray(np.stack(cols[2]), dtype),      # u
            jnp.asarray(np.stack(cols[3]), dtype),      # usmax
            jnp.asarray(np.stack(cols[4])),             # meta
            jnp.asarray(np.stack(cols[5]), dtype))      # lb


def _job_arrays(job: Job, T: int, m_pad: int, dtype):
    """Legacy bundling for the monolithic (Pallas) core."""
    from .subroutine import workload_tables
    dcap = min(job.max_chunks_per_slot, job.workload)
    W, Z = workload_tables(job, dcap)
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    WZ[0, :dcap + 1] = W
    WZ[1, :dcap + 1] = Z
    a = job.arrival
    u = np.array([job.utility(t - a) if t >= a else 0.0 for t in range(T)])
    resbw = np.concatenate([job.worker_res, job.ps_res,
                            [job.worker_bw, job.ps_bw]])
    meta = np.array([a, job.num_chunks, job.workload], np.int32)
    return (jnp.asarray(resbw, dtype), jnp.asarray(WZ), jnp.asarray(u, dtype),
            jnp.asarray(meta))


def _x64_context(precision: str):
    """Engine precision policy.  "auto": float64 on CPU (exact agreement with
    the numpy paths), float32 on TPU.  An ambient jax_enable_x64 always wins.
    """
    import contextlib
    from jax.experimental import enable_x64
    if precision == "x64":
        return enable_x64(True)
    if precision == "auto" and jax.default_backend() == "cpu":
        return enable_x64(True)
    return contextlib.nullcontext()


@dataclasses.dataclass
class _Pending:
    """A decided-but-unplaced candidate from the tiled core.

    Holds the launch's device-resident row/cost tables (shared across the
    lanes of one launch) so the split backtrack — and the placement — run
    only if the candidate is actually accepted AND survives the commit
    pass.  Rejects never pay for either."""
    job: Job
    best_t: int
    payoff: float
    rows_full: jax.Array            # (B, T_pad, M) device, shared
    cost_full: jax.Array            # (B, T_pad, d1) device, shared
    lane: int                       # this job's lane in the launch
    t_start: int                    # first slot the decision loop visited
    W: np.ndarray                   # (dcap+1,) workload tables
    Z: np.ndarray
    cache: RowCache
    cost: float = float("nan")      # filled by _materialize for accepts


def _materialize(pend: _Pending, state: PriceState, sd, dtype
                 ) -> Optional[Schedule]:
    """Extract the split + placement for an accepted candidate (None =
    reject).

    Runs ``_backtrack`` over the stored lane tables and ``_place_slots``
    over just the deploying slots — MUST be called at the same price
    state the decision was made at."""
    job, best_t = pend.job, pend.best_t
    if best_t < 0:
        return None
    total_cost, d_left, d_slots = _backtrack(
        pend.rows_full[pend.lane], pend.cost_full[pend.lane],
        jnp.int32(best_t), jnp.int32(job.workload), jnp.int32(pend.t_start))
    d_slots = np.asarray(d_slots)
    pend.cost = float(total_cost)
    # mirrors _extract's backtrack assert: an accepted schedule must place
    # the whole workload (guards e.g. mixed-precision runs)
    assert int(d_left) == 0, \
        f"fused backtrack failed: {int(d_left)} chunk-passes unassigned"
    a = job.arrival
    # place only the slots that actually deploy (typically well under
    # half the [arrival, finish] window): each slot's greedy fill reads
    # its own state column only, so the gather changes nothing bit-wise
    ts_active = np.nonzero(d_slots[a:best_t + 1])[0] + a
    if len(ts_active) == 0:        # degenerate zero-workload accept
        utility = job.utility(best_t - a)
        return Schedule(jid=job.jid, workers={}, ps={}, finish=int(best_t),
                        cost=float(pend.cost),
                        payoff=utility - float(pend.cost), utility=utility)
    wa = _bucket(len(ts_active), floor=8, step=32)
    ts = np.full(wa, ts_active[-1], np.int32)
    ts[:len(ts_active)] = ts_active
    d_act = np.zeros(wa, d_slots.dtype)
    d_act[:len(ts_active)] = d_slots[ts_active]
    Wc = pend.W[d_act].astype(np.float64)
    Zc = pend.Z[d_act].astype(np.float64)
    Wc[len(ts_active):] = 0.0
    Zc[len(ts_active):] = 0.0
    y, z = _place_slots(sd, jnp.asarray(
        np.concatenate([job.worker_res, job.ps_res,
                        [job.worker_bw, job.ps_bw]]), dtype),
        jnp.asarray(Wc, dtype), jnp.asarray(Zc, dtype),
        jnp.asarray(ts), wa)
    y = np.asarray(y)
    z = np.asarray(z)
    H, K = state.cluster.H, state.cluster.K
    workers, ps = {}, {}
    for k, t in enumerate(ts_active):
        workers[int(t)] = y[k, :H].astype(np.int64)
        ps[int(t)] = z[k, :K].astype(np.int64)
    utility = job.utility(best_t - a)
    return Schedule(jid=job.jid, workers=workers, ps=ps, finish=int(best_t),
                    cost=float(pend.cost), payoff=utility - float(pend.cost),
                    utility=utility)


def _schedule_from_outputs(job: Job, state: PriceState, best_t: int,
                           cost: float, d_left: int, d_slots: np.ndarray,
                           y: np.ndarray, z: np.ndarray
                           ) -> Optional[Schedule]:
    """Schedule assembly for the legacy monolithic core's outputs."""
    if best_t < 0:
        return None
    assert d_left == 0, \
        f"fused backtrack failed: {d_left} chunk-passes unassigned"
    H, K = state.cluster.H, state.cluster.K
    workers, ps = {}, {}
    for t in range(job.arrival, best_t + 1):
        if d_slots[t] > 0:
            workers[t] = y[t, :H].astype(np.int64)
            ps[t] = z[t, :K].astype(np.int64)
    utility = job.utility(best_t - job.arrival)
    return Schedule(jid=job.jid, workers=workers, ps=ps, finish=int(best_t),
                    cost=float(cost), payoff=utility - float(cost),
                    utility=utility)


@functools.lru_cache(maxsize=32)
def _empty_cache(b_pad: int, T_pad: int, n_tiles: int, m_pad: int,
                 dtype_name: str):
    """Device-resident all-invalid row cache, one per launch shape: lets
    the cache-less decision path run the ``use_cache=True`` compiled
    variant without uploading a fresh buffer per launch."""
    rows0 = np.zeros((b_pad, T_pad, m_pad))
    rows0[:, :, 1:] = np.inf
    return (jnp.asarray(rows0, jnp.dtype(dtype_name)),
            jnp.zeros((b_pad, n_tiles), bool))


def _decide_jobs(jobs: Sequence[Tuple[int, Job]], state: PriceState, dtype,
                 m_pad: int, d1: int,
                 caches: Optional[dict] = None) -> List[_Pending]:
    """Run the tiled core over one shape-bucket group (<= _MAX_LANES jobs
    per launch).  ``caches``: optional {index: RowCache} serving lanes."""
    T = state.horizon
    T_pad = _pad_tiles(T)
    n_tiles = T_pad // TILE
    sd = _padded_state(state, dtype, T_pad)
    out: List[_Pending] = []
    for c0 in range(0, len(jobs), _MAX_LANES):
        chunk = jobs[c0:c0 + _MAX_LANES]
        b_pad = _bucket(len(chunk), floor=1, step=_MAX_LANES)
        lanes, tables = [], []
        for _, j in chunk:
            la, wz = _job_arrays_tiled(j, state, T, T_pad, m_pad, dtype)
            lanes.append(la)
            tables.append(wz)
        for _ in range(b_pad - len(chunk)):
            la, wz = _reject_lane(T, T_pad, m_pad)
            lanes.append(la)
            tables.append(wz)
        jd = _stack_lanes(lanes, dtype)
        # the no-cache case runs the SAME compiled variant with an
        # all-invalid (device-cached) empty cache: every distinct
        # (shape, use_cache) pair is a separate multi-second XLA
        # compilation, and the cond-per-tile overhead of the cached
        # variant is microseconds
        use_cache = caches is not None and any(
            caches.get(i) is not None for i, _ in chunk)
        if use_cache:
            rows0 = np.zeros((b_pad, T_pad, m_pad))
            rows0[:, :, 1:] = np.inf
            valid0 = np.zeros((b_pad, n_tiles), bool)
            rows_list = [None] * b_pad
            for bi, (i, _) in enumerate(chunk):
                cache = caches.get(i)
                if cache is not None and cache.rows is not None:
                    rows_list[bi] = cache.rows
                    valid0[bi] = cache.valid
            base = jnp.asarray(rows0, dtype)
            stackable = [rows_list[bi] if rows_list[bi] is not None
                         else base[bi] for bi in range(b_pad)]
            rows_init = jnp.stack(stackable)
            valid_tiles = jnp.asarray(valid0)
        else:
            rows_init, valid_tiles = _empty_cache(
                b_pad, T_pad, n_tiles, m_pad, jnp.dtype(dtype).name)
        best_t, payoff, rows_buf, cost_buf, k0, k_end = \
            _decide_tiled(sd, jd, rows_init, valid_tiles, T=T, d1=d1,
                          use_cache=True)
        best_t = np.asarray(best_t)
        payoff = np.asarray(payoff)
        k0, k_end = int(k0), int(k_end)
        for bi, (i, job) in enumerate(chunk):
            valid = np.zeros(n_tiles, bool)
            if use_cache and caches.get(i) is not None:
                valid |= caches[i].valid
            valid[k0:k_end] = True
            cache = RowCache(rows=rows_buf[bi], valid=valid,
                             version=state.version, m_pad=m_pad, d1=d1)
            out.append(_Pending(
                job=job, best_t=int(best_t[bi]), payoff=float(payoff[bi]),
                rows_full=rows_buf, cost_full=cost_buf, lane=bi,
                t_start=k0 * TILE, W=tables[bi][0], Z=tables[bi][1],
                cache=cache))
    return out


def _pow2_bucket(n: int, floor: int) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


def _shape_bucket(job: Job) -> Optional[Tuple[int, int]]:
    """Padded (m_pad, d1) compile bucket for a job's DP tables.

    Deliberately coarse — powers of two with high floors — because every
    distinct (m_pad, d1, lanes) triple is a separate XLA compilation of
    the decision loop, and compile time dominates wall clock at scale.
    The d1 floor covers the auto-quantized workload range (engine quantum
    targets <= 1200 chunk-passes) so scale runs see a SINGLE d1."""
    dcap = min(job.max_chunks_per_slot, job.workload)
    if dcap == 0:
        return None
    return (_pow2_bucket(dcap + 1, 64), _pow2_bucket(job.workload + 1, 1280))


def best_schedule_fused(job: Job, state: PriceState, *,
                        use_pallas: Optional[bool] = None,
                        precision: str = "auto",
                        row_cache: Optional[RowCache] = None
                        ) -> Optional[Schedule]:
    """Alg. 2 for one job through the fused jit engine.

    The default path is the tiled early-exit core; ``row_cache`` (from a
    previous decision for the SAME job, ``sync``-ed against the state)
    lets it recompute only dirtied tiles.  ``use_pallas=True`` routes
    through the legacy monolithic core with the Pallas sweep kernel (the
    TPU path)."""
    key = _shape_bucket(job)
    if key is None:
        return None
    m_pad, d1 = key
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    T = state.horizon      # window-local lookahead (== cluster.T episodic)
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        if use_pallas:
            sd = _state_arrays(state, dtype)
            jd = _job_arrays(job, T, m_pad, dtype)
            best_t, _, cost, d_left, d_slots, y, z = _decide_one(
                sd, jd, d1=d1, use_pallas=True)
            return _schedule_from_outputs(
                job, state, int(best_t), float(cost), int(d_left),
                np.asarray(d_slots), np.asarray(y), np.asarray(z))
        caches = {0: row_cache} if row_cache is not None else None
        pend = _decide_jobs([(0, job)], state, dtype, m_pad, d1,
                            caches=caches)[0]
        if row_cache is not None:
            row_cache.rows = pend.cache.rows
            row_cache.valid = pend.cache.valid
            row_cache.version = pend.cache.version
        sd = _state_arrays(state, dtype)
        return _materialize(pend, state, sd, dtype)


def decide_burst(jobs: Sequence[Job], state: PriceState, *,
                 precision: str = "auto",
                 timings: Optional[List[float]] = None) -> List[_Pending]:
    """Speculative batched Alg. 2: the whole burst decided at the CURRENT
    prices, one tiled launch per shape bucket (jobs are grouped by
    (dcap, workload) bucket so a small job is never padded up to the
    burst's largest DP table).  Returns per-job ``_Pending`` candidates —
    decision + split + row cache, placement deferred to
    ``_materialize`` — in input order (None for dcap-0 jobs).  Commit
    order / price updates are the caller's job (``OASiS.on_arrivals``
    re-solves any job whose prices moved).

    ``timings``, when given, is filled in place with each job's share of
    its own shape group's wall time."""
    out: List[Optional[_Pending]] = [None] * len(jobs)
    if timings is not None:
        timings[:] = [0.0] * len(jobs)
    groups = {}
    for i, j in enumerate(jobs):
        key = _shape_bucket(j)
        if key is None:
            continue
        groups.setdefault(key, []).append((i, j))
    if not groups:
        return out
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        for (m_pad, d1), live in groups.items():
            t0 = time.perf_counter()
            pends = _decide_jobs(live, state, dtype, m_pad, d1)
            for (i, _), pend in zip(live, pends):
                out[i] = pend
            if timings is not None:
                share = (time.perf_counter() - t0) / len(live)
                for i, _ in live:
                    timings[i] = share
    return out


def best_schedule_fused_batch(jobs: Sequence[Job], state: PriceState, *,
                              precision: str = "auto",
                              timings: Optional[List[float]] = None
                              ) -> List[Optional[Schedule]]:
    """Speculative batched Alg. 2 with placements materialized for every
    accepted candidate (all at the CURRENT prices — the caller must not
    commit between the call and using the results)."""
    pends = decide_burst(jobs, state, precision=precision, timings=timings)
    out: List[Optional[Schedule]] = [None] * len(jobs)
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        sd = _state_arrays(state, dtype)
        for i, pend in enumerate(pends):
            if pend is not None:
                out[i] = _materialize(pend, state, sd, dtype)
    return out
