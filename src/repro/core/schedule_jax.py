"""JAX backend for the Alg. 2 DP sweep.

``dp_sweep_jax(rows, D)`` runs the min-plus recurrence over time slots with
``lax.scan``; the inner banded min-plus is the Pallas VPU kernel
(``repro.kernels.minplus``) on TPU, interpret-mode on CPU.  Returns the
same (cost table, split table) as the numpy path in ``subroutine.py``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.minplus.ref import minplus_ref

_INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("d_total", "use_pallas"))
def _sweep(rows: jax.Array, d_total: int, use_pallas: bool
           ) -> Tuple[jax.Array, jax.Array]:
    if use_pallas:
        from ..kernels.minplus.kernel import minplus_pallas
        interpret = jax.default_backend() != "tpu"
        inner = functools.partial(minplus_pallas, interpret=interpret)
    else:
        inner = minplus_ref

    def step(prev, row):
        new, arg = inner(row, prev)
        return new, (new, arg)

    init = jnp.full((d_total + 1,), _INF).at[0].set(0.0)
    _, (costs, args) = jax.lax.scan(step, init, rows)
    return costs, args


def dp_sweep_jax(rows: np.ndarray, d_total: int, use_pallas: bool = False
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """rows: (T', dcap+1) float64/32 with +inf; returns (cost (T', D+1),
    split (T', D+1) int)."""
    rows32 = jnp.asarray(np.nan_to_num(rows, posinf=np.inf), jnp.float32)
    costs, args = _sweep(rows32, int(d_total), bool(use_pallas))
    return np.asarray(costs, np.float64), np.asarray(args, np.int64)
