"""Fused, jit-compiled JAX backend for the Alg. 2 dual subroutine.

The engine runs the WHOLE per-arrival pipeline as XLA computations: dual
prices from the allocation state, per-server capacity + sorted prefix-sum
greedy COST_t rows, the banded min-plus DP sweep over slots, the payoff
argmax with the reference tie rule, the split-table backtrack, and the
greedy placement extraction.

**Tiled decision core** (``_decide_tiled``): the horizon is walked in
``TILE``-slot blocks inside a ``lax.while_loop``, natively batched over a
lane axis so an entire arrival burst is one device launch:

* blocks before the earliest arrival in the batch are skipped outright
  (their COST rows are the DP identity ``[0, inf, ...]``);
* after each block the loop exits early once **no remaining slot can beat
  the incumbent payoff for any lane** — exact, not heuristic, because the
  suffix maximum of the utility curve bounds future payoffs from above and
  every schedule's cost is bounded below by the LIVE price-floor bound
  ``workload * min_d(workers_for(d)/d) * min over feasible slots of the
  cheapest single-worker slot cost`` at the current prices (>= the static
  ``L1 * sum(worker_res)`` floor, and far tighter once the cluster fills
  up).  The reference tie rule (``> best + 1e-12``) therefore cannot
  switch on any skipped slot and decisions stay bit-identical to
  ``best_schedule_ref``;
* COST rows can be served from a :class:`RowCache` — a commit only moves
  prices inside the committed slot window, so re-solves (the sequential
  half of ``OASiS.on_arrivals``) recompute only dirtied tiles.

Placement is extracted by a second, small jit (``_place_slots``) over
just the slots of the accepted schedule that actually deploy, so the
decision loop never materializes placement tables for slots it will
not use.

``best_schedule_fused_batch`` decides a padded batch of jobs (shared
price state) in one launch per shape bucket — the speculative half of
``OASiS.on_arrivals``.

Precision: on CPU the engine runs under ``jax.experimental.enable_x64``
by default so its decisions match the float64 numpy/reference paths
exactly; on TPU it runs float32 (f64 is unsupported there) with the
Pallas min-plus sweep kernel via the legacy monolithic core
(``use_pallas=True`` keeps that path compiled and equivalence-tested).

``dp_sweep_jax`` (the seed's DP-only entry point) is kept for
micro-benches and backward compatibility.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
import weakref
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.minplus.kernel import minplus_sweep_pallas
from ..kernels.minplus.monotone import (PATH_CHAIN, PATH_DNC, PATH_PLATEAU,
                                        convex_certificate, monotone_dnc_step,
                                        plateau_step_unrolled, run_count)
from ..kernels.minplus.ref import minplus_sweep_cost, minplus_sweep_ref
from ..kernels.minplus.tiled import TILE, minplus_chain_step
from .pricing import PriceState, size_bucket as _bucket
from .types import Job, R, Schedule
from .. import obs as _obs

# Stand-in for "unbounded" per-server instance capacity (job has no demand
# on some resource): big enough to never bind, small enough that prefix sums
# of it stay exact-ish in f32 comparisons against tiny instance counts.
_BIG_CAP = 1.0e9
_PAY_EPS = 1e-12        # payoff tie epsilon — same as the reference path
# safety margin on the price-floor cost lower bound: the bound is proved
# in exact arithmetic; scale it down so float64 rounding in the engine's
# prefix sums can never push a computed cost below it
_LB_MARGIN = 0.999
# split-tie band for the backtrack argmin: XLA vectorizes the same f64
# pipeline differently per launch shape (lane count, cache path), so two
# launches over identical state can disagree in the LAST ULPS of a DP
# cell.  An exact argmin then flips between equally-optimal splits and
# the committed placements — hence the whole price trajectory — fork
# between the burst and sequential paths.  Snapping the backtrack to the
# first index within this RELATIVE band of the minimum makes the split a
# function of the (macroscopically) optimal set, not of ulp noise: costs
# are nonnegative sums of ≲1e3 rounded f64 terms, so cross-launch noise
# on an exact tie stays ≲1e-13 relative, while genuinely distinct splits
# differ by far more than 1e-12 relative.  Decisions (best_t) are
# already protected the same way by _PAY_EPS.
_SPLIT_TOL = 1e-12
# Lane cap per launch: bounds the (B, T_pad, D+1) DP table memory.  On a
# single-core CPU backend the DP sweep is memory-bandwidth bound and lane
# fusion scales SUPERLINEARLY in wall clock (8 fused lanes measured ~2.7x
# the cost of 8 singleton launches at paper-10x shapes), so bursts there
# decide lane-by-lane — still speculative, still one RowCache per job —
# while parallel backends get real fusion.  Override with REPRO_BURST_LANES.
_MAX_LANES = int(os.environ.get(
    "REPRO_BURST_LANES", "8" if jax.default_backend() == "tpu" else "1"))


# ---------------------------------------------------------------------------
# Decision-phase stage profiling (REPRO_DECIDE_PROFILE=1)
# ---------------------------------------------------------------------------

_PROFILE_STAGES = ("row_build", "dp_sweep", "backtrack", "placement")
_profile_acc = {k: 0.0 for k in _PROFILE_STAGES}
_profile_acc["decisions"] = 0.0


def _profiling() -> bool:
    """Re-read the environment per launch so callers (e.g.
    ``examples/cluster_sim.py --profile``) can toggle profiling after
    this module is imported."""
    return os.environ.get("REPRO_DECIDE_PROFILE", "") not in ("", "0")


def decide_profile_reset() -> None:
    for k in _profile_acc:
        _profile_acc[k] = 0.0


def decide_profile_snapshot() -> dict:
    """Accumulated per-stage decision wall clock since the last reset.

    Stages: ``row_build`` (COST-row construction inside the decide
    launch), ``dp_sweep`` (min-plus DP + early-exit loop), ``backtrack``
    (split recovery for accepts), ``placement`` (greedy fills).  The
    row/DP split is measured by re-running the decide launch with every
    visited tile served from the just-refreshed row cache — the second
    launch is DP-only, so ``row_build = total - dp_only``.  Profiling
    therefore roughly doubles decision latency; it is a diagnostic mode,
    not a benchmark mode."""
    return dict(_profile_acc)


# ---------------------------------------------------------------------------
# Seed-compatible DP-only entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("d_total", "use_pallas"))
def _sweep(rows: jax.Array, d_total: int, use_pallas: bool
           ) -> Tuple[jax.Array, jax.Array]:
    if use_pallas:
        interpret = jax.default_backend() != "tpu"
        return minplus_sweep_pallas(rows, d_total, interpret=interpret)
    return minplus_sweep_ref(rows, d_total)


def dp_sweep_jax(rows: np.ndarray, d_total: int, use_pallas: bool = False
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """rows: (T', dcap+1) with +inf; returns (cost (T', D+1), split (T', D+1)).

    Runs in float64 when ``jax_enable_x64`` is on (the numpy path's dtype),
    float32 otherwise.  The Pallas path is always float32 (TPU VPU kernel).
    """
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    rows_j = jnp.asarray(np.nan_to_num(rows, posinf=np.inf), dtype)
    costs, args = _sweep(rows_j, int(d_total), bool(use_pallas))
    return np.asarray(costs, np.float64), np.asarray(args, np.int64)


# ---------------------------------------------------------------------------
# Shared single-lane helpers (also used by the legacy Pallas core)
# ---------------------------------------------------------------------------

def _price_pow(ratio: jax.Array, x: jax.Array) -> jax.Array:
    """``ratio ** x`` computed as ``exp(x * log(ratio))``.

    XLA's CPU backend lowers a broadcast ``pow`` with a non-constant base
    to per-element libm calls (~100 ns each), which made the per-tile
    price tables the single largest cost of a fused decision launch; the
    explicit exp/log form vectorizes.  ``ratio`` is clamped to
    ``1 + 1e-9`` upstream so the log is always finite, and ``x == 0``
    still yields exactly 1.  Every price computation in this module must
    go through this helper — mixing it with ``**`` would produce
    last-ulp price disagreements between the decision and placement
    paths.
    """
    return jnp.exp(x * jnp.log(ratio))


def _prefix_tables_jnp(prices: jax.Array, headroom: jax.Array,
                       demand: jax.Array):
    """Per-slot sorted unit costs + prefix sums (whole-array, all slots).

    Returns (order, scap, scost, ccap, ccost), each (T, S)."""
    unit = (prices * demand[None, None, :]).sum(axis=2)          # (T, S)
    safe = jnp.where(demand > 0, demand, 1.0)
    per_r = jnp.where(demand[None, None, :] > 0,
                      jnp.floor(headroom / safe[None, None, :] + 1e-9),
                      _BIG_CAP)
    cap = jnp.clip(jnp.min(per_r, axis=2), 0.0, _BIG_CAP)        # (T, S)
    order = jnp.argsort(unit, axis=1, stable=True)
    scost = jnp.take_along_axis(unit, order, axis=1)
    scap = jnp.take_along_axis(cap, order, axis=1)
    ccap = jnp.cumsum(scap, axis=1)
    ccost = jnp.cumsum(scap * scost, axis=1)
    return order, scap, scost, ccap, ccost


def _greedy_cost_jnp(ccap: jax.Array, ccost: jax.Array, scost: jax.Array,
                     counts: jax.Array) -> jax.Array:
    """Greedy (cheapest-first) deployment cost for ``counts`` (T, M) at every
    slot, from (T, S) prefix tables.  +inf where counts exceed capacity."""
    S = ccap.shape[1]
    # first prefix covering each count (== np.searchsorted side="left";
    # binary search, not the quadratic (T, S, M) comparison tensor)
    idx = jax.vmap(
        functools.partial(jnp.searchsorted, side="left"))(ccap, counts)
    zcol = jnp.zeros((ccap.shape[0], 1), ccap.dtype)
    prev_cap = jnp.take_along_axis(jnp.concatenate([zcol, ccap], 1), idx, 1)
    prev_cost = jnp.take_along_axis(jnp.concatenate([zcol, ccost], 1), idx, 1)
    marg = jnp.take_along_axis(scost, jnp.minimum(idx, S - 1), 1)
    vals = prev_cost + (counts - prev_cap) * marg
    return jnp.where(counts == 0, 0.0,
                     jnp.where(counts <= ccap[:, -1:], vals, jnp.inf))


def _greedy_place_jnp(order: jax.Array, scap: jax.Array, ccap: jax.Array,
                      count: jax.Array) -> jax.Array:
    """Per-server instance counts for a greedy fill of ``count`` (T,) at each
    slot: cheapest servers first, each up to its capacity.  Returns (T, S)
    int32 in ORIGINAL server order."""
    prev = jnp.concatenate(
        [jnp.zeros((ccap.shape[0], 1), ccap.dtype), ccap[:, :-1]], axis=1)
    take = jnp.clip(count[:, None] - prev, 0.0, scap)            # sorted order
    inv = jnp.argsort(order, axis=1, stable=True)                # rank of h
    return jnp.round(jnp.take_along_axis(take, inv, axis=1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Batched (lane-axis) helpers for the tiled core
# ---------------------------------------------------------------------------

def _prefix_tables_b(prices: jax.Array, headroom: jax.Array,
                     demand: jax.Array):
    """Lane-batched prefix tables for one tile.

    prices/headroom: (TILE, S, R) shared across lanes; demand: (B, R) per
    lane.  Returns (scost, ccap, ccost), each (B, TILE, S) — the greedy
    cost tables only (placement order is extracted by ``_place_slots``,
    never in the decision loop)."""
    unit = (prices[None] * demand[:, None, None, :]).sum(axis=3)
    safe = jnp.where(demand > 0, demand, 1.0)
    per_r = jnp.where(demand[:, None, None, :] > 0,
                      jnp.floor(headroom[None] / safe[:, None, None, :]
                                + 1e-9),
                      _BIG_CAP)
    cap = jnp.clip(jnp.min(per_r, axis=3), 0.0, _BIG_CAP)
    order = jnp.argsort(unit, axis=2, stable=True)
    scost = jnp.take_along_axis(unit, order, axis=2)
    scap = jnp.take_along_axis(cap, order, axis=2)
    ccap = jnp.cumsum(scap, axis=2)
    ccost = jnp.cumsum(scap * scost, axis=2)
    return scost, ccap, ccost


def _greedy_cost_b(ccap: jax.Array, ccost: jax.Array, scost: jax.Array,
                   counts: jax.Array) -> jax.Array:
    """Lane-batched greedy cost: (B, TILE, S) tables, (B, TILE, M) counts."""
    S = ccap.shape[2]
    # first prefix covering each count.  ``searchsorted`` (binary search)
    # returns exactly ``(ccap < counts).sum(axis=2)`` — ``ccap`` is a
    # nondecreasing cumsum — but skips materializing the (B, TILE, S, M)
    # comparison tensor, which was ~10x the cost of everything else here.
    idx = jax.vmap(jax.vmap(
        functools.partial(jnp.searchsorted, side="left")))(ccap, counts)
    zcol = jnp.zeros(ccap.shape[:2] + (1,), ccap.dtype)
    prev_cap = jnp.take_along_axis(
        jnp.concatenate([zcol, ccap], -1), idx, -1)
    prev_cost = jnp.take_along_axis(
        jnp.concatenate([zcol, ccost], -1), idx, -1)
    marg = jnp.take_along_axis(scost, jnp.minimum(idx, S - 1), -1)
    vals = prev_cost + (counts - prev_cap) * marg
    return jnp.where(counts == 0, 0.0,
                     jnp.where(counts <= ccap[..., -1:], vals, jnp.inf))


# ---------------------------------------------------------------------------
# Per-job sorted-order / cumsum tables (the "order cache")
# ---------------------------------------------------------------------------

@jax.jit
def _sorted_fill_lanes(p, q, g, v, wcaps, scaps, resbw):
    """Full sorted-order/cumsum table set for every lane: 6 arrays
    (B, T_pad, H|K) — ``(w_scost, w_ccap, w_ccost, s_scost, s_ccap,
    s_ccost)``.

    ``_prefix_tables_b``'s ops (reduce over R, argsort + cumsum along
    the trailing server axis) touch each slot independently, so TILE
    slices of these tables are bit-identical to the per-tile tables the
    decide loop used to build inline — and the per-tile argsorts leave
    the decide launch entirely."""
    wres, sres = resbw[:, :R], resbw[:, R:2 * R]
    w = _prefix_tables_b(p, wcaps[None] - g, wres)
    s = _prefix_tables_b(q, scaps[None] - v, sres)
    return w + s


@functools.partial(jax.jit, static_argnames=("span",))
def _sorted_fill(tabs, p, q, g, v, wcaps, scaps, resbw, t0, span: int):
    """Patch one dirty slot span of a single lane's (T_pad, S) table set
    in place — the exact ``_sorted_fill_lanes`` formulas on the span's
    rows, so the patched tables are bit-identical to a full rebuild at
    the new state version (per-slot sort cost O(dirty) on re-solves)."""
    zero = jnp.zeros_like(t0)
    p_s = jax.lax.dynamic_slice(p, (t0, zero, zero), (span,) + p.shape[1:])
    q_s = jax.lax.dynamic_slice(q, (t0, zero, zero), (span,) + q.shape[1:])
    g_s = jax.lax.dynamic_slice(g, (t0, zero, zero), (span,) + g.shape[1:])
    v_s = jax.lax.dynamic_slice(v, (t0, zero, zero), (span,) + v.shape[1:])
    wres, sres = resbw[None, :R], resbw[None, R:2 * R]
    w = _prefix_tables_b(p_s, wcaps[None] - g_s, wres)
    s = _prefix_tables_b(q_s, scaps[None] - v_s, sres)
    return tuple(jax.lax.dynamic_update_slice(tab, n[0], (t0, zero))
                 for tab, n in zip(tabs, w + s))


# ---------------------------------------------------------------------------
# Tiled, batched decision core
# ---------------------------------------------------------------------------

def _mono_band() -> int:
    """Band-width ceiling for the monotone min-plus dispatch (env-tunable;
    0 disables).  Re-read per launch so tests can toggle it."""
    return int(os.environ.get("REPRO_MONOTONE_BAND", "64"))


def _mono_dnc() -> bool:
    """Whether the decide loop may take the SMAWK-style divide-and-conquer
    branch (vs plateau/chain only).  Default off: on CPU XLA the D&C's
    scatter-heavy lowering loses to the unrolled chain at every shape we
    measured, and compiling it per shape bucket adds seconds of cold
    latency — the kernel stays fully exercised via ops/tests/benchmarks."""
    return os.environ.get("REPRO_MONOTONE_DNC", "") not in ("", "0")

def _table_max() -> int:
    """Order-cache footprint ceiling: full sorted-table sets are only
    built (and thereafter span-patched) when ``T_pad * max(H, K)`` is at
    most this many slot-server cells.  Above it the one-shot build costs
    more than it can ever amortize — XLA CPU's stable argsort over a
    (512, 100) table runs ~26 ms while the early-exit decide loop sorts
    only the tiles it visits — so big shapes keep the inline per-tile
    path and small re-solve-heavy shapes (serving windows) get O(dirty)
    patching.  Env-tunable for the order-cache tests."""
    return int(os.environ.get("REPRO_ORDER_CACHE_MAX", "16384"))


@functools.lru_cache(maxsize=4)
def _dummy_tabs(dtype_name: str):
    """Placeholder tabs operand for ``use_tabs=False`` launches (the
    static flag keeps them out of the compiled program entirely)."""
    z = jnp.zeros((1, 1, 1), jnp.dtype(dtype_name))
    return (z,) * 6


def _decide_tiled_core(sd, jd, tabs, rows_init, valid_tiles, *, T: int,
                       d1: int, use_cache: bool, mono: int,
                       use_tabs: bool):
    """Alg. 2 decisions for a lane batch, horizon-tiled with exact early
    exit (module docstring).

    sd: PADDED state arrays from ``_pad_state`` (g (T_pad,H,R),
        v (T_pad,K,R), wcaps (H,R), scaps (K,R), U1 (R,), U2 (R,),
        L1 (), L2 (), pmin (T_pad, R) — the per-slot minimum worker
        price for the live cost floor, precomputed per state version)
    jd: lane-batched job arrays —
        resbw (B, 2R+2) = [wres, sres, wbw, psbw],
        WZ (B, 2, M) i32, u (B, T_pad), usmax (B, T_pad) suffix-max of u,
        meta (B, 4) i32 = [a, nchunks, d_tot, dcap], lb (B,) — the
        price-free per-chunk-pass lower-bound base from
        ``_cost_lower_bound`` (a live greedy price floor over the
        cheapest feasible slots is multiplied in on device).
    tabs: per-job sorted-order/cumsum tables from ``_sorted_fill_lanes``
        — 6 arrays (B, T_pad, H|K) when ``use_tabs``; the decide loop
        then only slices them, so it runs no prices and no argsorts at
        all.  When ``use_tabs`` is False (the common first-decision
        path), tabs are (1, 1, 1) dummies and the loop builds each
        visited tile's tables inline from the cached price tables —
        argsorts only on visited tiles, which the early exit keeps far
        below T_pad.
    rows_init/valid_tiles: ``use_cache`` row cache — (B, T_pad, M) rows at
        the current prices plus a (B, n_tiles) tile-validity mask; a tile
        is recomputed unless it is valid for EVERY lane.  Scalars when
        ``use_cache`` is False.
    T: static — the real (unpadded) horizon.
    d1: static — DP columns (padded D_total + 1).
    mono: static — monotone min-plus dispatch level: 0 = chain only,
        1 = staircase-plateau + chain, 2 = also the divide-and-conquer
        branch (``REPRO_MONOTONE_DNC``).  Levels > 0 require a single
        lane; the branch is chosen ONCE PER TILE (per-slot dispatch costs
        more than it saves) and every branch produces bit-identical DP
        values (see ``kernels.minplus.monotone``).

    Returns (best_t i32 (-1 = reject), payoff, total_cost, d_left i32,
    d_slots (B, T_pad) i32, rows (B, T_pad, M) — the refreshed row cache —
    k0, k_end i32: the visited tile range [k0, k_end), paths (3,) i32 —
    per-branch processed-tile counts [dnc, plateau, chain]).
    """
    g, v, wcaps, scaps, U1, U2, L1, L2, pmin, p_pad, q_pad = sd
    resbw, WZ, u, usmax, meta, lb = jd
    B = resbw.shape[0]
    T_pad = u.shape[1]
    n_tiles = T_pad // TILE
    M = WZ.shape[2]
    dt = g.dtype
    wres, sres = resbw[:, :R], resbw[:, R:2 * R]
    wbw, psbw = resbw[:, 2 * R], resbw[:, 2 * R + 1]
    W, Z = WZ[:, 0], WZ[:, 1]                                    # (B, M) i32
    a, nchunks, d_tot = meta[:, 0], meta[:, 1], meta[:, 2]
    dcap = meta[:, 3]
    tw_scost, tw_ccap, tw_ccost, ts_scost, ts_ccap, ts_ccost = tabs
    H = g.shape[1]
    K = v.shape[1]
    if mono:
        assert B == 1, "monotone dispatch is single-lane only"
    r_max = max(16, M // 4)

    Wf = W.astype(dt)
    deploy_target = jnp.minimum(Z, W).astype(dt)                 # (B, M)
    feas_n = (W <= nchunks[:, None])[:, None, :]                 # (B, 1, M)
    ms = jnp.arange(M)

    def rows_for_tile(t0):
        """COST_t rows for slots [t0, t0+TILE), all lanes: (B, TILE, M).

        ``use_tabs``: assembled from the cached sorted tables (greedy
        prefix lookups only — the prices and argsorts happened in the
        table build).  Otherwise the tile's prefix tables are built here
        from slices of the version-cached price tables, with the SAME
        ``_prefix_tables_b`` formulas — the two modes are bit-identical
        (argsort + cumsum touch each slot independently)."""
        zero = jnp.zeros_like(t0)
        if use_tabs:
            w_scost = jax.lax.dynamic_slice(
                tw_scost, (zero, t0, zero), (B, TILE, H))
            w_ccap = jax.lax.dynamic_slice(
                tw_ccap, (zero, t0, zero), (B, TILE, H))
            w_ccost = jax.lax.dynamic_slice(
                tw_ccost, (zero, t0, zero), (B, TILE, H))
            s_scost = jax.lax.dynamic_slice(
                ts_scost, (zero, t0, zero), (B, TILE, K))
            s_ccap = jax.lax.dynamic_slice(
                ts_ccap, (zero, t0, zero), (B, TILE, K))
            s_ccost = jax.lax.dynamic_slice(
                ts_ccost, (zero, t0, zero), (B, TILE, K))
        else:
            nr = p_pad.shape[2]
            p_t = jax.lax.dynamic_slice(
                p_pad, (t0, zero, zero), (TILE, H, nr))
            q_t = jax.lax.dynamic_slice(
                q_pad, (t0, zero, zero), (TILE, K, nr))
            g_t = jax.lax.dynamic_slice(
                g, (t0, zero, zero), (TILE, H, nr))
            v_t = jax.lax.dynamic_slice(
                v, (t0, zero, zero), (TILE, K, nr))
            w_scost, w_ccap, w_ccost = _prefix_tables_b(
                p_t, wcaps[None] - g_t, wres)
            s_scost, s_ccap, s_ccost = _prefix_tables_b(
                q_t, scaps[None] - v_t, sres)
        Wt = jnp.broadcast_to(Wf[:, None, :], (B, TILE, M))
        w_costs = _greedy_cost_b(w_ccap, w_ccost, w_scost, Wt)
        pool = s_ccap[..., -1:]                                  # (B, TILE, 1)
        deploy = jnp.minimum(deploy_target[:, None, :], pool)
        feas_ps = deploy * psbw[:, None, None] >= Wt * wbw[:, None, None] - 1e-9
        z_costs = _greedy_cost_b(s_ccap, s_ccost, s_scost, deploy)
        rows = jnp.where(feas_n & feas_ps, w_costs + z_costs, jnp.inf)
        rows = rows.at[:, :, 0].set(0.0)
        # pre-arrival and beyond-horizon slots carry the DP unchanged
        ts = t0 + jnp.arange(TILE, dtype=jnp.int32)
        dead = (ts[None, :] < a[:, None]) | (ts >= T)[None, :]
        return jnp.where(dead[:, :, None] & (ms > 0)[None, None, :],
                         jnp.inf, rows)

    a_min = jnp.min(a)
    init_col = jnp.full((B, d1), jnp.inf, dt).at[:, 0].set(0.0)
    if use_cache:
        rows_buf0 = rows_init
    else:
        rows_buf0 = jnp.full((B, T_pad, M), jnp.inf, dt).at[:, :, 0].set(0.0)
    cost_buf0 = jnp.full((B, T_pad, d1), jnp.inf, dt)
    k0 = jnp.min(a).astype(jnp.int32) // TILE
    t_start = k0 * TILE

    # Live early-exit cost floor.  ``lb`` from the host is the price-free
    # per-chunk-pass base min_d(W(d)/d) (times _LB_MARGIN); every worker
    # a schedule deploys in slot s costs >= sum_r wres_r * min_h
    # p[s,h,r] =: wslot[s], so placing d chunk-passes in slot s costs
    # >= d * base * wslot[s].  A schedule can place at most dcap
    # chunk-passes per slot, so ANY schedule's total cost is >= base
    # times the greedy spread of d_tot over the CHEAPEST feasible slots
    # (dcap each, remainder on the last) — minimizing sum_s d_s *
    # wslot[s] subject to 0 <= d_s <= dcap, sum d_s = d_tot puts dcap on
    # the cheapest slots, so the spread is a true minimum over feasible
    # splits.  This reduces to the old single-cheapest-slot floor when
    # dcap >= d_tot and is far tighter for multi-slot workloads: rejects
    # exit the tile loop after a prefix of the horizon (often before the
    # first tile) instead of sweeping the DP to the deadline.  ``pmin``
    # (the per-slot minimum worker price, (T_pad, R)) is computed once
    # per state version in ``_pad_state``, not per launch.
    wslot = jnp.einsum("tr,br->bt", pmin, wres)
    ts_all = jnp.arange(T_pad, dtype=jnp.int32)
    feas_t = (ts_all[None, :] >= a[:, None]) & (ts_all < T)[None, :]
    wsort = jnp.sort(jnp.where(feas_t, wslot, jnp.inf), axis=1)  # (B, T_pad)
    dcap_f = jnp.maximum(dcap, 1).astype(dt)
    take = jnp.clip(d_tot[:, None].astype(dt)
                    - ts_all[None, :].astype(dt) * dcap_f[:, None],
                    0.0, dcap_f[:, None])
    # infeasible-window tail: missing slots contribute 0, keeping the
    # floor a valid (weaker) lower bound; the DP itself rejects such jobs
    floor_sum = jnp.sum(
        take * jnp.where(jnp.isfinite(wsort), wsort, 0.0), axis=1)
    lb = jnp.where(lb > 0, lb * floor_sum, 0.0)

    def cond(c):
        k, _, best, _, _, _, _ = c
        t_next = jnp.clip(k * TILE, 0, T_pad - 1)
        um = jax.lax.dynamic_slice_in_dim(usmax, t_next, 1, axis=1)[:, 0]
        active = um > best + _PAY_EPS + lb
        return (k < n_tiles) & jnp.any(active)

    def body(c):
        k, prev, best, best_t, paths, cost_buf, rows_buf = c
        t0 = k * TILE
        zero = jnp.zeros_like(t0)
        if use_cache:
            tile_ok = jnp.all(
                jax.lax.dynamic_slice_in_dim(valid_tiles, k, 1, axis=1))
            rows_tile = jax.lax.cond(
                tile_ok,
                lambda: jax.lax.dynamic_slice(
                    rows_init, (zero, t0, zero), (B, TILE, M)),
                lambda: rows_for_tile(t0))
        else:
            rows_tile = rows_for_tile(t0)
        u_tile = jax.lax.dynamic_slice(u, (zero, t0), (B, TILE))
        ts_tile = t0 + jnp.arange(TILE, dtype=jnp.int32)

        # Monotone min-plus dispatch, decided ONCE for the whole tile:
        # every slot row in the tile must qualify, because a per-slot
        # branch costs more in dispatch than the fast path saves.  The
        # plateau gate (run_count <= r_max, no NaN / -inf) is exactly the
        # soundness condition of ``plateau_step_unrolled``; identity rows
        # of dead slots have 2 runs and never block it.
        if mono:
            rt = rows_tile[0]
            clean = jnp.all((rt == rt) & (rt > -jnp.inf))
            plat_ok = clean & jnp.all(jax.vmap(run_count)(rt) <= r_max)
            if mono >= 2:
                conv_ok = clean & jnp.all(jax.vmap(convex_certificate)(rt))
                branch = jnp.where(
                    conv_ok, PATH_DNC,
                    jnp.where(plat_ok, PATH_PLATEAU, PATH_CHAIN))
            else:
                branch = jnp.where(plat_ok, PATH_PLATEAU, PATH_CHAIN)
        else:
            branch = jnp.int32(PATH_CHAIN)
        paths = paths.at[branch].add(1)

        def slot(carry, x):
            prev, best, best_t = carry
            row, u_t, t = x

            def live(_):
                if mono >= 2:
                    def _dnc():
                        out, ovf = monotone_dnc_step(row[0], prev[0])
                        return jax.lax.cond(
                            ovf,
                            lambda: minplus_chain_step(row, prev),
                            lambda: out[None])
                    new = jax.lax.switch(branch, [
                        _dnc,
                        lambda: plateau_step_unrolled(
                            row[0], prev[0], r_max)[None],
                        lambda: minplus_chain_step(row, prev)])
                elif mono:
                    new = jax.lax.cond(
                        branch == PATH_PLATEAU,
                        lambda: plateau_step_unrolled(
                            row[0], prev[0], r_max)[None],
                        lambda: minplus_chain_step(row, prev))
                else:
                    new = minplus_chain_step(row, prev)
                costD = jnp.take_along_axis(new, d_tot[:, None],
                                            axis=1)[:, 0]
                pay = jnp.where(jnp.isfinite(costD) & (t >= a) & (t < T),
                                u_t - costD, -jnp.inf)
                switch = pay > best + _PAY_EPS
                return (new, jnp.where(switch, pay, best),
                        jnp.where(switch, t, best_t))

            def dead(_):
                # slots before every lane's arrival (or past the horizon)
                # have the identity row [0, inf, ...]: the chain step
                # would return ``prev`` bit-for-bit, so skip it at
                # runtime — with single-lane launches this skips the DP
                # for the whole pre-arrival prefix of the first tile
                return (prev, best, best_t)

            new, best, best_t = jax.lax.cond(
                (t >= a_min) & (t < T), live, dead, None)
            return (new, best, best_t), new

        (prev, best, best_t), cols = jax.lax.scan(
            slot, (prev, best, best_t),
            (jnp.swapaxes(rows_tile, 0, 1), u_tile.T, ts_tile))
        cost_buf = jax.lax.dynamic_update_slice(
            cost_buf, jnp.swapaxes(cols, 0, 1), (zero, t0, zero))
        rows_buf = jax.lax.dynamic_update_slice(
            rows_buf, rows_tile, (zero, t0, zero))
        return k + 1, prev, best, best_t, paths, cost_buf, rows_buf

    k_end, _, best, best_t, paths, cost_buf, rows_buf = jax.lax.while_loop(
        cond, body,
        (k0, init_col, jnp.zeros((B,), dt), jnp.full((B,), -1, jnp.int32),
         jnp.zeros((3,), jnp.int32), cost_buf0, rows_buf0))
    return best_t, best, rows_buf, cost_buf, k0, k_end, paths


@functools.partial(jax.jit,
                   static_argnames=("T", "d1", "use_cache", "mono",
                                    "use_tabs"))
def _decide_tiled(sd, jd, tabs, rows_init, valid_tiles, T: int, d1: int,
                  use_cache: bool, mono: int, use_tabs: bool):
    return _decide_tiled_core(sd, jd, tabs, rows_init, valid_tiles, T=T,
                              d1=d1, use_cache=use_cache, mono=mono,
                              use_tabs=use_tabs)


@jax.jit
def _backtrack(rows_lane: jax.Array, cost_lane: jax.Array, best_t, d_tot,
               t_start):
    """Split recovery for ONE accepted lane, from the decision loop's
    stored row/cost tables (device-resident; rejects never pay this).

    Walks t DOWN from ``best_t`` (later slots place nothing by
    construction), recomputing each slot's split as the FIRST j with
    rows[t, j] + cost_{t-1}[d_rem - j] within ``_SPLIT_TOL`` of the
    minimum — an exact argmin would make the split (and so the committed
    placements) a function of launch-shape ulp noise; see the
    ``_SPLIT_TOL`` note.  Stops as soon as the remaining workload hits
    zero: every earlier slot's only in-band candidate is then j = 0
    (idx = -j < 0 is masked to inf for j > 0 and vals[0] = 0 + prev[0]),
    so skipping them is bit-identical to the full scan the loop
    replaces — and a typical accept backtracks a short suffix of the
    horizon instead of all T_pad slots.  ``t_start`` is the first slot
    the decision loop processed (earlier slots carry the DP identity).
    Returns (total_cost, d_left, d_slots (T_pad,) i32)."""
    T_pad, M = rows_lane.shape
    d1 = cost_lane.shape[1]
    dt = cost_lane.dtype
    init_col = jnp.full((d1,), jnp.inf, dt).at[0].set(0.0)
    js = jnp.arange(M)

    def cond(c):
        t, d_rem, _ = c
        return (t >= 0) & (d_rem > 0)

    def body(c):
        t, d_rem, d_slots = c
        row = jax.lax.dynamic_slice_in_dim(rows_lane, t, 1, axis=0)[0]
        prev = jax.lax.dynamic_slice_in_dim(
            cost_lane, jnp.maximum(t - 1, 0), 1, axis=0)[0]
        prev = jnp.where(t <= t_start, init_col, prev)
        idx = d_rem - js
        vals = jnp.where(idx >= 0, row + prev[jnp.clip(idx, 0, d1 - 1)],
                         jnp.inf)
        m = jnp.min(vals)
        band = vals <= m * (1.0 + _SPLIT_TOL)
        d_here = jnp.argmax(band).astype(jnp.int32)
        return t - 1, d_rem - d_here, d_slots.at[t].set(d_here)

    _, d_left, d_slots = jax.lax.while_loop(
        cond, body,
        (jnp.clip(best_t, -1, T_pad - 1), d_tot,
         jnp.zeros((T_pad,), jnp.int32)))
    bt = jnp.clip(best_t, 0, T_pad - 1)
    col = jax.lax.dynamic_slice_in_dim(cost_lane, bt, 1, axis=0)[0]
    total_cost = col[jnp.minimum(d_tot, d1 - 1)]
    return total_cost, d_left, d_slots


@functools.partial(jax.jit, static_argnames=("wa",))
def _place_slots(sd, resbw, Wc, Zc, ts, wa: int):
    """Greedy placements for the ACTIVE slots of an accepted schedule.

    ``ts``: (wa,) i32 slot indices with a nonzero split (padded by
    repeating the last index; padding lanes carry ``Wc = 0`` and are
    discarded by the caller).  ``Wc``/``Zc``: per-slot worker / PS-target
    counts (wa,) from the decided split.  Returns (y (wa, H'), z (wa, K'))
    int32 — the same cheapest-first fills the reference ``cost_t_ref``
    greedy produces.  Each slot's fill depends only on that slot's state
    column, so gathering the active subset is bit-identical to slicing
    the whole [arrival, finish] window and discarding the idle slots."""
    g, v, wcaps, scaps, U1, U2, L1, L2 = sd
    g_w = jnp.take(g, ts, axis=0)
    v_w = jnp.take(v, ts, axis=0)
    wres, sres = resbw[:R], resbw[R:2 * R]
    p = L1 * _price_pow(jnp.maximum(U1 / L1, 1.0 + 1e-9)[None, None, :],
                        g_w / jnp.maximum(wcaps, 1e-12)[None])
    q = L2 * _price_pow(jnp.maximum(U2 / L2, 1.0 + 1e-9)[None, None, :],
                        v_w / jnp.maximum(scaps, 1e-12)[None])
    w_order, w_scap, _, w_ccap, _ = _prefix_tables_jnp(
        p, wcaps[None] - g_w, wres)
    s_order, s_scap, _, s_ccap, _ = _prefix_tables_jnp(
        q, scaps[None] - v_w, sres)
    y = _greedy_place_jnp(w_order, w_scap, w_ccap, Wc)
    pool = s_ccap[:, -1]
    deploy = jnp.minimum(jnp.minimum(Zc, Wc), pool)
    z = _greedy_place_jnp(s_order, s_scap, s_ccap, deploy)
    return y, z


# ---------------------------------------------------------------------------
# Legacy monolithic core — kept for the TPU/Pallas path (use_pallas=True)
# ---------------------------------------------------------------------------

def _decide_core(sd, jd, *, d1: int, use_pallas: bool):
    """One Alg. 2 decision, fully fused, whole horizon in one block.

    sd: state arrays (g (T,H,R), v (T,K,R), wcaps (H,R), scaps (K,R),
        U1 (R,), U2 (R,), L1 (), L2 ())
    jd: bundled job arrays (resbw (2R+2,) = [wres, sres, wbw, psbw],
        WZ (2, M) i32, u (T,), meta (3,) i32 = [a, nchunks, workload])
    d1: static — DP columns (padded D_total + 1).

    Returns (best_t i32 (-1 = reject), payoff, total_cost, d_left i32 —
    workload still unassigned after the backtrack, 0 for any sound accept —
    d_slots (T,) i32, y (T, H) i32, z (T, K) i32).
    """
    g, v, wcaps, scaps, U1, U2, L1, L2 = sd
    resbw, WZ, u, meta = jd
    wres, sres = resbw[:R], resbw[R:2 * R]
    wbw, psbw = resbw[2 * R], resbw[2 * R + 1]
    W, Z = WZ[0], WZ[1]
    a, nchunks, d_tot = meta[0], meta[1], meta[2]
    T = g.shape[0]
    M = W.shape[0]
    dt = g.dtype

    # dual prices p = L1 (U1/L1)^(g/c), q = L2 (U2/L2)^(v/c)   (eq. 22, 25)
    p = L1 * _price_pow(jnp.maximum(U1 / L1, 1.0 + 1e-9)[None, None, :],
                        g / jnp.maximum(wcaps, 1e-12)[None])
    q = L2 * _price_pow(jnp.maximum(U2 / L2, 1.0 + 1e-9)[None, None, :],
                        v / jnp.maximum(scaps, 1e-12)[None])

    w_order, w_scap, w_scost, w_ccap, w_ccost = _prefix_tables_jnp(
        p, wcaps[None] - g, wres)
    s_order, s_scap, s_scost, s_ccap, s_ccost = _prefix_tables_jnp(
        q, scaps[None] - v, sres)

    # COST_t rows for all (t, d)
    Wt = jnp.broadcast_to(W.astype(dt)[None, :], (T, M))
    w_costs = _greedy_cost_jnp(w_ccap, w_ccost, w_scost, Wt)
    pool = s_ccap[:, -1:]                                        # (T, 1)
    deploy = jnp.minimum(jnp.minimum(Z, W).astype(dt)[None, :], pool)
    feas_n = (W <= nchunks)[None, :]
    feas_ps = deploy * psbw >= Wt * wbw - 1e-9
    z_costs = _greedy_cost_jnp(s_ccap, s_ccost, s_scost, deploy)
    rows = jnp.where(feas_n & feas_ps, w_costs + z_costs, jnp.inf)
    rows = rows.at[:, 0].set(0.0)
    # slots before arrival carry the DP unchanged: row = [0, inf, ...]
    ts = jnp.arange(T, dtype=jnp.int32)
    pre = (ts[:, None] < a) & (jnp.arange(M)[None, :] > 0)
    rows = jnp.where(pre, jnp.inf, rows)

    # banded min-plus DP over slots (cost only; splits recovered below)
    if use_pallas:
        cost_tab = minplus_sweep_pallas(
            rows, d1 - 1, interpret=jax.default_backend() != "tpu")[0]
        cost_tab = cost_tab.astype(dt)
    else:
        cost_tab = minplus_sweep_cost(rows, d1 - 1)

    # payoff argmax with the reference tie rule (> best + eps switches)
    costD = jnp.take(cost_tab, d_tot, axis=1)                    # (T,)
    payoff_t = jnp.where(jnp.isfinite(costD) & (ts >= a), u - costD, -jnp.inf)

    def _pick(carry, x):
        best, best_t = carry
        pt, t = x
        switch = pt > best + _PAY_EPS
        return (jnp.where(switch, pt, best),
                jnp.where(switch, t, best_t)), None

    (best_payoff, best_t), _ = jax.lax.scan(
        _pick, (jnp.asarray(0.0, dt), jnp.int32(-1)), (payoff_t, ts))

    # backtrack from best_t down to arrival, recomputing each slot's split
    # as argmin_j rows[t, j] + cost_{t-1}[d_rem - j] over the stored table —
    # the same first-minimum the carried DP argmin would have produced
    init_row = jnp.full((d1,), jnp.inf, dt).at[0].set(0.0)
    prev_tab = jnp.concatenate([init_row[None, :], cost_tab[:-1]], axis=0)
    js = jnp.arange(M)

    def _back(d_rem, x):
        row, prev, t = x
        idx = d_rem - js
        vals = jnp.where(idx >= 0, row + prev[jnp.clip(idx, 0, d1 - 1)],
                         jnp.inf)
        d_here = jnp.where(t <= best_t,
                           jnp.argmin(vals).astype(jnp.int32), 0)
        return d_rem - d_here, d_here

    d_left, d_slots = jax.lax.scan(_back, d_tot, (rows, prev_tab, ts),
                                   reverse=True)

    # greedy placements for the chosen per-slot counts
    W_slots = jnp.take(W, d_slots)
    Z_slots = jnp.take(Z, d_slots)
    deploy_slots = jnp.minimum(jnp.minimum(Z_slots, W_slots).astype(dt),
                               pool[:, 0])
    y = _greedy_place_jnp(w_order, w_scap, w_ccap, W_slots.astype(dt))
    z = _greedy_place_jnp(s_order, s_scap, s_ccap, deploy_slots)

    total_cost = jnp.take(costD, jnp.maximum(best_t, 0))
    return best_t, best_payoff, total_cost, d_left, d_slots, y, z


@functools.partial(jax.jit, static_argnames=("d1", "use_pallas"))
def _decide_one(sd, jd, d1: int, use_pallas: bool):
    return _decide_core(sd, jd, d1=d1, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Row cache (incremental COST-row maintenance)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RowCache:
    """Per-job COST-row cache across price-state versions.

    ``rows`` holds the (T_pad, m_pad) COST_t table the engine computed
    at ``version``; ``valid`` marks which ``TILE``-slot blocks of it are
    both *visited* (actually computed, not the identity placeholder) and
    *fresh* (no commit/release has moved prices inside them since).  The
    engine recomputes exactly the invalid tiles (``use_cache`` path of
    ``_decide_tiled``); :meth:`sync` invalidates against the price
    state's dirty-slot log (``PriceState.dirty_spans_since``).

    ``tables`` is the job's sorted-order/cumsum table set (6 arrays
    (T_pad, H|K) from ``_sorted_fill_lanes``) at ``tables_version``.  It
    is NOT maintained by :meth:`sync`: ``_decide_jobs`` patches exactly
    the slots ``PriceState.patch_spans(tables_version)`` reports dirty
    (``_sorted_fill``) right before each launch, so re-solves pay an
    O(dirty) sort bill instead of re-sorting the horizon."""
    rows: Optional[jax.Array]       # (T_pad, m_pad) device-resident
    valid: np.ndarray               # (n_tiles,) bool, host
    version: int
    m_pad: int
    d1: int
    # 6 x (T_pad, S) device-resident, or a lazy ``_LaneTabs`` view into
    # the stacked launch build (materialized via ``_tabs_get`` on reuse)
    tables: Optional[object] = None
    tables_version: int = -1

    @classmethod
    def empty(cls, state: PriceState, job: Job) -> Optional["RowCache"]:
        """A cache with no valid tiles (first decision fills it).  None
        for dcap-0 jobs (the engine rejects those without solving)."""
        key = _shape_bucket(job)
        if key is None:
            return None
        m_pad, d1 = key
        n_tiles = _pad_tiles(state.horizon) // TILE
        return cls(rows=None, valid=np.zeros(n_tiles, bool),
                   version=state.version, m_pad=m_pad, d1=d1)

    def invalidate_spans(self, spans) -> None:
        """Mark every tile overlapping a dirtied [t0, t1) slot span stale."""
        for t0, t1 in spans:
            k0 = max(int(t0) // TILE, 0)
            k1 = min((int(t1) - 1) // TILE + 1, len(self.valid))
            self.valid[k0:k1] = False

    def invalidate_all(self) -> None:
        self.valid[:] = False

    def sync(self, state: PriceState) -> "RowCache":
        """Invalidate whatever ``state`` has dirtied since ``version``.

        Uses the commit/release dirty-slot log; an unknown delta (window
        slide, log trimmed) invalidates everything.  Returns self."""
        if state.version != self.version:
            spans = state.dirty_spans_since(self.version)
            if spans is None:
                self.invalidate_all()
                if _obs.ENABLED:
                    _obs.inc("decide.row_cache_full_invalidations")
            else:
                self.invalidate_spans(spans)
            self.version = state.version
            if _obs.ENABLED:
                _obs.inc("decide.row_cache_syncs")
        return self


# ---------------------------------------------------------------------------
# Python wrappers: padding, bucketing, Schedule construction
# ---------------------------------------------------------------------------

def _state_arrays(state: PriceState, dtype):
    """Engine view of the price state: the device-resident allocation
    tensors plus static caps/params (``PriceState.device_state``).

    The first call per state uploads the full tensors once; afterwards
    ``commit``/``release`` maintain the residency with streamed slot-window
    adds, so a sequential simulation performs O(1) full uploads instead of
    re-uploading (T,H,R)+(T,K,R) after every accepted job."""
    return state.device_state(dtype)


def _price_tables(g, v, wcaps, scaps, U1, U2, L1, L2):
    """Job-independent dual price tables p (T', H, R), q (T', K, R) —
    the exact per-tile formula ``rows_for_tile`` used to evaluate inline
    (same elementwise ops, so slices of these are bit-identical)."""
    ratio1 = jnp.maximum(U1 / L1, 1.0 + 1e-9)
    ratio2 = jnp.maximum(U2 / L2, 1.0 + 1e-9)
    p = L1 * _price_pow(ratio1[None, None, :],
                        g / jnp.maximum(wcaps, 1e-12)[None])
    q = L2 * _price_pow(ratio2[None, None, :],
                        v / jnp.maximum(scaps, 1e-12)[None])
    return p, q


@functools.partial(jax.jit, static_argnames=("T_pad",))
def _pad_state(g, v, wcaps, scaps, U1, U2, L1, L2, T_pad: int):
    """Tile-pad the allocation tensors and precompute everything about
    the state the decide launch re-derived per tile: the live-floor
    minimum worker price ``pmin`` (module docstring: every deployed
    worker in slot s costs >= sum_r wres_r * min_h p[s,h,r]; with
    ratio >= 1, min_h ratio^(g/c) == ratio^(min_h g/c), so the floor
    needs only (T_pad, R) pows) and the full job-independent price
    tables ``p``/``q`` — the exp/log transcendentals that used to
    dominate the row-build stage now run once per state version instead
    of once per visited tile per decision."""
    T = g.shape[0]
    g = jnp.pad(g, ((0, T_pad - T), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, T_pad - T), (0, 0), (0, 0)))
    ratio1 = jnp.maximum(U1 / L1, 1.0 + 1e-9)
    umin = jnp.min(g / jnp.maximum(wcaps, 1e-12)[None], axis=1)
    pmin = L1 * _price_pow(ratio1[None, :], umin)
    p, q = _price_tables(g, v, wcaps, scaps, U1, U2, L1, L2)
    return g, v, pmin, p, q


@functools.partial(jax.jit, static_argnames=("span",))
def _pad_patch(g_pad, v_pad, pmin, p_pad, q_pad, g, v, wcaps, scaps,
               U1, U2, L1, L2, t0, span: int):
    """Refresh one dirty slot span of the padded-state cache in place:
    re-slice ``g``/``v`` and recompute the ``pmin`` floor and price-table
    rows with the exact ``_pad_state`` formulas, so the patched tensors
    are bit-identical to a from-scratch pad at the new state version."""
    zero = jnp.zeros_like(t0)
    g_s = jax.lax.dynamic_slice(g, (t0, zero, zero), (span,) + g.shape[1:])
    v_s = jax.lax.dynamic_slice(v, (t0, zero, zero), (span,) + v.shape[1:])
    ratio1 = jnp.maximum(U1 / L1, 1.0 + 1e-9)
    umin = jnp.min(g_s / jnp.maximum(wcaps, 1e-12)[None], axis=1)
    pmin_s = L1 * _price_pow(ratio1[None, :], umin)
    p_s, q_s = _price_tables(g_s, v_s, wcaps, scaps, U1, U2, L1, L2)
    g_pad = jax.lax.dynamic_update_slice(g_pad, g_s, (t0, zero, zero))
    v_pad = jax.lax.dynamic_update_slice(v_pad, v_s, (t0, zero, zero))
    pmin = jax.lax.dynamic_update_slice(pmin, pmin_s, (t0, zero))
    p_pad = jax.lax.dynamic_update_slice(p_pad, p_s, (t0, zero, zero))
    q_pad = jax.lax.dynamic_update_slice(q_pad, q_s, (t0, zero, zero))
    return g_pad, v_pad, pmin, p_pad, q_pad


_pad_cache: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

# full-repad fallback threshold: more dirty spans than this and the
# span-by-span patching would launch more kernels than one full pad
_PATCH_MAX_SPANS = 8


def _padded_state(state: PriceState, dtype, T_pad: int):
    """``_state_arrays`` extended with the decide core's per-launch
    prologue — tile padding + the live-floor price ``pmin`` — computed
    once per (state version, dtype) and reused across every decision
    launch until the next commit/release, instead of inside each one.

    Between consecutive versions the cache is patched incrementally:
    ``PriceState.dirty_spans_since`` names the slots the commits touched
    and ``_pad_patch`` refreshes just those rows (the same maintenance
    contract ``RowCache`` uses).  Falls back to a full re-pad when the
    delta is unknowable or fragmented."""
    g, v, wcaps, scaps, U1, U2, L1, L2 = _state_arrays(state, dtype)
    key = (state.version, T_pad, jnp.dtype(dtype).name)
    hit = _pad_cache.get(state)
    if hit is not None and hit[0] == key:
        if _obs.ENABLED:
            _obs.inc("decide.pad_hit")
        return hit[1]
    T = g.shape[0]
    if hit is not None and hit[0][1:] == key[1:]:
        spans = state.dirty_spans_since(hit[0][0])
        if spans is not None and len(spans) <= _PATCH_MAX_SPANS:
            g_pad, v_pad, pmin = hit[1][0], hit[1][1], hit[1][8]
            p_pad, q_pad = hit[1][9], hit[1][10]
            for s0, s1 in spans:
                span = _bucket(max(s1 - s0, 1), floor=8, step=64)
                if span > T:
                    break
                start = min(max(int(s0), 0), T - span)
                g_pad, v_pad, pmin, p_pad, q_pad = _pad_patch(
                    g_pad, v_pad, pmin, p_pad, q_pad, g, v, wcaps, scaps,
                    U1, U2, L1, L2, jnp.int32(start), span)
            else:
                hit = (key, (g_pad, v_pad, wcaps, scaps, U1, U2, L1, L2,
                             pmin, p_pad, q_pad))
                _pad_cache[state] = hit
                if _obs.ENABLED:
                    _obs.inc("decide.pad_patch")
                return hit[1]
    if _obs.ENABLED:
        _obs.inc("decide.pad_full")
    g_pad, v_pad, pmin, p_pad, q_pad = _pad_state(
        g, v, wcaps, scaps, U1, U2, L1, L2, T_pad=T_pad)
    hit = (key, (g_pad, v_pad, wcaps, scaps, U1, U2, L1, L2, pmin,
                 p_pad, q_pad))
    _pad_cache[state] = hit
    return hit[1]


def _pad_tiles(T: int) -> int:
    return ((T + TILE - 1) // TILE) * TILE


def _utility_curve(job: Job, T: int, T_pad: int) -> np.ndarray:
    u = np.zeros(T_pad)
    a = job.arrival
    u[a:T] = [job.utility(t - a) for t in range(a, T)]
    return u


def _cost_lower_bound(job: Job, state: PriceState, W: np.ndarray) -> float:
    """Price-free per-chunk-pass base of the cost lower bound:
    min_d W(d)/d.

    Any split's worker-slots for d chunk-passes in one slot is
    >= d * min_d W(d)/d, so ANY schedule's cost is >= this base times a
    workload-weighted sum of live per-slot price floors — the device
    side of ``_decide_tiled_core`` multiplies in a greedy spread over
    the cheapest feasible slots (each capped at dcap chunk-passes),
    which is >= the old single-cheapest-slot floor and reduces to it
    when one slot can hold the whole workload.  Scaled by ``_LB_MARGIN``
    so engine float64 rounding stays above the bound."""
    if len(W) < 2:
        return 0.0
    per_unit = float(np.min(W[1:] / np.arange(1, len(W), dtype=np.float64)))
    return _LB_MARGIN * per_unit


def _job_arrays_tiled(job: Job, state: PriceState, T: int, T_pad: int,
                      m_pad: int, dtype):
    """Lane arrays for the tiled core.  Padded d entries get a sentinel
    worker count larger than any N so they are infeasible."""
    from .subroutine import workload_tables
    dcap = min(job.max_chunks_per_slot, job.workload)
    W, Z = workload_tables(job, dcap)
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    WZ[0, :dcap + 1] = W
    WZ[1, :dcap + 1] = Z
    u = _utility_curve(job, T, T_pad)
    usmax = np.maximum.accumulate(u[::-1])[::-1].copy()
    lb = _cost_lower_bound(job, state, W)
    resbw = np.concatenate([job.worker_res, job.ps_res,
                            [job.worker_bw, job.ps_bw]])
    meta = np.array([job.arrival, job.num_chunks, job.workload, dcap],
                    np.int32)
    return (resbw.astype(np.float64), WZ, u, usmax, meta, np.float64(lb)), (W, Z)


def _reject_lane(T: int, T_pad: int, m_pad: int):
    """A batch-padding dummy: infeasible everywhere (nchunks = -1), arrival
    at T so it never drags the start tile down, zero utility so it never
    keeps the early-exit loop alive."""
    resbw = np.zeros(2 * R + 2)
    resbw[-2:] = 1.0
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    meta = np.array([T, -1, 1, 1], np.int32)
    z = np.zeros(T_pad)
    return (resbw, WZ, z, z, meta, np.float64(0.0)), (WZ[0, :1], WZ[1, :1])


def _stack_lanes(lanes, dtype):
    cols = list(zip(*lanes))
    return (jnp.asarray(np.stack(cols[0]), dtype),      # resbw
            jnp.asarray(np.stack(cols[1])),             # WZ
            jnp.asarray(np.stack(cols[2]), dtype),      # u
            jnp.asarray(np.stack(cols[3]), dtype),      # usmax
            jnp.asarray(np.stack(cols[4])),             # meta
            jnp.asarray(np.stack(cols[5]), dtype))      # lb


def _job_arrays(job: Job, T: int, m_pad: int, dtype):
    """Legacy bundling for the monolithic (Pallas) core."""
    from .subroutine import workload_tables
    dcap = min(job.max_chunks_per_slot, job.workload)
    W, Z = workload_tables(job, dcap)
    WZ = np.zeros((2, m_pad), np.int32)
    WZ[0] = np.int32(1) << 30
    WZ[0, :dcap + 1] = W
    WZ[1, :dcap + 1] = Z
    a = job.arrival
    u = np.array([job.utility(t - a) if t >= a else 0.0 for t in range(T)])
    resbw = np.concatenate([job.worker_res, job.ps_res,
                            [job.worker_bw, job.ps_bw]])
    meta = np.array([a, job.num_chunks, job.workload], np.int32)
    return (jnp.asarray(resbw, dtype), jnp.asarray(WZ), jnp.asarray(u, dtype),
            jnp.asarray(meta))


def _x64_context(precision: str):
    """Engine precision policy.  "auto": float64 on CPU (exact agreement with
    the numpy paths), float32 on TPU.  An ambient jax_enable_x64 always wins.
    """
    import contextlib
    from jax.experimental import enable_x64
    if precision == "x64" or (precision == "auto"
                              and jax.default_backend() == "cpu"):
        # already-enabled is a no-op: entering enable_x64 flips the
        # thread-local config even when the value is unchanged, and every
        # flip knocks jit calls off the C fast path (~ms of python
        # dispatch per call).  The sim drivers hold one enable_x64 open
        # across the whole run so per-decision entries land here.
        if jax.config.jax_enable_x64:
            return contextlib.nullcontext()
        return enable_x64(True)
    return contextlib.nullcontext()


@dataclasses.dataclass
class _Pending:
    """A decided-but-unplaced candidate from the tiled core.

    Holds the launch's device-resident row/cost tables (shared across the
    lanes of one launch) so the split backtrack — and the placement — run
    only if the candidate is actually accepted AND survives the commit
    pass.  Rejects never pay for either."""
    job: Job
    best_t: int
    payoff: float
    rows_full: jax.Array            # (B, T_pad, M) device, shared
    cost_full: jax.Array            # (B, T_pad, d1) device, shared
    lane: int                       # this job's lane in the launch
    t_start: int                    # first slot the decision loop visited
    W: np.ndarray                   # (dcap+1,) workload tables
    Z: np.ndarray
    cache: RowCache
    cost: float = float("nan")      # filled by _materialize for accepts


def _materialize(pend: _Pending, state: PriceState, sd, dtype
                 ) -> Optional[Schedule]:
    """Extract the split + placement for an accepted candidate (None =
    reject).

    Runs ``_backtrack`` over the stored lane tables and ``_place_slots``
    over just the deploying slots — MUST be called at the same price
    state the decision was made at."""
    job, best_t = pend.job, pend.best_t
    if best_t < 0:
        return None
    profiling = _profiling()
    if profiling:
        t_bt = time.perf_counter()
    with _obs.span("decide.backtrack", jid=job.jid):
        total_cost, d_left, d_slots = jax.device_get(_backtrack(
            pend.rows_full[pend.lane], pend.cost_full[pend.lane],
            jnp.int32(best_t), jnp.int32(job.workload),
            jnp.int32(pend.t_start)))
    if profiling:
        _profile_acc["backtrack"] += time.perf_counter() - t_bt
    pend.cost = float(total_cost)
    # mirrors _extract's backtrack assert: an accepted schedule must place
    # the whole workload (guards e.g. mixed-precision runs)
    assert int(d_left) == 0, \
        f"fused backtrack failed: {int(d_left)} chunk-passes unassigned"
    a = job.arrival
    # place only the slots that actually deploy (typically well under
    # half the [arrival, finish] window): each slot's greedy fill reads
    # its own state column only, so the gather changes nothing bit-wise
    ts_active = np.nonzero(d_slots[a:best_t + 1])[0] + a
    if len(ts_active) == 0:        # degenerate zero-workload accept
        utility = job.utility(best_t - a)
        return Schedule(jid=job.jid, workers={}, ps={}, finish=int(best_t),
                        cost=float(pend.cost),
                        payoff=utility - float(pend.cost), utility=utility)
    wa = _bucket(len(ts_active), floor=8, step=32)
    ts = np.full(wa, ts_active[-1], np.int32)
    ts[:len(ts_active)] = ts_active
    d_act = np.zeros(wa, d_slots.dtype)
    d_act[:len(ts_active)] = d_slots[ts_active]
    Wc = pend.W[d_act].astype(np.float64)
    Zc = pend.Z[d_act].astype(np.float64)
    Wc[len(ts_active):] = 0.0
    Zc[len(ts_active):] = 0.0
    if profiling:
        t_pl = time.perf_counter()
    with _obs.span("decide.placement", jid=job.jid, slots=len(ts_active)):
        y, z = jax.device_get(_place_slots(sd, jnp.asarray(
            np.concatenate([job.worker_res, job.ps_res,
                            [job.worker_bw, job.ps_bw]]), dtype),
            jnp.asarray(Wc, dtype), jnp.asarray(Zc, dtype),
            jnp.asarray(ts), wa))
    if profiling:
        _profile_acc["placement"] += time.perf_counter() - t_pl
    H, K = state.cluster.H, state.cluster.K
    workers, ps = {}, {}
    for k, t in enumerate(ts_active):
        workers[int(t)] = y[k, :H].astype(np.int64)
        ps[int(t)] = z[k, :K].astype(np.int64)
    utility = job.utility(best_t - a)
    return Schedule(jid=job.jid, workers=workers, ps=ps, finish=int(best_t),
                    cost=float(pend.cost), payoff=utility - float(pend.cost),
                    utility=utility)


def _schedule_from_outputs(job: Job, state: PriceState, best_t: int,
                           cost: float, d_left: int, d_slots: np.ndarray,
                           y: np.ndarray, z: np.ndarray
                           ) -> Optional[Schedule]:
    """Schedule assembly for the legacy monolithic core's outputs."""
    if best_t < 0:
        return None
    assert d_left == 0, \
        f"fused backtrack failed: {d_left} chunk-passes unassigned"
    H, K = state.cluster.H, state.cluster.K
    workers, ps = {}, {}
    for t in range(job.arrival, best_t + 1):
        if d_slots[t] > 0:
            workers[t] = y[t, :H].astype(np.int64)
            ps[t] = z[t, :K].astype(np.int64)
    utility = job.utility(best_t - job.arrival)
    return Schedule(jid=job.jid, workers=workers, ps=ps, finish=int(best_t),
                    cost=float(cost), payoff=utility - float(cost),
                    utility=utility)


@functools.lru_cache(maxsize=32)
def _empty_cache(b_pad: int, T_pad: int, n_tiles: int, m_pad: int,
                 dtype_name: str):
    """Device-resident all-invalid row cache, one per launch shape: lets
    the cache-less decision path run the ``use_cache=True`` compiled
    variant without uploading a fresh buffer per launch."""
    rows0 = np.zeros((b_pad, T_pad, m_pad))
    rows0[:, :, 1:] = np.inf
    return (jnp.asarray(rows0, jnp.dtype(dtype_name)),
            jnp.zeros((b_pad, n_tiles), bool))


# per-branch processed-tile totals across decide launches (the fallback
# counter of the monotone dispatch; see monotone_counters_snapshot)
_monotone_counters = {"dnc": 0, "plateau": 0, "chain": 0}

# (b_pad, T_pad, m_pad, d1, mono, use_tabs, dtype) tuples already
# launched this process: a first sighting means XLA is about to compile
# a new variant, surfaced as a ``jit_cold_compile`` trace event
_launch_keys_seen: set = set()


def monotone_counters_reset() -> None:
    for k in _monotone_counters:
        _monotone_counters[k] = 0


def monotone_counters_snapshot() -> dict:
    """Tiles processed per min-plus branch since the last reset: ``dnc``
    (divide-and-conquer row-minima), ``plateau`` (staircase run
    compression), ``chain`` (quadratic banded fallback).  All three are
    bit-identical; the split records how often the monotone paths fired
    vs fell back."""
    return dict(_monotone_counters)


class _LaneTabs:
    """Deferred per-lane view into a stacked table set.

    A fresh ``_sorted_fill_lanes`` launch returns six ``(B, T_pad, S)``
    arrays; slicing every lane's 6-tuple out of them eagerly costs six
    device ``__getitem__`` dispatches per lane, and in the streaming
    engine nearly every launch is fresh while the slices are consumed
    only if that job is later re-solved.  This holds (stack, lane) and
    materializes the 6-tuple on first :meth:`get`."""

    __slots__ = ("stack", "lane", "_tabs")

    def __init__(self, stack: tuple, lane: int):
        self.stack = stack
        self.lane = lane
        self._tabs: Optional[tuple] = None

    def get(self) -> tuple:
        if self._tabs is None:
            bi = self.lane
            self._tabs = tuple(t[bi] for t in self.stack)
            self.stack = None
        return self._tabs


def _tabs_get(tabs) -> tuple:
    """Materialize a RowCache ``tables`` entry (concrete or _LaneTabs)."""
    return tabs.get() if isinstance(tabs, _LaneTabs) else tabs


def _lane_tables(chunk, caches, state, psd, lanes, b_pad, T, dtype):
    """Sorted-order/cumsum tables for every lane of one launch.

    Serves each lane from its RowCache when fresh, patches it through
    ``_sorted_fill`` when ``PriceState.patch_spans`` can name the dirty
    slots (O(dirty) sort cost on the re-solve path), and rebuilds from
    the cached price tables otherwise (one fused ``_sorted_fill_lanes``
    launch).  Tables only exist at all below the ``_table_max``
    footprint gate — above it the launch keeps the inline per-tile path
    and this returns dummies.  Returns (tabs — 6 launch operands,
    lane_tabs — per-lane entries (6-tuple, ``_LaneTabs``, or None) for
    cache write-back, use_tabs — whether the launch slices ``tabs``)."""
    g_pad, v_pad, wcaps, scaps = psd[0], psd[1], psd[2], psd[3]
    p_pad, q_pad = psd[9], psd[10]
    T_pad = g_pad.shape[0]
    if T_pad * max(g_pad.shape[1], v_pad.shape[1]) > _table_max():
        return _dummy_tabs(jnp.dtype(dtype).name), [None] * b_pad, False
    lane_tabs: List[Optional[object]] = [None] * b_pad
    for bi, (i, _) in enumerate(chunk):
        cache = caches.get(i) if caches else None
        if cache is None or cache.tables is None:
            continue
        if cache.tables_version == state.version:
            lane_tabs[bi] = cache.tables
            continue
        spans = state.patch_spans(cache.tables_version,
                                  limit=_PATCH_MAX_SPANS)
        if spans is None:
            continue
        tabs_l = _tabs_get(cache.tables)
        resbw = jnp.asarray(lanes[bi][0], dtype)
        for s0, s1 in spans:
            span = _bucket(max(s1 - s0, 1), floor=8, step=64)
            if span > T:
                tabs_l = None
                break
            start = min(max(int(s0), 0), T - span)
            tabs_l = _sorted_fill(tabs_l, p_pad, q_pad, g_pad, v_pad,
                                  wcaps, scaps, resbw, jnp.int32(start),
                                  span)
        lane_tabs[bi] = tabs_l
    if all(t is None for t in lane_tabs):
        resbw_all = jnp.asarray(np.stack([la[0] for la in lanes]), dtype)
        full = _sorted_fill_lanes(p_pad, q_pad, g_pad, v_pad, wcaps,
                                  scaps, resbw_all)
        return full, [_LaneTabs(full, bi) for bi in range(b_pad)], True
    for bi in range(b_pad):
        if lane_tabs[bi] is None:
            resbw = jnp.asarray(lanes[bi][0], dtype)
            one = _sorted_fill_lanes(p_pad, q_pad, g_pad, v_pad, wcaps,
                                     scaps, resbw[None])
            lane_tabs[bi] = _LaneTabs(one, 0)
    if b_pad == 1:
        lt = lane_tabs[0]
        if isinstance(lt, _LaneTabs) and lt.stack is not None \
                and lt.lane == 0 and lt.stack[0].shape[0] == 1:
            tabs = lt.stack       # reuse the stacked build directly
        else:
            tabs = tuple(t[None] for t in _tabs_get(lt))
    else:
        mats = [_tabs_get(lt) for lt in lane_tabs]
        tabs = tuple(jnp.stack([m[k] for m in mats]) for k in range(6))
    return tabs, lane_tabs, True


def _decide_jobs(jobs: Sequence[Tuple[int, Job]], state: PriceState, dtype,
                 m_pad: int, d1: int,
                 caches: Optional[dict] = None) -> List[_Pending]:
    """Run the tiled core over one shape-bucket group (<= _MAX_LANES jobs
    per launch).  ``caches``: optional {index: RowCache} serving lanes."""
    T = state.horizon
    T_pad = _pad_tiles(T)
    n_tiles = T_pad // TILE
    psd = _padded_state(state, dtype, T_pad)
    sd = psd
    out: List[_Pending] = []
    for c0 in range(0, len(jobs), _MAX_LANES):
        chunk = jobs[c0:c0 + _MAX_LANES]
        b_pad = _bucket(len(chunk), floor=1, step=_MAX_LANES)
        lanes, tables = [], []
        for _, j in chunk:
            la, wz = _job_arrays_tiled(j, state, T, T_pad, m_pad, dtype)
            lanes.append(la)
            tables.append(wz)
        for _ in range(b_pad - len(chunk)):
            la, wz = _reject_lane(T, T_pad, m_pad)
            lanes.append(la)
            tables.append(wz)
        jd = _stack_lanes(lanes, dtype)
        # the no-cache case runs the SAME compiled variant with an
        # all-invalid (device-cached) empty cache: every distinct
        # (shape, use_cache) pair is a separate multi-second XLA
        # compilation, and the cond-per-tile overhead of the cached
        # variant is microseconds
        use_cache = caches is not None and any(
            caches.get(i) is not None for i, _ in chunk)
        if use_cache:
            rows0 = np.zeros((b_pad, T_pad, m_pad))
            rows0[:, :, 1:] = np.inf
            valid0 = np.zeros((b_pad, n_tiles), bool)
            rows_list = [None] * b_pad
            for bi, (i, _) in enumerate(chunk):
                cache = caches.get(i)
                if cache is not None and cache.rows is not None:
                    rows_list[bi] = cache.rows
                    valid0[bi] = cache.valid
            base = jnp.asarray(rows0, dtype)
            stackable = [rows_list[bi] if rows_list[bi] is not None
                         else base[bi] for bi in range(b_pad)]
            rows_init = jnp.stack(stackable)
            valid_tiles = jnp.asarray(valid0)
            if _obs.ENABLED:
                _obs.inc("decide.cache_tiles_valid",
                         int(valid0[:len(chunk)].sum()))
                _obs.inc("decide.cache_tiles_total", len(chunk) * n_tiles)
        else:
            # cache-less launches stay out of the cache_tiles_* counters:
            # the tracked hit rate measures how much of a RE-SOLVE the
            # row cache saved, not how often the cache path ran at all
            rows_init, valid_tiles = _empty_cache(
                b_pad, T_pad, n_tiles, m_pad, jnp.dtype(dtype).name)
        profiling = _profiling()
        if profiling:
            jax.block_until_ready((psd, jd, rows_init, valid_tiles))
            t_tabs = time.perf_counter()
        tabs, lane_tabs, use_tabs = _lane_tables(chunk, caches, state,
                                                 psd, lanes, b_pad, T,
                                                 dtype)
        if profiling:
            jax.block_until_ready(tabs)
            t_launch = time.perf_counter()
            _profile_acc["row_build"] += t_launch - t_tabs
        mono = 0
        if b_pad == 1 and m_pad <= _mono_band():
            mono = 2 if _mono_dnc() else 1
        launch_key = (b_pad, T_pad, m_pad, d1, mono, use_tabs,
                      jnp.dtype(dtype).name)
        if launch_key not in _launch_keys_seen:
            _launch_keys_seen.add(launch_key)
            if _obs.ENABLED:
                _obs.inc("decide.jit_cold_launches")
                _obs.event("jit_cold_compile", b_pad=b_pad, T_pad=T_pad,
                           m_pad=m_pad, d1=d1, mono=mono,
                           use_tabs=use_tabs)
        dp_span = _obs.span("decide.dp_sweep", lanes=len(chunk),
                            T_pad=T_pad, m_pad=m_pad)
        dp_span.__enter__()
        best_t, payoff, rows_buf, cost_buf, k0, k_end, paths = \
            _decide_tiled(sd, jd, tabs, rows_init, valid_tiles, T=T,
                          d1=d1, use_cache=True, mono=mono,
                          use_tabs=use_tabs)
        if profiling:
            jax.block_until_ready((best_t, rows_buf, cost_buf))
            total = time.perf_counter() - t_launch
            # DP-only re-run: every tile served from the row cache the
            # first launch just refreshed.  The early-exit loop visits
            # the same tiles (same carries), so the delta is the row
            # build.  See ``decide_profile_snapshot``.
            t_dp = time.perf_counter()
            jax.block_until_ready(_decide_tiled(
                sd, jd, tabs, rows_buf, jnp.ones_like(valid_tiles), T=T,
                d1=d1, use_cache=True, mono=mono, use_tabs=use_tabs)[:4])
            dp_only = time.perf_counter() - t_dp
            _profile_acc["dp_sweep"] += dp_only
            _profile_acc["row_build"] += max(total - dp_only, 0.0)
            _profile_acc["decisions"] += len(chunk)
        best_t, payoff, k0, k_end, pth = jax.device_get(
            (best_t, payoff, k0, k_end, paths))
        k0, k_end = int(k0), int(k_end)
        dp_span.set(tiles_visited=k_end - k0, n_tiles=n_tiles)
        dp_span.__exit__(None, None, None)
        if _obs.ENABLED:
            _obs.inc("decide.launches")
            _obs.inc("decide.tiles_visited", k_end - k0)
            _obs.inc("decide.tiles_horizon", n_tiles)
            _obs.observe("decide.early_exit_frac",
                         (k_end - k0) / max(n_tiles, 1))
        _monotone_counters["dnc"] += int(pth[0])
        _monotone_counters["plateau"] += int(pth[1])
        _monotone_counters["chain"] += int(pth[2])
        for bi, (i, job) in enumerate(chunk):
            valid = np.zeros(n_tiles, bool)
            if use_cache and caches.get(i) is not None:
                valid |= caches[i].valid
            valid[k0:k_end] = True
            cache = RowCache(rows=rows_buf[bi], valid=valid,
                             version=state.version, m_pad=m_pad, d1=d1,
                             tables=lane_tabs[bi],
                             tables_version=(state.version
                                             if lane_tabs[bi] is not None
                                             else -1))
            out.append(_Pending(
                job=job, best_t=int(best_t[bi]), payoff=float(payoff[bi]),
                rows_full=rows_buf, cost_full=cost_buf, lane=bi,
                t_start=k0 * TILE, W=tables[bi][0], Z=tables[bi][1],
                cache=cache))
    return out


def _pow2_bucket(n: int, floor: int) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


def _band_bucket(n: int) -> int:
    """Band-width (m_pad) compile bucket: 64, 128, then multiples of 128.

    The DP slot scan is O(m_pad) per column, so the old power-of-two
    buckets made a dcap-296 job sweep a 512-wide band — 1.7x the work —
    where 384 suffices.  Padded columns carry the infeasible sentinel
    (W = 2^30 -> +inf rows), so narrowing the pad only removes all-inf
    min-plus candidates and DP values are bit-identical across buckets.
    128-steps above 128 keep the bucket count (and XLA compile count) as
    coarse as the pow2 scheme at the shapes the benchmarks see."""
    if n <= 64:
        return 64
    if n <= 128:
        return 128
    return ((n + 127) // 128) * 128


def _shape_bucket(job: Job) -> Optional[Tuple[int, int]]:
    """Padded (m_pad, d1) compile bucket for a job's DP tables.

    Deliberately coarse — band buckets with high floors — because every
    distinct (m_pad, d1, lanes) triple is a separate XLA compilation of
    the decision loop, and compile time dominates wall clock at scale.
    The d1 floor covers the auto-quantized workload range (engine quantum
    targets <= 1200 chunk-passes) so scale runs see a SINGLE d1."""
    dcap = min(job.max_chunks_per_slot, job.workload)
    if dcap == 0:
        return None
    return (_band_bucket(dcap + 1), _pow2_bucket(job.workload + 1, 1280))


def best_schedule_fused(job: Job, state: PriceState, *,
                        use_pallas: Optional[bool] = None,
                        precision: str = "auto",
                        row_cache: Optional[RowCache] = None
                        ) -> Optional[Schedule]:
    """Alg. 2 for one job through the fused jit engine.

    The default path is the tiled early-exit core; ``row_cache`` (from a
    previous decision for the SAME job, ``sync``-ed against the state)
    lets it recompute only dirtied tiles.  ``use_pallas=True`` routes
    through the legacy monolithic core with the Pallas sweep kernel (the
    TPU path)."""
    key = _shape_bucket(job)
    if key is None:
        return None
    m_pad, d1 = key
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    T = state.horizon      # window-local lookahead (== cluster.T episodic)
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        if use_pallas:
            sd = _state_arrays(state, dtype)
            jd = _job_arrays(job, T, m_pad, dtype)
            best_t, _, cost, d_left, d_slots, y, z = _decide_one(
                sd, jd, d1=d1, use_pallas=True)
            return _schedule_from_outputs(
                job, state, int(best_t), float(cost), int(d_left),
                np.asarray(d_slots), np.asarray(y), np.asarray(z))
        caches = {0: row_cache} if row_cache is not None else None
        pend = _decide_jobs([(0, job)], state, dtype, m_pad, d1,
                            caches=caches)[0]
        if row_cache is not None:
            row_cache.rows = pend.cache.rows
            row_cache.valid = pend.cache.valid
            row_cache.version = pend.cache.version
            row_cache.tables = pend.cache.tables
            row_cache.tables_version = pend.cache.tables_version
        sd = _state_arrays(state, dtype)
        return _materialize(pend, state, sd, dtype)


def decide_burst(jobs: Sequence[Job], state: PriceState, *,
                 precision: str = "auto",
                 timings: Optional[List[float]] = None) -> List[_Pending]:
    """Speculative batched Alg. 2: the whole burst decided at the CURRENT
    prices, one tiled launch per shape bucket (jobs are grouped by
    (dcap, workload) bucket so a small job is never padded up to the
    burst's largest DP table).  Returns per-job ``_Pending`` candidates —
    decision + split + row cache, placement deferred to
    ``_materialize`` — in input order (None for dcap-0 jobs).  Commit
    order / price updates are the caller's job (``OASiS.on_arrivals``
    re-solves any job whose prices moved).

    ``timings``, when given, is filled in place with each job's share of
    its own shape group's wall time."""
    out: List[Optional[_Pending]] = [None] * len(jobs)
    if timings is not None:
        timings[:] = [0.0] * len(jobs)
    groups = {}
    for i, j in enumerate(jobs):
        key = _shape_bucket(j)
        if key is None:
            continue
        groups.setdefault(key, []).append((i, j))
    if not groups:
        return out
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        for (m_pad, d1), live in groups.items():
            t0 = time.perf_counter()
            pends = _decide_jobs(live, state, dtype, m_pad, d1)
            for (i, _), pend in zip(live, pends):
                out[i] = pend
            if timings is not None:
                share = (time.perf_counter() - t0) / len(live)
                for i, _ in live:
                    timings[i] = share
    return out


def best_schedule_fused_batch(jobs: Sequence[Job], state: PriceState, *,
                              precision: str = "auto",
                              timings: Optional[List[float]] = None
                              ) -> List[Optional[Schedule]]:
    """Speculative batched Alg. 2 with placements materialized for every
    accepted candidate (all at the CURRENT prices — the caller must not
    commit between the call and using the results)."""
    pends = decide_burst(jobs, state, precision=precision, timings=timings)
    out: List[Optional[Schedule]] = [None] * len(jobs)
    with _x64_context(precision):
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        sd = _state_arrays(state, dtype)
        for i, pend in enumerate(pends):
            if pend is not None:
                out[i] = _materialize(pend, state, sd, dtype)
    return out
