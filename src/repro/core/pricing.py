"""Dual price functions (paper eq. (22)-(26)) and mutable price state.

Prices are maintained per (slot t, server, resource r):

    p_h^r(t) = L1 * (U1^r / L1) ** (g_h^r(t) / c_h^r)        (workers pool)
    q_k^r(t) = L2 * (U2^r / L2) ** (v_k^r(t) / c_k^r)        (PS pool)

``U`` bounds are the max per-unit-resource utility over jobs, ``L`` the
min unit-time-unit-resource utility scaled by 1/(4*eta).  In the online
setting the exact values need future knowledge, so the operator supplies
*estimates* (benchmarks/fig6 sweeps their accuracy).

``PriceState`` keeps the allocation tensors in two representations:

* a **host mirror** (numpy float64) — the source of truth for the numpy
  backends (``ref``/``fast``/``loop``) and for all read access via the
  ``g``/``v`` properties; always kept in sync by ``commit``/``release``
  with the same IEEE ops the pre-device implementation used, so the
  equivalence suites pin identical semantics;
* a **device residency** (jax arrays), materialised lazily on the first
  ``device_state()`` call (one full host→device upload, counted in
  ``device_uploads``) and then maintained *incrementally*: each
  ``commit``/``release`` streams only the committed slot window to the
  device and applies it with a jit-compiled dense window add (buffers
  donated off-CPU).  The fused jax engine reads prices directly from
  this resident state, so a long simulation performs O(1) full-state
  uploads instead of one per accepted job.

Reading the ``g``/``v`` properties hands out the mutable host arrays, so
it conservatively drops the device residency (the caller may write); the
jax hot path never touches them — it goes through ``device_state``,
``capacity_ok`` and ``gpu_slot_usage`` instead.

**Rolling horizon (continuous serving mode).**  ``PriceState(...,
window=W)`` keeps only a ``W``-slot sliding window of the price tables:
local slot ``i`` is absolute slot ``origin + i``, and ``advance(now)``
slides the window forward, retiring past slots into scalar aggregates
(``retired_slots``, ``retired_gpu_slots``) and opening exact-zero future
slots at the tail.  Both representations slide *in place*: the host
mirror by a shift-and-zero copy, the device residency by a jit roll —
pure moves of existing values, so slots shared by the pre- and
post-advance windows stay bit-equal in both representations (any dtype)
and ``device_uploads`` stays O(1) across an entire streamed run.  With
``window`` omitted (or ``>= T``) the arrays are the full ``(T, ...)``
tables and nothing changes: the fixed-horizon episodic mode is the
``window >= T`` special case of this state.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Optional, Sequence

import numpy as np

from .types import ClusterSpec, Job, R
from .. import obs as _obs


@dataclasses.dataclass(frozen=True)
class PriceParams:
    U1: np.ndarray  # (R,)
    U2: np.ndarray  # (R,)
    L1: float
    L2: float

    def scaled(self, factor: float) -> "PriceParams":
        """Scale the U/L *ratio* by ``factor`` keeping L fixed (Fig. 6 sweeps)."""
        ratio1 = np.maximum(self.U1 / self.L1, 1.0 + 1e-6) ** factor
        ratio2 = np.maximum(self.U2 / self.L2, 1.0 + 1e-6) ** factor
        return PriceParams(U1=self.L1 * ratio1, U2=self.L2 * ratio2,
                           L1=self.L1, L2=self.L2)

    @property
    def alpha(self) -> float:
        """Competitive-ratio parameter: alpha = max_r(1, ln U1/L1, ln U2/L2)."""
        a = 1.0
        for r in range(len(self.U1)):
            if self.L1 > 0 and self.U1[r] > 0:
                a = max(a, math.log(max(self.U1[r] / self.L1, 1.0)))
            if self.L2 > 0 and self.U2[r] > 0:
                a = max(a, math.log(max(self.U2[r] / self.L2, 1.0)))
        return a


def price_params_from_jobs(jobs: Sequence[Job], cluster: ClusterSpec,
                           floor_frac: float = 0.05) -> PriceParams:
    """U1^r, U2^r (23)(24) and L1, L2 (25)(26) from a job population.

    ``floor_frac`` clamps each job's worst-case utility f_i(T - a_i) to
    at least floor_frac * f_i(best): the paper's literal min degenerates
    to ~0 whenever a time-critical sigmoid job exists (f(T-a) is doubly-
    exponentially small), which disables the price filter entirely.  The
    paper itself runs with *estimated* U/L "based on past experience"
    (Sec. IV-B, Fig. 6); this is that estimator.  Pass floor_frac=0 for
    the literal formulas (used by the competitive-ratio tests — the
    Theorem-4 bound is w.r.t. the literal values).
    """
    T = cluster.T
    U1 = np.zeros(R)
    U2 = np.zeros(R)
    L1_num = math.inf
    L2_num = math.inf
    eta1_inv = math.inf  # min over i of the eta_1 bound RHS
    eta2_inv = math.inf
    cap_w = float(cluster.worker_caps.sum())
    cap_s = float(cluster.ps_caps.sum())
    for job in jobs:
        f_max = job.utility(job.min_duration)          # best achievable utility
        f_min = job.utility(T - job.arrival)           # worst (finish at T)
        f_min = max(f_min, floor_frac * f_max)
        total_work = math.ceil(job.total_work_slots)  # ceil(E N M (tau+2e/b))
        for r in range(R):
            if job.worker_res[r] > 0:
                U1[r] = max(U1[r], f_max / job.worker_res[r])
            if job.ps_res[r] > 0:
                U2[r] = max(U2[r], f_max / job.ps_res[r])
        wsum = float(job.worker_res.sum())
        ssum = float(job.ps_res.sum())
        # A job with zero demand on a pool places no constraint on that
        # pool's prices: worker-only jobs (ssum == 0) are a legal workload
        # and must not divide by zero here.
        if wsum > 0:
            L1_num = min(L1_num, f_min / (total_work * wsum))
            if cap_w > 0:
                eta1_inv = min(eta1_inv, total_work * wsum / (T * cap_w))
        if ssum > 0:
            L2_num = min(L2_num, f_min / (total_work * ssum))
            if cap_s > 0:
                eta2_inv = min(eta2_inv, total_work * ssum / (T * cap_s))
    eta1 = 1.0 / max(eta1_inv, 1e-12) if math.isfinite(eta1_inv) else 1.0
    eta2 = 1.0 / max(eta2_inv, 1e-12) if math.isfinite(eta2_inv) else 1.0
    eta1 = max(eta1, 1.0)  # paper requires 1/eta <= 1
    eta2 = max(eta2, 1.0)
    # No job constrains a pool -> any finite price works; fall back to the
    # other pool's floor (or 1.0) so the exponential price stays defined.
    if not math.isfinite(L1_num):
        L1_num = L2_num if math.isfinite(L2_num) else 4.0
    if not math.isfinite(L2_num):
        L2_num = L1_num
    L1 = L1_num / (4.0 * eta1)
    L2 = L2_num / (4.0 * eta2)
    # Guard degenerate resources (e.g. PS pool has no GPUs): keep U >= L so
    # the exponential price is well defined; a zero-demand resource never
    # contributes to cost anyway.
    U1 = np.maximum(U1, L1 * (1.0 + 1e-9))
    U2 = np.maximum(U2, L2 * (1.0 + 1e-9))
    return PriceParams(U1=U1, U2=U2, L1=L1, L2=L2)


# dirty-slot log length cap: on overflow the oldest half is dropped and
# the floor moves up (older caches then take one full recompute).  4096
# commit windows of history is far more than any burst re-solve needs.
_DIRTY_LOG_MAX = 4096


def size_bucket(n: int, floor: int = 32, step: int = 64) -> int:
    """Size bucket: powers of two up to ``step``, then multiples of ``step``.

    Shared by the fused engine's shape buckets and the price-state's
    commit-window buckets: balances jit recompiles (few distinct shapes)
    against padded work (cost is linear in each padded axis)."""
    b = floor
    while b < n and b < step:
        b *= 2
    if b >= n:
        return b
    return ((n + step - 1) // step) * step


def _pool_prices(alloc: np.ndarray, caps: np.ndarray, U: np.ndarray,
                 L: float) -> np.ndarray:
    """Exponential dual price table  L * (U/L)^(alloc/caps)  (eq. 22/25).

    ``alloc``: (..., S, R) allocation entries; ``caps``: (S, R).  Shared by
    the full-table ``worker_prices``/``ps_prices`` and the slot-window
    reads used by duality tracking — entries are priced elementwise, so a
    window evaluation is bit-identical to the same entries of the full
    table."""
    c = np.maximum(caps, 1e-12)
    ratio = np.maximum(U / L, 1.0 + 1e-9)
    return L * ratio ** (alloc / c)


@functools.lru_cache(maxsize=None)
def _window_add_jit(donate: bool):
    """jit'd dense slot-window add: buf[t0:t0+win] += delta (win static per
    compile via delta's shape, t0 dynamic).  Donated buffers where the
    backend supports it (donation on CPU only triggers a warning)."""
    import jax

    import jax.numpy as jnp

    def _add(buf, delta, t0):
        start = (t0,) + (jnp.zeros_like(t0),) * (buf.ndim - 1)
        cur = jax.lax.dynamic_slice(buf, start, delta.shape)
        return jax.lax.dynamic_update_slice(buf, cur + delta, start)

    return jax.jit(_add, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def _window_roll_jit(donate: bool):
    """jit'd window slide: drop the first ``k`` slots, zero-fill the tail
    (``k`` dynamic, shape static).  Values merely move, so the surviving
    slots stay bit-equal to their pre-slide selves in any dtype — no
    resync cadence needed (unlike the f32 incremental adds)."""
    import jax

    import jax.numpy as jnp

    def _slide(buf, k):
        rolled = jnp.roll(buf, -k, axis=0)
        idx = jax.lax.broadcasted_iota(jnp.int32, buf.shape, 0)
        return jnp.where(idx < buf.shape[0] - k, rolled, 0)

    return jax.jit(_slide, donate_argnums=(0,) if donate else ())


def _x64_if(dtype) -> contextlib.AbstractContextManager:
    """enable_x64 context when the device dtype is float64 (CPU policy) —
    keeps uploads/window ops from being canonicalized down to float32."""
    if np.dtype(dtype) == np.float64:
        from jax.experimental import enable_x64
        return enable_x64(True)
    return contextlib.nullcontext()


class PriceState:
    """Allocations g_h^r(t), v_k^r(t) and the derived price tables.

    Host mirror + lazily-materialised device residency (module docstring);
    ``device_uploads`` counts full host→device state syncs — O(1) per
    simulation on the jax path, not O(accepted jobs).

    ``window`` bounds the number of resident slots: slot arrays are
    ``(min(window, T), ...)`` and ``advance(now)`` slides them along the
    absolute clock.  All slot-indexed methods (commit/release, prices,
    headroom, ``alloc_window``) take *local* indices, i.e. offsets from
    ``origin``; with the default ``window=None`` the horizon equals
    ``cluster.T`` and ``origin`` stays 0, so local == absolute and the
    fixed-horizon behaviour is untouched.

    Example — prices start at the ``L1`` floor, rise on ``commit`` and
    return exactly on ``release``::

        >>> import numpy as np
        >>> from repro.core.oasis import OASiS
        >>> from repro.core.pricing import PriceState, price_params_from_jobs
        >>> from repro.sim.workload import make_cluster, make_jobs
        >>> cluster = make_cluster(T=20, H=3, K=3)
        >>> jobs = make_jobs(4, T=20, seed=0, small=True)
        >>> params = price_params_from_jobs(jobs, cluster)
        >>> state = PriceState(cluster, params)
        >>> bool(np.all(state.worker_prices() == params.L1))
        True
        >>> plan = OASiS(cluster, params).propose(jobs[0])   # no commitment
        >>> state.commit(jobs[0], plan.workers, plan.ps)
        >>> bool(np.any(state.worker_prices() > params.L1))
        True
        >>> state.release(jobs[0], plan.workers, plan.ps)
        >>> bool(np.all(state.worker_prices() == params.L1))
        True
    """

    def __init__(self, cluster: ClusterSpec, params: PriceParams,
                 window: Optional[int] = None):
        self.cluster = cluster
        self.params = params
        T, H, K = cluster.T, cluster.H, cluster.K
        self.window = T if window is None else min(int(window), T)
        self._g_host = np.zeros((self.window, H, R))  # alloc on worker servers
        self._v_host = np.zeros((self.window, K, R))  # alloc on PS servers
        # absolute slot of local index 0; advance() moves it forward
        self.origin = 0
        # aggregate accounting for slots retired out of the window
        self.retired_slots = 0
        self.retired_gpu_slots = 0.0        # sum of per-slot GPU units used
        # bumped on every commit/release (consumers may key caches on it)
        self.version = 0
        # dirty-slot log: (version, t0, t1) per commit/release slot window,
        # so row caches can invalidate only the slots a commit touched.
        # ``_dirty_floor`` is the oldest version the log still covers —
        # ``dirty_spans_since`` answers None (unknowable; invalidate all)
        # for anything older.  advance() and mutable ``g``/``v`` access
        # reset the floor: those change prices outside any logged window.
        self._dirty_log: list = []
        self._dirty_floor = 0
        # device residency: (g_dev, v_dev) jax arrays or None; static side
        # tables (caps + price params) cached per dtype
        self._dev = None
        self._dev_dtype = None
        self._dev_static = {}
        self._commits_since_sync = 0
        self.device_uploads = 0

    # -- rolling window ----------------------------------------------------
    @property
    def horizon(self) -> int:
        """Number of resident slots — the schedulable lookahead.  Equals
        ``cluster.T`` for fixed-horizon states; the scheduling subroutines
        size their DP tables from this, never from ``cluster.T``."""
        return self._g_host.shape[0]

    @property
    def window_bytes(self) -> int:
        """Host-mirror bytes of the slot-indexed state — the peak-RSS
        proxy the serving benchmark records (the device residency, when
        materialised, is the same shape at the device dtype)."""
        return self._g_host.nbytes + self._v_host.nbytes

    def advance(self, now: int) -> None:
        """Slide the window so local slot 0 is absolute slot ``now``.

        The ``now - origin`` oldest slots are retired into the scalar
        aggregates (their allocation is immutable history: a schedule can
        no longer touch them) and the same number of exact-zero slots
        opens at the tail.  Surviving slots keep their values bit-for-bit
        in both the host mirror and the device residency — the slide is a
        pure move, applied on-device as a jit roll so ``device_uploads``
        stays O(1) across a whole streamed run.  No-op when ``now ==
        origin``; the clock never runs backwards."""
        shift = int(now) - self.origin
        if shift == 0:
            return
        if shift < 0:
            raise ValueError(f"advance({now}) before origin {self.origin}")
        if _obs.ENABLED:
            _obs.inc("price.window_advances")
            _obs.inc("price.window_slots_retired", shift)
        W = self._g_host.shape[0]
        k = min(shift, W)
        self.retired_gpu_slots += float(self._g_host[:k, :, 0].sum())
        self.retired_slots += shift
        self.origin = int(now)
        if k >= W:
            self._g_host[:] = 0.0
            self._v_host[:] = 0.0
        else:
            self._g_host[:W - k] = self._g_host[k:].copy()
            self._g_host[W - k:] = 0.0
            self._v_host[:W - k] = self._v_host[k:].copy()
            self._v_host[W - k:] = 0.0
        if self._dev is not None:
            import jax
            slide = _window_roll_jit(jax.default_backend() != "cpu")
            with _x64_if(self._dev_dtype):
                self._dev = tuple(slide(buf, np.int32(k))
                                  for buf in self._dev)
        self.version += 1
        # a slide remaps every local slot index — caches from before it
        # cannot be patched span-wise, only rebuilt
        self._dirty_log.clear()
        self._dirty_floor = self.version

    # -- host views --------------------------------------------------------
    @property
    def g(self) -> np.ndarray:
        """Worker-pool allocation (T, H, R), host numpy.  Hands out the
        mutable mirror, so the device residency is conservatively dropped
        (re-uploaded on next ``device_state``) and existing row caches are
        conservatively invalidated (dirty floor moves past ``version``)."""
        self._dev = None
        self._dirty_log.clear()
        self._dirty_floor = self.version + 1
        return self._g_host

    @g.setter
    def g(self, value: np.ndarray) -> None:
        self._g_host = np.asarray(value, dtype=np.float64)
        self._dev = None
        self._dirty_log.clear()
        self._dirty_floor = self.version + 1

    @property
    def v(self) -> np.ndarray:
        self._dev = None
        self._dirty_log.clear()
        self._dirty_floor = self.version + 1
        return self._v_host

    @v.setter
    def v(self, value: np.ndarray) -> None:
        self._v_host = np.asarray(value, dtype=np.float64)
        self._dev = None
        self._dirty_log.clear()
        self._dirty_floor = self.version + 1

    # -- price tables -----------------------------------------------------
    def worker_prices(self) -> np.ndarray:
        """p (T, H, R) with p = L1 * (U1/L1)^(g/c)."""
        return _pool_prices(self._g_host, self.cluster.worker_caps[None],
                            self.params.U1[None, None], self.params.L1)

    def ps_prices(self) -> np.ndarray:
        return _pool_prices(self._v_host, self.cluster.ps_caps[None],
                            self.params.U2[None, None], self.params.L2)

    def worker_prices_at(self, slots: np.ndarray) -> np.ndarray:
        """Price entries for ``slots`` only, (n, H, R) — bit-identical to
        ``worker_prices()[slots]`` without materializing the full table.
        Read-only (keeps the device residency)."""
        return _pool_prices(self._g_host[slots], self.cluster.worker_caps[None],
                            self.params.U1[None, None], self.params.L1)

    def ps_prices_at(self, slots: np.ndarray) -> np.ndarray:
        return _pool_prices(self._v_host[slots], self.cluster.ps_caps[None],
                            self.params.U2[None, None], self.params.L2)

    # -- bookkeeping (Alg. 1 lines 7-10) -----------------------------------
    def _window_delta(self, alloc: dict, res: np.ndarray, T: int,
                      sign: float):
        """Dense (win, S, R) slot-window delta for one commit/release.

        The window spans [t0, t0+win) with ``win`` bucketed (few distinct
        jit shapes); slots inside the window but absent from ``alloc``
        carry an exact 0.0 delta."""
        ts = np.fromiter(alloc.keys(), dtype=np.int64, count=len(alloc))
        t0, t1 = int(ts.min()), int(ts.max())
        win = min(size_bucket(t1 - t0 + 1, floor=8, step=64), T)
        t0 = min(t0, T - win)
        counts = np.stack([alloc[int(t)] for t in ts]).astype(np.float64)
        delta = np.zeros((win, counts.shape[1], R))
        delta[ts - t0] = sign * (counts[:, :, None] * res[None, None, :])
        return t0, delta

    def _apply(self, workers: dict, ps: dict, wres: np.ndarray,
               sres: np.ndarray, sign: float) -> None:
        T = self._g_host.shape[0]           # == horizon (window-local slots)
        deltas = []
        if workers and self.cluster.H:
            deltas.append((0, self._g_host) + self._window_delta(
                workers, wres, T, sign))
        if ps and self.cluster.K:
            deltas.append((1, self._v_host) + self._window_delta(
                ps, sres, T, sign))
        self._apply_deltas(deltas, negative=sign < 0)

    def _apply_deltas(self, deltas, negative: bool) -> None:
        """Common tail of every state mutation (job commits/releases and
        fleet-churn server blocks): host add, incremental device stream,
        version bump, dirty-span logging."""
        for _, host, t0, delta in deltas:
            host[t0:t0 + delta.shape[0]] += delta
        if self._dev is not None and deltas:
            if np.dtype(self._dev_dtype) != np.float64 and (
                    negative
                    or self._commits_since_sync >= self._F32_RESYNC_EVERY):
                # float32 residency (GPU/TPU): incremental adds round per
                # commit, so the residency slowly drifts from the float64
                # mirror, and (g + d) - d is not exact at all, so a
                # release would leave phantom allocation behind.  Resync
                # from the mirror on every release (rare: cancellations /
                # fault handling) and every _F32_RESYNC_EVERY commits —
                # the drift stays bounded at O(uploads) ~
                # O(accepts / 256 + cancels), not O(accepted jobs).
                self._dev = None
            else:
                self._device_apply(deltas)
                self._commits_since_sync += 1
        self.version += 1
        for _, _, t0, delta in deltas:
            self._dirty_log.append((self.version, t0, t0 + delta.shape[0]))
        if len(self._dirty_log) > _DIRTY_LOG_MAX:
            drop = len(self._dirty_log) - _DIRTY_LOG_MAX // 2
            self._dirty_floor = self._dirty_log[drop - 1][0]
            del self._dirty_log[:drop]

    def _device_apply(self, deltas) -> None:
        """Stream the slot-window deltas to the resident device arrays."""
        import jax
        import jax.numpy as jnp
        add = _window_add_jit(jax.default_backend() != "cpu")
        dev = list(self._dev)
        with _x64_if(self._dev_dtype):
            for pool, _, t0, delta in deltas:
                dev[pool] = add(dev[pool],
                                jnp.asarray(delta, self._dev_dtype),
                                np.int32(t0))
        self._dev = tuple(dev)

    def commit(self, job: Job, workers: dict, ps: dict) -> None:
        with _obs.span("price.commit", jid=job.jid):
            self._apply(workers, ps, job.worker_res, job.ps_res, 1.0)
        if _obs.ENABLED:
            _obs.inc("price.commits")

    def release(self, job: Job, workers: dict, ps: dict) -> None:
        """Inverse of commit — used when a running job is preempted/killed
        (fault handling), not part of the paper's committed schedules."""
        with _obs.span("price.release", jid=job.jid):
            self._apply(workers, ps, job.worker_res, job.ps_res, -1.0)
        if _obs.ENABLED:
            _obs.inc("price.releases")

    # -- fleet churn (sim/fleet.py): capacity-aware headroom ----------------
    def _server_pool(self, pool: str):
        if pool == "worker":
            return 0, self._g_host, self.cluster.worker_caps
        if pool == "ps":
            return 1, self._v_host, self.cluster.ps_caps
        raise ValueError(f"unknown pool {pool!r}")

    def block_server(self, pool: str, server: int, t0: int = 0) -> float:
        """Fill one server's resident slots ``[t0, horizon)`` to capacity.

        Called when the server fails or drains (after its victims' tails
        have been released): its prices rise to the U bound and — the
        property the scheduling subroutines actually rely on — its
        per-slot headroom drops to exactly 0, so Alg. 2 can never plan
        onto a dead server.  Applied through the same delta machinery as
        ``commit`` (incremental device stream, dirty-span log), so the
        O(1)-upload residency invariant is preserved.  Idempotent per
        slot (already-full slots get an exact-0.0 delta) — the streaming
        engine re-blocks after every ``advance`` to cover the freshly
        opened tail slots.  Returns the GPU-slot units (resource 0)
        added, for the caller's utilization accounting."""
        pool_i, host, caps = self._server_pool(pool)
        T = host.shape[0]
        t0 = int(min(max(t0, 0), T))
        if t0 >= T or host.shape[1] == 0:
            return 0.0
        amt = caps[server][None, :] - host[t0:, server, :]
        win = min(size_bucket(T - t0, floor=8, step=64), T)
        w0 = T - win
        delta = np.zeros((win, host.shape[1], R))
        delta[t0 - w0:, server, :] = amt
        self._apply_deltas([(pool_i, host, w0, delta)], negative=False)
        if _obs.ENABLED:
            _obs.inc("price.server_blocks")
        return float(amt[:, 0].sum())

    def unblock_server(self, pool: str, server: int, t0: int = 0) -> float:
        """Inverse of :meth:`block_server`: zero the server's resident
        content on ``[t0, horizon)`` when it recovers.  While blocked the
        server's headroom is 0, so nothing can have committed onto it —
        its content *is* the blocked amount, and removing it restores
        the pre-block zeros bit-exactly.  Returns the GPU-slot units
        (resource 0) released."""
        pool_i, host, _ = self._server_pool(pool)
        T = host.shape[0]
        t0 = int(min(max(t0, 0), T))
        if t0 >= T or host.shape[1] == 0:
            return 0.0
        amt = host[t0:, server, :].copy()
        win = min(size_bucket(T - t0, floor=8, step=64), T)
        w0 = T - win
        delta = np.zeros((win, host.shape[1], R))
        delta[t0 - w0:, server, :] = -amt
        self._apply_deltas([(pool_i, host, w0, delta)], negative=True)
        if _obs.ENABLED:
            _obs.inc("price.server_unblocks")
        return float(amt[:, 0].sum())

    def dirty_spans_since(self, version: int):
        """Slot spans whose prices may have moved since ``version``.

        Returns a list of local-slot ``[t0, t1)`` pairs (possibly
        overlapping, possibly empty), or ``None`` when the delta is
        unknowable — ``version`` predates the log floor (log trimmed, a
        window slide, or mutable ``g``/``v`` access) — in which case the
        caller must invalidate everything.  Commit/release windows are
        logged in :meth:`_apply`; row caches consume this via
        ``RowCache.sync``."""
        if version < self._dirty_floor:
            return None
        return [(t0, t1) for v, t0, t1 in self._dirty_log if v > version]

    def patch_spans(self, version: int, limit: int = 8):
        """Dirty spans in the form an incremental table patcher consumes:
        the :meth:`dirty_spans_since` list when it has at most ``limit``
        entries, else ``None`` — more spans than that and span-by-span
        patching launches more kernels than one full rebuild.  Shared by
        the engine's padded-state price-table cache and the per-job
        sorted-order/cumsum cache (``schedule_jax._sorted_fill``)."""
        spans = self.dirty_spans_since(version)
        if spans is None or len(spans) > limit:
            return None
        return spans

    def headroom_workers(self, t: int) -> np.ndarray:
        return self.cluster.worker_caps - self._g_host[t]

    def headroom_ps(self, t: int) -> np.ndarray:
        return self.cluster.ps_caps - self._v_host[t]

    # -- whole-state queries (no host/device churn) -------------------------
    def capacity_ok(self, tol: float = 1e-6):
        """(workers_ok, ps_ok): no allocation entry exceeds capacity."""
        ok_w = bool(np.all(self._g_host
                           <= self.cluster.worker_caps[None] + tol))
        ok_p = bool(np.all(self._v_host <= self.cluster.ps_caps[None] + tol))
        return ok_w, ok_p

    def gpu_slot_usage(self) -> np.ndarray:
        """(T,) worker-pool GPU units in use per slot (resource 0)."""
        return self._g_host[:, :, 0].sum(axis=1)

    def alloc_window(self, t0: int, w: int):
        """Per-slot pool-total allocation for slots ``[t0, t0+w)``:
        ``(g_win, v_win)`` of shape (min(w, T-t0), R) each, summed over
        servers.  Read-only (keeps the device residency) — the rl/ env's
        capacity-window observation reads this instead of ``g``/``v``."""
        return (self._g_host[t0:t0 + w].sum(axis=1),
                self._v_host[t0:t0 + w].sum(axis=1))

    # -- device residency ---------------------------------------------------
    def _static_arrays(self, dtype):
        key = np.dtype(dtype).str
        cached = self._dev_static.get(key)
        if cached is not None:
            return cached
        import jax.numpy as jnp
        wcaps, scaps = self.cluster.worker_caps, self.cluster.ps_caps
        # empty pools are padded with one zero-capacity server so engine
        # gathers stay in bounds (it can never be used)
        if wcaps.shape[0] == 0:
            wcaps = np.zeros((1, R))
        if scaps.shape[0] == 0:
            scaps = np.zeros((1, R))
        pp = self.params
        with _x64_if(dtype):
            sd = (jnp.asarray(wcaps, dtype), jnp.asarray(scaps, dtype),
                  jnp.asarray(pp.U1, dtype), jnp.asarray(pp.U2, dtype),
                  jnp.asarray(pp.L1, dtype), jnp.asarray(pp.L2, dtype))
        self._dev_static[key] = sd
        return sd

    # full f32-residency resync cadence (see _apply); f64 never resyncs —
    # its incremental adds are bit-identical to the mirror's
    _F32_RESYNC_EVERY = 256

    def _upload(self, dtype):
        import jax.numpy as jnp
        self._commits_since_sync = 0
        g, v = self._g_host, self._v_host
        if g.shape[1] == 0:
            g = np.zeros((self.horizon, 1, R))
        if v.shape[1] == 0:
            v = np.zeros((self.horizon, 1, R))
        self.device_uploads += 1
        if _obs.ENABLED:
            _obs.inc("price.device_uploads")
        # jnp.array (not asarray): jax CPU conversion can be zero-copy for
        # aligned buffers, and an aliased residency would silently track
        # (and double-count) subsequent host-mirror writes
        with _x64_if(dtype):
            return (jnp.array(g, dtype, copy=True),
                    jnp.array(v, dtype, copy=True))

    def device_state(self, dtype=None):
        """Engine view ``(g, v, wcaps, scaps, U1, U2, L1, L2)`` on device.

        The first call uploads the full state (counted in
        ``device_uploads``); afterwards ``commit``/``release`` keep the
        residency fresh incrementally, so repeat calls are free.  Empty
        pools are padded with one zero-capacity server."""
        if dtype is None:
            import jax
            dtype = (np.float64 if jax.default_backend() == "cpu"
                     else np.float32)
        if self._dev is None or np.dtype(self._dev_dtype) != np.dtype(dtype):
            self._dev_dtype = np.dtype(dtype)
            self._dev = self._upload(dtype)
        return self._dev + self._static_arrays(dtype)
