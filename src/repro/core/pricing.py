"""Dual price functions (paper eq. (22)-(26)) and mutable price state.

Prices are maintained per (slot t, server, resource r):

    p_h^r(t) = L1 * (U1^r / L1) ** (g_h^r(t) / c_h^r)        (workers pool)
    q_k^r(t) = L2 * (U2^r / L2) ** (v_k^r(t) / c_k^r)        (PS pool)

``U`` bounds are the max per-unit-resource utility over jobs, ``L`` the
min unit-time-unit-resource utility scaled by 1/(4*eta).  In the online
setting the exact values need future knowledge, so the operator supplies
*estimates* (benchmarks/fig6 sweeps their accuracy).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .types import ClusterSpec, Job, R


@dataclasses.dataclass(frozen=True)
class PriceParams:
    U1: np.ndarray  # (R,)
    U2: np.ndarray  # (R,)
    L1: float
    L2: float

    def scaled(self, factor: float) -> "PriceParams":
        """Scale the U/L *ratio* by ``factor`` keeping L fixed (Fig. 6 sweeps)."""
        ratio1 = np.maximum(self.U1 / self.L1, 1.0 + 1e-6) ** factor
        ratio2 = np.maximum(self.U2 / self.L2, 1.0 + 1e-6) ** factor
        return PriceParams(U1=self.L1 * ratio1, U2=self.L2 * ratio2,
                           L1=self.L1, L2=self.L2)

    @property
    def alpha(self) -> float:
        """Competitive-ratio parameter: alpha = max_r(1, ln U1/L1, ln U2/L2)."""
        a = 1.0
        for r in range(len(self.U1)):
            if self.L1 > 0 and self.U1[r] > 0:
                a = max(a, math.log(max(self.U1[r] / self.L1, 1.0)))
            if self.L2 > 0 and self.U2[r] > 0:
                a = max(a, math.log(max(self.U2[r] / self.L2, 1.0)))
        return a


def price_params_from_jobs(jobs: Sequence[Job], cluster: ClusterSpec,
                           floor_frac: float = 0.05) -> PriceParams:
    """U1^r, U2^r (23)(24) and L1, L2 (25)(26) from a job population.

    ``floor_frac`` clamps each job's worst-case utility f_i(T - a_i) to
    at least floor_frac * f_i(best): the paper's literal min degenerates
    to ~0 whenever a time-critical sigmoid job exists (f(T-a) is doubly-
    exponentially small), which disables the price filter entirely.  The
    paper itself runs with *estimated* U/L "based on past experience"
    (Sec. IV-B, Fig. 6); this is that estimator.  Pass floor_frac=0 for
    the literal formulas (used by the competitive-ratio tests — the
    Theorem-4 bound is w.r.t. the literal values).
    """
    T = cluster.T
    U1 = np.zeros(R)
    U2 = np.zeros(R)
    L1_num = math.inf
    L2_num = math.inf
    eta1_inv = math.inf  # min over i of the eta_1 bound RHS
    eta2_inv = math.inf
    cap_w = float(cluster.worker_caps.sum())
    cap_s = float(cluster.ps_caps.sum())
    for job in jobs:
        f_max = job.utility(job.min_duration)          # best achievable utility
        f_min = job.utility(T - job.arrival)           # worst (finish at T)
        f_min = max(f_min, floor_frac * f_max)
        total_work = math.ceil(job.total_work_slots)  # ceil(E N M (tau+2e/b))
        for r in range(R):
            if job.worker_res[r] > 0:
                U1[r] = max(U1[r], f_max / job.worker_res[r])
            if job.ps_res[r] > 0:
                U2[r] = max(U2[r], f_max / job.ps_res[r])
        wsum = float(job.worker_res.sum())
        ssum = float(job.ps_res.sum())
        # A job with zero demand on a pool places no constraint on that
        # pool's prices: worker-only jobs (ssum == 0) are a legal workload
        # and must not divide by zero here.
        if wsum > 0:
            L1_num = min(L1_num, f_min / (total_work * wsum))
            if cap_w > 0:
                eta1_inv = min(eta1_inv, total_work * wsum / (T * cap_w))
        if ssum > 0:
            L2_num = min(L2_num, f_min / (total_work * ssum))
            if cap_s > 0:
                eta2_inv = min(eta2_inv, total_work * ssum / (T * cap_s))
    eta1 = 1.0 / max(eta1_inv, 1e-12) if math.isfinite(eta1_inv) else 1.0
    eta2 = 1.0 / max(eta2_inv, 1e-12) if math.isfinite(eta2_inv) else 1.0
    eta1 = max(eta1, 1.0)  # paper requires 1/eta <= 1
    eta2 = max(eta2, 1.0)
    # No job constrains a pool -> any finite price works; fall back to the
    # other pool's floor (or 1.0) so the exponential price stays defined.
    if not math.isfinite(L1_num):
        L1_num = L2_num if math.isfinite(L2_num) else 4.0
    if not math.isfinite(L2_num):
        L2_num = L1_num
    L1 = L1_num / (4.0 * eta1)
    L2 = L2_num / (4.0 * eta2)
    # Guard degenerate resources (e.g. PS pool has no GPUs): keep U >= L so
    # the exponential price is well defined; a zero-demand resource never
    # contributes to cost anyway.
    U1 = np.maximum(U1, L1 * (1.0 + 1e-9))
    U2 = np.maximum(U2, L2 * (1.0 + 1e-9))
    return PriceParams(U1=U1, U2=U2, L1=L1, L2=L2)


class PriceState:
    """Allocations g_h^r(t), v_k^r(t) and the derived price tables."""

    def __init__(self, cluster: ClusterSpec, params: PriceParams):
        self.cluster = cluster
        self.params = params
        T, H, K = cluster.T, cluster.H, cluster.K
        self.g = np.zeros((T, H, R))   # allocated on worker servers
        self.v = np.zeros((T, K, R))   # allocated on PS servers
        # bumped on every commit/release; lets the jit engine cache its
        # device-side copy of (g, v) between allocation changes
        self.version = 0

    # -- price tables -----------------------------------------------------
    def worker_prices(self) -> np.ndarray:
        """p (T, H, R) with p = L1 * (U1/L1)^(g/c)."""
        c = np.maximum(self.cluster.worker_caps[None], 1e-12)
        ratio = np.maximum(self.params.U1[None, None] / self.params.L1, 1.0 + 1e-9)
        return self.params.L1 * ratio ** (self.g / c)

    def ps_prices(self) -> np.ndarray:
        c = np.maximum(self.cluster.ps_caps[None], 1e-12)
        ratio = np.maximum(self.params.U2[None, None] / self.params.L2, 1.0 + 1e-9)
        return self.params.L2 * ratio ** (self.v / c)

    # -- bookkeeping (Alg. 1 lines 7-10) -----------------------------------
    def commit(self, job: Job, workers: dict, ps: dict) -> None:
        for t, y in workers.items():
            self.g[t] += y[:, None] * job.worker_res[None, :]
        for t, z in ps.items():
            self.v[t] += z[:, None] * job.ps_res[None, :]
        self.version += 1

    def release(self, job: Job, workers: dict, ps: dict) -> None:
        """Inverse of commit — used when a running job is preempted/killed
        (fault handling), not part of the paper's committed schedules."""
        for t, y in workers.items():
            self.g[t] -= y[:, None] * job.worker_res[None, :]
        for t, z in ps.items():
            self.v[t] -= z[:, None] * job.ps_res[None, :]
        self.version += 1

    def headroom_workers(self, t: int) -> np.ndarray:
        return self.cluster.worker_caps - self.g[t]

    def headroom_ps(self, t: int) -> np.ndarray:
        return self.cluster.ps_caps - self.v[t]
