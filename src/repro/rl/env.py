"""Gymnasium-style cluster-scheduling environment over sim v2.

One episode = one job trace driven through ``sim/engine.py``; one env
step = one per-arrival admission decision (the engine's
:class:`~repro.sim.engine.DecisionPoint`).  Everything between decisions
— placements, repacks, fast-forwarded work accounting, completions — is
the event engine itself, so the env inherits sim v2's semantics *and*
its speed.

* **observation** — a flat float vector: dense job features (demand,
  workload, deadline/utility shape) + the decision point's per-slot free
  capacity window for both pools + queue/congestion scalars
  (:func:`observe`).
* **action** — ``(workers, ps_slack)``: admit with ``workers`` workers
  and ``ps_for(workers) + ps_slack`` parameter servers, or reject with
  ``workers == 0``.  A bare int is accepted (slack 0).  Actions are
  clamped to the job's feasibility envelope (at most ``num_chunks``
  concurrent workers, at least the bandwidth-matched PS count), so no
  action can request a capacity-violating allocation; the engine's
  placement kernels never over-commit servers regardless.
* **reward** — the paper's objective: utility of completed jobs, paid
  when completion happens between this decision and the next (terminal
  step pays the tail), so the un-discounted episode return equals
  ``SimResult.total_utility`` exactly.

``scheduler`` selects the allocation machinery the decisions drive:
``"learned"`` (FIFO-queue machinery with per-job counts — the action is
consumed literally) or any named scheduler (``"oasis"``/``"fifo"``/
``"drf"``/``"rrh"``/``"dorm"`` — the action gates admission, allocation
follows the scheduler's own kernels).  In every mode
``info["expert_action"]`` is the action replaying the named scheduler's
own decision; feeding it back (:class:`ReplayPolicy`) reproduces
``sim.engine.run`` bit-for-bit (tests/test_rl_env.py).

Gymnasium is an optional dependency: when importable the env subclasses
``gymnasium.Env`` with real ``spaces``; otherwise a minimal stand-in
keeps the exact same ``reset``/``step`` API.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from ..core.types import R, ClusterSpec, Job
from ..sim import engine
from ..sim.engine import DECISION_WINDOW, DecisionPoint, SimResult
from ..sim.workload import make_cluster, make_jobs

try:                                         # optional dependency
    import gymnasium as _gym
    from gymnasium import spaces as _spaces
except ImportError:                          # pragma: no cover - CI has no gym
    _gym = None
    _spaces = None

# observation layout: job/context scalars + two capacity windows.  The
# last two scalars are the fleet-churn context: live-capacity fraction
# (1.0 on a healthy fleet) and whether this decision re-admits a
# preempted job — both 24-feature-era defaults on churn-free traces, so
# policies trained before churn see identical leading features.
N_SCALAR_FEATURES = 26
OBS_DIM = N_SCALAR_FEATURES + 2 * DECISION_WINDOW * R
# index of the best-achievable-utility feature (utility at min_duration,
# scaled by 1/100) in the scalar block — the trainer's warm-start expert
# reads it back out of the observation
F_BEST_UTILITY = 8

# default action bounds: worker head 0..MAX_WORKERS, PS slack head 0..3
MAX_WORKERS = 32
PS_SLACK_LEVELS = 4


def paper_instance(seed: int, T: int = 100, H: int = 50, K: int = 50,
                   n_jobs: int = 200, small: bool = False
                   ) -> Tuple[ClusterSpec, Sequence[Job]]:
    """The paper-scale instance family (ROADMAP: T=100, 100 servers,
    200 jobs).  ``small=True`` is the equivalence-suite variant (shrunk
    job internals, fast Alg. 2); ``small=False`` is the congested fig3
    workload the learned policy trains on."""
    return (make_cluster(T=T, H=H, K=K),
            make_jobs(n_jobs, T=T, seed=seed, small=small))


def observe(dp: DecisionPoint, cluster: ClusterSpec) -> np.ndarray:
    """Flat observation vector for one decision point (shape (OBS_DIM,))."""
    job = dp.job
    T = max(cluster.T, 1)
    u = job.utility
    g1 = float(getattr(u, "gamma1", 0.0))
    g2 = float(getattr(u, "gamma2", 0.0))
    g3 = float(getattr(u, "gamma3", 0.0))
    mean_w = np.maximum(cluster.worker_caps.mean(axis=0), 1e-9) \
        if cluster.H else np.full(R, 1e-9)
    mean_s = np.maximum(cluster.ps_caps.mean(axis=0), 1e-9) \
        if cluster.K else np.full(R, 1e-9)
    best = float(u(job.min_duration))
    seen = dp.accepted + dp.rejected
    scalars = np.array([
        dp.t / T,
        job.num_chunks / 100.0,
        np.log1p(job.total_work_slots) / 8.0,
        job.min_duration / T,
        min(job.chunk_time, 2.0),
        g1 / 100.0,
        min(g2, 6.0) / 6.0,
        g3 / T,
        best / 100.0,
        float(u(2.0 * job.min_duration)) / 100.0,   # deadline-decay probe
        *(job.worker_res / mean_w),
        *(job.ps_res / mean_s),
        job.ps_for(8) / 8.0,
        dp.n_running / 64.0,
        dp.n_waiting / 64.0,
        dp.accepted / max(seen, 1),
        dp.live_frac,
        float(dp.preempted),
    ])
    assert scalars.shape[0] == N_SCALAR_FEATURES
    return np.concatenate([scalars,
                           dp.free_frac_workers.ravel(),
                           dp.free_frac_ps.ravel()]).astype(np.float32)


def split_action(action) -> Tuple[int, int]:
    """Normalize an env action to ``(workers, ps_slack)``."""
    if action is None:
        return 0, 0
    if np.ndim(action) == 0:
        return int(action), 0
    a = np.asarray(action).ravel()
    return int(a[0]), int(a[1]) if a.size > 1 else 0

def engine_action(dp: DecisionPoint, action) -> Optional[Tuple[int, int]]:
    """Translate an env action into the engine's ``(n_workers, n_ps)``
    decision, clamped to the job's feasibility envelope.  ``None``
    rejects."""
    w, slack = split_action(action)
    if w <= 0:
        return None
    job = dp.job
    w = min(w, job.num_chunks)
    return w, job.ps_for(w) + max(slack, 0)


def expert_env_action(dp: DecisionPoint) -> np.ndarray:
    """The env action replaying the wrapped scheduler's own decision."""
    nw, _ = dp.expert
    return np.array([nw, 0], dtype=np.int64)


_EnvBase = _gym.Env if _gym is not None else object


class ClusterSchedulingEnv(_EnvBase):
    """Per-arrival scheduling decisions over one sim-v2 episode.

    Parameters
    ----------
    instance_fn : ``seed -> (cluster, jobs)``; defaults to
        :func:`paper_instance` with ``**instance_kwargs``.  ``reset``
        draws a fresh trace from it per episode (``options["instance"]``
        overrides the seed), so the same env object trains across many
        seeded instances.
    scheduler : allocation machinery (see module docstring).
    check : assert capacity feasibility inside the engine every repack.
    engine_kwargs : forwarded to ``engine.decisions`` (``params``,
        ``impl``, ``quantum``, ``cancellations``, ``throughput``, ...).
    """

    metadata: Dict = {"render_modes": []}

    def __init__(self, instance_fn: Optional[Callable] = None,
                 scheduler: str = "learned",
                 max_workers: int = MAX_WORKERS,
                 ps_slack_levels: int = PS_SLACK_LEVELS,
                 check: bool = False, seed: int = 0,
                 instance_kwargs: Optional[Dict] = None,
                 **engine_kwargs):
        self.instance_fn = instance_fn or (
            lambda s: paper_instance(s, **(instance_kwargs or {})))
        self.scheduler = scheduler
        self.max_workers = int(max_workers)
        self.ps_slack_levels = int(ps_slack_levels)
        self.check = check
        self.engine_kwargs = engine_kwargs
        self._instance_seed = seed
        if _spaces is not None:
            self.action_space = _spaces.MultiDiscrete(
                np.array([self.max_workers + 1, self.ps_slack_levels]))
            self.observation_space = _spaces.Box(
                -np.inf, np.inf, shape=(OBS_DIM,), dtype=np.float32)
        else:                                   # gym-less stand-in
            self.action_space = (self.max_workers + 1, self.ps_slack_levels)
            self.observation_space = (OBS_DIM,)
        self.cluster: Optional[ClusterSpec] = None
        self.jobs: Sequence[Job] = ()
        self._gen = None
        self._dp: Optional[DecisionPoint] = None
        self._paid = 0.0
        self._done = True
        self.result: Optional[SimResult] = None

    # -- episode control ----------------------------------------------------
    def reset(self, *, seed: Optional[int] = None,
              options: Optional[Dict] = None):
        if _gym is not None:
            super().reset(seed=seed)
        if options and "instance" in options:
            self._instance_seed = int(options["instance"])
        elif seed is not None:
            self._instance_seed = int(seed)
        self.cluster, self.jobs = self.instance_fn(self._instance_seed)
        self._instance_seed += 1                # next reset: fresh trace
        self._gen = engine.decisions(
            self.cluster, self.jobs, scheduler=self.scheduler,
            check=self.check, **self.engine_kwargs)
        self.result = None
        self._paid = 0.0
        self._done = False
        obs, info = self._advance(None)
        if self._done:
            # empty trace: episode is already over; the first step()
            # terminates immediately whatever the action
            info = dict(info, empty_trace=True)
        return obs, info

    def step(self, action):
        assert self._gen is not None, "call reset() first"
        if self._done:
            return (np.zeros(OBS_DIM, np.float32), 0.0, True, False,
                    self._terminal_info())
        send = engine_action(self._dp, action)
        obs, info = self._advance(send)
        if self._done:
            reward = float(self.result.total_utility) - self._paid
            self._paid = float(self.result.total_utility)
            return obs, reward, True, False, self._terminal_info()
        reward = self._dp.utility_so_far - self._paid
        self._paid = self._dp.utility_so_far
        return obs, reward, False, False, info

    # -- internals ----------------------------------------------------------
    def _advance(self, send):
        try:
            if self._dp is None:                # fresh generator (reset)
                self._dp = next(self._gen)
            else:                               # answer the paused decision
                self._dp = self._gen.send(send)
            return observe(self._dp, self.cluster), self._step_info()
        except StopIteration as stop:
            self.result = stop.value
            self._done = True
            self._dp = None
            return np.zeros(OBS_DIM, np.float32), {}

    def _step_info(self) -> Dict:
        dp = self._dp
        return {"jid": dp.job.jid, "t": dp.t, "scheduler": dp.scheduler,
                "expert_action": expert_env_action(dp),
                "n_running": dp.n_running, "n_waiting": dp.n_waiting}

    def _terminal_info(self) -> Dict:
        return {"result": self.result, "summary": self.result.summary()}


@dataclasses.dataclass
class ReplayPolicy:
    """Feeds back ``info["expert_action"]`` — the wrapped scheduler's own
    decision — so the env provably replays ``sim.engine.run``."""

    def __call__(self, obs: np.ndarray, info: Dict) -> np.ndarray:
        return info["expert_action"]


def run_episode(env: ClusterSchedulingEnv,
                policy: Callable[[np.ndarray, Dict], object],
                seed: Optional[int] = None) -> SimResult:
    """Drive one full episode; returns the engine's ``SimResult``."""
    obs, info = env.reset(seed=seed)
    done = info.get("empty_trace", False)
    total = 0.0
    while not done:
        obs, reward, done, _, info = env.step(policy(obs, info))
        total += reward
    assert abs(total - env.result.total_utility) < 1e-6
    return env.result
