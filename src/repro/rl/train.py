"""REINFORCE-with-baseline training for the learned scheduler.

One iteration = a batch of episodes rolled out in lockstep (every env is
always either finished or paused at a decision point, so each decision
round is ONE jit-compiled vmapped policy call over the whole batch),
then one Adam step on the advantage-weighted log-likelihood:

    loss = -E[ logp(a|obs) * A ] - entropy_coef * H(pi)

with A the return-to-go whitened across the batch (the "baseline": mean
return subtracted, std-normalized).  Rewards are the paper's utility
deltas between decisions, so the un-discounted return equals the
episode's total job utility — the objective OASiS optimizes.

Shapes are padded to (batch, n_jobs) once, so the update compiles a
single executable per run.  Checkpoints go through ``ckpt/checkpoint.py``
(manifest + crc32 npz, atomic publish) and are reloadable into
``engine.run(scheduler="learned", policy=...)`` via
``policy.load_policy`` — see ``examples/cluster_sim.py --scheduler
learned --policy-ckpt``.

CLI::

    PYTHONPATH=src python -m repro.rl.train --iterations 40 \
        --ckpt-dir runs/learned
    PYTHONPATH=src python -m repro.rl.train --smoke      # CI: 2 tiny iters

optax supplies the optimizer and is required only here (the env and
policy inference are optax-free).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sim import engine
from . import env as env_mod
from . import policy as policy_mod
from .policy import LearnedDecider, PolicyConfig

try:
    import optax
except ImportError:                          # pragma: no cover
    optax = None


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    iterations: int = 40
    batch: int = 8                  # episodes per iteration
    lr: float = 2e-3
    entropy_coef: float = 0.001
    # sampling-time ε-uniform exploration: after behavior cloning the
    # policy is (near-)deterministic and its entropy gradient vanishes,
    # so on-policy sampling would never try a deviation again; mixing in
    # uniform actions with probability ε keeps every level tested while
    # the policy itself stays sharp (evaluation is greedy regardless)
    explore_eps: float = 0.1
    # greedy validation on instances disjoint from train and held-out
    # eval seeds; the returned params are the best validation iterate
    val_seeds: Tuple[int, ...] = (200, 201, 202)
    val_every: int = 20
    # expert anchor during REINFORCE: a small cross-entropy pull toward
    # the heuristic's action keeps the policy from drifting to uniform
    # on decisions where the advantage signal is silent; deviations that
    # actually pay overpower it
    anchor_coef: float = 0.005
    # horizon (in decisions) of the return-to-go: an admission's
    # externality lands on the queue right behind it; summing credit to
    # the episode end buries that signal under the whole trace's noise
    rtg_window: int = 32
    # supervised warm start (DL2-style "bootstrap from an existing
    # scheduler"): clone an admission-filtered expert — FIFO counts plus
    # an RRH-style value test that rejects jobs whose best achievable
    # utility is below ``admit_threshold`` (head-of-line blocking makes
    # admitting near-worthless jobs expensive) — before letting
    # REINFORCE explore from there.  The anchor pulls toward the same
    # expert.
    bc_episodes: int = 8
    bc_steps: int = 30
    bc_lr: float = 5e-3
    admit_threshold: float = 10.0
    seed: int = 0
    # instance family (paper scale, congested full-size jobs by default)
    T: int = 100
    H: int = 50
    K: int = 50
    n_jobs: int = 200
    small: bool = False
    # disjoint from the held-out seeds: the equivalence suite pins 0-4
    # and the scoreboard evaluates on 5-7
    train_seeds: Tuple[int, ...] = tuple(range(100, 132))
    budget_seconds: Optional[float] = None
    log_every: int = 5


def _make_env(cfg: TrainConfig) -> env_mod.ClusterSchedulingEnv:
    return env_mod.ClusterSchedulingEnv(
        scheduler="learned", check=False,
        instance_kwargs=dict(T=cfg.T, H=cfg.H, K=cfg.K,
                             n_jobs=cfg.n_jobs, small=cfg.small))


def _expert_level(obs: np.ndarray, expert_workers: int,
                  pcfg: PolicyConfig, cfg: TrainConfig) -> int:
    """Warm-start expert in level space: reject jobs below the value
    threshold (see ``admit_threshold``), else the heuristic's count."""
    if expert_workers <= 0:
        return 0
    best_utility = float(obs[env_mod.F_BEST_UTILITY]) * 100.0
    return 0 if best_utility < cfg.admit_threshold else pcfg.expert_level


def rollout_batch(params: Dict, pcfg: PolicyConfig, cfg: TrainConfig,
                  envs: Sequence[env_mod.ClusterSchedulingEnv],
                  instance_seeds: Sequence[int], key: jax.Array,
                  sampler) -> Tuple[np.ndarray, ...]:
    """Run one lockstep batch of episodes.

    Returns padded ``(obs (B,L,D), actions (B,L,2), credit (B,L),
    mask (B,L), experts (B,L,2), utilities (B,))`` with ``L =
    cfg.n_jobs`` (exactly one decision per in-horizon job).
    ``credit[b, k]`` is the *per-job* reward attribution: the realized
    utility of the job decided at step ``k`` (0 when rejected or never
    completed).  Credit sums to the episode's total utility like the
    env's stepwise reward but assigns each job's outcome to its own
    decision — the variance reduction that makes REINFORCE converge on
    200-decision episodes.  ``experts`` records the heuristic's action
    per decision (the anchor term's target)."""
    B, L, D = len(envs), cfg.n_jobs, pcfg.obs_dim
    obs_buf = np.zeros((B, L, D), np.float32)
    act_buf = np.zeros((B, L, 2), np.int32)
    exp_buf = np.zeros((B, L, 2), np.int32)
    credit = np.zeros((B, L), np.float32)
    jid_buf = np.full((B, L), -1, np.int64)
    mask = np.zeros((B, L), np.float32)
    cur = np.zeros((B, D), np.float32)
    done = np.zeros(B, bool)
    jids = np.full(B, -1, np.int64)
    experts = np.zeros((B, 2), np.int64)
    for i, e in enumerate(envs):
        o, info = e.reset(options={"instance": int(instance_seeds[i])})
        cur[i] = o
        done[i] = info.get("empty_trace", False)
        jids[i] = info.get("jid", -1)
        experts[i] = info.get("expert_action", (0, 0))
    steps = np.zeros(B, np.int64)
    r = 0
    while not done.all():
        key, sub = jax.random.split(key)
        actions = np.asarray(sampler(params, jnp.asarray(cur),
                                     jax.random.split(sub, B)))
        for i, e in enumerate(envs):
            if done[i]:
                continue
            obs_buf[i, steps[i]] = cur[i]
            act_buf[i, steps[i]] = actions[i]          # level space
            exp_buf[i, steps[i]] = (
                _expert_level(cur[i], int(experts[i, 0]), pcfg, cfg), 0)
            jid_buf[i, steps[i]] = jids[i]
            mask[i, steps[i]] = 1.0
            env_act = (pcfg.level_to_workers(int(actions[i, 0]),
                                             int(experts[i, 0])),
                       int(actions[i, 1]))
            o, _, d, _, info = e.step(env_act)
            steps[i] += 1
            cur[i] = o
            done[i] = d
            jids[i] = info.get("jid", -1)
            experts[i] = info.get("expert_action", (0, 0))
        r += 1
        assert r <= L, "more decisions than jobs in a trace"
    for i, e in enumerate(envs):
        res = e.result
        jmap = {j.jid: j for j in e.jobs}
        for k in range(int(steps[i])):
            jid = int(jid_buf[i, k])
            if jid in res.completion:
                credit[i, k] = jmap[jid].utility(
                    res.completion[jid] - res.arrivals[jid])
    utils = np.array([e.result.total_utility for e in envs], np.float32)
    return obs_buf, act_buf, credit, mask, exp_buf, utils


def _advantages(credit: np.ndarray, mask: np.ndarray,
                window: int) -> np.ndarray:
    """Input-driven whitened advantage (Decima-style baseline).

    The return for decision ``k`` is a *windowed* return-to-go over
    per-job credit: the decided job's own realized utility plus that of
    the next ``window`` decisions.  The queue right behind an admission
    is exactly where its externality lands (a greedy worker grab delays
    those jobs; rejecting a low-value job unclogs them), while the far
    future — which this action barely influences — would only add
    variance.  Jobs decided earlier stay out entirely.

    All rollouts in a batch share one instance, so decision index ``k``
    refers to the same job in every rollout; the baseline is the mean
    windowed return across rollouts at ``k`` and the advantage isolates
    what THIS rollout's actions changed, globally std-normalized."""
    c = credit * mask
    returns = np.flip(np.cumsum(np.flip(c, axis=1), axis=1), axis=1)
    if window and window < c.shape[1]:
        tail = np.zeros_like(returns)
        tail[:, :-window] = returns[:, window:]
        returns = returns - tail
    denom = np.maximum(mask.sum(axis=0), 1.0)
    baseline = (returns * mask).sum(axis=0) / denom          # (L,)
    adv = (returns - baseline[None]) * mask
    sd = adv[mask.astype(bool)].std() if mask.any() else 1.0
    return (adv / (sd + 1e-8)).astype(np.float32)


def behavior_clone(params: Dict, pcfg: PolicyConfig, cfg: TrainConfig,
                   log=print) -> Dict:
    """DL2-style supervised bootstrap: collect expert (FIFO-counts)
    episodes and maximize the policy's log-likelihood of the expert
    actions.  Starts REINFORCE at the heuristic's behavior instead of a
    uniform policy — the exploration then only has to find *deviations*
    that pay."""
    if cfg.bc_episodes <= 0 or cfg.bc_steps <= 0:
        return params
    env = _make_env(cfg)
    obs_rows: List[np.ndarray] = []
    act_rows: List[np.ndarray] = []
    for e in range(cfg.bc_episodes):
        obs, info = env.reset(options={
            "instance": int(cfg.train_seeds[e % len(cfg.train_seeds)])})
        done = info.get("empty_trace", False)
        while not done:
            expert = info["expert_action"]
            level = _expert_level(obs, int(expert[0]), pcfg, cfg)
            obs_rows.append(obs)
            act_rows.append(np.array([level, 0], np.int32))
            # follow the augmented expert so the cloned observation
            # distribution is its own trajectory, not plain FIFO's
            obs, _, done, _, info = env.step(
                expert if level > 0 else (0, 0))
    if not obs_rows:
        return params
    obs_b = jnp.asarray(np.stack(obs_rows))
    act_b = jnp.asarray(np.stack(act_rows))
    logp_fn = jax.vmap(
        lambda p, o, a: policy_mod.action_log_prob(p, o, a, pcfg)[0],
        in_axes=(None, 0, 0))
    optimizer = optax.adam(cfg.bc_lr)
    opt_state = optimizer.init(params)

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(
            lambda p: -logp_fn(p, obs_b, act_b).mean())(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return optax.apply_updates(params, updates), opt_state, loss

    loss = np.inf
    for _ in range(cfg.bc_steps):
        params, opt_state, loss = step(params, opt_state)
    if log:
        log(f"behavior cloning: {len(obs_rows)} expert decisions, "
            f"final NLL {float(loss):.3f}")
    return params


def make_update_fn(pcfg: PolicyConfig, cfg: TrainConfig, optimizer):
    logp_fn = jax.vmap(jax.vmap(
        lambda p, o, a: policy_mod.action_log_prob(p, o, a, pcfg),
        in_axes=(None, 0, 0)), in_axes=(None, 0, 0))

    def loss_fn(params, obs, act, adv, mask, expert, ent_coef):
        logp, ent = logp_fn(params, obs, act)
        logp_exp, _ = logp_fn(params, obs, expert)
        denom = jnp.maximum(mask.sum(), 1.0)
        pol = -(logp * adv * mask).sum() / denom
        entropy = (ent * mask).sum() / denom
        anchor = -(logp_exp * mask).sum() / denom
        return (pol - ent_coef * entropy
                + cfg.anchor_coef * anchor), (pol, entropy)

    @jax.jit
    def update(params, opt_state, obs, act, adv, mask, expert, ent_coef):
        (loss, (pol, ent)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, obs, act, adv, mask, expert,
                                   ent_coef)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return optax.apply_updates(params, updates), opt_state, loss, pol, ent

    return update


def train(cfg: TrainConfig = TrainConfig(),
          pcfg: PolicyConfig = PolicyConfig(),
          params: Optional[Dict] = None,
          log=print) -> Tuple[Dict, List[Dict]]:
    """Train a policy; returns ``(params, history)``.

    ``cfg.budget_seconds`` bounds wall time: training stops after the
    first iteration that crosses the budget (the acceptance bar is "≤ 5
    minutes on CPU").
    """
    if optax is None:
        raise ImportError("repro.rl.train requires optax "
                          "(policy inference does not)")
    if cfg.batch < 2:
        # with one rollout the input-driven baseline equals the rollout's
        # own return: advantages are identically zero and only the
        # anchor/entropy terms would train — silently learning nothing
        raise ValueError("TrainConfig.batch must be >= 2 (the cross-"
                         "rollout baseline needs at least two rollouts)")
    key = jax.random.PRNGKey(cfg.seed)
    key, init_key = jax.random.split(key)
    if params is None:
        params = policy_mod.policy_init(init_key, pcfg)
        params = behavior_clone(params, pcfg, cfg, log=log)
    optimizer = optax.adam(cfg.lr)
    opt_state = optimizer.init(params)
    update = make_update_fn(pcfg, cfg, optimizer)

    def _sample_explore(p, o, k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        a = policy_mod.sample_action(p, o, k1, pcfg)[0]
        u = jnp.stack([
            jax.random.randint(k2, (), 0, pcfg.n_worker_actions),
            jax.random.randint(k3, (), 0, pcfg.ps_slack_levels)])
        mix = jax.random.bernoulli(k4, cfg.explore_eps)
        return jnp.where(mix, u, a)

    sampler = jax.jit(jax.vmap(_sample_explore, in_axes=(None, 0, 0)))
    envs = [_make_env(cfg) for _ in range(cfg.batch)]
    history: List[Dict] = []
    ent_coef = cfg.entropy_coef
    best_params, best_val = params, -np.inf
    t0 = time.perf_counter()

    def _validate(params, it, elapsed):
        nonlocal best_params, best_val
        val = evaluate(params, pcfg, cfg.val_seeds, cfg=cfg,
                       schedulers=("learned",))["learned"]["mean_utility"]
        if val > best_val:
            best_params, best_val = params, val
        if log:
            log(f"iter {it:3d}  validation utility {val:8.1f} "
                f"(best {best_val:8.1f})  [{elapsed:6.1f}s]")

    if cfg.val_every:
        # score the warm start too: if REINFORCE only ever degrades it
        # (bad lr, noisy signal), the best iterate IS the warm start —
        # never return something worse than the policy training began at
        _validate(params, -1, time.perf_counter() - t0)
    for it in range(cfg.iterations):
        key, rkey = jax.random.split(key)
        # every rollout in the batch replays the SAME instance (only the
        # action noise differs): the per-step cross-rollout baseline in
        # _advantages needs comparable returns
        seeds = [cfg.train_seeds[it % len(cfg.train_seeds)]] * cfg.batch
        obs, act, rew, mask, expert, utils = rollout_batch(
            params, pcfg, cfg, envs, seeds, rkey, sampler)
        adv = _advantages(rew, mask, cfg.rtg_window)
        params, opt_state, loss, pol, ent = update(
            params, opt_state, jnp.asarray(obs), jnp.asarray(act),
            jnp.asarray(adv), jnp.asarray(mask), jnp.asarray(expert),
            jnp.asarray(ent_coef, jnp.float32))
        elapsed = time.perf_counter() - t0
        row = {"iteration": it, "loss": float(loss), "policy_loss": float(pol),
               "entropy": float(ent), "mean_utility": float(utils.mean()),
               "entropy_coef": ent_coef, "elapsed_seconds": elapsed}
        history.append(row)
        if log and (it % cfg.log_every == 0 or it == cfg.iterations - 1):
            log(f"iter {it:3d}  loss {row['loss']:+8.4f}  "
                f"entropy {row['entropy']:5.2f}  "
                f"mean utility {row['mean_utility']:8.1f}  "
                f"[{elapsed:6.1f}s]")
        if cfg.val_every and (it + 1) % cfg.val_every == 0:
            _validate(params, it, time.perf_counter() - t0)
        if cfg.budget_seconds and elapsed > cfg.budget_seconds:
            if log:
                log(f"stopping at iter {it}: budget "
                    f"{cfg.budget_seconds:.0f}s exceeded")
            break
    if cfg.val_every:
        if len(history) % cfg.val_every != 0:   # last iterate unvalidated
            _validate(params, len(history), time.perf_counter() - t0)
        return best_params, history
    return params, history


def evaluate(params: Dict, pcfg: PolicyConfig, seeds: Sequence[int],
             cfg: TrainConfig = TrainConfig(),
             schedulers: Sequence[str] = ("learned", "fifo")
             ) -> Dict[str, Dict[str, float]]:
    """Greedy-policy evaluation on held-out instances vs the baselines.

    Returns ``{scheduler: {"mean_utility": ..., "per_seed": {...}}}``."""
    out: Dict[str, Dict] = {}
    for name in schedulers:
        per = {}
        for s in seeds:
            cluster, jobs = env_mod.paper_instance(
                int(s), T=cfg.T, H=cfg.H, K=cfg.K, n_jobs=cfg.n_jobs,
                small=cfg.small)
            kw = {}
            if name == "learned":
                kw["policy"] = LearnedDecider(params, pcfg, cluster)
            elif name == "oasis":
                kw["quantum"] = 0
            r = engine.run(cluster, jobs, scheduler=name, check=False, **kw)
            per[str(s)] = float(r.total_utility)
        vals = np.array(list(per.values()))
        out[name] = {"mean_utility": float(vals.mean()), "per_seed": per}
    return out


def smoke_config(seed: int = 0) -> Tuple[TrainConfig, PolicyConfig]:
    """The tiny shared smoke instance (T=32, 8+8 servers, 24 jobs, 2
    iterations) used by both the CI gate (``--smoke``) and the quick
    scoreboard (``figs.rl_scoreboard(quick=True)``) — one definition so
    the two cannot drift."""
    return (TrainConfig(iterations=2, batch=4, T=32, H=8, K=8, n_jobs=24,
                        small=False, train_seeds=(100, 101, 102, 103),
                        val_every=0, seed=seed),
            PolicyConfig(max_workers=16))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _smoke(args) -> int:
    """CI gate: 2 iterations on a tiny instance — loss finite, a
    checkpoint round-trips to an identical greedy evaluation."""
    import tempfile
    cfg, pcfg = smoke_config(seed=args.seed)
    params, history = train(cfg, pcfg)
    assert len(history) == 2, history
    assert all(np.isfinite(h["loss"]) for h in history), history
    with tempfile.TemporaryDirectory() as d:
        policy_mod.save_policy(d, params, pcfg, step=len(history))
        re_params, re_cfg, _ = policy_mod.load_policy(d)
        assert re_cfg == pcfg
        a = evaluate(params, pcfg, seeds=(9,), cfg=cfg,
                     schedulers=("learned",))
        b = evaluate(re_params, re_cfg, seeds=(9,), cfg=cfg,
                     schedulers=("learned",))
        assert a["learned"]["per_seed"] == b["learned"]["per_seed"], (a, b)
    print("rl_smoke PASS: loss finite over 2 iterations, "
          "checkpoint round-trip evaluation identical "
          f"(utility {a['learned']['mean_utility']:.2f})")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    # CLI defaults == TrainConfig defaults (instance family + optimizer).
    # The tracked BENCH_decision.json rl row additionally overrides
    # --iterations 160 --budget-seconds 270 (see figs.rl_scoreboard).
    dflt = TrainConfig()
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--iterations", type=int, default=dflt.iterations)
    ap.add_argument("--batch", type=int, default=dflt.batch)
    ap.add_argument("--lr", type=float, default=dflt.lr)
    ap.add_argument("--entropy", type=float, default=dflt.entropy_coef)
    ap.add_argument("--seed", type=int, default=dflt.seed)
    ap.add_argument("--T", type=int, default=dflt.T)
    ap.add_argument("--servers", type=int, default=dflt.H,
                    help="H and K (paper scale: 50+50)")
    ap.add_argument("--jobs", type=int, default=dflt.n_jobs)
    ap.add_argument("--small", action="store_true",
                    help="shrunk job internals (equivalence-suite family)")
    ap.add_argument("--budget-seconds", type=float, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--eval-seeds", default="5,6,7",
                    help="held-out instance seeds for the final eval")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: 2 iterations on a tiny instance + checkpoint "
                         "round-trip assertion")
    args = ap.parse_args(argv)
    if args.smoke:
        return _smoke(args)
    cfg = TrainConfig(iterations=args.iterations, batch=args.batch,
                      lr=args.lr, entropy_coef=args.entropy, seed=args.seed,
                      T=args.T, H=args.servers, K=args.servers,
                      n_jobs=args.jobs, small=args.small,
                      budget_seconds=args.budget_seconds)
    pcfg = PolicyConfig()
    params, history = train(cfg, pcfg)
    seeds = [int(s) for s in args.eval_seeds.split(",") if s]
    ev = evaluate(params, pcfg, seeds, cfg=cfg,
                  schedulers=("learned", "fifo"))
    for name, stats in ev.items():
        print(f"{name:8s} mean utility {stats['mean_utility']:8.1f}  "
              + "  ".join(f"s{s}={v:.1f}"
                          for s, v in stats["per_seed"].items()))
    if args.ckpt_dir:
        path = policy_mod.save_policy(
            args.ckpt_dir, params, pcfg, step=len(history),
            extra={"history_tail": history[-3:], "eval": ev})
        print(f"checkpoint -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
