"""Learned-scheduler subsystem (Decima / DL2 direction).

``env``     — Gymnasium-style ``ClusterSchedulingEnv`` exposing the sim-v2
              engine as a stepwise per-arrival decision process (exactly
              equivalence-tested against ``sim.engine.run`` for OASiS and
              all four reactive baselines, tests/test_rl_env.py).
``policy``  — jax policy network (MLP + single-head attention over the
              capacity window, built from ``models/layers.py`` specs) and
              the ``LearnedDecider`` adapter that plugs a trained policy
              into ``engine.run(scheduler="learned")``.
``train``   — REINFORCE-with-baseline training loop (optax, vmapped
              batched rollouts, checkpointing via ``ckpt/checkpoint.py``).
"""
from . import env, policy

__all__ = ["env", "policy"]
