"""jax policy network for the cluster-scheduling env.

Architecture (small, CPU-trainable in seconds per iteration):

* the observation's two capacity windows (2W slot tokens of R free-
  capacity fractions each, tagged with slot offset + pool id) go through
  a **single-head attention read-out**: keys/values from the tokens, the
  query from the embedded job features — "which upcoming slots matter
  for this job";
* the job embedding and the attention context feed a silu MLP trunk with
  an rms-normed residual stream (``models/layers.py`` primitives);
* two categorical heads: worker count (0 = reject, else 1..max_workers)
  and PS slack (extra parameter servers on top of the bandwidth-matched
  minimum).

Parameters are built from ``models.layers.P`` specs via ``init_params``
— the same spec machinery the transformer blocks use — so the policy
checkpoints through ``ckpt/checkpoint.py`` like any other model tree.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import checkpoint
from ..core.types import R
from ..models.layers import P, init_params, rmsnorm
from ..sim.engine import DECISION_WINDOW, DecisionPoint
from . import env as env_mod

N_TOKENS = 2 * DECISION_WINDOW          # worker window + PS window
TOKEN_DIM = R + 2                       # free fractions + slot pos + pool id


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """The worker head picks a *multiplier on the expert's worker count*
    (0 = reject) instead of an absolute count: the heuristic prior
    ("×1") is then a single constant logit pattern — trivially stable
    under noisy policy gradients — and exploration only has to rank the
    few ``worker_levels``, not 33 counts.  ``level_to_workers`` maps
    back to the env's count action, capped at ``max_workers``."""

    obs_dim: int = env_mod.OBS_DIM
    d_model: int = 64
    worker_levels: Tuple[float, ...] = (0.0, 0.5, 1.0, 1.5, 2.0)
    max_workers: int = env_mod.MAX_WORKERS
    ps_slack_levels: int = env_mod.PS_SLACK_LEVELS

    @property
    def n_worker_actions(self) -> int:
        return len(self.worker_levels)

    @property
    def expert_level(self) -> int:
        return self.worker_levels.index(1.0)

    @property
    def n_scalars(self) -> int:
        return self.obs_dim - N_TOKENS * R

    def level_to_workers(self, level: int, expert_workers: int) -> int:
        """Env worker-count action for one sampled level."""
        mult = self.worker_levels[int(level)]
        if mult <= 0.0 or expert_workers <= 0:
            return 0
        return int(np.clip(round(mult * expert_workers), 1,
                           self.max_workers))


def policy_spec(cfg: PolicyConfig) -> Dict:
    d = cfg.d_model
    return {
        "job": {"w": P((cfg.n_scalars, d), (None, "embed")),
                "b": P((d,), (None,), "zeros")},
        "tok": {"w": P((TOKEN_DIM, d), (None, "embed"))},
        "attn": {"q": P((d, d), ("embed", "heads")),
                 "k": P((d, d), ("embed", "heads")),
                 "v": P((d, d), ("embed", "heads"))},
        "norm": {"w": P((2 * d,), (None,), "zeros")},
        "mlp": {"w1": P((2 * d, d), ("embed", "mlp")),
                "b1": P((d,), (None,), "zeros"),
                "w2": P((d, d), ("mlp", "embed")),
                "b2": P((d,), (None,), "zeros")},
        "head_w": {"w": P((d, cfg.n_worker_actions), ("embed", None),
                          scale=0.01),
                   "b": P((cfg.n_worker_actions,), (None,), "zeros")},
        "head_s": {"w": P((d, cfg.ps_slack_levels), ("embed", None),
                          scale=0.01),
                   "b": P((cfg.ps_slack_levels,), (None,), "zeros")},
    }


def policy_init(key: jax.Array, cfg: PolicyConfig) -> Dict:
    return init_params(key, policy_spec(cfg), dtype=jnp.float32)


# static per-token tags: slot offset within the window, pool id
_TOKEN_TAGS = np.concatenate([
    np.stack([np.arange(DECISION_WINDOW) / DECISION_WINDOW,
              np.zeros(DECISION_WINDOW)], axis=1),
    np.stack([np.arange(DECISION_WINDOW) / DECISION_WINDOW,
              np.ones(DECISION_WINDOW)], axis=1),
]).astype(np.float32)                    # (2W, 2)


def policy_logits(params: Dict, obs: jax.Array,
                  cfg: PolicyConfig) -> Tuple[jax.Array, jax.Array]:
    """(worker-head logits, slack-head logits) for one observation."""
    scalars = obs[:cfg.n_scalars]
    tokens = obs[cfg.n_scalars:].reshape(N_TOKENS, R)
    tokens = jnp.concatenate([tokens, jnp.asarray(_TOKEN_TAGS)], axis=1)
    x = scalars @ params["job"]["w"] + params["job"]["b"]        # (d,)
    tok = tokens @ params["tok"]["w"]                            # (2W, d)
    q = x @ params["attn"]["q"]
    k = tok @ params["attn"]["k"]
    v = tok @ params["attn"]["v"]
    a = jax.nn.softmax(k @ q / jnp.sqrt(jnp.asarray(q.shape[-1], x.dtype)))
    ctx = a @ v                                                  # (d,)
    h = rmsnorm(jnp.concatenate([x, ctx]), params["norm"]["w"])
    h = jax.nn.silu(h @ params["mlp"]["w1"] + params["mlp"]["b1"])
    h = h + jax.nn.silu(h @ params["mlp"]["w2"] + params["mlp"]["b2"])
    return (h @ params["head_w"]["w"] + params["head_w"]["b"],
            h @ params["head_s"]["w"] + params["head_s"]["b"])


def sample_action(params: Dict, obs: jax.Array, key: jax.Array,
                  cfg: PolicyConfig) -> Tuple[jax.Array, jax.Array]:
    """Sample ``(action (2,), joint log-prob)`` for one observation."""
    lw, ls = policy_logits(params, obs, cfg)
    kw, ks = jax.random.split(key)
    aw = jax.random.categorical(kw, lw)
    asl = jax.random.categorical(ks, ls)
    logp = (jax.nn.log_softmax(lw)[aw] + jax.nn.log_softmax(ls)[asl])
    return jnp.stack([aw, asl]), logp


def greedy_action(params: Dict, obs: jax.Array,
                  cfg: PolicyConfig) -> jax.Array:
    lw, ls = policy_logits(params, obs, cfg)
    return jnp.stack([jnp.argmax(lw), jnp.argmax(ls)])


def action_log_prob(params: Dict, obs: jax.Array, action: jax.Array,
                    cfg: PolicyConfig) -> Tuple[jax.Array, jax.Array]:
    """(joint log-prob of ``action``, summed head entropy) — the
    REINFORCE loss terms for one (obs, action) pair."""
    lw, ls = policy_logits(params, obs, cfg)
    lpw, lps = jax.nn.log_softmax(lw), jax.nn.log_softmax(ls)
    ent = -(jnp.exp(lpw) @ lpw) - (jnp.exp(lps) @ lps)
    return lpw[action[0]] + lps[action[1]], ent


# ---------------------------------------------------------------------------
# checkpointing (ckpt/checkpoint.py: manifest + crc32'd npz, atomic publish)
# ---------------------------------------------------------------------------

def save_policy(ckpt_dir: str, params: Dict, cfg: PolicyConfig,
                step: int = 0, extra: Optional[Dict] = None) -> Path:
    meta = {"policy_cfg": dataclasses.asdict(cfg), **(extra or {})}
    return checkpoint.save(ckpt_dir, step, params, extra=meta)


def load_policy(ckpt_dir: str, step: Optional[int] = None
                ) -> Tuple[Dict, PolicyConfig, Dict]:
    """Restore ``(params, cfg, extra)`` from the latest (or given) step."""
    if step is None:
        step = checkpoint.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    manifest = json.loads(
        (Path(ckpt_dir) / f"ckpt_{step}" / "manifest.json").read_text())
    raw = dict(manifest["extra"]["policy_cfg"])
    raw["worker_levels"] = tuple(raw["worker_levels"])   # json list -> tuple
    cfg = PolicyConfig(**raw)
    target = policy_init(jax.random.PRNGKey(0), cfg)
    params, extra = checkpoint.restore(ckpt_dir, step, target)
    return params, cfg, extra


# ---------------------------------------------------------------------------
# engine adapter
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _greedy_jit(cfg: PolicyConfig):
    """One compiled greedy forward pass per config (jit caches on
    function identity, so a fresh ``jax.jit(lambda ...)`` per decider
    would retrace every time)."""
    return jax.jit(lambda p, o: greedy_action(p, o, cfg))


@functools.lru_cache(maxsize=None)
def _sample_jit(cfg: PolicyConfig):
    return jax.jit(lambda p, o, k: sample_action(p, o, k, cfg)[0])


class LearnedDecider:
    """``engine.run(..., policy=...)``-compatible callable around a policy.

    Greedy by default (deterministic eval); ``greedy=False`` samples with
    a seeded key stream.  The observation needs the cluster spec, which
    the engine does not pass — it is bound at construction.
    """

    def __init__(self, params: Dict, cfg: PolicyConfig, cluster,
                 greedy: bool = True, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.cluster = cluster
        self.greedy = greedy
        self._key = jax.random.PRNGKey(seed)
        if greedy:
            self._fn = _greedy_jit(cfg)
            warm_args = ()
        else:
            self._fn = _sample_jit(cfg)
            warm_args = (self._key,)
        # compile now (a cache hit after the first decider per config):
        # the engine times every policy call into decision_seconds, and
        # the one-off jit compile would otherwise be recorded as the
        # first decision's latency
        self._fn(self.params, jnp.zeros(cfg.obs_dim, jnp.float32),
                 *warm_args)

    def __call__(self, dp: DecisionPoint):
        obs = jnp.asarray(env_mod.observe(dp, self.cluster))
        if self.greedy:
            action = self._fn(self.params, obs)
        else:
            self._key, sub = jax.random.split(self._key)
            action = self._fn(self.params, obs, sub)
        level, slack = np.asarray(action)
        w = self.cfg.level_to_workers(int(level), int(dp.expert[0]))
        return env_mod.engine_action(dp, (w, int(slack)))


def default_policy(cluster, seed: int = 0,
                   cfg: Optional[PolicyConfig] = None) -> LearnedDecider:
    """A deterministic seed-initialized (untrained) policy decider — the
    CI smoke column's stand-in when no checkpoint is supplied."""
    cfg = cfg or PolicyConfig()
    return LearnedDecider(policy_init(jax.random.PRNGKey(seed), cfg), cfg,
                          cluster, greedy=True)
