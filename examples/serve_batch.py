"""Serve a small model with batched requests: prefill + decode loop over
the sharded KV cache (the serving path the decode_* dry-run cells lower).

    PYTHONPATH=src python examples/serve_batch.py
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import decode_step, init_cache, init_model
from repro.models.model import prefill


def pad_cache(prefill_cache, full_cache):
    """Place prefill K/V (length = prompt) into the pre-allocated buffers."""
    def one(small, big):
        if small is None:
            return big
        if small.shape == big.shape:
            return small.astype(big.dtype)
        pads = [(0, b - s) for s, b in zip(small.shape, big.shape)]
        return jnp.pad(small.astype(big.dtype), pads)
    return jax.tree_util.tree_map(one, prefill_cache, full_cache)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    # prefill the batch of requests
    t0 = time.time()
    logits, pc = jax.jit(
        lambda p, b: prefill(p, cfg, b, args.prompt_len))(params,
                                                          {"tokens": prompts})
    cache = pad_cache(pc, init_cache(cfg, args.batch, max_len))
    t_prefill = time.time() - t0

    step = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l, None))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        lg, cache = step(params, tok, cache, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(lg[:, 0, :cfg.vocab_size], -1)[:, None]
        out.append(tok)
    gen = np.asarray(jnp.concatenate(out, 1))
    t_decode = time.time() - t0
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.0f} ms   decode: {t_decode*1e3:.0f} ms "
          f"({tps:.1f} tok/s aggregate, CPU interpret)")
    print(f"first request tokens: {gen[0][:16].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab_size)
    print("OK")


if __name__ == "__main__":
    main()
