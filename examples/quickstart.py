"""Quickstart: schedule a burst of ML training jobs with OASiS and
compare against FIFO/DRF/RRH/Dorm — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


from repro.core import OASiS, price_params_from_jobs
from repro.sim import make_cluster, make_jobs, simulate


def main():
    # a shared GPU cluster: 20 worker servers + 20 PS servers, 100 slots
    cluster = make_cluster(T=100, H=20, K=20)
    # 50 training jobs arriving online (paper Sec. V-A parameter ranges)
    jobs = make_jobs(50, T=100, seed=0, small=False)

    print("== per-scheduler totals ==")
    for name in ["oasis", "fifo", "drf", "rrh", "dorm"]:
        kw = dict(quantum=0) if name == "oasis" else {}
        r = simulate(cluster, jobs, scheduler=name, **kw)
        print(f"{name:6s} utility={r.total_utility:9.1f} "
              f"accepted={r.accepted:3d} completed={r.completed:3d} "
              f"gpu-util={r.utilization:.2f}")

    # inspect one OASiS decision in detail
    params = price_params_from_jobs(jobs, cluster)
    sched = OASiS(cluster, params)
    job = sorted(jobs, key=lambda j: j.arrival)[0]
    s = sched.on_arrival(job)
    if s is not None:
        per_slot = {t: int(y.sum()) for t, y in sorted(s.workers.items())}
        print(f"\njob {job.jid}: admitted, finish slot {s.finish}, "
              f"payoff {s.payoff:.2f}")
        print(f"  elastic worker plan (slot -> workers): {per_slot}")
        print("  note the time-varying worker count — the paper's key knob.")
    else:
        print(f"\njob {job.jid}: rejected (payoff <= 0)")


if __name__ == "__main__":
    main()
