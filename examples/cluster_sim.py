"""Reproduce the paper's headline comparison (Figs. 3-4) at configurable
scale and print a small ASCII chart.

    PYTHONPATH=src python examples/cluster_sim.py --jobs 60 --T 100

Or drive one of the sim-v2 scenarios (heterogeneous fleets, mid-run
cancellation, stragglers, U/L mis-estimation, 10x-paper scale):

    PYTHONPATH=src python examples/cluster_sim.py --scenario cancel
    PYTHONPATH=src python examples/cluster_sim.py --scenario straggler --quick

The scale scenario (alias ``scale10x``) accepts ``--scheduler`` to run a
single scheduler — including OASiS itself on the fused jit engine against
the device-resident price state, and the rl/ subsystem's learned policy
scheduler — and prints per-decision latency percentiles for plan-ahead
schedulers:

    PYTHONPATH=src python examples/cluster_sim.py --scenario scale10x \
        --scheduler oasis --quick
    PYTHONPATH=src python examples/cluster_sim.py --scenario scale10x \
        --scheduler learned --policy-ckpt runs/learned --quick

(``--policy-ckpt`` points at a ``repro.rl.train`` checkpoint directory
and is required for ``--scheduler learned`` — an untrained net is a
benchmark-harness pipeline exercise, not something to demo.)

The serving scenario streams an open-ended diurnal x bursty trace
through the rolling-window engine and prints sustained decisions/sec
plus the resident price-window bytes per scheduler:

    PYTHONPATH=src python examples/cluster_sim.py --scenario serving --quick

The churn scenario fails a seeded fraction of each server pool mid-run,
preempts the victims with checkpoint/restart cost, and prints the
utility-retention table (churned / churn-free utility per scheduler and
churn level — higher is better):

    PYTHONPATH=src python examples/cluster_sim.py --scenario churn --quick
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import obs as obslib
from repro.sim import make_cluster, make_jobs, simulate
from repro.sim.scenarios import ALL_SCHEDULERS, SCENARIOS, run_scenario


def bar(v, vmax, width=40):
    n = int(width * v / max(vmax, 1e-9))
    return "#" * n


def print_decide_profile():
    """Stage breakdown accumulated by the fused engine under
    ``REPRO_DECIDE_PROFILE=1`` (see ``core.schedule_jax
    .decide_profile_snapshot`` — profiling re-runs the DP launch to
    split row build from the sweep, so latencies roughly double)."""
    from repro.core.schedule_jax import decide_profile_snapshot
    snap = decide_profile_snapshot()
    n = max(snap.get("decisions", 0.0), 1.0)
    print("\n== decision stage breakdown "
          f"({int(n)} fused decisions; REPRO_DECIDE_PROFILE) ==")
    for stage in ("row_build", "dp_sweep", "backtrack", "placement"):
        tot = snap.get(stage, 0.0)
        print(f"{stage:10s} {tot:8.2f}s total  "
              f"{tot / n * 1e3:8.2f}ms/decision")


def run_figs(args):
    summaries = {}
    gaps = {}
    for seed in range(args.seeds):
        cluster = make_cluster(T=args.T, H=args.servers, K=args.servers)
        jobs = make_jobs(args.jobs, T=args.T, seed=seed, small=False)
        for name in ["oasis", "fifo", "drf", "rrh", "dorm"]:
            kw = dict(quantum=0) if name == "oasis" else {}
            r = simulate(cluster, jobs, scheduler=name, check=False, **kw)
            summaries.setdefault(name, []).append(r.summary())
            if r.target_gap:
                gaps.setdefault(name, []).extend(r.target_gap)

    def mean_of(name, key):
        vals = [s[key] for s in summaries[name] if s[key] is not None]
        return float(np.mean(vals)) if vals else float("nan")

    print(f"== per-scheduler episode summary "
          f"(mean of {args.seeds} seeds; Fig. 3) ==")
    means = {k: mean_of(k, "total_utility") for k in summaries}
    vmax = max(means.values())
    for k, v in sorted(means.items(), key=lambda kv: -kv[1]):
        print(f"{k:6s} {v:9.1f}  acc={mean_of(k, 'accept_rate'):5.2f} "
              f"comp={mean_of(k, 'completion_rate'):5.2f} "
              f"p50-lat={mean_of(k, 'p50_latency'):6.1f} "
              f"p95-lat={mean_of(k, 'p95_latency'):6.1f}  {bar(v, vmax)}")

    print("\n== completion - target time (mean abs; Fig. 4) ==")
    for k in means:
        g = gaps.get(k, [])
        print(f"{k:6s} {np.mean(np.abs(g)) if g else float('nan'):8.2f} "
              f"(n={len(g)})")


def run_one_scenario(args):
    name = "scale" if args.scenario == "scale10x" else args.scenario
    kw = {}
    if args.scheduler:
        kw["schedulers"] = (args.scheduler,)
    if args.policy_ckpt:
        kw["policy_ckpt"] = args.policy_ckpt
    rows = run_scenario(name, seed=args.seed, quick=args.quick, **kw)
    print(f"== scenario: {args.scenario} "
          f"(seed={args.seed}{', quick' if args.quick else ''}) ==")
    vmax = max(r.utility for r in rows)
    for r in rows:
        extra = f" canceled={r.canceled}" if r.canceled else ""
        print(f"{r.scheduler:6s} {r.variant:14s} {r.utility:9.1f} "
              f"acc={r.accepted:4d} comp={r.completed:4d} "
              f"util={r.utilization:5.2f} {r.wall_seconds:7.2f}s{extra}  "
              f"{bar(r.utility, vmax, width=24)}")
    decided = [r for r in rows if r.decision_p50 is not None]
    if decided:
        print("\n== per-decision latency (plan-ahead schedulers) ==")
        for r in decided:
            print(f"{r.scheduler:6s} {r.variant:14s} "
                  f"p50={r.decision_p50*1e3:8.2f}ms "
                  f"p95={r.decision_p95*1e3:8.2f}ms "
                  f"mean={r.decision_mean*1e3:8.2f}ms")
    churned = [r for r in rows if r.retention is not None]
    if churned:
        print("\n== utility retention under fleet churn "
              "(churned / churn-free; higher is better) ==")
        for r in churned:
            lf = f" live={r.live_frac:.2f}" if r.live_frac is not None else ""
            print(f"{r.scheduler:6s} {r.variant:14s} ret={r.retention:6.3f} "
                  f"preempted={r.preempted:3d} dropped={r.preempt_dropped:3d}"
                  f"{lf}  {bar(r.retention, 1.0, width=24)}")
    streamed = [r for r in rows if r.decisions_per_sec is not None]
    if streamed:
        print("\n== sustained throughput (streamed trace) ==")
        for r in streamed:
            wb = (f" window={r.window_bytes/1024:.0f}KiB"
                  if r.window_bytes else "")
            print(f"{r.scheduler:6s} {r.decisions_per_sec:10.1f} "
                  f"decisions/sec over {r.n_jobs} jobs{wb}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=60)
    ap.add_argument("--T", type=int, default=100)
    ap.add_argument("--servers", type=int, default=20)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--scenario", default=None,
                    choices=sorted(SCENARIOS) + ["scale10x"],
                    help="run a sim-v2 scenario instead of the Fig. 3/4 "
                         "comparison (scale10x = alias for scale)")
    ap.add_argument("--scheduler", default=None,
                    choices=list(ALL_SCHEDULERS) + ["learned"],
                    help="scale/serving scenarios only: run this single "
                         "scheduler (oasis uses the fused jit engine; "
                         "learned runs the rl/ policy scheduler)")
    ap.add_argument("--policy-ckpt", default=None,
                    help="checkpoint directory from repro.rl.train "
                         "(required for --scheduler learned)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="shrink the scenario instance")
    ap.add_argument("--profile", action="store_true",
                    help="record per-stage decision wall clock in the "
                         "fused engine (row build / DP sweep / backtrack "
                         "/ placement) and print the breakdown; roughly "
                         "doubles decision latency")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record the run with the flight recorder "
                         "(repro.obs) and write a Chrome-trace / Perfetto "
                         "JSON with the metrics snapshot embedded — open "
                         "it at https://ui.perfetto.dev")
    args = ap.parse_args()
    if args.profile:
        os.environ["REPRO_DECIDE_PROFILE"] = "1"
    if args.scheduler and args.scenario not in ("scale", "scale10x",
                                                "serving"):
        ap.error("--scheduler only applies to --scenario "
                 f"scale/scale10x/serving (got --scenario {args.scenario})")
    if args.policy_ckpt and args.scheduler != "learned":
        ap.error("--policy-ckpt only applies to --scheduler learned")
    if args.scheduler == "learned" and not args.policy_ckpt:
        ap.error("--scheduler learned requires --policy-ckpt "
                 "(a repro.rl.train checkpoint directory)")
    ob = obslib.enable() if args.trace else None
    if args.scenario:
        run_one_scenario(args)
    else:
        run_figs(args)
    if ob is not None:
        n = ob.export_chrome(args.trace)
        snap = ob.metrics.snapshot()
        print(f"\n== flight recorder ==\n{n} trace events -> {args.trace} "
              f"({len(snap['counters'])} counters, "
              f"{len(snap['histograms'])} histograms embedded)")
        obslib.disable()
    if args.profile:
        print_decide_profile()


if __name__ == "__main__":
    main()
