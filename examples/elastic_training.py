"""End-to-end driver: OASiS schedules a real training job, and the elastic
runtime executes it — re-meshing between slots as the planned worker
count changes, with async checkpointing and exact data-cursor resume.

The model is a ~100M-param dense transformer (use --tiny for CI).  On
this CPU container "workers" map to dp slices of the host mesh; on a
real cluster the identical driver re-shards across pods.

    PYTHONPATH=src python examples/elastic_training.py --steps 300
    PYTHONPATH=src python examples/elastic_training.py --tiny
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OASiS, job_from_arch, price_params_from_jobs
from repro.data.pipeline import DataConfig
from repro.models import init_model
from repro.models.config import ModelConfig
from repro.runtime.elastic import ElasticTrainer, schedule_to_plan
from repro.sim import make_cluster
from repro.train.optimizer import OptConfig, init_opt
from repro.train.steps import make_train_step

M100 = ModelConfig(name="m100", family="dense", n_layers=10, d_model=768,
                   n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072,
                   vocab_size=32000, dtype="float32", param_dtype="float32",
                   remat=False)
TINY = M100.scaled(name="m-tiny", n_layers=2, d_model=128, d_ff=256,
                   vocab_size=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_elastic_ckpt")
    args = ap.parse_args()
    cfg = TINY if args.tiny else M100
    if args.tiny:
        args.steps = min(args.steps, 30)

    # 1) OASiS plans the job: resource terms derived from the model itself
    cluster = make_cluster(T=50, H=10, K=10)
    from repro.models.layers import is_spec
    from repro.models.model import model_specs
    specs, _ = jax.tree_util.tree_flatten(model_specs(cfg), is_leaf=is_spec)
    n_params = sum(int(np.prod(s.shape)) for s in specs)
    job = job_from_arch(cfg.name, arrival=0, flops_per_token=6 * n_params,
                        param_bytes=4 * n_params,
                        tokens_per_step=args.seq * args.batch,
                        target_steps=args.steps)
    sched = OASiS(cluster, price_params_from_jobs([job], cluster))
    s = sched.on_arrival(job)
    assert s is not None, "job rejected?!"
    plan = schedule_to_plan(s)
    steps_per_slot = max(1, args.steps // max(len(plan), 1))
    plan = plan[:max(1, args.steps // steps_per_slot)]
    print(f"OASiS plan: finish={s.finish} payoff={s.payoff:.2f} "
          f"workers/slot={[p.n_workers for p in plan]}")

    # 2) elastic execution of the plan
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=0)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                        weight_decay=0.01)

    def make_step(mesh):
        fn, in_sh, out_sh = make_train_step(cfg, mesh, opt_cfg)
        jfn = jax.jit(fn)
        def wrapped(params, opt, batch):
            return jfn(params, opt, {k: jnp.asarray(v)
                                     for k, v in batch.items()})
        return wrapped, in_sh[0], in_sh[1]

    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt(params, opt_cfg)
    trainer = ElasticTrainer(cfg, opt_cfg, data_cfg, args.ckpt, make_step,
                             steps_per_slot=steps_per_slot)
    t0 = time.time()
    out = trainer.run(plan, params, opt)
    dt = time.time() - t0
    ces = [m["ce"] for m in trainer.metrics_log]
    n = max(1, len(ces) // 10)
    print(f"\ntrained {out['steps']} steps in {dt:.0f}s "
          f"({n_params/1e6:.1f}M params); dp widths used: "
          f"{trainer.mesh_history}")
    print(f"loss: first10={np.mean(ces[:n]):.3f} last10={np.mean(ces[-n:]):.3f}")
    assert np.mean(ces[-n:]) < np.mean(ces[:n]), "loss did not decrease"
    print("OK: loss decreased across elastic re-meshes")


if __name__ == "__main__":
    main()
